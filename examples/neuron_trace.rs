//! Fig.-4-style single-neuron trace on the cycle-accurate RTL core:
//! integrate → threshold crossing → hard reset, with the pruning mask
//! visible once the neuron has fired its calibrated quota.
//!
//! ```bash
//! make artifacts && cargo run --release --example neuron_trace [-- <class>]
//! ```

use snn_rtl::data::{codec, DigitGen};
use snn_rtl::rtl::RtlCore;
use snn_rtl::runtime::Manifest;

type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn main() -> Result<()> {
    let class: u8 = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(3);
    let manifest = Manifest::load("artifacts")
        .map_err(|e| format!("run `make artifacts` first: {e}"))?;
    let weights = codec::load_weights(manifest.path("weights.bin"))?;
    let cfg = manifest.snn_config()?;
    let v_th = cfg.v_th;

    let img = DigitGen::new(manifest.u32("test_seed")?).sample(class, 0);
    println!("{}", img.to_ascii());

    let mut core = RtlCore::new(cfg, weights.weights)?;
    let r = core.run(&img, 0xC0FFEE)?;
    println!(
        "RTL run: class {} in {} cycles ({:.1} µs @ 40 MHz), {:.1} nJ dynamic",
        r.class,
        r.cycles,
        r.energy.time_us,
        r.energy.dynamic_nj
    );

    let neuron = class as usize;
    let max_v = r
        .membrane_by_step
        .iter()
        .map(|m| m[neuron])
        .max()
        .unwrap_or(1)
        .max(v_th);
    println!("\nneuron {neuron} membrane (| marks V_th = {v_th}):");
    for (t, (mem, spikes)) in r.membrane_by_step.iter().zip(&r.spikes_by_step).enumerate() {
        let v = mem[neuron];
        let width = 56usize;
        let bar = if v <= 0 { 0 } else { v as usize * width / max_v as usize };
        let th = v_th as usize * width / max_v as usize;
        let mut line: Vec<char> = vec![' '; width + 1];
        for c in line.iter_mut().take(bar) {
            *c = '#';
        }
        if th < line.len() {
            line[th] = '|';
        }
        println!(
            "t={t:>2} {v:>7} {}{}",
            line.iter().collect::<String>(),
            if spikes[neuron] { "  << FIRE (hard reset)" } else { "" }
        );
    }
    println!("\nspike counts: {:?}", r.spike_counts);
    println!(
        "activity: {} adds, {} shifts, {} BRAM reads, {} PRNG steps, {} reg-bit toggles",
        r.activity.adds,
        r.activity.shifts,
        r.activity.bram_reads,
        r.activity.prng_steps,
        r.activity.reg_toggles
    );
    Ok(())
}
