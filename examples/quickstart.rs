//! Quickstart: load the trained artifacts, classify a handful of digits on
//! the pure-Rust behavioral backend, and print what happened.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use snn_rtl::data::{codec, DigitGen};
use snn_rtl::runtime::Manifest;
use snn_rtl::snn::BehavioralNet;

type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn main() -> Result<()> {
    // 1. Load the calibrated artifacts (built by `make artifacts`).
    let manifest = Manifest::load("artifacts")
        .map_err(|e| format!("artifacts/ missing — run `make artifacts` first: {e}"))?;
    let weights = codec::load_weights(manifest.path("weights.bin"))?;
    let cfg = manifest.snn_config()?;
    println!(
        "loaded 784x10 SNN: V_th={} decay=2^-{} prune={:?} window={} steps",
        cfg.v_th, cfg.decay_shift, cfg.prune, cfg.timesteps
    );

    // 2. Build the behavioral network (bit-equivalent to the RTL core and
    //    the compiled JAX/Pallas stack — see rust/tests/golden.rs).
    let net = BehavioralNet::new(cfg, weights.weights)?;

    // 3. Classify one sample of every digit class.
    let gen = DigitGen::new(manifest.u32("test_seed")?);
    let mut hits = 0;
    for class in 0u8..10 {
        let img = gen.sample(class, 42);
        let out = net.classify(&img, 0x5EED + u32::from(class));
        let ok = out.class == class;
        hits += u32::from(ok);
        println!(
            "digit {class}: predicted {} {} spike counts {:?}",
            out.class,
            if ok { "ok " } else { "MISS" },
            out.spike_counts
        );
    }
    println!("{hits}/10 correct");

    // 4. Show one digit + its winning neuron's evidence.
    let img = gen.sample(7, 42);
    println!("{}", img.to_ascii());
    let (out, traces) = net.classify_traced(&img, 0x5EED + 7, 10);
    println!("class {}; neuron 7 membrane over time:", out.class);
    for (t, tr) in traces.iter().enumerate() {
        println!(
            "  t={t:>2} membrane {:>6} current {:>6} fired {}",
            tr.membrane[7], tr.input_current[7], tr.fired[7]
        );
    }
    Ok(())
}
