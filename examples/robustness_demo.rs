//! Fig.-8-style robustness demo: one digit under the paper's perturbation
//! suite, rendered side by side with the classifier's verdict, then a
//! small accuracy sweep.
//!
//! ```bash
//! make artifacts && cargo run --release --example robustness_demo
//! ```

use snn_rtl::data::perturb::Perturbation;
use snn_rtl::data::{codec, DigitGen};
use snn_rtl::runtime::Manifest;
use snn_rtl::snn::BehavioralNet;

type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn main() -> Result<()> {
    let manifest = Manifest::load("artifacts")
        .map_err(|e| format!("run `make artifacts` first: {e}"))?;
    let weights = codec::load_weights(manifest.path("weights.bin"))?;
    let cfg = manifest.snn_config()?.with_timesteps(10);
    let net = BehavioralNet::new(cfg, weights.weights)?;
    let gen = DigitGen::new(manifest.u32("test_seed")?);

    // Show the suite on one digit.
    let img = gen.sample(5, 1);
    for p in Perturbation::paper_suite() {
        let perturbed = p.apply(&img, 99, 0);
        let out = net.classify(&perturbed, 0xC0FFEE);
        println!(
            "--- {} -> predicted {} {}",
            p.label(),
            out.class,
            if out.class == 5 { "ok" } else { "MISS" }
        );
        println!("{}", perturbed.to_ascii());
    }

    // Mini accuracy sweep (the full Fig. 8 harness is
    // `snn-rtl experiment fig8`).
    println!("accuracy over 300 samples:");
    for p in Perturbation::paper_suite() {
        let mut hits = 0;
        let n = 300;
        for i in 0..n {
            let class = (i % 10) as u8;
            let sample = gen.sample(class, i / 10);
            let perturbed = p.apply(&sample, 99, i);
            if net.classify(&perturbed, 0xACE + i).class == class {
                hits += 1;
            }
        }
        println!("  {:<24} {:>5.1}%", p.label(), f64::from(hits) / f64::from(n) * 100.0);
    }
    Ok(())
}
