//! Dump a GTKWave-compatible VCD of the RTL core running one inference,
//! plus a textual FSM timeline of the first timestep.
//!
//! ```bash
//! make artifacts && cargo run --release --example rtl_waveform
//! gtkwave results/core.vcd   # on a machine with gtkwave
//! ```

use snn_rtl::data::{codec, DigitGen};
use snn_rtl::rtl::{CtrlState, RtlCore, VcdWriter};
use snn_rtl::runtime::Manifest;

type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn main() -> Result<()> {
    let manifest = Manifest::load("artifacts")
        .map_err(|e| format!("run `make artifacts` first: {e}"))?;
    let weights = codec::load_weights(manifest.path("weights.bin"))?;
    let cfg = manifest.snn_config()?.with_timesteps(3);
    let n_outputs = cfg.n_outputs;

    let img = DigitGen::new(manifest.u32("test_seed")?).sample(2, 0);
    let mut core = RtlCore::new(cfg, weights.weights)?;
    core.attach_vcd(VcdWriter::new(n_outputs, 25)); // 25 ns = 40 MHz

    // Drive the core cycle by cycle, narrating the first timestep's FSM.
    core.load_image(&img, 0xC0FFEE)?;
    println!("FSM timeline (first 12 + phase-boundary cycles):");
    let mut cycle = 0u64;
    let mut last_phase = String::new();
    loop {
        let state = core.state();
        let phase = match state {
            CtrlState::Integrate { pixel } => {
                if cycle < 12 {
                    println!("  cycle {cycle:>5}: INTEGRATE pixel {pixel}");
                }
                "INTEGRATE".to_string()
            }
            CtrlState::Leak { .. } => "LEAK".to_string(),
            CtrlState::Fire => "FIRE".to_string(),
            CtrlState::Idle => "IDLE".to_string(),
            CtrlState::Done => "DONE".to_string(),
        };
        if phase != last_phase && cycle >= 12 {
            println!("  cycle {cycle:>5}: -> {phase}  membranes {:?}", core.membranes());
            last_phase = phase;
        } else if cycle < 12 {
            last_phase = phase;
        }
        if !core.tick_cycle() {
            break;
        }
        cycle += 1;
    }
    println!("total cycles: {cycle}");

    let vcd = core.detach_vcd().expect("vcd attached").finish();
    std::fs::create_dir_all("results")?;
    std::fs::write("results/core.vcd", &vcd)?;
    println!(
        "wrote results/core.vcd ({} bytes, {} change records)",
        vcd.len(),
        vcd.matches('#').count()
    );
    Ok(())
}
