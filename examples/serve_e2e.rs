//! End-to-end serving driver (the repository's headline validation run,
//! recorded in EXPERIMENTS.md): start the coordinator over the AOT-
//! compiled JAX/Pallas stack (PJRT), replay a batched classification
//! workload with a synthetic-arrival load generator, and report accuracy,
//! latency percentiles and throughput — plus the early-exit scheduler's
//! timestep savings.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e [-- <requests>]
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use snn_rtl::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, FanoutPolicy, Request, XlaBackend,
};
use snn_rtl::data::DigitGen;
use snn_rtl::prng::Xorshift32;
use snn_rtl::runtime::XlaSnn;
use snn_rtl::snn::EarlyExit;

type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn percentile_line(tag: &str, snap: &snn_rtl::coordinator::MetricsSnapshot) {
    println!(
        "{tag}: p50 {} µs  p95 {} µs  p99 {} µs  mean {:.0} µs  max {} µs",
        snap.latency_p50_us,
        snap.latency_p95_us,
        snap.latency_p99_us,
        snap.latency_mean_us,
        snap.latency_max_us
    );
}

fn run_phase(
    name: &str,
    snn_dir: &str,
    requests: usize,
    early: EarlyExit,
) -> Result<(f64, f64, f64)> {
    let snn = XlaSnn::load(snn_dir)
        .map_err(|e| format!("loading compiled artifacts: {e}"))?;
    let window = snn.config().timesteps;
    let backend = Arc::new(XlaBackend::new(snn));
    let coord = Coordinator::start(
        backend,
        CoordinatorConfig {
            workers: 2,
            queue_depth: 1024,
            batch: BatchPolicy { max_batch: 8, max_delay: Duration::from_millis(2) },
            early,
            fanout: FanoutPolicy::default(),
        },
    );
    let handle = coord.handle();
    let gen = DigitGen::new(2);
    let mut workload_rng = Xorshift32::new(0xBEEF);

    println!("\n--- phase: {name} ({requests} requests) ---");
    let t0 = Instant::now();
    let mut receivers = Vec::with_capacity(requests);
    for i in 0..requests {
        // Synthetic open-loop arrivals: random class, random style index.
        let class = workload_rng.below(10) as u8;
        let index = workload_rng.below(280);
        let img = gen.sample(class, index);
        // Retry on backpressure (bounded queue) with a tiny backoff.
        loop {
            match handle.submit(Request { image: img.clone(), seed: Some(i as u32 + 1) }) {
                Ok(rx) => {
                    receivers.push((class, rx));
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_micros(200)),
            }
        }
    }
    let mut hits = 0usize;
    for (class, rx) in &receivers {
        let resp = rx.recv()??;
        if resp.class == *class {
            hits += 1;
        }
    }
    let wall = t0.elapsed();
    let snap = coord.metrics().snapshot();
    let qps = requests as f64 / wall.as_secs_f64();
    let acc = hits as f64 / requests as f64;
    let mean_steps = snap.steps_executed as f64 / requests as f64;
    println!(
        "throughput {qps:.0} req/s   accuracy {:.2}%   mean batch {:.2}",
        acc * 100.0,
        snap.mean_batch_size
    );
    percentile_line("latency", &snap);
    println!(
        "timesteps/request {mean_steps:.2} (window {window}) -> {:.0}% of full-window compute",
        mean_steps / f64::from(window) * 100.0
    );
    coord.shutdown();
    Ok((qps, acc, mean_steps))
}

fn main() -> Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()
        .map_err(|e| format!("argument must be a request count: {e}"))?
        .unwrap_or(2000);

    let (qps_full, acc_full, steps_full) =
        run_phase("full window", "artifacts", requests, EarlyExit::Off)?;
    let (qps_early, acc_early, steps_early) = run_phase(
        "early exit (margin 2)",
        "artifacts",
        requests,
        EarlyExit::Margin { margin: 2, min_steps: 5 },
    )?;

    println!("\n=== serve_e2e summary ===");
    println!("full window : {qps_full:.0} req/s  acc {:.2}%  {steps_full:.1} steps/req", acc_full * 100.0);
    println!("early exit  : {qps_early:.0} req/s  acc {:.2}%  {steps_early:.1} steps/req", acc_early * 100.0);
    println!(
        "early exit saves {:.0}% of timesteps and changes accuracy by {:+.2} pts",
        (1.0 - steps_early / steps_full) * 100.0,
        (acc_early - acc_full) * 100.0
    );
    Ok(())
}
