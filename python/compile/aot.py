"""AOT build entry point: `python -m compile.aot --out-dir ../artifacts`.

Produces everything the Rust binary needs at runtime (Python never runs on
the request path):

  digits_train.bin / digits_test.bin   synthetic dataset (SNND)
  weights.bin                          trained 9-bit SNN weights (SNNW)
  ann_weights.bin                      baseline 784-32-10 MLP (SNNA)
  golden_encoder.bin                   encoder spike train golden (SNNE)
  golden_trace.bin                     LIF per-step trace golden (SNNT)
  snn_forward_b{1,8,32}.hlo.txt        full-window forward, HLO text
  snn_init_b8.hlo.txt                  chunked-serving carry init
  snn_chunk_b8.hlo.txt                 5-timestep chunk with carry
  ann_mlp_b{1,32}.hlo.txt              baseline ANN forward
  manifest.txt                         key=value description of all above

HLO *text* is the interchange format (not serialized protos): jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import artifact_io as aio
from . import dataset as ds
from . import model as M
from . import train as T
from .kernels import ref

# Canonical build constants (recorded in the manifest).
TRAIN_SEED = 1
TEST_SEED = 2
TRAIN_PER_CLASS = 500
TEST_PER_CLASS = 300
EVAL_SEED_BASE = 0xC0FFEE
EVAL_SEED_MULT = 0x9E3779B1
GOLDEN_SEED = 0xC0FFEE
CHUNK_STEPS = 5
FORWARD_BATCHES = (1, 8, 32)
ANN_BATCHES = (1, 32)
# Unstructured magnitude-pruning sweep for the sparse serving engine:
# ascending candidate thresholds, keep the largest whose validation
# accuracy stays within the budget of the dense calibration.
SPARSE_THRESHOLD_CANDIDATES = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32)
SPARSE_ACC_BUDGET = 0.01


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docs).

    `return_tuple=False` leaves multiple results as separate PJRT output
    buffers — the chunked serving executables use this so the Rust side can
    keep the carry device-resident between chunks (EXPERIMENTS.md §Perf
    pass 6) instead of round-tripping a tuple literal.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple)
    return comp.as_hlo_text()


def eval_seeds(n: int) -> np.ndarray:
    """The shared eval-seed convention: seed_i = base + i·mult (mod 2^32).
    rust/src/experiments/mod.rs mirrors this."""
    return ((np.arange(n, dtype=np.uint64) * EVAL_SEED_MULT + EVAL_SEED_BASE)
            % (1 << 32)).astype(np.uint32)


def build_datasets(out_dir: str, log):
    train_path = os.path.join(out_dir, "digits_train.bin")
    test_path = os.path.join(out_dir, "digits_test.bin")
    if os.path.exists(train_path) and os.path.exists(test_path):
        log("datasets: cached")
        return aio.load_dataset(train_path), aio.load_dataset(test_path)
    t0 = time.time()
    log(f"datasets: rendering {TRAIN_PER_CLASS * 10} train + {TEST_PER_CLASS * 10} test ...")
    train = ds.build_dataset(TRAIN_SEED, TRAIN_PER_CLASS)
    test = ds.build_dataset(TEST_SEED, TEST_PER_CLASS)
    aio.save_dataset(train_path, *train)
    aio.save_dataset(test_path, *test)
    log(f"datasets: done in {time.time() - t0:.1f}s")
    return train, test


def calibrate_sparse(out_dir: str, w_q, xval, yval, cfg: M.ModelConfig, log):
    """Magnitude-pruning sweep + SNNW v4 export for the sparse engine.

    Ascending thresholds zero ever more |w| < t entries
    (aio.magnitude_prune, same keep predicate as the Rust CSR builder);
    the largest threshold whose validation accuracy stays within
    SPARSE_ACC_BUDGET of dense wins. The v4 artifact stores the ORIGINAL
    dense weights + the threshold — the serving side derives the CSR, so
    threshold 0 (nothing safely prunable) still yields a valid sparse
    artifact that is bit-exact with dense."""
    dense_acc = T.evaluate_snn(w_q, xval, yval, cfg, timesteps=10)
    best_t = 0
    for t in SPARSE_THRESHOLD_CANDIDATES:
        acc = T.evaluate_snn(aio.magnitude_prune(w_q, t), xval, yval, cfg,
                             timesteps=10)
        density = aio.sparse_nnz(w_q, t) / w_q.size
        log(f"sparse: threshold {t}: acc {acc:.4f} "
            f"(dense {dense_acc:.4f}, density {density:.3f})")
        if acc + SPARSE_ACC_BUDGET >= dense_acc:
            best_t = t
        else:
            break
    aio.save_weight_stack(
        os.path.join(out_dir, "weights_sparse.bin"), [w_q],
        bits=cfg.weight_bits, v_th=cfg.v_th, decay_shift=cfg.decay_shift,
        timesteps=cfg.timesteps, prune_after=cfg.prune_after,
        sparse_threshold=best_t)
    return best_t, aio.sparse_nnz(w_q, best_t) / w_q.size


def build_weights(out_dir: str, train, test, cfg: M.ModelConfig, log):
    wpath = os.path.join(out_dir, "weights.bin")
    apath = os.path.join(out_dir, "ann_weights.bin")
    spath = os.path.join(out_dir, "weights_sparse.bin")
    stats = {}
    if os.path.exists(wpath) and os.path.exists(apath):
        log("weights: cached")
        w, meta = aio.load_weights(wpath)
        cfg = M.ModelConfig(v_th=meta["v_th"], decay_shift=meta["decay_shift"],
                            timesteps=meta["timesteps"],
                            prune_after=meta["prune_after"])
        if os.path.exists(spath):
            _, smeta = aio.load_weight_stack(spath)
            stats["sparse_threshold"] = smeta["sparse_threshold"]
            stats["sparse_density"] = aio.sparse_nnz(
                w, smeta["sparse_threshold"]) / w.size
        else:
            (xte, yte) = test
            stats["sparse_threshold"], stats["sparse_density"] = \
                calibrate_sparse(out_dir, w, xte[:1000], yte[:1000], cfg, log)
        return w, aio.load_ann(apath), cfg, stats

    (xtr, ytr), (xte, yte) = train, test
    log("weights: training rate-proxy SNN ...")
    w_f = T.train_rate_proxy(xtr, ytr, log=log)
    w_q = T.centre_and_quantize(w_f, bits=cfg.weight_bits, images=xtr, labels=ytr)
    log("weights: calibrating (V_th, prune_after) on validation slice ...")
    v_th, prune_after, scores = T.calibrate(w_q, xte[:1000], yte[:1000], cfg, log=log)
    cfg = M.ModelConfig(v_th=v_th, decay_shift=cfg.decay_shift,
                        timesteps=cfg.timesteps, prune_after=prune_after)
    stats["snn_train_acc"] = T.evaluate_snn(w_q, xtr[:2000], ytr[:2000], cfg, timesteps=10)
    stats["snn_test_acc_t10"] = T.evaluate_snn(w_q, xte, yte, cfg, timesteps=10)
    log(f"weights: SNN test acc @T=10: {stats['snn_test_acc_t10']:.4f}")
    aio.save_weights(wpath, w_q, bits=cfg.weight_bits, v_th=cfg.v_th,
                     decay_shift=cfg.decay_shift, timesteps=cfg.timesteps,
                     prune_after=cfg.prune_after)
    log("weights: magnitude-pruning sweep for the sparse engine ...")
    stats["sparse_threshold"], stats["sparse_density"] = \
        calibrate_sparse(out_dir, w_q, xte[:1000], yte[:1000], cfg, log)

    log("weights: training baseline ANN (784-32-10) ...")
    ann = T.train_ann(xtr, ytr, log=log)
    stats["ann_test_acc"] = T.evaluate_ann(ann, xte, yte)
    log(f"weights: ANN test acc: {stats['ann_test_acc']:.4f}")
    aio.save_ann(apath, *ann)
    return w_q, ann, cfg, stats


def build_goldens(out_dir: str, test, w_q, cfg: M.ModelConfig, log):
    log("goldens: encoder spike train + LIF trace ...")
    (xte, yte) = test
    # Canonical golden sample: test-set class 3, sample index 0 => position
    # 0*10+3 in the interleaved layout.
    img = xte[3]
    assert yte[3] == 3
    t = cfg.timesteps
    states = ref.initial_states(jnp.asarray([GOLDEN_SEED], jnp.uint32), 784)
    spikes_all = []
    for _ in range(t):
        states, spikes = ref.encoder_step(states, jnp.asarray(img[None, :], jnp.int32))
        spikes_all.append(np.asarray(spikes[0]))
    aio.save_golden_encoder(os.path.join(out_dir, "golden_encoder.bin"),
                            img, GOLDEN_SEED, np.stack(spikes_all))

    counts, membranes, fired, currents = ref.snn_forward_traced(
        jnp.asarray(img[None, :], jnp.int32),
        jnp.asarray([GOLDEN_SEED], jnp.uint32),
        jnp.asarray(w_q, jnp.int32),
        timesteps=t, v_th=cfg.v_th, v_rest=cfg.v_rest,
        decay_shift=cfg.decay_shift, acc_bits=cfg.acc_bits,
        prune_after=cfg.prune_after)
    aio.save_golden_trace(
        os.path.join(out_dir, "golden_trace.bin"), img, GOLDEN_SEED,
        v_th=cfg.v_th, decay_shift=cfg.decay_shift, acc_bits=cfg.acc_bits,
        prune_after=cfg.prune_after,
        membranes=np.asarray(membranes[:, 0]), fired=np.asarray(fired[:, 0]),
        currents=np.asarray(currents[:, 0]), counts=np.asarray(counts[0]))


def lower_hlo(out_dir: str, cfg: M.ModelConfig, log):
    files = []

    def dump(name, fn, *specs, return_tuple=True):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered, return_tuple=return_tuple)
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        files.append(name)
        log(f"hlo: {name} ({len(text)} chars)")

    p, n = cfg.n_inputs, cfg.n_outputs
    w_spec = jax.ShapeDtypeStruct((p, n), jnp.int32)

    for b in FORWARD_BATCHES:
        dump(f"snn_forward_b{b}.hlo.txt",
             functools.partial(snn_forward_fn, cfg=cfg),
             jax.ShapeDtypeStruct((b, p), jnp.int32),
             jax.ShapeDtypeStruct((b,), jnp.uint32),
             w_spec)

    # Chunked serving executables use the PACKED carry (a single int32
    # array; model.pack_carry layout) and are lowered with
    # return_tuple=False so the root is a plain array — the returned PJRT
    # buffer is fed straight back into the next chunk without any host
    # round-trip (perf pass 6).
    b = 8
    dump(f"snn_init_b{b}.hlo.txt",
         functools.partial(snn_init_packed_fn, cfg=cfg, n_pixels=p),
         jax.ShapeDtypeStruct((b,), jnp.uint32),
         return_tuple=False)
    dump(f"snn_chunk_b{b}.hlo.txt",
         functools.partial(snn_chunk_packed_fn, cfg=cfg),
         jax.ShapeDtypeStruct((b, p), jnp.int32),
         jax.ShapeDtypeStruct((b, p + 3 * n), jnp.int32),
         w_spec,
         return_tuple=False)

    for b in ANN_BATCHES:
        dump(f"ann_mlp_b{b}.hlo.txt", ann_fn,
             jax.ShapeDtypeStruct((b, p), jnp.float32),
             jax.ShapeDtypeStruct((p, 32), jnp.float32),
             jax.ShapeDtypeStruct((32,), jnp.float32),
             jax.ShapeDtypeStruct((32, n), jnp.float32),
             jax.ShapeDtypeStruct((n,), jnp.float32))
    return files


# Top-level lowered functions (named so the HLO modules are identifiable).

def snn_forward_fn(images, seeds, weights, *, cfg):
    return (M.snn_forward(images, seeds, weights, cfg),)


def snn_init_packed_fn(seeds, *, cfg, n_pixels):
    return M.snn_init_packed(seeds, cfg, n_pixels)


def snn_chunk_packed_fn(images, carry, weights, *, cfg):
    return M.snn_chunk_packed(images, carry, weights, cfg,
                              chunk_steps=CHUNK_STEPS)


def ann_fn(images_f32, w1, b1, w2, b2):
    return (M.ann_forward(images_f32, w1, b1, w2, b2),)


def write_manifest(out_dir: str, cfg: M.ModelConfig, stats: dict, files, log):
    path = os.path.join(out_dir, "manifest.txt")
    lines = [
        "schema=1",
        f"n_inputs={cfg.n_inputs}",
        f"n_outputs={cfg.n_outputs}",
        f"v_th={cfg.v_th}",
        f"v_rest={cfg.v_rest}",
        f"decay_shift={cfg.decay_shift}",
        f"acc_bits={cfg.acc_bits}",
        f"weight_bits={cfg.weight_bits}",
        f"timesteps={cfg.timesteps}",
        f"prune_after={cfg.prune_after}",
        f"chunk_steps={CHUNK_STEPS}",
        f"forward_batches={','.join(str(b) for b in FORWARD_BATCHES)}",
        f"ann_batches={','.join(str(b) for b in ANN_BATCHES)}",
        f"train_seed={TRAIN_SEED}",
        f"test_seed={TEST_SEED}",
        f"train_per_class={TRAIN_PER_CLASS}",
        f"test_per_class={TEST_PER_CLASS}",
        f"eval_seed_base={EVAL_SEED_BASE}",
        f"eval_seed_mult={EVAL_SEED_MULT}",
        f"golden_seed={GOLDEN_SEED}",
    ]
    for k, v in sorted(stats.items()):
        lines.append(f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}")
    lines.append(f"hlo_files={','.join(files)}")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    log(f"manifest: {path}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true",
                    help="rebuild even if artifacts exist")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    log = print

    manifest = os.path.join(out_dir, "manifest.txt")
    if os.path.exists(manifest) and not args.force:
        log("artifacts: manifest present; nothing to do "
            "(make handles staleness; use --force to rebuild)")
        return

    t0 = time.time()
    cfg = M.ModelConfig()
    train, test = build_datasets(out_dir, log)
    w_q, ann, cfg, stats = build_weights(out_dir, train, test, cfg, log)
    build_goldens(out_dir, test, w_q, cfg, log)
    files = lower_hlo(out_dir, cfg, log)
    write_manifest(out_dir, cfg, stats, files, log)
    log(f"artifacts: complete in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
