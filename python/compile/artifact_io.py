"""Binary artifact writers/readers — byte-identical to rust/src/data/codec.rs.

Formats (little-endian, 4-byte ASCII magic):

SNND  labelled image dataset           (rust: codec::{save,load}_dataset)
SNNW  packed 9-bit weights + LIF cal.  (rust: codec::{save,load}_weights)
SNNA  baseline ANN f32 weights         (rust: ann::load_ann_weights)
SNNE  golden encoder spike train       (rust: tests/golden.rs)
SNNT  golden LIF trace                 (rust: tests/golden.rs)
"""

import os
import struct

import numpy as np

VERSION = 1


def _write_atomic(path: str, payload: bytes):
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


# -- SNND -------------------------------------------------------------------

def save_dataset(path: str, images: np.ndarray, labels: np.ndarray):
    """images uint8[N, 784], labels uint8[N]."""
    n, p = images.shape
    assert p == 784 and images.dtype == np.uint8
    out = bytearray()
    out += b"SNND"
    out += struct.pack("<II", VERSION, n)
    out += struct.pack("<HH", 28, 28)
    for i in range(n):
        out.append(int(labels[i]))
        out += images[i].tobytes()
    _write_atomic(path, bytes(out))


def load_dataset(path: str):
    with open(path, "rb") as f:
        buf = f.read()
    assert buf[:4] == b"SNND", "bad magic"
    version, n = struct.unpack_from("<II", buf, 4)
    assert version == VERSION
    h, w = struct.unpack_from("<HH", buf, 12)
    assert (h, w) == (28, 28)
    images = np.zeros((n, 784), dtype=np.uint8)
    labels = np.zeros(n, dtype=np.uint8)
    pos = 16
    for i in range(n):
        labels[i] = buf[pos]
        images[i] = np.frombuffer(buf, np.uint8, 784, pos + 1)
        pos += 785
    assert pos == len(buf), "trailing bytes"
    return images, labels


# -- SNNW -------------------------------------------------------------------

def pack_weights(weights: np.ndarray, bits: int) -> bytes:
    """Dense LSB-first two's-complement bitstream (mirror of rust
    fixed::pack_weights)."""
    flat = weights.reshape(-1).astype(np.int64)
    mask = (1 << bits) - 1
    total_bits = flat.size * bits
    out = bytearray((total_bits + 7) // 8)
    bitpos = 0
    for w in flat:
        raw = int(w) & mask
        remaining = bits
        val = raw
        pos = bitpos
        while remaining > 0:
            byte = pos // 8
            off = pos % 8
            take = min(8 - off, remaining)
            out[byte] |= (val & ((1 << take) - 1)) << off
            val >>= take
            pos += take
            remaining -= take
        bitpos += bits
    return bytes(out)


def unpack_weights(data: bytes, n_inputs: int, n_outputs: int, bits: int) -> np.ndarray:
    n = n_inputs * n_outputs
    out = np.zeros(n, dtype=np.int64)
    bitpos = 0
    for k in range(n):
        raw = 0
        got = 0
        pos = bitpos
        while got < bits:
            byte = pos // 8
            off = pos % 8
            take = min(8 - off, bits - got)
            raw |= ((data[byte] >> off) & ((1 << take) - 1)) << got
            got += take
            pos += take
        bitpos += bits
        if raw >= (1 << (bits - 1)):  # sign-extend
            raw -= 1 << bits
        out[k] = raw
    return out.reshape(n_inputs, n_outputs).astype(np.int32)


def save_weights(path: str, weights: np.ndarray, *, bits: int, v_th: int,
                 decay_shift: int, timesteps: int, prune_after: int):
    """weights int32[784, 10] row-major by input."""
    n_in, n_out = weights.shape
    packed = pack_weights(weights, bits)
    out = bytearray()
    out += b"SNNW"
    out += struct.pack("<IIIIiIIII", VERSION, n_in, n_out, bits, v_th,
                       decay_shift, timesteps, prune_after, len(packed))
    out += packed
    _write_atomic(path, bytes(out))


def load_weights(path: str):
    with open(path, "rb") as f:
        buf = f.read()
    assert buf[:4] == b"SNNW"
    version, n_in, n_out, bits, v_th, decay, steps, prune, plen = \
        struct.unpack_from("<IIIIiIIII", buf, 4)
    assert version == VERSION
    packed = buf[40:40 + plen]
    w = unpack_weights(packed, n_in, n_out, bits)
    return w, dict(v_th=v_th, decay_shift=decay, timesteps=steps, bits=bits,
                   prune_after=prune)


# -- SNNW stack (v2 uniform / v3 per-layer params / v4 sparse) ----------------
#
# Byte-identical to rust/src/data/codec.rs::save_weight_stack. Version
# selection mirrors the Rust writer: a sparse threshold forces v4, a
# per-layer parameter block alone gives v3, plain uniform stacks stay v2.

STACK_VERSION = 2
LAYER_PARAMS_VERSION = 3
SPARSE_VERSION = 4


def magnitude_prune(weights: np.ndarray, threshold: int) -> np.ndarray:
    """Unstructured magnitude pruning: zero every |w| < threshold.

    The keep predicate (|w| >= threshold) matches rust
    fixed::SparseWeightLayer::from_dense, so a dense engine running the
    pruned matrix and a sparse engine walking the CSR at `threshold`
    integrate identical currents."""
    assert threshold >= 0
    w = np.asarray(weights)
    return np.where(np.abs(w) >= threshold, w, 0).astype(w.dtype)


def sparse_nnz(weights: np.ndarray, threshold: int) -> int:
    """Survivors of the keep predicate — the v4 per-layer checksum word."""
    assert threshold >= 0
    return int((np.abs(np.asarray(weights)) >= threshold).sum())


def save_weight_stack(path: str, layers, *, bits: int, v_th: int,
                      decay_shift: int, timesteps: int, prune_after: int,
                      layer_params=None, sparse_threshold=None):
    """layers: list of int32[ni, no] (each no == next ni); layer_params:
    optional list of fully-resolved (v_th, decay_shift, prune_after)
    triples, one per layer; sparse_threshold: optional magnitude-pruning
    calibration (>= 0) that adds the v4 sparse section."""
    layers = [np.asarray(w) for w in layers]
    for a, b in zip(layers, layers[1:]):
        assert a.shape[1] == b.shape[0], "inconsistent layer chain"
    if layer_params is not None:
        assert len(layer_params) == len(layers)
    if sparse_threshold is not None:
        assert sparse_threshold >= 0
        version = SPARSE_VERSION
    elif layer_params:
        version = LAYER_PARAMS_VERSION
    else:
        version = STACK_VERSION
    out = bytearray()
    out += b"SNNW"
    out += struct.pack("<II", version, len(layers))
    for w in layers:
        out += struct.pack("<II", *w.shape)
    out += struct.pack("<IiIII", bits, v_th, decay_shift, timesteps,
                       prune_after)
    if version == SPARSE_VERSION:
        out += struct.pack("<I", 1 if layer_params else 0)
    if layer_params:
        for lv, ld, lp in layer_params:
            out += struct.pack("<iII", lv, ld, lp)
    if version == SPARSE_VERSION:
        out += struct.pack("<i", sparse_threshold)
        for w in layers:
            out += struct.pack("<I", sparse_nnz(w, sparse_threshold))
    for w in layers:
        packed = pack_weights(w, bits)
        out += struct.pack("<I", len(packed))
        out += packed
    _write_atomic(path, bytes(out))


def load_weight_stack(path: str):
    """Returns (layers, meta) for SNNW v2/v3/v4 (v1 loads via
    load_weights). meta carries layer_params (list of triples or None) and
    sparse_threshold (int or None); the v4 nnz words are re-checked."""
    with open(path, "rb") as f:
        buf = f.read()
    assert buf[:4] == b"SNNW"
    version, n_layers = struct.unpack_from("<II", buf, 4)
    assert version in (STACK_VERSION, LAYER_PARAMS_VERSION, SPARSE_VERSION)
    pos = 12
    dims = []
    for _ in range(n_layers):
        dims.append(struct.unpack_from("<II", buf, pos))
        pos += 8
    bits, v_th, decay, steps, prune = struct.unpack_from("<IiIII", buf, pos)
    pos += 20
    has_params = version == LAYER_PARAMS_VERSION
    if version == SPARSE_VERSION:
        (flag,) = struct.unpack_from("<I", buf, pos)
        pos += 4
        assert flag in (0, 1)
        has_params = flag == 1
    layer_params = None
    if has_params:
        layer_params = []
        for _ in range(n_layers):
            layer_params.append(struct.unpack_from("<iII", buf, pos))
            pos += 12
    sparse_threshold = None
    expected_nnz = []
    if version == SPARSE_VERSION:
        (sparse_threshold,) = struct.unpack_from("<i", buf, pos)
        pos += 4
        assert sparse_threshold >= 0
        for _ in range(n_layers):
            expected_nnz.append(struct.unpack_from("<I", buf, pos)[0])
            pos += 4
    layers = []
    for ni, no in dims:
        (plen,) = struct.unpack_from("<I", buf, pos)
        pos += 4
        assert plen == (ni * no * bits + 7) // 8
        layers.append(unpack_weights(buf[pos:pos + plen], ni, no, bits))
        pos += plen
    assert pos == len(buf), "trailing bytes"
    if sparse_threshold is not None:
        for l, w in enumerate(layers):
            got = sparse_nnz(w, sparse_threshold)
            assert got == expected_nnz[l], \
                f"layer {l}: nnz {got} != header {expected_nnz[l]}"
    meta = dict(v_th=v_th, decay_shift=decay, timesteps=steps, bits=bits,
                prune_after=prune, layer_params=layer_params,
                sparse_threshold=sparse_threshold)
    return layers, meta


# -- SNNA (ANN f32 weights) --------------------------------------------------

def save_ann(path: str, w1, b1, w2, b2):
    w1 = np.asarray(w1, np.float32)
    b1 = np.asarray(b1, np.float32)
    w2 = np.asarray(w2, np.float32)
    b2 = np.asarray(b2, np.float32)
    n_in, n_h = w1.shape
    n_out = w2.shape[1]
    out = bytearray()
    out += b"SNNA"
    out += struct.pack("<IIII", VERSION, n_in, n_h, n_out)
    out += w1.tobytes() + b1.tobytes() + w2.tobytes() + b2.tobytes()
    _write_atomic(path, bytes(out))


def load_ann(path: str):
    with open(path, "rb") as f:
        buf = f.read()
    assert buf[:4] == b"SNNA"
    version, n_in, n_h, n_out = struct.unpack_from("<IIII", buf, 4)
    assert version == VERSION
    pos = 20
    def take(shape):
        nonlocal pos
        count = int(np.prod(shape))
        arr = np.frombuffer(buf, np.float32, count, pos).reshape(shape)
        pos += count * 4
        return arr
    w1 = take((n_in, n_h))
    b1 = take((n_h,))
    w2 = take((n_h, n_out))
    b2 = take((n_out,))
    return w1, b1, w2, b2


# -- Golden traces ------------------------------------------------------------

def save_golden_encoder(path: str, image: np.ndarray, seed: int,
                        spikes: np.ndarray):
    """image uint8[784]; spikes int{0,1}[T, 784] packed LSB-first."""
    t, p = spikes.shape
    out = bytearray()
    out += b"SNNE"
    out += struct.pack("<IIII", VERSION, seed, p, t)
    out += image.astype(np.uint8).tobytes()
    for step in range(t):
        out += np.packbits(spikes[step].astype(np.uint8), bitorder="little").tobytes()
    _write_atomic(path, bytes(out))


def save_golden_trace(path: str, image: np.ndarray, seed: int, *, v_th: int,
                      decay_shift: int, acc_bits: int, prune_after: int,
                      membranes: np.ndarray, fired: np.ndarray,
                      currents: np.ndarray, counts: np.ndarray):
    """Per-step LIF observability for one image (T, N arrays)."""
    t, n = membranes.shape
    out = bytearray()
    out += b"SNNT"
    out += struct.pack("<IiIIIIII", VERSION, v_th, decay_shift, acc_bits,
                       prune_after, t, n, seed)
    out += image.astype(np.uint8).tobytes()
    for step in range(t):
        out += membranes[step].astype("<i4").tobytes()
        out += fired[step].astype(np.uint8).tobytes()
        out += currents[step].astype("<i4").tobytes()
    out += counts.astype("<i4").tobytes()
    _write_atomic(path, bytes(out))
