"""Bit-exact Python mirror of rust/src/data/digitgen.rs and perturb.rs.

Every arithmetic step is integer-only with floor semantics shared by both
languages (Python ``>>`` on negative ints and Rust arithmetic shift both
round toward -inf). The PRNG draw order is the contract documented in the
Rust module; the cross-language golden tests regenerate images in both
languages and compare bytes.
"""

from dataclasses import dataclass

import numpy as np

from .prng import Xorshift32, derive_state
from .templates import TEMPLATES

IMG_SIDE = 28
IMG_PIXELS = IMG_SIDE * IMG_SIDE
HI = 112  # 4x oversampled raster

SIN_Q10 = [0, 18, 36, 54, 71, 89, 107, 125, 143, 160, 178, 195, 213, 230, 248, 265]
COS_Q10 = [1024, 1024, 1023, 1023, 1022, 1020, 1018, 1016, 1014, 1011, 1008, 1005,
           1002, 998, 994, 989]

# Precomputed disc offsets per radius (stamping acceleration).
_DISC_CACHE = {}


def _disc_offsets(r: int):
    if r not in _DISC_CACHE:
        ys, xs = np.mgrid[-r:r + 1, -r:r + 1]
        keep = (xs * xs + ys * ys) <= r * r
        _DISC_CACHE[r] = (ys[keep].astype(np.int64), xs[keep].astype(np.int64))
    return _DISC_CACHE[r]


@dataclass(frozen=True)
class GenParams:
    dx: int
    dy: int
    angle_deg: int
    scale_q8: int
    thickness: int
    peak: int


def _sin_q10(deg: int) -> int:
    v = SIN_Q10[abs(deg)]
    return -v if deg < 0 else v


def _cos_q10(deg: int) -> int:
    return COS_Q10[abs(deg)]


def _virt_to_hi(v: int) -> int:
    return (v * 7 + 8) >> 4


def _stamp_segment(bitmap: np.ndarray, x0: int, y0: int, x1: int, y1: int, r: int):
    """Bresenham walk stamping a disc at every cell (mirrors Rust)."""
    oy, ox = _disc_offsets(r)
    dx = abs(x1 - x0)
    dy = -abs(y1 - y0)
    sx = 1 if x0 < x1 else -1
    sy = 1 if y0 < y1 else -1
    err = dx + dy
    x, y = x0, y0
    while True:
        ys = oy + y
        xs = ox + x
        keep = (ys >= 0) & (ys < HI) & (xs >= 0) & (xs < HI)
        bitmap[ys[keep], xs[keep]] = 1
        if x == x1 and y == y1:
            break
        e2 = 2 * err
        if e2 >= dy:
            err += dy
            x += sx
        if e2 <= dx:
            err += dx
            y += sy


def render_digit(seed: int, cls: int, index: int):
    """Render sample `index` of digit `cls` under `seed`.

    Returns (pixels: np.uint8[28,28], GenParams). Bit-identical to
    rust ``render_digit``.
    """
    assert 0 <= cls <= 9
    rng = Xorshift32.from_raw_state(derive_state(seed, cls, index))

    params = GenParams(
        dx=rng.range_i32(-14, 14),
        dy=rng.range_i32(-14, 14),
        angle_deg=rng.range_i32(-12, 12),
        scale_q8=rng.range_i32(210, 290),
        thickness=rng.range_i32(8, 12),
        peak=rng.range_i32(170, 255),
    )
    sinv = _sin_q10(params.angle_deg)
    cosv = _cos_q10(params.angle_deg)

    bitmap = np.zeros((HI, HI), dtype=np.uint8)
    for stroke in TEMPLATES[cls]:
        pts = []
        for (tx, ty) in stroke:
            jx = rng.range_i32(-5, 5)
            jy = rng.range_i32(-5, 5)
            px = tx + jx - 128
            py = ty + jy - 128
            rx = (px * cosv - py * sinv) >> 10
            ry = (px * sinv + py * cosv) >> 10
            sx = (rx * params.scale_q8) >> 8
            sy = (ry * params.scale_q8) >> 8
            pts.append((_virt_to_hi(sx + 128 + params.dx), _virt_to_hi(sy + 128 + params.dy)))
        for (x0, y0), (x1, y1) in zip(pts, pts[1:]):
            _stamp_segment(bitmap, x0, y0, x1, y1, params.thickness)

    # 4x4 box downsample -> coverage 0..16, scaled by peak.
    blocks = bitmap.reshape(IMG_SIDE, 4, IMG_SIDE, 4).sum(axis=(1, 3)).astype(np.int64)
    pixels = ((blocks * params.peak) // 16).astype(np.uint8)
    return pixels, params


def build_dataset(seed: int, per_class: int):
    """Balanced interleaved dataset: (images uint8[N,784], labels uint8[N])
    with sample i of class c at position i*10+c — mirrors rust
    ``DigitGen::dataset``."""
    n = per_class * 10
    images = np.zeros((n, IMG_PIXELS), dtype=np.uint8)
    labels = np.zeros(n, dtype=np.uint8)
    for index in range(per_class):
        for cls in range(10):
            px, _ = render_digit(seed, cls, index)
            pos = index * 10 + cls
            images[pos] = px.reshape(-1)
            labels[pos] = cls
    return images, labels


# ---------------------------------------------------------------------------
# Perturbations (Fig. 8) — mirrors rust/src/data/perturb.rs
# ---------------------------------------------------------------------------

PERTURB_CLEAN = 0
PERTURB_ROTATE = 1
PERTURB_SHIFT = 2
PERTURB_NOISE = 3
PERTURB_OCCLUDE = 4


def rotate(img: np.ndarray, deg: int) -> np.ndarray:
    """Integer inverse-mapped nearest-neighbour rotation (|deg| <= 15)."""
    assert -15 <= deg <= 15
    a = abs(deg)
    sinv = -SIN_Q10[a] if deg < 0 else SIN_Q10[a]
    cosv = COS_Q10[a]
    src = img.reshape(IMG_SIDE, IMG_SIDE)
    out = np.zeros_like(src)
    for r in range(IMG_SIDE):
        for c in range(IMG_SIDE):
            xr = c * 2 - 27
            yr = r * 2 - 27
            sx = xr * cosv + yr * sinv
            sy = -xr * sinv + yr * cosv
            sc = (sx + 27 * 1024 + 1024) >> 11
            sr = (sy + 27 * 1024 + 1024) >> 11
            if 0 <= sc < IMG_SIDE and 0 <= sr < IMG_SIDE:
                out[r, c] = src[sr, sc]
    return out.reshape(img.shape)


def shift(img: np.ndarray, dx: int, dy: int) -> np.ndarray:
    src = img.reshape(IMG_SIDE, IMG_SIDE)
    out = np.zeros_like(src)
    for r in range(IMG_SIDE):
        for c in range(IMG_SIDE):
            sr, sc = r - dy, c - dx
            if 0 <= sr < IMG_SIDE and 0 <= sc < IMG_SIDE:
                out[r, c] = src[sr, sc]
    return out.reshape(img.shape)


def noise(img: np.ndarray, scale_q8: int, rng: Xorshift32) -> np.ndarray:
    flat = img.reshape(-1).astype(np.int64)
    out = np.zeros_like(flat)
    for i in range(flat.size):
        s = sum((rng.next_u32() & 0xFF) for _ in range(4))
        delta = ((s - 510) * scale_q8) >> 9
        out[i] = min(255, max(0, int(flat[i]) + delta))
    return out.astype(np.uint8).reshape(img.shape)


def occlude(img: np.ndarray, r0: int, c0: int, side: int) -> np.ndarray:
    out = img.reshape(IMG_SIDE, IMG_SIDE).copy()
    out[r0:r0 + side, c0:c0 + side] = 0
    return out.reshape(img.shape)


def apply_perturbation(kind: int, img: np.ndarray, seed: int, index: int,
                       deg: int = 15, percent: int = 20, scale_q8: int = 138,
                       side: int = 10) -> np.ndarray:
    """Apply perturbation `kind` to `img` as sample `index` under `seed`
    (mirrors rust ``Perturbation::apply`` including draw order)."""
    rng = Xorshift32.from_raw_state(derive_state(seed, kind, index))
    if kind == PERTURB_CLEAN:
        return img.copy()
    if kind == PERTURB_ROTATE:
        sign = 1 if rng.next_u32() & 1 == 0 else -1
        return rotate(img, sign * deg)
    if kind == PERTURB_SHIFT:
        mag = (percent * IMG_SIDE + 50) // 100
        dirs = [(1, 0), (1, 1), (0, 1), (-1, 1), (-1, 0), (-1, -1), (0, -1), (1, -1)]
        sx, sy = dirs[rng.below(8)]
        return shift(img, sx * mag, sy * mag)
    if kind == PERTURB_NOISE:
        return noise(img, scale_q8, rng)
    if kind == PERTURB_OCCLUDE:
        r0 = rng.below(IMG_SIDE - side + 1)
        c0 = rng.below(IMG_SIDE - side + 1)
        return occlude(img, r0, c0, side)
    raise ValueError(f"unknown perturbation kind {kind}")


def fnv1a32(data: bytes) -> int:
    """FNV-1a hash used for compact cross-language image goldens."""
    h = 0x811C9DC5
    for b in data:
        h = ((h ^ b) * 0x01000193) & 0xFFFFFFFF
    return h
