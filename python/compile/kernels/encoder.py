"""L1 Pallas kernel: the on-chip Poisson encoder (paper Fig. 2).

One timestep for a whole batch tile: advance every pixel's xorshift32
register and compare the low byte against the pixel intensity. On real TPU
hardware this is a pure-VPU elementwise kernel over uint32 lanes (no MXU
involvement); the BlockSpec tiles the batch dimension so a tile's states +
intensities + spikes fit comfortably in VMEM (see DESIGN.md §10).

Lowered with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom calls (see /opt/xla-example/README.md), and interpret mode folds the
kernel into plain HLO, which is what the Rust runtime loads.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encoder_kernel(states_ref, intensities_ref, new_states_ref, spikes_ref):
    """Pallas body: one xorshift32 step + 8-bit comparator per lane."""
    x = states_ref[...]
    x = x ^ (x << jnp.uint32(13))
    x = x ^ (x >> jnp.uint32(17))
    x = x ^ (x << jnp.uint32(5))
    new_states_ref[...] = x
    low = (x & jnp.uint32(0xFF)).astype(jnp.int32)
    spikes_ref[...] = (intensities_ref[...] > low).astype(jnp.int32)


def encoder_step(states, intensities, *, block_batch: int = 8,
                 interpret: bool = True):
    """One encoder timestep via pallas_call.

    states: uint32[B, P]; intensities: int32[B, P] (0..255).
    Returns (new_states uint32[B, P], spikes int32[B, P]).

    The grid walks the batch in `block_batch` tiles; P stays whole (784
    uint32 = ~3 KB per row — trivially VMEM-resident).
    """
    b, p = states.shape
    bt = min(block_batch, b)
    if b % bt != 0:
        bt = b  # fall back to one tile rather than padding
    grid = (b // bt,)
    return pl.pallas_call(
        _encoder_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, p), lambda i: (i, 0)),
            pl.BlockSpec((bt, p), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, p), lambda i: (i, 0)),
            pl.BlockSpec((bt, p), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, p), jnp.uint32),
            jax.ShapeDtypeStruct((b, p), jnp.int32),
        ],
        interpret=interpret,
    )(states, intensities)
