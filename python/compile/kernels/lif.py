"""L1 Pallas kernel: one LIF layer timestep (paper Eq. 1-2 + pruning).

TPU mapping of the paper's design (DESIGN.md §3 Hardware-Adaptation): the
784-input adder tree of the RTL becomes a {0,1}-masked int matmul on the
MXU — `current = spikes @ W` — followed by elementwise VPU ops for the
shift-leak, threshold compare, hard reset and pruning-mask update. The
BlockSpec tiles the batch dimension; the full 784×10 weight block rides
along in VMEM (784·10·4 B ≈ 31 KB).

Lowered with interpret=True for the CPU PJRT runtime (Mosaic custom calls
cannot execute there); numerics are identical either way and are pinned to
kernels/ref.py by the pytest/hypothesis suite.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lif_kernel(spikes_ref, w_ref, acc_ref, counts_ref, enabled_ref,
                acc_out_ref, counts_out_ref, enabled_out_ref, fired_out_ref,
                *, v_th: int, v_rest: int, decay_shift: int, acc_max: int,
                prune_after: int):
    """Pallas body: integrate → leak → fire/reset → prune for one tile."""
    spikes = spikes_ref[...]
    w = w_ref[...]
    acc = acc_ref[...]
    counts = counts_ref[...]
    en = enabled_ref[...].astype(jnp.bool_)

    current = jnp.dot(spikes, w, preferred_element_type=jnp.int32)
    integrated = jnp.clip(acc + current, -acc_max, acc_max)
    leaked = integrated - (integrated >> jnp.int32(decay_shift))
    fired = jnp.logical_and(leaked >= v_th, en)
    acc_next = jnp.where(en, jnp.where(fired, jnp.int32(v_rest), leaked), acc)
    counts_next = counts + fired.astype(jnp.int32)
    if prune_after > 0:
        en_next = jnp.logical_and(en, counts_next < prune_after)
    else:
        en_next = en

    acc_out_ref[...] = acc_next
    counts_out_ref[...] = counts_next
    enabled_out_ref[...] = en_next.astype(jnp.int32)
    fired_out_ref[...] = fired.astype(jnp.int32)


def lif_step(spikes, weights, acc, counts, enabled, *, v_th: int,
             v_rest: int, decay_shift: int, acc_bits: int, prune_after: int,
             block_batch: int = 8, interpret: bool = True):
    """One LIF timestep via pallas_call. Same contract as ref.lif_step."""
    b, p = spikes.shape
    n = weights.shape[1]
    acc_max = (1 << (acc_bits - 1)) - 1
    bt = min(block_batch, b)
    if b % bt != 0:
        bt = b
    grid = (b // bt,)
    kernel = functools.partial(
        _lif_kernel, v_th=v_th, v_rest=v_rest, decay_shift=decay_shift,
        acc_max=acc_max, prune_after=prune_after)
    tile_bn = lambda i: (i, 0)  # noqa: E731
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, p), tile_bn),
            pl.BlockSpec((p, n), lambda i: (0, 0)),
            pl.BlockSpec((bt, n), tile_bn),
            pl.BlockSpec((bt, n), tile_bn),
            pl.BlockSpec((bt, n), tile_bn),
        ],
        out_specs=[
            pl.BlockSpec((bt, n), tile_bn),
            pl.BlockSpec((bt, n), tile_bn),
            pl.BlockSpec((bt, n), tile_bn),
            pl.BlockSpec((bt, n), tile_bn),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.int32),
            jax.ShapeDtypeStruct((b, n), jnp.int32),
            jax.ShapeDtypeStruct((b, n), jnp.int32),
            jax.ShapeDtypeStruct((b, n), jnp.int32),
        ],
        interpret=interpret,
    )(spikes, weights, acc, counts, enabled)
