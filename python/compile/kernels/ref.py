"""Pure-jnp oracle for the L1 kernels — the correctness reference.

Implements the architectural contract of DESIGN.md §4 with plain jax.numpy
integer ops (no pallas). The Pallas kernels in encoder.py / lif.py must
match these functions bit-for-bit (pytest + hypothesis enforce it), and the
golden traces consumed by the Rust integration tests are generated from
here.

All arithmetic is int32/uint32; `>>` on int32 is arithmetic (matches Rust),
on uint32 logical (matches the hardware PRNG).
"""

import jax.numpy as jnp

M32 = 0xFFFFFFFF
GOLDEN_GAMMA = 0x9E3779B9
ZERO_STATE_FALLBACK = 0xDEADBEEF


def splitmix32(x):
    """Vectorized splitmix32 over uint32 arrays (seeding network)."""
    x = x.astype(jnp.uint32)
    z = x + jnp.uint32(GOLDEN_GAMMA)
    z = (z ^ (z >> jnp.uint32(16))) * jnp.uint32(0x85EBCA6B)
    z = (z ^ (z >> jnp.uint32(13))) * jnp.uint32(0xC2B2AE35)
    return z ^ (z >> jnp.uint32(16))


def initial_states(seeds, n_pixels: int):
    """Per-pixel xorshift32 initial states for a batch of image seeds.

    seeds: uint32[B] -> uint32[B, n_pixels], following the pixel_seed
    contract shared with rust/src/prng and python/compile/prng.py.
    """
    seeds = seeds.astype(jnp.uint32)
    idx = jnp.arange(n_pixels, dtype=jnp.uint32)
    mixed = seeds[:, None] ^ (idx[None, :] * jnp.uint32(GOLDEN_GAMMA))
    s = splitmix32(mixed)
    return jnp.where(s == 0, jnp.uint32(ZERO_STATE_FALLBACK), s)


def xorshift32_step(x):
    """One xorshift32 (13/17/5) transition over uint32 arrays."""
    x = x.astype(jnp.uint32)
    x = x ^ (x << jnp.uint32(13))
    x = x ^ (x >> jnp.uint32(17))
    x = x ^ (x << jnp.uint32(5))
    return x


def encoder_step(states, intensities):
    """One Poisson-encoder timestep.

    states: uint32[B, P] PRNG registers; intensities: int32[B, P] in 0..255.
    Returns (new_states uint32[B, P], spikes int32[B, P] in {0, 1}).
    """
    new_states = xorshift32_step(states)
    low = (new_states & jnp.uint32(0xFF)).astype(jnp.int32)
    spikes = (intensities.astype(jnp.int32) > low).astype(jnp.int32)
    return new_states, spikes


def lif_step(spikes, weights, acc, counts, enabled, *, v_th: int, v_rest: int,
             decay_shift: int, acc_bits: int, prune_after: int):
    """One architectural LIF timestep for the whole layer.

    spikes   int32[B, P] in {0, 1}
    weights  int32[P, N]
    acc      int32[B, N] membrane accumulators
    counts   int32[B, N] output spike counts
    enabled  int32[B, N] in {0, 1} (pruning mask; 1 = enabled)
    v_th / v_rest / decay_shift / acc_bits: the SnnConfig constants
    prune_after: 0 = pruning off, else gate off after that many fires.

    Returns (acc', counts', enabled', fired int32[B, N]).
    """
    acc_max = (1 << (acc_bits - 1)) - 1
    current = jnp.dot(spikes.astype(jnp.int32), weights.astype(jnp.int32),
                      preferred_element_type=jnp.int32)
    en = enabled.astype(jnp.bool_)
    integrated = jnp.clip(acc + current, -acc_max, acc_max)
    leaked = integrated - (integrated >> jnp.int32(decay_shift))
    fired_b = jnp.logical_and(leaked >= v_th, en)
    acc_next = jnp.where(en, jnp.where(fired_b, jnp.int32(v_rest), leaked), acc)
    counts_next = counts + fired_b.astype(jnp.int32)
    if prune_after > 0:
        enabled_next = jnp.logical_and(en, counts_next < prune_after)
    else:
        enabled_next = en
    return acc_next, counts_next, enabled_next.astype(jnp.int32), fired_b.astype(jnp.int32)


def snn_forward(images, seeds, weights, *, timesteps: int, v_th: int,
                v_rest: int, decay_shift: int, acc_bits: int, prune_after: int):
    """Full-window reference forward pass (python loop over timesteps).

    images: int32[B, P] 0..255; seeds: uint32[B]; weights: int32[P, N].
    Returns spike counts int32[B, N].
    """
    b, p = images.shape
    n = weights.shape[1]
    states = initial_states(seeds, p)
    acc = jnp.full((b, n), v_rest, dtype=jnp.int32)
    counts = jnp.zeros((b, n), dtype=jnp.int32)
    enabled = jnp.ones((b, n), dtype=jnp.int32)
    for _ in range(timesteps):
        states, spikes = encoder_step(states, images)
        acc, counts, enabled, _ = lif_step(
            spikes, weights, acc, counts, enabled, v_th=v_th, v_rest=v_rest,
            decay_shift=decay_shift, acc_bits=acc_bits, prune_after=prune_after)
    return counts


def snn_forward_traced(images, seeds, weights, *, timesteps: int, v_th: int,
                       v_rest: int, decay_shift: int, acc_bits: int,
                       prune_after: int):
    """Reference forward that also returns per-step observability
    (membranes after fire/reset, fired flags, input currents) — the source
    of the golden traces checked by the Rust integration tests.

    The reported per-step input current is masked by the (pre-update)
    enabled mask, matching the RTL where pruned neurons integrate nothing.
    """
    b, p = images.shape
    n = weights.shape[1]
    states = initial_states(seeds, p)
    acc = jnp.full((b, n), v_rest, dtype=jnp.int32)
    counts = jnp.zeros((b, n), dtype=jnp.int32)
    enabled = jnp.ones((b, n), dtype=jnp.int32)
    membranes, fireds, currents = [], [], []
    for _ in range(timesteps):
        states, spikes = encoder_step(states, images)
        current = jnp.dot(spikes, weights.astype(jnp.int32),
                          preferred_element_type=jnp.int32)
        current = current * enabled  # pruned neurons integrate nothing
        acc, counts, enabled, fired = lif_step(
            spikes, weights, acc, counts, enabled, v_th=v_th, v_rest=v_rest,
            decay_shift=decay_shift, acc_bits=acc_bits, prune_after=prune_after)
        membranes.append(acc)
        fireds.append(fired)
        currents.append(current)
    return counts, jnp.stack(membranes), jnp.stack(fireds), jnp.stack(currents)
