"""L2: the JAX compute graphs — SNN forward (scan of L1 kernels), the
chunked serving variant, and the baseline ANN — plus their training
objectives.

Everything here is build-time only: `aot.py` lowers the jitted forwards to
HLO text and the Rust runtime executes them; Python never runs on the
request path.
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import encoder as k_encoder
from .kernels import lif as k_lif
from .kernels import ref


@dataclass(frozen=True)
class ModelConfig:
    """Mirror of rust SnnConfig (the architectural constants baked into the
    lowered HLO; the weights artifact records the same values and the Rust
    runtime cross-checks them at load time)."""
    n_inputs: int = 784
    n_outputs: int = 10
    v_th: int = 128
    v_rest: int = 0
    decay_shift: int = 3
    acc_bits: int = 24
    weight_bits: int = 9
    timesteps: int = 20
    prune_after: int = 1  # 0 = pruning off

    def lif_kwargs(self):
        return dict(v_th=self.v_th, v_rest=self.v_rest,
                    decay_shift=self.decay_shift, acc_bits=self.acc_bits,
                    prune_after=self.prune_after)


# ---------------------------------------------------------------------------
# SNN forward (scan over timesteps, calling the L1 pallas kernels)
# ---------------------------------------------------------------------------

def snn_forward(images, seeds, weights, cfg: ModelConfig, *,
                use_pallas: bool = True, block_batch: int = 8):
    """Full-window forward: spike counts int32[B, N].

    images int32[B, P] (0..255), seeds uint32[B], weights int32[P, N].
    A single `lax.scan` carries (prng states, membranes, counts, enabled);
    the encoder is folded into the scan so no [T, B, P] spike tensor is
    ever materialized (DESIGN.md §10 L2).
    """
    b, p = images.shape
    n = weights.shape[1]
    states0 = ref.initial_states(seeds, p)
    acc0 = jnp.full((b, n), cfg.v_rest, dtype=jnp.int32)
    counts0 = jnp.zeros((b, n), dtype=jnp.int32)
    enabled0 = jnp.ones((b, n), dtype=jnp.int32)

    def step(carry, _):
        states, acc, counts, enabled = carry
        if use_pallas:
            states, spikes = k_encoder.encoder_step(
                states, images, block_batch=block_batch)
            acc, counts, enabled, _ = k_lif.lif_step(
                spikes, weights, acc, counts, enabled,
                block_batch=block_batch, **cfg.lif_kwargs())
        else:
            states, spikes = ref.encoder_step(states, images)
            acc, counts, enabled, _ = ref.lif_step(
                spikes, weights, acc, counts, enabled, **cfg.lif_kwargs())
        return (states, acc, counts, enabled), None

    (_, _, counts, _), _ = lax.scan(
        step, (states0, acc0, counts0, enabled0), None, length=cfg.timesteps)
    return counts


def snn_chunk(images, states, acc, counts, enabled, weights,
              cfg: ModelConfig, *, chunk_steps: int, use_pallas: bool = True,
              block_batch: int = 8):
    """Run `chunk_steps` timesteps from an explicit carry and return the
    updated carry — the building block of the coordinator's early-exit
    scheduler (run a chunk, check the margin, decide whether to continue).

    Returns (states', acc', counts', enabled').
    """
    def step(carry, _):
        st, a, c, e = carry
        if use_pallas:
            st, spikes = k_encoder.encoder_step(st, images, block_batch=block_batch)
            a, c, e, _ = k_lif.lif_step(
                spikes, weights, a, c, e, block_batch=block_batch,
                **cfg.lif_kwargs())
        else:
            st, spikes = ref.encoder_step(st, images)
            a, c, e, _ = ref.lif_step(spikes, weights, a, c, e, **cfg.lif_kwargs())
        return (st, a, c, e), None

    (states, acc, counts, enabled), _ = lax.scan(
        step, (states, acc, counts, enabled), None, length=chunk_steps)
    return states, acc, counts, enabled


def snn_init_carry(images, seeds, cfg: ModelConfig):
    """Fresh carry for `snn_chunk` (also lowered as an artifact so the Rust
    side never re-implements the seeding network for the XLA backend)."""
    b, p = images.shape
    n = cfg.n_outputs
    return (ref.initial_states(seeds, p),
            jnp.full((b, n), cfg.v_rest, dtype=jnp.int32),
            jnp.zeros((b, n), dtype=jnp.int32),
            jnp.ones((b, n), dtype=jnp.int32))


# ---------------------------------------------------------------------------
# Packed-carry chunk variant (the serving executables).
#
# The Rust runtime's PJRT wrapper returns a computation's root as a single
# buffer, so a tuple carry would force a host round-trip per chunk. Packing
# the carry into ONE int32 array — [states (bitcast), acc, counts, enabled]
# along axis 1 — makes the chunk executable array-in/array-out, and the
# carry buffer stays device-resident across chunks (EXPERIMENTS.md §Perf
# pass 6). Column layout (n_inputs = P, n_outputs = N):
#   [0, P)            xorshift32 states, bitcast uint32<->int32
#   [P, P+N)          membrane accumulators
#   [P+N, P+2N)       spike counts   <- the slice Rust reads per chunk
#   [P+2N, P+3N)      enabled mask
# ---------------------------------------------------------------------------

def pack_carry(states, acc, counts, enabled):
    """Pack the scan carry into a single int32 array (see layout above)."""
    states_i32 = jax.lax.bitcast_convert_type(states, jnp.int32)
    return jnp.concatenate([states_i32, acc, counts, enabled], axis=1)


def unpack_carry(packed, n_outputs: int):
    """Inverse of `pack_carry`."""
    n = n_outputs
    p = packed.shape[1] - 3 * n
    states = jax.lax.bitcast_convert_type(packed[:, :p], jnp.uint32)
    acc = packed[:, p:p + n]
    counts = packed[:, p + n:p + 2 * n]
    enabled = packed[:, p + 2 * n:]
    return states, acc, counts, enabled


def snn_init_packed(seeds, cfg: ModelConfig, n_pixels: int):
    """Packed-carry init: seeds -> carry0 (single int32 array)."""
    b = seeds.shape[0]
    n = cfg.n_outputs
    return pack_carry(
        ref.initial_states(seeds, n_pixels),
        jnp.full((b, n), cfg.v_rest, dtype=jnp.int32),
        jnp.zeros((b, n), dtype=jnp.int32),
        jnp.ones((b, n), dtype=jnp.int32))


def snn_chunk_packed(images, carry, weights, cfg: ModelConfig, *,
                     chunk_steps: int, use_pallas: bool = True,
                     block_batch: int = 8):
    """Packed-carry chunk: `chunk_steps` timesteps, array-in/array-out."""
    states, acc, counts, enabled = unpack_carry(carry, cfg.n_outputs)
    states, acc, counts, enabled = snn_chunk(
        images, states, acc, counts, enabled, weights, cfg,
        chunk_steps=chunk_steps, use_pallas=use_pallas,
        block_batch=block_batch)
    return pack_carry(states, acc, counts, enabled)


# ---------------------------------------------------------------------------
# Baseline ANN (the paper's §V comparator: 784-32-10 f32 MLP)
# ---------------------------------------------------------------------------

def ann_forward(images_f32, w1, b1, w2, b2):
    """Baseline MLP logits: relu(images @ w1 + b1) @ w2 + b2.

    images_f32: f32[B, 784] already scaled to [0, 1].
    """
    h = jax.nn.relu(images_f32 @ w1 + b1)
    return h @ w2 + b2


def ann_init(key, n_in=784, n_hidden=32, n_out=10):
    k1, k2 = jax.random.split(key)
    w1 = jax.random.normal(k1, (n_in, n_hidden), jnp.float32) * (1.0 / jnp.sqrt(n_in))
    w2 = jax.random.normal(k2, (n_hidden, n_out), jnp.float32) * (1.0 / jnp.sqrt(n_hidden))
    return w1, jnp.zeros((n_hidden,), jnp.float32), w2, jnp.zeros((n_out,), jnp.float32)


# ---------------------------------------------------------------------------
# Training objectives
# ---------------------------------------------------------------------------

def rate_proxy_logits(images_f32, w_f32):
    """The rate-coded proxy: E[input current per step] ∝ (I/256) @ W, so a
    linear classifier on normalized intensity transfers directly to the
    spiking readout (DESIGN.md §5 train path)."""
    return images_f32 @ w_f32


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def rate_proxy_loss(w_f32, images_f32, labels, l2: float = 1e-4):
    return cross_entropy(rate_proxy_logits(images_f32, w_f32), labels) \
        + l2 * jnp.sum(w_f32 * w_f32)


def ann_loss(params, images_f32, labels, l2: float = 1e-4):
    w1, b1, w2, b2 = params
    logits = ann_forward(images_f32, w1, b1, w2, b2)
    reg = l2 * (jnp.sum(w1 * w1) + jnp.sum(w2 * w2))
    return cross_entropy(logits, labels) + reg


# ---------------------------------------------------------------------------
# Surrogate-gradient training (optional second path): float relaxation of
# the fixed-point dynamics with a straight-through spike estimator.
# ---------------------------------------------------------------------------

@jax.custom_vjp
def spike_st(v):
    """Heaviside spike with a triangular surrogate gradient."""
    return (v >= 0.0).astype(jnp.float32)


def _spike_fwd(v):
    return spike_st(v), v


def _spike_bwd(v, g):
    # Triangular surrogate: max(0, 1 - |v| / width), width = 2·V_th scale.
    grad = jnp.maximum(0.0, 1.0 - jnp.abs(v)) * g
    return (grad,)


spike_st.defvjp(_spike_fwd, _spike_bwd)


def surrogate_forward(images_f32, w_f32, key, cfg: ModelConfig, *,
                      timesteps: int):
    """Differentiable SNN: Bernoulli(intensity) encoding with a float LIF,
    returning spike counts. Used by `train.py --method surrogate`."""
    beta = 1.0 - 2.0 ** (-cfg.decay_shift)
    v_th = float(cfg.v_th)

    def step(carry, k):
        acc = carry
        spikes = jax.random.bernoulli(k, images_f32).astype(jnp.float32)
        current = spikes @ w_f32
        leaked = (acc + current) * beta
        fired = spike_st((leaked - v_th) / v_th)
        acc = leaked * (1.0 - fired)
        return acc, fired

    b = images_f32.shape[0]
    acc0 = jnp.zeros((b, cfg.n_outputs), jnp.float32)
    keys = jax.random.split(key, timesteps)
    _, fires = lax.scan(step, acc0, keys)
    return fires.sum(axis=0)


def surrogate_loss(w_f32, images_f32, labels, key, cfg: ModelConfig,
                   timesteps: int = 10, l2: float = 1e-5):
    counts = surrogate_forward(images_f32, w_f32, key, cfg, timesteps=timesteps)
    return cross_entropy(counts, labels) + l2 * jnp.sum(w_f32 * w_f32)
