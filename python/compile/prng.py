"""Bit-exact Python mirror of rust/src/prng/ (xorshift32 + splitmix32).

This module is the cross-language PRNG contract. Scalar helpers use plain
Python ints (masked to 32 bits); vectorized helpers use numpy uint32 and
produce the identical streams. Golden values are pinned in
python/tests/test_prng.py and rust/src/prng/mod.rs.
"""

import numpy as np

M32 = 0xFFFFFFFF
GOLDEN_GAMMA = 0x9E3779B9
ZERO_STATE_FALLBACK = 0xDEADBEEF


def xorshift32_step(x: int) -> int:
    """One Marsaglia xorshift32 (13/17/5) state transition."""
    x ^= (x << 13) & M32
    x ^= x >> 17
    x ^= (x << 5) & M32
    return x


def splitmix32(x: int) -> int:
    """32-bit splitmix finalizer (full avalanche), for seeding."""
    z = (x + GOLDEN_GAMMA) & M32
    z = ((z ^ (z >> 16)) * 0x85EBCA6B) & M32
    z = ((z ^ (z >> 13)) * 0xC2B2AE35) & M32
    return z ^ (z >> 16)


def pixel_seed(seed: int, index: int) -> int:
    """Initial xorshift state for pixel `index` of an image under `seed`."""
    s = splitmix32((seed ^ (index * GOLDEN_GAMMA)) & M32)
    return s if s != 0 else ZERO_STATE_FALLBACK


def derive_state(seed: int, a: int, b: int) -> int:
    """Initial state for the (a, b)-indexed derived stream (dataset etc.)."""
    s = splitmix32((splitmix32((seed ^ (a * 0x85EBCA6B)) & M32) ^ (b * GOLDEN_GAMMA)) & M32)
    return s if s != 0 else ZERO_STATE_FALLBACK


class Xorshift32:
    """Scalar stateful generator mirroring rust's ``Xorshift32``."""

    def __init__(self, seed: int):
        s = splitmix32(seed & M32)
        self.state = s if s != 0 else ZERO_STATE_FALLBACK

    @classmethod
    def from_raw_state(cls, state: int) -> "Xorshift32":
        assert state != 0, "xorshift32 cannot leave the zero state"
        r = cls.__new__(cls)
        r.state = state & M32
        return r

    def next_u32(self) -> int:
        self.state = xorshift32_step(self.state)
        return self.state

    def below(self, bound: int) -> int:
        """Uniform in [0, bound) by multiply-shift (matches rust)."""
        return (self.next_u32() * bound) >> 32

    def range_i32(self, lo: int, hi: int) -> int:
        """Uniform in [lo, hi] inclusive (matches rust)."""
        assert lo <= hi
        return lo + self.below(hi - lo + 1)


def pixel_seeds_np(seed: int, n: int) -> np.ndarray:
    """Vectorized [`pixel_seed`] for indices 0..n (uint32)."""
    idx = np.arange(n, dtype=np.uint64)
    x = (np.uint64(seed) ^ (idx * np.uint64(GOLDEN_GAMMA))) & np.uint64(M32)
    s = splitmix32_np(x.astype(np.uint32))
    return np.where(s == 0, np.uint32(ZERO_STATE_FALLBACK), s)


def splitmix32_np(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix32 over uint32 arrays."""
    assert x.dtype == np.uint32
    with np.errstate(over="ignore"):
        z = x + np.uint32(GOLDEN_GAMMA)
        z = (z ^ (z >> np.uint32(16))) * np.uint32(0x85EBCA6B)
        z = (z ^ (z >> np.uint32(13))) * np.uint32(0xC2B2AE35)
        return z ^ (z >> np.uint32(16))


def xorshift32_step_np(x: np.ndarray) -> np.ndarray:
    """Vectorized xorshift32 step over uint32 arrays."""
    assert x.dtype == np.uint32
    x = x ^ ((x << np.uint32(13)) & np.uint32(M32))
    x = x ^ (x >> np.uint32(17))
    x = x ^ ((x << np.uint32(5)) & np.uint32(M32))
    return x
