"""Training: produce the 9-bit SNN weights and the baseline ANN weights.

The paper does not describe its training procedure (the RTL is inference-
only); we train offline exactly the way such weights are normally obtained
for a rate-coded SNN:

1. **Rate proxy** (default): with Poisson encoding, the expected input
   current per timestep is `(I/256) @ W`, so a linear softmax classifier on
   normalized intensities transfers directly to the spiking readout. After
   training we *centre* the weights across classes (a per-pixel shift that
   cannot change the softmax decision) so that wrong-class currents go
   negative and the spike-count readout discriminates, quantize to the
   9-bit grid, and calibrate `V_th` by a validation sweep of the actual
   fixed-point spiking forward (kernels/ref.py).

2. **Surrogate gradient** (`method="surrogate"`): BPTT through a float
   relaxation of the LIF dynamics with a triangular straight-through spike
   estimator — slower, used by the ablation study.

Also trains the §V baseline: the 784-32-10 f32 MLP whose op counts and
memory footprint reproduce the paper's Table II arithmetic exactly.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .kernels import ref


# ---------------------------------------------------------------------------
# Minimal Adam (optax is not part of the offline environment)
# ---------------------------------------------------------------------------

def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return zeros, zeros, 0


def adam_update(params, grads, state, lr=1e-2, b1=0.9, b2=0.999, eps=1e-8):
    m, v, t = state
    t += 1
    m = jax.tree_util.tree_map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mhat = jax.tree_util.tree_map(lambda a: a / (1 - b1 ** t), m)
    vhat = jax.tree_util.tree_map(lambda a: a / (1 - b2 ** t), v)
    params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat)
    return params, (m, v, t)


# ---------------------------------------------------------------------------
# Rate-proxy SNN training
# ---------------------------------------------------------------------------

def train_rate_proxy(images: np.ndarray, labels: np.ndarray, *, steps: int = 400,
                     lr: float = 5e-2, l2: float = 1e-4, seed: int = 0,
                     log=print):
    """Full-batch Adam on the linear rate proxy. Returns float32 W[784, 10]."""
    x = jnp.asarray(images, jnp.float32) / 256.0
    y = jnp.asarray(labels, jnp.int32)
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (images.shape[1], 10), jnp.float32) * 0.01

    loss_grad = jax.jit(jax.value_and_grad(
        functools.partial(M.rate_proxy_loss, l2=l2)))
    state = adam_init(w)
    for i in range(steps):
        loss, g = loss_grad(w, x, y)
        w, state = adam_update(w, g, state, lr=lr)
        if i % 100 == 0 or i == steps - 1:
            acc = float(jnp.mean(jnp.argmax(M.rate_proxy_logits(x, w), 1) == y))
            log(f"  rate-proxy step {i}: loss {float(loss):.4f} acc {acc:.4f}")
    return np.asarray(w)


def train_surrogate(images: np.ndarray, labels: np.ndarray, cfg: M.ModelConfig,
                    *, epochs: int = 30, batch: int = 256, lr: float = 2e-2,
                    timesteps: int = 10, seed: int = 0, log=print):
    """Minibatch surrogate-gradient BPTT. Returns float32 W[784, 10]."""
    x_all = jnp.asarray(images, jnp.float32) / 256.0
    y_all = jnp.asarray(labels, jnp.int32)
    n = x_all.shape[0]
    key = jax.random.PRNGKey(seed)
    key, wkey = jax.random.split(key)
    w = jax.random.normal(wkey, (images.shape[1], 10), jnp.float32) * 0.01

    loss_grad = jax.jit(jax.value_and_grad(
        lambda wt, xb, yb, k: M.surrogate_loss(wt, xb, yb, k, cfg,
                                               timesteps=timesteps)))
    state = adam_init(w)
    rng = np.random.default_rng(seed)
    for ep in range(epochs):
        order = rng.permutation(n)
        losses = []
        for s in range(0, n - batch + 1, batch):
            idx = order[s:s + batch]
            key, k = jax.random.split(key)
            loss, g = loss_grad(w, x_all[idx], y_all[idx], k)
            w, state = adam_update(w, g, state, lr=lr)
            losses.append(float(loss))
        if ep % 10 == 0 or ep == epochs - 1:
            log(f"  surrogate epoch {ep}: loss {np.mean(losses):.4f}")
    return np.asarray(w)


def centre_and_quantize(w_f32: np.ndarray, *, bits: int = 9,
                        target_mean_current: float = 260.0,
                        images: np.ndarray = None, labels: np.ndarray = None):
    """Centre weights across classes, then scale so the mean correct-class
    expected per-step current lands near `target_mean_current` accumulator
    units (the regime of the paper's Table I), saturating the 9-bit grid.
    """
    w = w_f32 - w_f32.mean(axis=1, keepdims=True)
    wmax = (1 << (bits - 1)) - 1
    if images is not None:
        x = images.astype(np.float64) / 256.0
        cur = x @ w  # expected per-step current, float scale
        correct = cur[np.arange(len(labels)), labels]
        mean_cur = float(np.mean(correct))
        scale = target_mean_current / max(mean_cur, 1e-9)
        # Never exceed the representable range.
        scale = min(scale, wmax / float(np.abs(w).max()))
    else:
        scale = wmax / float(np.abs(w).max())
    q = np.round(w * scale)
    q = np.clip(q, -(1 << (bits - 1)), wmax).astype(np.int32)
    return q


def calibrate(weights_q: np.ndarray, images: np.ndarray,
              labels: np.ndarray, cfg: M.ModelConfig, *,
              vth_candidates=(128, 192, 256, 320, 384, 512, 640),
              prune_candidates=(1, 3, 5, 8),
              windows=(10, 20), seed: int = 0xC0FFEE, log=print):
    """Joint (V_th, prune_after) sweep on the actual fixed-point spiking
    forward, scored by the *worst* accuracy across the evaluation windows
    (the convergence point T=10 and the deployed full window).

    Two measured pathologies motivate this (EXPERIMENTS.md):
    * the paper's literal pruning (gate after the *first* fire) caps every
      spike count at 1 and collapses the argmax readout;
    * small prune_after values that look fine at T=10 saturate the correct
      class's count by T=20, letting wrong classes tie.
    Ties prefer smaller V_th then smaller prune_after (more energy saved).
    """
    x = jnp.asarray(images, jnp.int32)
    y = np.asarray(labels)
    seeds = (np.arange(len(y), dtype=np.uint64) * 2654435761 + seed) % (1 << 32)
    seeds = jnp.asarray(seeds.astype(np.uint32))
    w = jnp.asarray(weights_q, jnp.int32)
    scores = {}
    for prune in prune_candidates:
        for vth in vth_candidates:
            accs = []
            for t in windows:
                counts = ref.snn_forward(
                    x, seeds, w, timesteps=t, v_th=vth, v_rest=cfg.v_rest,
                    decay_shift=cfg.decay_shift, acc_bits=cfg.acc_bits,
                    prune_after=prune)
                pred = np.asarray(jnp.argmax(counts, axis=1))
                accs.append(float(np.mean(pred == y)))
            scores[(vth, prune)] = min(accs)
        row = "  ".join(f"vth {v}: {scores[(v, prune)]:.3f}" for v in vth_candidates)
        log(f"  prune={prune}: {row}")
    best = max(scores, key=lambda k: (scores[k], -k[0], -k[1]))
    log(f"  calibrated (v_th, prune_after) = {best} (min-window acc {scores[best]:.4f})")
    return best[0], best[1], scores


# ---------------------------------------------------------------------------
# Baseline ANN training (784-32-10, the paper's §V comparator)
# ---------------------------------------------------------------------------

def train_ann(images: np.ndarray, labels: np.ndarray, *, steps: int = 600,
              lr: float = 5e-3, batch: int = 512, seed: int = 0, log=print):
    x_all = jnp.asarray(images, jnp.float32) / 256.0
    y_all = jnp.asarray(labels, jnp.int32)
    n = x_all.shape[0]
    params = M.ann_init(jax.random.PRNGKey(seed))
    loss_grad = jax.jit(jax.value_and_grad(M.ann_loss))
    state = adam_init(params)
    rng = np.random.default_rng(seed)
    for i in range(steps):
        idx = rng.integers(0, n, batch)
        loss, g = loss_grad(params, x_all[idx], y_all[idx])
        params, state = adam_update(params, g, state, lr=lr)
        if i % 200 == 0 or i == steps - 1:
            logits = M.ann_forward(x_all, *params)
            acc = float(jnp.mean(jnp.argmax(logits, 1) == y_all))
            log(f"  ann step {i}: loss {float(loss):.4f} acc {acc:.4f}")
    return [np.asarray(p) for p in params]


def evaluate_ann(params, images, labels):
    x = jnp.asarray(images, jnp.float32) / 256.0
    logits = M.ann_forward(x, *[jnp.asarray(p) for p in params])
    return float(jnp.mean(jnp.argmax(logits, 1) == jnp.asarray(labels)))


def evaluate_snn(weights_q, images, labels, cfg: M.ModelConfig, *,
                 timesteps=None, seed: int = 0xC0FFEE):
    t = timesteps if timesteps is not None else cfg.timesteps
    seeds = (np.arange(len(labels), dtype=np.uint64) * 2654435761 + seed) % (1 << 32)
    counts = ref.snn_forward(
        jnp.asarray(images, jnp.int32),
        jnp.asarray(seeds.astype(np.uint32)),
        jnp.asarray(weights_q, jnp.int32),
        timesteps=t, v_th=cfg.v_th, v_rest=cfg.v_rest,
        decay_shift=cfg.decay_shift, acc_bits=cfg.acc_bits,
        prune_after=cfg.prune_after)
    pred = np.asarray(jnp.argmax(counts, axis=1))
    return float(np.mean(pred == np.asarray(labels)))
