fn main() {}
