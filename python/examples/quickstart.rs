fn main() {}
