fn main() {}
