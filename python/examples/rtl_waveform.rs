fn main() {}
