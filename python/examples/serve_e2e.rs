fn main() {}
