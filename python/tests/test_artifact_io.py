"""Artifact codec roundtrips + agreement with the built artifacts."""

import os
import struct

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import artifact_io as aio

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_dataset_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    images = rng.integers(0, 256, (12, 784)).astype(np.uint8)
    labels = (np.arange(12) % 10).astype(np.uint8)
    p = str(tmp_path / "ds.bin")
    aio.save_dataset(p, images, labels)
    i2, l2 = aio.load_dataset(p)
    assert (i2 == images).all() and (l2 == labels).all()


@given(st.integers(0, 2**32 - 1), st.integers(2, 12))
@settings(max_examples=20, deadline=None)
def test_weights_pack_roundtrip(seed, bits):
    rng = np.random.default_rng(seed)
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    w = rng.integers(lo, hi + 1, (17, 5)).astype(np.int32)
    packed = aio.pack_weights(w, bits)
    assert len(packed) == (17 * 5 * bits + 7) // 8
    back = aio.unpack_weights(packed, 17, 5, bits)
    assert (back == w).all()


def test_weights_file_roundtrip(tmp_path):
    rng = np.random.default_rng(1)
    w = rng.integers(-256, 256, (784, 10)).astype(np.int32)
    p = str(tmp_path / "w.bin")
    aio.save_weights(p, w, bits=9, v_th=384, decay_shift=3, timesteps=20,
                     prune_after=5)
    w2, meta = aio.load_weights(p)
    assert (w2 == w).all()
    assert meta == dict(v_th=384, decay_shift=3, timesteps=20, bits=9,
                        prune_after=5)


def test_magnitude_prune_matches_csr_keep_predicate():
    w = np.array([[-5, -3, 0, 2], [7, -2, 3, -8]], dtype=np.int32)
    pruned = aio.magnitude_prune(w, 3)
    assert (pruned == np.array([[-5, -3, 0, 0], [7, 0, 3, -8]])).all()
    assert aio.sparse_nnz(w, 3) == 5
    # Threshold 0 keeps everything, explicit zeros included.
    assert (aio.magnitude_prune(w, 0) == w).all()
    assert aio.sparse_nnz(w, 0) == w.size


def test_weight_stack_roundtrip_v2_v3_v4(tmp_path):
    rng = np.random.default_rng(7)
    layers = [rng.integers(-200, 201, (20, 6)).astype(np.int32),
              rng.integers(-200, 201, (6, 4)).astype(np.int32)]
    cal = dict(bits=9, v_th=300, decay_shift=3, timesteps=8, prune_after=2)

    p2 = str(tmp_path / "s2.bin")
    aio.save_weight_stack(p2, layers, **cal)
    back, meta = aio.load_weight_stack(p2)
    assert all((a == b).all() for a, b in zip(back, layers))
    assert meta["layer_params"] is None and meta["sparse_threshold"] is None
    with open(p2, "rb") as f:
        assert struct.unpack_from("<I", f.read(), 4)[0] == 2

    p3 = str(tmp_path / "s3.bin")
    triples = [(160, 3, 1), (40, 2, 0)]
    aio.save_weight_stack(p3, layers, layer_params=triples, **cal)
    _, meta = aio.load_weight_stack(p3)
    assert meta["layer_params"] == triples
    with open(p3, "rb") as f:
        assert struct.unpack_from("<I", f.read(), 4)[0] == 3

    p4 = str(tmp_path / "s4.bin")
    aio.save_weight_stack(p4, layers, layer_params=triples,
                          sparse_threshold=25, **cal)
    back, meta = aio.load_weight_stack(p4)
    assert all((a == b).all() for a, b in zip(back, layers))
    assert meta["layer_params"] == triples
    assert meta["sparse_threshold"] == 25
    with open(p4, "rb") as f:
        buf = f.read()
    assert struct.unpack_from("<I", buf, 4)[0] == 4
    # A lying nnz word must be caught by the load-time recount.
    # v4 header with params: 4+4+4 + 2*8 + 20 + 4(flag) + 2*12 + 4(thresh).
    nnz_off = 4 + 4 + 4 + 16 + 20 + 4 + 24 + 4
    (nnz0,) = struct.unpack_from("<I", buf, nnz_off)
    assert nnz0 == aio.sparse_nnz(layers[0], 25)
    lied = bytearray(buf)
    struct.pack_into("<I", lied, nnz_off, nnz0 + 1)
    p4bad = str(tmp_path / "s4bad.bin")
    with open(p4bad, "wb") as f:
        f.write(bytes(lied))
    with pytest.raises(AssertionError):
        aio.load_weight_stack(p4bad)


def test_ann_roundtrip(tmp_path):
    rng = np.random.default_rng(2)
    w1 = rng.normal(size=(784, 32)).astype(np.float32)
    b1 = rng.normal(size=32).astype(np.float32)
    w2 = rng.normal(size=(32, 10)).astype(np.float32)
    b2 = rng.normal(size=10).astype(np.float32)
    p = str(tmp_path / "ann.bin")
    aio.save_ann(p, w1, b1, w2, b2)
    r1, rb1, r2, rb2 = aio.load_ann(p)
    for a, b in [(r1, w1), (rb1, b1), (r2, w2), (rb2, b2)]:
        assert (a == b).all()


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.txt")),
                    reason="artifacts not built")
def test_built_artifacts_consistent():
    """The canonical artifacts load and agree with the manifest."""
    manifest = {}
    with open(os.path.join(ART, "manifest.txt")) as f:
        for line in f:
            k, _, v = line.strip().partition("=")
            manifest[k] = v
    w, meta = aio.load_weights(os.path.join(ART, "weights.bin"))
    assert w.shape == (int(manifest["n_inputs"]), int(manifest["n_outputs"]))
    assert meta["v_th"] == int(manifest["v_th"])
    assert meta["prune_after"] == int(manifest["prune_after"])
    images, labels = aio.load_dataset(os.path.join(ART, "digits_test.bin"))
    assert len(labels) == 10 * int(manifest["test_per_class"])
    for name in manifest["hlo_files"].split(","):
        path = os.path.join(ART, name)
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{name} is not HLO text"
