"""Artifact codec roundtrips + agreement with the built artifacts."""

import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import artifact_io as aio

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_dataset_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    images = rng.integers(0, 256, (12, 784)).astype(np.uint8)
    labels = (np.arange(12) % 10).astype(np.uint8)
    p = str(tmp_path / "ds.bin")
    aio.save_dataset(p, images, labels)
    i2, l2 = aio.load_dataset(p)
    assert (i2 == images).all() and (l2 == labels).all()


@given(st.integers(0, 2**32 - 1), st.integers(2, 12))
@settings(max_examples=20, deadline=None)
def test_weights_pack_roundtrip(seed, bits):
    rng = np.random.default_rng(seed)
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    w = rng.integers(lo, hi + 1, (17, 5)).astype(np.int32)
    packed = aio.pack_weights(w, bits)
    assert len(packed) == (17 * 5 * bits + 7) // 8
    back = aio.unpack_weights(packed, 17, 5, bits)
    assert (back == w).all()


def test_weights_file_roundtrip(tmp_path):
    rng = np.random.default_rng(1)
    w = rng.integers(-256, 256, (784, 10)).astype(np.int32)
    p = str(tmp_path / "w.bin")
    aio.save_weights(p, w, bits=9, v_th=384, decay_shift=3, timesteps=20,
                     prune_after=5)
    w2, meta = aio.load_weights(p)
    assert (w2 == w).all()
    assert meta == dict(v_th=384, decay_shift=3, timesteps=20, bits=9,
                        prune_after=5)


def test_ann_roundtrip(tmp_path):
    rng = np.random.default_rng(2)
    w1 = rng.normal(size=(784, 32)).astype(np.float32)
    b1 = rng.normal(size=32).astype(np.float32)
    w2 = rng.normal(size=(32, 10)).astype(np.float32)
    b2 = rng.normal(size=10).astype(np.float32)
    p = str(tmp_path / "ann.bin")
    aio.save_ann(p, w1, b1, w2, b2)
    r1, rb1, r2, rb2 = aio.load_ann(p)
    for a, b in [(r1, w1), (rb1, b1), (r2, w2), (rb2, b2)]:
        assert (a == b).all()


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.txt")),
                    reason="artifacts not built")
def test_built_artifacts_consistent():
    """The canonical artifacts load and agree with the manifest."""
    manifest = {}
    with open(os.path.join(ART, "manifest.txt")) as f:
        for line in f:
            k, _, v = line.strip().partition("=")
            manifest[k] = v
    w, meta = aio.load_weights(os.path.join(ART, "weights.bin"))
    assert w.shape == (int(manifest["n_inputs"]), int(manifest["n_outputs"]))
    assert meta["v_th"] == int(manifest["v_th"])
    assert meta["prune_after"] == int(manifest["prune_after"])
    images, labels = aio.load_dataset(os.path.join(ART, "digits_test.bin"))
    assert len(labels) == 10 * int(manifest["test_per_class"])
    for name in manifest["hlo_files"].split(","):
        path = os.path.join(ART, name)
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{name} is not HLO text"
