"""Dataset generator tests, including the cross-language goldens."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.dataset import (IMG_PIXELS, IMG_SIDE, apply_perturbation,
                             build_dataset, fnv1a32, noise, occlude,
                             render_digit, rotate, shift,
                             PERTURB_CLEAN, PERTURB_NOISE, PERTURB_OCCLUDE,
                             PERTURB_ROTATE, PERTURB_SHIFT)
from compile.prng import Xorshift32


def test_cross_language_golden_hashes():
    """Mirrors rust data::digitgen::tests::cross_language_golden_hashes."""
    a, _ = render_digit(1, 3, 7)
    assert fnv1a32(a.tobytes()) == 0x03D495A4
    b, _ = render_digit(2, 8, 0)
    assert fnv1a32(b.tobytes()) == 0x74ACA3A0


def test_deterministic():
    a, pa = render_digit(1, 3, 7)
    b, pb = render_digit(1, 3, 7)
    assert (a == b).all()
    assert pa == pb


def test_distinct_across_keys():
    a, _ = render_digit(1, 3, 7)
    for other in [render_digit(2, 3, 7), render_digit(1, 4, 7), render_digit(1, 3, 8)]:
        assert not (a == other[0]).all()


@given(st.integers(0, 2**32 - 1), st.integers(0, 9), st.integers(0, 999))
@settings(max_examples=40, deadline=None)
def test_images_have_ink(seed, cls, index):
    px, params = render_digit(seed, cls, index)
    ink = int((px > 0).sum())
    assert 40 <= ink <= 600
    assert int(px.max()) == params.peak


def test_dataset_balanced_interleaved():
    imgs, lbls = build_dataset(1, 4)
    assert imgs.shape == (40, IMG_PIXELS)
    for pos in range(40):
        assert lbls[pos] == pos % 10


def test_rotate_zero_identity():
    px, _ = render_digit(1, 5, 0)
    assert (rotate(px, 0) == px).all()


def test_shift_exact():
    px, _ = render_digit(1, 5, 0)
    s = shift(px, 3, -2).reshape(IMG_SIDE, IMG_SIDE)
    src = px.reshape(IMG_SIDE, IMG_SIDE)
    assert (s[0:26, 3:] == src[2:28, 0:25]).all()


def test_noise_statistics():
    img = np.full(IMG_PIXELS, 128, np.uint8)
    rng = Xorshift32(1)
    n = noise(img, 138, rng).astype(np.float64)
    assert abs(n.mean() - 128) < 6
    assert abs(n.std() - 39.9) < 6


def test_occlude_block():
    px, _ = render_digit(1, 5, 0)
    o = occlude(px, 5, 7, 10).reshape(IMG_SIDE, IMG_SIDE)
    assert (o[5:15, 7:17] == 0).all()


def test_perturbations_deterministic_per_index():
    px, _ = render_digit(1, 5, 0)
    for kind in [PERTURB_CLEAN, PERTURB_ROTATE, PERTURB_SHIFT, PERTURB_NOISE,
                 PERTURB_OCCLUDE]:
        a = apply_perturbation(kind, px, 42, 3)
        b = apply_perturbation(kind, px, 42, 3)
        assert (a == b).all()
