"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the core correctness signal of the compile path: hypothesis sweeps
shapes, seeds, weights and config constants, asserting bit-exact agreement
between the pallas_call implementations and the reference.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.encoder import encoder_step as pallas_encoder_step
from compile.kernels.lif import lif_step as pallas_lif_step

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def np_rng(seed):
    return np.random.default_rng(seed)


# -- encoder ------------------------------------------------------------------

@given(st.integers(0, 2**32 - 1), st.integers(1, 16), st.integers(1, 900))
def test_encoder_matches_ref(seed, b, p):
    rng = np_rng(seed)
    states = rng.integers(1, 2**32, (b, p), dtype=np.uint64).astype(np.uint32)
    intensities = rng.integers(0, 256, (b, p)).astype(np.int32)
    ns_r, sp_r = ref.encoder_step(jnp.asarray(states), jnp.asarray(intensities))
    ns_k, sp_k = pallas_encoder_step(jnp.asarray(states), jnp.asarray(intensities))
    assert (np.asarray(ns_r) == np.asarray(ns_k)).all()
    assert (np.asarray(sp_r) == np.asarray(sp_k)).all()


def test_encoder_rate_tracks_intensity():
    # Statistical check of the Poisson property (paper §III-C).
    b, p, t = 1, 784, 200
    for intensity in [32, 128, 224]:
        states = ref.initial_states(jnp.asarray([7], jnp.uint32), p)
        imgs = jnp.full((b, p), intensity, jnp.int32)
        total = 0
        for _ in range(t):
            states, spikes = pallas_encoder_step(states, imgs)
            total += int(spikes.sum())
        rate = total / (t * p)
        assert abs(rate - intensity / 256) < 0.01


def test_encoder_zero_never_spikes():
    states = ref.initial_states(jnp.asarray([3], jnp.uint32), 784)
    imgs = jnp.zeros((1, 784), jnp.int32)
    for _ in range(20):
        states, spikes = pallas_encoder_step(states, imgs)
        assert int(spikes.sum()) == 0


# -- LIF ----------------------------------------------------------------------

@given(
    st.integers(0, 2**32 - 1),
    st.integers(1, 12),     # batch
    st.integers(1, 64),     # inputs
    st.integers(1, 12),     # outputs
    st.integers(1, 6),      # decay shift
    st.integers(1, 3),      # steps to chain
    st.sampled_from([0, 1, 3]),  # prune_after
)
def test_lif_matches_ref_chained(seed, b, p, n, decay, steps, prune):
    rng = np_rng(seed)
    w = jnp.asarray(rng.integers(-256, 256, (p, n)).astype(np.int32))
    kw = dict(v_th=int(rng.integers(8, 200)), v_rest=0, decay_shift=decay,
              acc_bits=24, prune_after=prune)
    acc_r = acc_k = jnp.zeros((b, n), jnp.int32)
    cnt_r = cnt_k = jnp.zeros((b, n), jnp.int32)
    en_r = en_k = jnp.ones((b, n), jnp.int32)
    for _ in range(steps):
        spikes = jnp.asarray(rng.integers(0, 2, (b, p)).astype(np.int32))
        acc_r, cnt_r, en_r, f_r = ref.lif_step(spikes, w, acc_r, cnt_r, en_r, **kw)
        acc_k, cnt_k, en_k, f_k = pallas_lif_step(spikes, w, acc_k, cnt_k, en_k, **kw)
        for a, b2, name in [(acc_r, acc_k, "acc"), (cnt_r, cnt_k, "counts"),
                            (en_r, en_k, "enabled"), (f_r, f_k, "fired")]:
            assert (np.asarray(a) == np.asarray(b2)).all(), name


def test_lif_saturation_clamps():
    # acc_bits=8 -> rails ±127; an absurd drive must clamp, not wrap.
    w = jnp.full((4, 2), 255, jnp.int32)
    spikes = jnp.ones((1, 4), jnp.int32)
    acc = jnp.zeros((1, 2), jnp.int32)
    cnt = jnp.zeros((1, 2), jnp.int32)
    en = jnp.ones((1, 2), jnp.int32)
    acc2, _, _, _ = pallas_lif_step(spikes, w, acc, cnt, en, v_th=1000, v_rest=0,
                                    decay_shift=3, acc_bits=8, prune_after=0)
    # clip(1020, -127, 127) = 127; leak: 127 - 15 = 112.
    assert int(acc2[0, 0]) == 112


def test_lif_pruned_neuron_frozen():
    w = jnp.full((4, 1), 100, jnp.int32)
    spikes = jnp.ones((1, 4), jnp.int32)
    acc = jnp.zeros((1, 1), jnp.int32)
    cnt = jnp.zeros((1, 1), jnp.int32)
    en = jnp.ones((1, 1), jnp.int32)
    kw = dict(v_th=50, v_rest=0, decay_shift=3, acc_bits=24, prune_after=1)
    acc, cnt, en, fired = pallas_lif_step(spikes, w, acc, cnt, en, **kw)
    assert int(fired[0, 0]) == 1 and int(en[0, 0]) == 0
    # Second step: no integration, no new fire, membrane untouched.
    acc2, cnt2, en2, fired2 = pallas_lif_step(spikes, w, acc, cnt, en, **kw)
    assert int(fired2[0, 0]) == 0
    assert int(cnt2[0, 0]) == 1
    assert int(acc2[0, 0]) == int(acc[0, 0])


def test_lif_negative_membrane_decays_toward_zero():
    w = jnp.full((1, 1), -64, jnp.int32)
    spikes = jnp.ones((1, 1), jnp.int32)
    acc = jnp.zeros((1, 1), jnp.int32)
    cnt = jnp.zeros((1, 1), jnp.int32)
    en = jnp.ones((1, 1), jnp.int32)
    kw = dict(v_th=100, v_rest=0, decay_shift=2, acc_bits=24, prune_after=0)
    acc, cnt, en, _ = pallas_lif_step(spikes, w, acc, cnt, en, **kw)
    # -64 - (-64>>2) = -64 + 16 = -48
    assert int(acc[0, 0]) == -48
    zero = jnp.zeros((1, 1), jnp.int32)
    acc2, _, _, _ = pallas_lif_step(zero, w, acc, cnt, en, **kw)
    assert int(acc2[0, 0]) == -36
