"""L2 tests: scan forward vs the reference loop, the chunked serving
variant, config semantics, and the ANN baseline shape contract."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.kernels import ref


def setup_case(b=8, seed=0):
    rng = np.random.default_rng(seed)
    images = jnp.asarray(rng.integers(0, 256, (b, 784)).astype(np.int32))
    seeds = jnp.asarray(rng.integers(0, 2**32, b, dtype=np.uint64).astype(np.uint32))
    w = jnp.asarray(rng.integers(-64, 64, (784, 10)).astype(np.int32))
    return images, seeds, w


@pytest.mark.parametrize("use_pallas", [True, False])
@pytest.mark.parametrize("prune", [0, 5])
def test_forward_matches_ref(use_pallas, prune):
    images, seeds, w = setup_case()
    cfg = M.ModelConfig(timesteps=6, v_th=200, prune_after=prune)
    counts = M.snn_forward(images, seeds, w, cfg, use_pallas=use_pallas)
    expect = ref.snn_forward(images, seeds, w, timesteps=6, v_th=200, v_rest=0,
                             decay_shift=3, acc_bits=24, prune_after=prune)
    assert (np.asarray(counts) == np.asarray(expect)).all()


def test_forward_jits_and_is_deterministic():
    images, seeds, w = setup_case()
    cfg = M.ModelConfig(timesteps=5)
    f = jax.jit(lambda i, s, wt: M.snn_forward(i, s, wt, cfg))
    a = f(images, seeds, w)
    b = f(images, seeds, w)
    assert (np.asarray(a) == np.asarray(b)).all()


def test_chunks_compose_to_full_window():
    """Running 4 chunks of 5 steps == one 20-step window (the early-exit
    scheduler's correctness precondition)."""
    images, seeds, w = setup_case()
    cfg = M.ModelConfig(timesteps=20, v_th=300)
    full = M.snn_forward(images, seeds, w, cfg)
    carry = M.snn_init_carry(images, seeds, cfg)
    for _ in range(4):
        carry = M.snn_chunk(images, *carry, w, cfg, chunk_steps=5)
    _, _, counts, _ = carry
    assert (np.asarray(counts) == np.asarray(full)).all()


def test_packed_chunks_compose_to_full_window():
    """The packed-carry serving executables (array-in/array-out) must
    compose to the same counts as the monolithic forward."""
    images, seeds, w = setup_case()
    cfg = M.ModelConfig(timesteps=20, v_th=300)
    full = M.snn_forward(images, seeds, w, cfg)
    carry = M.snn_init_packed(seeds, cfg, images.shape[1])
    assert carry.shape == (8, 784 + 3 * 10)
    assert carry.dtype == jnp.int32
    for _ in range(4):
        carry = M.snn_chunk_packed(images, carry, w, cfg, chunk_steps=5)
    _, _, counts, _ = M.unpack_carry(carry, cfg.n_outputs)
    assert (np.asarray(counts) == np.asarray(full)).all()


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(3)
    states = jnp.asarray(rng.integers(1, 2**32, (4, 20), dtype=np.uint64).astype(np.uint32))
    acc = jnp.asarray(rng.integers(-1000, 1000, (4, 10)).astype(np.int32))
    counts = jnp.asarray(rng.integers(0, 20, (4, 10)).astype(np.int32))
    enabled = jnp.asarray(rng.integers(0, 2, (4, 10)).astype(np.int32))
    s2, a2, c2, e2 = M.unpack_carry(M.pack_carry(states, acc, counts, enabled), 10)
    for x, y in [(s2, states), (a2, acc), (c2, counts), (e2, enabled)]:
        assert (np.asarray(x) == np.asarray(y)).all()


def test_batch_rows_independent():
    """Each batch row's result must not depend on its neighbours."""
    images, seeds, w = setup_case(b=8)
    cfg = M.ModelConfig(timesteps=4, v_th=250)
    full = np.asarray(M.snn_forward(images, seeds, w, cfg))
    for i in [0, 3, 7]:
        solo = np.asarray(M.snn_forward(images[i:i + 1], seeds[i:i + 1], w, cfg))
        assert (solo[0] == full[i]).all()


def test_counts_bounded_by_prune_and_window():
    images, seeds, w = setup_case()
    for prune, bound in [(1, 1), (3, 3), (0, 6)]:
        cfg = M.ModelConfig(timesteps=6, v_th=64, prune_after=prune)
        counts = np.asarray(M.snn_forward(images, seeds, w, cfg))
        assert counts.max() <= bound


def test_ann_forward_shapes_and_range():
    images, _, _ = setup_case(b=4)
    params = M.ann_init(jax.random.PRNGKey(0))
    logits = M.ann_forward(images.astype(jnp.float32) / 256.0, *params)
    assert logits.shape == (4, 10)
    assert np.isfinite(np.asarray(logits)).all()


def test_surrogate_forward_counts():
    images, _, _ = setup_case(b=4)
    cfg = M.ModelConfig()
    w = jnp.zeros((784, 10), jnp.float32)
    counts = M.surrogate_forward(images.astype(jnp.float32) / 256.0, w,
                                 jax.random.PRNGKey(1), cfg, timesteps=5)
    assert counts.shape == (4, 10)
    assert (np.asarray(counts) == 0).all()  # zero weights never cross v_th


def test_surrogate_gradient_nonzero():
    images, _, _ = setup_case(b=4)
    cfg = M.ModelConfig(v_th=16)
    labels = jnp.asarray([0, 1, 2, 3], jnp.int32)
    w = jnp.ones((784, 10), jnp.float32) * 0.05
    g = jax.grad(M.surrogate_loss)(w, images.astype(jnp.float32) / 256.0,
                                   labels, jax.random.PRNGKey(0), cfg,
                                   timesteps=6)
    assert float(jnp.abs(g).sum()) > 0.0
