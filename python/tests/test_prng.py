"""Cross-language PRNG contract tests.

The golden values here are identical to those asserted in
rust/src/prng/mod.rs — together they pin the bit-exact contract.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.prng import (GOLDEN_GAMMA, M32, Xorshift32, derive_state,
                          pixel_seed, pixel_seeds_np, splitmix32,
                          splitmix32_np, xorshift32_step, xorshift32_step_np)


def test_xorshift32_golden():
    r = Xorshift32.from_raw_state(1)
    got = [r.next_u32() for _ in range(6)]
    assert got == [270369, 67634689, 2647435461, 307599695, 2398689233, 745495504]


def test_xorshift32_golden_large_seed():
    r = Xorshift32.from_raw_state(0xDEADBEEF)
    got = [r.next_u32() for _ in range(4)]
    assert got == [1199382711, 2384302402, 3129746520, 4276113467]


def test_splitmix32_golden():
    assert splitmix32(0) == 2462723854
    assert splitmix32(1) == 2527132011
    assert splitmix32(0xDEADBEEF) == 3553530007
    assert splitmix32(0xFFFFFFFF) == 920564995


def test_pixel_seed_never_zero():
    for seed in [0, 1, 42, 0xFFFFFFFF]:
        for i in range(2048):
            assert pixel_seed(seed, i) != 0


@given(st.integers(0, M32), st.integers(0, 10_000))
@settings(max_examples=200, deadline=None)
def test_vectorized_pixel_seeds_match_scalar(seed, n_probe):
    n = (n_probe % 64) + 1
    vec = pixel_seeds_np(seed, n)
    for i in range(n):
        assert int(vec[i]) == pixel_seed(seed, i)


@given(st.integers(1, M32))
@settings(max_examples=300, deadline=None)
def test_vectorized_xorshift_matches_scalar(state):
    vec = xorshift32_step_np(np.array([state], np.uint32))
    assert int(vec[0]) == xorshift32_step(state)


@given(st.integers(0, M32))
@settings(max_examples=300, deadline=None)
def test_vectorized_splitmix_matches_scalar(x):
    vec = splitmix32_np(np.array([x], np.uint32))
    assert int(vec[0]) == splitmix32(x)


@given(st.integers(0, M32), st.integers(1, 1000))
@settings(max_examples=100, deadline=None)
def test_below_in_range(seed, bound):
    r = Xorshift32(seed)
    for _ in range(20):
        assert 0 <= r.below(bound) < bound


def test_derive_state_domain_separation():
    # Different (a, b) pairs must give different streams.
    states = {derive_state(7, a, b) for a in range(10) for b in range(50)}
    assert len(states) == 500


def test_low_byte_uniformity():
    r = Xorshift32(2024)
    counts = np.zeros(256, np.int64)
    n = 1 << 16
    for _ in range(n):
        counts[r.next_u32() & 0xFF] += 1
    expect = n / 256
    chi2 = float(((counts - expect) ** 2 / expect).sum())
    assert chi2 < 400.0, f"chi2 {chi2}"
