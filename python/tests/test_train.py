"""Training smoke tests (small budgets; the real run happens in aot.py)."""

import numpy as np
import pytest

from compile import model as M
from compile import train as T
from compile.dataset import build_dataset


@pytest.fixture(scope="module")
def tiny_data():
    return build_dataset(11, 12)  # 120 samples


def test_rate_proxy_learns(tiny_data):
    images, labels = tiny_data
    logs = []
    w = T.train_rate_proxy(images, labels, steps=120, log=logs.append)
    import jax.numpy as jnp
    x = jnp.asarray(images, jnp.float32) / 256.0
    acc = float((jnp.argmax(M.rate_proxy_logits(x, jnp.asarray(w)), 1)
                 == jnp.asarray(labels)).mean())
    assert acc > 0.9, f"rate proxy failed to fit tiny set: {acc}"


def test_centre_and_quantize_properties(tiny_data):
    images, labels = tiny_data
    w = T.train_rate_proxy(images, labels, steps=60, log=lambda *_: None)
    q = T.centre_and_quantize(w, bits=9, images=images, labels=labels)
    assert q.dtype == np.int32
    assert q.min() >= -256 and q.max() <= 255
    # Centring: rows sum ~0 before scaling; quantized rows stay near 0.
    assert abs(q.sum(axis=1)).mean() <= 5


def test_calibrate_returns_candidate(tiny_data):
    images, labels = tiny_data
    w = T.train_rate_proxy(images, labels, steps=60, log=lambda *_: None)
    q = T.centre_and_quantize(w, bits=9, images=images, labels=labels)
    cfg = M.ModelConfig()
    vth, prune, scores = T.calibrate(
        q, images[:50], labels[:50], cfg, vth_candidates=(128, 320),
        prune_candidates=(1, 5), log=lambda *_: None)
    assert vth in (128, 320)
    assert prune in (1, 5)
    assert len(scores) == 4


def test_ann_learns(tiny_data):
    images, labels = tiny_data
    params = T.train_ann(images, labels, steps=150, log=lambda *_: None)
    acc = T.evaluate_ann(params, images, labels)
    assert acc > 0.9


def test_surrogate_runs(tiny_data):
    images, labels = tiny_data
    cfg = M.ModelConfig(v_th=64)
    w = T.train_surrogate(images[:64], labels[:64], cfg, epochs=2, batch=32,
                          timesteps=4, log=lambda *_: None)
    assert w.shape == (784, 10)
    assert np.isfinite(w).all()
