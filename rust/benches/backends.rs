//! Backend latency/throughput comparison: behavioral golden model vs the
//! AOT-compiled JAX/Pallas stack (PJRT) across batch sizes, plus the
//! encoder and the baseline ANN. Skips the XLA rows when artifacts are
//! absent.

use snn_rtl::bench::{black_box, csv_header, Bench, BenchResult};
use snn_rtl::data::{codec, DigitGen, Image};
use snn_rtl::runtime::{Manifest, XlaSnn};
use snn_rtl::snn::{BehavioralNet, PoissonEncoder};

fn main() {
    let bench = Bench::default();
    let mut results: Vec<BenchResult> = Vec::new();
    let gen = DigitGen::new(2);
    let images: Vec<Image> = (0..32).map(|i| gen.sample((i % 10) as u8, i / 10)).collect();

    // Encoder alone (the per-timestep hot loop's front half).
    {
        let mut enc = PoissonEncoder::new(&images[0], 7);
        let mut out = vec![false; 784];
        let r = bench.run("encoder_step_784px", || {
            enc.step_into(black_box(&mut out));
        });
        println!("{}  |  {:.1}M pixel-steps/s", r.report(), r.throughput(784.0) / 1e6);
        results.push(r);
    }

    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("artifacts not built; skipping model benches");
        write_csv("backends", &results);
        return;
    };
    let weights = codec::load_weights(manifest.path("weights.bin")).unwrap();
    let cfg = manifest.snn_config().unwrap();

    // Behavioral model, single image, T=10 and T=20.
    for t in [10u32, 20] {
        let net = BehavioralNet::new(cfg.clone().with_timesteps(t), weights.weights.clone())
            .unwrap();
        let mut seed = 0u32;
        let r = bench.run(&format!("behavioral_classify_t{t}"), || {
            seed = seed.wrapping_add(1);
            black_box(net.classify(&images[(seed % 32) as usize], seed));
        });
        println!("{}  |  {:.0} images/s", r.report(), r.throughput(1.0));
        results.push(r);
    }

    // XLA stack at each compiled batch size.
    match XlaSnn::load("artifacts") {
        Ok(snn) => {
            for &b in &snn.batch_sizes() {
                let refs: Vec<&Image> = images.iter().take(b).collect();
                let seeds: Vec<u32> = (0..b as u32).map(|i| i + 1).collect();
                let r = bench.run(&format!("xla_forward_b{b}_t{}", cfg.timesteps), || {
                    black_box(snn.spike_counts(&refs, &seeds).unwrap());
                });
                println!("{}  |  {:.0} images/s", r.report(), r.throughput(b as f64));
                results.push(r);
            }
            // Chunked path (one chunk).
            let b = snn.chunk_batch();
            let refs: Vec<&Image> = images.iter().take(b).collect();
            let seeds: Vec<u32> = (0..b as u32).map(|i| i + 1).collect();
            let r = bench.run(&format!("xla_chunk_b{b}_k{}", snn.chunk_steps()), || {
                let mut st = snn.chunk_start(&refs, &seeds).unwrap();
                black_box(snn.chunk_advance(&mut st).unwrap());
            });
            println!("{}", r.report());
            results.push(r);
            // Baseline ANN.
            let refs: Vec<&Image> = images.iter().take(32).collect();
            let r = bench.run("xla_ann_b32", || {
                black_box(snn.ann_logits(&refs).unwrap());
            });
            println!("{}  |  {:.0} images/s", r.report(), r.throughput(32.0));
            results.push(r);
        }
        Err(e) => eprintln!("XLA backend unavailable: {e}"),
    }

    write_csv("backends", &results);
}

fn write_csv(name: &str, results: &[BenchResult]) {
    std::fs::create_dir_all("results").ok();
    let mut body = String::from(csv_header());
    body.push('\n');
    for r in results {
        body.push_str(&r.csv_row());
        body.push('\n');
    }
    let path = format!("results/bench_{name}.csv");
    std::fs::write(&path, body).ok();
    println!("-> {path}");
}
