//! End-to-end coordinator benchmark: request throughput and latency under
//! closed-loop load across worker counts, batch policies, intra-batch
//! fan-out and early-exit settings — the L3 perf target of DESIGN.md §10,
//! now with the p99 column the sharded work-stealing ingress is
//! accountable to.

use std::sync::Arc;
use std::time::{Duration, Instant};

use snn_rtl::coordinator::{
    BatchPolicy, BehavioralBackend, Coordinator, CoordinatorConfig, FanoutPolicy, Request,
    SupervisionPolicy,
};
use snn_rtl::data::{codec, DigitGen, Image};
use snn_rtl::runtime::Manifest;
use snn_rtl::snn::EarlyExit;

struct Row {
    name: String,
    qps: f64,
    p50_us: u64,
    p95_us: u64,
    p99_us: u64,
    mean_batch: f64,
    steps_per_req: f64,
    steals: u64,
}

fn drive(name: &str, coord: &Coordinator, images: &[Image], requests: usize) -> Row {
    let handle = coord.handle();
    let t0 = Instant::now();
    let mut receivers = Vec::with_capacity(requests);
    for i in 0..requests {
        let img = images[i % images.len()].clone();
        loop {
            match handle.submit(Request::new(img.clone()).with_seed(i as u32 + 1)) {
                Ok(rx) => {
                    receivers.push(rx);
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_micros(100)),
            }
        }
    }
    for rx in receivers {
        rx.recv().unwrap().unwrap();
    }
    let wall = t0.elapsed();
    let snap = coord.metrics().snapshot();
    Row {
        name: name.to_string(),
        qps: requests as f64 / wall.as_secs_f64(),
        p50_us: snap.latency_p50_us,
        p95_us: snap.latency_p95_us,
        p99_us: snap.latency_p99_us,
        mean_batch: snap.mean_batch_size,
        steps_per_req: snap.steps_executed as f64 / requests as f64,
        steals: snap.steals,
    }
}

fn print_row(r: &Row) {
    println!(
        "{:<30} {:>9.0} req/s  p50 {:>6} µs  p95 {:>6} µs  p99 {:>6} µs  batch {:>5.2}  \
         steps/req {:>5.1}  steals {:>4}",
        r.name, r.qps, r.p50_us, r.p95_us, r.p99_us, r.mean_batch, r.steps_per_req, r.steals
    );
}

fn main() {
    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("artifacts not built; skipping coordinator bench");
        return;
    };
    let weights = codec::load_weights(manifest.path("weights.bin")).unwrap();
    let cfg = manifest.snn_config().unwrap().with_timesteps(10);
    let gen = DigitGen::new(2);
    let images: Vec<Image> = (0..64).map(|i| gen.sample((i % 10) as u8, i / 10)).collect();
    let requests = 4000usize;
    let mut rows = Vec::new();

    // Worker scaling over the sharded work-stealing ingress.
    for workers in [1usize, 2, 4, 8] {
        for max_batch in [1usize, 8] {
            let backend = Arc::new(
                BehavioralBackend::new(cfg.clone(), weights.weights.clone()).unwrap(),
            );
            let coord = Coordinator::start(
                backend,
                CoordinatorConfig {
                    workers,
                    queue_depth: 2048,
                    batch: BatchPolicy { max_batch, max_delay: Duration::from_micros(500) },
                    early: EarlyExit::Off,
                    fanout: FanoutPolicy::default(),
                    supervision: SupervisionPolicy::default(),
                },
            );
            let name = format!("behavioral_w{workers}_b{max_batch}");
            let row = drive(&name, &coord, &images, requests);
            coord.shutdown();
            print_row(&row);
            rows.push(row);
        }
    }

    // Intra-batch fan-out on large batches: same load, fan-out off vs on.
    for (tag, fanout) in [
        ("fanout_off", FanoutPolicy::off()),
        ("fanout_on", FanoutPolicy { min_batch: 32, max_parts: 4 }),
    ] {
        let backend =
            Arc::new(BehavioralBackend::new(cfg.clone(), weights.weights.clone()).unwrap());
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 4,
                queue_depth: 2048,
                batch: BatchPolicy { max_batch: 64, max_delay: Duration::from_micros(500) },
                early: EarlyExit::Off,
                fanout,
                supervision: SupervisionPolicy::default(),
            },
        );
        let name = format!("behavioral_w4_b64_{tag}");
        let row = drive(&name, &coord, &images, requests);
        coord.shutdown();
        print_row(&row);
        rows.push(row);
    }

    // Early exit on the behavioral backend.
    {
        let backend =
            Arc::new(BehavioralBackend::new(cfg.clone(), weights.weights.clone()).unwrap());
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 2,
                queue_depth: 2048,
                batch: BatchPolicy { max_batch: 8, max_delay: Duration::from_micros(500) },
                early: EarlyExit::Margin { margin: 2, min_steps: 3 },
                fanout: FanoutPolicy::default(),
                supervision: SupervisionPolicy::default(),
            },
        );
        let row = drive("behavioral_early_exit", &coord, &images, requests);
        coord.shutdown();
        print_row(&row);
        rows.push(row);
    }

    std::fs::create_dir_all("results").ok();
    let mut body =
        String::from("name,qps,p50_us,p95_us,p99_us,mean_batch,steps_per_req,steals\n");
    for r in &rows {
        body.push_str(&format!(
            "{},{:.0},{},{},{},{:.2},{:.2},{}\n",
            r.name, r.qps, r.p50_us, r.p95_us, r.p99_us, r.mean_batch, r.steps_per_req, r.steals
        ));
    }
    std::fs::write("results/bench_coordinator.csv", body).ok();
    println!("-> results/bench_coordinator.csv");
}
