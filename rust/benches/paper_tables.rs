//! "One bench per paper table/figure": regenerates every table and figure
//! of the paper's evaluation through the experiment harnesses and times
//! each regeneration. `cargo bench` therefore reproduces the entire
//! evaluation section in one command (rows go to stdout + results/*.csv).

use std::time::Instant;

use snn_rtl::experiments::{self, Ctx};

fn main() {
    let ctx = match Ctx::load("artifacts", "results") {
        Ok(mut ctx) => {
            // Bench profile: a balanced 1000-sample slice keeps the full
            // suite under a couple of minutes; `snn-rtl experiment all`
            // runs the full test set.
            ctx.samples = Some(1000);
            ctx
        }
        Err(e) => {
            eprintln!("artifacts not built ({e}); skipping paper-table bench");
            return;
        }
    };

    let suite = [
        "table1",
        "fig4",
        "fig5",
        "fig6",
        "fig7",
        "table2",
        "fig8",
        "ablation-pruning",
        "ablation-decay",
        "ablation-modes",
        "ablation-width",
    ];
    let mut timings = Vec::new();
    for id in suite {
        println!("\n================ {id} ================");
        let t0 = Instant::now();
        experiments::run(id, &ctx).unwrap_or_else(|e| panic!("experiment {id} failed: {e}"));
        let dt = t0.elapsed();
        timings.push((id, dt));
    }
    println!("\n=== regeneration timings ===");
    for (id, dt) in &timings {
        println!("{id:<20} {dt:?}");
    }
}
