//! Benchmarks of the cycle-accurate RTL simulator: simulated cycles per
//! wall-second (the simulator's own throughput), per-window latency, and
//! the cost split across FSM phases.

use snn_rtl::bench::{black_box, csv_header, Bench, BenchResult};
use snn_rtl::data::DigitGen;
use snn_rtl::fixed::WeightMatrix;
use snn_rtl::prng::Xorshift32;
use snn_rtl::rtl::RtlCore;
use snn_rtl::SnnConfig;

fn weights(seed: u32) -> WeightMatrix {
    let mut rng = Xorshift32::new(seed);
    WeightMatrix::from_rows(784, 10, 9, (0..7840).map(|_| rng.range_i32(-30, 60)).collect())
        .unwrap()
}

fn main() {
    let bench = Bench::default();
    let gen = DigitGen::new(1);
    let img = gen.sample(3, 0);
    let mut results: Vec<BenchResult> = Vec::new();

    // Full-window inference at the paper's configuration: the cycle-stepped
    // engine vs the batched-timestep fast path (bit-exact by property test;
    // the headline perf target of EXPERIMENTS.md §Perf).
    for t in [1u32, 10, 20] {
        let cfg = SnnConfig::paper().with_timesteps(t);
        let mut core = RtlCore::new(cfg, weights(7)).unwrap();
        let mut seed = 1u32;
        let r = bench.run(&format!("rtl_window_t{t}"), || {
            seed = seed.wrapping_add(1);
            black_box(core.run(&img, seed).unwrap());
        });
        let cycles_per_window = 786.0 * f64::from(t);
        println!(
            "{}  |  {:.1}M simulated cycles/s",
            r.report(),
            r.throughput(cycles_per_window) / 1e6
        );
        let cycle_mean_ns = r.mean_ns;
        results.push(r);

        let mut seed = 1u32;
        let r = bench.run(&format!("rtl_fast_window_t{t}"), || {
            seed = seed.wrapping_add(1);
            black_box(core.run_fast(&img, seed).unwrap());
        });
        println!(
            "{}  |  {:.1}M simulated cycles/s  ({:.1}x vs cycle path)",
            r.report(),
            r.throughput(cycles_per_window) / 1e6,
            cycle_mean_ns / r.mean_ns
        );
        results.push(r);
    }

    // Sparse vs dense input (event-driven gating at work).
    for (name, intensity) in [("black", 0u8), ("mid", 128), ("bright", 255)] {
        let cfg = SnnConfig::paper().with_timesteps(10);
        let mut core = RtlCore::new(cfg, weights(7)).unwrap();
        let flat = snn_rtl::data::Image { label: 0, pixels: vec![intensity; 784] };
        let mut seed = 1u32;
        let r = bench.run(&format!("rtl_input_{name}"), || {
            seed = seed.wrapping_add(1);
            black_box(core.run(&flat, seed).unwrap());
        });
        println!("{}", r.report());
        results.push(r);
    }

    // Immediate fire mode (extra comparator work per integrate cycle).
    {
        let cfg = SnnConfig::paper()
            .with_timesteps(10)
            .with_fire_mode(snn_rtl::config::FireMode::Immediate);
        let mut core = RtlCore::new(cfg, weights(7)).unwrap();
        let mut seed = 1u32;
        let r = bench.run("rtl_immediate_mode_t10", || {
            seed = seed.wrapping_add(1);
            black_box(core.run(&img, seed).unwrap());
        });
        println!("{}", r.report());
        results.push(r);

        let mut seed = 1u32;
        let r = bench.run("rtl_fast_immediate_mode_t10", || {
            seed = seed.wrapping_add(1);
            black_box(core.run_fast(&img, seed).unwrap());
        });
        println!("{}", r.report());
        results.push(r);
    }

    // Fast path under sparse vs dense input (the active-pixel list pays
    // off most when few comparators fire).
    for (name, intensity) in [("black", 0u8), ("mid", 128), ("bright", 255)] {
        let cfg = SnnConfig::paper().with_timesteps(10);
        let mut core = RtlCore::new(cfg, weights(7)).unwrap();
        let flat = snn_rtl::data::Image { label: 0, pixels: vec![intensity; 784] };
        let mut seed = 1u32;
        let r = bench.run(&format!("rtl_fast_input_{name}"), || {
            seed = seed.wrapping_add(1);
            black_box(core.run_fast(&flat, seed).unwrap());
        });
        println!("{}", r.report());
        results.push(r);
    }

    write_csv("rtl_core", &results);
}

fn write_csv(name: &str, results: &[BenchResult]) {
    std::fs::create_dir_all("results").ok();
    let mut body = String::from(csv_header());
    body.push('\n');
    for r in results {
        body.push_str(&r.csv_row());
        body.push('\n');
    }
    let path = format!("results/bench_{name}.csv");
    std::fs::write(&path, body).ok();
    println!("-> {path}");
}
