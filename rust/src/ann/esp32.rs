//! ESP32 deployment cost model (the paper's §V-C latency substitution).
//!
//! The paper measured its baseline ANN on an ESP32 and reports ~3 s
//! without DSP optimization and 5130 µs with it. We reproduce those rows
//! with a documented cycles-per-operation model of the 240 MHz Xtensa LX6:
//!
//! * **Software floats** (no FPU use, `-mno-fp`, double-promotion traps —
//!   the pathological path the paper's 3 s implies): an f32 MAC through
//!   the soft-float library costs on the order of ~10⁴ cycles once the
//!   surrounding interpreter/framework overhead (TFLM reference kernels,
//!   im2col copies, quant/dequant) is charged per op, which is how a
//!   ~25 k-MAC network lands at seconds.
//! * **DSP/FPU path** (ESP-NN / esp-dsp optimized kernels): ~48 cycles per
//!   MAC effective, including loads — giving 25,408 MACs ≈ 5.1 ms at
//!   240 MHz, the paper's 5130 µs row.
//!
//! Both constants are *calibrated to the paper's own measurements* (the
//! paper reports latencies, not mechanisms); the model's value is that the
//! same op-count input reproduces both rows and scales to other
//! topologies, making the Table II comparison auditable.

use super::AnnOpCounts;

/// Cost model for ANN inference on an ESP32-class MCU.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Esp32Model {
    /// Core clock in Hz (ESP32: 240 MHz).
    pub f_clk_hz: f64,
    /// Effective cycles per f32 MAC on the unoptimized path.
    pub cycles_per_mac_soft: f64,
    /// Effective cycles per f32 MAC on the DSP-optimized path.
    pub cycles_per_mac_dsp: f64,
    /// Fixed per-inference overhead cycles (buffer setup, activation
    /// copies), charged on both paths.
    pub overhead_cycles: f64,
    /// Active power draw in milliwatts (datasheet: ~160 mA @ 3.3 V under
    /// full CPU load ≈ 530 mW; we charge the CPU-core share).
    pub active_power_mw: f64,
}

impl Default for Esp32Model {
    fn default() -> Self {
        Esp32Model {
            f_clk_hz: 240.0e6,
            cycles_per_mac_soft: 28_000.0, // calibrated: 25,408 MACs -> ~3.0 s
            cycles_per_mac_dsp: 48.0,      // calibrated: 25,408 MACs -> ~5.1 ms
            overhead_cycles: 10_000.0,
            active_power_mw: 300.0,
        }
    }
}

/// Evaluated deployment estimate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Esp32Report {
    /// Latency without DSP optimization, in microseconds.
    pub latency_soft_us: f64,
    /// Latency with DSP optimization, in microseconds.
    pub latency_dsp_us: f64,
    /// Energy per inference on the DSP path, in microjoules.
    pub energy_dsp_uj: f64,
    /// Energy per inference on the soft path, in microjoules.
    pub energy_soft_uj: f64,
}

impl Esp32Model {
    /// Evaluate the model for a network's op counts.
    pub fn evaluate(&self, ops: &AnnOpCounts) -> Esp32Report {
        let macs = ops.multiplications as f64;
        let soft_s = (macs * self.cycles_per_mac_soft + self.overhead_cycles) / self.f_clk_hz;
        let dsp_s = (macs * self.cycles_per_mac_dsp + self.overhead_cycles) / self.f_clk_hz;
        Esp32Report {
            latency_soft_us: soft_s * 1e6,
            latency_dsp_us: dsp_s * 1e6,
            energy_soft_uj: soft_s * self.active_power_mw * 1e3,
            energy_dsp_uj: dsp_s * self.active_power_mw * 1e3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_latency_rows() {
        let ops = AnnOpCounts::for_topology(784, 32, 10);
        let r = Esp32Model::default().evaluate(&ops);
        // Paper: "nearly 3 seconds" without DSP.
        assert!((r.latency_soft_us / 1e6 - 3.0).abs() < 0.05, "{}", r.latency_soft_us);
        // Paper: "5130 µs" with DSP.
        assert!((r.latency_dsp_us - 5130.0).abs() / 5130.0 < 0.05, "{}", r.latency_dsp_us);
    }

    #[test]
    fn latency_scales_with_topology() {
        let m = Esp32Model::default();
        let small = m.evaluate(&AnnOpCounts::for_topology(784, 16, 10));
        let big = m.evaluate(&AnnOpCounts::for_topology(784, 64, 10));
        assert!(big.latency_dsp_us > small.latency_dsp_us * 3.0);
    }

    #[test]
    fn energy_consistent_with_latency() {
        let ops = AnnOpCounts::for_topology(784, 32, 10);
        let r = Esp32Model::default().evaluate(&ops);
        // E = P·t: 300 mW × 5.13 ms ≈ 1.54 mJ.
        assert!((r.energy_dsp_uj - r.latency_dsp_us * 0.3).abs() < 1.0);
    }
}
