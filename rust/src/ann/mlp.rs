//! Pure-Rust f32 MLP forward (the comparator the paper deployed on the
//! ESP32) + exact op accounting.

use std::path::Path;

use crate::config::LayerParams;
use crate::data::Image;
use crate::error::{Error, Result};
use crate::fixed::{quantize, WeightMatrix, WeightStack};

/// Exact operation counts for one dense-MLP inference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AnnOpCounts {
    /// f32 multiplications (the MAC multiplies).
    pub multiplications: u64,
    /// f32 additions (MAC accumulates + bias adds).
    pub additions: u64,
    /// Weight + bias storage in bytes at f32.
    pub model_bytes: u64,
}

impl AnnOpCounts {
    /// Counts for a `n_in → n_hidden → n_out` dense MLP.
    pub fn for_topology(n_in: u64, n_hidden: u64, n_out: u64) -> Self {
        let macs = n_in * n_hidden + n_hidden * n_out;
        AnnOpCounts {
            multiplications: macs,
            additions: macs + n_hidden + n_out, // + bias adds
            model_bytes: 4 * (n_in * n_hidden + n_hidden + n_hidden * n_out + n_out),
        }
    }
}

/// The baseline MLP with trained weights (loaded from `ann_weights.bin`,
/// SNNA format written by the python build path).
#[derive(Debug, Clone)]
pub struct Mlp {
    pub n_in: usize,
    pub n_hidden: usize,
    pub n_out: usize,
    /// Row-major `[n_in][n_hidden]`.
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    /// Row-major `[n_hidden][n_out]`.
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

impl Mlp {
    /// Load from an SNNA artifact.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let buf = std::fs::read(path).map_err(|e| Error::io(path, e))?;
        if buf.len() < 20 || &buf[..4] != b"SNNA" {
            return Err(Error::malformed(path, "bad magic (want SNNA)"));
        }
        let rd = |at: usize| u32::from_le_bytes(buf[at..at + 4].try_into().unwrap()) as usize;
        if rd(4) != 1 {
            return Err(Error::malformed(path, "unsupported version"));
        }
        let (n_in, n_hidden, n_out) = (rd(8), rd(12), rd(16));
        let need = 20 + 4 * (n_in * n_hidden + n_hidden + n_hidden * n_out + n_out);
        if buf.len() != need {
            return Err(Error::malformed(path, format!("size {} != {need}", buf.len())));
        }
        let mut pos = 20usize;
        let mut take = |count: usize| -> Vec<f32> {
            let v = buf[pos..pos + count * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            pos += count * 4;
            v
        };
        Ok(Mlp {
            w1: take(n_in * n_hidden),
            b1: take(n_hidden),
            w2: take(n_hidden * n_out),
            b2: take(n_out),
            n_in,
            n_hidden,
            n_out,
        })
    }

    /// Synthetic weights for tests.
    pub fn zeros(n_in: usize, n_hidden: usize, n_out: usize) -> Self {
        Mlp {
            w1: vec![0.0; n_in * n_hidden],
            b1: vec![0.0; n_hidden],
            w2: vec![0.0; n_hidden * n_out],
            b2: vec![0.0; n_out],
            n_in,
            n_hidden,
            n_out,
        }
    }

    /// Forward one image (intensities scaled by 1/256 as in training).
    pub fn logits(&self, img: &Image) -> Vec<f32> {
        assert_eq!(img.pixels.len(), self.n_in);
        let mut hidden = self.b1.clone();
        for (i, &px) in img.pixels.iter().enumerate() {
            if px == 0 {
                continue; // exact zero contributes nothing
            }
            let x = f32::from(px) / 256.0;
            let row = &self.w1[i * self.n_hidden..(i + 1) * self.n_hidden];
            for (h, &w) in hidden.iter_mut().zip(row) {
                *h += x * w;
            }
        }
        for h in &mut hidden {
            *h = h.max(0.0); // relu
        }
        let mut out = self.b2.clone();
        for (j, &h) in hidden.iter().enumerate() {
            if h == 0.0 {
                continue;
            }
            let row = &self.w2[j * self.n_out..(j + 1) * self.n_out];
            for (o, &w) in out.iter_mut().zip(row) {
                *o += h * w;
            }
        }
        out
    }

    /// Classify one image.
    pub fn classify(&self, img: &Image) -> u8 {
        let logits = self.logits(img);
        let mut best = 0usize;
        for (i, &l) in logits.iter().enumerate() {
            if l > logits[best] {
                best = i;
            }
        }
        best as u8
    }

    /// Op counts for this topology.
    pub fn op_counts(&self) -> AnnOpCounts {
        AnnOpCounts::for_topology(self.n_in as u64, self.n_hidden as u64, self.n_out as u64)
    }

    /// Quantize the trained MLP into a spiking [`WeightStack`]
    /// (`[n_in, n_hidden, n_out]`): each dense layer maps to `bits`-wide
    /// fixed point under a shared per-layer scale that places the largest
    /// |w| at full range, so relative weight magnitudes — which determine
    /// spiking winner order — survive quantization. Biases are dropped:
    /// the SNN core has no bias path; threshold calibration absorbs them
    /// (same substitution the paper's training pipeline makes).
    pub fn to_weight_stack(&self, bits: u32) -> Result<WeightStack> {
        Ok(self.to_weight_stack_scaled(bits)?.0)
    }

    /// Like [`Mlp::to_weight_stack`], additionally returning each layer's
    /// float→integer scale (`full_range / max|w|`; 1.0 for an all-zero
    /// layer) — the input to per-layer threshold calibration.
    pub fn to_weight_stack_scaled(&self, bits: u32) -> Result<(WeightStack, Vec<f32>)> {
        let quantize_layer =
            |w: &[f32], n_in: usize, n_out: usize| -> Result<(WeightMatrix, f32)> {
                let max_abs = w.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = if max_abs > 0.0 {
                    ((1i32 << (bits - 1)) - 1) as f32 / max_abs
                } else {
                    1.0
                };
                let m = WeightMatrix::from_rows(
                    n_in,
                    n_out,
                    bits,
                    w.iter().map(|&v| quantize(v, scale, bits)).collect(),
                )?;
                Ok((m, scale))
            };
        let (m1, s1) = quantize_layer(&self.w1, self.n_in, self.n_hidden)?;
        let (m2, s2) = quantize_layer(&self.w2, self.n_hidden, self.n_out)?;
        Ok((WeightStack::from_layers(vec![m1, m2])?, vec![s1, s2]))
    }

    /// Quantize *and calibrate*: because each layer independently maps its
    /// largest |w| to full range, a single integer threshold means a
    /// *different* effective float threshold per layer — the deep-accuracy
    /// limiter the ROADMAP calls out. This exporter fixes the float-domain
    /// threshold instead: `base_v_th` is taken as layer 0's calibration
    /// (i.e. the float threshold `θ = base_v_th / scale_0`) and every
    /// layer `l` gets `v_th_l = round(θ · scale_l)`, so all layers fire at
    /// the same point of their float-domain activation. Returns the stack
    /// plus one threshold-only [`LayerParams`] override per layer (layer 0
    /// keeps `base_v_th` exactly).
    pub fn calibrated_layer_params(
        &self,
        bits: u32,
        base_v_th: i32,
    ) -> Result<(WeightStack, Vec<LayerParams>)> {
        let (stack, scales) = self.to_weight_stack_scaled(bits)?;
        let s0 = scales[0].max(f32::EPSILON);
        let params = scales
            .iter()
            .map(|&s| {
                let v = (base_v_th as f32 * s / s0).round().max(1.0) as i32;
                LayerParams::with_v_th(v)
            })
            .collect();
        Ok((stack, params))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::IMG_PIXELS;

    #[test]
    fn zero_mlp_outputs_bias() {
        let mut m = Mlp::zeros(IMG_PIXELS, 32, 10);
        m.b2 = (0..10).map(|i| i as f32).collect();
        let img = Image { label: 0, pixels: vec![100; IMG_PIXELS] };
        assert_eq!(m.logits(&img), m.b2);
        assert_eq!(m.classify(&img), 9);
    }

    #[test]
    fn hand_computed_forward() {
        // 784-1-2 with only two active pixels: h = relu(x0·w + x1·w' + b1),
        // logits = [3h, -3h + 1].
        let mut m = Mlp::zeros(IMG_PIXELS, 1, 2);
        m.w1[0] = 1.0; // pixel 0 -> hidden 0
        m.w1[1] = 2.0; // pixel 1 -> hidden 0
        m.b1 = vec![0.5];
        m.w2 = vec![3.0, -3.0];
        m.b2 = vec![0.0, 1.0];
        let mut pixels = vec![0u8; IMG_PIXELS];
        pixels[0] = 128;
        pixels[1] = 64;
        let img = Image { label: 0, pixels };
        let logits = m.logits(&img);
        let h = 128.0f32 / 256.0 * 1.0 + 64.0 / 256.0 * 2.0 + 0.5; // = 1.5
        assert!((logits[0] - h * 3.0).abs() < 1e-6, "{logits:?}");
        assert!((logits[1] - (h * -3.0 + 1.0)).abs() < 1e-6, "{logits:?}");
        assert_eq!(m.classify(&img), 0);
    }

    #[test]
    fn relu_gates_hidden() {
        let mut m = Mlp::zeros(IMG_PIXELS, 2, 2);
        // hidden0 gets a negative preactivation, hidden1 positive.
        for i in 0..IMG_PIXELS {
            m.w1[i * 2] = -1.0;
            m.w1[i * 2 + 1] = 1.0;
        }
        m.w2 = vec![10.0, 0.0, 0.0, 10.0];
        let img = Image { label: 0, pixels: vec![128; IMG_PIXELS] };
        let logits = m.logits(&img);
        assert_eq!(logits[0], 0.0, "relu must zero the negative hidden unit");
        assert!(logits[1] > 0.0);
    }

    #[test]
    fn quantizing_exporter_builds_matching_stack() {
        let mut m = Mlp::zeros(IMG_PIXELS, 4, 3);
        // Distinct magnitudes per layer so the per-layer scale differs.
        m.w1[0] = 2.0;
        m.w1[1] = -1.0;
        m.w1[5] = 0.5;
        m.w2 = vec![0.25, -0.125, 0.0, 0.25, 0.0, 0.125, 0.0, 0.0, 0.25, -0.25, 0.125, 0.0];
        let stack = m.to_weight_stack(9).unwrap();
        assert_eq!(stack.topology(), vec![IMG_PIXELS, 4, 3]);
        assert_eq!(stack.bits(), 9);
        // The largest |w| of each layer maps to the full positive range.
        assert_eq!(stack.layer(0).get(0, 0), 255);
        assert_eq!(stack.layer(0).get(0, 1), -128, "half-magnitude negative weight");
        assert_eq!(stack.layer(1).get(0, 0), 255);
        // Sign and relative order survive.
        assert!(stack.layer(1).get(0, 1) < 0);
        assert!(stack.layer(1).get(1, 2).abs() < stack.layer(1).get(0, 0));
    }

    #[test]
    fn calibrated_exporter_scales_thresholds_per_layer() {
        let mut m = Mlp::zeros(IMG_PIXELS, 4, 3);
        // Layer 1 max |w| = 2.0 → scale 255/2 = 127.5; layer 2 max |w| =
        // 0.25 → scale 255/0.25 = 1020 (4x layer 1's scale).
        m.w1[0] = 2.0;
        m.w2[0] = 0.25;
        let (stack, params) = m.calibrated_layer_params(9, 128).unwrap();
        assert_eq!(stack.topology(), vec![IMG_PIXELS, 4, 3]);
        assert_eq!(params.len(), 2);
        assert_eq!(params[0].v_th, Some(128), "layer 0 keeps the base calibration");
        assert_eq!(
            params[1].v_th,
            Some(1024),
            "layer 1's threshold must scale with its quantization scale (8x here: \
             scale ratio 1020/127.5)"
        );
        assert!(params.iter().all(|p| p.decay_shift.is_none() && p.prune.is_none()));
        // The calibrated params slot straight into a config.
        let cfg = crate::SnnConfig::paper()
            .with_topology(stack.topology())
            .with_v_th(128)
            .with_layer_params(params)
            .validated()
            .unwrap();
        assert_eq!(cfg.layer_v_th(1), 1024);
    }

    #[test]
    fn quantizing_exporter_handles_all_zero_layer() {
        let m = Mlp::zeros(IMG_PIXELS, 2, 2);
        let stack = m.to_weight_stack(9).unwrap();
        assert!(stack.layer(0).as_slice().iter().all(|&w| w == 0));
        assert!(stack.layer(1).as_slice().iter().all(|&w| w == 0));
    }

    #[test]
    fn loader_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("snn_ann_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(Mlp::load(&p).is_err());
        std::fs::write(&p, b"SNNA\x01\x00\x00\x00").unwrap();
        assert!(Mlp::load(&p).is_err());
    }
}
