//! Baseline ANN (paper §V): the 784-32-10 f32 MLP whose op counts and
//! memory footprint the paper's Table II is built from, plus the ESP32
//! deployment cost model that reproduces the latency rows.
//!
//! The identification of the baseline comes from the paper's own numbers:
//! 25,408 multiplies = 784·32 + 32·10 and 99.4 KB = (784·32+32 +
//! 32·10+10)·4 B — exactly a 784-32-10 f32 MLP (DESIGN.md §1).

mod esp32;
mod mlp;

pub use esp32::{Esp32Model, Esp32Report};
pub use mlp::{AnnOpCounts, Mlp};

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Table II arithmetic, reproduced exactly.
    #[test]
    fn table2_op_counts() {
        let counts = AnnOpCounts::for_topology(784, 32, 10);
        assert_eq!(counts.multiplications, 25_408);
        // Paper: "approximately ... 25,450 additions" = MACs + biases.
        assert_eq!(counts.additions, 25_408 + 42);
        // Paper: "approximately 99.4 KB".
        let kb = counts.model_bytes as f64 / 1024.0;
        assert!((kb - 99.4).abs() < 0.1, "model size {kb} KB");
    }
}
