//! Minimal benchmarking harness (criterion is not in the offline crate
//! set). Provides warmup + timed iterations with p50/p95/mean reporting
//! and a derived-throughput helper; used by `benches/*.rs`
//! (`harness = false`) and the perf pass.

use std::time::{Duration, Instant};

/// One benchmark's results.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub min_ns: u64,
    pub max_ns: u64,
}

impl BenchResult {
    /// Events/second given `events` per iteration.
    pub fn throughput(&self, events_per_iter: f64) -> f64 {
        events_per_iter / (self.mean_ns / 1e9)
    }

    /// One-line human report.
    pub fn report(&self) -> String {
        format!(
            "{:<34} {:>10.1} ns/iter  p50 {:>9} ns  p95 {:>9} ns  ({} iters)",
            self.name, self.mean_ns, self.p50_ns, self.p95_ns, self.iters
        )
    }

    /// CSV row (matches [`csv_header`]).
    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{:.1},{},{},{},{}",
            self.name, self.iters, self.mean_ns, self.p50_ns, self.p95_ns, self.min_ns,
            self.max_ns
        )
    }
}

/// Header for [`BenchResult::csv_row`].
pub fn csv_header() -> &'static str {
    "name,iters,mean_ns,p50_ns,p95_ns,min_ns,max_ns"
}

/// Benchmark runner with a time budget per benchmark.
pub struct Bench {
    warmup: Duration,
    budget: Duration,
    max_iters: u32,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            max_iters: 10_000,
        }
    }
}

impl Bench {
    pub fn new(warmup: Duration, budget: Duration, max_iters: u32) -> Self {
        Bench { warmup, budget, max_iters }
    }

    /// Quick profile for CI-ish runs.
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(400),
            max_iters: 2_000,
        }
    }

    /// Run `f` repeatedly, timing each call.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Timed.
        let mut samples_ns: Vec<u64> = Vec::new();
        let b0 = Instant::now();
        while b0.elapsed() < self.budget && samples_ns.len() < self.max_iters as usize {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos().min(u128::from(u64::MAX)) as u64);
        }
        samples_ns.sort_unstable();
        let n = samples_ns.len().max(1);
        let sum: u128 = samples_ns.iter().map(|&s| u128::from(s)).sum();
        BenchResult {
            name: name.to_string(),
            iters: samples_ns.len() as u32,
            mean_ns: sum as f64 / n as f64,
            p50_ns: samples_ns.get(n / 2).copied().unwrap_or(0),
            p95_ns: samples_ns.get(n * 95 / 100).copied().unwrap_or(0),
            min_ns: samples_ns.first().copied().unwrap_or(0),
            max_ns: samples_ns.last().copied().unwrap_or(0),
        }
    }
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_plausible() {
        let b = Bench::new(Duration::from_millis(5), Duration::from_millis(50), 1000);
        let mut acc = 0u64;
        let r = b.run("spin", || {
            for i in 0..1000u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        assert!(r.iters > 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p95_ns);
        assert!(r.min_ns <= r.p50_ns && r.p95_ns <= r.max_ns);
    }

    #[test]
    fn throughput_derivation() {
        let r = BenchResult {
            name: "x".into(),
            iters: 10,
            mean_ns: 1e6, // 1 ms per iter
            p50_ns: 0,
            p95_ns: 0,
            min_ns: 0,
            max_ns: 0,
        };
        assert!((r.throughput(100.0) - 100_000.0).abs() < 1.0);
    }

    #[test]
    fn csv_row_matches_header_arity() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1.0,
            p50_ns: 1,
            p95_ns: 1,
            min_ns: 1,
            max_ns: 1,
        };
        assert_eq!(r.csv_row().split(',').count(), csv_header().split(',').count());
    }
}
