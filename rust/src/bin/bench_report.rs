//! `bench-report` — the perf-trajectory probe behind `tools/run_bench.sh`.
//!
//! Measures, on synthetic weights/digits (no artifacts needed):
//!
//! * images/sec of the RTL **cycle path** (`RtlCore::run`),
//! * images/sec of the RTL **fast path** (`RtlCore::run_fast`),
//! * end-to-end coordinator throughput over the pooled fast-path
//!   `RtlBackend` at 1 / 2 / 4 workers,
//!
//! and writes the results to `BENCH_1.json` (plus stdout). The JSON seeds
//! the repository's performance trajectory: the fast-path speedup and the
//! multi-worker scaling curve are the acceptance numbers of the fast-path
//! engine PR (EXPERIMENTS.md §Perf).

use std::sync::Arc;
use std::time::{Duration, Instant};

use snn_rtl::bench::{black_box, Bench};
use snn_rtl::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, Request, RtlBackend,
};
use snn_rtl::data::{DigitGen, Image};
use snn_rtl::fixed::WeightMatrix;
use snn_rtl::prng::Xorshift32;
use snn_rtl::rtl::RtlCore;
use snn_rtl::snn::EarlyExit;
use snn_rtl::SnnConfig;

fn weights(seed: u32) -> WeightMatrix {
    let mut rng = Xorshift32::new(seed);
    WeightMatrix::from_rows(784, 10, 9, (0..7840).map(|_| rng.range_i32(-30, 60)).collect())
        .unwrap()
}

fn coordinator_qps(cfg: &SnnConfig, workers: usize, requests: usize, images: &[Image]) -> f64 {
    let backend = Arc::new(RtlBackend::new(cfg.clone(), weights(7)).unwrap());
    let coord = Coordinator::start(
        backend,
        CoordinatorConfig {
            workers,
            queue_depth: 2048,
            batch: BatchPolicy { max_batch: 8, max_delay: Duration::from_micros(500) },
            early: EarlyExit::Off,
        },
    );
    let handle = coord.handle();
    let t0 = Instant::now();
    let mut receivers = Vec::with_capacity(requests);
    for i in 0..requests {
        let img = images[i % images.len()].clone();
        loop {
            match handle.submit(Request { image: img.clone(), seed: Some(i as u32 + 1) }) {
                Ok(rx) => {
                    receivers.push(rx);
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_micros(100)),
            }
        }
    }
    for rx in receivers {
        rx.recv().unwrap().unwrap();
    }
    let qps = requests as f64 / t0.elapsed().as_secs_f64();
    coord.shutdown();
    qps
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let cfg = SnnConfig::paper().with_timesteps(10);
    let gen = DigitGen::new(2);
    let img = gen.sample(3, 0);

    // Engine-level throughput.
    let mut core = RtlCore::new(cfg.clone(), weights(7)).unwrap();
    let mut seed = 1u32;
    let cycle = bench.run("rtl_cycle_path_t10", || {
        seed = seed.wrapping_add(1);
        black_box(core.run(&img, seed).unwrap());
    });
    let mut seed = 1u32;
    let fast = bench.run("rtl_fast_path_t10", || {
        seed = seed.wrapping_add(1);
        black_box(core.run_fast(&img, seed).unwrap());
    });
    let cycle_ips = cycle.throughput(1.0);
    let fast_ips = fast.throughput(1.0);
    let speedup = cycle.mean_ns / fast.mean_ns;
    println!("{}  |  {cycle_ips:.1} images/s", cycle.report());
    println!("{}  |  {fast_ips:.1} images/s  ({speedup:.1}x)", fast.report());

    // Coordinator scaling over the pooled fast-path backend.
    let images: Vec<Image> = (0..32).map(|i| gen.sample((i % 10) as u8, i / 10)).collect();
    let requests = if quick { 128 } else { 512 };
    let mut qps = Vec::new();
    for workers in [1usize, 2, 4] {
        let q = coordinator_qps(&cfg, workers, requests, &images);
        println!("coordinator_rtl_w{workers}: {q:.0} req/s");
        qps.push((workers, q));
    }

    // Hand-rolled JSON (no serde in the offline crate set).
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"BENCH_1\",\n");
    json.push_str("  \"config\": \"paper_t10\",\n");
    json.push_str(&format!("  \"rtl_cycle_images_per_s\": {cycle_ips:.2},\n"));
    json.push_str(&format!("  \"rtl_fast_images_per_s\": {fast_ips:.2},\n"));
    json.push_str(&format!("  \"fast_path_speedup\": {speedup:.2},\n"));
    json.push_str("  \"coordinator_rtl_qps\": {\n");
    for (i, (workers, q)) in qps.iter().enumerate() {
        let comma = if i + 1 == qps.len() { "" } else { "," };
        json.push_str(&format!("    \"workers_{workers}\": {q:.2}{comma}\n"));
    }
    json.push_str("  }\n}\n");
    std::fs::write("BENCH_1.json", &json).expect("write BENCH_1.json");
    println!("-> BENCH_1.json");
}
