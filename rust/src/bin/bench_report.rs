//! `bench-report` — the perf-trajectory probe behind `tools/run_bench.sh`.
//!
//! Measures, on synthetic weights/digits (no artifacts needed):
//!
//! * images/sec of the RTL **cycle path** (`RtlCore::run`) and **fast
//!   path** (`RtlCore::run_fast`),
//! * images/sec of the fast path at depth 1 (`[784, 10]`) vs depth 2
//!   (`[784, 128, 10]`) plus coordinator qps for both — the cost of the
//!   layered schedule, on the perf record,
//! * end-to-end coordinator qps **and latency percentiles** over the
//!   pooled fast-path `RtlBackend` at 1 / 2 / 4 / 8 workers on the
//!   sharded work-stealing ingress,
//! * p50/p99 for large (≥ 32) batches with intra-batch fan-out off vs on
//!   — the latency (not just throughput) acceptance number of the
//!   sharded-ingress PR,
//!
//! * accuracy of the 3-layer calibration demo stack under one shared
//!   `v_th` vs per-layer calibrated thresholds (+ per-layer pruning) — the
//!   per-layer parameterization acceptance row — plus 3-layer fast-path
//!   images/sec,
//!
//! * **batched vs per-image engine throughput** at batch
//!   1/8/32/64/128/256: one `RtlCore::run_fast_batch` sweep for the whole
//!   batch vs the same images through a per-image `run_fast` loop — the
//!   row-reuse acceptance numbers of the batch-parallel engine PR
//!   (coordinator rows above run the batched backends end to end). The
//!   b128/b256 rows run a single multi-word chunk (`BATCH_LANES` = 256),
//!   so each weight row is fetched once per timestep for the whole batch;
//!   the report asserts images/s at b128 beats b64 — scaling must not go
//!   flat past the old one-word lane limit,
//!
//! * **paced-arrival (open-loop) tail latency**: a fixed-rate request
//!   clock with latency measured from each request's *scheduled* arrival,
//!   not its send — free of coordinated omission, which the closed-loop
//!   rows (kept for comparison) structurally understate at saturation,
//!
//! * the **calibrated fan-out crossover** (`FanoutPolicy::calibrated`)
//!   measured for the RTL backend,
//!
//! * **degraded-mode serving**: the closed-loop 4-worker shape with the
//!   RTL backend wrapped in `FaultInjectingBackend` at 0‰ / 10‰ / 50‰
//!   mixed fault rates (panics, transient errors, wrong-length replies) —
//!   throughput, p99, completed/failed splits, retry and worker-restart
//!   counts — plus a best-of-3 paired overhead check of the wrapper at 0‰
//!   against the unwrapped backend,
//!
//! * **sparse vs dense engine throughput**: the same magnitude-pruned
//!   stack through the dense row sweep (`run_fast_batch`) and the CSR
//!   silence-skipping sweep (`run_fast_batch_sparse`) at 100 / 50 / 10%
//!   weight density for `[784, 10]` and `[784, 128, 10]` — images/s and
//!   adds-performed per batch, the acceptance numbers of the event-driven
//!   sparse engine PR (plus the `density_crossover` constant the pooled
//!   backend routes by), and a `sparse_batched_wide` row: the 10%-density
//!   `[784, 128, 10]` stack through one 128-lane (two mask words) chunk,
//!   asserting the CSR speedup survives the neuron-major wide sweep
//!   (≥ 2× dense at b128),
//!
//! * the **thread-parallel batch kernel**: images/s of the dense wide
//!   sweep at threads 1 / 2 / 4 × hidden 128 / 512 × fixed lane widths
//!   64 / 128 / 256, plus the 10%-density CSR sweep at threads 1 / 4 —
//!   the neuron-range-sharding acceptance numbers (threads = 4 must beat
//!   threads = 1 on the [784, 512, 10] dense batch-128 row), and the
//!   cache-aware autotuned `ChunkPlan` vs the fixed 256-lane plan at
//!   batch 256 (the narrower autotuned chunk must hold ≥ 0.9× of
//!   fixed-256 — it trades lane amortization for plane residency, so it
//!   must never *lose* throughput to the tune),
//!
//! and writes the results to `BENCH_10.json` (plus stdout; the emitted
//! name is the single `BENCH_NAME` constant). BENCH_1 recorded qps only;
//! BENCH_2 added the percentile columns; BENCH_3 added the depth rows of
//! the N-layer refactor; BENCH_4 the per-layer threshold/pruning rows;
//! BENCH_5 the batched-engine and open-loop rows (EXPERIMENTS.md §Batch);
//! BENCH_6 the fault-injection rows (EXPERIMENTS.md §Robustness);
//! BENCH_7 the sparse-vs-dense rows (EXPERIMENTS.md §Sparse); BENCH_8
//! supersedes them with the wide-lane rows — `batched_engine` extended to
//! b128/b256 and the `sparse_batched_wide` row of the neuron-major
//! multi-word engine; BENCH_9 adds the `pallas_lint` row (full-tree
//! static-analysis runtime, asserting zero findings from the bench binary
//! too); BENCH_10 adds the `parallel_kernel` rows above
//! (EXPERIMENTS.md §Kernel Tuning). Note the guarded batch path
//! (`catch_unwind` + typed replies) is in *every* row since BENCH_6 — its
//! cost shows up as the BENCH_5 → BENCH_6 delta of the unchanged rows,
//! not as a within-report column.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use snn_rtl::bench::{black_box, Bench};
use snn_rtl::config::PruneMode;
use snn_rtl::coordinator::{
    Backend, BatchPolicy, Coordinator, CoordinatorConfig, FanoutPolicy, FaultInjectingBackend,
    FaultPlan, Histogram, Request, RtlBackend, SupervisionPolicy, SPARSE_DENSITY_CROSSOVER,
};
use snn_rtl::data::{DigitGen, Image};
use snn_rtl::experiments::{
    calibration_demo_image, calibration_demo_prune, calibration_demo_stack,
};
use snn_rtl::fixed::{WeightMatrix, WeightStack};
use snn_rtl::plan::ChunkPlan;
use snn_rtl::prng::Xorshift32;
use snn_rtl::rtl::RtlCore;
use snn_rtl::snn::EarlyExit;
use snn_rtl::SnnConfig;

/// The emitted report name — bump this (one place) when a PR adds rows.
const BENCH_NAME: &str = "BENCH_10";

fn weights(seed: u32) -> WeightMatrix {
    let mut rng = Xorshift32::new(seed);
    WeightMatrix::from_rows(784, 10, 9, (0..7840).map(|_| rng.range_i32(-30, 60)).collect())
        .unwrap()
}

/// A random stack for an arbitrary topology (same magnitude regime as the
/// single-layer synthetic weights).
fn stack(topology: &[usize], seed: u32) -> WeightStack {
    let mut rng = Xorshift32::new(seed);
    WeightStack::from_layers(
        topology
            .windows(2)
            .map(|d| {
                let data: Vec<i32> =
                    (0..d[0] * d[1]).map(|_| rng.range_i32(-30, 60)).collect();
                WeightMatrix::from_rows(d[0], d[1], 9, data).unwrap()
            })
            .collect(),
    )
    .unwrap()
}

/// A stack with a deterministic fraction of entries zeroed — magnitude
/// pruning's worst-case layout (uniformly scattered holes, no structure),
/// so the CSR sweep earns its speedup purely from skipped synapses.
fn stack_at_density(topology: &[usize], seed: u32, density_pct: u32) -> WeightStack {
    let mut rng = Xorshift32::new(seed);
    let mut mask = Xorshift32::new(seed ^ 0x9E37_79B9);
    WeightStack::from_layers(
        topology
            .windows(2)
            .map(|d| {
                let data: Vec<i32> = (0..d[0] * d[1])
                    .map(|_| {
                        let w = rng.range_i32(-30, 60);
                        if mask.range_i32(1, 100) <= density_pct as i32 {
                            w
                        } else {
                            0
                        }
                    })
                    .collect();
                WeightMatrix::from_rows(d[0], d[1], 9, data).unwrap()
            })
            .collect(),
    )
    .unwrap()
}

struct SparseRow {
    topology: &'static str,
    density_pct: u32,
    measured_density: f64,
    dense_ips: f64,
    sparse_ips: f64,
    dense_adds: u64,
    sparse_adds: u64,
}

struct CoordRow {
    qps: f64,
    p50_us: u64,
    p99_us: u64,
    steals: u64,
}

fn drive_coordinator(
    cfg: &SnnConfig,
    engine_weights: WeightStack,
    workers: usize,
    batch: BatchPolicy,
    fanout: FanoutPolicy,
    requests: usize,
    images: &[Image],
) -> CoordRow {
    let backend = Arc::new(RtlBackend::new(cfg.clone(), engine_weights).unwrap());
    let coord = Coordinator::start(
        backend,
        CoordinatorConfig {
            workers,
            queue_depth: 2048,
            batch,
            early: EarlyExit::Off,
            fanout,
            supervision: SupervisionPolicy::default(),
        },
    );
    let handle = coord.handle();
    let t0 = Instant::now();
    let mut receivers = Vec::with_capacity(requests);
    for i in 0..requests {
        let img = images[i % images.len()].clone();
        loop {
            match handle.submit(Request::new(img.clone()).with_seed(i as u32 + 1)) {
                Ok(rx) => {
                    receivers.push(rx);
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_micros(100)),
            }
        }
    }
    for rx in receivers {
        rx.recv().unwrap().unwrap();
    }
    let qps = requests as f64 / t0.elapsed().as_secs_f64();
    let snap = coord.metrics().snapshot();
    coord.shutdown();
    CoordRow { qps, p50_us: snap.latency_p50_us, p99_us: snap.latency_p99_us, steals: snap.steals }
}

struct PacedRow {
    offered_qps: f64,
    achieved_qps: f64,
    p50_us: u64,
    p99_us: u64,
    max_us: u64,
    rejected: u64,
}

/// Open-loop (paced-arrival) load generator: requests fire on a fixed-rate
/// clock regardless of how fast earlier responses come back, and each
/// latency is measured from the request's *scheduled* arrival — so a slow
/// server stalls the measurement, not the arrival process. The closed-loop
/// driver above, by contrast, only sends request `i+1` after `i` was
/// accepted, which silently thins the arrival rate exactly when the server
/// is slow (coordinated omission) and under-reports tail latency. A
/// request rejected by backpressure is counted (`rejected`), not retried —
/// an open-loop client does not wait for permission to exist.
#[allow(clippy::too_many_arguments)]
fn drive_coordinator_paced(
    cfg: &SnnConfig,
    engine_weights: WeightStack,
    workers: usize,
    batch: BatchPolicy,
    fanout: FanoutPolicy,
    offered_qps: f64,
    requests: usize,
    images: &[Image],
) -> PacedRow {
    let backend = Arc::new(RtlBackend::new(cfg.clone(), engine_weights).unwrap());
    let coord = Coordinator::start(
        backend,
        CoordinatorConfig {
            workers,
            queue_depth: 4096,
            batch,
            early: EarlyExit::Off,
            fanout,
            supervision: SupervisionPolicy::default(),
        },
    );
    let handle = coord.handle();
    let latency = Arc::new(Histogram::default());
    // Collector thread: polls every pending reply with `try_recv` instead
    // of draining serially — responses complete out of submission order
    // across workers, and a serial `recv` would attribute an earlier slow
    // request's completion time to later fast ones (head-of-line blocking
    // in the *measurement*). Polling bounds the timestamp error by the
    // poll interval, independent of completion order.
    let (tx, rx) = mpsc::channel::<(Instant, mpsc::Receiver<_>)>();
    let collector = {
        let latency = Arc::clone(&latency);
        std::thread::spawn(move || {
            let mut pending: Vec<(Instant, mpsc::Receiver<_>)> = Vec::new();
            let mut open = true;
            let mut done = 0u64;
            while open || !pending.is_empty() {
                let mut progressed = false;
                loop {
                    match rx.try_recv() {
                        Ok(entry) => {
                            pending.push(entry);
                            progressed = true;
                        }
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
                pending.retain(|(scheduled, reply)| match reply.try_recv() {
                    Ok(_) => {
                        latency.record(scheduled.elapsed());
                        done += 1;
                        progressed = true;
                        false
                    }
                    Err(mpsc::TryRecvError::Empty) => true,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        progressed = true;
                        false // dropped reply: not a completion
                    }
                });
                if !progressed {
                    std::thread::sleep(Duration::from_micros(20));
                }
            }
            done
        })
    };
    let interval = Duration::from_secs_f64(1.0 / offered_qps);
    let t0 = Instant::now();
    let mut rejected = 0u64;
    for i in 0..requests {
        let scheduled = t0 + interval.mul_f64(i as f64);
        while let Some(wait) = scheduled.checked_duration_since(Instant::now()) {
            if wait.is_zero() {
                break;
            }
            std::thread::sleep(wait);
        }
        let image = images[i % images.len()].clone();
        match handle.submit(Request::new(image).with_seed(i as u32 + 1)) {
            Ok(reply) => tx.send((scheduled, reply)).unwrap(),
            Err(_) => rejected += 1, // open-loop: the request is lost, not retried
        }
    }
    drop(tx);
    let done = collector.join().unwrap();
    let wall = t0.elapsed().as_secs_f64();
    coord.shutdown();
    PacedRow {
        offered_qps,
        achieved_qps: done as f64 / wall,
        p50_us: latency.quantile_us(0.50),
        p99_us: latency.quantile_us(0.99),
        max_us: latency.max_us(),
        rejected,
    }
}

struct FaultRow {
    per_mille: u32,
    qps: f64,
    p99_us: u64,
    completed: u64,
    failed: u64,
    retries: u64,
    restarts: u64,
    panics: u64,
}

/// Closed-loop 4-worker serving with the RTL backend wrapped in
/// [`FaultInjectingBackend`] at a mixed fault rate. Every request gets a
/// terminal reply (success or typed error); `recv` is never unwrapped
/// past the outer channel, so the row reports the completed/failed split
/// instead of dying on the first injected fault. Supervision is generous
/// (unbounded restarts, short backoff): the row measures degraded-mode
/// throughput, not restart-budget exhaustion.
fn drive_coordinator_faulted(
    cfg: &SnnConfig,
    engine_weights: WeightStack,
    per_mille: u32,
    requests: usize,
    images: &[Image],
) -> FaultRow {
    let inner: Arc<dyn Backend> =
        Arc::new(RtlBackend::new(cfg.clone(), engine_weights).unwrap());
    let backend =
        Arc::new(FaultInjectingBackend::new(inner, FaultPlan::mixed(0xFA57, per_mille)));
    let coord = Coordinator::start(
        backend,
        CoordinatorConfig {
            workers: 4,
            queue_depth: 2048,
            batch: BatchPolicy { max_batch: 8, max_delay: Duration::from_micros(500) },
            early: EarlyExit::Off,
            fanout: FanoutPolicy::default(),
            supervision: SupervisionPolicy {
                max_restarts_per_worker: u32::MAX,
                backoff_base: Duration::from_micros(50),
                backoff_cap: Duration::from_millis(1),
            },
        },
    );
    let handle = coord.handle();
    let t0 = Instant::now();
    let mut receivers = Vec::with_capacity(requests);
    for i in 0..requests {
        let img = images[i % images.len()].clone();
        loop {
            match handle.submit(Request::new(img.clone()).with_seed(i as u32 + 1)) {
                Ok(rx) => {
                    receivers.push(rx);
                    break;
                }
                Err(_) => std::thread::sleep(Duration::from_micros(100)),
            }
        }
    }
    let mut completed = 0u64;
    let mut failed = 0u64;
    for rx in receivers {
        match rx.recv().expect("fault-injected request lost its terminal reply") {
            Ok(_) => completed += 1,
            Err(_) => failed += 1,
        }
    }
    let qps = requests as f64 / t0.elapsed().as_secs_f64();
    let snap = coord.metrics().snapshot();
    coord.shutdown();
    FaultRow {
        per_mille,
        qps,
        p99_us: snap.latency_p99_us,
        completed,
        failed,
        retries: snap.subbatch_retries,
        restarts: snap.worker_restarts,
        panics: snap.panics_recovered,
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let cfg = SnnConfig::paper().with_timesteps(10);
    let gen = DigitGen::new(2);
    let img = gen.sample(3, 0);

    // Engine-level throughput.
    let mut core = RtlCore::new(cfg.clone(), weights(7)).unwrap();
    let mut seed = 1u32;
    let cycle = bench.run("rtl_cycle_path_t10", || {
        seed = seed.wrapping_add(1);
        black_box(core.run(&img, seed).unwrap());
    });
    let mut seed = 1u32;
    let fast = bench.run("rtl_fast_path_t10", || {
        seed = seed.wrapping_add(1);
        black_box(core.run_fast(&img, seed).unwrap());
    });
    let cycle_ips = cycle.throughput(1.0);
    let fast_ips = fast.throughput(1.0);
    let speedup = cycle.mean_ns / fast.mean_ns;
    println!("{}  |  {cycle_ips:.1} images/s", cycle.report());
    println!("{}  |  {fast_ips:.1} images/s  ({speedup:.1}x)", fast.report());

    // Batched vs per-image engine throughput: one `run_fast_batch` sweep
    // for the whole batch (each weight row walked once per timestep)
    // against the same images through the per-image fast path. b128 and
    // b256 exercise the multi-word lane masks: one chunk (BATCH_LANES =
    // 256) serves the whole batch, so each weight row is fetched once per
    // timestep for 128 / 256 images instead of twice / four times.
    let batch_gen = DigitGen::new(9);
    let mut batched_rows: Vec<(usize, f64, f64)> = Vec::new();
    for bs in [1usize, 8, 32, 64, 128, 256] {
        let batch_images: Vec<Image> =
            (0..bs).map(|i| batch_gen.sample((i % 10) as u8, i as u32)).collect();
        let refs: Vec<&Image> = batch_images.iter().collect();
        let mut core = RtlCore::new(cfg.clone(), weights(7)).unwrap();
        let mut round = 0u32;
        let batched = bench.run(&format!("rtl_fast_batch_b{bs}"), || {
            round = round.wrapping_add(1);
            let seeds: Vec<u32> =
                (0..bs as u32).map(|i| round.wrapping_mul(131).wrapping_add(i)).collect();
            black_box(core.run_fast_batch(&refs, &seeds, EarlyExit::Off).unwrap());
        });
        let mut core = RtlCore::new(cfg.clone(), weights(7)).unwrap();
        let mut round = 0u32;
        let per_image = bench.run(&format!("rtl_fast_per_image_b{bs}"), || {
            round = round.wrapping_add(1);
            for (i, img) in batch_images.iter().enumerate() {
                let seed = round.wrapping_mul(131).wrapping_add(i as u32);
                black_box(core.run_fast(img, seed).unwrap());
            }
        });
        let batched_ips = batched.throughput(bs as f64);
        let per_image_ips = per_image.throughput(bs as f64);
        println!(
            "batched_engine_b{bs}: batched {batched_ips:.1} images/s  |  per-image \
             {per_image_ips:.1} images/s  ({:.2}x)",
            batched_ips / per_image_ips
        );
        batched_rows.push((bs, batched_ips, per_image_ips));
    }
    let batched_ips_at = |n: usize| {
        batched_rows.iter().find(|&&(bs, ..)| bs == n).map(|&(_, ips, _)| ips).unwrap()
    };
    assert!(
        batched_ips_at(128) > batched_ips_at(64),
        "acceptance: wide-lane scaling — batched images/s at b128 ({:.1}) must beat \
         b64 ({:.1}); flat scaling past 64 lanes means the multi-word chunk is not \
         amortizing row fetches",
        batched_ips_at(128),
        batched_ips_at(64)
    );

    // Sparse vs dense: the same pruned stack through the dense row sweep
    // and the CSR silence-skipping sweep at 100 / 50 / 10% weight density.
    // Threshold 0 on the unpruned stack is the bit-exactness anchor (CSR
    // keeps every entry, explicit zeros included); the pruned rows use
    // threshold 1 so the CSR drops exactly the zeroed entries. Dense adds
    // stay ~flat across densities (every output in an active row pays an
    // add, zero weight or not); sparse adds must scale with density.
    let sparse_gen = DigitGen::new(11);
    let sparse_images: Vec<Image> =
        (0..32).map(|i| sparse_gen.sample((i % 10) as u8, i)).collect();
    let sparse_refs: Vec<&Image> = sparse_images.iter().collect();
    let sparse_seeds: Vec<u32> = (1..=sparse_refs.len() as u32).collect();
    let mut sparse_rows: Vec<SparseRow> = Vec::new();
    for (name, topology) in
        [("784_10", vec![784usize, 10]), ("784_128_10", vec![784usize, 128, 10])]
    {
        let row_cfg = SnnConfig::paper().with_topology(topology.clone()).with_timesteps(10);
        for density_pct in [100u32, 50, 10] {
            let pruned = stack_at_density(&topology, 7, density_pct);
            let threshold = if density_pct == 100 { 0 } else { 1 };
            let measured_density = pruned.to_csr(threshold).density();
            let mut dense_core = RtlCore::new(row_cfg.clone(), pruned.clone()).unwrap();
            let dense = bench.run(&format!("rtl_dense_{name}_d{density_pct}"), || {
                black_box(
                    dense_core.run_fast_batch(&sparse_refs, &sparse_seeds, EarlyExit::Off).unwrap(),
                );
            });
            let dense_adds: u64 = dense_core
                .run_fast_batch(&sparse_refs, &sparse_seeds, EarlyExit::Off)
                .unwrap()
                .iter()
                .map(|r| r.activity.adds)
                .sum();
            let mut sparse_core = RtlCore::new(row_cfg.clone(), pruned.clone()).unwrap();
            sparse_core.attach_sparse(threshold);
            let sparse = bench.run(&format!("rtl_sparse_{name}_d{density_pct}"), || {
                black_box(
                    sparse_core
                        .run_fast_batch_sparse(&sparse_refs, &sparse_seeds, EarlyExit::Off)
                        .unwrap(),
                );
            });
            let sparse_adds: u64 = sparse_core
                .run_fast_batch_sparse(&sparse_refs, &sparse_seeds, EarlyExit::Off)
                .unwrap()
                .iter()
                .map(|r| r.activity.adds)
                .sum();
            let row = SparseRow {
                topology: name,
                density_pct,
                measured_density,
                dense_ips: dense.throughput(sparse_refs.len() as f64),
                sparse_ips: sparse.throughput(sparse_refs.len() as f64),
                dense_adds,
                sparse_adds,
            };
            println!(
                "sparse_vs_dense_{name}_d{density_pct}: dense {:.1} images/s ({} adds)  |  \
                 sparse {:.1} images/s ({} adds)  ({:.2}x, density {:.3})",
                row.dense_ips,
                row.dense_adds,
                row.sparse_ips,
                row.sparse_adds,
                row.sparse_ips / row.dense_ips,
                row.measured_density
            );
            if density_pct == 10 {
                assert!(
                    row.sparse_ips >= 2.0 * row.dense_ips,
                    "acceptance: the CSR sweep must be >= 2x dense at 10% density \
                     ({name}: {:.1} vs {:.1} images/s)",
                    row.sparse_ips,
                    row.dense_ips
                );
                assert!(
                    row.sparse_adds * 5 < row.dense_adds,
                    "acceptance: sparse adds must scale with density \
                     ({name}: {} sparse vs {} dense at 10%)",
                    row.sparse_adds,
                    row.dense_adds
                );
            }
            sparse_rows.push(row);
        }
    }

    // Wide-lane sparse row: the 10%-density two-layer stack through one
    // 128-lane chunk — two mask words, every CSR row walked once per
    // timestep for all 128 lanes. The silence-skipping speedup must
    // survive the neuron-major wide sweep at the same width.
    let wide_images: Vec<Image> =
        (0..128).map(|i| sparse_gen.sample((i % 10) as u8, 1000 + i)).collect();
    let wide_refs: Vec<&Image> = wide_images.iter().collect();
    let wide_seeds: Vec<u32> = (1..=wide_refs.len() as u32).collect();
    let wide_topology = vec![784usize, 128, 10];
    let wide_cfg = SnnConfig::paper().with_topology(wide_topology.clone()).with_timesteps(10);
    let wide_pruned = stack_at_density(&wide_topology, 7, 10);
    let wide_density = wide_pruned.to_csr(1).density();
    let mut wide_dense_core = RtlCore::new(wide_cfg.clone(), wide_pruned.clone()).unwrap();
    let wide_dense = bench.run("rtl_dense_784_128_10_d10_b128", || {
        black_box(
            wide_dense_core.run_fast_batch(&wide_refs, &wide_seeds, EarlyExit::Off).unwrap(),
        );
    });
    let mut wide_sparse_core = RtlCore::new(wide_cfg, wide_pruned).unwrap();
    wide_sparse_core.attach_sparse(1);
    let wide_sparse = bench.run("rtl_sparse_784_128_10_d10_b128", || {
        black_box(
            wide_sparse_core
                .run_fast_batch_sparse(&wide_refs, &wide_seeds, EarlyExit::Off)
                .unwrap(),
        );
    });
    let wide_dense_ips = wide_dense.throughput(wide_refs.len() as f64);
    let wide_sparse_ips = wide_sparse.throughput(wide_refs.len() as f64);
    println!(
        "sparse_batched_wide_784_128_10_d10_b128: dense {wide_dense_ips:.1} images/s  |  \
         sparse {wide_sparse_ips:.1} images/s  ({:.2}x, density {wide_density:.3})",
        wide_sparse_ips / wide_dense_ips
    );
    assert!(
        wide_sparse_ips >= 2.0 * wide_dense_ips,
        "acceptance: the CSR sweep must stay >= 2x dense through a >64-lane chunk \
         ({wide_sparse_ips:.1} vs {wide_dense_ips:.1} images/s at b128)"
    );

    // Thread-parallel batch kernel: neuron-range sharding across worker
    // threads, swept over hidden width and fixed lane width. Results are
    // bit-identical at any thread count (the kernel's invariant, pinned
    // by the engine tests); these rows record what the sharding *buys* —
    // each worker walks a disjoint output-neuron range of the same
    // neuron-major planes, so the win should grow with hidden width
    // (more rows to split) and shrink when the per-range walk is too
    // short to cover the scope-spawn cost. `--quick` trims the grid to
    // the corners the asserts need.
    let par_gen = DigitGen::new(13);
    let par_images: Vec<Image> =
        (0..128).map(|i| par_gen.sample((i % 10) as u8, i)).collect();
    let par_refs: Vec<&Image> = par_images.iter().collect();
    let par_seeds: Vec<u32> = (1..=par_refs.len() as u32).collect();
    let thread_counts: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4] };
    let lane_widths: &[usize] = if quick { &[128] } else { &[64, 128, 256] };
    struct ParallelRow {
        hidden: usize,
        threads: usize,
        lanes: usize,
        ips: f64,
    }
    let mut parallel_dense: Vec<ParallelRow> = Vec::new();
    for hidden in [128usize, 512] {
        let topology = vec![784usize, hidden, 10];
        let row_cfg =
            SnnConfig::paper().with_topology(topology.clone()).with_timesteps(10);
        for &threads in thread_counts {
            for &lanes in lane_widths {
                let mut core = RtlCore::new(row_cfg.clone(), stack(&topology, 7))
                    .unwrap()
                    .with_batch_threads(threads)
                    .with_chunk_plan(ChunkPlan::fixed(lanes));
                let run = bench.run(
                    &format!("rtl_parallel_784_{hidden}_10_t{threads}_l{lanes}_b128"),
                    || {
                        black_box(
                            core.run_fast_batch(&par_refs, &par_seeds, EarlyExit::Off)
                                .unwrap(),
                        );
                    },
                );
                let ips = run.throughput(par_refs.len() as f64);
                println!(
                    "parallel_dense_784_{hidden}_10_t{threads}_l{lanes}: {ips:.1} images/s"
                );
                parallel_dense.push(ParallelRow { hidden, threads, lanes, ips });
            }
        }
    }
    let parallel_ips_at = |hidden: usize, threads: usize, lanes: usize| {
        parallel_dense
            .iter()
            .find(|r| r.hidden == hidden && r.threads == threads && r.lanes == lanes)
            .map(|r| r.ips)
            .unwrap()
    };
    assert!(
        parallel_ips_at(512, 4, 128) > parallel_ips_at(512, 1, 128),
        "acceptance: neuron-range sharding — 4 worker threads ({:.1} images/s) must \
         beat 1 ({:.1}) on the [784, 512, 10] dense b128 sweep; a flat line means \
         the shards serialized or the per-layer barrier dominates the walk",
        parallel_ips_at(512, 4, 128),
        parallel_ips_at(512, 1, 128)
    );

    // The sharded sweep through the CSR engine: the same worker split
    // drives `run_fast_batch_sparse`, so silence skipping and sharding
    // compose (each worker skips the silent rows of its own range).
    let mut parallel_sparse: Vec<ParallelRow> = Vec::new();
    for hidden in [128usize, 512] {
        let topology = vec![784usize, hidden, 10];
        let row_cfg =
            SnnConfig::paper().with_topology(topology.clone()).with_timesteps(10);
        let pruned = stack_at_density(&topology, 7, 10);
        for threads in [1usize, 4] {
            let mut core = RtlCore::new(row_cfg.clone(), pruned.clone())
                .unwrap()
                .with_batch_threads(threads)
                .with_chunk_plan(ChunkPlan::fixed(128));
            core.attach_sparse(1);
            let run = bench.run(
                &format!("rtl_parallel_sparse_784_{hidden}_10_d10_t{threads}_b128"),
                || {
                    black_box(
                        core.run_fast_batch_sparse(&par_refs, &par_seeds, EarlyExit::Off)
                            .unwrap(),
                    );
                },
            );
            let ips = run.throughput(par_refs.len() as f64);
            println!(
                "parallel_sparse_784_{hidden}_10_d10_t{threads}: {ips:.1} images/s"
            );
            parallel_sparse.push(ParallelRow { hidden, threads, lanes: 128, ips });
        }
    }

    // Cache-aware lane autotuning: the default (autotuned) plan vs the
    // widest fixed plan at batch 256 on the wide stack. At batch 128 the
    // two plans execute identically ([784, 512, 10] autotunes to 128
    // lanes = one chunk either way), so the comparison needs a batch the
    // plans actually split differently: 256 images is two 128-lane
    // chunks autotuned vs one 256-lane chunk fixed. The narrower chunk
    // walks each weight row twice but keeps the plane working set inside
    // the L2 budget; the acceptance bar is "never loses more than noise"
    // (>= 0.9x), with the upside left on the record, not asserted.
    let tune_images: Vec<Image> =
        (0..256).map(|i| par_gen.sample((i % 10) as u8, 2000 + i)).collect();
    let tune_refs: Vec<&Image> = tune_images.iter().collect();
    let tune_seeds: Vec<u32> = (1..=tune_refs.len() as u32).collect();
    let tune_topology = vec![784usize, 512, 10];
    let tune_cfg =
        SnnConfig::paper().with_topology(tune_topology.clone()).with_timesteps(10);
    let mut tuned_core = RtlCore::new(tune_cfg.clone(), stack(&tune_topology, 7)).unwrap();
    let tuned_lanes = tuned_core.chunk_plan().lanes();
    let tuned = bench.run("rtl_autotuned_784_512_10_b256", || {
        black_box(
            tuned_core.run_fast_batch(&tune_refs, &tune_seeds, EarlyExit::Off).unwrap(),
        );
    });
    let mut fixed_core = RtlCore::new(tune_cfg, stack(&tune_topology, 7))
        .unwrap()
        .with_chunk_plan(ChunkPlan::fixed(256));
    let fixed256 = bench.run("rtl_fixed256_784_512_10_b256", || {
        black_box(
            fixed_core.run_fast_batch(&tune_refs, &tune_seeds, EarlyExit::Off).unwrap(),
        );
    });
    let tuned_ips = tuned.throughput(tune_refs.len() as f64);
    let fixed256_ips = fixed256.throughput(tune_refs.len() as f64);
    println!(
        "lane_autotune_784_512_10_b256: autotuned(l{tuned_lanes}) {tuned_ips:.1} images/s  |  \
         fixed-256 {fixed256_ips:.1} images/s  ({:.3}x)",
        tuned_ips / fixed256_ips
    );
    assert!(
        tuned_ips >= fixed256_ips * 0.9,
        "acceptance: the L2-budget autotuned plan ({tuned_lanes} lanes, \
         {tuned_ips:.1} images/s) must hold >= 0.9x of the fixed 256-lane plan \
         ({fixed256_ips:.1} images/s) at b256 — a bigger loss means the narrower \
         chunk's extra row walks are not being paid back by plane residency"
    );

    // Adaptive fan-out crossover, measured against the (batched) RTL
    // backend: the policy the fixed 32/4 defaults would be replaced by.
    let probe_backend = RtlBackend::new(cfg.clone(), weights(7)).unwrap();
    let calibrated = FanoutPolicy::calibrated(&probe_backend, 4);
    println!(
        "calibrated_fanout: min_batch {}  max_parts {}",
        calibrated.min_batch, calibrated.max_parts
    );

    // Depth: single-layer vs the MLP-shaped two-layer pipeline, engine
    // level first (images/sec of the fast path).
    let deep_topology = vec![784usize, 128, 10];
    let deep_cfg = SnnConfig::paper()
        .with_topology(deep_topology.clone())
        .with_timesteps(10);
    let mut deep_core = RtlCore::new(deep_cfg.clone(), stack(&deep_topology, 7)).unwrap();
    let mut seed = 1u32;
    let deep_fast = bench.run("rtl_fast_path_784_128_10_t10", || {
        seed = seed.wrapping_add(1);
        black_box(deep_core.run_fast(&img, seed).unwrap());
    });
    let deep_ips = deep_fast.throughput(1.0);
    let depth_cost = fast.mean_ns / deep_fast.mean_ns;
    println!("{}  |  {deep_ips:.1} images/s  ({depth_cost:.2}x of single-layer)", deep_fast.report());

    // 3-layer rows: fast-path throughput of the [784, 20, 10, 10] demo
    // stack, and the per-layer-threshold acceptance numbers — the same
    // closed-form stack under one shared v_th (which provably silences
    // the readout) vs calibrated per-layer thresholds (+ pruning).
    let (demo_stack, demo_v_th) = calibration_demo_stack();
    let demo_topology = demo_stack.topology();
    let three_base = SnnConfig::paper()
        .with_topology(demo_topology.clone())
        .with_timesteps(10)
        .with_v_th(128)
        .with_prune(PruneMode::Off);
    let mut three_core = RtlCore::new(
        three_base.clone().with_layer_params(demo_v_th.clone()),
        demo_stack.clone(),
    )
    .unwrap();
    let mut seed = 1u32;
    let three_fast = bench.run("rtl_fast_path_784_20_10_10_t10", || {
        seed = seed.wrapping_add(1);
        black_box(three_core.run_fast(&img, seed).unwrap());
    });
    let three_ips = three_fast.throughput(1.0);
    println!("{}  |  {three_ips:.1} images/s (3-layer)", three_fast.report());

    let demo_accuracy = |cfg: &SnnConfig| -> f64 {
        let mut core = RtlCore::new(cfg.clone(), demo_stack.clone()).unwrap();
        let mut hits = 0usize;
        for class in 0..10usize {
            let r = core.run_fast(&calibration_demo_image(class), 0x900 + class as u32).unwrap();
            hits += usize::from(r.class as usize == class);
        }
        hits as f64 / 10.0
    };
    let acc_shared = demo_accuracy(&three_base);
    let acc_calibrated =
        demo_accuracy(&three_base.clone().with_layer_params(demo_v_th));
    let acc_cal_prune =
        demo_accuracy(&three_base.clone().with_layer_params(calibration_demo_prune()));
    println!(
        "depth_ablation_3layer: shared v_th {:.0}%  |  per-layer v_th {:.0}%  |  \
         per-layer v_th + prune {:.0}%",
        acc_shared * 100.0,
        acc_calibrated * 100.0,
        acc_cal_prune * 100.0
    );
    assert!(
        acc_calibrated > acc_shared,
        "acceptance: the calibrated 3-layer stack must beat the shared-v_th baseline"
    );

    // Worker scaling over the sharded ingress (small batches: throughput
    // and tail latency of the steady-state serving path).
    let images: Vec<Image> = (0..32).map(|i| gen.sample((i % 10) as u8, i / 10)).collect();
    let requests = if quick { 128 } else { 512 };
    let small_batch = BatchPolicy { max_batch: 8, max_delay: Duration::from_micros(500) };
    let mut scaling = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let row = drive_coordinator(
            &cfg,
            weights(7).into(),
            workers,
            small_batch,
            FanoutPolicy::default(),
            requests,
            &images,
        );
        println!(
            "coordinator_rtl_w{workers}: {:.0} req/s  p50 {} µs  p99 {} µs  steals {}",
            row.qps, row.p50_us, row.p99_us, row.steals
        );
        scaling.push((workers, row));
    }

    // Depth through the pooled coordinator: same serving shape, 4
    // workers, single- vs two-layer engines.
    let depth_requests = if quick { 96 } else { 384 };
    let coord_shallow = drive_coordinator(
        &cfg,
        weights(7).into(),
        4,
        small_batch,
        FanoutPolicy::default(),
        depth_requests,
        &images,
    );
    let coord_deep = drive_coordinator(
        &deep_cfg,
        stack(&deep_topology, 7),
        4,
        small_batch,
        FanoutPolicy::default(),
        depth_requests,
        &images,
    );
    println!(
        "coordinator_depth_w4: [784,10] {:.0} req/s p99 {} µs  |  [784,128,10] {:.0} req/s p99 {} µs",
        coord_shallow.qps, coord_shallow.p99_us, coord_deep.qps, coord_deep.p99_us
    );

    // Intra-batch fan-out: one worker stream of large (>= 32) batches; the
    // fan-out path must cut p99 against the single-engine baseline.
    let big_batch = BatchPolicy { max_batch: 64, max_delay: Duration::from_micros(500) };
    let fan_requests = if quick { 256 } else { 1024 };
    let fan_off = drive_coordinator(
        &cfg,
        weights(7).into(),
        4,
        big_batch,
        FanoutPolicy::off(),
        fan_requests,
        &images,
    );
    let fan_on = drive_coordinator(
        &cfg,
        weights(7).into(),
        4,
        big_batch,
        FanoutPolicy { min_batch: 32, max_parts: 4 },
        fan_requests,
        &images,
    );
    println!(
        "large_batch_fanout_off: {:.0} req/s  p50 {} µs  p99 {} µs",
        fan_off.qps, fan_off.p50_us, fan_off.p99_us
    );
    println!(
        "large_batch_fanout_on:  {:.0} req/s  p50 {} µs  p99 {} µs",
        fan_on.qps, fan_on.p50_us, fan_on.p99_us
    );

    // Open-loop (paced-arrival) tail latency at ~70% of the closed-loop
    // 4-worker capacity: latency measured from each request's scheduled
    // arrival, so queueing delay the closed-loop driver hides is on the
    // record. The closed-loop w4 row above is the comparison point.
    let closed_w4_qps = scaling.iter().find(|(w, _)| *w == 4).map(|(_, r)| r.qps).unwrap();
    let offered = (closed_w4_qps * 0.7).max(50.0);
    let paced_requests =
        ((offered * if quick { 1.0 } else { 3.0 }) as usize).clamp(100, 8000);
    let paced = drive_coordinator_paced(
        &cfg,
        weights(7).into(),
        4,
        small_batch,
        FanoutPolicy::default(),
        offered,
        paced_requests,
        &images,
    );
    println!(
        "paced_arrival_w4: offered {:.0} req/s  achieved {:.0} req/s  p50 {} µs  \
         p99 {} µs  max {} µs  rejected {}",
        paced.offered_qps, paced.achieved_qps, paced.p50_us, paced.p99_us, paced.max_us,
        paced.rejected
    );

    // Degraded-mode serving: 0‰ / 10‰ / 50‰ mixed fault schedules through
    // the fault-injecting wrapper, plus a best-of-3 paired overhead check
    // of the wrapper itself at 0‰ (it must be free when injecting
    // nothing; the catch_unwind guard is in both paths by construction).
    let fault_requests = if quick { 192 } else { 768 };
    let mut fault_rows = Vec::new();
    for per_mille in [0u32, 10, 50] {
        let row =
            drive_coordinator_faulted(&cfg, weights(7).into(), per_mille, fault_requests, &images);
        println!(
            "fault_injection_w4_{per_mille}permille: {:.0} req/s  p99 {} µs  ok {}  \
             failed {}  retries {}  restarts {}  panics {}",
            row.qps, row.p99_us, row.completed, row.failed, row.retries, row.restarts, row.panics
        );
        fault_rows.push(row);
    }
    let mut plain_best = 0f64;
    let mut wrapped_best = 0f64;
    for _ in 0..3 {
        let plain = drive_coordinator(
            &cfg,
            weights(7).into(),
            4,
            small_batch,
            FanoutPolicy::default(),
            fault_requests,
            &images,
        );
        plain_best = plain_best.max(plain.qps);
        let wrapped =
            drive_coordinator_faulted(&cfg, weights(7).into(), 0, fault_requests, &images);
        wrapped_best = wrapped_best.max(wrapped.qps);
    }
    let wrapper_ratio = wrapped_best / plain_best;
    println!(
        "fault_wrapper_overhead: plain {plain_best:.0} req/s  wrapped@0 {wrapped_best:.0} req/s  \
         ratio {wrapper_ratio:.3} (target >= 0.98)"
    );
    assert!(
        wrapper_ratio > 0.90,
        "fault wrapper at 0 per mille costs >10% throughput ({wrapper_ratio:.3}) — \
         the injection path is on the hot path"
    );

    // The static-analysis pass, timed in-process. CI gates on the
    // dedicated binary; the bench records how long the full-tree walk
    // takes (it must stay cheap enough to run on every push) and asserts
    // a clean tree from this binary too.
    let lint_started = Instant::now();
    let lint_root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let lint_analysis = snn_rtl::lint::analyze_tree(lint_root).expect("pallas-lint tree walk");
    let lint_runtime_ms = lint_started.elapsed().as_secs_f64() * 1e3;
    assert!(
        lint_analysis.findings.is_empty(),
        "pallas-lint reported {} finding(s) during the bench run",
        lint_analysis.findings.len()
    );
    println!(
        "pallas_lint: {} files, {} lines, 0 findings in {lint_runtime_ms:.1} ms",
        lint_analysis.files, lint_analysis.lines
    );

    // Hand-rolled JSON (no serde in the offline crate set).
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"bench\": \"{BENCH_NAME}\",\n"));
    json.push_str("  \"config\": \"paper_t10\",\n");
    json.push_str(&format!("  \"rtl_cycle_images_per_s\": {cycle_ips:.2},\n"));
    json.push_str(&format!("  \"rtl_fast_images_per_s\": {fast_ips:.2},\n"));
    json.push_str(&format!("  \"fast_path_speedup\": {speedup:.2},\n"));
    json.push_str("  \"depth\": {\n");
    json.push_str(&format!(
        "    \"single_layer_784_10\": {{ \"images_per_s\": {fast_ips:.2}, \"coordinator_w4_qps\": {:.2}, \"coordinator_w4_p99_us\": {} }},\n",
        coord_shallow.qps, coord_shallow.p99_us
    ));
    json.push_str(&format!(
        "    \"two_layer_784_128_10\": {{ \"images_per_s\": {deep_ips:.2}, \"coordinator_w4_qps\": {:.2}, \"coordinator_w4_p99_us\": {} }},\n",
        coord_deep.qps, coord_deep.p99_us
    ));
    json.push_str(&format!("    \"two_layer_throughput_ratio\": {depth_cost:.3},\n"));
    json.push_str(&format!(
        "    \"three_layer_784_20_10_10\": {{ \"images_per_s\": {three_ips:.2} }},\n"
    ));
    json.push_str("    \"three_layer_calibration\": {\n");
    json.push_str(&format!("      \"shared_v_th_accuracy\": {acc_shared:.3},\n"));
    json.push_str(&format!("      \"per_layer_v_th_accuracy\": {acc_calibrated:.3},\n"));
    json.push_str(&format!(
        "      \"per_layer_v_th_prune_accuracy\": {acc_cal_prune:.3}\n"
    ));
    json.push_str("    }\n");
    json.push_str("  },\n");
    json.push_str("  \"batched_engine\": {\n");
    for (i, (bs, batched_ips, per_image_ips)) in batched_rows.iter().enumerate() {
        let comma = if i + 1 == batched_rows.len() { "" } else { "," };
        json.push_str(&format!(
            "    \"b{bs}\": {{ \"batched_images_per_s\": {batched_ips:.2}, \
             \"per_image_images_per_s\": {per_image_ips:.2}, \"speedup\": {:.3} }}{comma}\n",
            batched_ips / per_image_ips
        ));
    }
    json.push_str("  },\n");
    json.push_str("  \"sparse_vs_dense\": {\n");
    json.push_str(&format!("    \"density_crossover\": {SPARSE_DENSITY_CROSSOVER},\n"));
    for (i, r) in sparse_rows.iter().enumerate() {
        let comma = if i + 1 == sparse_rows.len() { "" } else { "," };
        json.push_str(&format!(
            "    \"{}_d{}\": {{ \"density\": {:.4}, \"dense_images_per_s\": {:.2}, \
             \"sparse_images_per_s\": {:.2}, \"dense_adds\": {}, \"sparse_adds\": {}, \
             \"speedup\": {:.3} }}{comma}\n",
            r.topology,
            r.density_pct,
            r.measured_density,
            r.dense_ips,
            r.sparse_ips,
            r.dense_adds,
            r.sparse_adds,
            r.sparse_ips / r.dense_ips
        ));
    }
    json.push_str("  },\n");
    json.push_str(&format!(
        "  \"sparse_batched_wide\": {{ \"topology\": \"784_128_10\", \"batch\": 128, \
         \"density\": {wide_density:.4}, \"dense_images_per_s\": {wide_dense_ips:.2}, \
         \"sparse_images_per_s\": {wide_sparse_ips:.2}, \"speedup\": {:.3} }},\n",
        wide_sparse_ips / wide_dense_ips
    ));
    json.push_str("  \"parallel_kernel\": {\n");
    json.push_str("    \"dense_b128\": {\n");
    for (i, r) in parallel_dense.iter().enumerate() {
        let comma = if i + 1 == parallel_dense.len() { "" } else { "," };
        json.push_str(&format!(
            "      \"784_{}_10_t{}_l{}\": {{ \"images_per_s\": {:.2} }}{comma}\n",
            r.hidden, r.threads, r.lanes, r.ips
        ));
    }
    json.push_str("    },\n");
    json.push_str("    \"sparse_d10_b128\": {\n");
    for (i, r) in parallel_sparse.iter().enumerate() {
        let comma = if i + 1 == parallel_sparse.len() { "" } else { "," };
        json.push_str(&format!(
            "      \"784_{}_10_t{}\": {{ \"images_per_s\": {:.2} }}{comma}\n",
            r.hidden, r.threads, r.ips
        ));
    }
    json.push_str("    },\n");
    json.push_str(&format!(
        "    \"thread_scaling_784_512_10_l128\": {:.3},\n",
        parallel_ips_at(512, 4, 128) / parallel_ips_at(512, 1, 128)
    ));
    json.push_str(&format!(
        "    \"autotune_b256\": {{ \"auto_lanes\": {tuned_lanes}, \
         \"auto_images_per_s\": {tuned_ips:.2}, \"fixed256_images_per_s\": \
         {fixed256_ips:.2}, \"ratio\": {:.4} }}\n",
        tuned_ips / fixed256_ips
    ));
    json.push_str("  },\n");
    json.push_str(&format!(
        "  \"calibrated_fanout\": {{ \"min_batch\": {}, \"max_parts\": {} }},\n",
        calibrated.min_batch, calibrated.max_parts
    ));
    json.push_str("  \"paced_arrival_w4\": {\n");
    json.push_str(&format!("    \"offered_qps\": {:.2},\n", paced.offered_qps));
    json.push_str(&format!("    \"achieved_qps\": {:.2},\n", paced.achieved_qps));
    json.push_str(&format!("    \"p50_us\": {},\n", paced.p50_us));
    json.push_str(&format!("    \"p99_us\": {},\n", paced.p99_us));
    json.push_str(&format!("    \"max_us\": {},\n", paced.max_us));
    json.push_str(&format!("    \"rejected\": {},\n", paced.rejected));
    json.push_str(&format!("    \"closed_loop_w4_qps\": {closed_w4_qps:.2}\n"));
    json.push_str("  },\n");
    json.push_str("  \"coordinator_rtl\": {\n");
    for (i, (workers, row)) in scaling.iter().enumerate() {
        let comma = if i + 1 == scaling.len() { "" } else { "," };
        json.push_str(&format!(
            "    \"workers_{workers}\": {{ \"qps\": {:.2}, \"p50_us\": {}, \"p99_us\": {}, \
             \"steals\": {} }}{comma}\n",
            row.qps, row.p50_us, row.p99_us, row.steals
        ));
    }
    json.push_str("  },\n");
    json.push_str("  \"large_batch_b64_w4\": {\n");
    json.push_str(&format!(
        "    \"fanout_off\": {{ \"qps\": {:.2}, \"p50_us\": {}, \"p99_us\": {} }},\n",
        fan_off.qps, fan_off.p50_us, fan_off.p99_us
    ));
    json.push_str(&format!(
        "    \"fanout_on\": {{ \"qps\": {:.2}, \"p50_us\": {}, \"p99_us\": {} }}\n",
        fan_on.qps, fan_on.p50_us, fan_on.p99_us
    ));
    json.push_str("  },\n");
    json.push_str("  \"fault_injection_w4\": {\n");
    json.push_str(&format!(
        "    \"wrapper_overhead\": {{ \"plain_qps\": {plain_best:.2}, \
         \"wrapped_0permille_qps\": {wrapped_best:.2}, \"ratio\": {wrapper_ratio:.4} }},\n"
    ));
    for (i, r) in fault_rows.iter().enumerate() {
        let comma = if i + 1 == fault_rows.len() { "" } else { "," };
        json.push_str(&format!(
            "    \"per_mille_{}\": {{ \"qps\": {:.2}, \"p99_us\": {}, \"completed\": {}, \
             \"failed\": {}, \"subbatch_retries\": {}, \"worker_restarts\": {}, \
             \"panics_recovered\": {} }}{comma}\n",
            r.per_mille, r.qps, r.p99_us, r.completed, r.failed, r.retries, r.restarts, r.panics
        ));
    }
    json.push_str("  },\n");
    json.push_str(&format!(
        "  \"pallas_lint\": {{ \"files\": {}, \"lines\": {}, \
         \"lint_runtime_ms\": {lint_runtime_ms:.2} }}\n",
        lint_analysis.files, lint_analysis.lines
    ));
    json.push_str("}\n");
    let out = format!("{BENCH_NAME}.json");
    std::fs::write(&out, &json).unwrap_or_else(|e| panic!("write {out}: {e}"));
    println!("-> {out}");
}
