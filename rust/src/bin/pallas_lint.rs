//! pallas-lint CLI — run the repo-invariant static analysis over
//! `rust/src` and `rust/tests` and exit non-zero on any finding.
//!
//! Usage:
//!   pallas_lint [ROOT] [--fix-list]
//!
//! `ROOT` defaults to the current directory (the repo root in CI). The
//! default output prints one human-readable line per finding
//! (`file:line: [Lx] message — excerpt`); `--fix-list` prints the
//! machine-readable `file:line<TAB>rule` triples only, for piping into
//! editors or scripts.

use std::path::PathBuf;
use std::process::ExitCode;

use snn_rtl::lint;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut fix_list = false;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--fix-list" => fix_list = true,
            "--help" | "-h" => {
                println!("usage: pallas_lint [ROOT] [--fix-list]");
                return ExitCode::SUCCESS;
            }
            other => root = PathBuf::from(other),
        }
    }
    let analysis = match lint::analyze_tree(&root) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("pallas-lint: failed to walk {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    if fix_list {
        for f in &analysis.findings {
            println!("{}:{}\t{}", f.file, f.line, f.rule.id());
        }
    } else {
        for f in &analysis.findings {
            println!("{f}");
        }
        eprintln!(
            "pallas-lint: {} finding(s) across {} files ({} lines)",
            analysis.findings.len(),
            analysis.files,
            analysis.lines
        );
    }
    if analysis.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
