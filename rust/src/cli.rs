//! Hand-rolled CLI argument parsing (clap is not in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! with typed accessors and an unknown-flag check — the slice of clap this
//! binary needs.

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Parsed arguments: positionals in order + flag map.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    /// Flags that were consumed by typed accessors (unknown-flag check).
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` ends flag parsing.
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.flags.insert(body.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    /// String flag with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string flag.
    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    /// Boolean flag (present or `--key true/false`).
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        matches!(self.flags.get(key).map(String::as_str), Some("true") | Some("1"))
    }

    /// Typed numeric flag with default.
    pub fn num_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::InvalidConfig(format!("--{key}: cannot parse {v:?}"))
            }),
        }
    }

    /// Error on any flag that no accessor consumed (catch typos).
    pub fn check_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        for k in self.flags.keys() {
            if !seen.iter().any(|s| s == k) {
                return Err(Error::InvalidConfig(format!("unknown flag --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["experiment", "fig5", "--timesteps", "10", "--quick", "--k=v"]);
        assert_eq!(a.positional, vec!["experiment", "fig5"]);
        assert_eq!(a.num_or("timesteps", 0u32).unwrap(), 10);
        assert!(a.flag("quick"));
        assert_eq!(a.str_or("k", ""), "v");
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["cmd"]);
        assert_eq!(a.num_or("n", 7i32).unwrap(), 7);
        assert_eq!(a.str_or("s", "d"), "d");
        assert!(!a.flag("quick"));
        assert!(a.str_opt("missing").is_none());
    }

    #[test]
    fn bad_number_reports_flag() {
        let a = parse(&["--n", "abc"]);
        let err = a.num_or("n", 0u32).unwrap_err().to_string();
        assert!(err.contains("--n"), "{err}");
    }

    #[test]
    fn unknown_flag_detected() {
        let a = parse(&["--typo", "1"]);
        let _ = a.num_or("ok", 0u32);
        assert!(a.check_unknown().is_err());
        let b = parse(&["--known", "1"]);
        let _ = b.num_or("known", 0u32);
        assert!(b.check_unknown().is_ok());
    }

    #[test]
    fn double_dash_ends_flags() {
        let a = parse(&["--a", "1", "--", "--not-a-flag"]);
        assert_eq!(a.num_or("a", 0u32).unwrap(), 1);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }
}
