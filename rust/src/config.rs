//! Network and core configuration shared by every backend.
//!
//! A single [`SnnConfig`] value describes the architectural parameters of
//! the paper's core (topology, fixed-point geometry, LIF constants, firing
//! and pruning policy). The behavioral model, the RTL simulator and the
//! AOT-compiled JAX graph all consume the same struct so that equivalence
//! tests compare like with like.
//!
//! Since the N-layer refactor the topology is a dimension chain
//! (`[784, 10]` for the paper's single fully connected layer,
//! `[784, 128, 10]` for the MLP-shaped deep variant): entry `l` is the
//! input width of layer `l`, entry `l+1` its output width.
//!
//! Since the per-layer parameterization pass the LIF threshold, decay and
//! pruning policy can additionally differ *per connection*:
//! [`SnnConfig::layer_params`] holds one optional [`LayerParams`] override
//! per layer, and the scalar fields remain the shared defaults — an empty
//! override list reproduces the shared-parameter core bit for bit. The
//! accumulator/weight geometry and the fire/leak scheduling policies stay
//! global (one datapath design instantiated per layer; only its
//! calibration registers differ).

use crate::error::{Error, Result};

/// When a neuron's threshold comparison takes effect.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FireMode {
    /// Threshold is checked once per timestep, after leak (the
    /// architectural contract; what L1/L2 implement).
    EndOfStep,
    /// The comparator acts combinationally: the accumulator resets on the
    /// very cycle it crosses threshold, mid-integration (paper §III-B3
    /// "continuously monitors"). Only the RTL simulator implements this
    /// refinement.
    Immediate,
}

/// When the leak (right-shift decay) is applied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeakMode {
    /// Once per timestep after all inputs are integrated (architectural
    /// contract).
    PerTimestep,
    /// After every `row_len` inputs (paper §III-B2 "after processing one
    /// image row"); RTL-only refinement. Rows are image geometry, so this
    /// schedule applies to the input layer's pixel walk; deeper layers
    /// (whose inputs are spike registers, not pixel rows) leak once per
    /// walk.
    PerRow { row_len: usize },
}

/// Active-pruning policy (paper §III-D).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PruneMode {
    /// No pruning: every neuron stays enabled the whole window.
    Off,
    /// Gate a neuron's enable off after it has fired `after_spikes` times.
    /// The paper gates after the first fire (`after_spikes = 1`).
    AfterFires { after_spikes: u32 },
}

/// How the output layer turns spike activity into a class decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionPolicy {
    /// Argmax of output spike counts over the full window (ties broken by
    /// lowest class index — also the hardware behaviour of a priority
    /// encoder).
    SpikeCount,
    /// The first neuron to fire wins; falls back to spike count when no
    /// neuron fires within the window.
    FirstSpike,
}

/// Per-layer overrides of the scalar LIF calibration. `None` fields
/// inherit the matching scalar on [`SnnConfig`], so an all-`None` entry
/// (or an empty override list) is bit-identical to the shared-parameter
/// core. Hardware view: each layer's neuron array has its own threshold
/// and decay registers plus its own pruning counter limit; the datapath
/// geometry is shared.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LayerParams {
    /// Firing threshold for this layer (`None` = shared `v_th`).
    pub v_th: Option<i32>,
    /// Decay exponent for this layer (`None` = shared `decay_shift`).
    pub decay_shift: Option<u32>,
    /// Pruning policy for this layer (`None` = shared `prune`).
    pub prune: Option<PruneMode>,
}

impl LayerParams {
    /// Override only the threshold (the most common calibration axis).
    pub fn with_v_th(v: i32) -> Self {
        LayerParams { v_th: Some(v), ..Self::default() }
    }
}

/// Complete architectural configuration of the SNN core.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SnnConfig {
    /// Layer dimension chain: `topology[0]` input channels (pixels),
    /// `topology[last]` output neurons (classes), anything between a
    /// hidden spiking layer. Paper: `[784, 10]`.
    pub topology: Vec<usize>,
    /// Firing threshold `V_th` in accumulator units. Paper: 128 (scaled by
    /// training; see artifacts manifest).
    pub v_th: i32,
    /// Resting/reset potential. Paper: 0 ("to minimize logic gates").
    pub v_rest: i32,
    /// Decay exponent: leak is `acc -= acc >> decay_shift`. Paper: β = 2^-n.
    pub decay_shift: u32,
    /// Accumulator width in bits (signed). The accumulator saturates at
    /// ±(2^(acc_bits-1) - 1) like a hardware register with saturation logic.
    pub acc_bits: u32,
    /// Signed weight width in bits. Paper: 9 (memory math: 784×10×9 bits).
    pub weight_bits: u32,
    /// Simulation window in timesteps. Paper evaluates T ∈ [1, 20].
    pub timesteps: u32,
    /// Threshold-check policy.
    pub fire_mode: FireMode,
    /// Leak scheduling policy.
    pub leak_mode: LeakMode,
    /// Active-pruning policy.
    pub prune: PruneMode,
    /// Classification readout policy.
    pub decision: DecisionPolicy,
    /// Per-layer overrides of `v_th`/`decay_shift`/`prune`. Either empty
    /// (every layer shares the scalars above) or exactly one entry per
    /// weight layer. Resolved via [`SnnConfig::layer_v_th`] and friends.
    pub layer_params: Vec<LayerParams>,
}

impl Default for SnnConfig {
    /// The paper's configuration: 784→10, V_th = 128, V_rest = 0,
    /// β = 2^-3, 9-bit weights, 24-bit accumulator, T = 20 window,
    /// end-of-step firing, per-timestep leak, prune-after-first-fire,
    /// spike-count readout.
    fn default() -> Self {
        SnnConfig {
            topology: vec![784, 10],
            v_th: 128,
            v_rest: 0,
            decay_shift: 3,
            acc_bits: 24,
            weight_bits: 9,
            timesteps: 20,
            fire_mode: FireMode::EndOfStep,
            leak_mode: LeakMode::PerTimestep,
            prune: PruneMode::AfterFires { after_spikes: 1 },
            decision: DecisionPolicy::SpikeCount,
            layer_params: Vec::new(),
        }
    }
}

impl SnnConfig {
    /// The paper's published configuration (alias of [`Default`]).
    pub fn paper() -> Self {
        Self::default()
    }

    /// Number of input channels (pixels). Paper: 28×28 = 784.
    pub fn n_inputs(&self) -> usize {
        self.topology[0]
    }

    /// Number of output neurons (classes). Paper: 10.
    pub fn n_outputs(&self) -> usize {
        self.topology[self.topology.len() - 1]
    }

    /// Number of weight layers (connections): `topology.len() - 1`.
    pub fn n_layers(&self) -> usize {
        self.topology.len() - 1
    }

    /// Input width of weight layer `l`.
    pub fn layer_input(&self, l: usize) -> usize {
        self.topology[l]
    }

    /// Output width (neuron count) of weight layer `l`.
    pub fn layer_output(&self, l: usize) -> usize {
        self.topology[l + 1]
    }

    /// The override record for layer `l` (all-`None` when the list is
    /// empty or the layer has no entry).
    fn layer_over(&self, l: usize) -> LayerParams {
        self.layer_params.get(l).copied().unwrap_or_default()
    }

    /// Resolved firing threshold of layer `l` (override or shared `v_th`).
    pub fn layer_v_th(&self, l: usize) -> i32 {
        self.layer_over(l).v_th.unwrap_or(self.v_th)
    }

    /// Resolved decay exponent of layer `l`.
    pub fn layer_decay_shift(&self, l: usize) -> u32 {
        self.layer_over(l).decay_shift.unwrap_or(self.decay_shift)
    }

    /// Resolved pruning policy of layer `l`.
    pub fn layer_prune(&self, l: usize) -> PruneMode {
        self.layer_over(l).prune.unwrap_or(self.prune)
    }

    /// The single-connection view of layer `l`: topology narrowed to
    /// `[topology[l], topology[l+1]]` with the layer's *resolved*
    /// threshold/decay/prune written into the scalar fields (and no
    /// further overrides). This is the config one behavioral
    /// [`crate::snn::LifLayer`] — or one RTL neuron array — runs, so the
    /// per-layer parameterization threads through every model level from
    /// this one resolution point.
    pub fn layer_config(&self, l: usize) -> SnnConfig {
        SnnConfig {
            topology: vec![self.topology[l], self.topology[l + 1]],
            v_th: self.layer_v_th(l),
            decay_shift: self.layer_decay_shift(l),
            prune: self.layer_prune(l),
            layer_params: Vec::new(),
            ..self.clone()
        }
    }

    /// The largest early-exit margin the *output* layer's pruning policy
    /// can ever produce: with `AfterFires { after_spikes: a }` every spike
    /// count register caps at `a`, so the best reachable lead is `a` (the
    /// leader at `a`, the runner-up at 0) and any larger margin silently
    /// never triggers. `None` = unbounded (readout pruning off).
    pub fn max_reachable_margin(&self) -> Option<u32> {
        match self.layer_prune(self.n_layers().saturating_sub(1)) {
            PruneMode::Off => None,
            PruneMode::AfterFires { after_spikes } => Some(after_spikes),
        }
    }

    /// Saturation bound of the accumulator: `2^(acc_bits-1) - 1`.
    pub fn acc_max(&self) -> i32 {
        (1i32 << (self.acc_bits - 1)) - 1
    }

    /// Negative saturation bound (symmetric saturation, as hardware
    /// saturation logic is usually built: `-(2^(acc_bits-1) - 1)`).
    pub fn acc_min(&self) -> i32 {
        -self.acc_max()
    }

    /// Maximum representable weight: `2^(weight_bits-1) - 1`.
    pub fn weight_max(&self) -> i32 {
        (1i32 << (self.weight_bits - 1)) - 1
    }

    /// Minimum representable weight (two's complement).
    pub fn weight_min(&self) -> i32 {
        -(1i32 << (self.weight_bits - 1))
    }

    /// Weight storage footprint in bits, summed over the layer chain (the
    /// paper's 8.6 KB figure is `784 × 10 × 9`).
    pub fn weight_storage_bits(&self) -> u64 {
        (0..self.n_layers())
            .map(|l| {
                self.layer_input(l) as u64
                    * self.layer_output(l) as u64
                    * u64::from(self.weight_bits)
            })
            .sum()
    }

    /// Validate internal consistency; returns `self` for builder-style use.
    pub fn validated(self) -> Result<Self> {
        if self.topology.len() < 2 {
            return Err(Error::InvalidConfig(format!(
                "topology needs at least an input and an output width, got {:?}",
                self.topology
            )));
        }
        if self.topology.iter().any(|&d| d == 0) {
            return Err(Error::InvalidConfig("topology dimensions must be nonzero".into()));
        }
        if !(2..=31).contains(&self.acc_bits) {
            return Err(Error::InvalidConfig(format!(
                "acc_bits {} outside supported range 2..=31",
                self.acc_bits
            )));
        }
        if !(2..=16).contains(&self.weight_bits) {
            return Err(Error::InvalidConfig(format!(
                "weight_bits {} outside supported range 2..=16",
                self.weight_bits
            )));
        }
        if self.decay_shift == 0 || self.decay_shift > 30 {
            return Err(Error::InvalidConfig(format!(
                "decay_shift {} outside supported range 1..=30 (0 would zero the \
                 membrane every step)",
                self.decay_shift
            )));
        }
        if self.v_th <= self.v_rest {
            return Err(Error::InvalidConfig(format!(
                "v_th ({}) must exceed v_rest ({})",
                self.v_th, self.v_rest
            )));
        }
        if self.v_th > self.acc_max() {
            return Err(Error::InvalidConfig(format!(
                "v_th ({}) exceeds accumulator saturation ({})",
                self.v_th,
                self.acc_max()
            )));
        }
        if self.timesteps == 0 {
            return Err(Error::InvalidConfig("timesteps must be nonzero".into()));
        }
        if let LeakMode::PerRow { row_len } = self.leak_mode {
            if row_len == 0 || row_len > self.n_inputs() {
                return Err(Error::InvalidConfig(format!(
                    "leak row_len {} outside 1..={}",
                    row_len,
                    self.n_inputs()
                )));
            }
        }
        if let PruneMode::AfterFires { after_spikes } = self.prune {
            if after_spikes == 0 {
                return Err(Error::InvalidConfig(
                    "prune after_spikes must be >= 1 (0 would disable neurons \
                     before they ever fire)"
                        .into(),
                ));
            }
        }
        if !self.layer_params.is_empty() && self.layer_params.len() != self.n_layers() {
            return Err(Error::InvalidConfig(format!(
                "layer_params carries {} entries for a {}-layer topology \
                 (must be empty or one per weight layer)",
                self.layer_params.len(),
                self.n_layers()
            )));
        }
        for l in 0..self.n_layers() {
            let v = self.layer_v_th(l);
            if v <= self.v_rest {
                return Err(Error::InvalidConfig(format!(
                    "layer {l} v_th ({v}) must exceed v_rest ({})",
                    self.v_rest
                )));
            }
            if v > self.acc_max() {
                return Err(Error::InvalidConfig(format!(
                    "layer {l} v_th ({v}) exceeds accumulator saturation ({})",
                    self.acc_max()
                )));
            }
            let d = self.layer_decay_shift(l);
            if d == 0 || d > 30 {
                return Err(Error::InvalidConfig(format!(
                    "layer {l} decay_shift {d} outside supported range 1..=30"
                )));
            }
            if let PruneMode::AfterFires { after_spikes: 0 } = self.layer_prune(l) {
                return Err(Error::InvalidConfig(format!(
                    "layer {l} prune after_spikes must be >= 1"
                )));
            }
        }
        Ok(self)
    }

    /// Builder-style setters (used pervasively by experiments/ablations).
    pub fn with_topology(mut self, t: Vec<usize>) -> Self {
        self.topology = t;
        self
    }
    pub fn with_timesteps(mut self, t: u32) -> Self {
        self.timesteps = t;
        self
    }
    pub fn with_v_th(mut self, v: i32) -> Self {
        self.v_th = v;
        self
    }
    pub fn with_decay_shift(mut self, n: u32) -> Self {
        self.decay_shift = n;
        self
    }
    pub fn with_prune(mut self, p: PruneMode) -> Self {
        self.prune = p;
        self
    }
    pub fn with_fire_mode(mut self, m: FireMode) -> Self {
        self.fire_mode = m;
        self
    }
    pub fn with_leak_mode(mut self, m: LeakMode) -> Self {
        self.leak_mode = m;
        self
    }
    pub fn with_decision(mut self, d: DecisionPolicy) -> Self {
        self.decision = d;
        self
    }
    pub fn with_layer_params(mut self, p: Vec<LayerParams>) -> Self {
        self.layer_params = p;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_is_valid() {
        let c = SnnConfig::paper().validated().unwrap();
        assert_eq!(c.n_inputs(), 784);
        assert_eq!(c.n_outputs(), 10);
        assert_eq!(c.n_layers(), 1);
        assert_eq!(c.v_th, 128);
        assert_eq!(c.weight_storage_bits(), 784 * 10 * 9);
        // Paper: "~8.6 KB"
        let kb = c.weight_storage_bits() as f64 / 8.0 / 1024.0;
        assert!((kb - 8.61).abs() < 0.02, "weight storage {kb} KB");
    }

    #[test]
    fn layered_topology_accessors() {
        let c = SnnConfig::paper().with_topology(vec![784, 128, 10]).validated().unwrap();
        assert_eq!(c.n_layers(), 2);
        assert_eq!(c.n_inputs(), 784);
        assert_eq!(c.n_outputs(), 10);
        assert_eq!((c.layer_input(0), c.layer_output(0)), (784, 128));
        assert_eq!((c.layer_input(1), c.layer_output(1)), (128, 10));
        assert_eq!(c.weight_storage_bits(), (784 * 128 + 128 * 10) * 9);
        let l0 = c.layer_config(0);
        assert_eq!(l0.topology, vec![784, 128]);
        assert_eq!(l0.v_th, c.v_th);
        let l1 = c.layer_config(1);
        assert_eq!(l1.topology, vec![128, 10]);
    }

    #[test]
    fn saturation_bounds() {
        let c = SnnConfig::paper();
        assert_eq!(c.acc_max(), (1 << 23) - 1);
        assert_eq!(c.acc_min(), -((1 << 23) - 1));
        assert_eq!(c.weight_max(), 255);
        assert_eq!(c.weight_min(), -256);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(SnnConfig { topology: vec![0, 10], ..SnnConfig::paper() }.validated().is_err());
        assert!(SnnConfig { topology: vec![784], ..SnnConfig::paper() }.validated().is_err());
        assert!(SnnConfig { topology: vec![784, 0, 10], ..SnnConfig::paper() }
            .validated()
            .is_err());
        assert!(SnnConfig { decay_shift: 0, ..SnnConfig::paper() }.validated().is_err());
        assert!(SnnConfig { v_th: 0, ..SnnConfig::paper() }.validated().is_err());
        assert!(SnnConfig { v_th: 1 << 30, acc_bits: 24, ..SnnConfig::paper() }
            .validated()
            .is_err());
        assert!(SnnConfig { timesteps: 0, ..SnnConfig::paper() }.validated().is_err());
        assert!(SnnConfig {
            leak_mode: LeakMode::PerRow { row_len: 0 },
            ..SnnConfig::paper()
        }
        .validated()
        .is_err());
        assert!(SnnConfig {
            prune: PruneMode::AfterFires { after_spikes: 0 },
            ..SnnConfig::paper()
        }
        .validated()
        .is_err());
        assert!(SnnConfig { acc_bits: 32, ..SnnConfig::paper() }.validated().is_err());
    }

    #[test]
    fn layer_params_resolve_with_scalar_fallback() {
        let c = SnnConfig::paper()
            .with_topology(vec![784, 16, 10])
            .with_layer_params(vec![
                LayerParams { v_th: Some(300), decay_shift: None, prune: Some(PruneMode::Off) },
                LayerParams { v_th: None, decay_shift: Some(5), prune: None },
            ])
            .validated()
            .unwrap();
        assert_eq!(c.layer_v_th(0), 300);
        assert_eq!(c.layer_v_th(1), c.v_th, "missing field inherits the scalar");
        assert_eq!(c.layer_decay_shift(0), c.decay_shift);
        assert_eq!(c.layer_decay_shift(1), 5);
        assert_eq!(c.layer_prune(0), PruneMode::Off);
        assert_eq!(c.layer_prune(1), c.prune);
        // layer_config writes the resolved values into the scalar slots.
        let l0 = c.layer_config(0);
        assert_eq!(l0.v_th, 300);
        assert_eq!(l0.prune, PruneMode::Off);
        assert!(l0.layer_params.is_empty());
        let l1 = c.layer_config(1);
        assert_eq!(l1.v_th, c.v_th);
        assert_eq!(l1.decay_shift, 5);
    }

    #[test]
    fn empty_layer_params_is_bit_identical_default() {
        // The shared-parameter core resolves to the scalars everywhere.
        let c = SnnConfig::paper();
        assert!(c.layer_params.is_empty());
        assert_eq!(c.layer_v_th(0), 128);
        assert_eq!(c.layer_decay_shift(0), 3);
        assert_eq!(c.layer_prune(0), PruneMode::AfterFires { after_spikes: 1 });
        assert_eq!(c.layer_config(0), SnnConfig::paper());
    }

    #[test]
    fn layer_params_are_validated() {
        // Wrong arity.
        assert!(SnnConfig::paper()
            .with_layer_params(vec![LayerParams::default(), LayerParams::default()])
            .validated()
            .is_err());
        // Per-layer v_th below rest / above saturation.
        assert!(SnnConfig::paper()
            .with_layer_params(vec![LayerParams::with_v_th(0)])
            .validated()
            .is_err());
        assert!(SnnConfig::paper()
            .with_layer_params(vec![LayerParams::with_v_th(1 << 24)])
            .validated()
            .is_err());
        // Per-layer decay/prune out of range.
        assert!(SnnConfig::paper()
            .with_layer_params(vec![LayerParams {
                decay_shift: Some(0),
                ..Default::default()
            }])
            .validated()
            .is_err());
        assert!(SnnConfig::paper()
            .with_layer_params(vec![LayerParams {
                prune: Some(PruneMode::AfterFires { after_spikes: 0 }),
                ..Default::default()
            }])
            .validated()
            .is_err());
        // A full, in-range override list passes.
        assert!(SnnConfig::paper()
            .with_layer_params(vec![LayerParams::with_v_th(200)])
            .validated()
            .is_ok());
    }

    #[test]
    fn margin_cap_follows_output_layer_prune() {
        let c = SnnConfig::paper();
        assert_eq!(c.max_reachable_margin(), Some(1), "paper prunes after one fire");
        assert_eq!(c.clone().with_prune(PruneMode::Off).max_reachable_margin(), None);
        let prune_at = |n: u32| LayerParams {
            prune: Some(PruneMode::AfterFires { after_spikes: n }),
            ..Default::default()
        };
        let prune_off = LayerParams { prune: Some(PruneMode::Off), ..Default::default() };
        // Per-layer: aggressive hidden pruning, readout intact → unbounded.
        let c = SnnConfig::paper()
            .with_topology(vec![784, 16, 10])
            .with_layer_params(vec![prune_at(1), prune_off]);
        assert_eq!(c.max_reachable_margin(), None);
        // And the converse: readout pruned at 3 caps the margin at 3.
        let c = SnnConfig::paper()
            .with_topology(vec![784, 16, 10])
            .with_layer_params(vec![prune_off, prune_at(3)]);
        assert_eq!(c.max_reachable_margin(), Some(3));
    }

    #[test]
    fn builders_compose() {
        let c = SnnConfig::paper()
            .with_topology(vec![784, 32, 10])
            .with_timesteps(5)
            .with_v_th(200)
            .with_decay_shift(4)
            .with_prune(PruneMode::Off)
            .with_fire_mode(FireMode::Immediate)
            .with_decision(DecisionPolicy::FirstSpike)
            .validated()
            .unwrap();
        assert_eq!(c.topology, vec![784, 32, 10]);
        assert_eq!(c.timesteps, 5);
        assert_eq!(c.v_th, 200);
        assert_eq!(c.decay_shift, 4);
        assert_eq!(c.prune, PruneMode::Off);
        assert_eq!(c.fire_mode, FireMode::Immediate);
        assert_eq!(c.decision, DecisionPolicy::FirstSpike);
    }
}
