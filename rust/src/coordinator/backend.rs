//! Inference backends the coordinator can schedule onto.
//!
//! | backend | substrate | early exit | use |
//! |---|---|---|---|
//! | [`BehavioralBackend`] | pure-Rust golden model | per-timestep | exactness + speed |
//! | [`RtlBackend`] | RTL core (fast-path engine) | full window | cycle/energy accounting |
//! | [`XlaBackend`] | AOT JAX/Pallas via PJRT | per-chunk | the compiled L2/L1 stack |
//!
//! All three implement the same architectural contract, so the coordinator
//! (and the equivalence tests) can swap them freely.
//!
//! Concurrency: the behavioral and RTL backends keep their stateful
//! engines in an [`InstancePool`] — each `classify_batch` checks a private
//! instance out for the duration of the batch, so worker threads fan out
//! instead of serializing on one shared `Mutex` (see `pool.rs`). The
//! coordinator's intra-batch fan-out relies on exactly this: each
//! sub-batch of a split batch calls `classify_batch` concurrently and
//! draws its own engine, so one large request burst spreads across the
//! pool. The XLA backend still serializes (PJRT handles are `Send` but
//! not `Sync`).

use std::sync::Mutex;

use crate::config::SnnConfig;
use crate::data::Image;
use crate::error::Result;
use crate::fixed::WeightMatrix;
use crate::rtl::RtlCore;
use crate::runtime::XlaSnn;
use crate::snn::{BehavioralNet, EarlyExit, LifLayer};
use crate::util::priority_argmax;

use super::pool::{default_pool_slots, InstancePool};

/// Per-image inference output, backend-agnostic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BackendOutput {
    /// Predicted class (priority-encoded argmax of spike counts).
    pub class: u8,
    /// Output spike counts.
    pub spike_counts: Vec<u32>,
    /// Timesteps actually executed.
    pub steps_run: u32,
}

/// A batched classification backend. Implementations must be `Send + Sync`
/// (shared by the worker pool).
pub trait Backend: Send + Sync {
    /// Human-readable backend name (metrics, logs).
    fn name(&self) -> &'static str;

    /// Classify a batch. `seeds[i]` drives image `i`'s encoder stream.
    /// `early` is a hint: backends that cannot early-exit run the full
    /// window (still correct — early exit only trades compute).
    fn classify_batch(
        &self,
        images: &[&Image],
        seeds: &[u32],
        early: EarlyExit,
    ) -> Result<Vec<BackendOutput>>;

    /// Whether concurrent `classify_batch` calls actually run in parallel
    /// (pooled engines). The coordinator only fans a large batch out when
    /// this is true — splitting work across a backend that serializes
    /// internally (the XLA mutex) would add thread dispatch and padding
    /// waste for zero overlap.
    fn parallel_capable(&self) -> bool {
        true
    }

    /// The architectural config this backend runs.
    fn config(&self) -> &SnnConfig;
}

// ---------------------------------------------------------------------------

/// The behavioral golden model as a backend (per-image, early-exit
/// capable). Worker threads check reusable [`LifLayer`] instances out of a
/// pool, so concurrent batches neither serialize nor clone layer state per
/// request.
pub struct BehavioralBackend {
    net: BehavioralNet,
    layers: InstancePool<LifLayer>,
}

impl BehavioralBackend {
    pub fn new(cfg: SnnConfig, weights: WeightMatrix) -> Result<Self> {
        let net = BehavioralNet::new(cfg, weights)?;
        let proto = net.layer_prototype();
        let layers = InstancePool::new(default_pool_slots(), move || proto.clone());
        Ok(BehavioralBackend { net, layers })
    }
}

impl Backend for BehavioralBackend {
    fn name(&self) -> &'static str {
        "behavioral"
    }

    fn classify_batch(
        &self,
        images: &[&Image],
        seeds: &[u32],
        early: EarlyExit,
    ) -> Result<Vec<BackendOutput>> {
        let t = self.net.config().timesteps;
        let mut layer = self.layers.checkout();
        Ok(images
            .iter()
            .zip(seeds)
            .map(|(img, &seed)| {
                let c = self.net.classify_with(&mut layer, img, seed, t, early);
                BackendOutput {
                    class: c.class,
                    spike_counts: c.spike_counts,
                    steps_run: c.steps_run,
                }
            })
            .collect())
    }

    fn config(&self) -> &SnnConfig {
        self.net.config()
    }
}

// ---------------------------------------------------------------------------

/// The RTL core as a backend, running the batched-timestep fast path
/// ([`RtlCore::run_fast`] — bit-exact with the cycle engine by property
/// test). Each worker's batch checks a private core out of the pool, so
/// cycle-accounted serving scales with the coordinator's worker count
/// instead of serializing on a single simulator instance.
pub struct RtlBackend {
    cores: InstancePool<RtlCore>,
    cfg: SnnConfig,
}

impl RtlBackend {
    pub fn new(cfg: SnnConfig, weights: WeightMatrix) -> Result<Self> {
        // Validate geometry/config once, up front, so the pool factory
        // cannot fail later.
        RtlCore::new(cfg.clone(), weights.clone())?;
        let factory_cfg = cfg.clone();
        let cores = InstancePool::new(default_pool_slots(), move || {
            RtlCore::new(factory_cfg.clone(), weights.clone())
                .expect("validated at RtlBackend::new")
        });
        Ok(RtlBackend { cores, cfg })
    }

    /// Total cycles burned so far across the pooled cores (experiment
    /// observability). Overflow instances are recycled through the pool's
    /// stash and counted once released; only cores currently mid-batch or
    /// dropped past the stash cap are missed.
    pub fn total_cycles(&self) -> u64 {
        let mut total = 0u64;
        self.cores.for_each(|core| total += core.total_activity().cycles);
        total
    }
}

impl Backend for RtlBackend {
    fn name(&self) -> &'static str {
        "rtl"
    }

    fn classify_batch(
        &self,
        images: &[&Image],
        seeds: &[u32],
        _early: EarlyExit,
    ) -> Result<Vec<BackendOutput>> {
        let mut core = self.cores.checkout();
        images
            .iter()
            .zip(seeds)
            .map(|(img, &seed)| {
                let r = core.run_fast(img, seed)?;
                Ok(BackendOutput {
                    class: r.class,
                    spike_counts: r.spike_counts,
                    steps_run: self.cfg.timesteps,
                })
            })
            .collect()
    }

    fn config(&self) -> &SnnConfig {
        &self.cfg
    }
}

// ---------------------------------------------------------------------------

/// The compiled JAX/Pallas stack as a backend. Uses the full-window
/// executables when `early` is off and the chunked executable + margin
/// check when it is on.
///
/// `XlaSnn` is `Send` but not `Sync` (PJRT handles), so it sits behind a
/// mutex; run more coordinator workers for parallelism across cores.
pub struct XlaBackend {
    snn: Mutex<XlaSnn>,
    cfg: SnnConfig,
}

impl XlaBackend {
    pub fn new(snn: XlaSnn) -> Self {
        let cfg = snn.config().clone();
        XlaBackend { snn: Mutex::new(snn), cfg }
    }

    fn classify_chunked(
        &self,
        snn: &XlaSnn,
        images: &[&Image],
        seeds: &[u32],
        margin: u32,
        min_steps: u32,
    ) -> Result<Vec<BackendOutput>> {
        let cap = snn.chunk_batch();
        let window = snn.config().timesteps;
        let mut out = Vec::with_capacity(images.len());
        for (imgs, sds) in images.chunks(cap).zip(seeds.chunks(cap)) {
            let mut st = snn.chunk_start(imgs, sds)?;
            let mut counts = snn.chunk_advance(&mut st)?;
            while st.steps_run < window {
                if st.steps_run >= min_steps && all_confident(&counts, margin) {
                    break;
                }
                counts = snn.chunk_advance(&mut st)?;
            }
            for c in counts {
                out.push(BackendOutput {
                    class: priority_argmax(&c) as u8,
                    spike_counts: c,
                    steps_run: st.steps_run,
                });
            }
        }
        Ok(out)
    }
}

/// True when every row's leader beats its runner-up by `margin`.
fn all_confident(counts: &[Vec<u32>], margin: u32) -> bool {
    counts.iter().all(|row| {
        let mut sorted = row.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        sorted[0] >= sorted[1] + margin
    })
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    /// Sub-batches would serialize on the PJRT mutex *and* pad each chunk
    /// up to a compiled batch size — strictly worse than one big call.
    fn parallel_capable(&self) -> bool {
        false
    }

    fn classify_batch(
        &self,
        images: &[&Image],
        seeds: &[u32],
        early: EarlyExit,
    ) -> Result<Vec<BackendOutput>> {
        let snn = self.snn.lock().unwrap();
        match early {
            EarlyExit::Margin { margin, min_steps } => {
                self.classify_chunked(&snn, images, seeds, margin, min_steps)
            }
            EarlyExit::Off => {
                let window = snn.config().timesteps;
                Ok(snn
                    .spike_counts(images, seeds)?
                    .into_iter()
                    .map(|c| BackendOutput {
                        class: priority_argmax(&c) as u8,
                        spike_counts: c,
                        steps_run: window,
                    })
                    .collect())
            }
        }
    }

    fn config(&self) -> &SnnConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DigitGen;
    use std::sync::Arc;

    fn test_weights() -> WeightMatrix {
        let mut w = vec![0i32; 784 * 10];
        for i in 0..784 {
            let block = i / 79;
            if block < 10 {
                w[i * 10 + block] = 40;
            }
        }
        WeightMatrix::from_rows(784, 10, 9, w).unwrap()
    }

    #[test]
    fn behavioral_and_rtl_backends_agree() {
        let cfg = SnnConfig::paper().with_timesteps(4);
        let beh = BehavioralBackend::new(cfg.clone(), test_weights()).unwrap();
        let rtl = RtlBackend::new(cfg, test_weights()).unwrap();
        let gen = DigitGen::new(5);
        let images: Vec<Image> = (0..6).map(|i| gen.sample(i as u8, i)).collect();
        let refs: Vec<&Image> = images.iter().collect();
        let seeds: Vec<u32> = (0..6).map(|i| 100 + i).collect();
        let a = beh.classify_batch(&refs, &seeds, EarlyExit::Off).unwrap();
        let b = rtl.classify_batch(&refs, &seeds, EarlyExit::Off).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.class, y.class);
            assert_eq!(x.spike_counts, y.spike_counts);
        }
        assert!(rtl.total_cycles() > 0);
    }

    #[test]
    fn concurrent_batches_do_not_serialize_or_corrupt() {
        // Hammer both pooled backends from many threads; every response
        // must match the single-threaded answer for its (image, seed).
        let cfg = SnnConfig::paper().with_timesteps(4);
        let beh = Arc::new(BehavioralBackend::new(cfg.clone(), test_weights()).unwrap());
        let rtl = Arc::new(RtlBackend::new(cfg, test_weights()).unwrap());
        let gen = DigitGen::new(9);
        let images: Arc<Vec<Image>> =
            Arc::new((0..10).map(|i| gen.sample(i as u8, i)).collect());
        let expected: Vec<BackendOutput> = {
            let refs: Vec<&Image> = images.iter().collect();
            let seeds: Vec<u32> = (0..10).map(|i| 700 + i).collect();
            beh.classify_batch(&refs, &seeds, EarlyExit::Off).unwrap()
        };

        let mut handles = Vec::new();
        for _ in 0..6 {
            let beh = Arc::clone(&beh);
            let rtl = Arc::clone(&rtl);
            let images = Arc::clone(&images);
            let expected = expected.clone();
            handles.push(std::thread::spawn(move || {
                for round in 0..8 {
                    let i = round % images.len();
                    let seed = 700 + i as u32;
                    let a = beh
                        .classify_batch(&[&images[i]], &[seed], EarlyExit::Off)
                        .unwrap();
                    let b = rtl
                        .classify_batch(&[&images[i]], &[seed], EarlyExit::Off)
                        .unwrap();
                    assert_eq!(a[0], expected[i], "behavioral diverged under load");
                    assert_eq!(b[0].class, expected[i].class);
                    assert_eq!(b[0].spike_counts, expected[i].spike_counts);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn confidence_check() {
        assert!(all_confident(&[vec![5, 1, 0], vec![4, 0, 0]], 3));
        assert!(!all_confident(&[vec![5, 4, 0]], 3));
        assert!(all_confident(&[], 3));
    }
}
