//! Inference backends the coordinator can schedule onto.
//!
//! | backend | substrate | early exit | batch dimension | use |
//! |---|---|---|---|---|
//! | [`BehavioralBackend`] | batched golden model ([`LifBatchStack`]) | per-timestep, per-image | one `step_batch` sweep per timestep | exactness + speed |
//! | [`RtlBackend`] | RTL core batch engine ([`RtlCore::run_fast_batch`]) | per-timestep, per-image | one row walk serves the sub-batch | cycle/energy accounting |
//! | [`XlaBackend`] | AOT JAX/Pallas via PJRT | per-chunk | compiled batch dim (padded chunks) | the compiled L2/L1 stack |
//!
//! All three implement the same architectural contract, so the coordinator
//! (and the equivalence tests) can swap them freely. Backends are built
//! from a [`WeightStack`], so any `SnnConfig::topology` depth serves —
//! a bare [`WeightMatrix`] converts into the single-layer chain.
//!
//! The batch dimension survives the engine boundary: `classify_batch`
//! hands the **whole sub-batch to one engine call**, which runs one
//! timestep sweep for all of its images (each weight row fetched once per
//! timestep, applied to every image whose input fired) instead of a
//! per-image loop. Results are bit-exact with the sequential engines
//! image for image — per-`(image, seed)` PRNG streams commute with
//! batching (EXPERIMENTS.md §Batch).
//!
//! Concurrency: the behavioral and RTL backends keep their stateful
//! engines in an [`InstancePool`] — each `classify_batch` checks a private
//! instance out for the duration of the batch, so worker threads fan out
//! instead of serializing on one shared `Mutex` (see `pool.rs`). The
//! coordinator's intra-batch fan-out relies on exactly this: each
//! sub-batch of a split batch calls `classify_batch` concurrently and
//! draws its own engine, so one large request burst spreads across the
//! pool — [`crate::coordinator::FanoutPolicy`] remains the *outer*
//! parallelism tier above the engines' inner batch dimension. The XLA
//! backend still serializes (PJRT handles are `Send` but not `Sync`).

use std::sync::{Arc, Mutex};

use crate::config::SnnConfig;
use crate::data::Image;
use crate::error::Result;
use crate::fixed::{WeightMatrix, WeightStack};
use crate::rtl::{ActivityCounters, RtlCore};
use crate::runtime::XlaSnn;
use crate::snn::{BehavioralNet, EarlyExit, LifBatchStack};
use crate::util::{lock_recover, margin_reached, priority_argmax};

use super::pool::{default_pool_slots, InstancePool};

/// Per-image inference output, backend-agnostic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BackendOutput {
    /// Predicted class (priority-encoded argmax of spike counts).
    pub class: u8,
    /// Output spike counts.
    pub spike_counts: Vec<u32>,
    /// Timesteps actually executed.
    pub steps_run: u32,
}

/// A batched classification backend. Implementations must be `Send + Sync`
/// (shared by the worker pool).
pub trait Backend: Send + Sync {
    /// Human-readable backend name (metrics, logs).
    fn name(&self) -> &'static str;

    /// Classify a batch. `seeds[i]` drives image `i`'s encoder stream.
    /// `early` is a hint: backends that cannot early-exit run the full
    /// window (still correct — early exit only trades compute).
    fn classify_batch(
        &self,
        images: &[&Image],
        seeds: &[u32],
        early: EarlyExit,
    ) -> Result<Vec<BackendOutput>>;

    /// Whether concurrent `classify_batch` calls actually run in parallel
    /// (pooled engines). The coordinator only fans a large batch out when
    /// this is true — splitting work across a backend that serializes
    /// internally (the XLA mutex) would add thread dispatch and padding
    /// waste for zero overlap.
    fn parallel_capable(&self) -> bool {
        true
    }

    /// The architectural config this backend runs.
    fn config(&self) -> &SnnConfig;

    /// Engines this backend has quarantined (discarded as possibly-torn
    /// after an error or panic) and rebuilt from its factory. Backends
    /// without pooled engines report 0. The coordinator mirrors this into
    /// `ServerMetrics::quarantined_engines` after every batch.
    fn quarantined_engines(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------------

/// The behavioral golden model as a backend (batched, early-exit
/// capable). Worker threads check reusable [`LifBatchStack`] instances
/// out of a pool and hand each whole sub-batch to **one**
/// [`BehavioralNet::classify_batch_with`] engine pass, so concurrent
/// batches neither serialize nor degrade to a per-image loop at the
/// engine boundary.
pub struct BehavioralBackend {
    net: BehavioralNet,
    stacks: InstancePool<LifBatchStack>,
}

impl BehavioralBackend {
    pub fn new(cfg: SnnConfig, weights: impl Into<WeightStack>) -> Result<Self> {
        let net = BehavioralNet::new(cfg, weights)?;
        let proto = net.batch_prototype();
        let stacks = InstancePool::new(default_pool_slots(), move || proto.clone());
        Ok(BehavioralBackend { net, stacks })
    }
}

impl Backend for BehavioralBackend {
    fn name(&self) -> &'static str {
        "behavioral"
    }

    fn classify_batch(
        &self,
        images: &[&Image],
        seeds: &[u32],
        early: EarlyExit,
    ) -> Result<Vec<BackendOutput>> {
        let t = self.net.config().timesteps;
        let mut stack = self.stacks.checkout();
        match self.net.classify_batch_with(&mut stack, images, seeds, t, early) {
            Ok(results) => Ok(results
                .into_iter()
                .map(|c| BackendOutput {
                    class: c.class,
                    spike_counts: c.spike_counts,
                    steps_run: c.steps_run,
                })
                .collect()),
            Err(e) => {
                // The stack may hold partial membrane/PRNG state from the
                // failed pass; quarantine it rather than serve from it.
                stack.discard();
                Err(e)
            }
        }
    }

    fn config(&self) -> &SnnConfig {
        self.net.config()
    }

    fn quarantined_engines(&self) -> u64 {
        self.stacks.quarantined()
    }
}

// ---------------------------------------------------------------------------

/// The RTL core as a backend, running the batch-parallel fast path
/// ([`RtlCore::run_fast_batch`] — bit-exact with the sequential fast
/// path image for image, itself bit-exact with the cycle engine, with
/// the serving-level margin policy applied between timesteps per image).
/// Each worker's batch checks a private core out of the pool and runs its
/// whole sub-batch through one timestep sweep, so cycle-accounted serving
/// scales with the coordinator's worker count *and* amortizes every
/// weight-row fetch over the sub-batch.
pub struct RtlBackend {
    cores: InstancePool<RtlCore>,
    cfg: SnnConfig,
    /// Activity harvested from cores the pool dropped (overflow past the
    /// stash cap, poisoned slots). Folded into [`RtlBackend::total_cycles`]
    /// so accounting stays exact under fan-out bursts.
    evicted: Arc<Mutex<ActivityCounters>>,
    /// CSR density of the attached sparse image, when one was built
    /// ([`RtlBackend::with_sparse`]); `None` = dense-only backend.
    sparse_density: Option<f64>,
    /// Whether batches route to the event-driven sparse sweep (density at
    /// or below [`SPARSE_DENSITY_CROSSOVER`]).
    serve_sparse: bool,
}

/// Density at which the event-driven sparse sweep overtakes the dense row
/// walk. The dense engine does `n_out` adds per active input row
/// regardless of weights; the sparse sweep does `nnz(row)` adds plus
/// per-entry indexing overhead (an index load and an indirect write per
/// entry, versus the dense walk's streaming access) — roughly 2× the
/// per-entry cost, putting break-even near half density. Measured on the
/// bench harness (BENCH_7 `density_crossover`) the observed crossover sits
/// between 0.5 and 1.0 depending on topology; 0.5 is the conservative
/// choice, guaranteeing the sparse route is never slower.
pub const SPARSE_DENSITY_CROSSOVER: f64 = 0.5;

impl RtlBackend {
    pub fn new(cfg: SnnConfig, weights: impl Into<WeightStack>) -> Result<Self> {
        Self::with_slots(cfg, weights, default_pool_slots())
    }

    /// Build with an explicit pool size (tests pin eviction behaviour;
    /// production uses [`RtlBackend::new`]'s per-core default).
    pub fn with_slots(
        cfg: SnnConfig,
        weights: impl Into<WeightStack>,
        slots: usize,
    ) -> Result<Self> {
        Self::build(cfg, weights.into(), slots, None)
    }

    /// Build with a sparse calibration (an SNNW v4 artifact's magnitude
    /// threshold): the CSR image is derived once, its density decides the
    /// serving route — at or below [`SPARSE_DENSITY_CROSSOVER`] every
    /// pooled core carries the CSR and batches run the event-driven
    /// sparse sweep; above it the dense row walk stays (the CSR would win
    /// nothing), and only the density measurement is kept.
    pub fn with_sparse(
        cfg: SnnConfig,
        weights: impl Into<WeightStack>,
        threshold: i32,
    ) -> Result<Self> {
        Self::with_sparse_slots(cfg, weights, threshold, default_pool_slots())
    }

    /// [`RtlBackend::with_sparse`] with an explicit pool size.
    pub fn with_sparse_slots(
        cfg: SnnConfig,
        weights: impl Into<WeightStack>,
        threshold: i32,
        slots: usize,
    ) -> Result<Self> {
        Self::build(cfg, weights.into(), slots, Some(threshold))
    }

    fn build(
        cfg: SnnConfig,
        weights: WeightStack,
        slots: usize,
        sparse_threshold: Option<i32>,
    ) -> Result<Self> {
        // Validate geometry/config once, up front, so the pool factory
        // cannot fail later.
        RtlCore::new(cfg.clone(), weights.clone())?;
        let csr = sparse_threshold.map(|t| weights.to_csr(t));
        let sparse_density = csr.as_ref().map(crate::fixed::SparseWeightStack::density);
        let serve_sparse = sparse_density.map_or(false, |d| d <= SPARSE_DENSITY_CROSSOVER);
        let attach = if serve_sparse { csr } else { None };
        let factory_cfg = cfg.clone();
        let evicted = Arc::new(Mutex::new(ActivityCounters::default()));
        let sink = Arc::clone(&evicted);
        let cores = InstancePool::new(slots, move || {
            let mut core = RtlCore::new(factory_cfg.clone(), weights.clone())
                .expect("validated at RtlBackend::build");
            if let Some(csr) = &attach {
                core.attach_sparse_stack(csr.clone())
                    .expect("CSR derived from this core's own stack");
            }
            core
        })
        .with_evict_hook(move |core: &mut RtlCore| {
            // Poison-recovering: the harvested totals are plain counters
            // and must survive a panicking thread, or cycle accounting
            // silently loses the dying core's activity. The pool may run
            // this hook while one of its slot guards is held (quarantine
            // paths), so this acquisition is a declared leaf of the lock
            // graph: it must never take a pool or shard lock itself.
            // pallas-lint: lock(backend.evict_sink)
            lock_recover(&sink).add(&core.total_activity());
            // pallas-lint: end-lock(backend.evict_sink)
        });
        Ok(RtlBackend { cores, cfg, evicted, sparse_density, serve_sparse })
    }

    /// CSR density of the sparse calibration, when one was supplied.
    pub fn sparse_density(&self) -> Option<f64> {
        self.sparse_density
    }

    /// True when batches route to the event-driven sparse sweep.
    pub fn serves_sparse(&self) -> bool {
        self.serve_sparse
    }

    /// Total activity burned so far across every core this backend ever
    /// ran: the live pool (slots + recycled stash) plus everything
    /// harvested from dropped cores by the eviction hook. Exact once all
    /// in-flight batches have returned their engines.
    pub fn total_activity(&self) -> ActivityCounters {
        // pallas-lint: lock(backend.evict_sink)
        let mut total = *lock_recover(&self.evicted);
        // pallas-lint: end-lock(backend.evict_sink)
        self.cores.for_each(|core| total.add(&core.total_activity()));
        total
    }

    /// Total cycles burned so far (see [`RtlBackend::total_activity`]).
    pub fn total_cycles(&self) -> u64 {
        self.total_activity().cycles
    }
}

impl Backend for RtlBackend {
    fn name(&self) -> &'static str {
        "rtl"
    }

    fn classify_batch(
        &self,
        images: &[&Image],
        seeds: &[u32],
        early: EarlyExit,
    ) -> Result<Vec<BackendOutput>> {
        let mut core = self.cores.checkout();
        let run = if self.serve_sparse {
            core.run_fast_batch_sparse(images, seeds, early)
        } else {
            core.run_fast_batch(images, seeds, early)
        };
        match run {
            Ok(results) => Ok(results
                .into_iter()
                .map(|r| BackendOutput {
                    class: r.class,
                    steps_run: r.membrane_by_step.len() as u32,
                    spike_counts: r.spike_counts,
                })
                .collect()),
            Err(e) => {
                // Quarantine the core: the failed run may have advanced
                // membranes/PRNGs partway. The evict hook harvests its
                // cycle counters first, so accounting stays exact.
                core.discard();
                Err(e)
            }
        }
    }

    fn config(&self) -> &SnnConfig {
        &self.cfg
    }

    fn quarantined_engines(&self) -> u64 {
        self.cores.quarantined()
    }
}

// ---------------------------------------------------------------------------

/// The compiled JAX/Pallas stack as a backend. Uses the full-window
/// executables when `early` is off and the chunked executable + margin
/// check when it is on.
///
/// `XlaSnn` is `Send` but not `Sync` (PJRT handles), so it sits behind a
/// mutex; run more coordinator workers for parallelism across cores.
pub struct XlaBackend {
    snn: Mutex<XlaSnn>,
    cfg: SnnConfig,
}

impl XlaBackend {
    pub fn new(snn: XlaSnn) -> Self {
        let cfg = snn.config().clone();
        XlaBackend { snn: Mutex::new(snn), cfg }
    }

    fn classify_chunked(
        &self,
        snn: &XlaSnn,
        images: &[&Image],
        seeds: &[u32],
        margin: u32,
        min_steps: u32,
    ) -> Result<Vec<BackendOutput>> {
        let cap = snn.chunk_batch();
        let window = snn.config().timesteps;
        let mut out = Vec::with_capacity(images.len());
        for (imgs, sds) in images.chunks(cap).zip(seeds.chunks(cap)) {
            let mut st = snn.chunk_start(imgs, sds)?;
            let mut counts = snn.chunk_advance(&mut st)?;
            while st.steps_run < window {
                if st.steps_run >= min_steps && all_confident(&counts, margin) {
                    break;
                }
                counts = snn.chunk_advance(&mut st)?;
            }
            for c in counts {
                out.push(BackendOutput {
                    class: priority_argmax(&c) as u8,
                    spike_counts: c,
                    steps_run: st.steps_run,
                });
            }
        }
        Ok(out)
    }
}

/// True when every row's leader beats its runner-up by `margin` — the
/// batched form of the one shared margin predicate
/// ([`crate::util::margin_reached`]), so all three backends apply the
/// identical rule (including "no runner-up is never confident").
fn all_confident(counts: &[Vec<u32>], margin: u32) -> bool {
    counts.iter().all(|row| margin_reached(row, margin))
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    /// Sub-batches would serialize on the PJRT mutex *and* pad each chunk
    /// up to a compiled batch size — strictly worse than one big call.
    fn parallel_capable(&self) -> bool {
        false
    }

    fn classify_batch(
        &self,
        images: &[&Image],
        seeds: &[u32],
        early: EarlyExit,
    ) -> Result<Vec<BackendOutput>> {
        // Poison-recovering: a panic elsewhere must not cascade through
        // every subsequent XLA request. `XlaSnn` holds opaque PJRT
        // executables and buffers that a Rust unwind cannot tear (no
        // internal invariants are mutated mid-call from this side), so
        // recovering the guard is sound.
        // pallas-lint: lock(backend.xla_snn)
        let snn = lock_recover(&self.snn);
        // Behavioral/RTL engines clamp internally; the chunked XLA loop
        // applies the same clamp here so an unreachable margin cannot
        // silently run every chunk to the full window.
        match early.clamped_for(&self.cfg) {
            EarlyExit::Margin { margin, min_steps } => {
                self.classify_chunked(&snn, images, seeds, margin, min_steps)
            }
            EarlyExit::Off => {
                let window = snn.config().timesteps;
                Ok(snn
                    .spike_counts(images, seeds)?
                    .into_iter()
                    .map(|c| BackendOutput {
                        class: priority_argmax(&c) as u8,
                        spike_counts: c,
                        steps_run: window,
                    })
                    .collect())
            }
        }
        // pallas-lint: end-lock(backend.xla_snn)
    }

    fn config(&self) -> &SnnConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PruneMode;
    use crate::data::DigitGen;
    use std::sync::Arc;

    fn test_weights() -> WeightMatrix {
        let mut w = vec![0i32; 784 * 10];
        for i in 0..784 {
            let block = i / 79;
            if block < 10 {
                w[i * 10 + block] = 40;
            }
        }
        WeightMatrix::from_rows(784, 10, 9, w).unwrap()
    }

    /// A crisp 784→20→10 stack (same block structure as `test_weights`
    /// routed through hidden pairs).
    fn test_stack() -> WeightStack {
        let mut w1 = vec![0i32; 784 * 20];
        for i in 0..784 {
            let block = i / 79;
            if block < 10 {
                w1[i * 20 + 2 * block] = 40;
                w1[i * 20 + 2 * block + 1] = 40;
            }
        }
        let mut w2 = vec![0i32; 20 * 10];
        for h in 0..20 {
            w2[h * 10 + h / 2] = 200;
        }
        WeightStack::from_layers(vec![
            WeightMatrix::from_rows(784, 20, 9, w1).unwrap(),
            WeightMatrix::from_rows(20, 10, 9, w2).unwrap(),
        ])
        .unwrap()
    }

    #[test]
    fn behavioral_and_rtl_backends_agree() {
        let cfg = SnnConfig::paper().with_timesteps(4);
        let beh = BehavioralBackend::new(cfg.clone(), test_weights()).unwrap();
        let rtl = RtlBackend::new(cfg, test_weights()).unwrap();
        let gen = DigitGen::new(5);
        let images: Vec<Image> = (0..6).map(|i| gen.sample(i as u8, i)).collect();
        let refs: Vec<&Image> = images.iter().collect();
        let seeds: Vec<u32> = (0..6).map(|i| 100 + i).collect();
        let a = beh.classify_batch(&refs, &seeds, EarlyExit::Off).unwrap();
        let b = rtl.classify_batch(&refs, &seeds, EarlyExit::Off).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.class, y.class);
            assert_eq!(x.spike_counts, y.spike_counts);
        }
        assert!(rtl.total_cycles() > 0);
    }

    #[test]
    fn deep_backends_agree_through_the_pool() {
        // The 2-layer stack through both pooled backends: same decisions,
        // same final-layer counts.
        let cfg = SnnConfig::paper()
            .with_topology(vec![784, 20, 10])
            .with_timesteps(5)
            .with_prune(PruneMode::Off);
        let beh = BehavioralBackend::new(cfg.clone(), test_stack()).unwrap();
        let rtl = RtlBackend::new(cfg, test_stack()).unwrap();
        let gen = DigitGen::new(3);
        let images: Vec<Image> = (0..8).map(|i| gen.sample((i % 10) as u8, i)).collect();
        let refs: Vec<&Image> = images.iter().collect();
        let seeds: Vec<u32> = (0..8).map(|i| 300 + i).collect();
        let a = beh.classify_batch(&refs, &seeds, EarlyExit::Off).unwrap();
        let b = rtl.classify_batch(&refs, &seeds, EarlyExit::Off).unwrap();
        assert_eq!(a, b, "deep behavioral and RTL backends diverge");
    }

    #[test]
    fn rtl_early_exit_matches_behavioral_steps_run() {
        // The satellite contract: the RTL backend's per-timestep margin
        // check stops on exactly the timestep the behavioral model does —
        // for every image, not just on average.
        let cfg = SnnConfig::paper().with_timesteps(20).with_prune(PruneMode::Off);
        let beh = BehavioralBackend::new(cfg.clone(), test_weights()).unwrap();
        let rtl = RtlBackend::new(cfg, test_weights()).unwrap();
        // Block images: class k lights exactly the pixels feeding output
        // k, so the margin reliably opens within the window.
        let images: Vec<Image> = (0..10)
            .map(|class: usize| {
                let mut px = vec![0u8; 784];
                for (i, p) in px.iter_mut().enumerate() {
                    if i / 79 == class {
                        *p = 250;
                    }
                }
                Image { label: class as u8, pixels: px }
            })
            .collect();
        let refs: Vec<&Image> = images.iter().collect();
        let seeds: Vec<u32> = (0..10).map(|i| 900 + i).collect();
        let early = EarlyExit::Margin { margin: 3, min_steps: 2 };
        let a = beh.classify_batch(&refs, &seeds, early).unwrap();
        let b = rtl.classify_batch(&refs, &seeds, early).unwrap();
        let mut any_early = false;
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.steps_run, y.steps_run, "steps_run diverges for image {i}");
            assert_eq!(x.class, y.class, "class diverges for image {i}");
            assert_eq!(x.spike_counts, y.spike_counts, "counts diverge for image {i}");
            any_early |= x.steps_run < 20;
        }
        assert!(any_early, "margin never triggered — the test exercises nothing");
    }

    #[test]
    fn batched_backend_equals_singleton_calls() {
        // The batch dimension must be invisible in the results: one call
        // with 8 images equals 8 one-image calls, on both pooled batched
        // backends, including per-image early exit.
        let cfg = SnnConfig::paper().with_timesteps(5).with_prune(PruneMode::Off);
        let beh = BehavioralBackend::new(cfg.clone(), test_weights()).unwrap();
        let rtl = RtlBackend::new(cfg, test_weights()).unwrap();
        let gen = DigitGen::new(17);
        let images: Vec<Image> = (0..8).map(|i| gen.sample(i as u8, i)).collect();
        let refs: Vec<&Image> = images.iter().collect();
        let seeds: Vec<u32> = (0..8).map(|i| 50 + i).collect();
        let early = EarlyExit::Margin { margin: 2, min_steps: 2 };
        for backend in [&beh as &dyn Backend, &rtl as &dyn Backend] {
            let batched = backend.classify_batch(&refs, &seeds, early).unwrap();
            for i in 0..8 {
                let solo =
                    backend.classify_batch(&refs[i..=i], &seeds[i..=i], early).unwrap();
                assert_eq!(batched[i], solo[0], "{} lane {i}", backend.name());
            }
        }
    }

    #[test]
    fn sparse_backend_routes_by_density_and_agrees_with_dense() {
        // `test_weights` is ~1 hot entry per row: at threshold 1 the CSR
        // drops the explicit zeros and lands near 10% density, so the
        // backend must route to the event-driven sweep — and dropping
        // zero weights changes no accumulator, so every output matches
        // the dense backend bit for bit (including early-exit steps_run).
        let cfg = SnnConfig::paper().with_timesteps(6).with_prune(PruneMode::Off);
        let dense = RtlBackend::new(cfg.clone(), test_weights()).unwrap();
        let sparse = RtlBackend::with_sparse(cfg.clone(), test_weights(), 1).unwrap();
        assert!(sparse.serves_sparse());
        let d = sparse.sparse_density().unwrap();
        assert!(d < 0.2, "block-diagonal weights should be very sparse: {d}");
        assert_eq!(dense.sparse_density(), None);
        assert!(!dense.serves_sparse());

        let gen = DigitGen::new(21);
        let images: Vec<Image> = (0..8).map(|i| gen.sample(i as u8, i)).collect();
        let refs: Vec<&Image> = images.iter().collect();
        let seeds: Vec<u32> = (0..8).map(|i| 400 + i).collect();
        for early in [EarlyExit::Off, EarlyExit::Margin { margin: 2, min_steps: 2 }] {
            let a = dense.classify_batch(&refs, &seeds, early).unwrap();
            let b = sparse.classify_batch(&refs, &seeds, early).unwrap();
            assert_eq!(a, b, "sparse-routed backend diverges from dense ({early:?})");
        }

        // Deep stacks route too: the 2-layer test stack is also sparse.
        let deep_cfg = SnnConfig::paper()
            .with_topology(vec![784, 20, 10])
            .with_timesteps(5)
            .with_prune(PruneMode::Off);
        let deep_dense = RtlBackend::new(deep_cfg.clone(), test_stack()).unwrap();
        let deep_sparse = RtlBackend::with_sparse(deep_cfg, test_stack(), 1).unwrap();
        assert!(deep_sparse.serves_sparse());
        let a = deep_dense.classify_batch(&refs, &seeds, EarlyExit::Off).unwrap();
        let b = deep_sparse.classify_batch(&refs, &seeds, EarlyExit::Off).unwrap();
        assert_eq!(a, b, "deep sparse-routed backend diverges from dense");
    }

    #[test]
    fn dense_weights_stay_on_the_dense_route() {
        // Threshold 0 keeps every entry (density 1.0 > crossover): the
        // backend measures the density but serves dense — and still
        // answers identically.
        let cfg = SnnConfig::paper().with_timesteps(4);
        let auto = RtlBackend::with_sparse(cfg.clone(), test_weights(), 0).unwrap();
        assert_eq!(auto.sparse_density(), Some(1.0));
        assert!(!auto.serves_sparse(), "density 1.0 must not route sparse");
        let dense = RtlBackend::new(cfg, test_weights()).unwrap();
        let gen = DigitGen::new(2);
        let img = gen.sample(4, 0);
        let a = dense.classify_batch(&[&img], &[9], EarlyExit::Off).unwrap();
        let b = auto.classify_batch(&[&img], &[9], EarlyExit::Off).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn rtl_cycle_accounting_is_exact_under_fanout_pressure() {
        // 1 slot + stash cap 1, six concurrent batches: at least four
        // overflow cores get built and some drop past the stash cap. The
        // eviction hook must preserve their cycles, making the total
        // exactly requests × (784+1+1) × T.
        let timesteps = 3u32;
        let cfg = SnnConfig::paper().with_timesteps(timesteps);
        let rtl = Arc::new(RtlBackend::with_slots(cfg, test_weights(), 1).unwrap());
        let gen = DigitGen::new(11);
        let images: Arc<Vec<Image>> =
            Arc::new((0..6).map(|i| gen.sample(i as u8, i)).collect());
        let barrier = Arc::new(std::sync::Barrier::new(6));
        let handles: Vec<_> = (0..6)
            .map(|i| {
                let rtl = Arc::clone(&rtl);
                let images = Arc::clone(&images);
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    barrier.wait();
                    rtl.classify_batch(&[&images[i]], &[500 + i as u32], EarlyExit::Off)
                        .unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(
            rtl.total_cycles(),
            6 * 786 * u64::from(timesteps),
            "cycles lost: eviction hook failed to harvest dropped cores"
        );
    }

    #[test]
    fn concurrent_batches_do_not_serialize_or_corrupt() {
        // Hammer both pooled backends from many threads; every response
        // must match the single-threaded answer for its (image, seed).
        let cfg = SnnConfig::paper().with_timesteps(4);
        let beh = Arc::new(BehavioralBackend::new(cfg.clone(), test_weights()).unwrap());
        let rtl = Arc::new(RtlBackend::new(cfg, test_weights()).unwrap());
        let gen = DigitGen::new(9);
        let images: Arc<Vec<Image>> =
            Arc::new((0..10).map(|i| gen.sample(i as u8, i)).collect());
        let expected: Vec<BackendOutput> = {
            let refs: Vec<&Image> = images.iter().collect();
            let seeds: Vec<u32> = (0..10).map(|i| 700 + i).collect();
            beh.classify_batch(&refs, &seeds, EarlyExit::Off).unwrap()
        };

        let mut handles = Vec::new();
        for _ in 0..6 {
            let beh = Arc::clone(&beh);
            let rtl = Arc::clone(&rtl);
            let images = Arc::clone(&images);
            let expected = expected.clone();
            handles.push(std::thread::spawn(move || {
                for round in 0..8 {
                    let i = round % images.len();
                    let seed = 700 + i as u32;
                    let a = beh
                        .classify_batch(&[&images[i]], &[seed], EarlyExit::Off)
                        .unwrap();
                    let b = rtl
                        .classify_batch(&[&images[i]], &[seed], EarlyExit::Off)
                        .unwrap();
                    assert_eq!(a[0], expected[i], "behavioral diverged under load");
                    assert_eq!(b[0].class, expected[i].class);
                    assert_eq!(b[0].spike_counts, expected[i].spike_counts);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn rtl_quarantines_errored_cores_and_keeps_cycles_exact() {
        use crate::error::Error;
        let timesteps = 3u32;
        let cfg = SnnConfig::paper().with_timesteps(timesteps);
        let rtl = RtlBackend::with_slots(cfg, test_weights(), 1).unwrap();
        let gen = DigitGen::new(4);
        let good = gen.sample(1, 0);
        // Burn cycles on a good request...
        rtl.classify_batch(&[&good], &[1], EarlyExit::Off).unwrap();
        // ...then hit the engine with a malformed image: typed error, the
        // core is quarantined, and its cycles are harvested by the evict
        // hook rather than lost.
        let bad = Image { label: 0, pixels: vec![0u8; 10] };
        let err = rtl.classify_batch(&[&bad], &[2], EarlyExit::Off);
        assert!(matches!(err, Err(Error::ShapeMismatch(_))), "want shape error: {err:?}");
        assert_eq!(rtl.quarantined_engines(), 1);
        // The pool rebuilds from the factory: serving continues and the
        // accounting is exact — two successful full-window runs, nothing
        // lost to the discard, nothing double-counted.
        rtl.classify_batch(&[&good], &[1], EarlyExit::Off).unwrap();
        assert_eq!(rtl.total_cycles(), 2 * 786 * u64::from(timesteps));
    }

    #[test]
    fn confidence_check() {
        assert!(all_confident(&[vec![5, 1, 0], vec![4, 0, 0]], 3));
        assert!(!all_confident(&[vec![5, 4, 0]], 3));
        assert!(all_confident(&[], 3));
    }
}
