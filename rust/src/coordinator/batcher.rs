//! Dynamic batching policy: group requests up to `max_batch`, waiting at
//! most `max_delay` from the *first* request of the forming batch — the
//! standard size-or-timeout policy of serving systems (vLLM-router-like),
//! factored out as a pure, testable state machine.
//!
//! Lock-freedom note (pallas-lint L5): this module holds no `Mutex` and
//! acquires none — each worker owns its `Batcher` exclusively, so the
//! module contributes no nodes to the declared lock graph by design.
//! Keep it that way: batch forming sits on the request path.

use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Hard cap on batch size (match a compiled batch size for the XLA
    /// backend to avoid padding waste).
    pub max_batch: usize,
    /// Max time the first request of a batch may wait for company.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_delay: Duration::from_millis(2) }
    }
}

/// Decision returned by [`Batcher::poll`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchDecision {
    /// Keep accumulating; re-poll within the given duration.
    Wait(Duration),
    /// Dispatch the current batch now.
    Dispatch,
}

/// Pure batch-forming state machine over opaque item tokens.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    items: Vec<T>,
    first_arrival: Option<Instant>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        Batcher { policy, items: Vec::with_capacity(policy.max_batch), first_arrival: None }
    }

    /// Number of queued items.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True when no items are pending.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Remaining capacity before the batch is full.
    pub fn remaining(&self) -> usize {
        self.policy.max_batch - self.items.len()
    }

    /// The forming batch's items, in arrival order (read-only: the worker
    /// inspects pending deadlines to bound its park).
    pub fn items(&self) -> &[T] {
        &self.items
    }

    /// Add an item that arrived at `now`.
    pub fn push(&mut self, item: T, now: Instant) {
        assert!(self.items.len() < self.policy.max_batch, "push into full batch");
        if self.items.is_empty() {
            self.first_arrival = Some(now);
        }
        self.items.push(item);
    }

    /// Add a run of items that all arrived at `now` — one ingress drain's
    /// worth (the sharded queue hands batches out under a single lock
    /// acquisition). The run must fit within [`Batcher::remaining`].
    pub fn push_many(&mut self, items: impl IntoIterator<Item = T>, now: Instant) {
        for item in items {
            self.push(item, now);
        }
    }

    /// Decide whether to dispatch at time `now`.
    pub fn poll(&self, now: Instant) -> BatchDecision {
        if self.items.is_empty() {
            return BatchDecision::Wait(self.policy.max_delay);
        }
        if self.items.len() >= self.policy.max_batch {
            return BatchDecision::Dispatch;
        }
        let deadline = self.first_arrival.expect("non-empty batch has arrival")
            + self.policy.max_delay;
        if now >= deadline {
            BatchDecision::Dispatch
        } else {
            BatchDecision::Wait(deadline - now)
        }
    }

    /// Take the formed batch, resetting the state machine.
    pub fn take(&mut self) -> Vec<T> {
        self.first_arrival = None;
        std::mem::take(&mut self.items)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::PropRunner;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn dispatches_when_full() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_delay: Duration::from_secs(10) });
        let now = t0();
        b.push(1, now);
        b.push(2, now);
        assert!(matches!(b.poll(now), BatchDecision::Wait(_)));
        b.push(3, now);
        assert_eq!(b.poll(now), BatchDecision::Dispatch);
        assert_eq!(b.take(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn dispatches_on_deadline() {
        let policy = BatchPolicy { max_batch: 8, max_delay: Duration::from_millis(5) };
        let mut b = Batcher::new(policy);
        let now = t0();
        b.push("a", now);
        assert!(matches!(b.poll(now), BatchDecision::Wait(_)));
        let later = now + Duration::from_millis(5);
        assert_eq!(b.poll(later), BatchDecision::Dispatch);
    }

    #[test]
    fn deadline_tracks_first_arrival_not_last() {
        let policy = BatchPolicy { max_batch: 8, max_delay: Duration::from_millis(10) };
        let mut b = Batcher::new(policy);
        let now = t0();
        b.push(1, now);
        // A second item arriving later must NOT extend the deadline.
        b.push(2, now + Duration::from_millis(8));
        assert_eq!(b.poll(now + Duration::from_millis(10)), BatchDecision::Dispatch);
    }

    #[test]
    fn push_many_preserves_order_and_deadline() {
        let policy = BatchPolicy { max_batch: 8, max_delay: Duration::from_millis(10) };
        let mut b = Batcher::new(policy);
        let now = t0();
        b.push_many([1, 2, 3], now);
        assert_eq!(b.len(), 3);
        // A later run must not extend the deadline set by the first push.
        b.push_many([4, 5], now + Duration::from_millis(8));
        assert_eq!(b.poll(now + Duration::from_millis(10)), BatchDecision::Dispatch);
        assert_eq!(b.take(), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_batcher_waits_full_delay() {
        let policy = BatchPolicy { max_batch: 4, max_delay: Duration::from_millis(7) };
        let b: Batcher<u8> = Batcher::new(policy);
        match b.poll(t0()) {
            BatchDecision::Wait(d) => assert_eq!(d, Duration::from_millis(7)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn prop_no_loss_no_duplication_fifo() {
        PropRunner::new("batcher_conservation", 200).run(|g| {
            let max_batch = g.rng.range_i32(1, 16) as usize;
            let policy =
                BatchPolicy { max_batch, max_delay: Duration::from_millis(1) };
            let mut b = Batcher::new(policy);
            let now = t0();
            let n = g.rng.range_i32(1, 100) as u32;
            let mut dispatched: Vec<u32> = Vec::new();
            for i in 0..n {
                if b.remaining() == 0 {
                    dispatched.extend(b.take());
                }
                b.push(i, now);
                // Random mid-stream deadline dispatches.
                if g.rng.chance_u8(32) {
                    dispatched.extend(b.take());
                }
            }
            dispatched.extend(b.take());
            // Conservation + FIFO: exactly 0..n in order.
            assert_eq!(dispatched, (0..n).collect::<Vec<_>>());
            assert!(b.is_empty());
        });
    }
}
