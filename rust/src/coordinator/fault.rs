//! Deterministic fault injection for chaos tests and degraded-mode
//! benchmarks.
//!
//! [`FaultInjectingBackend`] wraps any [`Backend`] and injects faults on a
//! **deterministic seeded schedule**: whether a request is a fault victim
//! is a pure function of `(plan seed, request seed)` — see
//! [`FaultPlan::classify`] — so a chaos run is reproducible bit for bit
//! and a test can enumerate its victims up front instead of asserting on
//! probabilities.
//!
//! Fault semantics are chosen so the coordinator's recovery story is
//! observable end to end:
//!
//! * **Panic** victims are *hard* faults: every call whose batch contains
//!   one panics (before touching the inner backend), so the request can
//!   never succeed — it must surface as `Err(BackendPanicked)` after the
//!   retry also panics, and each panicked batch costs the worker its
//!   thread (exercising supervision).
//! * **Transient error** and **wrong-length** victims fire **once per
//!   victim seed**: the first call containing the victim misbehaves, the
//!   coordinator's single retry re-runs the same images and seeds on a
//!   fresh engine, and the retry succeeds — bit-exact with a fault-free
//!   run, which the chaos suite asserts.
//! * **Latency-spike** victims sleep before delegating: deadlines expire,
//!   queues back up, shedding and admission control engage — but results
//!   stay correct.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::SnnConfig;
use crate::data::Image;
use crate::error::{Error, Result};
use crate::prng::{splitmix32, GOLDEN_GAMMA};
use crate::snn::EarlyExit;

use super::backend::{Backend, BackendOutput};
use crate::util::lock_recover;

/// What the schedule has in store for one request seed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Left alone.
    None,
    /// Hard fault: every batch containing this seed panics.
    Panic,
    /// Fires once: the first batch containing this seed gets an error.
    TransientError,
    /// Fires once: the first batch containing this seed returns one
    /// output too few (a broken batch contract).
    WrongLength,
    /// Every batch containing this seed sleeps `latency_spike` first.
    LatencySpike,
}

/// Deterministic fault schedule: per-mille rates over the request-seed
/// space, keyed by a plan seed. Rates must sum to ≤ 1000.
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    /// Schedule key: different plan seeds pick different victim sets.
    pub seed: u32,
    /// Per-mille of request seeds that are hard panic victims.
    pub panic_per_mille: u32,
    /// Per-mille of request seeds that fire one transient error.
    pub error_per_mille: u32,
    /// Per-mille of request seeds that fire one wrong-length reply.
    pub wrong_len_per_mille: u32,
    /// Per-mille of request seeds that always spike latency.
    pub latency_per_mille: u32,
    /// Sleep inserted for latency victims' batches.
    pub latency_spike: Duration,
}

impl FaultPlan {
    /// A schedule that injects nothing (overhead measurements).
    pub fn none(seed: u32) -> Self {
        FaultPlan {
            seed,
            panic_per_mille: 0,
            error_per_mille: 0,
            wrong_len_per_mille: 0,
            latency_per_mille: 0,
            latency_spike: Duration::ZERO,
        }
    }

    /// A mixed schedule totalling `per_mille` faults: half transient
    /// errors, a quarter panics, a quarter wrong-length replies (the
    /// BENCH_6 degraded-mode mix; latency spikes are left to tests that
    /// exercise deadlines explicitly).
    pub fn mixed(seed: u32, per_mille: u32) -> Self {
        assert!(per_mille <= 1000, "rate out of range: {per_mille}");
        FaultPlan {
            seed,
            panic_per_mille: per_mille / 4,
            error_per_mille: per_mille / 2,
            wrong_len_per_mille: per_mille / 4,
            latency_per_mille: 0,
            latency_spike: Duration::ZERO,
        }
    }

    /// The fate of `request_seed` under this plan — a pure function, so
    /// tests can enumerate victims before submitting anything.
    pub fn classify(&self, request_seed: u32) -> FaultKind {
        let total = self.panic_per_mille
            + self.error_per_mille
            + self.wrong_len_per_mille
            + self.latency_per_mille;
        debug_assert!(total <= 1000, "fault rates sum past 1000 per mille");
        if total == 0 {
            return FaultKind::None;
        }
        let roll = splitmix32(request_seed ^ self.seed.wrapping_mul(GOLDEN_GAMMA)) % 1000;
        if roll < self.panic_per_mille {
            FaultKind::Panic
        } else if roll < self.panic_per_mille + self.error_per_mille {
            FaultKind::TransientError
        } else if roll < self.panic_per_mille + self.error_per_mille + self.wrong_len_per_mille {
            FaultKind::WrongLength
        } else if roll < total {
            FaultKind::LatencySpike
        } else {
            FaultKind::None
        }
    }
}

/// Injection counters (what actually fired, for test assertions).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultInjections {
    pub calls: u64,
    pub panics: u64,
    pub errors: u64,
    pub wrong_lengths: u64,
    pub latency_spikes: u64,
}

/// A [`Backend`] decorator that injects the [`FaultPlan`]'s faults. See
/// the module docs for the exact semantics of each fault kind.
pub struct FaultInjectingBackend {
    inner: Arc<dyn Backend>,
    plan: FaultPlan,
    /// Transient victims (error / wrong-length) that have already fired.
    fired: Mutex<HashSet<u32>>,
    calls: AtomicU64,
    panics: AtomicU64,
    errors: AtomicU64,
    wrong_lengths: AtomicU64,
    latency_spikes: AtomicU64,
}

impl FaultInjectingBackend {
    pub fn new(inner: Arc<dyn Backend>, plan: FaultPlan) -> Self {
        FaultInjectingBackend {
            inner,
            plan,
            fired: Mutex::new(HashSet::new()),
            calls: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            wrong_lengths: AtomicU64::new(0),
            latency_spikes: AtomicU64::new(0),
        }
    }

    /// The schedule this wrapper runs.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// What has fired so far.
    pub fn injections(&self) -> FaultInjections {
        FaultInjections {
            calls: self.calls.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            wrong_lengths: self.wrong_lengths.load(Ordering::Relaxed),
            latency_spikes: self.latency_spikes.load(Ordering::Relaxed),
        }
    }

    /// First not-yet-fired transient victim of `kind` in `seeds`, marking
    /// it fired. One victim per call: the coordinator's retry then meets
    /// an already-fired victim and passes.
    fn take_transient(&self, seeds: &[u32], kind: FaultKind) -> Option<u32> {
        // pallas-lint: lock(fault.fired)
        let mut fired = lock_recover(&self.fired);
        let victim =
            seeds.iter().copied().find(|&s| self.plan.classify(s) == kind && !fired.contains(&s));
        if let Some(s) = victim {
            fired.insert(s);
        }
        // pallas-lint: end-lock(fault.fired)
        victim
    }
}

impl Backend for FaultInjectingBackend {
    fn name(&self) -> &'static str {
        "fault-injecting"
    }

    fn classify_batch(
        &self,
        images: &[&Image],
        seeds: &[u32],
        early: EarlyExit,
    ) -> Result<Vec<BackendOutput>> {
        self.calls.fetch_add(1, Ordering::Relaxed);

        // Transient error: fires before the inner backend runs, so a
        // retry of the identical (images, seeds) chunk is bit-exact.
        if let Some(victim) = self.take_transient(seeds, FaultKind::TransientError) {
            self.errors.fetch_add(1, Ordering::Relaxed);
            return Err(Error::Coordinator(format!(
                "injected transient backend error (victim seed {victim})"
            )));
        }

        // Hard panic: fires on every call containing a victim.
        let hard = seeds.iter().find(|&&s| self.plan.classify(s) == FaultKind::Panic);
        if let Some(&victim) = hard {
            self.panics.fetch_add(1, Ordering::Relaxed);
            panic!("injected backend panic (victim seed {victim})");
        }

        let wrong_len = self.take_transient(seeds, FaultKind::WrongLength);

        // Latency victims stall only their own sub-batch: fault-free
        // siblings sharing the batch run on the inner backend *before*
        // the injected sleep, so their measured latency is untouched —
        // only the victims' slice pays the spike. Results are re-spliced
        // in submission order, bit-exact with an unsplit call (per-image
        // PRNG streams are independent). Pinned by the chaos suite's
        // `latency_spike_delays_only_the_victims_subbatch`.
        let victim_idx: Vec<usize> = seeds
            .iter()
            .enumerate()
            .filter_map(|(i, &s)| (self.plan.classify(s) == FaultKind::LatencySpike).then_some(i))
            .collect();
        let mut out = if victim_idx.is_empty() {
            self.inner.classify_batch(images, seeds, early)?
        } else {
            self.latency_spikes.fetch_add(1, Ordering::Relaxed);
            let rest_idx: Vec<usize> =
                (0..seeds.len()).filter(|i| !victim_idx.contains(i)).collect();
            let gather = |idx: &[usize]| -> (Vec<&Image>, Vec<u32>) {
                (idx.iter().map(|&i| images[i]).collect(), idx.iter().map(|&i| seeds[i]).collect())
            };
            let rest_out = if rest_idx.is_empty() {
                Vec::new()
            } else {
                let (imgs, sds) = gather(&rest_idx);
                self.inner.classify_batch(&imgs, &sds, early)?
            };
            std::thread::sleep(self.plan.latency_spike);
            let (imgs, sds) = gather(&victim_idx);
            let vic_out = self.inner.classify_batch(&imgs, &sds, early)?;
            let mut merged: Vec<Option<BackendOutput>> = Vec::new();
            merged.resize_with(seeds.len(), || None);
            for (&i, o) in rest_idx.iter().zip(rest_out) {
                merged[i] = Some(o);
            }
            for (&i, o) in victim_idx.iter().zip(vic_out) {
                merged[i] = Some(o);
            }
            merged.into_iter().flatten().collect()
        };
        if wrong_len.is_some() {
            self.wrong_lengths.fetch_add(1, Ordering::Relaxed);
            out.pop();
        }
        Ok(out)
    }

    fn parallel_capable(&self) -> bool {
        self.inner.parallel_capable()
    }

    fn config(&self) -> &SnnConfig {
        self.inner.config()
    }

    fn quarantined_engines(&self) -> u64 {
        self.inner.quarantined_engines()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count_kinds(plan: &FaultPlan, n: u32) -> (u32, u32, u32, u32) {
        let (mut p, mut e, mut w, mut l) = (0, 0, 0, 0);
        for s in 0..n {
            match plan.classify(s) {
                FaultKind::Panic => p += 1,
                FaultKind::TransientError => e += 1,
                FaultKind::WrongLength => w += 1,
                FaultKind::LatencySpike => l += 1,
                FaultKind::None => {}
            }
        }
        (p, e, w, l)
    }

    #[test]
    fn classify_is_deterministic_and_tracks_rates() {
        let plan = FaultPlan {
            seed: 0xC0FFEE,
            panic_per_mille: 10,
            error_per_mille: 20,
            wrong_len_per_mille: 10,
            latency_per_mille: 10,
            latency_spike: Duration::from_millis(1),
        };
        for s in 0..256 {
            assert_eq!(plan.classify(s), plan.classify(s), "must be pure");
        }
        let n = 20_000;
        let (p, e, w, l) = count_kinds(&plan, n);
        // splitmix32 is a good mixer: observed rates land near nominal.
        let near = |got: u32, per_mille: u32| {
            let want = n * per_mille / 1000;
            got >= want / 2 && got <= want * 2
        };
        assert!(near(p, 10), "panic rate off: {p}");
        assert!(near(e, 20), "error rate off: {e}");
        assert!(near(w, 10), "wrong-length rate off: {w}");
        assert!(near(l, 10), "latency rate off: {l}");
    }

    #[test]
    fn none_plan_never_classifies_victims() {
        let plan = FaultPlan::none(7);
        let (p, e, w, l) = count_kinds(&plan, 4096);
        assert_eq!((p, e, w, l), (0, 0, 0, 0));
    }

    #[test]
    fn mixed_plan_splits_the_budget() {
        let plan = FaultPlan::mixed(3, 40);
        assert_eq!(plan.panic_per_mille, 10);
        assert_eq!(plan.error_per_mille, 20);
        assert_eq!(plan.wrong_len_per_mille, 10);
        assert_eq!(plan.latency_per_mille, 0);
    }

    #[test]
    fn different_plan_seeds_pick_different_victims() {
        let a = FaultPlan::mixed(1, 100);
        let b = FaultPlan::mixed(2, 100);
        let victims = |p: &FaultPlan| -> Vec<u32> {
            (0..2000).filter(|&s| p.classify(s) != FaultKind::None).collect()
        };
        assert_ne!(victims(&a), victims(&b));
    }
}
