//! Serving metrics: lock-free counters + a log-bucketed latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log₂-bucketed histogram over microseconds: bucket k covers
/// [2^k, 2^(k+1)) µs, bucket 0 covers [0, 2) µs. 40 buckets ≈ 12 days.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; 40],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().min(u128::from(u64::MAX)) as u64;
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(39);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean in microseconds.
    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile (upper bound of the bucket holding it).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0u64;
        for (k, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (k + 1);
            }
        }
        self.max_us()
    }
}

/// Shared server counters.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    /// End-to-end request latency (submit → response).
    pub latency: Histogram,
    /// Backend batch execution latency.
    pub batch_latency: Histogram,
    pub submitted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    /// Requests a worker stole from a sibling's ingress shard (nonzero
    /// means the steal path is actually rebalancing load).
    pub steals: AtomicU64,
    /// Batches split across engines by intra-batch fan-out.
    pub fanout_batches: AtomicU64,
    /// Sub-batches dispatched by fan-out (>= 2 per fanned batch).
    pub subbatches: AtomicU64,
    /// Timesteps actually executed (early-exit savings show up here).
    pub steps_executed: AtomicU64,
    /// Queued requests dropped at pop time because their deadline had
    /// already expired (each one still gets a terminal `Shed` reply).
    pub shed: AtomicU64,
    /// Deadline expiry events: shed requests, submit-time rejections of
    /// already-expired deadlines, and completed-but-late deliveries.
    pub deadline_expired: AtomicU64,
    /// Backend panics caught by the `catch_unwind` batch guard (initial
    /// attempts and retries both count).
    pub panics_recovered: AtomicU64,
    /// Worker threads respawned by the supervisor after a panic death.
    pub worker_restarts: AtomicU64,
    /// Failed (sub-)batches retried once on a fresh engine.
    pub subbatch_retries: AtomicU64,
    /// Gauge mirroring the backend's quarantined-engine count (engines
    /// discarded as possibly-torn and rebuilt from the factory).
    pub quarantined_engines: AtomicU64,
}

/// Point-in-time copy for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub batched_items: u64,
    pub steals: u64,
    pub fanout_batches: u64,
    pub subbatches: u64,
    pub mean_batch_size: f64,
    pub latency_p50_us: u64,
    pub latency_p95_us: u64,
    pub latency_p99_us: u64,
    pub latency_mean_us: f64,
    pub latency_max_us: u64,
    pub steps_executed: u64,
    pub shed: u64,
    pub deadline_expired: u64,
    pub panics_recovered: u64,
    pub worker_restarts: u64,
    pub subbatch_retries: u64,
    pub quarantined_engines: u64,
}

impl ServerMetrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        // Conservation law: `submitted >= completed + failed + shed` must
        // hold in *every* snapshot, not just at quiescence. Each request's
        // lifecycle bumps `submitted` (at admission) strictly before its
        // terminal counter, so the snapshot reads the terminal sinks
        // FIRST and `submitted` LAST: the `Acquire` loads pair with the
        // sinks' `Release` increments (and the admission bump
        // happens-before the terminal bump via the queue hand-off), so
        // every terminal event we count here has its submission visible
        // by the time `submitted` is read. Reading in the other order let
        // a racing completion land between the two loads and transiently
        // break the invariant (see `snapshot_conservation_under_load`).
        //
        // Every other load is Acquire too — pallas-lint rule L4 enforces
        // it, and its publication half enforces the matching discipline
        // tree-wide: every counter `fetch_add` must spell
        // `Ordering::Release`. For the non-conservation counters the
        // pairing buys the same monotone guarantee (e.g. `subbatches`
        // never lags behind the `fanout_batches` read that preceded it)
        // at zero cost on x86, and it keeps both halves simple enough to
        // machine-check: no per-field exemption list to rot.
        let shed = self.shed.load(Ordering::Acquire);
        let completed = self.completed.load(Ordering::Acquire);
        let failed = self.failed.load(Ordering::Acquire);
        let batches = self.batches.load(Ordering::Acquire);
        let batched_items = self.batched_items.load(Ordering::Acquire);
        let snap = MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Acquire),
            rejected: self.rejected.load(Ordering::Acquire),
            completed,
            failed,
            batches,
            batched_items,
            steals: self.steals.load(Ordering::Acquire),
            fanout_batches: self.fanout_batches.load(Ordering::Acquire),
            subbatches: self.subbatches.load(Ordering::Acquire),
            mean_batch_size: if batches == 0 {
                0.0
            } else {
                batched_items as f64 / batches as f64
            },
            latency_p50_us: self.latency.quantile_us(0.50),
            latency_p95_us: self.latency.quantile_us(0.95),
            latency_p99_us: self.latency.quantile_us(0.99),
            latency_mean_us: self.latency.mean_us(),
            latency_max_us: self.latency.max_us(),
            steps_executed: self.steps_executed.load(Ordering::Acquire),
            shed,
            deadline_expired: self.deadline_expired.load(Ordering::Acquire),
            panics_recovered: self.panics_recovered.load(Ordering::Acquire),
            worker_restarts: self.worker_restarts.load(Ordering::Acquire),
            subbatch_retries: self.subbatch_retries.load(Ordering::Acquire),
            quarantined_engines: self.quarantined_engines.load(Ordering::Acquire),
        };
        // Dynamic twin of the static L4 check: test builds verify the
        // conservation law on every snapshot ever taken. `>=` (not `==`)
        // because requests legitimately sit in flight between admission
        // and their terminal counter; equality holds only at quiescence
        // and is asserted there by `snapshot_conservation_under_load`.
        debug_assert!(
            snap.submitted >= snap.completed + snap.failed + snap.shed,
            "metrics conservation torn: {} submitted < {} + {} + {} resolved",
            snap.submitted,
            snap.completed,
            snap.failed,
            snap.shed
        );
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::default();
        for us in [1u64, 3, 3, 3, 100, 100, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.max_us(), 10_000);
        assert!((h.mean_us() - 1401.25).abs() < 0.01);
        // p50 falls in the [2,4) bucket -> upper bound 4.
        assert_eq!(h.quantile_us(0.5), 4);
        assert!(h.quantile_us(0.99) >= 8192);
        // Quantiles are monotone in q.
        assert!(h.quantile_us(0.25) <= h.quantile_us(0.75));
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn snapshot_math() {
        let m = ServerMetrics::default();
        m.submitted.store(10, Ordering::Relaxed);
        m.batches.store(4, Ordering::Relaxed);
        m.batched_items.store(10, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.submitted, 10);
        assert_eq!(s.batched_items, 10);
        assert!((s.mean_batch_size - 2.5).abs() < 1e-9);
    }

    #[test]
    fn snapshot_carries_steal_and_fanout_counters() {
        let m = ServerMetrics::default();
        m.steals.store(3, Ordering::Relaxed);
        m.fanout_batches.store(2, Ordering::Relaxed);
        m.subbatches.store(7, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.steals, 3);
        assert_eq!(s.fanout_batches, 2);
        assert_eq!(s.subbatches, 7);
    }

    /// The conservation law must hold in *every* concurrent snapshot:
    /// writer threads drive full submit→terminal lifecycles (with the
    /// production ordering — every counter bump publishes with Release,
    /// as pallas-lint L4 enforces tree-wide) while a
    /// hammer thread snapshots nonstop and asserts
    /// `submitted >= completed + failed + shed` each time, then exact
    /// equality at quiescence. Deterministic: fixed iteration counts,
    /// join()-synchronized, no sleeps.
    ///
    /// This test is also the dynamic side of pallas-lint rule L4's
    /// cross-file check: every `AtomicU64` counter declared on
    /// `ServerMetrics` must be bumped and asserted here by name, so a
    /// counter added without extending this test fails the lint gate.
    #[test]
    fn snapshot_conservation_under_load() {
        use std::sync::atomic::AtomicBool;
        use std::sync::Arc;

        let m = Arc::new(ServerMetrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        const WRITERS: usize = 4;
        const PER_WRITER: u64 = 50_000;

        let writers: Vec<_> = (0..WRITERS)
            .map(|w| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    for i in 0..PER_WRITER {
                        // Every bump publishes with Release, exactly like
                        // the production sites (pallas-lint L4 holds this
                        // test to the same spelling it holds them to).
                        m.submitted.fetch_add(1, Ordering::Release);
                        match (i + w as u64) % 3 {
                            0 => m.completed.fetch_add(1, Ordering::Release),
                            1 => m.failed.fetch_add(1, Ordering::Release),
                            _ => m.shed.fetch_add(1, Ordering::Release),
                        };
                        // Every remaining counter churns concurrently too,
                        // so the hammer exercises whole-struct snapshots
                        // and the quiescent totals below pin each one.
                        m.rejected.fetch_add(1, Ordering::Release);
                        m.batches.fetch_add(1, Ordering::Release);
                        m.batched_items.fetch_add(2, Ordering::Release);
                        m.steals.fetch_add(1, Ordering::Release);
                        m.fanout_batches.fetch_add(1, Ordering::Release);
                        m.subbatches.fetch_add(1, Ordering::Release);
                        m.steps_executed.fetch_add(1, Ordering::Release);
                        m.deadline_expired.fetch_add(1, Ordering::Release);
                        m.panics_recovered.fetch_add(1, Ordering::Release);
                        m.worker_restarts.fetch_add(1, Ordering::Release);
                        m.subbatch_retries.fetch_add(1, Ordering::Release);
                        m.quarantined_engines.fetch_add(1, Ordering::Release);
                    }
                })
            })
            .collect();
        let hammer = {
            let m = Arc::clone(&m);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut snaps = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let s = m.snapshot();
                    assert!(
                        s.submitted >= s.completed + s.failed + s.shed,
                        "conservation torn: {} submitted < {}+{}+{} resolved",
                        s.submitted,
                        s.completed,
                        s.failed,
                        s.shed
                    );
                    snaps += 1;
                }
                snaps
            })
        };
        for w in writers {
            w.join().expect("writer panicked");
        }
        stop.store(true, Ordering::Relaxed);
        let snaps = hammer.join().expect("snapshot hammer saw a torn snapshot");
        assert!(snaps > 0, "hammer never ran");
        let s = m.snapshot();
        let total = WRITERS as u64 * PER_WRITER;
        assert_eq!(s.submitted, total);
        assert_eq!(s.completed + s.failed + s.shed, total, "quiescent equality");
        // Whole-struct quiescent totals: one assert per counter.
        assert_eq!(s.rejected, total);
        assert_eq!(s.batches, total);
        assert_eq!(s.batched_items, 2 * total);
        assert!((s.mean_batch_size - 2.0).abs() < 1e-9);
        assert_eq!(s.steals, total);
        assert_eq!(s.fanout_batches, total);
        assert_eq!(s.subbatches, total);
        assert_eq!(s.steps_executed, total);
        assert_eq!(s.deadline_expired, total);
        assert_eq!(s.panics_recovered, total);
        assert_eq!(s.worker_restarts, total);
        assert_eq!(s.subbatch_retries, total);
        assert_eq!(s.quarantined_engines, total);
    }

    #[test]
    fn snapshot_carries_fault_tolerance_counters() {
        let m = ServerMetrics::default();
        m.shed.store(4, Ordering::Relaxed);
        m.deadline_expired.store(5, Ordering::Relaxed);
        m.panics_recovered.store(6, Ordering::Relaxed);
        m.worker_restarts.store(3, Ordering::Relaxed);
        m.subbatch_retries.store(2, Ordering::Relaxed);
        m.quarantined_engines.store(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.shed, 4);
        assert_eq!(s.deadline_expired, 5);
        assert_eq!(s.panics_recovered, 6);
        assert_eq!(s.worker_restarts, 3);
        assert_eq!(s.subbatch_retries, 2);
        assert_eq!(s.quarantined_engines, 1);
    }
}
