//! The serving coordinator (L3): bounded-queue router, dynamic batcher,
//! worker pool over pluggable inference backends, and the early-exit
//! scheduler that generalizes the paper's active-pruning idea to the
//! request path (stop paying for timesteps once the decision is
//! confident).
//!
//! Threading model: callers submit through a bounded ingress channel
//! (backpressure = `Error::Rejected` when full); worker threads assemble
//! batches under a max-size / max-delay policy and run them on a
//! [`Backend`]; responses travel back through per-request oneshot
//! channels. tokio is not part of the offline crate set — the event loop
//! is small enough that blocking threads are the honest design
//! (DESIGN.md §7).
//!
//! Stateful backends (behavioral, RTL) draw private engine instances from
//! a non-blocking [`InstancePool`] per batch, so adding workers adds real
//! parallelism instead of queueing on one engine mutex.

mod backend;
mod batcher;
mod metrics;
mod pool;
mod server;

pub use backend::{Backend, BackendOutput, BehavioralBackend, RtlBackend, XlaBackend};
pub use batcher::{BatchPolicy, Batcher};
pub use metrics::{Histogram, MetricsSnapshot, ServerMetrics};
pub use pool::{InstancePool, PoolGuard};
pub use server::{Coordinator, CoordinatorConfig, Request, Response, SubmitHandle};
