//! The serving coordinator (L3): sharded bounded-queue router with work
//! stealing, dynamic batcher, worker pool over pluggable inference
//! backends, intra-batch fan-out across pooled engines, and the
//! early-exit scheduler that generalizes the paper's active-pruning idea
//! to the request path (stop paying for timesteps once the decision is
//! confident).
//!
//! Threading model: callers submit through a [`ShardedQueue`] — one
//! bounded deque per worker, shortest-queue placement, backpressure =
//! `Error::Overloaded` when every shard is full. Each worker drains its
//! own shard first and steals the oldest entries from the deepest sibling
//! when dry, so a slow batch cannot head-of-line-block the pool. Workers
//! assemble batches under a max-size / max-delay policy and run them on a
//! [`Backend`]; batches above the [`FanoutPolicy`] crossover split into
//! sub-batches executed concurrently on pooled engines and reassembled in
//! submission order. Responses travel back through per-request oneshot
//! channels. tokio is not part of the offline crate set — the event loop
//! is small enough that blocking threads are the honest design
//! (DESIGN.md §7).
//!
//! Stateful backends (behavioral, RTL) draw private engine instances from
//! a non-blocking [`InstancePool`] per batch (or per sub-batch under
//! fan-out), so adding workers adds real parallelism instead of queueing
//! on one engine mutex.
//!
//! Fault tolerance: requests carry optional deadlines (expired work is
//! shed with a typed reply instead of computed), backend calls run behind
//! `catch_unwind` (a panicking engine is quarantined by its pool and the
//! worker is respawned by a supervisor under [`SupervisionPolicy`]),
//! failed sub-batches are retried once on a fresh engine (bit-exact, same
//! seeds), and shutdown drains-or-rejects so every in-flight request gets
//! exactly one terminal reply. [`FaultInjectingBackend`] provides the
//! deterministic fault schedule the chaos suite and BENCH_6 run against.

mod backend;
mod batcher;
mod fault;
mod metrics;
mod pool;
mod server;
mod shard;

pub use backend::{
    Backend, BackendOutput, BehavioralBackend, RtlBackend, XlaBackend, SPARSE_DENSITY_CROSSOVER,
};
pub use batcher::{BatchPolicy, Batcher};
pub use fault::{FaultInjectingBackend, FaultInjections, FaultKind, FaultPlan};
pub use metrics::{Histogram, MetricsSnapshot, ServerMetrics};
pub use pool::{InstancePool, PoolGuard};
pub use server::{
    Coordinator, CoordinatorConfig, FanoutPolicy, Request, Response, SubmitHandle,
    SupervisionPolicy,
};
pub use shard::{Popped, PushError, ShardedQueue};
