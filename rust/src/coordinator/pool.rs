//! Non-blocking instance pool for stateful backend engines.
//!
//! The coordinator's worker threads all share one `Arc<dyn Backend>`. A
//! backend whose engine is stateful (the RTL core, a behavioral layer)
//! used to hide that engine behind a single `Mutex`, which serialized
//! every `classify_batch` across the whole pool — adding workers bought
//! nothing. [`InstancePool`] removes the serialization: each checkout
//! hands the caller a private engine instance for the duration of a batch.
//!
//! Design:
//!
//! * a fixed ring of slots, each a `Mutex<Option<T>>`, populated lazily by
//!   the factory on first use;
//! * [`InstancePool::checkout`] probes slots round-robin with `try_lock` —
//!   it **never blocks**: if every slot is busy (more concurrent batches
//!   than slots) it builds a fresh overflow instance that is simply
//!   dropped on release;
//! * the returned [`PoolGuard`] derefs to `T`; dropping it releases the
//!   slot.
//!
//! The slot mutex is only ever acquired uncontended (`try_lock`), so the
//! hot path is one atomic per checkout — worker scaling is limited by the
//! engines themselves, not by pool bookkeeping. A poisoned slot (a panic
//! mid-batch) is healed by rebuilding the instance from the factory.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, TryLockError};

/// A pool of reusable engine instances. See the module docs.
pub struct InstancePool<T> {
    slots: Box<[Mutex<Option<T>>]>,
    next: AtomicUsize,
    factory: Box<dyn Fn() -> T + Send + Sync>,
}

impl<T> InstancePool<T> {
    /// Create a pool of `slots` lazily-built instances.
    pub fn new(slots: usize, factory: impl Fn() -> T + Send + Sync + 'static) -> Self {
        assert!(slots >= 1, "pool needs at least one slot");
        InstancePool {
            slots: (0..slots).map(|_| Mutex::new(None)).collect(),
            next: AtomicUsize::new(0),
            factory: Box::new(factory),
        }
    }

    /// Slot count (capacity before overflow instances get built).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Check out an instance without ever blocking: the first free slot in
    /// round-robin order, or a fresh overflow instance when all slots are
    /// mid-batch.
    pub fn checkout(&self) -> PoolGuard<'_, T> {
        let n = self.slots.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        for i in 0..n {
            let slot = &self.slots[(start + i) % n];
            let mut guard = match slot.try_lock() {
                Ok(g) => g,
                // A worker panicked mid-batch: the instance may be in a
                // torn state, so drop it, heal the poison flag (or every
                // future checkout would rebuild forever) and refill below.
                Err(TryLockError::Poisoned(p)) => {
                    slot.clear_poison();
                    let mut g = p.into_inner();
                    *g = None;
                    g
                }
                Err(TryLockError::WouldBlock) => continue,
            };
            if guard.is_none() {
                *guard = Some((self.factory)());
            }
            return PoolGuard { inner: GuardInner::Slot(guard) };
        }
        PoolGuard { inner: GuardInner::Overflow((self.factory)()) }
    }

    /// Visit every pooled instance (blocking on busy slots). Used for
    /// cross-instance aggregation like cumulative cycle counts; overflow
    /// instances are not tracked.
    pub fn for_each(&self, mut f: impl FnMut(&T)) {
        for slot in self.slots.iter() {
            let guard = match slot.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            if let Some(v) = guard.as_ref() {
                f(v);
            }
        }
    }
}

enum GuardInner<'a, T> {
    Slot(MutexGuard<'a, Option<T>>),
    Overflow(T),
}

/// RAII handle to a checked-out instance; releases its slot on drop.
pub struct PoolGuard<'a, T> {
    inner: GuardInner<'a, T>,
}

impl<T> Deref for PoolGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        match &self.inner {
            GuardInner::Slot(g) => g.as_ref().expect("slot populated at checkout"),
            GuardInner::Overflow(v) => v,
        }
    }
}

impl<T> DerefMut for PoolGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        match &mut self.inner {
            GuardInner::Slot(g) => g.as_mut().expect("slot populated at checkout"),
            GuardInner::Overflow(v) => v,
        }
    }
}

/// Default slot count: one engine per hardware thread (min 4, so small
/// machines still overlap batches with pool headroom).
pub fn default_pool_slots() -> usize {
    std::thread::available_parallelism().map_or(4, |n| n.get()).max(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;
    use std::sync::Arc;

    #[test]
    fn builds_lazily_and_reuses() {
        let built = Arc::new(AtomicU32::new(0));
        let b = Arc::clone(&built);
        let pool = InstancePool::new(4, move || {
            b.fetch_add(1, Ordering::Relaxed);
            vec![0u8; 8]
        });
        assert_eq!(built.load(Ordering::Relaxed), 0, "no eager construction");
        {
            let mut a = pool.checkout();
            a[0] = 7;
        }
        assert_eq!(built.load(Ordering::Relaxed), 1);
        // Sequential checkouts after release reuse pooled instances
        // (round-robin may land on a different slot, so up to `capacity`
        // builds — never more).
        for _ in 0..32 {
            let _g = pool.checkout();
        }
        assert!(
            built.load(Ordering::Relaxed) <= pool.capacity() as u32,
            "pool must reuse instances: built {}",
            built.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn concurrent_checkouts_get_distinct_instances() {
        let pool = InstancePool::new(2, || vec![0u32; 4]);
        let mut a = pool.checkout();
        let mut b = pool.checkout();
        a[0] = 1;
        b[0] = 2;
        // Distinct storage: writes don't alias.
        assert_eq!(a[0], 1);
        assert_eq!(b[0], 2);
        // Third concurrent checkout overflows (both slots busy) and still
        // works without blocking.
        let mut c = pool.checkout();
        c[0] = 3;
        assert_eq!(c[0], 3);
    }

    #[test]
    fn for_each_sees_pooled_state() {
        let pool = InstancePool::new(3, || 0u64);
        {
            let mut g = pool.checkout();
            *g = 41;
        }
        {
            let mut g = pool.checkout();
            *g += 1;
        }
        let mut total = 0u64;
        pool.for_each(|v| total += v);
        // Either the same slot was reused (41+1) or two slots hold 41 and 1.
        assert_eq!(total, 42);
    }

    #[test]
    fn parallel_hammering_is_safe() {
        let pool = Arc::new(InstancePool::new(4, || 0u64));
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    for _ in 0..500 {
                        let mut g = pool.checkout();
                        *g += 1;
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let mut total = 0u64;
        pool.for_each(|v| total += v);
        // Overflow instances lose their counts, so pooled totals are a
        // lower bound capped by the true total.
        assert!(total > 0 && total <= 8 * 500, "total {total}");
    }
}
