//! Non-blocking instance pool for stateful backend engines.
//!
//! The coordinator's worker threads all share one `Arc<dyn Backend>`. A
//! backend whose engine is stateful (the RTL core, a behavioral layer)
//! used to hide that engine behind a single `Mutex`, which serialized
//! every `classify_batch` across the whole pool — adding workers bought
//! nothing. [`InstancePool`] removes the serialization: each checkout
//! hands the caller a private engine instance for the duration of a batch.
//!
//! Design:
//!
//! * a fixed ring of slots, each a `Mutex<Option<T>>`, populated lazily by
//!   the factory on first use;
//! * [`InstancePool::checkout`] probes slots round-robin with `try_lock` —
//!   it **never blocks**: if every slot is busy (more concurrent batches
//!   than slots) it takes a recycled overflow instance from the stash, or
//!   builds a fresh one when the stash is empty too;
//! * overflow instances are **recycled**: on release they return to a
//!   bounded stash (capacity = the slot count) instead of being dropped,
//!   so a burst of concurrency does not pay repeated construction and the
//!   pool never shrinks below its configured size;
//! * the returned [`PoolGuard`] derefs to `T`; dropping it releases the
//!   slot (or restashes the overflow instance).
//!
//! The slot mutex is only ever acquired uncontended (`try_lock`), so the
//! hot path is one atomic per checkout — worker scaling is limited by the
//! engines themselves, not by pool bookkeeping.
//!
//! **Quarantine.** An engine that was checked out when something went
//! wrong never returns to the free list: callers route errors through
//! [`PoolGuard::discard`], a panic while an overflow guard is live is
//! detected in `Drop` via `std::thread::panicking()`, and a panic while a
//! *slot* guard is live poisons the slot mutex, which the next `checkout`
//! heals by evicting the torn instance and rebuilding from the factory.
//! All three paths run the eviction hook first (so cumulative state such
//! as RTL cycle counters survives) and bump the [`InstancePool::quarantined`]
//! counter. Capacity never shrinks: a discarded slot refills lazily on the
//! next checkout exactly like a never-used slot.

use crate::util::lock_recover;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, TryLockError};

/// A pool of reusable engine instances. See the module docs.
pub struct InstancePool<T> {
    slots: Box<[Mutex<Option<T>>]>,
    /// Recycled overflow instances (bounded by `overflow_cap`).
    extra: Mutex<Vec<T>>,
    overflow_cap: usize,
    next: AtomicUsize,
    factory: Box<dyn Fn() -> T + Send + Sync>,
    /// Last-look hook run on any instance the pool is about to *drop*
    /// (overflow past the stash cap, or a poisoned slot being healed).
    /// Lets owners harvest cumulative state — e.g. the RTL backend folds
    /// a dying core's `ActivityCounters` into a shared total so cycle
    /// accounting stays exact under fan-out bursts.
    on_evict: Option<Box<dyn Fn(&mut T) + Send + Sync>>,
    /// Instances thrown away because they may be in a torn state (explicit
    /// [`PoolGuard::discard`], poisoned-slot heal, panic during an
    /// overflow checkout). Each one is rebuilt from the factory on demand.
    quarantined: AtomicU64,
}

impl<T> InstancePool<T> {
    /// Create a pool of `slots` lazily-built instances. Up to `slots`
    /// additional overflow instances are kept for reuse.
    pub fn new(slots: usize, factory: impl Fn() -> T + Send + Sync + 'static) -> Self {
        assert!(slots >= 1, "pool needs at least one slot");
        InstancePool {
            slots: (0..slots).map(|_| Mutex::new(None)).collect(),
            extra: Mutex::new(Vec::new()),
            overflow_cap: slots,
            next: AtomicUsize::new(0),
            factory: Box::new(factory),
            on_evict: None,
            quarantined: AtomicU64::new(0),
        }
    }

    /// Install the eviction hook (builder style; set before the pool is
    /// shared). See the `on_evict` field docs.
    pub fn with_evict_hook(mut self, hook: impl Fn(&mut T) + Send + Sync + 'static) -> Self {
        self.on_evict = Some(Box::new(hook));
        self
    }

    /// Run the eviction hook on an instance that is about to drop.
    fn evict(&self, mut instance: T) {
        if let Some(hook) = &self.on_evict {
            hook(&mut instance);
        }
    }

    /// Drop a possibly-torn instance through the eviction hook and count
    /// the quarantine event.
    fn quarantine_instance(&self, instance: T) {
        self.quarantined.fetch_add(1, Ordering::Relaxed);
        self.evict(instance);
    }

    /// Engines quarantined (and later rebuilt) over the pool's lifetime.
    pub fn quarantined(&self) -> u64 {
        self.quarantined.load(Ordering::Relaxed)
    }

    /// Slot count (capacity before overflow instances get built).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Recycled overflow instances currently stashed (observability).
    pub fn stashed(&self) -> usize {
        // pallas-lint: lock(pool.extra)
        let n = lock_recover(&self.extra).len();
        // pallas-lint: end-lock(pool.extra)
        n
    }

    /// Check out an instance without ever blocking: the first free slot in
    /// round-robin order, a recycled overflow instance, or a freshly built
    /// one when all slots are mid-batch and the stash is dry.
    pub fn checkout(&self) -> PoolGuard<'_, T> {
        let n = self.slots.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        for i in 0..n {
            let slot = &self.slots[(start + i) % n];
            let mut guard = match slot.try_lock() {
                Ok(g) => g,
                // A worker panicked mid-batch: the instance may be in a
                // torn state, so quarantine it (through the eviction hook,
                // so its cumulative counters are not lost), heal the
                // poison flag (or every future checkout would rebuild
                // forever) and refill below.
                Err(TryLockError::Poisoned(p)) => {
                    slot.clear_poison();
                    // pallas-lint: lock(pool.slot)
                    let mut g = p.into_inner();
                    if let Some(dead) = g.take() {
                        // The eviction hook runs while the slot guard is
                        // held and may take the owner's harvest sink.
                        self.quarantine_instance(dead); // pallas-lint: calls-lock(backend.evict_sink)
                    }
                    // pallas-lint: end-lock(pool.slot)
                    g
                }
                Err(TryLockError::WouldBlock) => continue,
            };
            if guard.is_none() {
                *guard = Some((self.factory)());
            }
            return PoolGuard { pool: self, inner: GuardInner::Slot(guard) };
        }
        // pallas-lint: lock(pool.extra)
        let recycled = lock_recover(&self.extra).pop();
        // pallas-lint: end-lock(pool.extra)
        let instance = recycled.unwrap_or_else(|| (self.factory)());
        PoolGuard { pool: self, inner: GuardInner::Overflow(Some(instance)) }
    }

    /// Return a released overflow instance to the stash, up to the cap.
    fn restash(&self, instance: T) {
        let mut instance = Some(instance);
        {
            // pallas-lint: lock(pool.extra)
            let mut e = lock_recover(&self.extra);
            if e.len() < self.overflow_cap {
                e.push(instance.take().expect("instance present"));
            }
            // pallas-lint: end-lock(pool.extra)
        }
        // A full stash drops the instance — the slot ring alone already
        // guarantees the configured capacity — but the eviction hook gets
        // a last look first, so cumulative state (cycle counters)
        // survives the drop.
        if let Some(dropped) = instance {
            self.evict(dropped);
        }
    }

    /// Visit every pooled instance (blocking on busy slots), including
    /// recycled overflow instances in the stash. Used for cross-instance
    /// aggregation like cumulative cycle counts; only overflow instances
    /// currently checked out (or dropped past the stash cap) are missed.
    pub fn for_each(&self, mut f: impl FnMut(&T)) {
        for slot in self.slots.iter() {
            // pallas-lint: lock(pool.slot)
            let guard = match slot.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            if let Some(v) = guard.as_ref() {
                f(v);
            }
            // pallas-lint: end-lock(pool.slot)
        }
        // pallas-lint: lock(pool.extra)
        let extra = lock_recover(&self.extra);
        for v in extra.iter() {
            f(v);
        }
        // pallas-lint: end-lock(pool.extra)
    }
}

enum GuardInner<'a, T> {
    Slot(MutexGuard<'a, Option<T>>),
    /// Always `Some` until the guard drops (the option exists so `Drop`
    /// can move the instance back into the stash).
    Overflow(Option<T>),
}

/// RAII handle to a checked-out instance; releases its slot (or restashes
/// the overflow instance) on drop.
pub struct PoolGuard<'a, T> {
    pool: &'a InstancePool<T>,
    inner: GuardInner<'a, T>,
}

impl<T> PoolGuard<'_, T> {
    /// Quarantine the held instance instead of returning it to the pool.
    ///
    /// The engine is dropped through the eviction hook (cumulative
    /// counters survive) and its slot refills lazily from the factory on
    /// the next checkout, so pool capacity never shrinks. Callers invoke
    /// this whenever the engine returned an error mid-batch: the engine's
    /// internal state (membranes, PRNG banks, pipeline registers) may be
    /// torn, and a rebuilt instance is cheap insurance against serving
    /// wrong answers from it.
    pub fn discard(mut self) {
        // A slot guard may live inside `self.inner` for the whole body, so
        // the eviction hook below runs while that slot is held.
        // pallas-lint: lock(pool.slot)
        let dead = match &mut self.inner {
            GuardInner::Slot(g) => g.take(),
            GuardInner::Overflow(v) => v.take(),
        };
        if let Some(instance) = dead {
            self.pool.quarantine_instance(instance); // pallas-lint: calls-lock(backend.evict_sink)
        }
        // pallas-lint: end-lock(pool.slot)
        // Drop now releases an empty slot (or an empty overflow option).
    }
}

impl<T> Deref for PoolGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        match &self.inner {
            GuardInner::Slot(g) => g.as_ref().expect("slot populated at checkout"),
            GuardInner::Overflow(v) => v.as_ref().expect("overflow held until drop"),
        }
    }
}

impl<T> DerefMut for PoolGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        match &mut self.inner {
            GuardInner::Slot(g) => g.as_mut().expect("slot populated at checkout"),
            GuardInner::Overflow(v) => v.as_mut().expect("overflow held until drop"),
        }
    }
}

impl<T> Drop for PoolGuard<'_, T> {
    fn drop(&mut self) {
        if let GuardInner::Overflow(v) = &mut self.inner {
            if let Some(instance) = v.take() {
                // Unwinding through an overflow checkout leaves no poison
                // trace (no slot mutex involved), so the panic check here
                // is what keeps a torn overflow engine out of the stash.
                if std::thread::panicking() {
                    self.pool.quarantine_instance(instance);
                } else {
                    self.pool.restash(instance);
                }
            }
        }
    }
}

/// Default slot count: one engine per hardware thread (min 4, so small
/// machines still overlap batches with pool headroom).
pub fn default_pool_slots() -> usize {
    std::thread::available_parallelism().map_or(4, |n| n.get()).max(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;
    use std::sync::Arc;

    #[test]
    fn builds_lazily_and_reuses() {
        let built = Arc::new(AtomicU32::new(0));
        let b = Arc::clone(&built);
        let pool = InstancePool::new(4, move || {
            b.fetch_add(1, Ordering::Relaxed);
            vec![0u8; 8]
        });
        assert_eq!(built.load(Ordering::Relaxed), 0, "no eager construction");
        {
            let mut a = pool.checkout();
            a[0] = 7;
        }
        assert_eq!(built.load(Ordering::Relaxed), 1);
        // Sequential checkouts after release reuse pooled instances
        // (round-robin may land on a different slot, so up to `capacity`
        // builds — never more).
        for _ in 0..32 {
            let _g = pool.checkout();
        }
        assert!(
            built.load(Ordering::Relaxed) <= pool.capacity() as u32,
            "pool must reuse instances: built {}",
            built.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn concurrent_checkouts_get_distinct_instances() {
        let pool = InstancePool::new(2, || vec![0u32; 4]);
        let mut a = pool.checkout();
        let mut b = pool.checkout();
        a[0] = 1;
        b[0] = 2;
        // Distinct storage: writes don't alias.
        assert_eq!(a[0], 1);
        assert_eq!(b[0], 2);
        // Third concurrent checkout overflows (both slots busy) and still
        // works without blocking.
        let mut c = pool.checkout();
        c[0] = 3;
        assert_eq!(c[0], 3);
    }

    #[test]
    fn overflow_instances_are_recycled_not_dropped() {
        let built = Arc::new(AtomicU32::new(0));
        let b = Arc::clone(&built);
        let pool = InstancePool::new(2, move || {
            b.fetch_add(1, Ordering::Relaxed);
            0u64
        });
        {
            // Four concurrent checkouts: 2 slots + 2 overflow builds.
            let _g1 = pool.checkout();
            let _g2 = pool.checkout();
            let _g3 = pool.checkout();
            let _g4 = pool.checkout();
            assert_eq!(built.load(Ordering::Relaxed), 4);
        }
        // The overflow pair is stashed, not dropped...
        assert_eq!(pool.stashed(), 2);
        {
            // ...so the same burst again builds nothing new.
            let _g1 = pool.checkout();
            let _g2 = pool.checkout();
            let _g3 = pool.checkout();
            let _g4 = pool.checkout();
            assert_eq!(built.load(Ordering::Relaxed), 4, "burst must reuse the stash");
        }
        // The pool never shrinks below its configured size (and here keeps
        // the whole burst's worth of instances alive).
        let mut live = 0;
        pool.for_each(|_| live += 1);
        assert!(
            live >= pool.capacity(),
            "pool shrank below its configured size: {live} < {}",
            pool.capacity()
        );
    }

    #[test]
    fn overflow_stash_is_bounded() {
        let pool = InstancePool::new(2, || 0u64);
        {
            // 6 concurrent checkouts: 2 slots + 4 overflow, stash cap 2.
            let _gs: Vec<_> = (0..6).map(|_| pool.checkout()).collect();
        }
        assert_eq!(pool.stashed(), 2, "stash must stay bounded at the slot count");
    }

    #[test]
    fn for_each_sees_pooled_state() {
        let pool = InstancePool::new(3, || 0u64);
        {
            let mut g = pool.checkout();
            *g = 41;
        }
        {
            let mut g = pool.checkout();
            *g += 1;
        }
        let mut total = 0u64;
        pool.for_each(|v| total += v);
        // Either the same slot was reused (41+1) or two slots hold 41 and 1.
        assert_eq!(total, 42);
    }

    #[test]
    fn for_each_includes_recycled_overflow_state() {
        let pool = InstancePool::new(1, || 0u64);
        {
            let mut a = pool.checkout(); // the slot
            let mut b = pool.checkout(); // overflow
            *a += 1;
            *b += 10;
        }
        let mut total = 0u64;
        pool.for_each(|v| total += v);
        assert_eq!(total, 11, "recycled overflow state must be visible");
    }

    #[test]
    fn evict_hook_sees_instances_dropped_past_the_stash_cap() {
        let harvested = Arc::new(AtomicU32::new(0));
        let sink = Arc::clone(&harvested);
        let pool = InstancePool::new(2, || 1u32)
            .with_evict_hook(move |v: &mut u32| {
                sink.fetch_add(*v, Ordering::Relaxed);
            });
        {
            // 6 concurrent checkouts: 2 slots + 4 overflow; stash cap 2,
            // so exactly 2 overflow instances drop — through the hook.
            let _gs: Vec<_> = (0..6).map(|_| pool.checkout()).collect();
        }
        assert_eq!(pool.stashed(), 2);
        assert_eq!(
            harvested.load(Ordering::Relaxed),
            2,
            "the two past-cap instances must pass through the evict hook"
        );
    }

    #[test]
    fn parallel_hammering_is_safe_and_evict_hook_keeps_totals_exact() {
        let evicted = Arc::new(AtomicU32::new(0));
        let sink = Arc::clone(&evicted);
        let pool = Arc::new(InstancePool::new(4, || 0u64).with_evict_hook(move |v: &mut u64| {
            sink.fetch_add(*v as u32, Ordering::Relaxed);
        }));
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    for _ in 0..500 {
                        let mut g = pool.checkout();
                        *g += 1;
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let mut total = 0u64;
        pool.for_each(|v| total += v);
        total += u64::from(evicted.load(Ordering::Relaxed));
        // With the hook harvesting dropped instances the count is exact,
        // not a lower bound.
        assert_eq!(total, 8 * 500, "pooled + evicted totals must be exact");
    }

    #[test]
    fn discard_quarantines_and_slot_rebuilds_from_factory() {
        let built = Arc::new(AtomicU32::new(0));
        let harvested = Arc::new(AtomicU32::new(0));
        let (b, sink) = (Arc::clone(&built), Arc::clone(&harvested));
        let pool = InstancePool::new(1, move || {
            b.fetch_add(1, Ordering::Relaxed);
            7u32
        })
        .with_evict_hook(move |v: &mut u32| {
            sink.fetch_add(*v, Ordering::Relaxed);
        });
        {
            let mut g = pool.checkout();
            *g = 100; // accumulate some state, then hit an "error"
            g.discard();
        }
        assert_eq!(pool.quarantined(), 1);
        assert_eq!(harvested.load(Ordering::Relaxed), 100, "evict hook must harvest the discard");
        // The slot refills lazily — capacity never shrank.
        {
            let g = pool.checkout();
            assert_eq!(*g, 7, "factory-fresh instance after discard");
        }
        assert_eq!(built.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn panic_with_slot_guard_poisons_then_heals_with_quarantine() {
        let harvested = Arc::new(AtomicU32::new(0));
        let sink = Arc::clone(&harvested);
        let pool = Arc::new(InstancePool::new(1, || 5u32).with_evict_hook(move |v: &mut u32| {
            sink.fetch_add(*v, Ordering::Relaxed);
        }));
        let p = Arc::clone(&pool);
        let t = std::thread::spawn(move || {
            let mut g = p.checkout();
            *g = 99;
            panic!("boom mid-batch");
        });
        assert!(t.join().is_err(), "probe thread must panic");
        // Next checkout heals the poisoned slot: torn instance evicted +
        // counted, fresh one built.
        let g = pool.checkout();
        assert_eq!(*g, 5);
        assert_eq!(pool.quarantined(), 1);
        assert_eq!(harvested.load(Ordering::Relaxed), 99);
    }

    #[test]
    fn panic_with_overflow_guard_quarantines_instead_of_restashing() {
        let pool = Arc::new(InstancePool::new(1, || 0u32));
        let slot_guard = pool.checkout(); // occupy the only slot
        let p = Arc::clone(&pool);
        let t = std::thread::spawn(move || {
            let mut g = p.checkout(); // overflow checkout
            *g = 1;
            panic!("boom with overflow engine");
        });
        assert!(t.join().is_err());
        drop(slot_guard);
        assert_eq!(pool.stashed(), 0, "torn overflow instance must not be recycled");
        assert_eq!(pool.quarantined(), 1);
    }
}
