//! The coordinator proper: sharded ingress router + work-stealing worker
//! pool + intra-batch fan-out + response plumbing.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::data::Image;
use crate::error::{Error, Result};
use crate::snn::EarlyExit;

use super::backend::{Backend, BackendOutput};
use super::batcher::{BatchDecision, BatchPolicy, Batcher};
use super::metrics::ServerMetrics;
use super::shard::{Popped, PushError, ShardedQueue};

/// How long an idle worker parks between shutdown checks.
const IDLE_POLL: Duration = Duration::from_millis(50);

/// A classification request.
#[derive(Debug, Clone)]
pub struct Request {
    pub image: Image,
    /// Encoder seed; `None` lets the coordinator assign one from its
    /// request counter (deterministic given submission order).
    pub seed: Option<u32>,
}

/// A classification response.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Response {
    pub class: u8,
    pub spike_counts: Vec<u32>,
    pub steps_run: u32,
    /// Seed the encoder actually used (echo for reproducibility).
    pub seed: u32,
}

struct InFlight {
    request: Request,
    seed: u32,
    submitted: Instant,
    reply: SyncSender<Result<Response>>,
}

/// Intra-batch fan-out policy: when a formed batch is large enough, split
/// it into sub-batches dispatched concurrently across pooled engines and
/// reassembled in submission order — latency parallelism for one big
/// request burst, not just throughput across bursts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FanoutPolicy {
    /// Crossover threshold: batches smaller than this keep the
    /// single-engine path (splitting tiny batches costs more in thread
    /// dispatch than it saves in compute).
    pub min_batch: usize,
    /// Maximum sub-batches one batch splits into. Keep at or below the
    /// backend's pool capacity, or the extra parts just queue.
    pub max_parts: usize,
}

impl Default for FanoutPolicy {
    fn default() -> Self {
        FanoutPolicy { min_batch: 32, max_parts: 4 }
    }
}

impl FanoutPolicy {
    /// Disable fan-out entirely (every batch runs on one engine).
    pub fn off() -> Self {
        FanoutPolicy { min_batch: usize::MAX, max_parts: 1 }
    }

    /// Number of sub-batches a batch of `n` splits into (1 = no fan-out).
    pub fn parts_for(&self, n: usize) -> usize {
        if self.max_parts <= 1 || n < self.min_batch.max(2) {
            1
        } else {
            self.max_parts.min(n)
        }
    }

    /// Approximate overhead of dispatching one fan-out sub-batch (scoped
    /// thread spawn + join + reassembly). The calibration constant behind
    /// [`FanoutPolicy::from_cost`].
    pub const DISPATCH_COST: Duration = Duration::from_micros(120);

    /// Derive the crossover from a *measured* per-image cost and the
    /// engine pool's slot count — the adaptive replacement for the fixed
    /// `32/4` defaults. Splitting a batch of `n` into two halves saves
    /// `n/2 · c` of serialized compute and pays ~2 dispatches, so fan-out
    /// starts earning its keep from `n > 4·D/c`: a slow backend (large
    /// `c`) wants a low crossover, an echo-fast one a high crossover.
    /// `max_parts` is the pool's slot count — more parts than engines
    /// just queue. Deterministic given its inputs (the probe lives in
    /// [`FanoutPolicy::calibrated`]).
    pub fn from_cost(per_image: Duration, pool_slots: usize) -> FanoutPolicy {
        let per_image_ns = per_image.as_nanos().max(1);
        let min_batch = (4 * Self::DISPATCH_COST.as_nanos())
            .div_ceil(per_image_ns)
            .clamp(2, 1 << 16) as usize;
        FanoutPolicy { min_batch, max_parts: pool_slots.max(1) }
    }

    /// One-shot measured calibration: probe the backend with a small
    /// synthetic batch (mid-gray images, fixed seeds — deterministic
    /// work), take the per-image wall cost, and derive the policy via
    /// [`FanoutPolicy::from_cost`].
    pub fn calibrated(backend: &dyn Backend, pool_slots: usize) -> FanoutPolicy {
        const PROBE: usize = 4;
        const REPS: u32 = 3;
        let n = backend.config().n_inputs();
        let images: Vec<Image> = (0..PROBE)
            .map(|i| Image { label: 0, pixels: vec![64 + 32 * i as u8; n] })
            .collect();
        let refs: Vec<&Image> = images.iter().collect();
        let seeds: Vec<u32> = (1..=PROBE as u32).collect();
        // Warmup builds the pool instance and faults the weights in.
        let _ = backend.classify_batch(&refs, &seeds, EarlyExit::Off);
        let t0 = Instant::now();
        for _ in 0..REPS {
            let _ = backend.classify_batch(&refs, &seeds, EarlyExit::Off);
        }
        let per_image = t0.elapsed() / (REPS * PROBE as u32);
        Self::from_cost(per_image, pool_slots)
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Worker threads pulling batches (also the ingress shard count).
    pub workers: usize,
    /// Total ingress queue capacity across all shards (backpressure
    /// bound). Split evenly across shards, rounded up — so the effective
    /// bound is the next multiple of `workers` when it does not divide
    /// evenly.
    pub queue_depth: usize,
    /// Batch forming policy.
    pub batch: BatchPolicy,
    /// Early-exit policy handed to the backend.
    pub early: EarlyExit,
    /// Intra-batch fan-out policy.
    pub fanout: FanoutPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            queue_depth: 256,
            batch: BatchPolicy::default(),
            early: EarlyExit::Off,
            fanout: FanoutPolicy::default(),
        }
    }
}

/// Client handle: cheap to clone, submits requests.
#[derive(Clone)]
pub struct SubmitHandle {
    queue: Arc<ShardedQueue<InFlight>>,
    seed_counter: Arc<AtomicU32>,
    metrics: Arc<ServerMetrics>,
}

impl SubmitHandle {
    /// Submit a request; returns the receiver for its response. Fails fast
    /// with [`Error::Rejected`] when every ingress shard is full
    /// (backpressure) or the server is shutting down.
    pub fn submit(&self, request: Request) -> Result<Receiver<Result<Response>>> {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        let seed = request
            .seed
            .unwrap_or_else(|| self.seed_counter.fetch_add(1, Ordering::Relaxed));
        let inflight =
            InFlight { request, seed, submitted: Instant::now(), reply: reply_tx };
        match self.queue.push(inflight) {
            Ok(_shard) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(reply_rx)
            }
            Err(PushError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(Error::Rejected("ingress queue full".into()))
            }
            Err(PushError::Closed(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(Error::Rejected("coordinator is shut down".into()))
            }
        }
    }

    /// Submit and block for the response (convenience).
    pub fn classify(&self, image: Image) -> Result<Response> {
        let rx = self.submit(Request { image, seed: None })?;
        rx.recv()
            .map_err(|_| Error::Coordinator("worker dropped the reply channel".into()))?
    }
}

/// The running coordinator.
pub struct Coordinator {
    handle: SubmitHandle,
    workers: Vec<JoinHandle<()>>,
    queue: Arc<ShardedQueue<InFlight>>,
    metrics: Arc<ServerMetrics>,
}

impl Coordinator {
    /// Start the worker pool over `backend`. Each worker owns one ingress
    /// shard; the submit path load-balances across them and workers steal
    /// from siblings when their own shard runs dry.
    pub fn start(backend: Arc<dyn Backend>, cfg: CoordinatorConfig) -> Self {
        assert!(cfg.workers >= 1);
        let queue = Arc::new(ShardedQueue::new(cfg.workers, cfg.queue_depth));
        let metrics = Arc::new(ServerMetrics::default());

        let workers = (0..cfg.workers)
            .map(|id| {
                let queue = Arc::clone(&queue);
                let backend = Arc::clone(&backend);
                let metrics = Arc::clone(&metrics);
                let cfg = cfg.clone();
                std::thread::spawn(move || worker_loop(id, queue, backend, metrics, cfg))
            })
            .collect();

        Coordinator {
            handle: SubmitHandle {
                queue: Arc::clone(&queue),
                seed_counter: Arc::new(AtomicU32::new(1)),
                metrics: Arc::clone(&metrics),
            },
            workers,
            queue,
            metrics,
        }
    }

    /// Client handle for submitting requests.
    pub fn handle(&self) -> SubmitHandle {
        self.handle.clone()
    }

    /// Shared metrics.
    pub fn metrics(&self) -> &ServerMetrics {
        &self.metrics
    }

    /// Instantaneous per-shard ingress depths (observability gauge).
    pub fn shard_depths(&self) -> Vec<usize> {
        self.queue.depths()
    }

    /// Drain and stop: queued and in-flight requests complete, new
    /// submissions fail with [`Error::Rejected`].
    pub fn shutdown(mut self) {
        self.queue.close();
        for w in std::mem::take(&mut self.workers) {
            let _ = w.join();
        }
    }

    /// Alias of [`Coordinator::shutdown`].
    pub fn stop(self) {
        self.shutdown()
    }
}

impl Drop for Coordinator {
    /// Parity with the old channel-based design, where dropping the
    /// coordinator disconnected the ingress channel: close the queue so
    /// the workers drain what is left and exit, instead of parking on
    /// the condvar forever. `shutdown()` additionally joins them; a bare
    /// drop only guarantees they terminate.
    fn drop(&mut self) {
        self.queue.close();
    }
}

fn worker_loop(
    id: usize,
    queue: Arc<ShardedQueue<InFlight>>,
    backend: Arc<dyn Backend>,
    metrics: Arc<ServerMetrics>,
    cfg: CoordinatorConfig,
) {
    let mut batcher: Batcher<InFlight> = Batcher::new(cfg.batch);
    // Per-worker steal-rotation cursor: the steal path touches no shared
    // atomic — each worker's sweeps walk the siblings on its own schedule.
    let mut steal_cursor = 0usize;
    loop {
        match batcher.poll(Instant::now()) {
            BatchDecision::Dispatch => {
                run_batch(&backend, &metrics, &cfg, batcher.take());
            }
            BatchDecision::Wait(timeout) => {
                // Fill the forming batch: own shard first, then steal.
                match queue.pop_some(id, batcher.remaining(), &mut steal_cursor) {
                    Popped::Items { items, stolen } => {
                        if stolen > 0 {
                            metrics.steals.fetch_add(stolen as u64, Ordering::Relaxed);
                        }
                        batcher.push_many(items, Instant::now());
                    }
                    Popped::Drained => {
                        // Every shard empty + closed: flush and exit.
                        if batcher.is_empty() {
                            return;
                        }
                        run_batch(&backend, &metrics, &cfg, batcher.take());
                    }
                    Popped::Empty => {
                        // Nothing to pop: park until new work, the batch
                        // deadline, or shutdown.
                        queue.wait(if batcher.is_empty() { IDLE_POLL } else { timeout });
                    }
                }
            }
        }
    }
}

fn run_batch(
    backend: &Arc<dyn Backend>,
    metrics: &ServerMetrics,
    cfg: &CoordinatorConfig,
    batch: Vec<InFlight>,
) {
    if batch.is_empty() {
        return;
    }
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.batched_items.fetch_add(batch.len() as u64, Ordering::Relaxed);

    let images: Vec<&Image> = batch.iter().map(|f| &f.request.image).collect();
    let seeds: Vec<u32> = batch.iter().map(|f| f.seed).collect();
    let parts = if backend.parallel_capable() {
        cfg.fanout.parts_for(batch.len())
    } else {
        // Splitting across a backend that serializes internally (the XLA
        // mutex) costs thread dispatch for zero overlap.
        1
    };
    let start = Instant::now();
    let result = if parts <= 1 {
        backend.classify_batch(&images, &seeds, cfg.early)
    } else {
        fan_out_batch(&**backend, metrics, cfg.early, &images, &seeds, parts)
    };
    metrics.batch_latency.record(start.elapsed());

    match result {
        Ok(outputs) => {
            debug_assert_eq!(outputs.len(), batch.len());
            for (inflight, out) in batch.into_iter().zip(outputs) {
                respond_ok(metrics, inflight, out);
            }
        }
        Err(e) => {
            // Batch-level failure: every request in it gets the error.
            let msg = e.to_string();
            for inflight in batch {
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                let _ = inflight.reply.try_send(Err(Error::Coordinator(msg.clone())));
            }
        }
    }
}

/// Split one large batch into `parts` contiguous sub-batches, run them
/// concurrently on the backend (whose engine pool hands each call a
/// private instance), and reassemble the outputs in submission order.
///
/// Ordering argument: `chunks` yields contiguous, non-overlapping slices
/// in ascending index order; sub-batch `k` is joined and appended before
/// sub-batch `k+1`, and every backend returns outputs positionally, so
/// `out[i]` is the result of `images[i]` regardless of which thread ran
/// it or when it finished. The stress suite pins this end to end.
fn fan_out_batch(
    backend: &dyn Backend,
    metrics: &ServerMetrics,
    early: EarlyExit,
    images: &[&Image],
    seeds: &[u32],
    parts: usize,
) -> Result<Vec<BackendOutput>> {
    let chunk = images.len().div_ceil(parts);
    metrics.fanout_batches.fetch_add(1, Ordering::Relaxed);
    std::thread::scope(|scope| {
        let mut tails = Vec::new();
        for (imgs, sds) in images[chunk..].chunks(chunk).zip(seeds[chunk..].chunks(chunk)) {
            tails.push(scope.spawn(move || backend.classify_batch(imgs, sds, early)));
        }
        metrics.subbatches.fetch_add(tails.len() as u64 + 1, Ordering::Relaxed);
        // Run the first sub-batch on this worker thread; the spawned tails
        // overlap with it.
        let mut out = backend.classify_batch(&images[..chunk], &seeds[..chunk], early)?;
        let mut first_err = None;
        for handle in tails {
            match handle.join().expect("sub-batch thread panicked") {
                Ok(mut part) => out.append(&mut part),
                Err(e) => first_err = first_err.or(Some(e)),
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    })
}

fn respond_ok(metrics: &ServerMetrics, inflight: InFlight, out: BackendOutput) {
    metrics.completed.fetch_add(1, Ordering::Relaxed);
    metrics.steps_executed.fetch_add(u64::from(out.steps_run), Ordering::Relaxed);
    metrics.latency.record(inflight.submitted.elapsed());
    let _ = inflight.reply.try_send(Ok(Response {
        class: out.class,
        spike_counts: out.spike_counts,
        steps_run: out.steps_run,
        seed: inflight.seed,
    }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SnnConfig;
    use crate::coordinator::backend::BehavioralBackend;
    use crate::data::{DigitGen, IMG_PIXELS};
    use crate::fixed::WeightMatrix;

    fn block_weights() -> WeightMatrix {
        let mut w = vec![0i32; 784 * 10];
        for i in 0..784 {
            let block = i / 79;
            if block < 10 {
                w[i * 10 + block] = 40;
            }
        }
        WeightMatrix::from_rows(784, 10, 9, w).unwrap()
    }

    fn block_image(class: usize) -> Image {
        let mut px = vec![0u8; IMG_PIXELS];
        for i in 0..784 {
            if i / 79 == class {
                px[i] = 250;
            }
        }
        Image { label: class as u8, pixels: px }
    }

    fn start_coordinator(workers: usize, queue: usize) -> Coordinator {
        let cfg = SnnConfig::paper().with_timesteps(6);
        let backend = Arc::new(BehavioralBackend::new(cfg, block_weights()).unwrap());
        Coordinator::start(
            backend,
            CoordinatorConfig {
                workers,
                queue_depth: queue,
                batch: BatchPolicy { max_batch: 4, max_delay: Duration::from_millis(1) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy::default(),
            },
        )
    }

    #[test]
    fn end_to_end_classification() {
        let coord = start_coordinator(2, 64);
        let handle = coord.handle();
        for class in 0..10usize {
            let resp = handle.classify(block_image(class)).unwrap();
            assert_eq!(resp.class as usize, class);
            assert_eq!(resp.steps_run, 6);
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.completed, 10);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_answered() {
        let coord = start_coordinator(3, 256);
        let handle = coord.handle();
        let receivers: Vec<_> = (0..64)
            .map(|i| {
                let img = block_image(i % 10);
                (i % 10, handle.submit(Request { image: img, seed: Some(42 + i as u32) }).unwrap())
            })
            .collect();
        for (class, rx) in receivers {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.class as usize, class);
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.completed, 64);
        assert!(snap.batches >= 16, "batches {}", snap.batches);
        coord.shutdown();
    }

    #[test]
    fn deterministic_with_explicit_seed() {
        let coord = start_coordinator(2, 64);
        let handle = coord.handle();
        let img = DigitGen::new(1).sample(4, 0);
        let a = handle
            .submit(Request { image: img.clone(), seed: Some(7) })
            .unwrap()
            .recv()
            .unwrap()
            .unwrap();
        let b = handle
            .submit(Request { image: img, seed: Some(7) })
            .unwrap()
            .recv()
            .unwrap()
            .unwrap();
        assert_eq!(a, b);
        coord.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // One worker, tiny queue, and a flood of submissions from this
        // thread: some must be rejected, none lost.
        let coord = start_coordinator(1, 2);
        let handle = coord.handle();
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for i in 0..200 {
            match handle.submit(Request { image: block_image(i % 10), seed: Some(i as u32) }) {
                Ok(rx) => accepted.push(rx),
                Err(Error::Rejected(_)) => rejected += 1,
                Err(e) => panic!("unexpected error {e}"),
            }
        }
        for rx in accepted {
            rx.recv().unwrap().unwrap();
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.completed + snap.rejected as u64, 200);
        assert_eq!(snap.rejected as usize, rejected);
        coord.shutdown();
    }

    #[test]
    fn shutdown_stops_new_work() {
        let coord = start_coordinator(1, 8);
        let handle = coord.handle();
        handle.classify(block_image(1)).unwrap();
        coord.shutdown();
        assert!(matches!(
            handle.submit(Request { image: block_image(1), seed: None }),
            Err(Error::Rejected(_))
        ));
    }

    #[test]
    fn early_exit_reduces_steps() {
        let cfg = SnnConfig::paper()
            .with_timesteps(20)
            .with_prune(crate::config::PruneMode::Off);
        let backend = Arc::new(BehavioralBackend::new(cfg, block_weights()).unwrap());
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 16,
                batch: BatchPolicy { max_batch: 1, max_delay: Duration::from_micros(100) },
                early: EarlyExit::Margin { margin: 3, min_steps: 2 },
                fanout: FanoutPolicy::default(),
            },
        );
        let resp = coord.handle().classify(block_image(5)).unwrap();
        assert_eq!(resp.class, 5);
        assert!(resp.steps_run < 20, "early exit did not trigger: {}", resp.steps_run);
        coord.shutdown();
    }

    #[test]
    fn fanout_policy_crossover() {
        let p = FanoutPolicy { min_batch: 32, max_parts: 4 };
        assert_eq!(p.parts_for(1), 1);
        assert_eq!(p.parts_for(31), 1, "below the crossover stays single-engine");
        assert_eq!(p.parts_for(32), 4);
        assert_eq!(p.parts_for(400), 4, "parts capped at max_parts");
        assert_eq!(FanoutPolicy::off().parts_for(1_000_000), 1);
        // Degenerate policies never split a batch of one.
        let eager = FanoutPolicy { min_batch: 0, max_parts: 8 };
        assert_eq!(eager.parts_for(1), 1);
        assert_eq!(eager.parts_for(3), 3, "parts never exceed the batch size");
    }

    /// A stub backend whose per-image cost is known and fixed (busy-spin:
    /// sleep granularity is far too coarse for µs-scale calibration).
    struct FixedCostBackend {
        cfg: SnnConfig,
        per_image: Duration,
    }

    impl Backend for FixedCostBackend {
        fn name(&self) -> &'static str {
            "fixed-cost-stub"
        }

        fn classify_batch(
            &self,
            images: &[&Image],
            seeds: &[u32],
            _early: EarlyExit,
        ) -> Result<Vec<BackendOutput>> {
            let until = Instant::now() + self.per_image * images.len() as u32;
            while Instant::now() < until {
                std::hint::spin_loop();
            }
            Ok(images
                .iter()
                .zip(seeds)
                .map(|(_, &s)| BackendOutput {
                    class: (s % 10) as u8,
                    spike_counts: vec![0; 10],
                    steps_run: 1,
                })
                .collect())
        }

        fn config(&self) -> &SnnConfig {
            &self.cfg
        }
    }

    #[test]
    fn calibrated_fanout_adapts_to_backend_cost() {
        // The derivation is pure — pin the crossover math first.
        assert_eq!(
            FanoutPolicy::from_cost(Duration::from_micros(480), 4),
            FanoutPolicy { min_batch: 2, max_parts: 4 }
        );
        let fast = FanoutPolicy::from_cost(Duration::from_nanos(100), 8);
        assert_eq!(fast, FanoutPolicy { min_batch: 4800, max_parts: 8 });
        // Monotone: a slower backend gets a lower crossover.
        assert!(
            FanoutPolicy::from_cost(Duration::from_micros(10), 4).min_batch
                > FanoutPolicy::from_cost(Duration::from_micros(100), 4).min_batch
        );
        // Degenerate inputs clamp sanely.
        assert_eq!(FanoutPolicy::from_cost(Duration::ZERO, 0).max_parts, 1);
        assert!(FanoutPolicy::from_cost(Duration::ZERO, 1).min_batch <= 1 << 16);

        // The measured probe on stubs of known cost: the slow stub must
        // calibrate to (near) the floor, the zero-cost stub far above it,
        // and max_parts must follow the pool's slot count.
        let slow = FixedCostBackend {
            cfg: SnnConfig::paper(),
            per_image: Duration::from_micros(500),
        };
        let p_slow = FanoutPolicy::calibrated(&slow, 4);
        assert_eq!(p_slow.max_parts, 4);
        assert!(
            p_slow.min_batch <= 4,
            "slow backend must fan out early, got crossover {}",
            p_slow.min_batch
        );
        let echo = FixedCostBackend { cfg: SnnConfig::paper(), per_image: Duration::ZERO };
        let p_echo = FanoutPolicy::calibrated(&echo, 2);
        assert_eq!(p_echo.max_parts, 2);
        assert!(
            p_echo.min_batch > p_slow.min_batch && p_echo.min_batch >= 8,
            "echo-fast backend must get a much higher crossover, got {}",
            p_echo.min_batch
        );
    }

    #[test]
    fn fanned_out_batch_reassembles_in_submission_order() {
        // One worker, a batch policy that forms one large batch, and a
        // fan-out policy that splits it: every reply must still carry the
        // answer for its own (image, seed).
        let cfg = SnnConfig::paper().with_timesteps(6);
        let backend = Arc::new(BehavioralBackend::new(cfg, block_weights()).unwrap());
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 256,
                batch: BatchPolicy { max_batch: 40, max_delay: Duration::from_millis(20) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy { min_batch: 8, max_parts: 4 },
            },
        );
        let handle = coord.handle();
        let receivers: Vec<_> = (0..40)
            .map(|i| {
                let class = i % 10;
                let rx = handle
                    .submit(Request { image: block_image(class), seed: Some(1000 + i as u32) })
                    .unwrap();
                (class, rx)
            })
            .collect();
        for (class, rx) in receivers {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.class as usize, class, "reply wired to the wrong request");
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.completed, 40);
        assert!(snap.fanout_batches >= 1, "large batch must fan out");
        assert!(
            snap.subbatches >= 2 * snap.fanout_batches,
            "fanned batches must split into >= 2 parts: {} batches, {} parts",
            snap.fanout_batches,
            snap.subbatches
        );
        coord.shutdown();
    }

    #[test]
    fn shard_depth_gauges_exposed() {
        let coord = start_coordinator(3, 96);
        assert_eq!(coord.shard_depths().len(), 3);
        coord.shutdown();
    }
}
