//! The coordinator proper: ingress router + worker pool + response plumbing.

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::data::Image;
use crate::error::{Error, Result};
use crate::snn::EarlyExit;

use super::backend::{Backend, BackendOutput};
use super::batcher::{BatchDecision, BatchPolicy, Batcher};
use super::metrics::ServerMetrics;

/// A classification request.
#[derive(Debug, Clone)]
pub struct Request {
    pub image: Image,
    /// Encoder seed; `None` lets the coordinator assign one from its
    /// request counter (deterministic given submission order).
    pub seed: Option<u32>,
}

/// A classification response.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Response {
    pub class: u8,
    pub spike_counts: Vec<u32>,
    pub steps_run: u32,
    /// Seed the encoder actually used (echo for reproducibility).
    pub seed: u32,
}

struct InFlight {
    request: Request,
    seed: u32,
    submitted: Instant,
    reply: SyncSender<Result<Response>>,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Worker threads pulling batches.
    pub workers: usize,
    /// Ingress queue capacity (backpressure bound).
    pub queue_depth: usize,
    /// Batch forming policy.
    pub batch: BatchPolicy,
    /// Early-exit policy handed to the backend.
    pub early: EarlyExit,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            queue_depth: 256,
            batch: BatchPolicy::default(),
            early: EarlyExit::Off,
        }
    }
}

/// Client handle: cheap to clone, submits requests.
#[derive(Clone)]
pub struct SubmitHandle {
    tx: SyncSender<InFlight>,
    seed_counter: Arc<AtomicU32>,
    metrics: Arc<ServerMetrics>,
}

impl SubmitHandle {
    /// Submit a request; returns the receiver for its response. Fails fast
    /// with [`Error::Rejected`] when the ingress queue is full
    /// (backpressure) or the server is shutting down.
    pub fn submit(&self, request: Request) -> Result<Receiver<Result<Response>>> {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        let seed = request
            .seed
            .unwrap_or_else(|| self.seed_counter.fetch_add(1, Ordering::Relaxed));
        let inflight =
            InFlight { request, seed, submitted: Instant::now(), reply: reply_tx };
        match self.tx.try_send(inflight) {
            Ok(()) => {
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(reply_rx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(Error::Rejected("ingress queue full".into()))
            }
            Err(TrySendError::Disconnected(_)) => {
                Err(Error::Rejected("coordinator is shut down".into()))
            }
        }
    }

    /// Submit and block for the response (convenience).
    pub fn classify(&self, image: Image) -> Result<Response> {
        let rx = self.submit(Request { image, seed: None })?;
        rx.recv()
            .map_err(|_| Error::Coordinator("worker dropped the reply channel".into()))?
    }
}

/// The running coordinator.
pub struct Coordinator {
    handle: SubmitHandle,
    workers: Vec<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    metrics: Arc<ServerMetrics>,
}

impl Coordinator {
    /// Start the worker pool over `backend`.
    pub fn start(backend: Arc<dyn Backend>, cfg: CoordinatorConfig) -> Self {
        assert!(cfg.workers >= 1);
        let (tx, rx) = mpsc::sync_channel::<InFlight>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let shutdown = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(ServerMetrics::default());

        let workers = (0..cfg.workers)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let backend = Arc::clone(&backend);
                let shutdown = Arc::clone(&shutdown);
                let metrics = Arc::clone(&metrics);
                let cfg = cfg.clone();
                std::thread::spawn(move || worker_loop(rx, backend, shutdown, metrics, cfg))
            })
            .collect();

        Coordinator {
            handle: SubmitHandle {
                tx,
                seed_counter: Arc::new(AtomicU32::new(1)),
                metrics: Arc::clone(&metrics),
            },
            workers,
            shutdown,
            metrics,
        }
    }

    /// Client handle for submitting requests.
    pub fn handle(&self) -> SubmitHandle {
        self.handle.clone()
    }

    /// Shared metrics.
    pub fn metrics(&self) -> &ServerMetrics {
        &self.metrics
    }

    /// Drain and stop: in-flight requests complete, new submissions fail.
    pub fn shutdown(self) {
        self.shutdown.store(true, Ordering::SeqCst);
        drop(self.handle); // close the channel so workers see disconnect
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<InFlight>>>,
    backend: Arc<dyn Backend>,
    shutdown: Arc<AtomicBool>,
    metrics: Arc<ServerMetrics>,
    cfg: CoordinatorConfig,
) {
    let mut batcher: Batcher<InFlight> = Batcher::new(cfg.batch);
    loop {
        // Form a batch: block for the first item, then fill until the
        // policy says dispatch.
        let decision = batcher.poll(Instant::now());
        match decision {
            BatchDecision::Dispatch => {
                run_batch(&backend, &metrics, &cfg, batcher.take());
            }
            BatchDecision::Wait(timeout) => {
                let item = {
                    let guard = rx.lock().unwrap();
                    if batcher.is_empty() {
                        // Nothing pending: block indefinitely-ish, but wake
                        // periodically to observe shutdown.
                        guard.recv_timeout(std::time::Duration::from_millis(50))
                    } else {
                        guard.recv_timeout(timeout)
                    }
                };
                match item {
                    Ok(inflight) => batcher.push(inflight, Instant::now()),
                    Err(RecvTimeoutError::Timeout) => {
                        if !batcher.is_empty() {
                            run_batch(&backend, &metrics, &cfg, batcher.take());
                        } else if shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        if !batcher.is_empty() {
                            run_batch(&backend, &metrics, &cfg, batcher.take());
                        }
                        return;
                    }
                }
            }
        }
    }
}

fn run_batch(
    backend: &Arc<dyn Backend>,
    metrics: &ServerMetrics,
    cfg: &CoordinatorConfig,
    batch: Vec<InFlight>,
) {
    if batch.is_empty() {
        return;
    }
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.batched_items.fetch_add(batch.len() as u64, Ordering::Relaxed);

    let images: Vec<&Image> = batch.iter().map(|f| &f.request.image).collect();
    let seeds: Vec<u32> = batch.iter().map(|f| f.seed).collect();
    let start = Instant::now();
    let result = backend.classify_batch(&images, &seeds, cfg.early);
    metrics.batch_latency.record(start.elapsed());

    match result {
        Ok(outputs) => {
            debug_assert_eq!(outputs.len(), batch.len());
            for (inflight, out) in batch.into_iter().zip(outputs) {
                respond_ok(metrics, inflight, out);
            }
        }
        Err(e) => {
            // Batch-level failure: every request in it gets the error.
            let msg = e.to_string();
            for inflight in batch {
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                let _ = inflight.reply.try_send(Err(Error::Coordinator(msg.clone())));
            }
        }
    }
}

fn respond_ok(metrics: &ServerMetrics, inflight: InFlight, out: BackendOutput) {
    metrics.completed.fetch_add(1, Ordering::Relaxed);
    metrics.steps_executed.fetch_add(u64::from(out.steps_run), Ordering::Relaxed);
    metrics.latency.record(inflight.submitted.elapsed());
    let _ = inflight.reply.try_send(Ok(Response {
        class: out.class,
        spike_counts: out.spike_counts,
        steps_run: out.steps_run,
        seed: inflight.seed,
    }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SnnConfig;
    use crate::coordinator::backend::BehavioralBackend;
    use crate::data::{DigitGen, IMG_PIXELS};
    use crate::fixed::WeightMatrix;
    use std::time::Duration;

    fn block_weights() -> WeightMatrix {
        let mut w = vec![0i32; 784 * 10];
        for i in 0..784 {
            let block = i / 79;
            if block < 10 {
                w[i * 10 + block] = 40;
            }
        }
        WeightMatrix::from_rows(784, 10, 9, w).unwrap()
    }

    fn block_image(class: usize) -> Image {
        let mut px = vec![0u8; IMG_PIXELS];
        for i in 0..784 {
            if i / 79 == class {
                px[i] = 250;
            }
        }
        Image { label: class as u8, pixels: px }
    }

    fn start_coordinator(workers: usize, queue: usize) -> Coordinator {
        let cfg = SnnConfig::paper().with_timesteps(6);
        let backend = Arc::new(BehavioralBackend::new(cfg, block_weights()).unwrap());
        Coordinator::start(
            backend,
            CoordinatorConfig {
                workers,
                queue_depth: queue,
                batch: BatchPolicy { max_batch: 4, max_delay: Duration::from_millis(1) },
                early: EarlyExit::Off,
            },
        )
    }

    #[test]
    fn end_to_end_classification() {
        let coord = start_coordinator(2, 64);
        let handle = coord.handle();
        for class in 0..10usize {
            let resp = handle.classify(block_image(class)).unwrap();
            assert_eq!(resp.class as usize, class);
            assert_eq!(resp.steps_run, 6);
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.completed, 10);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_answered() {
        let coord = start_coordinator(3, 256);
        let handle = coord.handle();
        let receivers: Vec<_> = (0..64)
            .map(|i| {
                let img = block_image(i % 10);
                (i % 10, handle.submit(Request { image: img, seed: Some(42 + i as u32) }).unwrap())
            })
            .collect();
        for (class, rx) in receivers {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.class as usize, class);
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.completed, 64);
        assert!(snap.batches >= 16, "batches {}", snap.batches);
        coord.shutdown();
    }

    #[test]
    fn deterministic_with_explicit_seed() {
        let coord = start_coordinator(2, 64);
        let handle = coord.handle();
        let img = DigitGen::new(1).sample(4, 0);
        let a = handle
            .submit(Request { image: img.clone(), seed: Some(7) })
            .unwrap()
            .recv()
            .unwrap()
            .unwrap();
        let b = handle
            .submit(Request { image: img, seed: Some(7) })
            .unwrap()
            .recv()
            .unwrap()
            .unwrap();
        assert_eq!(a, b);
        coord.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // One worker, tiny queue, and a flood of submissions from this
        // thread: some must be rejected, none lost.
        let coord = start_coordinator(1, 2);
        let handle = coord.handle();
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for i in 0..200 {
            match handle.submit(Request { image: block_image(i % 10), seed: Some(i as u32) }) {
                Ok(rx) => accepted.push(rx),
                Err(Error::Rejected(_)) => rejected += 1,
                Err(e) => panic!("unexpected error {e}"),
            }
        }
        for rx in accepted {
            rx.recv().unwrap().unwrap();
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.completed + snap.rejected as u64, 200);
        assert_eq!(snap.rejected as usize, rejected);
        coord.shutdown();
    }

    #[test]
    fn shutdown_stops_new_work() {
        let coord = start_coordinator(1, 8);
        let handle = coord.handle();
        handle.classify(block_image(1)).unwrap();
        coord.shutdown();
        assert!(matches!(
            handle.submit(Request { image: block_image(1), seed: None }),
            Err(Error::Rejected(_))
        ));
    }

    #[test]
    fn early_exit_reduces_steps() {
        let cfg = SnnConfig::paper()
            .with_timesteps(20)
            .with_prune(crate::config::PruneMode::Off);
        let backend = Arc::new(BehavioralBackend::new(cfg, block_weights()).unwrap());
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 16,
                batch: BatchPolicy { max_batch: 1, max_delay: Duration::from_micros(100) },
                early: EarlyExit::Margin { margin: 3, min_steps: 2 },
            },
        );
        let resp = coord.handle().classify(block_image(5)).unwrap();
        assert_eq!(resp.class, 5);
        assert!(resp.steps_run < 20, "early exit did not trigger: {}", resp.steps_run);
        coord.shutdown();
    }
}
