//! The coordinator proper: sharded ingress router + supervised
//! work-stealing worker pool + intra-batch fan-out + response plumbing.
//!
//! # Fault model
//!
//! Every submitted request resolves to **exactly one terminal reply**:
//!
//! * `Ok(Response)` — classified (possibly after one transparent retry);
//! * `Err(Overloaded)` — refused at submit time, all ingress shards full;
//! * `Err(Shed)` — its deadline expired before the backend ran it (at
//!   submit or at pop time);
//! * `Err(BackendPanicked)` / a typed backend error — the batch (and its
//!   one retry) failed;
//! * `Err(ShuttingDown)` — the coordinator stopped before running it.
//!
//! The conservation argument: a request lives in exactly one place at a
//! time — the ingress queue, a worker's forming batch, or `run_batch` —
//! and every exit from each place sends a reply. `run_batch` sends all of
//! its replies (success, shed, or replicated error) *before* the worker
//! re-raises a caught backend panic, so a dying worker never carries
//! unanswered requests with it; the supervisor respawns the worker
//! (bounded restarts, exponential backoff) and, if every worker is gone
//! for good, sweeps the queue and rejects the leftovers with
//! `ShuttingDown`. Backend panics are contained by `catch_unwind` at the
//! engine-call boundary, and any engine that was checked out at the time
//! is quarantined by the pool instead of being reused.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::data::Image;
use crate::error::{Error, Result};
use crate::snn::EarlyExit;

use super::backend::{Backend, BackendOutput};
use super::batcher::{BatchDecision, BatchPolicy, Batcher};
use super::metrics::ServerMetrics;
use super::shard::{Popped, PushError, ShardedQueue};

/// How long an idle worker parks between shutdown checks.
const IDLE_POLL: Duration = Duration::from_millis(50);

/// How often the supervisor checks its workers for panic deaths.
const SUPERVISE_POLL: Duration = Duration::from_millis(2);

/// A caught panic's payload, carried out of the guarded backend call so
/// the worker can re-raise it once every reply in the batch is out.
type PanicPayload = Box<dyn std::any::Any + Send + 'static>;

/// A classification request.
#[derive(Debug, Clone)]
pub struct Request {
    pub image: Image,
    /// Encoder seed; `None` lets the coordinator assign one from its
    /// request counter (deterministic given submission order).
    pub seed: Option<u32>,
    /// Optional deadline: once passed, the coordinator sheds the request
    /// (typed `Shed` reply) instead of running work nobody awaits.
    pub deadline: Option<Instant>,
}

impl Request {
    /// A request with no explicit seed and no deadline.
    pub fn new(image: Image) -> Self {
        Request { image, seed: None, deadline: None }
    }

    /// Pin the encoder seed (reproducibility).
    pub fn with_seed(mut self, seed: u32) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Set the shedding deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }
}

/// A classification response.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Response {
    pub class: u8,
    pub spike_counts: Vec<u32>,
    pub steps_run: u32,
    /// Seed the encoder actually used (echo for reproducibility).
    pub seed: u32,
}

struct InFlight {
    request: Request,
    seed: u32,
    submitted: Instant,
    deadline: Option<Instant>,
    reply: SyncSender<Result<Response>>,
}

/// Intra-batch fan-out policy: when a formed batch is large enough, split
/// it into sub-batches dispatched concurrently across pooled engines and
/// reassembled in submission order — latency parallelism for one big
/// request burst, not just throughput across bursts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FanoutPolicy {
    /// Crossover threshold: batches smaller than this keep the
    /// single-engine path (splitting tiny batches costs more in thread
    /// dispatch than it saves in compute).
    pub min_batch: usize,
    /// Maximum sub-batches one batch splits into. Keep at or below the
    /// backend's pool capacity, or the extra parts just queue.
    pub max_parts: usize,
}

impl Default for FanoutPolicy {
    fn default() -> Self {
        FanoutPolicy { min_batch: 32, max_parts: 4 }
    }
}

impl FanoutPolicy {
    /// Disable fan-out entirely (every batch runs on one engine).
    pub fn off() -> Self {
        FanoutPolicy { min_batch: usize::MAX, max_parts: 1 }
    }

    /// Number of sub-batches a batch of `n` splits into (1 = no fan-out).
    pub fn parts_for(&self, n: usize) -> usize {
        if self.max_parts <= 1 || n < self.min_batch.max(2) {
            1
        } else {
            self.max_parts.min(n)
        }
    }

    /// Approximate overhead of dispatching one fan-out sub-batch (scoped
    /// thread spawn + join + reassembly). The calibration constant behind
    /// [`FanoutPolicy::from_cost`].
    pub const DISPATCH_COST: Duration = Duration::from_micros(120);

    /// Derive the crossover from a *measured* per-image cost and the
    /// engine pool's slot count — the adaptive replacement for the fixed
    /// `32/4` defaults. Splitting a batch of `n` into two halves saves
    /// `n/2 · c` of serialized compute and pays ~2 dispatches, so fan-out
    /// starts earning its keep from `n > 4·D/c`: a slow backend (large
    /// `c`) wants a low crossover, an echo-fast one a high crossover.
    /// `max_parts` is the pool's slot count — more parts than engines
    /// just queue. Deterministic given its inputs (the probe lives in
    /// [`FanoutPolicy::calibrated`]).
    pub fn from_cost(per_image: Duration, pool_slots: usize) -> FanoutPolicy {
        let per_image_ns = per_image.as_nanos().max(1);
        let min_batch = (4 * Self::DISPATCH_COST.as_nanos())
            .div_ceil(per_image_ns)
            .clamp(2, 1 << 16) as usize;
        FanoutPolicy { min_batch, max_parts: pool_slots.max(1) }
    }

    /// One-shot measured calibration: probe the backend with a small
    /// synthetic batch (mid-gray images, fixed seeds — deterministic
    /// work), take the per-image wall cost, and derive the policy via
    /// [`FanoutPolicy::from_cost`].
    pub fn calibrated(backend: &dyn Backend, pool_slots: usize) -> FanoutPolicy {
        const PROBE: usize = 4;
        const REPS: u32 = 3;
        let n = backend.config().n_inputs();
        let images: Vec<Image> = (0..PROBE)
            .map(|i| Image { label: 0, pixels: vec![64 + 32 * i as u8; n] })
            .collect();
        let refs: Vec<&Image> = images.iter().collect();
        let seeds: Vec<u32> = (1..=PROBE as u32).collect();
        // Warmup builds the pool instance and faults the weights in.
        let _ = backend.classify_batch(&refs, &seeds, EarlyExit::Off);
        let t0 = Instant::now();
        for _ in 0..REPS {
            let _ = backend.classify_batch(&refs, &seeds, EarlyExit::Off);
        }
        let per_image = t0.elapsed() / (REPS * PROBE as u32);
        Self::from_cost(per_image, pool_slots)
    }
}

/// Worker supervision: how aggressively panic-killed workers respawn.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SupervisionPolicy {
    /// Restart budget per worker slot; a slot that exhausts it stays
    /// dead. When every slot is dead the coordinator rejects the backlog
    /// (`ShuttingDown`) instead of stranding it.
    pub max_restarts_per_worker: u32,
    /// First-restart backoff; doubles per consecutive restart of the
    /// same slot.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
}

impl Default for SupervisionPolicy {
    fn default() -> Self {
        SupervisionPolicy {
            max_restarts_per_worker: 64,
            backoff_base: Duration::from_micros(200),
            backoff_cap: Duration::from_millis(5),
        }
    }
}

impl SupervisionPolicy {
    fn backoff_for(&self, restarts: u32) -> Duration {
        // Shift capped at 2^8 so the multiplier cannot overflow; the
        // duration itself is clamped to the configured ceiling anyway.
        let mult = 1u32 << restarts.min(8);
        (self.backoff_base * mult).min(self.backoff_cap)
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Worker threads pulling batches (also the ingress shard count).
    pub workers: usize,
    /// Total ingress queue capacity across all shards (backpressure
    /// bound). Split evenly across shards, rounded up — so the effective
    /// bound is the next multiple of `workers` when it does not divide
    /// evenly.
    pub queue_depth: usize,
    /// Batch forming policy.
    pub batch: BatchPolicy,
    /// Early-exit policy handed to the backend.
    pub early: EarlyExit,
    /// Intra-batch fan-out policy.
    pub fanout: FanoutPolicy,
    /// Worker restart policy after panic deaths.
    pub supervision: SupervisionPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            queue_depth: 256,
            batch: BatchPolicy::default(),
            early: EarlyExit::Off,
            fanout: FanoutPolicy::default(),
            supervision: SupervisionPolicy::default(),
        }
    }
}

/// Client handle: cheap to clone, submits requests.
#[derive(Clone)]
pub struct SubmitHandle {
    queue: Arc<ShardedQueue<InFlight>>,
    seed_counter: Arc<AtomicU32>,
    metrics: Arc<ServerMetrics>,
}

impl SubmitHandle {
    /// Submit a request; returns the receiver for its response. Fails
    /// fast — never blocks — with [`Error::Overloaded`] when every
    /// ingress shard is full (backpressure), [`Error::ShuttingDown`]
    /// after shutdown, or [`Error::Shed`] when the request's deadline has
    /// already passed.
    pub fn submit(&self, request: Request) -> Result<Receiver<Result<Response>>> {
        if request.deadline.is_some_and(|d| d <= Instant::now()) {
            self.metrics.rejected.fetch_add(1, Ordering::Release);
            self.metrics.deadline_expired.fetch_add(1, Ordering::Release);
            return Err(Error::Shed("deadline already expired at submit".into()));
        }
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        let seed = request
            .seed
            .unwrap_or_else(|| self.seed_counter.fetch_add(1, Ordering::Relaxed));
        let deadline = request.deadline;
        let inflight =
            InFlight { request, seed, submitted: Instant::now(), deadline, reply: reply_tx };
        match self.queue.push(inflight) {
            Ok(_shard) => {
                self.metrics.submitted.fetch_add(1, Ordering::Release);
                Ok(reply_rx)
            }
            Err(PushError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Release);
                Err(Error::Overloaded("every ingress shard is at capacity".into()))
            }
            Err(PushError::Closed(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Release);
                Err(Error::ShuttingDown("coordinator is shut down".into()))
            }
        }
    }

    /// Submit and block for the response (convenience).
    pub fn classify(&self, image: Image) -> Result<Response> {
        let rx = self.submit(Request::new(image))?;
        rx.recv()
            .map_err(|_| Error::Coordinator("worker dropped the reply channel".into()))?
    }

    /// Submit with a deadline and block at most `timeout` for the
    /// response. The deadline rides along on the request, so a timed-out
    /// caller's work is shed in the queue instead of computed for nobody;
    /// the wait itself resolves with [`Error::Timeout`] if no terminal
    /// reply arrives in time. No caller of this method can block forever.
    pub fn classify_timeout(&self, image: Image, timeout: Duration) -> Result<Response> {
        let deadline = Instant::now() + timeout;
        let rx = self.submit(Request::new(image).with_deadline(deadline))?;
        match rx.recv_timeout(timeout) {
            Ok(result) => result,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Err(Error::Timeout(format!("no reply within {timeout:?}")))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(Error::Coordinator("worker dropped the reply channel".into()))
            }
        }
    }
}

/// Everything a worker (or its supervisor, to respawn one) needs.
struct WorkerCtx {
    queue: Arc<ShardedQueue<InFlight>>,
    backend: Arc<dyn Backend>,
    metrics: Arc<ServerMetrics>,
    cfg: CoordinatorConfig,
}

struct WorkerSlot {
    id: usize,
    handle: Option<JoinHandle<()>>,
    restarts: u32,
}

/// The running coordinator.
pub struct Coordinator {
    handle: SubmitHandle,
    supervisor: Option<JoinHandle<()>>,
    queue: Arc<ShardedQueue<InFlight>>,
    metrics: Arc<ServerMetrics>,
}

impl Coordinator {
    /// Start the worker pool over `backend`. Each worker owns one ingress
    /// shard; the submit path load-balances across them and workers steal
    /// from siblings when their own shard runs dry. A supervisor thread
    /// watches the pool: a worker killed by a backend panic is respawned
    /// under [`SupervisionPolicy`], so no worker thread stays dead.
    pub fn start(backend: Arc<dyn Backend>, cfg: CoordinatorConfig) -> Self {
        assert!(cfg.workers >= 1);
        let queue = Arc::new(ShardedQueue::new(cfg.workers, cfg.queue_depth));
        let metrics = Arc::new(ServerMetrics::default());

        let ctx = WorkerCtx {
            queue: Arc::clone(&queue),
            backend,
            metrics: Arc::clone(&metrics),
            cfg: cfg.clone(),
        };
        let slots: Vec<WorkerSlot> = (0..cfg.workers)
            .map(|id| WorkerSlot { id, handle: Some(spawn_worker(id, &ctx)), restarts: 0 })
            .collect();
        let supervisor = std::thread::spawn(move || supervisor_loop(ctx, slots));

        Coordinator {
            handle: SubmitHandle {
                queue: Arc::clone(&queue),
                seed_counter: Arc::new(AtomicU32::new(1)),
                metrics: Arc::clone(&metrics),
            },
            supervisor: Some(supervisor),
            queue,
            metrics,
        }
    }

    /// Client handle for submitting requests.
    pub fn handle(&self) -> SubmitHandle {
        self.handle.clone()
    }

    /// Shared metrics.
    pub fn metrics(&self) -> &ServerMetrics {
        &self.metrics
    }

    /// Instantaneous per-shard ingress depths (observability gauge).
    pub fn shard_depths(&self) -> Vec<usize> {
        self.queue.depths()
    }

    /// Drain and stop: queued and in-flight requests complete (or resolve
    /// with a typed error — nothing is dropped on the floor, even if a
    /// worker dies mid-drain), new submissions fail with
    /// [`Error::ShuttingDown`].
    pub fn shutdown(mut self) {
        self.queue.close();
        if let Some(sup) = self.supervisor.take() {
            let _ = sup.join();
        }
    }

    /// Alias of [`Coordinator::shutdown`].
    pub fn stop(self) {
        self.shutdown()
    }
}

impl Drop for Coordinator {
    /// Parity with the old channel-based design, where dropping the
    /// coordinator disconnected the ingress channel: close the queue so
    /// the workers drain what is left and exit, instead of parking on
    /// the condvar forever. `shutdown()` additionally joins the
    /// supervisor; a bare drop only guarantees termination.
    fn drop(&mut self) {
        self.queue.close();
    }
}

fn spawn_worker(id: usize, ctx: &WorkerCtx) -> JoinHandle<()> {
    let queue = Arc::clone(&ctx.queue);
    let backend = Arc::clone(&ctx.backend);
    let metrics = Arc::clone(&ctx.metrics);
    let cfg = ctx.cfg.clone();
    std::thread::spawn(move || worker_loop(id, queue, backend, metrics, cfg))
}

/// Watch the worker slots; respawn panic deaths within budget. A worker
/// that returns normally finished a clean drain (queue closed and empty)
/// and leaves its slot retired. When every slot is retired or out of
/// budget, sweep whatever is still queued and give each request a typed
/// `ShuttingDown` reply — the drain-or-reject half of shutdown.
fn supervisor_loop(ctx: WorkerCtx, mut slots: Vec<WorkerSlot>) {
    loop {
        let mut alive = 0usize;
        for slot in &mut slots {
            if slot.handle.as_ref().is_some_and(JoinHandle::is_finished) {
                let died = slot.handle.take().expect("checked above").join().is_err();
                let drained = ctx.queue.is_closed() && ctx.queue.is_empty();
                let budget = ctx.cfg.supervision.max_restarts_per_worker;
                if died && !drained && slot.restarts < budget {
                    std::thread::sleep(ctx.cfg.supervision.backoff_for(slot.restarts));
                    slot.restarts += 1;
                    ctx.metrics.worker_restarts.fetch_add(1, Ordering::Release);
                    slot.handle = Some(spawn_worker(slot.id, &ctx));
                }
            }
            alive += usize::from(slot.handle.is_some());
        }
        if alive == 0 {
            break;
        }
        std::thread::sleep(SUPERVISE_POLL);
    }
    reject_leftovers(&ctx);
}

/// Terminal sweep: nothing is left to run requests, so every request
/// still queued gets exactly one `ShuttingDown` reply.
fn reject_leftovers(ctx: &WorkerCtx) {
    // Idempotent; also covers the every-worker-out-of-budget path, where
    // the queue is still open but permanently unserved.
    ctx.queue.close();
    let mut cursor = 0usize;
    loop {
        match ctx.queue.pop_some(0, 64, &mut cursor) {
            Popped::Items { items, .. } => {
                for inflight in items {
                    // Terminal counters bump with Release: they pair with
                    // the snapshot's Acquire loads so the conservation
                    // law `submitted >= completed + failed + shed` holds
                    // in every concurrent snapshot (see
                    // `ServerMetrics::snapshot`).
                    ctx.metrics.failed.fetch_add(1, Ordering::Release);
                    let msg = "coordinator stopped before this request ran";
                    let _ = inflight.reply.try_send(Err(Error::ShuttingDown(msg.into())));
                }
            }
            Popped::Drained => return,
            // Unreachable once the queue is closed and empty shards are
            // observed atomically, but parking briefly is safer than
            // spinning if that ever changes.
            Popped::Empty => std::thread::sleep(Duration::from_millis(1)),
        }
    }
}

// pallas-lint: hot
fn worker_loop(
    id: usize,
    queue: Arc<ShardedQueue<InFlight>>,
    backend: Arc<dyn Backend>,
    metrics: Arc<ServerMetrics>,
    cfg: CoordinatorConfig,
) {
    let mut batcher: Batcher<InFlight> = Batcher::new(cfg.batch);
    // Per-worker steal-rotation cursor: the steal path touches no shared
    // atomic — each worker's sweeps walk the siblings on its own schedule.
    let mut steal_cursor = 0usize;
    loop {
        match batcher.poll(Instant::now()) {
            BatchDecision::Dispatch => {
                dispatch(&backend, &metrics, &cfg, batcher.take());
            }
            BatchDecision::Wait(timeout) => {
                // Fill the forming batch: own shard first, then steal.
                match queue.pop_some(id, batcher.remaining(), &mut steal_cursor) {
                    Popped::Items { items, stolen } => {
                        if stolen > 0 {
                            metrics.steals.fetch_add(stolen as u64, Ordering::Release);
                        }
                        batcher.push_many(items, Instant::now());
                    }
                    Popped::Drained => {
                        // Every shard empty + closed: flush and exit.
                        if batcher.is_empty() {
                            return;
                        }
                        dispatch(&backend, &metrics, &cfg, batcher.take());
                    }
                    Popped::Empty => {
                        // Nothing to pop: park until new work, the batch
                        // deadline, the *soonest request deadline*, or
                        // shutdown. Without the deadline bound, an
                        // expired request on a quiet shard sat un-shed
                        // until the next push or the full batch delay
                        // woke the worker — its typed `Shed` reply
                        // arrived arbitrarily late (idle-shard deadline
                        // starvation; pinned by
                        // `idle_shard_sheds_expired_deadline_on_time`).
                        let park = if batcher.is_empty() { IDLE_POLL } else { timeout };
                        let now = Instant::now();
                        match soonest_deadline(batcher.items()) {
                            // A deadline already passed: dispatch now so
                            // `run_batch`'s pop-time shed sends the
                            // reply instead of computing for nobody.
                            Some(d) if d <= now => {
                                dispatch(&backend, &metrics, &cfg, batcher.take());
                            }
                            Some(d) => queue.wait(park.min(d - now)),
                            None => queue.wait(park),
                        }
                    }
                }
            }
        }
    }
}
// pallas-lint: end-hot

/// Earliest deadline among a forming batch's requests, if any carries
/// one.
fn soonest_deadline(items: &[InFlight]) -> Option<Instant> {
    items.iter().filter_map(|f| f.deadline).min()
}

/// Run one batch; if the backend panicked underneath it, re-raise the
/// panic *after* every reply is sent. The worker thread genuinely dies —
/// "let it crash" — and the supervisor replaces it with a fresh one, so
/// `worker_restarts` counts panicked batches one for one and no state
/// from the panicking call survives in the worker.
fn dispatch(
    backend: &Arc<dyn Backend>,
    metrics: &ServerMetrics,
    cfg: &CoordinatorConfig,
    batch: Vec<InFlight>,
) {
    if let Some(payload) = run_batch(backend, metrics, cfg, batch) {
        std::panic::resume_unwind(payload);
    }
}

/// Execute a batch and send exactly one terminal reply per request.
/// Returns the first caught panic payload, if any, for the worker to
/// re-raise (after the replies — see the module-level fault model).
fn run_batch(
    backend: &Arc<dyn Backend>,
    metrics: &ServerMetrics,
    cfg: &CoordinatorConfig,
    batch: Vec<InFlight>,
) -> Option<PanicPayload> {
    if batch.is_empty() {
        return None;
    }
    // Deadline check at pop time: work that nobody is waiting for any
    // more is shed, not computed.
    let now = Instant::now();
    let mut live = Vec::with_capacity(batch.len());
    for inflight in batch {
        if inflight.deadline.is_some_and(|d| d <= now) {
            metrics.shed.fetch_add(1, Ordering::Release);
            metrics.deadline_expired.fetch_add(1, Ordering::Release);
            let err = Error::Shed("deadline expired before execution".into());
            let _ = inflight.reply.try_send(Err(err));
        } else {
            live.push(inflight);
        }
    }
    if live.is_empty() {
        return None;
    }
    metrics.batches.fetch_add(1, Ordering::Release);
    metrics.batched_items.fetch_add(live.len() as u64, Ordering::Release);

    let images: Vec<&Image> = live.iter().map(|f| &f.request.image).collect();
    let seeds: Vec<u32> = live.iter().map(|f| f.seed).collect();
    let parts = if backend.parallel_capable() {
        cfg.fanout.parts_for(live.len())
    } else {
        // Splitting across a backend that serializes internally (the XLA
        // mutex) costs thread dispatch for zero overlap.
        1
    };
    let start = Instant::now();
    let (results, payload) = if parts <= 1 {
        run_chunk_with_retry(&**backend, metrics, cfg.early, &images, &seeds)
    } else {
        fan_out_batch(&**backend, metrics, cfg.early, &images, &seeds, parts)
    };
    metrics.batch_latency.record(start.elapsed());
    metrics.quarantined_engines.store(backend.quarantined_engines(), Ordering::Release);

    debug_assert_eq!(results.len(), live.len());
    for (inflight, result) in live.into_iter().zip(results) {
        match result {
            Ok(out) => respond_ok(metrics, inflight, out),
            Err(e) => {
                metrics.failed.fetch_add(1, Ordering::Release);
                let _ = inflight.reply.try_send(Err(e));
            }
        }
    }
    payload
}

/// One guarded backend call. `catch_unwind` converts an engine panic
/// into `Err(BackendPanicked)` (counted, payload preserved for the
/// worker's re-raise), and a wrong-length reply into a typed error
/// instead of silently cross-wiring request ↔ response pairs.
///
/// `AssertUnwindSafe` is justified by engine quarantine: an engine that
/// was checked out when the panic unwound never returns to the free list
/// (slot poisoning / panicking-drop eviction in `InstancePool`), so no
/// later caller can observe its broken invariants; the coordinator's own
/// shared state (queues, metrics) is either lock-free atomics or
/// poison-recovering locks over panic-sound data.
fn call_guarded(
    backend: &dyn Backend,
    metrics: &ServerMetrics,
    early: EarlyExit,
    images: &[&Image],
    seeds: &[u32],
) -> (Result<Vec<BackendOutput>>, Option<PanicPayload>) {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        backend.classify_batch(images, seeds, early)
    })) {
        Ok(Ok(out)) if out.len() == images.len() => (Ok(out), None),
        Ok(Ok(out)) => {
            let (got, want) = (out.len(), images.len());
            let msg = format!("backend returned {got} outputs for a batch of {want}");
            (Err(Error::Coordinator(msg)), None)
        }
        Ok(Err(e)) => (Err(e), None),
        Err(payload) => {
            metrics.panics_recovered.fetch_add(1, Ordering::Release);
            let msg = panic_message(payload.as_ref());
            (Err(Error::BackendPanicked(msg)), Some(payload))
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Expand a chunk-level result to per-request results (a failed chunk
/// replicates its error to every request in it).
fn expand_chunk(result: Result<Vec<BackendOutput>>, n: usize) -> Vec<Result<BackendOutput>> {
    match result {
        Ok(outs) => outs.into_iter().map(Ok).collect(),
        Err(e) => (0..n).map(|_| Err(e.replicate())).collect(),
    }
}

/// Guarded single-chunk execution with one retry. The retry checks a
/// fresh engine out of the pool (the failed one was quarantined) and
/// replays the identical images and seeds, so a recovered chunk is
/// bit-exact with an unfaulted run — per-(image, seed) PRNG streams make
/// results independent of which engine instance serves them.
fn run_chunk_with_retry(
    backend: &dyn Backend,
    metrics: &ServerMetrics,
    early: EarlyExit,
    images: &[&Image],
    seeds: &[u32],
) -> (Vec<Result<BackendOutput>>, Option<PanicPayload>) {
    let (first, mut payload) = call_guarded(backend, metrics, early, images, seeds);
    let result = match first {
        Ok(out) => Ok(out),
        Err(_) => {
            metrics.subbatch_retries.fetch_add(1, Ordering::Release);
            let (second, p2) = call_guarded(backend, metrics, early, images, seeds);
            if payload.is_none() {
                payload = p2;
            }
            second
        }
    };
    (expand_chunk(result, images.len()), payload)
}

/// Split one large batch into `parts` contiguous sub-batches, run them
/// concurrently on the backend (whose engine pool hands each call a
/// private instance), retry each failed sub-batch once, and reassemble
/// per-request outcomes in submission order.
///
/// Ordering argument: `chunks` yields contiguous, non-overlapping slices
/// in ascending index order; sub-batch `k`'s outcomes are appended before
/// sub-batch `k+1`'s, and every backend returns outputs positionally, so
/// `out[i]` is the outcome of `images[i]` regardless of which thread ran
/// it or when it finished. The stress suite pins this end to end.
///
/// Degradation argument: a sub-batch failure (error or caught panic) is
/// contained to its chunk — the other chunks' results are kept, the
/// failed chunk is retried once on a fresh engine with the same seeds
/// (bit-exact on success), and only a twice-failed chunk's requests get
/// error replies.
fn fan_out_batch(
    backend: &dyn Backend,
    metrics: &ServerMetrics,
    early: EarlyExit,
    images: &[&Image],
    seeds: &[u32],
    parts: usize,
) -> (Vec<Result<BackendOutput>>, Option<PanicPayload>) {
    let chunk = images.len().div_ceil(parts);
    metrics.fanout_batches.fetch_add(1, Ordering::Release);
    // Phase 1: all sub-batches run concurrently, each behind its own
    // catch_unwind (a panicking sub-batch thread would otherwise abort
    // the scope by poisoning the join).
    let mut attempts = std::thread::scope(|scope| {
        let mut tails = Vec::new();
        for (imgs, sds) in images[chunk..].chunks(chunk).zip(seeds[chunk..].chunks(chunk)) {
            tails.push(scope.spawn(move || call_guarded(backend, metrics, early, imgs, sds)));
        }
        metrics.subbatches.fetch_add(tails.len() as u64 + 1, Ordering::Release);
        // Run the first sub-batch on this worker thread; the spawned
        // tails overlap with it.
        let head = call_guarded(backend, metrics, early, &images[..chunk], &seeds[..chunk]);
        let mut all = vec![head];
        for handle in tails {
            all.push(handle.join().expect("guarded sub-batch cannot panic"));
        }
        all
    });
    // Phase 2: one sequential retry per failed sub-batch, same slices,
    // fresh engine (the failed one was quarantined by the pool).
    let mut payload = None;
    for (k, entry) in attempts.iter_mut().enumerate() {
        if payload.is_none() {
            payload = entry.1.take();
        }
        if entry.0.is_err() {
            metrics.subbatch_retries.fetch_add(1, Ordering::Release);
            let lo = k * chunk;
            let hi = (lo + chunk).min(images.len());
            let (retry, p2) =
                call_guarded(backend, metrics, early, &images[lo..hi], &seeds[lo..hi]);
            if payload.is_none() {
                payload = p2;
            }
            entry.0 = retry;
        }
    }
    // Phase 3: expand chunk outcomes to per-request outcomes, in order.
    let mut out = Vec::with_capacity(images.len());
    for (k, (result, _)) in attempts.into_iter().enumerate() {
        let lo = k * chunk;
        let n = (lo + chunk).min(images.len()) - lo;
        out.extend(expand_chunk(result, n));
    }
    (out, payload)
}

fn respond_ok(metrics: &ServerMetrics, inflight: InFlight, out: BackendOutput) {
    if inflight.deadline.is_some_and(|d| d <= Instant::now()) {
        // The work finished late: still delivered (the caller may yet be
        // listening), but the expiry goes on record.
        metrics.deadline_expired.fetch_add(1, Ordering::Release);
    }
    metrics.completed.fetch_add(1, Ordering::Release);
    metrics.steps_executed.fetch_add(u64::from(out.steps_run), Ordering::Release);
    metrics.latency.record(inflight.submitted.elapsed());
    let _ = inflight.reply.try_send(Ok(Response {
        class: out.class,
        spike_counts: out.spike_counts,
        steps_run: out.steps_run,
        seed: inflight.seed,
    }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SnnConfig;
    use crate::coordinator::backend::BehavioralBackend;
    use crate::data::{DigitGen, IMG_PIXELS};
    use crate::fixed::WeightMatrix;
    use std::sync::atomic::AtomicBool;

    fn block_weights() -> WeightMatrix {
        let mut w = vec![0i32; 784 * 10];
        for i in 0..784 {
            let block = i / 79;
            if block < 10 {
                w[i * 10 + block] = 40;
            }
        }
        WeightMatrix::from_rows(784, 10, 9, w).unwrap()
    }

    fn block_image(class: usize) -> Image {
        let mut px = vec![0u8; IMG_PIXELS];
        for i in 0..784 {
            if i / 79 == class {
                px[i] = 250;
            }
        }
        Image { label: class as u8, pixels: px }
    }

    fn start_coordinator(workers: usize, queue: usize) -> Coordinator {
        let cfg = SnnConfig::paper().with_timesteps(6);
        let backend = Arc::new(BehavioralBackend::new(cfg, block_weights()).unwrap());
        Coordinator::start(
            backend,
            CoordinatorConfig {
                workers,
                queue_depth: queue,
                batch: BatchPolicy { max_batch: 4, max_delay: Duration::from_millis(1) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy::default(),
                supervision: SupervisionPolicy::default(),
            },
        )
    }

    #[test]
    fn end_to_end_classification() {
        let coord = start_coordinator(2, 64);
        let handle = coord.handle();
        for class in 0..10usize {
            let resp = handle.classify(block_image(class)).unwrap();
            assert_eq!(resp.class as usize, class);
            assert_eq!(resp.steps_run, 6);
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.completed, 10);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_answered() {
        let coord = start_coordinator(3, 256);
        let handle = coord.handle();
        let receivers: Vec<_> = (0..64)
            .map(|i| {
                let img = block_image(i % 10);
                (i % 10, handle.submit(Request::new(img).with_seed(42 + i as u32)).unwrap())
            })
            .collect();
        for (class, rx) in receivers {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.class as usize, class);
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.completed, 64);
        assert!(snap.batches >= 16, "batches {}", snap.batches);
        coord.shutdown();
    }

    #[test]
    fn deterministic_with_explicit_seed() {
        let coord = start_coordinator(2, 64);
        let handle = coord.handle();
        let img = DigitGen::new(1).sample(4, 0);
        let a = handle
            .submit(Request::new(img.clone()).with_seed(7))
            .unwrap()
            .recv()
            .unwrap()
            .unwrap();
        let b = handle.submit(Request::new(img).with_seed(7)).unwrap().recv().unwrap().unwrap();
        assert_eq!(a, b);
        coord.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // One worker, tiny queue, and a flood of submissions from this
        // thread: some must be rejected (typed Overloaded), none lost.
        let coord = start_coordinator(1, 2);
        let handle = coord.handle();
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for i in 0..200 {
            match handle.submit(Request::new(block_image(i % 10)).with_seed(i as u32)) {
                Ok(rx) => accepted.push(rx),
                Err(Error::Overloaded(_)) => rejected += 1,
                Err(e) => panic!("unexpected error {e}"),
            }
        }
        for rx in accepted {
            rx.recv().unwrap().unwrap();
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.completed + snap.rejected as u64, 200);
        assert_eq!(snap.rejected as usize, rejected);
        coord.shutdown();
    }

    #[test]
    fn shutdown_stops_new_work() {
        let coord = start_coordinator(1, 8);
        let handle = coord.handle();
        handle.classify(block_image(1)).unwrap();
        coord.shutdown();
        let res = handle.submit(Request::new(block_image(1)));
        assert!(matches!(res, Err(Error::ShuttingDown(_))));
    }

    #[test]
    fn early_exit_reduces_steps() {
        let cfg = SnnConfig::paper()
            .with_timesteps(20)
            .with_prune(crate::config::PruneMode::Off);
        let backend = Arc::new(BehavioralBackend::new(cfg, block_weights()).unwrap());
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 16,
                batch: BatchPolicy { max_batch: 1, max_delay: Duration::from_micros(100) },
                early: EarlyExit::Margin { margin: 3, min_steps: 2 },
                fanout: FanoutPolicy::default(),
                supervision: SupervisionPolicy::default(),
            },
        );
        let resp = coord.handle().classify(block_image(5)).unwrap();
        assert_eq!(resp.class, 5);
        assert!(resp.steps_run < 20, "early exit did not trigger: {}", resp.steps_run);
        coord.shutdown();
    }

    #[test]
    fn fanout_policy_crossover() {
        let p = FanoutPolicy { min_batch: 32, max_parts: 4 };
        assert_eq!(p.parts_for(1), 1);
        assert_eq!(p.parts_for(31), 1, "below the crossover stays single-engine");
        assert_eq!(p.parts_for(32), 4);
        assert_eq!(p.parts_for(400), 4, "parts capped at max_parts");
        assert_eq!(FanoutPolicy::off().parts_for(1_000_000), 1);
        // Degenerate policies never split a batch of one.
        let eager = FanoutPolicy { min_batch: 0, max_parts: 8 };
        assert_eq!(eager.parts_for(1), 1);
        assert_eq!(eager.parts_for(3), 3, "parts never exceed the batch size");
    }

    /// A stub backend whose per-image cost is known and fixed (busy-spin:
    /// sleep granularity is far too coarse for µs-scale calibration).
    struct FixedCostBackend {
        cfg: SnnConfig,
        per_image: Duration,
    }

    impl Backend for FixedCostBackend {
        fn name(&self) -> &'static str {
            "fixed-cost-stub"
        }

        fn classify_batch(
            &self,
            images: &[&Image],
            seeds: &[u32],
            _early: EarlyExit,
        ) -> Result<Vec<BackendOutput>> {
            let until = Instant::now() + self.per_image * images.len() as u32;
            while Instant::now() < until {
                std::hint::spin_loop();
            }
            Ok(images
                .iter()
                .zip(seeds)
                .map(|(_, &s)| BackendOutput {
                    class: (s % 10) as u8,
                    spike_counts: vec![0; 10],
                    steps_run: 1,
                })
                .collect())
        }

        fn config(&self) -> &SnnConfig {
            &self.cfg
        }
    }

    fn start_fixed_cost(per_image: Duration, queue: usize) -> Coordinator {
        let backend = Arc::new(FixedCostBackend { cfg: SnnConfig::paper(), per_image });
        Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 1,
                queue_depth: queue,
                batch: BatchPolicy { max_batch: 1, max_delay: Duration::from_micros(50) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy::off(),
                supervision: SupervisionPolicy::default(),
            },
        )
    }

    #[test]
    fn calibrated_fanout_adapts_to_backend_cost() {
        // The derivation is pure — pin the crossover math first.
        assert_eq!(
            FanoutPolicy::from_cost(Duration::from_micros(480), 4),
            FanoutPolicy { min_batch: 2, max_parts: 4 }
        );
        let fast = FanoutPolicy::from_cost(Duration::from_nanos(100), 8);
        assert_eq!(fast, FanoutPolicy { min_batch: 4800, max_parts: 8 });
        // Monotone: a slower backend gets a lower crossover.
        assert!(
            FanoutPolicy::from_cost(Duration::from_micros(10), 4).min_batch
                > FanoutPolicy::from_cost(Duration::from_micros(100), 4).min_batch
        );
        // Degenerate inputs clamp sanely.
        assert_eq!(FanoutPolicy::from_cost(Duration::ZERO, 0).max_parts, 1);
        assert!(FanoutPolicy::from_cost(Duration::ZERO, 1).min_batch <= 1 << 16);

        // The measured probe on stubs of known cost: the slow stub must
        // calibrate to (near) the floor, the zero-cost stub far above it,
        // and max_parts must follow the pool's slot count.
        let slow = FixedCostBackend {
            cfg: SnnConfig::paper(),
            per_image: Duration::from_micros(500),
        };
        let p_slow = FanoutPolicy::calibrated(&slow, 4);
        assert_eq!(p_slow.max_parts, 4);
        assert!(
            p_slow.min_batch <= 4,
            "slow backend must fan out early, got crossover {}",
            p_slow.min_batch
        );
        let echo = FixedCostBackend { cfg: SnnConfig::paper(), per_image: Duration::ZERO };
        let p_echo = FanoutPolicy::calibrated(&echo, 2);
        assert_eq!(p_echo.max_parts, 2);
        assert!(
            p_echo.min_batch > p_slow.min_batch && p_echo.min_batch >= 8,
            "echo-fast backend must get a much higher crossover, got {}",
            p_echo.min_batch
        );
    }

    #[test]
    fn fanned_out_batch_reassembles_in_submission_order() {
        // One worker, a batch policy that forms one large batch, and a
        // fan-out policy that splits it: every reply must still carry the
        // answer for its own (image, seed).
        let cfg = SnnConfig::paper().with_timesteps(6);
        let backend = Arc::new(BehavioralBackend::new(cfg, block_weights()).unwrap());
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 256,
                batch: BatchPolicy { max_batch: 40, max_delay: Duration::from_millis(20) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy { min_batch: 8, max_parts: 4 },
                supervision: SupervisionPolicy::default(),
            },
        );
        let handle = coord.handle();
        let receivers: Vec<_> = (0..40)
            .map(|i| {
                let class = i % 10;
                let rx = handle
                    .submit(Request::new(block_image(class)).with_seed(1000 + i as u32))
                    .unwrap();
                (class, rx)
            })
            .collect();
        for (class, rx) in receivers {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.class as usize, class, "reply wired to the wrong request");
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.completed, 40);
        assert!(snap.fanout_batches >= 1, "large batch must fan out");
        assert!(
            snap.subbatches >= 2 * snap.fanout_batches,
            "fanned batches must split into >= 2 parts: {} batches, {} parts",
            snap.fanout_batches,
            snap.subbatches
        );
        coord.shutdown();
    }

    #[test]
    fn shard_depth_gauges_exposed() {
        let coord = start_coordinator(3, 96);
        assert_eq!(coord.shard_depths().len(), 3);
        coord.shutdown();
    }

    // -----------------------------------------------------------------
    // Fault tolerance
    // -----------------------------------------------------------------

    #[test]
    fn expired_deadline_is_rejected_at_submit() {
        let coord = start_coordinator(1, 8);
        let handle = coord.handle();
        let res = handle.submit(Request::new(block_image(0)).with_deadline(Instant::now()));
        assert!(matches!(res, Err(Error::Shed(_))), "want Shed, got {res:?}");
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.rejected, 1);
        assert_eq!(snap.deadline_expired, 1);
        assert_eq!(snap.submitted, 0, "an expired request never enters the queue");
        coord.shutdown();
    }

    #[test]
    fn queued_requests_past_deadline_are_shed_at_pop() {
        // A 5 ms-per-image backend and one worker: request A occupies the
        // worker long past B's 1 ms deadline, so B is shed at pop time.
        let coord = start_fixed_cost(Duration::from_millis(5), 16);
        let handle = coord.handle();
        let a = handle.submit(Request::new(block_image(0)).with_seed(1)).unwrap();
        let req = Request::new(block_image(1))
            .with_seed(2)
            .with_deadline(Instant::now() + Duration::from_millis(1));
        let b = handle.submit(req).unwrap();
        assert!(a.recv().unwrap().is_ok());
        let shed = b.recv().unwrap();
        assert!(matches!(shed, Err(Error::Shed(_))), "want Shed, got {shed:?}");
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.shed, 1);
        assert!(snap.deadline_expired >= 1);
        assert_eq!(snap.completed, 1);
        coord.shutdown();
    }

    /// Regression: idle-shard deadline starvation. With a huge batch
    /// `max_delay` and no other traffic, a short-deadline request used to
    /// sit in the worker's forming batch until the *batch* timer (or the
    /// next push) woke the worker — its typed `Shed` reply arrived
    /// arbitrarily late. The park is now bounded by the soonest pending
    /// deadline, so the reply must land promptly. Bounded by
    /// `recv_timeout`, no sleeps.
    #[test]
    fn idle_shard_sheds_expired_deadline_on_time() {
        let backend =
            Arc::new(FixedCostBackend { cfg: SnnConfig::paper(), per_image: Duration::ZERO });
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 8,
                // The batch timer alone would hold the reply for 30 s.
                batch: BatchPolicy { max_batch: 4, max_delay: Duration::from_secs(30) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy::off(),
                supervision: SupervisionPolicy::default(),
            },
        );
        let handle = coord.handle();
        let t0 = Instant::now();
        let rx = handle
            .submit(
                Request::new(block_image(0))
                    .with_seed(1)
                    .with_deadline(Instant::now() + Duration::from_millis(20)),
            )
            .unwrap();
        let reply = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("shed reply must not wait out the batch max_delay");
        assert!(matches!(reply, Err(Error::Shed(_))), "want Shed, got {reply:?}");
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "shed reply took {:?} — deadline did not bound the park",
            t0.elapsed()
        );
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.shed, 1);
        assert!(snap.deadline_expired >= 1);
        assert_eq!(snap.completed, 0, "expired work must be shed, not computed");
        coord.shutdown();
    }

    #[test]
    fn classify_timeout_bounds_the_wait() {
        let coord = start_fixed_cost(Duration::from_millis(50), 16);
        let handle = coord.handle();
        let t0 = Instant::now();
        let res = handle.classify_timeout(block_image(0), Duration::from_millis(2));
        assert!(
            matches!(res, Err(Error::Timeout(_)) | Err(Error::Shed(_))),
            "want Timeout or Shed, got {res:?}"
        );
        assert!(t0.elapsed() < Duration::from_secs(5), "classify_timeout must not block");
        coord.shutdown();
    }

    /// Panics on every batch containing the victim seed.
    struct PanickingBackend {
        cfg: SnnConfig,
        victim: u32,
    }

    impl Backend for PanickingBackend {
        fn name(&self) -> &'static str {
            "panicking-stub"
        }
        fn classify_batch(
            &self,
            images: &[&Image],
            seeds: &[u32],
            _early: EarlyExit,
        ) -> Result<Vec<BackendOutput>> {
            if seeds.contains(&self.victim) {
                panic!("stub panic (victim seed {})", self.victim);
            }
            Ok(images
                .iter()
                .zip(seeds)
                .map(|(_, &s)| BackendOutput {
                    class: (s % 10) as u8,
                    spike_counts: vec![s; 2],
                    steps_run: 1,
                })
                .collect())
        }
        fn config(&self) -> &SnnConfig {
            &self.cfg
        }
    }

    #[test]
    fn backend_panic_is_contained_and_worker_respawned() {
        let backend = Arc::new(PanickingBackend { cfg: SnnConfig::paper(), victim: 0xDEAD });
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 16,
                batch: BatchPolicy { max_batch: 1, max_delay: Duration::from_micros(10) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy::off(),
                supervision: SupervisionPolicy {
                    max_restarts_per_worker: 8,
                    backoff_base: Duration::from_micros(50),
                    backoff_cap: Duration::from_millis(1),
                },
            },
        );
        let handle = coord.handle();
        // The victim's batch panics on the first attempt and again on the
        // retry: typed terminal reply, not a hung channel.
        let bad = handle
            .submit(Request::new(block_image(0)).with_seed(0xDEAD))
            .unwrap()
            .recv()
            .expect("panicked batch must still send a terminal reply");
        assert!(matches!(bad, Err(Error::BackendPanicked(_))), "got {bad:?}");
        // The worker died with the panic; the supervisor respawns it and
        // serving continues.
        let good = handle
            .submit(Request::new(block_image(3)).with_seed(3))
            .unwrap()
            .recv()
            .unwrap()
            .unwrap();
        assert_eq!(good.class, 3);
        let deadline = Instant::now() + Duration::from_secs(10);
        while coord.metrics().snapshot().worker_restarts == 0 {
            assert!(Instant::now() < deadline, "supervisor never restarted the worker");
            std::thread::sleep(Duration::from_millis(1));
        }
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.worker_restarts, 1, "one panicked batch = one restart");
        assert_eq!(snap.panics_recovered, 2, "initial attempt + retry both panic");
        assert_eq!(snap.subbatch_retries, 1);
        assert_eq!(snap.failed, 1);
        assert_eq!(snap.completed, 1);
        coord.shutdown();
    }

    /// Always replies one output short (broken batch contract).
    struct ShortReplyBackend {
        cfg: SnnConfig,
    }

    impl Backend for ShortReplyBackend {
        fn name(&self) -> &'static str {
            "short-reply-stub"
        }
        fn classify_batch(
            &self,
            images: &[&Image],
            _seeds: &[u32],
            _early: EarlyExit,
        ) -> Result<Vec<BackendOutput>> {
            Ok((1..images.len())
                .map(|_| BackendOutput { class: 0, spike_counts: vec![], steps_run: 1 })
                .collect())
        }
        fn config(&self) -> &SnnConfig {
            &self.cfg
        }
    }

    #[test]
    fn wrong_length_reply_is_a_typed_error_not_a_lost_reply() {
        let backend = Arc::new(ShortReplyBackend { cfg: SnnConfig::paper() });
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 8,
                batch: BatchPolicy { max_batch: 1, max_delay: Duration::from_micros(10) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy::off(),
                supervision: SupervisionPolicy::default(),
            },
        );
        let res = coord
            .handle()
            .submit(Request::new(block_image(0)).with_seed(1))
            .unwrap()
            .recv()
            .expect("wrong-length batch must still send a terminal reply");
        match res {
            Err(Error::Coordinator(msg)) => {
                assert!(msg.contains("outputs"), "unhelpful message: {msg}")
            }
            other => panic!("want typed length error, got {other:?}"),
        }
        assert_eq!(coord.metrics().snapshot().failed, 1);
        coord.shutdown();
    }

    /// Fails exactly the first call, then behaves (seed-echo outputs).
    struct FlakyOnceBackend {
        cfg: SnnConfig,
        tripped: AtomicBool,
    }

    impl Backend for FlakyOnceBackend {
        fn name(&self) -> &'static str {
            "flaky-once-stub"
        }
        fn classify_batch(
            &self,
            images: &[&Image],
            seeds: &[u32],
            _early: EarlyExit,
        ) -> Result<Vec<BackendOutput>> {
            if !self.tripped.swap(true, Ordering::SeqCst) {
                return Err(Error::Xla("transient stub fault".into()));
            }
            Ok(images
                .iter()
                .zip(seeds)
                .map(|(_, &s)| BackendOutput {
                    class: (s % 10) as u8,
                    spike_counts: vec![s; 2],
                    steps_run: 1,
                })
                .collect())
        }
        fn config(&self) -> &SnnConfig {
            &self.cfg
        }
    }

    #[test]
    fn transient_backend_fault_recovers_via_retry() {
        let backend = Arc::new(FlakyOnceBackend {
            cfg: SnnConfig::paper(),
            tripped: AtomicBool::new(false),
        });
        let coord = Coordinator::start(
            backend,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 8,
                batch: BatchPolicy { max_batch: 1, max_delay: Duration::from_micros(10) },
                early: EarlyExit::Off,
                fanout: FanoutPolicy::off(),
                supervision: SupervisionPolicy::default(),
            },
        );
        let resp = coord
            .handle()
            .submit(Request::new(block_image(0)).with_seed(7))
            .unwrap()
            .recv()
            .unwrap()
            .expect("single transient fault must be absorbed by the retry");
        assert_eq!(resp.spike_counts, vec![7; 2]);
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.subbatch_retries, 1);
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 0);
        coord.shutdown();
    }
}
