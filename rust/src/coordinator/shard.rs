//! Sharded ingress: per-worker bounded deques with work stealing.
//!
//! The coordinator used to funnel every request through one
//! `mpsc::sync_channel` guarded by a `Mutex<Receiver>`. At high worker
//! counts that single channel is the scaling ceiling, and one slow batch
//! head-of-line-blocks everything behind it in the shared FIFO.
//! [`ShardedQueue`] replaces it:
//!
//! * **one bounded deque per worker** — the submit path places each item
//!   on the shallowest shard (round-robin tie-break), so ingress pressure
//!   spreads without a global lock;
//! * **work stealing** — a worker drains its own deque first and, when
//!   empty, sweeps the siblings from a *rotating* starting victim and
//!   steals at most *half* of the victim's backlog (oldest entries first),
//!   so a worker pinned on a slow batch cannot strand the requests queued
//!   behind it, while the victim is never emptied by one bulk steal and
//!   repeated steals spread across siblings instead of hammering one
//!   (the PR-2 follow-on: full-batch steals from a fixed victim order
//!   starved the deepest shard's own worker under skewed arrivals). The
//!   rotation cursor is **per-worker state** — each caller passes its own
//!   cursor to [`ShardedQueue::pop_some`] — so the steal path touches no
//!   shared atomic at all: a worker's successive sweeps open on victims
//!   `home+1, home+2, …` in its own deterministic schedule, and distinct
//!   workers still de-phase naturally because their `home` offsets differ;
//! * **exact close semantics** — `close()` latches a per-shard flag under
//!   each shard's lock, and [`ShardedQueue::pop_some`] only reports
//!   [`Popped::Drained`] after observing every shard empty *and* closed
//!   under its lock. Because a push checks the same flag under the same
//!   lock, no submission can slip into a queue no worker will ever visit:
//!   every accepted item is drained, every post-close submit is rejected.
//!
//! Blocking: idle workers sleep on one shared condvar with a bounded
//! timeout. Pushers only touch the condvar when a sleeper is registered,
//! so the ingress hot path stays two uncontended lock acquisitions (the
//! shard, and nothing else).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

// Poison-shrugging lock (the shared `util::lock_recover`): queue integrity
// is maintained by the operations themselves, not by the absence of panics
// elsewhere.
use crate::util::lock_recover as lock;

/// Why a push was refused.
#[derive(Debug)]
pub enum PushError<T> {
    /// Every shard is at capacity (backpressure); the item is handed back.
    Full(T),
    /// The queue is closed (coordinator shutting down).
    Closed(T),
}

/// Result of a [`ShardedQueue::pop_some`] sweep.
#[derive(Debug)]
pub enum Popped<T> {
    /// Items obtained; `stolen` is how many came from a sibling shard
    /// (0 = all from the caller's own deque).
    Items { items: Vec<T>, stolen: usize },
    /// Nothing available right now; the queue is still open.
    Empty,
    /// Every shard was observed empty *and* closed under its lock: no item
    /// exists and none can ever arrive. The caller can exit.
    Drained,
}

struct ShardState<T> {
    queue: VecDeque<T>,
    closed: bool,
}

struct Shard<T> {
    state: Mutex<ShardState<T>>,
    /// Depth mirror maintained under the lock, readable without it —
    /// drives shortest-queue placement, deepest-victim stealing and the
    /// metrics gauges. A stale read only costs a suboptimal choice.
    depth: AtomicUsize,
}

/// The sharded ingress queue. See the module docs.
pub struct ShardedQueue<T> {
    shards: Box<[Shard<T>]>,
    capacity_per_shard: usize,
    /// Round-robin cursor breaking shortest-queue ties.
    cursor: AtomicUsize,
    /// Fast "no push can ever succeed again" flag (the per-shard flags
    /// under their locks are the authoritative close protocol).
    closed: AtomicBool,
    /// Workers currently parked in [`ShardedQueue::wait`].
    sleepers: AtomicUsize,
    sleep_lock: Mutex<()>,
    wakeup: Condvar,
}

impl<T> ShardedQueue<T> {
    /// Create `shards` deques sharing `total_capacity` (split evenly,
    /// rounded up so every shard holds at least one item).
    pub fn new(shards: usize, total_capacity: usize) -> Self {
        assert!(shards >= 1, "need at least one shard");
        assert!(total_capacity >= 1, "need capacity for at least one item");
        let capacity_per_shard = total_capacity.div_ceil(shards);
        ShardedQueue {
            shards: (0..shards)
                .map(|_| Shard {
                    state: Mutex::new(ShardState { queue: VecDeque::new(), closed: false }),
                    depth: AtomicUsize::new(0),
                })
                .collect(),
            capacity_per_shard,
            cursor: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            sleepers: AtomicUsize::new(0),
            sleep_lock: Mutex::new(()),
            wakeup: Condvar::new(),
        }
    }

    /// Number of shards (one per worker).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Per-shard capacity bound.
    pub fn capacity_per_shard(&self) -> usize {
        self.capacity_per_shard
    }

    /// Instantaneous per-shard depths (racy gauges, for observability).
    pub fn depths(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.depth.load(Ordering::SeqCst)).collect()
    }

    /// Instantaneous total queued items.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.depth.load(Ordering::SeqCst)).sum()
    }

    /// True when no shard currently holds an item.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True once [`ShardedQueue::close`] has run.
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Place `item` on the shallowest shard (round-robin tie-break),
    /// falling through ring-order when the depth hint was stale and the
    /// chosen shard is actually full. Never blocks.
    pub fn push(&self, item: T) -> Result<usize, PushError<T>> {
        let n = self.shards.len();
        let start = self.cursor.fetch_add(1, Ordering::Relaxed) % n;
        let mut best = start;
        let mut best_depth = self.shards[start].depth.load(Ordering::SeqCst);
        for k in 1..n {
            let i = (start + k) % n;
            let d = self.shards[i].depth.load(Ordering::SeqCst);
            if d < best_depth {
                best = i;
                best_depth = d;
            }
        }
        let mut item = Some(item);
        for k in 0..n {
            let i = (best + k) % n;
            let shard = &self.shards[i];
            // pallas-lint: lock(shard.state)
            let mut st = lock(&shard.state);
            if st.closed {
                return Err(PushError::Closed(item.take().expect("item present")));
            }
            if st.queue.len() >= self.capacity_per_shard {
                continue;
            }
            st.queue.push_back(item.take().expect("item present"));
            shard.depth.store(st.queue.len(), Ordering::SeqCst);
            drop(st);
            // pallas-lint: end-lock(shard.state)
            // The wakeup handshake takes shard.sleep strictly *after* the
            // state guard dropped — declared outside the region above, so
            // the lock graph records no state→sleep edge.
            self.notify_one(); // pallas-lint: calls-lock(shard.sleep)
            return Ok(i);
        }
        Err(PushError::Full(item.take().expect("item present")))
    }

    /// The one lock-drain-store-depth primitive every pop path shares:
    /// lock shard `i`, drain items FIFO (refreshing the depth mirror under
    /// the same lock), and report the closed flag as observed under that
    /// lock — the evidence a `Drained` verdict needs. An owner drain
    /// (`steal_half: false`) takes up to `max` items; a steal
    /// (`steal_half: true`) additionally caps the take at *half* the
    /// victim's backlog (rounded up, so a 1-deep victim is still
    /// stealable), leaving the newer half for the victim's own worker.
    fn drain_locked(&self, i: usize, max: usize, steal_half: bool) -> (Option<Vec<T>>, bool) {
        let shard = &self.shards[i];
        // pallas-lint: lock(shard.state)
        let mut st = lock(&shard.state);
        let closed = st.closed;
        if st.queue.is_empty() {
            return (None, closed);
        }
        let cap = if steal_half { st.queue.len().div_ceil(2) } else { st.queue.len() };
        let k = cap.min(max);
        let items: Vec<T> = st.queue.drain(..k).collect();
        shard.depth.store(st.queue.len(), Ordering::SeqCst);
        // pallas-lint: end-lock(shard.state)
        (Some(items), closed)
    }

    /// Pop up to `max` items for worker `home`: its own deque first
    /// (FIFO), then a steal sweep over the siblings — starting victim
    /// rotated per sweep via the *caller-owned* `steal_cursor`, oldest
    /// entries first, at most half of one victim's backlog — so stolen
    /// requests keep their latency ordering without starving the victim.
    /// The cursor is per-worker state (each worker passes its own),
    /// advancing once per sweep: sweep `c` opens on victim
    /// `home + 1 + c mod (n-1)` — never `home` — so one worker's
    /// consecutive sweeps walk the siblings round-robin with zero shared
    /// atomics on the steal path. See [`Popped`] for the empty/drained
    /// distinction.
    pub fn pop_some(&self, home: usize, max: usize, steal_cursor: &mut usize) -> Popped<T> {
        let n = self.shards.len();
        debug_assert!(max > 0, "pop_some needs room for at least one item");
        let home = home % n;
        if let (Some(items), _) = self.drain_locked(home, max, false) {
            return Popped::Items { items, stolen: 0 };
        }

        // Steal sweep: walk every sibling once in ring order from the
        // rotated start, folding each sibling's (empty && closed) status
        // observed under its lock — the evidence for a `Drained` verdict.
        // No allocation, no shared state: a caller-owned cursor and a
        // ring walk.
        let mut all_closed = true;
        if n > 1 {
            let c = *steal_cursor;
            *steal_cursor = c.wrapping_add(1);
            let start = (home + 1 + c % (n - 1)) % n;
            for k in 0..n {
                let i = (start + k) % n;
                if i == home {
                    continue;
                }
                if let Some(stolen) = self.steal_from(i, max, &mut all_closed) {
                    return stolen;
                }
            }
        }

        // Re-check home under its lock: an item may have landed there
        // during the sweep, and the Drained verdict needs home's own
        // (empty && closed) observed under the lock too.
        match self.drain_locked(home, max, false) {
            (Some(items), _) => Popped::Items { items, stolen: 0 },
            (None, home_closed) if all_closed && home_closed => Popped::Drained,
            (None, _) => Popped::Empty,
        }
    }

    /// Steal sweep step over shard `i` (see [`ShardedQueue::drain_locked`]
    /// — steal-half semantics); when it is empty, fold its closed flag
    /// into `all_closed` for the caller's `Drained` verdict.
    fn steal_from(&self, i: usize, max: usize, all_closed: &mut bool) -> Option<Popped<T>> {
        match self.drain_locked(i, max, true) {
            (Some(items), _) => Some(Popped::Items { stolen: items.len(), items }),
            (None, closed) => {
                *all_closed &= closed;
                None
            }
        }
    }

    /// Park the caller until an item is likely available, the queue
    /// closes, or `timeout` elapses — whichever comes first. May wake
    /// spuriously; callers re-poll.
    pub fn wait(&self, timeout: Duration) {
        if !self.is_empty() || self.is_closed() {
            return;
        }
        self.sleepers.fetch_add(1, Ordering::SeqCst);
        // pallas-lint: lock(shard.sleep)
        let guard = lock(&self.sleep_lock);
        if self.is_empty() && !self.is_closed() {
            let _ = self.wakeup.wait_timeout(guard, timeout);
        }
        // pallas-lint: end-lock(shard.sleep)
        self.sleepers.fetch_sub(1, Ordering::SeqCst);
    }

    fn notify_one(&self) {
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            // Taking the sleep lock orders this notify after any sleeper's
            // final emptiness re-check, closing the lost-wakeup window.
            // pallas-lint: lock(shard.sleep)
            drop(lock(&self.sleep_lock));
            // pallas-lint: end-lock(shard.sleep)
            self.wakeup.notify_one();
        }
    }

    /// Close the queue: latch every shard's closed flag (under its lock)
    /// and wake all sleepers. Pushes fail from here on; queued items stay
    /// poppable until drained.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        for shard in self.shards.iter() {
            // pallas-lint: lock(shard.state)
            lock(&shard.state).closed = true;
            // pallas-lint: end-lock(shard.state)
        }
        // pallas-lint: lock(shard.sleep)
        drop(lock(&self.sleep_lock));
        // pallas-lint: end-lock(shard.sleep)
        self.wakeup.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn items<T>(p: Popped<T>) -> Vec<T> {
        match p {
            Popped::Items { items, .. } => items,
            other => panic!("expected items, got {}", kind(&other)),
        }
    }

    fn kind<T>(p: &Popped<T>) -> &'static str {
        match p {
            Popped::Items { .. } => "Items",
            Popped::Empty => "Empty",
            Popped::Drained => "Drained",
        }
    }

    #[test]
    fn push_pop_fifo_within_shard() {
        let q: ShardedQueue<u32> = ShardedQueue::new(1, 16);
        for v in 0..5 {
            q.push(v).unwrap();
        }
        let mut cur = 0;
        assert_eq!(items(q.pop_some(0, 3, &mut cur)), vec![0, 1, 2]);
        assert_eq!(items(q.pop_some(0, 8, &mut cur)), vec![3, 4]);
        assert!(matches!(q.pop_some(0, 1, &mut cur), Popped::Empty));
    }

    #[test]
    fn shortest_queue_placement_balances() {
        let q: ShardedQueue<u32> = ShardedQueue::new(4, 400);
        for v in 0..100 {
            q.push(v).unwrap();
        }
        let depths = q.depths();
        assert_eq!(depths.iter().sum::<usize>(), 100);
        assert!(
            depths.iter().all(|&d| d == 25),
            "shortest-queue placement must balance: {depths:?}"
        );
    }

    #[test]
    fn backpressure_rejects_when_all_full() {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 4); // 2 per shard
        for v in 0..4 {
            q.push(v).unwrap();
        }
        match q.push(99) {
            Err(PushError::Full(v)) => assert_eq!(v, 99, "item handed back"),
            other => panic!("expected Full, got {other:?}"),
        }
        // Draining frees capacity again.
        let _ = items(q.pop_some(0, 1, &mut 0));
        q.push(99).unwrap();
    }

    #[test]
    fn steal_takes_oldest_from_sibling() {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 64);
        let mut on0 = Vec::new();
        for v in 0..8 {
            if q.push(v).unwrap() == 0 {
                on0.push(v);
            }
        }
        assert!(on0.len() >= 2, "placement must use shard 0");
        // Worker 1 drains its own shard first, then steals shard 0's
        // entries half a backlog at a time — oldest first, so the
        // concatenation of the steals is exactly shard 0's FIFO order.
        let mut stolen_all = Vec::new();
        let mut steal_events = 0;
        let mut cur = 0;
        loop {
            match q.pop_some(1, 8, &mut cur) {
                Popped::Items { items, stolen: 0 } => {
                    assert!(items.iter().all(|v| !on0.contains(v)), "own-shard drain");
                }
                Popped::Items { mut items, stolen } => {
                    assert_eq!(stolen, items.len());
                    steal_events += 1;
                    stolen_all.append(&mut items);
                }
                Popped::Empty => break,
                other => panic!("expected items, got {}", kind(&other)),
            }
        }
        assert_eq!(stolen_all, on0, "steals must take oldest-first FIFO order");
        assert!(
            steal_events >= 2,
            "steal-half must take multiple rounds to empty a {}-deep victim",
            on0.len()
        );
    }

    #[test]
    fn steal_takes_at_most_half_and_rotates_victims() {
        // 4 shards, 10 items each (shortest-queue placement balances).
        let q: ShardedQueue<u32> = ShardedQueue::new(4, 40);
        for v in 0..40 {
            q.push(v).unwrap();
        }
        assert_eq!(q.depths(), vec![10, 10, 10, 10]);
        // Worker 0 drains its own shard, then steals with its own
        // per-worker cursor. Each steal must take exactly ceil(10/2) = 5
        // from a full victim, and the three successive sweeps must open
        // on victims 1, 2, 3 *in that order* — sweep `c` starts at
        // `home + 1 + c mod (n-1)`, the per-worker schedule.
        let mut cur = 0;
        let own = items(q.pop_some(0, 100, &mut cur));
        assert_eq!(own.len(), 10);
        let mut victims = Vec::new();
        for round in 0..3 {
            let before = q.depths();
            match q.pop_some(0, 100, &mut cur) {
                Popped::Items { items, stolen } => {
                    assert_eq!(stolen, 5, "round {round}: steal must cap at half of 10");
                    assert_eq!(items.len(), 5);
                }
                other => panic!("round {round}: expected items, got {}", kind(&other)),
            }
            let after = q.depths();
            let victim = (0..4)
                .find(|&i| after[i] < before[i])
                .expect("one shard must have shrunk");
            assert_eq!(before[victim] - after[victim], 5);
            victims.push(victim);
        }
        assert_eq!(
            victims,
            vec![1, 2, 3],
            "per-worker cursor must rotate victims deterministically in ring order"
        );
        // Next round: victims hold 5 each → steals take ceil(5/2) = 3.
        match q.pop_some(0, 100, &mut cur) {
            Popped::Items { stolen, .. } => assert_eq!(stolen, 3),
            other => panic!("expected items, got {}", kind(&other)),
        }
        // A different worker's fresh cursor opens on *its* first sibling:
        // after draining its own shard, worker 2's sweep 0 starts at
        // shard 3 (`home + 1 + 0`).
        let mut cur2 = 0;
        let own2 = items(q.pop_some(2, 100, &mut cur2));
        assert!(!own2.is_empty(), "worker 2 drains its own shard first");
        let before = q.depths();
        let _ = items(q.pop_some(2, 100, &mut cur2));
        let after = q.depths();
        assert!(after[3] < before[3], "worker 2's first steal must open on shard 3");
    }

    #[test]
    fn skewed_arrivals_drain_through_half_steals() {
        // Skewed-arrival stress: three of four workers are stalled, so
        // their shards only drain through worker 0's steal sweeps. Every
        // item must come out exactly once, and the steal path must be the
        // one doing the work (stolen > 0 on most pops once home is dry).
        let q: Arc<ShardedQueue<u64>> = Arc::new(ShardedQueue::new(4, 64));
        let producer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for v in 0..2000u64 {
                    loop {
                        match q.push(v) {
                            Ok(_) => break,
                            Err(PushError::Full(_)) => std::thread::yield_now(),
                            Err(PushError::Closed(_)) => panic!("closed early"),
                        }
                    }
                }
            })
        };
        let consumer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let (mut got, mut steal_pops) = (Vec::new(), 0u32);
                let mut cur = 0;
                loop {
                    match q.pop_some(0, 8, &mut cur) {
                        Popped::Items { mut items, stolen } => {
                            steal_pops += u32::from(stolen > 0);
                            got.append(&mut items);
                        }
                        Popped::Empty => q.wait(Duration::from_millis(2)),
                        Popped::Drained => return (got, steal_pops),
                    }
                }
            })
        };
        producer.join().unwrap();
        q.close();
        let (mut got, steal_pops) = consumer.join().unwrap();
        got.sort_unstable();
        assert_eq!(got, (0..2000u64).collect::<Vec<_>>(), "items lost or duplicated");
        assert!(
            steal_pops > 0,
            "skewed load must exercise the steal path (3 of 4 shards have no worker)"
        );
    }

    #[test]
    fn close_rejects_pushes_but_drains_queued() {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 16);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert!(matches!(q.push(3), Err(PushError::Closed(3))));
        let mut drained = Vec::new();
        let mut cur = 0;
        loop {
            match q.pop_some(0, 4, &mut cur) {
                Popped::Items { mut items, .. } => drained.append(&mut items),
                Popped::Drained => break,
                Popped::Empty => panic!("closed+empty must report Drained"),
            }
        }
        drained.sort_unstable();
        assert_eq!(drained, vec![1, 2]);
    }

    #[test]
    fn wait_returns_promptly_on_close() {
        let q: Arc<ShardedQueue<u32>> = Arc::new(ShardedQueue::new(1, 4));
        let waiter = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                // Generous timeout: the close below must cut it short.
                q.wait(Duration::from_secs(30));
            })
        };
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        waiter.join().unwrap();
    }

    #[test]
    fn wait_wakes_on_push() {
        let q: Arc<ShardedQueue<u32>> = Arc::new(ShardedQueue::new(2, 8));
        let waiter = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                q.wait(Duration::from_secs(30));
                items(q.pop_some(0, 1, &mut 0))
            })
        };
        std::thread::sleep(Duration::from_millis(20));
        q.push(7).unwrap();
        assert_eq!(waiter.join().unwrap(), vec![7]);
    }

    #[test]
    fn concurrent_producers_consumers_conserve_items() {
        let q: Arc<ShardedQueue<u64>> = Arc::new(ShardedQueue::new(4, 256));
        let producers: Vec<_> = (0..4u64)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        let v = p * 1000 + i;
                        loop {
                            match q.push(v) {
                                Ok(_) => break,
                                Err(PushError::Full(_)) => std::thread::yield_now(),
                                Err(PushError::Closed(_)) => panic!("closed early"),
                            }
                        }
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..4usize)
            .map(|w| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    let mut cur = 0;
                    loop {
                        match q.pop_some(w, 8, &mut cur) {
                            Popped::Items { mut items, .. } => got.append(&mut items),
                            Popped::Empty => q.wait(Duration::from_millis(5)),
                            Popped::Drained => return got,
                        }
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all = Vec::new();
        for c in consumers {
            all.append(&mut c.join().unwrap());
        }
        all.sort_unstable();
        let mut expect: Vec<u64> =
            (0..4u64).flat_map(|p| (0..500u64).map(move |i| p * 1000 + i)).collect();
        expect.sort_unstable();
        assert_eq!(all, expect, "every pushed item popped exactly once");
    }
}
