//! Sharded ingress: per-worker bounded deques with work stealing.
//!
//! The coordinator used to funnel every request through one
//! `mpsc::sync_channel` guarded by a `Mutex<Receiver>`. At high worker
//! counts that single channel is the scaling ceiling, and one slow batch
//! head-of-line-blocks everything behind it in the shared FIFO.
//! [`ShardedQueue`] replaces it:
//!
//! * **one bounded deque per worker** — the submit path places each item
//!   on the shallowest shard (round-robin tie-break), so ingress pressure
//!   spreads without a global lock;
//! * **work stealing** — a worker drains its own deque first and, when
//!   empty, steals the *oldest* entries from the deepest sibling, so a
//!   worker pinned on a slow batch cannot strand the requests queued
//!   behind it;
//! * **exact close semantics** — `close()` latches a per-shard flag under
//!   each shard's lock, and [`ShardedQueue::pop_some`] only reports
//!   [`Popped::Drained`] after observing every shard empty *and* closed
//!   under its lock. Because a push checks the same flag under the same
//!   lock, no submission can slip into a queue no worker will ever visit:
//!   every accepted item is drained, every post-close submit is rejected.
//!
//! Blocking: idle workers sleep on one shared condvar with a bounded
//! timeout. Pushers only touch the condvar when a sleeper is registered,
//! so the ingress hot path stays two uncontended lock acquisitions (the
//! shard, and nothing else).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Why a push was refused.
#[derive(Debug)]
pub enum PushError<T> {
    /// Every shard is at capacity (backpressure); the item is handed back.
    Full(T),
    /// The queue is closed (coordinator shutting down).
    Closed(T),
}

/// Result of a [`ShardedQueue::pop_some`] sweep.
#[derive(Debug)]
pub enum Popped<T> {
    /// Items obtained; `stolen` is how many came from a sibling shard
    /// (0 = all from the caller's own deque).
    Items { items: Vec<T>, stolen: usize },
    /// Nothing available right now; the queue is still open.
    Empty,
    /// Every shard was observed empty *and* closed under its lock: no item
    /// exists and none can ever arrive. The caller can exit.
    Drained,
}

struct ShardState<T> {
    queue: VecDeque<T>,
    closed: bool,
}

struct Shard<T> {
    state: Mutex<ShardState<T>>,
    /// Depth mirror maintained under the lock, readable without it —
    /// drives shortest-queue placement, deepest-victim stealing and the
    /// metrics gauges. A stale read only costs a suboptimal choice.
    depth: AtomicUsize,
}

/// The sharded ingress queue. See the module docs.
pub struct ShardedQueue<T> {
    shards: Box<[Shard<T>]>,
    capacity_per_shard: usize,
    /// Round-robin cursor breaking shortest-queue ties.
    cursor: AtomicUsize,
    /// Fast "no push can ever succeed again" flag (the per-shard flags
    /// under their locks are the authoritative close protocol).
    closed: AtomicBool,
    /// Workers currently parked in [`ShardedQueue::wait`].
    sleepers: AtomicUsize,
    sleep_lock: Mutex<()>,
    wakeup: Condvar,
}

/// Mutex lock that shrugs off poisoning: queue integrity is maintained by
/// the operations themselves, not by the absence of panics elsewhere.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl<T> ShardedQueue<T> {
    /// Create `shards` deques sharing `total_capacity` (split evenly,
    /// rounded up so every shard holds at least one item).
    pub fn new(shards: usize, total_capacity: usize) -> Self {
        assert!(shards >= 1, "need at least one shard");
        assert!(total_capacity >= 1, "need capacity for at least one item");
        let capacity_per_shard = total_capacity.div_ceil(shards);
        ShardedQueue {
            shards: (0..shards)
                .map(|_| Shard {
                    state: Mutex::new(ShardState { queue: VecDeque::new(), closed: false }),
                    depth: AtomicUsize::new(0),
                })
                .collect(),
            capacity_per_shard,
            cursor: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            sleepers: AtomicUsize::new(0),
            sleep_lock: Mutex::new(()),
            wakeup: Condvar::new(),
        }
    }

    /// Number of shards (one per worker).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Per-shard capacity bound.
    pub fn capacity_per_shard(&self) -> usize {
        self.capacity_per_shard
    }

    /// Instantaneous per-shard depths (racy gauges, for observability).
    pub fn depths(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.depth.load(Ordering::SeqCst)).collect()
    }

    /// Instantaneous total queued items.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.depth.load(Ordering::SeqCst)).sum()
    }

    /// True when no shard currently holds an item.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True once [`ShardedQueue::close`] has run.
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Place `item` on the shallowest shard (round-robin tie-break),
    /// falling through ring-order when the depth hint was stale and the
    /// chosen shard is actually full. Never blocks.
    pub fn push(&self, item: T) -> Result<usize, PushError<T>> {
        let n = self.shards.len();
        let start = self.cursor.fetch_add(1, Ordering::Relaxed) % n;
        let mut best = start;
        let mut best_depth = self.shards[start].depth.load(Ordering::SeqCst);
        for k in 1..n {
            let i = (start + k) % n;
            let d = self.shards[i].depth.load(Ordering::SeqCst);
            if d < best_depth {
                best = i;
                best_depth = d;
            }
        }
        let mut item = Some(item);
        for k in 0..n {
            let i = (best + k) % n;
            let shard = &self.shards[i];
            let mut st = lock(&shard.state);
            if st.closed {
                return Err(PushError::Closed(item.take().expect("item present")));
            }
            if st.queue.len() >= self.capacity_per_shard {
                continue;
            }
            st.queue.push_back(item.take().expect("item present"));
            shard.depth.store(st.queue.len(), Ordering::SeqCst);
            drop(st);
            self.notify_one();
            return Ok(i);
        }
        Err(PushError::Full(item.take().expect("item present")))
    }

    /// The one lock-drain-store-depth primitive every pop path shares:
    /// lock shard `i`, drain up to `max` items FIFO (refreshing the depth
    /// mirror under the same lock), and report the closed flag as
    /// observed under that lock — the evidence a `Drained` verdict needs.
    fn drain_locked(&self, i: usize, max: usize) -> (Option<Vec<T>>, bool) {
        let shard = &self.shards[i];
        let mut st = lock(&shard.state);
        let closed = st.closed;
        if st.queue.is_empty() {
            return (None, closed);
        }
        let k = st.queue.len().min(max);
        let items: Vec<T> = st.queue.drain(..k).collect();
        shard.depth.store(st.queue.len(), Ordering::SeqCst);
        (Some(items), closed)
    }

    /// Pop up to `max` items for worker `home`: its own deque first
    /// (FIFO), then a steal sweep over the siblings — deepest victim
    /// first, oldest entries first, so stolen requests keep their latency
    /// ordering. See [`Popped`] for the empty/drained distinction.
    pub fn pop_some(&self, home: usize, max: usize) -> Popped<T> {
        let n = self.shards.len();
        debug_assert!(max > 0, "pop_some needs room for at least one item");
        let home = home % n;
        if let (Some(items), _) = self.drain_locked(home, max) {
            return Popped::Items { items, stolen: 0 };
        }

        // Steal sweep: deepest sibling first (racy hint), then ring order.
        // Along the way, fold each sibling's (empty && closed) status
        // observed under its lock — the evidence for a `Drained` verdict.
        // No allocation: the victim order is a probe plus a ring walk.
        let mut deepest = home; // sentinel: no non-empty hint found
        let mut depth_hint = 0;
        for k in 1..n {
            let i = (home + k) % n;
            let d = self.shards[i].depth.load(Ordering::SeqCst);
            if d > depth_hint {
                depth_hint = d;
                deepest = i;
            }
        }
        let mut all_closed = true;
        if deepest != home {
            if let Some(stolen) = self.steal_from(deepest, max, &mut all_closed) {
                return stolen;
            }
        }
        for k in 1..n {
            let i = (home + k) % n;
            if i == deepest {
                continue; // already probed above
            }
            if let Some(stolen) = self.steal_from(i, max, &mut all_closed) {
                return stolen;
            }
        }

        // Re-check home under its lock: an item may have landed there
        // during the sweep, and the Drained verdict needs home's own
        // (empty && closed) observed under the lock too.
        match self.drain_locked(home, max) {
            (Some(items), _) => Popped::Items { items, stolen: 0 },
            (None, home_closed) if all_closed && home_closed => Popped::Drained,
            (None, _) => Popped::Empty,
        }
    }

    /// Steal sweep step over shard `i` (see [`ShardedQueue::drain_locked`]);
    /// when it is empty, fold its closed flag into `all_closed` for the
    /// caller's `Drained` verdict.
    fn steal_from(&self, i: usize, max: usize, all_closed: &mut bool) -> Option<Popped<T>> {
        match self.drain_locked(i, max) {
            (Some(items), _) => Some(Popped::Items { stolen: items.len(), items }),
            (None, closed) => {
                *all_closed &= closed;
                None
            }
        }
    }

    /// Park the caller until an item is likely available, the queue
    /// closes, or `timeout` elapses — whichever comes first. May wake
    /// spuriously; callers re-poll.
    pub fn wait(&self, timeout: Duration) {
        if !self.is_empty() || self.is_closed() {
            return;
        }
        self.sleepers.fetch_add(1, Ordering::SeqCst);
        let guard = lock(&self.sleep_lock);
        if self.is_empty() && !self.is_closed() {
            let _ = self.wakeup.wait_timeout(guard, timeout);
        }
        self.sleepers.fetch_sub(1, Ordering::SeqCst);
    }

    fn notify_one(&self) {
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            // Taking the sleep lock orders this notify after any sleeper's
            // final emptiness re-check, closing the lost-wakeup window.
            drop(lock(&self.sleep_lock));
            self.wakeup.notify_one();
        }
    }

    /// Close the queue: latch every shard's closed flag (under its lock)
    /// and wake all sleepers. Pushes fail from here on; queued items stay
    /// poppable until drained.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        for shard in self.shards.iter() {
            lock(&shard.state).closed = true;
        }
        drop(lock(&self.sleep_lock));
        self.wakeup.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn items<T>(p: Popped<T>) -> Vec<T> {
        match p {
            Popped::Items { items, .. } => items,
            other => panic!("expected items, got {}", kind(&other)),
        }
    }

    fn kind<T>(p: &Popped<T>) -> &'static str {
        match p {
            Popped::Items { .. } => "Items",
            Popped::Empty => "Empty",
            Popped::Drained => "Drained",
        }
    }

    #[test]
    fn push_pop_fifo_within_shard() {
        let q: ShardedQueue<u32> = ShardedQueue::new(1, 16);
        for v in 0..5 {
            q.push(v).unwrap();
        }
        assert_eq!(items(q.pop_some(0, 3)), vec![0, 1, 2]);
        assert_eq!(items(q.pop_some(0, 8)), vec![3, 4]);
        assert!(matches!(q.pop_some(0, 1), Popped::Empty));
    }

    #[test]
    fn shortest_queue_placement_balances() {
        let q: ShardedQueue<u32> = ShardedQueue::new(4, 400);
        for v in 0..100 {
            q.push(v).unwrap();
        }
        let depths = q.depths();
        assert_eq!(depths.iter().sum::<usize>(), 100);
        assert!(
            depths.iter().all(|&d| d == 25),
            "shortest-queue placement must balance: {depths:?}"
        );
    }

    #[test]
    fn backpressure_rejects_when_all_full() {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 4); // 2 per shard
        for v in 0..4 {
            q.push(v).unwrap();
        }
        match q.push(99) {
            Err(PushError::Full(v)) => assert_eq!(v, 99, "item handed back"),
            other => panic!("expected Full, got {other:?}"),
        }
        // Draining frees capacity again.
        let _ = items(q.pop_some(0, 1));
        q.push(99).unwrap();
    }

    #[test]
    fn steal_takes_oldest_from_sibling() {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 64);
        let mut on0 = Vec::new();
        for v in 0..8 {
            if q.push(v).unwrap() == 0 {
                on0.push(v);
            }
        }
        assert!(!on0.is_empty(), "placement must use shard 0");
        // Worker 1 drains its own shard first, then steals shard 0's
        // entries — all of them, oldest first.
        loop {
            match q.pop_some(1, 8) {
                Popped::Items { items, stolen: 0 } => {
                    assert!(items.iter().all(|v| !on0.contains(v)), "own-shard drain");
                }
                Popped::Items { items, stolen } => {
                    assert_eq!(stolen, items.len());
                    assert_eq!(items, on0, "steal must take oldest-first FIFO order");
                    break;
                }
                other => panic!("expected items, got {}", kind(&other)),
            }
        }
    }

    #[test]
    fn close_rejects_pushes_but_drains_queued() {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 16);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert!(matches!(q.push(3), Err(PushError::Closed(3))));
        let mut drained = Vec::new();
        loop {
            match q.pop_some(0, 4) {
                Popped::Items { mut items, .. } => drained.append(&mut items),
                Popped::Drained => break,
                Popped::Empty => panic!("closed+empty must report Drained"),
            }
        }
        drained.sort_unstable();
        assert_eq!(drained, vec![1, 2]);
    }

    #[test]
    fn wait_returns_promptly_on_close() {
        let q: Arc<ShardedQueue<u32>> = Arc::new(ShardedQueue::new(1, 4));
        let waiter = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                // Generous timeout: the close below must cut it short.
                q.wait(Duration::from_secs(30));
            })
        };
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        waiter.join().unwrap();
    }

    #[test]
    fn wait_wakes_on_push() {
        let q: Arc<ShardedQueue<u32>> = Arc::new(ShardedQueue::new(2, 8));
        let waiter = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                q.wait(Duration::from_secs(30));
                items(q.pop_some(0, 1))
            })
        };
        std::thread::sleep(Duration::from_millis(20));
        q.push(7).unwrap();
        assert_eq!(waiter.join().unwrap(), vec![7]);
    }

    #[test]
    fn concurrent_producers_consumers_conserve_items() {
        let q: Arc<ShardedQueue<u64>> = Arc::new(ShardedQueue::new(4, 256));
        let producers: Vec<_> = (0..4u64)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        let v = p * 1000 + i;
                        loop {
                            match q.push(v) {
                                Ok(_) => break,
                                Err(PushError::Full(_)) => std::thread::yield_now(),
                                Err(PushError::Closed(_)) => panic!("closed early"),
                            }
                        }
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..4usize)
            .map(|w| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match q.pop_some(w, 8) {
                            Popped::Items { mut items, .. } => got.append(&mut items),
                            Popped::Empty => q.wait(Duration::from_millis(5)),
                            Popped::Drained => return got,
                        }
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all = Vec::new();
        for c in consumers {
            all.append(&mut c.join().unwrap());
        }
        all.sort_unstable();
        let mut expect: Vec<u64> =
            (0..4u64).flat_map(|p| (0..500u64).map(move |i| p * 1000 + i)).collect();
        expect.sort_unstable();
        assert_eq!(all, expect, "every pushed item popped exactly once");
    }
}
