//! Binary artifact codecs shared with the Python build path.
//!
//! Two formats, both little-endian with 4-byte ASCII magic:
//!
//! * **SNND** — labelled image datasets (`artifacts/digits_{train,test}.bin`).
//! * **SNNW** — trained weights + LIF constants (`artifacts/weights.bin`):
//!   the dense 9-bit packed BRAM image of [`crate::fixed::pack_weights`]
//!   plus the threshold/decay the weights were calibrated for.
//!
//! The writers in `python/compile/artifact_io.py` emit byte-identical
//! files; integration tests round-trip both directions.

use std::fs;
use std::io::Write as _;
use std::path::Path;

use super::{Dataset, Image, IMG_PIXELS, IMG_SIDE};
use crate::config::LayerParams;
use crate::error::{Error, Result};
use crate::fixed::{pack_weights, unpack_weights, SparseWeightStack, WeightMatrix, WeightStack};

const DATASET_MAGIC: &[u8; 4] = b"SNND";
const WEIGHTS_MAGIC: &[u8; 4] = b"SNNW";
const VERSION: u32 = 1;
/// SNNW version 2: the multi-layer stack layout (layer count + per-layer
/// geometry header, then one packed blob per layer).
const STACK_VERSION: u32 = 2;
/// SNNW version 3: version 2 plus a per-layer parameter block — one
/// `(v_th: i32, decay_shift: u32, prune_after: u32)` triple per layer
/// between the scalar calibration and the packed blobs. Written only when
/// an artifact actually carries per-layer overrides, so uniform stacks
/// keep producing byte-identical v2 files.
const LAYER_PARAMS_VERSION: u32 = 3;
/// SNNW version 4: version 3's layout made self-describing (an explicit
/// `has_layer_params` flag instead of implying the block from the version
/// word) plus a sparse section between the calibration and the packed
/// blobs: the magnitude-pruning threshold the CSR serving path was
/// calibrated for and one expected-nnz word per layer (`|w| >= threshold`
/// survivor count, checked on load so a corrupted-but-unpackable blob is
/// still rejected). Written only when an artifact carries sparse
/// calibration, so dense artifacts keep producing byte-identical v2/v3
/// files.
const SPARSE_VERSION: u32 = 4;

/// Weights plus the LIF calibration they were trained against.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightArtifact {
    pub weights: WeightMatrix,
    /// Firing threshold the training run calibrated for.
    pub v_th: i32,
    /// Decay shift the training run calibrated for.
    pub decay_shift: u32,
    /// Recommended inference window.
    pub timesteps: u32,
    /// Calibrated pruning point (fires before a neuron is gated off);
    /// 0 = pruning off.
    pub prune_after: u32,
}

impl WeightArtifact {
    /// The [`crate::SnnConfig`] these weights were calibrated for.
    pub fn config(&self) -> crate::SnnConfig {
        use crate::config::PruneMode;
        crate::SnnConfig {
            topology: vec![self.weights.n_inputs(), self.weights.n_outputs()],
            v_th: self.v_th,
            decay_shift: self.decay_shift,
            weight_bits: self.weights.bits(),
            timesteps: self.timesteps,
            prune: if self.prune_after == 0 {
                PruneMode::Off
            } else {
                PruneMode::AfterFires { after_spikes: self.prune_after }
            },
            ..crate::SnnConfig::paper()
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    path: &'a Path,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::malformed(self.path, format!("truncated at offset {}", self.pos)));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
}

/// Write a dataset to `path` in SNND format.
pub fn save_dataset(path: impl AsRef<Path>, ds: &Dataset) -> Result<()> {
    let path = path.as_ref();
    let mut out = Vec::with_capacity(16 + ds.len() * (IMG_PIXELS + 1));
    out.extend_from_slice(DATASET_MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(ds.len() as u32).to_le_bytes());
    out.extend_from_slice(&(IMG_SIDE as u16).to_le_bytes());
    out.extend_from_slice(&(IMG_SIDE as u16).to_le_bytes());
    for img in &ds.images {
        out.push(img.label);
        out.extend_from_slice(&img.pixels);
    }
    write_atomic(path, &out)
}

/// Read a dataset from an SNND file.
pub fn load_dataset(path: impl AsRef<Path>) -> Result<Dataset> {
    let path = path.as_ref();
    let buf = fs::read(path).map_err(|e| Error::io(path, e))?;
    let mut r = Reader { buf: &buf, pos: 0, path };
    if r.take(4)? != DATASET_MAGIC {
        return Err(Error::malformed(path, "bad magic (want SNND)"));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(Error::malformed(path, format!("unsupported version {version}")));
    }
    let count = r.u32()? as usize;
    let h = r.u16()? as usize;
    let w = r.u16()? as usize;
    if h != IMG_SIDE || w != IMG_SIDE {
        return Err(Error::malformed(path, format!("unsupported geometry {h}x{w}")));
    }
    let mut images = Vec::with_capacity(count);
    for _ in 0..count {
        let label = r.take(1)?[0];
        if label > 9 {
            return Err(Error::malformed(path, format!("label {label} > 9")));
        }
        let pixels = r.take(IMG_PIXELS)?.to_vec();
        images.push(Image { label, pixels });
    }
    if r.pos != buf.len() {
        return Err(Error::malformed(path, format!("{} trailing bytes", buf.len() - r.pos)));
    }
    Ok(Dataset { images })
}

/// Write weights + calibration to `path` in SNNW format.
pub fn save_weights(path: impl AsRef<Path>, art: &WeightArtifact) -> Result<()> {
    let path = path.as_ref();
    let packed = pack_weights(&art.weights);
    let mut out = Vec::with_capacity(36 + packed.len());
    out.extend_from_slice(WEIGHTS_MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(art.weights.n_inputs() as u32).to_le_bytes());
    out.extend_from_slice(&(art.weights.n_outputs() as u32).to_le_bytes());
    out.extend_from_slice(&art.weights.bits().to_le_bytes());
    out.extend_from_slice(&art.v_th.to_le_bytes());
    out.extend_from_slice(&art.decay_shift.to_le_bytes());
    out.extend_from_slice(&art.timesteps.to_le_bytes());
    out.extend_from_slice(&art.prune_after.to_le_bytes());
    out.extend_from_slice(&(packed.len() as u32).to_le_bytes());
    out.extend_from_slice(&packed);
    write_atomic(path, &out)
}

/// Read weights + calibration from an SNNW file.
pub fn load_weights(path: impl AsRef<Path>) -> Result<WeightArtifact> {
    let path = path.as_ref();
    let buf = fs::read(path).map_err(|e| Error::io(path, e))?;
    let mut r = Reader { buf: &buf, pos: 0, path };
    if r.take(4)? != WEIGHTS_MAGIC {
        return Err(Error::malformed(path, "bad magic (want SNNW)"));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(Error::malformed(path, format!("unsupported version {version}")));
    }
    let n_inputs = r.u32()? as usize;
    let n_outputs = r.u32()? as usize;
    let bits = r.u32()?;
    if !(2..=16).contains(&bits) {
        return Err(Error::malformed(path, format!("weight bits {bits} out of range")));
    }
    let v_th = r.i32()?;
    let decay_shift = r.u32()?;
    let timesteps = r.u32()?;
    let prune_after = r.u32()?;
    let packed_len = r.u32()? as usize;
    let packed = r.take(packed_len)?;
    let expected = (n_inputs * n_outputs * bits as usize + 7) / 8;
    if packed_len != expected {
        return Err(Error::malformed(
            path,
            format!("packed length {packed_len} != expected {expected}"),
        ));
    }
    let weights = unpack_weights(packed, n_inputs, n_outputs, bits)?;
    if r.pos != buf.len() {
        return Err(Error::malformed(path, format!("{} trailing bytes", buf.len() - r.pos)));
    }
    Ok(WeightArtifact { weights, v_th, decay_shift, timesteps, prune_after })
}

/// A multi-layer weight chain plus the LIF calibration it was trained
/// against — the N-layer generalization of [`WeightArtifact`], stored as
/// SNNW version 2 (uniform calibration) or version 3 (per-layer
/// calibration block).
#[derive(Debug, Clone, PartialEq)]
pub struct WeightStackArtifact {
    pub stack: WeightStack,
    pub v_th: i32,
    pub decay_shift: u32,
    pub timesteps: u32,
    pub prune_after: u32,
    /// Per-layer overrides of the scalar calibration above. Empty = every
    /// layer shares the scalars (serialized as v2, byte-identical to
    /// pre-existing artifacts); non-empty = one entry per layer,
    /// serialized as the v3 parameter block. The writer stores *resolved*
    /// values, so a reloaded artifact carries all-`Some` entries.
    pub layer_params: Vec<LayerParams>,
    /// Magnitude-pruning threshold the sparse (CSR) serving path was
    /// calibrated for, from the export pipeline's unstructured pruning
    /// sweep. `None` = no sparse calibration (serializes as v2/v3,
    /// byte-identical to pre-v4 artifacts); `Some(t)` adds the v4 sparse
    /// section. Threshold 0 is a legal calibration: "serve sparse, prune
    /// nothing" (the CSR sweep is bit-exact with dense there).
    pub sparse_threshold: Option<i32>,
}

impl WeightStackArtifact {
    /// The [`crate::SnnConfig`] this stack was calibrated for.
    pub fn config(&self) -> crate::SnnConfig {
        use crate::config::PruneMode;
        crate::SnnConfig {
            topology: self.stack.topology(),
            v_th: self.v_th,
            decay_shift: self.decay_shift,
            weight_bits: self.stack.bits(),
            timesteps: self.timesteps,
            prune: if self.prune_after == 0 {
                PruneMode::Off
            } else {
                PruneMode::AfterFires { after_spikes: self.prune_after }
            },
            layer_params: self.layer_params.clone(),
            ..crate::SnnConfig::paper()
        }
    }

    /// The resolved `(v_th, decay_shift, prune_after)` triple of layer `l`
    /// — what the v3 writer serializes. `prune_after` uses the same
    /// encoding as the scalar field: 0 = pruning off.
    fn resolved_layer(&self, l: usize) -> (i32, u32, u32) {
        use crate::config::PruneMode;
        let over = self.layer_params.get(l).copied().unwrap_or_default();
        let prune_after = match over.prune {
            Some(PruneMode::Off) => 0,
            Some(PruneMode::AfterFires { after_spikes }) => after_spikes,
            None => self.prune_after,
        };
        (over.v_th.unwrap_or(self.v_th), over.decay_shift.unwrap_or(self.decay_shift), prune_after)
    }

    /// The CSR view of the stack at the artifact's calibrated threshold.
    /// Artifacts without a sparse section use threshold 0 (every entry
    /// kept), so the result is always a faithful sparse serving image.
    pub fn to_csr(&self) -> SparseWeightStack {
        self.stack.to_csr(self.sparse_threshold.unwrap_or(0))
    }
}

/// Write a multi-layer weight stack + calibration. Uniform artifacts
/// (empty `layer_params`, no sparse calibration) serialize as SNNW v2,
/// byte-identical to the previous writer; artifacts with per-layer
/// overrides add the v3 parameter block (resolved values, one triple per
/// layer); artifacts with a sparse threshold serialize as v4 (flagged
/// parameter block + sparse section).
pub fn save_weight_stack(path: impl AsRef<Path>, art: &WeightStackArtifact) -> Result<()> {
    let path = path.as_ref();
    if !art.layer_params.is_empty() && art.layer_params.len() != art.stack.n_layers() {
        return Err(Error::InvalidConfig(format!(
            "artifact layer_params carries {} entries for a {}-layer stack",
            art.layer_params.len(),
            art.stack.n_layers()
        )));
    }
    if let Some(t) = art.sparse_threshold {
        if t < 0 {
            return Err(Error::InvalidConfig(format!("sparse threshold {t} must be >= 0")));
        }
    }
    let version = if art.sparse_threshold.is_some() {
        SPARSE_VERSION
    } else if art.layer_params.is_empty() {
        STACK_VERSION
    } else {
        LAYER_PARAMS_VERSION
    };
    let mut out = Vec::new();
    out.extend_from_slice(WEIGHTS_MAGIC);
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&(art.stack.n_layers() as u32).to_le_bytes());
    for m in art.stack.layers() {
        out.extend_from_slice(&(m.n_inputs() as u32).to_le_bytes());
        out.extend_from_slice(&(m.n_outputs() as u32).to_le_bytes());
    }
    out.extend_from_slice(&art.stack.bits().to_le_bytes());
    out.extend_from_slice(&art.v_th.to_le_bytes());
    out.extend_from_slice(&art.decay_shift.to_le_bytes());
    out.extend_from_slice(&art.timesteps.to_le_bytes());
    out.extend_from_slice(&art.prune_after.to_le_bytes());
    let write_params = !art.layer_params.is_empty();
    if version == SPARSE_VERSION {
        out.extend_from_slice(&(write_params as u32).to_le_bytes());
    }
    if write_params {
        for l in 0..art.stack.n_layers() {
            let (v_th, decay_shift, prune_after) = art.resolved_layer(l);
            out.extend_from_slice(&v_th.to_le_bytes());
            out.extend_from_slice(&decay_shift.to_le_bytes());
            out.extend_from_slice(&prune_after.to_le_bytes());
        }
    }
    if let Some(t) = art.sparse_threshold {
        out.extend_from_slice(&t.to_le_bytes());
        let csr = art.stack.to_csr(t);
        for l in 0..csr.n_layers() {
            out.extend_from_slice(&(csr.layer(l).nnz() as u32).to_le_bytes());
        }
    }
    for m in art.stack.layers() {
        let packed = pack_weights(m);
        out.extend_from_slice(&(packed.len() as u32).to_le_bytes());
        out.extend_from_slice(&packed);
    }
    write_atomic(path, &out)
}

/// Read a weight stack from an SNNW file. Accepts the legacy single-layer
/// version 1 (loaded as a one-layer stack), the uniform multi-layer
/// version 2, the per-layer-parameter version 3, and the sparse-calibrated
/// version 4, so one loader serves every artifact vintage.
pub fn load_weight_stack(path: impl AsRef<Path>) -> Result<WeightStackArtifact> {
    let path = path.as_ref();
    let buf = fs::read(path).map_err(|e| Error::io(path, e))?;
    let mut r = Reader { buf: &buf, pos: 0, path };
    if r.take(4)? != WEIGHTS_MAGIC {
        return Err(Error::malformed(path, "bad magic (want SNNW)"));
    }
    let version = r.u32()?;
    if version == VERSION {
        // Legacy single-layer artifact: reuse the v1 loader wholesale.
        let art = load_weights(path)?;
        return Ok(WeightStackArtifact {
            stack: art.weights.into(),
            v_th: art.v_th,
            decay_shift: art.decay_shift,
            timesteps: art.timesteps,
            prune_after: art.prune_after,
            layer_params: Vec::new(),
            sparse_threshold: None,
        });
    }
    if version != STACK_VERSION && version != LAYER_PARAMS_VERSION && version != SPARSE_VERSION {
        return Err(Error::malformed(path, format!("unsupported version {version}")));
    }
    let n_layers = r.u32()? as usize;
    if n_layers == 0 || n_layers > 16 {
        return Err(Error::malformed(path, format!("layer count {n_layers} out of range")));
    }
    let mut dims = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let ni = r.u32()? as usize;
        let no = r.u32()? as usize;
        dims.push((ni, no));
    }
    let bits = r.u32()?;
    if !(2..=16).contains(&bits) {
        return Err(Error::malformed(path, format!("weight bits {bits} out of range")));
    }
    let v_th = r.i32()?;
    let decay_shift = r.u32()?;
    let timesteps = r.u32()?;
    let prune_after = r.u32()?;
    // v3 implies the parameter block from the version word; v4 carries an
    // explicit flag so sparse artifacts work with or without overrides.
    let has_layer_params = if version == SPARSE_VERSION {
        match r.u32()? {
            0 => false,
            1 => true,
            f => return Err(Error::malformed(path, format!("bad layer_params flag {f}"))),
        }
    } else {
        version == LAYER_PARAMS_VERSION
    };
    let mut layer_params = Vec::new();
    if has_layer_params {
        use crate::config::PruneMode;
        for l in 0..n_layers {
            let lv_th = r.i32()?;
            let ldecay = r.u32()?;
            let lprune = r.u32()?;
            if ldecay == 0 || ldecay > 30 {
                return Err(Error::malformed(
                    path,
                    format!("layer {l} decay_shift {ldecay} out of range"),
                ));
            }
            layer_params.push(LayerParams {
                v_th: Some(lv_th),
                decay_shift: Some(ldecay),
                prune: Some(if lprune == 0 {
                    PruneMode::Off
                } else {
                    PruneMode::AfterFires { after_spikes: lprune }
                }),
            });
        }
    }
    let mut sparse_threshold = None;
    let mut expected_nnz = Vec::new();
    if version == SPARSE_VERSION {
        let t = r.i32()?;
        if t < 0 {
            return Err(Error::malformed(path, format!("sparse threshold {t} < 0")));
        }
        sparse_threshold = Some(t);
        for _ in 0..n_layers {
            expected_nnz.push(r.u32()? as usize);
        }
    }
    let mut layers = Vec::with_capacity(n_layers);
    for &(ni, no) in &dims {
        let packed_len = r.u32()? as usize;
        let expected = (ni * no * bits as usize + 7) / 8;
        if packed_len != expected {
            return Err(Error::malformed(
                path,
                format!("packed length {packed_len} != expected {expected} for {ni}x{no}"),
            ));
        }
        let packed = r.take(packed_len)?;
        layers.push(unpack_weights(packed, ni, no, bits)?);
    }
    if r.pos != buf.len() {
        return Err(Error::malformed(path, format!("{} trailing bytes", buf.len() - r.pos)));
    }
    let stack = WeightStack::from_layers(layers)
        .map_err(|e| Error::malformed(path, format!("inconsistent layer chain: {e}")))?;
    if let Some(t) = sparse_threshold {
        // The stored survivor counts are a checksum over the weights: a
        // blob that unpacks cleanly but was bit-flipped almost surely
        // shifts some |w| across the threshold, so recount and compare.
        let csr = stack.to_csr(t);
        for l in 0..csr.n_layers() {
            let got = csr.layer(l).nnz();
            if got != expected_nnz[l] {
                return Err(Error::malformed(
                    path,
                    format!(
                        "layer {l}: {got} entries survive threshold {t}, header promised {}",
                        expected_nnz[l]
                    ),
                ));
            }
        }
    }
    Ok(WeightStackArtifact {
        stack,
        v_th,
        decay_shift,
        timesteps,
        prune_after,
        layer_params,
        sparse_threshold,
    })
}

/// Write via a temp file + rename so concurrent readers never observe a
/// half-written artifact.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    let mut f = fs::File::create(&tmp).map_err(|e| Error::io(&tmp, e))?;
    f.write_all(bytes).map_err(|e| Error::io(&tmp, e))?;
    f.sync_all().map_err(|e| Error::io(&tmp, e))?;
    fs::rename(&tmp, path).map_err(|e| Error::io(path, e))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DigitGen;
    use crate::testutil::PropRunner;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("snn_codec_{}", std::process::id()));
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn dataset_roundtrip() {
        let ds = DigitGen::new(1).dataset(3);
        let p = tmpdir().join("ds_roundtrip.bin");
        save_dataset(&p, &ds).unwrap();
        let back = load_dataset(&p).unwrap();
        assert_eq!(back.len(), ds.len());
        for (a, b) in ds.images.iter().zip(&back.images) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.pixels, b.pixels);
        }
    }

    #[test]
    fn weights_roundtrip() {
        let m = WeightMatrix::from_rows(4, 3, 9, (0..12).map(|v| v * 17 - 100).collect()).unwrap();
        let art = WeightArtifact { weights: m, v_th: 128, decay_shift: 3, timesteps: 20, prune_after: 3 };
        let p = tmpdir().join("w_roundtrip.bin");
        save_weights(&p, &art).unwrap();
        let back = load_weights(&p).unwrap();
        assert_eq!(back, art);
    }

    #[test]
    fn weight_stack_roundtrip_v2() {
        let l0 = WeightMatrix::from_rows(6, 4, 9, (0..24).map(|v| v * 11 - 120).collect()).unwrap();
        let l1 = WeightMatrix::from_rows(4, 3, 9, (0..12).map(|v| 90 - v * 7).collect()).unwrap();
        let art = WeightStackArtifact {
            stack: WeightStack::from_layers(vec![l0, l1]).unwrap(),
            v_th: 200,
            decay_shift: 2,
            timesteps: 12,
            prune_after: 0,
            layer_params: Vec::new(),
            sparse_threshold: None,
        };
        let p = tmpdir().join("stack_roundtrip.bin");
        save_weight_stack(&p, &art).unwrap();
        let back = load_weight_stack(&p).unwrap();
        assert_eq!(back, art);
        assert_eq!(back.config().topology, vec![6, 4, 3]);
        // Uniform artifacts must keep writing v2 bytes (read-compat with
        // every pre-v3 consumer): version word at offset 4.
        let bytes = fs::read(&p).unwrap();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), 2);
    }

    #[test]
    fn weight_stack_roundtrip_v3_per_layer_params() {
        use crate::config::PruneMode;
        let l0 = WeightMatrix::from_rows(6, 4, 9, (0..24).map(|v| v * 11 - 120).collect()).unwrap();
        let l1 = WeightMatrix::from_rows(4, 3, 9, (0..12).map(|v| 90 - v * 7).collect()).unwrap();
        let art = WeightStackArtifact {
            stack: WeightStack::from_layers(vec![l0, l1]).unwrap(),
            v_th: 200,
            decay_shift: 2,
            timesteps: 12,
            prune_after: 1,
            layer_params: vec![
                LayerParams {
                    v_th: Some(300),
                    decay_shift: Some(3),
                    prune: Some(PruneMode::AfterFires { after_spikes: 2 }),
                },
                LayerParams {
                    v_th: Some(40),
                    decay_shift: Some(4),
                    prune: Some(PruneMode::Off),
                },
            ],
            sparse_threshold: None,
        };
        let p = tmpdir().join("stack_roundtrip_v3.bin");
        save_weight_stack(&p, &art).unwrap();
        let bytes = fs::read(&p).unwrap();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), 3);
        let back = load_weight_stack(&p).unwrap();
        assert_eq!(back, art);
        let cfg = back.config().validated().unwrap();
        assert_eq!(cfg.layer_v_th(0), 300);
        assert_eq!(cfg.layer_v_th(1), 40);
        assert_eq!(cfg.layer_decay_shift(1), 4);
        assert_eq!(cfg.layer_prune(0), PruneMode::AfterFires { after_spikes: 2 });
        assert_eq!(cfg.layer_prune(1), PruneMode::Off);
        assert_eq!(cfg.max_reachable_margin(), None, "unpruned readout");
    }

    #[test]
    fn weight_stack_v3_writer_resolves_partial_overrides() {
        // A partially-specified override list (None fields inherit the
        // scalars) serializes resolved and loads back fully-specified.
        use crate::config::PruneMode;
        let art = WeightStackArtifact {
            stack: WeightStack::from_layers(vec![
                WeightMatrix::zeros(5, 4, 9),
                WeightMatrix::zeros(4, 2, 9),
            ])
            .unwrap(),
            v_th: 128,
            decay_shift: 3,
            timesteps: 8,
            prune_after: 2,
            layer_params: vec![LayerParams::with_v_th(60), LayerParams::default()],
            sparse_threshold: None,
        };
        let p = tmpdir().join("stack_v3_partial.bin");
        save_weight_stack(&p, &art).unwrap();
        let back = load_weight_stack(&p).unwrap();
        assert_eq!(
            back.layer_params,
            vec![
                LayerParams {
                    v_th: Some(60),
                    decay_shift: Some(3),
                    prune: Some(PruneMode::AfterFires { after_spikes: 2 }),
                },
                LayerParams {
                    v_th: Some(128),
                    decay_shift: Some(3),
                    prune: Some(PruneMode::AfterFires { after_spikes: 2 }),
                },
            ],
            "writer must resolve None fields against the scalar calibration"
        );
        // Resolved and original describe the same architectural config.
        assert_eq!(
            back.config().validated().unwrap().layer_config(0),
            art.config().validated().unwrap().layer_config(0)
        );
        // Arity mismatch is rejected at save time.
        let bad = WeightStackArtifact { layer_params: vec![LayerParams::default()], ..art };
        assert!(save_weight_stack(tmpdir().join("bad_arity.bin"), &bad).is_err());
    }

    #[test]
    fn weight_stack_roundtrip_v4_sparse() {
        let l0 = WeightMatrix::from_rows(6, 4, 9, (0..24).map(|v| v * 11 - 120).collect()).unwrap();
        let l1 = WeightMatrix::from_rows(4, 3, 9, (0..12).map(|v| 90 - v * 7).collect()).unwrap();
        let art = WeightStackArtifact {
            stack: WeightStack::from_layers(vec![l0, l1]).unwrap(),
            v_th: 200,
            decay_shift: 2,
            timesteps: 12,
            prune_after: 0,
            layer_params: Vec::new(),
            sparse_threshold: Some(30),
        };
        let p = tmpdir().join("stack_roundtrip_v4.bin");
        save_weight_stack(&p, &art).unwrap();
        let bytes = fs::read(&p).unwrap();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), 4);
        let back = load_weight_stack(&p).unwrap();
        assert_eq!(back, art);
        // The CSR view honors the calibrated threshold.
        let csr = back.to_csr();
        assert_eq!(csr.topology(), vec![6, 4, 3]);
        assert!(csr.density() < 1.0, "threshold 30 must prune something");
        assert_eq!(csr.to_dense(), art.stack.to_csr(30).to_dense());

        // Lying survivor counts are rejected: bump the first nnz word.
        // Uniform v4 header: magic(4) ver(4) n_layers(4) dims(4*4) bits(4)
        // v_th(4) decay(4) steps(4) prune(4) flag(4) threshold(4) = 52.
        let mut lied = bytes.clone();
        let nnz0 = u32::from_le_bytes(lied[52..56].try_into().unwrap());
        lied[52..56].copy_from_slice(&(nnz0 + 1).to_le_bytes());
        let p2 = tmpdir().join("stack_v4_lied_nnz.bin");
        fs::write(&p2, &lied).unwrap();
        let err = load_weight_stack(&p2).unwrap_err();
        assert!(err.to_string().contains("promised"), "{err}");

        // Negative thresholds never serialize.
        let bad = WeightStackArtifact { sparse_threshold: Some(-1), ..art.clone() };
        assert!(save_weight_stack(tmpdir().join("neg_thresh.bin"), &bad).is_err());
    }

    #[test]
    fn weight_stack_v4_carries_layer_params_and_threshold_zero() {
        use crate::config::PruneMode;
        let l0 = WeightMatrix::from_rows(6, 4, 9, (0..24).map(|v| v * 11 - 120).collect()).unwrap();
        let l1 = WeightMatrix::from_rows(4, 3, 9, (0..12).map(|v| 90 - v * 7).collect()).unwrap();
        let art = WeightStackArtifact {
            stack: WeightStack::from_layers(vec![l0, l1]).unwrap(),
            v_th: 200,
            decay_shift: 2,
            timesteps: 12,
            prune_after: 1,
            layer_params: vec![
                LayerParams {
                    v_th: Some(300),
                    decay_shift: Some(3),
                    prune: Some(PruneMode::AfterFires { after_spikes: 2 }),
                },
                LayerParams { v_th: Some(40), decay_shift: Some(4), prune: Some(PruneMode::Off) },
            ],
            // Threshold 0 = "serve sparse, prune nothing": the CSR image
            // keeps every entry and the sweep is bit-exact with dense.
            sparse_threshold: Some(0),
        };
        let p = tmpdir().join("stack_v4_params.bin");
        save_weight_stack(&p, &art).unwrap();
        let bytes = fs::read(&p).unwrap();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), 4);
        let back = load_weight_stack(&p).unwrap();
        assert_eq!(back, art);
        assert_eq!(back.config().validated().unwrap().layer_v_th(1), 40);
        let csr = back.to_csr();
        assert_eq!(csr.density(), 1.0, "threshold 0 keeps every entry");
        assert_eq!(csr.to_dense(), back.stack);
    }

    #[test]
    fn weight_stack_loader_accepts_legacy_v1() {
        let m = WeightMatrix::from_rows(4, 3, 9, (0..12).map(|v| v * 17 - 100).collect()).unwrap();
        let art =
            WeightArtifact { weights: m.clone(), v_th: 128, decay_shift: 3, timesteps: 20, prune_after: 3 };
        let p = tmpdir().join("stack_legacy.bin");
        save_weights(&p, &art).unwrap();
        let stacked = load_weight_stack(&p).unwrap();
        assert_eq!(stacked.stack.n_layers(), 1);
        assert_eq!(stacked.stack.layer(0), &m);
        assert_eq!(stacked.v_th, 128);
        assert_eq!(stacked.prune_after, 3);
    }

    #[test]
    fn weight_stack_rejects_truncation() {
        let art = WeightStackArtifact {
            stack: WeightStack::from_layers(vec![
                WeightMatrix::zeros(5, 4, 9),
                WeightMatrix::zeros(4, 2, 9),
            ])
            .unwrap(),
            v_th: 100,
            decay_shift: 3,
            timesteps: 8,
            prune_after: 1,
            layer_params: Vec::new(),
            sparse_threshold: None,
        };
        let p = tmpdir().join("stack_trunc.bin");
        save_weight_stack(&p, &art).unwrap();
        let bytes = fs::read(&p).unwrap();
        let p2 = tmpdir().join("stack_trunc_cut.bin");
        fs::write(&p2, &bytes[..bytes.len() - 3]).unwrap();
        assert!(load_weight_stack(&p2).is_err());
    }

    #[test]
    fn rejects_corruption() {
        let ds = DigitGen::new(1).dataset(1);
        let dir = tmpdir();
        let p = dir.join("ds_corrupt.bin");
        save_dataset(&p, &ds).unwrap();
        let mut bytes = fs::read(&p).unwrap();

        // Bad magic.
        let p2 = dir.join("bad_magic.bin");
        let mut b2 = bytes.clone();
        b2[0] = b'X';
        fs::write(&p2, &b2).unwrap();
        assert!(matches!(load_dataset(&p2), Err(Error::MalformedArtifact { .. })));

        // Truncation.
        let p3 = dir.join("trunc.bin");
        fs::write(&p3, &bytes[..bytes.len() - 5]).unwrap();
        assert!(load_dataset(&p3).is_err());

        // Trailing garbage.
        let p4 = dir.join("trailing.bin");
        bytes.push(0);
        fs::write(&p4, &bytes).unwrap();
        assert!(load_dataset(&p4).is_err());

        // Invalid label.
        let p5 = dir.join("badlabel.bin");
        let mut b5 = fs::read(&p).unwrap();
        b5[16] = 99; // first label byte (4 magic + 4 ver + 4 count + 2 h + 2 w)
        fs::write(&p5, &b5).unwrap();
        assert!(load_dataset(&p5).is_err());
    }

    #[test]
    fn missing_file_reports_path() {
        let err = load_dataset("/nonexistent/snn.bin").unwrap_err();
        assert!(err.to_string().contains("/nonexistent/snn.bin"));
    }

    #[test]
    fn prop_weights_roundtrip_random_geometry() {
        let dir = tmpdir();
        PropRunner::new("codec_weights_roundtrip", 50).run(|g| {
            let bits = g.rng.range_i32(2, 12) as u32;
            let ni = g.rng.range_i32(1, 30) as usize;
            let no = g.rng.range_i32(1, 10) as usize;
            let max = (1i32 << (bits - 1)) - 1;
            let data = g.vec_i32(ni * no, -max - 1, max);
            let art = WeightArtifact {
                weights: WeightMatrix::from_rows(ni, no, bits, data).unwrap(),
                v_th: g.rng.range_i32(1, 1000),
                decay_shift: g.rng.range_i32(1, 8) as u32,
                timesteps: g.rng.range_i32(1, 40) as u32,
                prune_after: g.rng.range_i32(0, 5) as u32,
            };
            let p = dir.join(format!("w_prop_{}.bin", g.case));
            save_weights(&p, &art).unwrap();
            assert_eq!(load_weights(&p).unwrap(), art);
        });
    }
}
