//! Synthetic stroke-rendered digit generator (the MNIST substitute).
//!
//! Rendering is **integer-only** and consumes a fixed number of PRNG draws
//! per sample in a documented order, which makes it bit-identical to the
//! mirror implementation in `python/compile/dataset.py`. Pipeline:
//!
//! 1. Draw jitter parameters (translation, rotation, scale, stroke
//!    thickness, peak intensity, per-point jitter) from a
//!    [`crate::prng::derive_stream`] keyed by `(seed, class, index)`.
//! 2. Transform the class's template polylines (256×256 virtual grid):
//!    per-point jitter → rotate about centre (Q10 integer trig tables) →
//!    scale (Q8) → translate.
//! 3. Rasterize at 4× oversampling (112×112 bitmap): Bresenham line walk,
//!    stamping a disc of the drawn thickness at every step.
//! 4. Box-downsample 4×4 → 28×28 coverage in 0..=16, scaled by the drawn
//!    peak intensity.
//!
//! The draw *order* in step 1 is part of the cross-language contract —
//! changing it breaks the golden tests.

use super::templates::TEMPLATES;
use super::{Dataset, Image, IMG_PIXELS, IMG_SIDE};
use crate::prng::derive_stream;

/// Oversampled raster side (4 × 28).
const HI: usize = 112;
/// sin(d°) in Q10 for d = 0..=15 (shared table; see tools/gen_templates.py).
const SIN_Q10: [i32; 16] =
    [0, 18, 36, 54, 71, 89, 107, 125, 143, 160, 178, 195, 213, 230, 248, 265];
/// cos(d°) in Q10 for d = 0..=15.
const COS_Q10: [i32; 16] =
    [1024, 1024, 1023, 1023, 1022, 1020, 1018, 1016, 1014, 1011, 1008, 1005, 1002, 998, 994, 989];

/// The per-sample generation parameters, drawn from the PRNG in this exact
/// field order (one `range_i32` draw each, then two per template point).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GenParams {
    /// Translation in virtual units, `[-14, 14]`.
    pub dx: i32,
    pub dy: i32,
    /// Rotation in degrees, `[-12, 12]`.
    pub angle_deg: i32,
    /// Isotropic scale in Q8 (256 = 1.0), `[210, 290]`.
    pub scale_q8: i32,
    /// Stroke (disc) radius in hi-res pixels, `[8, 12]`.
    pub thickness: i32,
    /// Peak output intensity, `[170, 255]`.
    pub peak: i32,
}

/// Q10 sine for degrees in `[-15, 15]`.
#[inline]
fn sin_q10(deg: i32) -> i32 {
    let a = deg.unsigned_abs() as usize;
    let v = SIN_Q10[a];
    if deg < 0 {
        -v
    } else {
        v
    }
}

/// Q10 cosine for degrees in `[-15, 15]`.
#[inline]
fn cos_q10(deg: i32) -> i32 {
    COS_Q10[deg.unsigned_abs() as usize]
}

/// Map a virtual coordinate (0..256) to the hi-res raster (0..112) with
/// rounding: `x · 112/256 = x · 7/16`.
#[inline]
fn virt_to_hi(v: i32) -> i32 {
    (v * 7 + 8) >> 4
}

/// Stamp a filled disc of radius `r` at `(cx, cy)` into the hi-res bitmap.
fn stamp_disc(bitmap: &mut [u8], cx: i32, cy: i32, r: i32) {
    let r2 = r * r;
    for dy in -r..=r {
        let y = cy + dy;
        if !(0..HI as i32).contains(&y) {
            continue;
        }
        for dx in -r..=r {
            let x = cx + dx;
            if !(0..HI as i32).contains(&x) {
                continue;
            }
            if dx * dx + dy * dy <= r2 {
                bitmap[y as usize * HI + x as usize] = 1;
            }
        }
    }
}

/// Walk a segment with the classic integer Bresenham algorithm, stamping a
/// disc at every visited cell. Endpoints may lie outside the raster; only
/// in-bounds disc pixels are written.
fn stamp_segment(bitmap: &mut [u8], x0: i32, y0: i32, x1: i32, y1: i32, r: i32) {
    let dx = (x1 - x0).abs();
    let dy = -(y1 - y0).abs();
    let sx = if x0 < x1 { 1 } else { -1 };
    let sy = if y0 < y1 { 1 } else { -1 };
    let mut err = dx + dy;
    let (mut x, mut y) = (x0, y0);
    loop {
        stamp_disc(bitmap, x, y, r);
        if x == x1 && y == y1 {
            break;
        }
        let e2 = 2 * err;
        if e2 >= dy {
            err += dy;
            x += sx;
        }
        if e2 <= dx {
            err += dx;
            y += sy;
        }
    }
}

/// Deterministically render sample `index` of digit `class` under `seed`.
///
/// Returns the finished [`Image`] plus the [`GenParams`] that were drawn
/// (useful for diagnostics and tests).
pub fn render_digit(seed: u32, class: u8, index: u32) -> (Image, GenParams) {
    assert!(class <= 9, "digit class out of range");
    let mut rng = derive_stream(seed, u32::from(class), index);

    // -- step 1: parameter draws (ORDER IS CONTRACT) ------------------------
    let params = GenParams {
        dx: rng.range_i32(-14, 14),
        dy: rng.range_i32(-14, 14),
        angle_deg: rng.range_i32(-12, 12),
        scale_q8: rng.range_i32(210, 290),
        thickness: rng.range_i32(8, 12),
        peak: rng.range_i32(170, 255),
    };
    let (sinv, cosv) = (sin_q10(params.angle_deg), cos_q10(params.angle_deg));

    // -- steps 2+3: transform and rasterize each stroke ---------------------
    let mut bitmap = vec![0u8; HI * HI];
    for stroke in TEMPLATES[class as usize] {
        // Transform every point (drawing jitter per point, in order).
        let mut pts_hi: Vec<(i32, i32)> = Vec::with_capacity(stroke.len());
        for &(tx, ty) in stroke.iter() {
            let jx = rng.range_i32(-5, 5);
            let jy = rng.range_i32(-5, 5);
            let px = tx + jx - 128;
            let py = ty + jy - 128;
            let rx = (px * cosv - py * sinv) >> 10;
            let ry = (px * sinv + py * cosv) >> 10;
            let sx = (rx * params.scale_q8) >> 8;
            let sy = (ry * params.scale_q8) >> 8;
            let vx = sx + 128 + params.dx;
            let vy = sy + 128 + params.dy;
            pts_hi.push((virt_to_hi(vx), virt_to_hi(vy)));
        }
        for w in pts_hi.windows(2) {
            let ((x0, y0), (x1, y1)) = (w[0], w[1]);
            stamp_segment(&mut bitmap, x0, y0, x1, y1, params.thickness);
        }
    }

    // -- step 4: 4×4 box downsample, scale by peak --------------------------
    let mut pixels = vec![0u8; IMG_PIXELS];
    for r in 0..IMG_SIDE {
        for c in 0..IMG_SIDE {
            let mut count = 0i32;
            for sr in 0..4 {
                for sc in 0..4 {
                    count += i32::from(bitmap[(r * 4 + sr) * HI + (c * 4 + sc)]);
                }
            }
            pixels[r * IMG_SIDE + c] = ((count * params.peak) / 16) as u8;
        }
    }

    (Image { label: class, pixels }, params)
}

/// Convenience builder for full datasets.
#[derive(Debug, Clone, Copy)]
pub struct DigitGen {
    /// Base seed; the canonical artifacts use 1 (train) and 2 (test).
    pub seed: u32,
}

impl DigitGen {
    pub fn new(seed: u32) -> Self {
        DigitGen { seed }
    }

    /// Render one sample.
    pub fn sample(&self, class: u8, index: u32) -> Image {
        render_digit(self.seed, class, index).0
    }

    /// Build a balanced dataset with `per_class` samples of every digit,
    /// interleaved by class (sample i of class c sits at `i * 10 + c`) so
    /// any prefix of the dataset is still balanced.
    pub fn dataset(&self, per_class: u32) -> Dataset {
        let mut images = Vec::with_capacity(per_class as usize * 10);
        for index in 0..per_class {
            for class in 0u8..10 {
                images.push(self.sample(class, index));
            }
        }
        Dataset { images }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::PropRunner;

    /// Cross-language golden: these FNV-1a hashes are independently
    /// asserted by `python/tests/test_dataset.py` against the Python
    /// mirror — together they pin the bit-exact dataset contract.
    #[test]
    fn cross_language_golden_hashes() {
        let fnv = |data: &[u8]| {
            data.iter()
                .fold(0x811C_9DC5u32, |h, &b| (h ^ u32::from(b)).wrapping_mul(0x0100_0193))
        };
        let (a, _) = render_digit(1, 3, 7);
        assert_eq!(fnv(&a.pixels), 0x03d4_95a4);
        let (b, _) = render_digit(2, 8, 0);
        assert_eq!(fnv(&b.pixels), 0x74ac_a3a0);
    }

    #[test]
    fn deterministic() {
        let (a, pa) = render_digit(1, 3, 7);
        let (b, pb) = render_digit(1, 3, 7);
        assert_eq!(a.pixels, b.pixels);
        assert_eq!(pa, pb);
    }

    #[test]
    fn distinct_across_seed_class_index() {
        let (a, _) = render_digit(1, 3, 7);
        let (b, _) = render_digit(2, 3, 7);
        let (c, _) = render_digit(1, 4, 7);
        let (d, _) = render_digit(1, 3, 8);
        assert_ne!(a.pixels, b.pixels);
        assert_ne!(a.pixels, c.pixels);
        assert_ne!(a.pixels, d.pixels);
    }

    #[test]
    fn images_have_ink_and_background() {
        // Every rendered digit must have a plausible amount of ink: not
        // blank, not solid.
        PropRunner::new("digit_ink", 100).run(|g| {
            let seed = g.rng.next_u32();
            let class = (g.rng.below(10)) as u8;
            let index = g.rng.below(1000);
            let (img, params) = render_digit(seed, class, index);
            let ink: usize = img.pixels.iter().filter(|&&p| p > 0).count();
            assert!(
                (40..600).contains(&ink),
                "digit {class} (seed {seed} idx {index}, {params:?}) has {ink} inked pixels"
            );
            let max = img.pixels.iter().copied().max().unwrap();
            assert_eq!(
                i32::from(max),
                params.peak,
                "peak intensity must be reached by fully-covered pixels"
            );
        });
    }

    #[test]
    fn params_within_documented_ranges() {
        PropRunner::new("digit_params", 200).run(|g| {
            let (_, p) = render_digit(g.rng.next_u32(), (g.rng.below(10)) as u8, g.rng.below(100));
            assert!((-14..=14).contains(&p.dx));
            assert!((-14..=14).contains(&p.dy));
            assert!((-12..=12).contains(&p.angle_deg));
            assert!((210..=290).contains(&p.scale_q8));
            assert!((8..=12).contains(&p.thickness));
            assert!((170..=255).contains(&p.peak));
        });
    }

    #[test]
    fn dataset_balanced_and_interleaved() {
        let ds = DigitGen::new(1).dataset(12);
        assert_eq!(ds.len(), 120);
        assert_eq!(ds.class_histogram(), [12; 10]);
        // Interleaving: position i*10+c holds class c.
        for (pos, img) in ds.images.iter().enumerate() {
            assert_eq!(img.label as usize, pos % 10);
        }
        // Any prefix that is a multiple of 10 is balanced.
        let h: [usize; 10] = {
            let mut h = [0; 10];
            for img in &ds.images[..50] {
                h[img.label as usize] += 1;
            }
            h
        };
        assert_eq!(h, [5; 10]);
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Mean inter-class L1 distance should comfortably exceed mean
        // intra-class distance — a cheap proxy for separability.
        let gen = DigitGen::new(3);
        let l1 = |a: &Image, b: &Image| -> f64 {
            a.pixels
                .iter()
                .zip(&b.pixels)
                .map(|(&x, &y)| (f64::from(x) - f64::from(y)).abs())
                .sum::<f64>()
        };
        let mut intra = 0.0;
        let mut inter = 0.0;
        let mut n_intra = 0.0;
        let mut n_inter = 0.0;
        let samples: Vec<Vec<Image>> =
            (0u8..10).map(|c| (0..4).map(|i| gen.sample(c, i)).collect()).collect();
        for c in 0..10 {
            for i in 0..4 {
                for j in (i + 1)..4 {
                    intra += l1(&samples[c][i], &samples[c][j]);
                    n_intra += 1.0;
                }
                for c2 in (c + 1)..10 {
                    inter += l1(&samples[c][i], &samples[c2][i]);
                    n_inter += 1.0;
                }
            }
        }
        let (intra, inter) = (intra / n_intra, inter / n_inter);
        assert!(
            inter > intra * 1.2,
            "classes not separable: intra {intra:.0} vs inter {inter:.0}"
        );
    }
}
