//! Loader for the standard MNIST IDX file format.
//!
//! The canonical artifacts in this repository use the synthetic digit set
//! (no network access at build time — see DESIGN.md §2), but users who have
//! the real `train-images-idx3-ubyte` / `train-labels-idx1-ubyte` files can
//! point any experiment at them with `--mnist-dir`; everything downstream
//! is dataset-agnostic.

use std::fs;
use std::path::Path;

use super::{Dataset, Image, IMG_PIXELS, IMG_SIDE};
use crate::error::{Error, Result};

/// Load a `(images, labels)` IDX pair into a [`Dataset`].
pub fn load_idx_pair(images_path: impl AsRef<Path>, labels_path: impl AsRef<Path>) -> Result<Dataset> {
    let images_path = images_path.as_ref();
    let labels_path = labels_path.as_ref();
    let raw_imgs = fs::read(images_path).map_err(|e| Error::io(images_path, e))?;
    let raw_lbls = fs::read(labels_path).map_err(|e| Error::io(labels_path, e))?;

    let (n_imgs, pixels) = parse_idx3(&raw_imgs, images_path)?;
    let labels = parse_idx1(&raw_lbls, labels_path)?;
    if n_imgs != labels.len() {
        return Err(Error::ShapeMismatch(format!(
            "{n_imgs} images but {} labels",
            labels.len()
        )));
    }
    let mut images = Vec::with_capacity(n_imgs);
    for (i, &label) in labels.iter().enumerate() {
        if label > 9 {
            return Err(Error::malformed(labels_path, format!("label {label} > 9 at {i}")));
        }
        images.push(Image {
            label,
            pixels: pixels[i * IMG_PIXELS..(i + 1) * IMG_PIXELS].to_vec(),
        });
    }
    Ok(Dataset { images })
}

/// Load the conventional test pair from a directory
/// (`t10k-images-idx3-ubyte`, `t10k-labels-idx1-ubyte`).
pub fn load_test_set(dir: impl AsRef<Path>) -> Result<Dataset> {
    let dir = dir.as_ref();
    load_idx_pair(dir.join("t10k-images-idx3-ubyte"), dir.join("t10k-labels-idx1-ubyte"))
}

fn be_u32(buf: &[u8], at: usize, path: &Path) -> Result<u32> {
    buf.get(at..at + 4)
        .map(|s| u32::from_be_bytes(s.try_into().unwrap()))
        .ok_or_else(|| Error::malformed(path, format!("truncated header at {at}")))
}

/// Parse an idx3-ubyte image file; returns (count, flattened pixels).
fn parse_idx3<'a>(buf: &'a [u8], path: &Path) -> Result<(usize, &'a [u8])> {
    let magic = be_u32(buf, 0, path)?;
    if magic != 0x0000_0803 {
        return Err(Error::malformed(path, format!("bad idx3 magic {magic:#010x}")));
    }
    let n = be_u32(buf, 4, path)? as usize;
    let h = be_u32(buf, 8, path)? as usize;
    let w = be_u32(buf, 12, path)? as usize;
    if h != IMG_SIDE || w != IMG_SIDE {
        return Err(Error::malformed(path, format!("unsupported geometry {h}x{w}")));
    }
    let body = &buf[16..];
    if body.len() != n * IMG_PIXELS {
        return Err(Error::malformed(
            path,
            format!("payload {} != {} x {IMG_PIXELS}", body.len(), n),
        ));
    }
    Ok((n, body))
}

/// Parse an idx1-ubyte label file.
fn parse_idx1<'a>(buf: &'a [u8], path: &Path) -> Result<&'a [u8]> {
    let magic = be_u32(buf, 0, path)?;
    if magic != 0x0000_0801 {
        return Err(Error::malformed(path, format!("bad idx1 magic {magic:#010x}")));
    }
    let n = be_u32(buf, 4, path)? as usize;
    let body = &buf[8..];
    if body.len() != n {
        return Err(Error::malformed(path, format!("payload {} != {n}", body.len())));
    }
    Ok(body)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_idx_pair(dir: &Path, n: usize) -> (std::path::PathBuf, std::path::PathBuf) {
        let mut imgs = Vec::new();
        imgs.extend_from_slice(&0x0803u32.to_be_bytes());
        imgs.extend_from_slice(&(n as u32).to_be_bytes());
        imgs.extend_from_slice(&(IMG_SIDE as u32).to_be_bytes());
        imgs.extend_from_slice(&(IMG_SIDE as u32).to_be_bytes());
        for i in 0..n {
            imgs.extend(std::iter::repeat(i as u8).take(IMG_PIXELS));
        }
        let mut lbls = Vec::new();
        lbls.extend_from_slice(&0x0801u32.to_be_bytes());
        lbls.extend_from_slice(&(n as u32).to_be_bytes());
        lbls.extend((0..n).map(|i| (i % 10) as u8));
        let pi = dir.join("imgs.idx3");
        let pl = dir.join("lbls.idx1");
        fs::write(&pi, &imgs).unwrap();
        fs::write(&pl, &lbls).unwrap();
        (pi, pl)
    }

    #[test]
    fn loads_valid_pair() {
        let dir = std::env::temp_dir().join(format!("snn_idx_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let (pi, pl) = write_idx_pair(&dir, 12);
        let ds = load_idx_pair(&pi, &pl).unwrap();
        assert_eq!(ds.len(), 12);
        assert_eq!(ds.images[3].label, 3);
        assert!(ds.images[3].pixels.iter().all(|&p| p == 3));
    }

    #[test]
    fn rejects_bad_magic_and_mismatch() {
        let dir = std::env::temp_dir().join(format!("snn_idx_bad_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let (pi, pl) = write_idx_pair(&dir, 4);

        let mut bad = fs::read(&pi).unwrap();
        bad[3] = 0x99;
        let pbad = dir.join("bad.idx3");
        fs::write(&pbad, &bad).unwrap();
        assert!(load_idx_pair(&pbad, &pl).is_err());

        // Count mismatch between images and labels.
        let (pi8, _) = {
            let d2 = dir.join("d2");
            fs::create_dir_all(&d2).unwrap();
            write_idx_pair(&d2, 8)
        };
        assert!(load_idx_pair(&pi8, &pl).is_err());
    }
}
