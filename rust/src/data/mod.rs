//! Datasets and image substrates.
//!
//! The paper evaluates on MNIST; this environment has no network access, so
//! the primary dataset is a **synthetic stroke-rendered digit set** that is
//! *bit-identical* between this module and `python/compile/dataset.py`
//! (integer-only rendering driven by the shared xorshift32 contract — see
//! DESIGN.md §2 for why this substitution preserves the paper's code path).
//! A standard MNIST IDX loader is also provided for users who have the real
//! files on disk.

pub mod codec;
pub mod digitgen;
pub mod mnist_idx;
pub mod perturb;
mod templates;

pub use codec::{load_dataset, load_weights, save_dataset, save_weights, WeightArtifact};
pub use digitgen::{render_digit, DigitGen, GenParams};
pub use templates::TEMPLATES;

/// Image side length (28 × 28, as in MNIST).
pub const IMG_SIDE: usize = 28;
/// Pixels per image.
pub const IMG_PIXELS: usize = IMG_SIDE * IMG_SIDE;

/// A labelled 28×28 8-bit grayscale image.
#[derive(Clone, PartialEq, Eq)]
pub struct Image {
    /// Ground-truth class, `0..=9`.
    pub label: u8,
    /// Row-major intensities, `0..=255`.
    pub pixels: Vec<u8>,
}

impl Image {
    /// Construct, checking geometry.
    pub fn new(label: u8, pixels: Vec<u8>) -> crate::Result<Self> {
        if pixels.len() != IMG_PIXELS {
            return Err(crate::Error::ShapeMismatch(format!(
                "image has {} pixels, expected {IMG_PIXELS}",
                pixels.len()
            )));
        }
        if label > 9 {
            return Err(crate::Error::InvalidConfig(format!("label {label} > 9")));
        }
        Ok(Image { label, pixels })
    }

    /// Pixel at (row, col).
    #[inline]
    pub fn at(&self, row: usize, col: usize) -> u8 {
        self.pixels[row * IMG_SIDE + col]
    }

    /// Mean intensity (diagnostics).
    pub fn mean_intensity(&self) -> f64 {
        self.pixels.iter().map(|&p| f64::from(p)).sum::<f64>() / IMG_PIXELS as f64
    }

    /// Render as ASCII art (examples / debugging).
    pub fn to_ascii(&self) -> String {
        let ramp = b" .:-=+*#%@";
        let mut s = String::with_capacity((IMG_SIDE + 1) * IMG_SIDE);
        for r in 0..IMG_SIDE {
            for c in 0..IMG_SIDE {
                let v = self.at(r, c) as usize * (ramp.len() - 1) / 255;
                s.push(ramp[v] as char);
            }
            s.push('\n');
        }
        s
    }
}

impl std::fmt::Debug for Image {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Image(label={}, mean={:.1})", self.label, self.mean_intensity())
    }
}

/// An in-memory labelled dataset.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub images: Vec<Image>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.images.len()
    }
    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// Count of samples per class.
    pub fn class_histogram(&self) -> [usize; 10] {
        let mut h = [0usize; 10];
        for img in &self.images {
            h[img.label as usize] += 1;
        }
        h
    }

    /// Borrow all samples of one class.
    pub fn of_class(&self, class: u8) -> impl Iterator<Item = &Image> {
        self.images.iter().filter(move |i| i.label == class)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_validation() {
        assert!(Image::new(0, vec![0; IMG_PIXELS]).is_ok());
        assert!(Image::new(0, vec![0; 100]).is_err());
        assert!(Image::new(10, vec![0; IMG_PIXELS]).is_err());
    }

    #[test]
    fn ascii_render_shape() {
        let img = Image::new(3, vec![128; IMG_PIXELS]).unwrap();
        let art = img.to_ascii();
        assert_eq!(art.lines().count(), IMG_SIDE);
        assert!(art.lines().all(|l| l.chars().count() == IMG_SIDE));
    }

    #[test]
    fn histogram_counts() {
        let mut d = Dataset::default();
        for label in [1u8, 1, 3, 9] {
            d.images.push(Image::new(label, vec![0; IMG_PIXELS]).unwrap());
        }
        let h = d.class_histogram();
        assert_eq!(h[1], 2);
        assert_eq!(h[3], 1);
        assert_eq!(h[9], 1);
        assert_eq!(d.of_class(1).count(), 2);
    }
}
