//! Image perturbations for the robustness study (paper Fig. 8).
//!
//! Four perturbations — rotation, pixel shift, Gaussian noise, occlusion —
//! all implemented in integer arithmetic over the shared xorshift32 streams
//! so that the Rust and Python harnesses evaluate the *same* perturbed
//! pixels (contract mirrored in `python/compile/dataset.py`).
//!
//! Per-sample randomness is drawn from `derive_stream(seed, kind as u32,
//! sample_index)`; the draw order within each perturbation is documented on
//! the function and is part of the contract.

use super::{Image, IMG_PIXELS, IMG_SIDE};
use crate::prng::{derive_stream, Xorshift32};

/// The perturbation kinds of Fig. 8, with their paper parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Perturbation {
    /// No perturbation (baseline bar of Fig. 8).
    Clean,
    /// Rotation by ±deg (paper: 15°). Sign drawn per sample.
    Rotate { deg: i32 },
    /// Translation by `round(fraction·28)` pixels in a random direction
    /// (paper: 20 % → 6 px).
    Shift { percent: u32 },
    /// Additive integer-Gaussian noise; `scale_q8` is the Q8 noise gain
    /// (effective σ ≈ 0.289 · scale_q8 intensity levels).
    Noise { scale_q8: i32 },
    /// A `side × side` black square at a random position (paper: partial
    /// occlusion; we use 10 px ≈ 36 % of the width).
    Occlude { side: usize },
}

impl Perturbation {
    /// Stable numeric id used for PRNG domain separation and CSV output.
    pub fn kind_id(&self) -> u32 {
        match self {
            Perturbation::Clean => 0,
            Perturbation::Rotate { .. } => 1,
            Perturbation::Shift { .. } => 2,
            Perturbation::Noise { .. } => 3,
            Perturbation::Occlude { .. } => 4,
        }
    }

    /// Human-readable label matching the Fig. 8 x-axis.
    pub fn label(&self) -> String {
        match self {
            Perturbation::Clean => "clean".into(),
            Perturbation::Rotate { deg } => format!("rotation {deg}deg"),
            Perturbation::Shift { percent } => format!("pixel shift {percent}%"),
            Perturbation::Noise { scale_q8 } => format!("gaussian noise s{scale_q8}"),
            Perturbation::Occlude { side } => format!("occlusion {side}px"),
        }
    }

    /// The paper's Fig. 8 suite.
    pub fn paper_suite() -> Vec<Perturbation> {
        vec![
            Perturbation::Clean,
            Perturbation::Rotate { deg: 15 },
            Perturbation::Shift { percent: 20 },
            Perturbation::Noise { scale_q8: 138 }, // σ ≈ 40 intensity levels
            Perturbation::Occlude { side: 10 },
        ]
    }

    /// Apply to `img` as sample `index` under `seed`.
    pub fn apply(&self, img: &Image, seed: u32, index: u32) -> Image {
        let mut rng = derive_stream(seed, self.kind_id(), index);
        match *self {
            Perturbation::Clean => img.clone(),
            Perturbation::Rotate { deg } => {
                // Draw order: sign.
                let sign = if rng.next_u32() & 1 == 0 { 1 } else { -1 };
                rotate(img, sign * deg)
            }
            Perturbation::Shift { percent } => {
                // Draw order: direction index (8 compass directions).
                let mag = ((percent as i32) * (IMG_SIDE as i32) + 50) / 100;
                let dir = rng.below(8) as usize;
                const DIRS: [(i32, i32); 8] =
                    [(1, 0), (1, 1), (0, 1), (-1, 1), (-1, 0), (-1, -1), (0, -1), (1, -1)];
                let (sx, sy) = DIRS[dir];
                shift(img, sx * mag, sy * mag)
            }
            Perturbation::Noise { scale_q8 } => noise(img, scale_q8, &mut rng),
            Perturbation::Occlude { side } => {
                // Draw order: row origin, then column origin.
                let r0 = rng.below((IMG_SIDE - side + 1) as u32) as usize;
                let c0 = rng.below((IMG_SIDE - side + 1) as u32) as usize;
                occlude(img, r0, c0, side)
            }
        }
    }
}

/// sin(d°) in Q10 for d = 0..=15 (shared with digitgen).
const SIN_Q10: [i32; 16] =
    [0, 18, 36, 54, 71, 89, 107, 125, 143, 160, 178, 195, 213, 230, 248, 265];
const COS_Q10: [i32; 16] =
    [1024, 1024, 1023, 1023, 1022, 1020, 1018, 1016, 1014, 1011, 1008, 1005, 1002, 998, 994, 989];

/// Rotate by `deg ∈ [-15, 15]` about the image centre with inverse-mapped
/// nearest-neighbour sampling, all in integer arithmetic.
///
/// Coordinates are handled in doubled units so the centre (13.5, 13.5)
/// is the integer 27; the final `>> 11` divides by 1024 (Q10 trig) and by
/// the doubling in one arithmetic shift.
pub fn rotate(img: &Image, deg: i32) -> Image {
    assert!((-15..=15).contains(&deg));
    let a = deg.unsigned_abs() as usize;
    let (sinv, cosv) = (if deg < 0 { -SIN_Q10[a] } else { SIN_Q10[a] }, COS_Q10[a]);
    let mut out = vec![0u8; IMG_PIXELS];
    for r in 0..IMG_SIDE as i32 {
        for c in 0..IMG_SIDE as i32 {
            let xr = c * 2 - 27; // doubled units, centred
            let yr = r * 2 - 27;
            // Inverse rotation (rotate sample grid by -deg).
            let sx = xr * cosv + yr * sinv;
            let sy = -xr * sinv + yr * cosv;
            let sc = (sx + 27 * 1024 + 1024) >> 11;
            let sr = (sy + 27 * 1024 + 1024) >> 11;
            if (0..IMG_SIDE as i32).contains(&sc) && (0..IMG_SIDE as i32).contains(&sr) {
                out[(r as usize) * IMG_SIDE + c as usize] =
                    img.pixels[(sr as usize) * IMG_SIDE + sc as usize];
            }
        }
    }
    Image { label: img.label, pixels: out }
}

/// Translate by `(dx, dy)` pixels (x = columns, y = rows), zero-filling.
pub fn shift(img: &Image, dx: i32, dy: i32) -> Image {
    let mut out = vec![0u8; IMG_PIXELS];
    for r in 0..IMG_SIDE as i32 {
        for c in 0..IMG_SIDE as i32 {
            let (sr, sc) = (r - dy, c - dx);
            if (0..IMG_SIDE as i32).contains(&sr) && (0..IMG_SIDE as i32).contains(&sc) {
                out[(r as usize) * IMG_SIDE + c as usize] =
                    img.pixels[(sr as usize) * IMG_SIDE + sc as usize];
            }
        }
    }
    Image { label: img.label, pixels: out }
}

/// Additive central-limit "Gaussian" noise: per pixel (row-major order),
/// draw four PRNG words, sum their low bytes, centre at 510 and scale by
/// `scale_q8 / 512`. Clamps to `0..=255`.
pub fn noise(img: &Image, scale_q8: i32, rng: &mut Xorshift32) -> Image {
    let mut out = vec![0u8; IMG_PIXELS];
    for (i, &p) in img.pixels.iter().enumerate() {
        let mut sum = 0i32;
        for _ in 0..4 {
            sum += (rng.next_u32() & 0xFF) as i32;
        }
        let delta = ((sum - 510) * scale_q8) >> 9;
        out[i] = (i32::from(p) + delta).clamp(0, 255) as u8;
    }
    Image { label: img.label, pixels: out }
}

/// Zero a `side × side` square whose top-left corner is `(r0, c0)`.
pub fn occlude(img: &Image, r0: usize, c0: usize, side: usize) -> Image {
    assert!(r0 + side <= IMG_SIDE && c0 + side <= IMG_SIDE);
    let mut out = img.pixels.clone();
    for r in r0..r0 + side {
        out[r * IMG_SIDE + c0..r * IMG_SIDE + c0 + side].fill(0);
    }
    Image { label: img.label, pixels: out }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::digitgen::render_digit;
    use crate::testutil::PropRunner;

    fn probe() -> Image {
        render_digit(1, 5, 0).0
    }

    #[test]
    fn rotate_zero_is_identity() {
        let img = probe();
        assert_eq!(rotate(&img, 0).pixels, img.pixels);
    }

    #[test]
    fn rotate_preserves_mass_roughly() {
        let img = probe();
        let rot = rotate(&img, 15);
        let m0 = img.mean_intensity();
        let m1 = rot.mean_intensity();
        assert!((m0 - m1).abs() / m0 < 0.15, "rotation lost too much ink: {m0} -> {m1}");
    }

    #[test]
    fn rotate_pm_are_different() {
        let img = probe();
        assert_ne!(rotate(&img, 15).pixels, rotate(&img, -15).pixels);
    }

    #[test]
    fn shift_moves_pixels_exactly() {
        let img = probe();
        let s = shift(&img, 3, -2);
        for r in 0..IMG_SIDE {
            for c in 0..IMG_SIDE {
                let sr = r as i32 + 2; // inverse of dy=-2
                let sc = c as i32 - 3;
                let expect = if (0..IMG_SIDE as i32).contains(&sr)
                    && (0..IMG_SIDE as i32).contains(&sc)
                {
                    img.pixels[sr as usize * IMG_SIDE + sc as usize]
                } else {
                    0
                };
                assert_eq!(s.pixels[r * IMG_SIDE + c], expect);
            }
        }
    }

    #[test]
    fn noise_statistics() {
        let img = Image { label: 0, pixels: vec![128; IMG_PIXELS] };
        let mut rng = Xorshift32::new(1);
        let n = noise(&img, 138, &mut rng);
        let mean = n.mean_intensity();
        assert!((mean - 128.0).abs() < 6.0, "noise is biased: mean {mean}");
        let var = n
            .pixels
            .iter()
            .map(|&p| {
                let d = f64::from(p) - mean;
                d * d
            })
            .sum::<f64>()
            / IMG_PIXELS as f64;
        let sd = var.sqrt();
        // Effective σ ≈ 0.289 * 138 ≈ 39.9 levels.
        assert!((sd - 39.9).abs() < 6.0, "noise σ {sd} far from 39.9");
    }

    #[test]
    fn occlude_zeroes_exact_block() {
        let img = probe();
        let o = occlude(&img, 5, 7, 10);
        for r in 0..IMG_SIDE {
            for c in 0..IMG_SIDE {
                let inside = (5..15).contains(&r) && (7..17).contains(&c);
                if inside {
                    assert_eq!(o.pixels[r * IMG_SIDE + c], 0);
                } else {
                    assert_eq!(o.pixels[r * IMG_SIDE + c], img.pixels[r * IMG_SIDE + c]);
                }
            }
        }
    }

    #[test]
    fn apply_is_deterministic_per_index() {
        let img = probe();
        for p in Perturbation::paper_suite() {
            let a = p.apply(&img, 42, 3);
            let b = p.apply(&img, 42, 3);
            assert_eq!(a.pixels, b.pixels, "{} not deterministic", p.label());
            if p != Perturbation::Clean {
                let c = p.apply(&img, 42, 4);
                // Different sample index must draw different randomness
                // (rotation only has two outcomes, so allow equality there).
                if !matches!(p, Perturbation::Rotate { .. }) {
                    assert_ne!(c.pixels, a.pixels, "{} ignored index", p.label());
                }
            }
        }
    }

    #[test]
    fn prop_all_perturbations_keep_label_and_range() {
        PropRunner::new("perturb_label_range", 100).run(|g| {
            let class = g.rng.below(10) as u8;
            let img = render_digit(7, class, g.rng.below(50)).0;
            let suite = Perturbation::paper_suite();
            let p = g.choice(&suite);
            let out = p.apply(&img, g.rng.next_u32(), g.rng.below(1000));
            assert_eq!(out.label, class);
            assert_eq!(out.pixels.len(), IMG_PIXELS);
        });
    }
}
