//! Crate-wide error type.
//!
//! The library uses a structured [`Error`] with hand-written `Display` /
//! `std::error::Error` impls (`thiserror` is not in the offline crate set);
//! binaries and examples bubble it up through `Box<dyn std::error::Error>`.

use std::fmt;
use std::path::PathBuf;

/// Convenience alias used across the crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Errors produced by the snn-rtl library.
#[derive(Debug)]
pub enum Error {
    /// An I/O failure, annotated with the path that was being accessed.
    Io { path: PathBuf, source: std::io::Error },

    /// A binary artifact had the wrong magic number / version / geometry.
    MalformedArtifact { path: PathBuf, reason: String },

    /// A configuration value was out of range or inconsistent.
    InvalidConfig(String),

    /// A runtime (PJRT / XLA) failure.
    Xla(String),

    /// The coordinator rejected a request (queue full, shut down, ...).
    Rejected(String),

    /// A worker or channel disappeared mid-flight.
    Coordinator(String),

    /// Dimension mismatch between tensors / images / weight matrices.
    ShapeMismatch(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io { path, source } => {
                write!(f, "i/o error on {}: {source}", path.display())
            }
            Error::MalformedArtifact { path, reason } => {
                write!(f, "malformed artifact {}: {reason}", path.display())
            }
            Error::InvalidConfig(msg) => write!(f, "invalid configuration: {msg}"),
            Error::Xla(msg) => write!(f, "xla runtime error: {msg}"),
            Error::Rejected(msg) => write!(f, "request rejected: {msg}"),
            Error::Coordinator(msg) => write!(f, "coordinator internal failure: {msg}"),
            Error::ShapeMismatch(msg) => write!(f, "shape mismatch: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    /// Wrap an `std::io::Error` with the offending path.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }

    /// Construct a malformed-artifact error.
    pub fn malformed(path: impl Into<PathBuf>, reason: impl Into<String>) -> Self {
        Error::MalformedArtifact { path: path.into(), reason: reason.into() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::io("weights.bin", std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let msg = e.to_string();
        assert!(msg.contains("weights.bin"), "{msg}");
        assert!(std::error::Error::source(&e).is_some());

        let e = Error::malformed("m.txt", "bad magic");
        assert!(e.to_string().contains("bad magic"));
        assert!(std::error::Error::source(&e).is_none());
    }
}
