//! Crate-wide error type.
//!
//! The library uses a structured [`Error`] with hand-written `Display` /
//! `std::error::Error` impls (`thiserror` is not in the offline crate set);
//! binaries and examples bubble it up through `Box<dyn std::error::Error>`.
//!
//! The serving coordinator relies on the *typed* variants as its terminal
//! reply vocabulary: every submitted request resolves to `Ok(Response)` or
//! exactly one of [`Error::Overloaded`], [`Error::Shed`],
//! [`Error::BackendPanicked`], [`Error::ShuttingDown`], or a backend error
//! ([`Error::Xla`] / [`Error::ShapeMismatch`] / [`Error::Coordinator`]).

use std::fmt;
use std::path::PathBuf;

/// Convenience alias used across the crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Errors produced by the snn-rtl library.
#[derive(Debug)]
pub enum Error {
    /// An I/O failure, annotated with the path that was being accessed.
    Io { path: PathBuf, source: std::io::Error },

    /// A binary artifact had the wrong magic number / version / geometry.
    MalformedArtifact { path: PathBuf, reason: String },

    /// A configuration value was out of range or inconsistent.
    InvalidConfig(String),

    /// A runtime (PJRT / XLA) failure.
    Xla(String),

    /// Admission control refused the request: every ingress shard was at
    /// capacity. The caller should back off and retry.
    Overloaded(String),

    /// The request's deadline expired before the backend ran it, so the
    /// coordinator dropped it instead of doing work nobody is waiting for.
    Shed(String),

    /// The backend panicked while executing the batch containing this
    /// request. The engine involved has been quarantined and the worker
    /// replaced; retrying with the same seed is deterministic and safe.
    BackendPanicked(String),

    /// The coordinator is shutting down (or has stopped) and will not run
    /// this request.
    ShuttingDown(String),

    /// A blocking wait on a reply gave up after its timeout.
    Timeout(String),

    /// A worker or channel disappeared mid-flight, or a backend broke the
    /// batch contract (e.g. a wrong-length reply).
    Coordinator(String),

    /// Dimension mismatch between tensors / images / weight matrices.
    ShapeMismatch(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io { path, source } => {
                write!(f, "i/o error on {}: {source}", path.display())
            }
            Error::MalformedArtifact { path, reason } => {
                write!(f, "malformed artifact {}: {reason}", path.display())
            }
            Error::InvalidConfig(msg) => write!(f, "invalid configuration: {msg}"),
            Error::Xla(msg) => write!(f, "xla runtime error: {msg}"),
            Error::Overloaded(msg) => write!(f, "overloaded: {msg}"),
            Error::Shed(msg) => write!(f, "request shed: {msg}"),
            Error::BackendPanicked(msg) => write!(f, "backend panicked: {msg}"),
            Error::ShuttingDown(msg) => write!(f, "shutting down: {msg}"),
            Error::Timeout(msg) => write!(f, "timed out: {msg}"),
            Error::Coordinator(msg) => write!(f, "coordinator internal failure: {msg}"),
            Error::ShapeMismatch(msg) => write!(f, "shape mismatch: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    /// Wrap an `std::io::Error` with the offending path.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }

    /// Construct a malformed-artifact error.
    pub fn malformed(path: impl Into<PathBuf>, reason: impl Into<String>) -> Self {
        Error::MalformedArtifact { path: path.into(), reason: reason.into() }
    }

    /// Clone-like duplication for fanning one failure out to every request
    /// in a batch. `std::io::Error` is not `Clone`, so [`Error::Io`]
    /// degrades to [`Error::Coordinator`] carrying the rendered message;
    /// every other variant replicates structurally.
    pub fn replicate(&self) -> Error {
        match self {
            Error::Io { .. } => Error::Coordinator(self.to_string()),
            Error::MalformedArtifact { path, reason } => {
                Error::MalformedArtifact { path: path.clone(), reason: reason.clone() }
            }
            Error::InvalidConfig(m) => Error::InvalidConfig(m.clone()),
            Error::Xla(m) => Error::Xla(m.clone()),
            Error::Overloaded(m) => Error::Overloaded(m.clone()),
            Error::Shed(m) => Error::Shed(m.clone()),
            Error::BackendPanicked(m) => Error::BackendPanicked(m.clone()),
            Error::ShuttingDown(m) => Error::ShuttingDown(m.clone()),
            Error::Timeout(m) => Error::Timeout(m.clone()),
            Error::Coordinator(m) => Error::Coordinator(m.clone()),
            Error::ShapeMismatch(m) => Error::ShapeMismatch(m.clone()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::io("weights.bin", std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let msg = e.to_string();
        assert!(msg.contains("weights.bin"), "{msg}");
        assert!(std::error::Error::source(&e).is_some());

        let e = Error::malformed("m.txt", "bad magic");
        assert!(e.to_string().contains("bad magic"));
        assert!(std::error::Error::source(&e).is_none());
    }

    #[test]
    fn typed_serving_errors_render_their_class() {
        assert!(Error::Overloaded("all shards full".into()).to_string().starts_with("overloaded"));
        assert!(Error::Shed("expired".into()).to_string().contains("shed"));
        assert!(Error::BackendPanicked("boom".into()).to_string().contains("panicked"));
        assert!(Error::ShuttingDown("stop".into()).to_string().contains("shutting down"));
        assert!(Error::Timeout("5ms".into()).to_string().contains("timed out"));
    }

    #[test]
    fn replicate_preserves_variant_except_io() {
        let e = Error::BackendPanicked("boom".into());
        assert!(matches!(e.replicate(), Error::BackendPanicked(m) if m == "boom"));

        let e = Error::ShapeMismatch("784 vs 10".into());
        assert!(matches!(e.replicate(), Error::ShapeMismatch(_)));

        let io = Error::io("x", std::io::Error::other("disk"));
        let r = io.replicate();
        assert!(matches!(&r, Error::Coordinator(m) if m.contains("disk")));
    }
}
