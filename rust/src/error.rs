//! Crate-wide error type.
//!
//! The library uses a structured [`Error`] (via `thiserror`); binaries and
//! examples wrap it in `anyhow` for context-rich reporting.

use std::path::PathBuf;

/// Convenience alias used across the crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Errors produced by the snn-rtl library.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// An I/O failure, annotated with the path that was being accessed.
    #[error("i/o error on {path}: {source}")]
    Io {
        path: PathBuf,
        #[source]
        source: std::io::Error,
    },

    /// A binary artifact had the wrong magic number / version / geometry.
    #[error("malformed artifact {path}: {reason}")]
    MalformedArtifact { path: PathBuf, reason: String },

    /// A configuration value was out of range or inconsistent.
    #[error("invalid configuration: {0}")]
    InvalidConfig(String),

    /// A runtime (PJRT / XLA) failure.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// The coordinator rejected a request (queue full, shut down, ...).
    #[error("request rejected: {0}")]
    Rejected(String),

    /// A worker or channel disappeared mid-flight.
    #[error("coordinator internal failure: {0}")]
    Coordinator(String),

    /// Dimension mismatch between tensors / images / weight matrices.
    #[error("shape mismatch: {0}")]
    ShapeMismatch(String),
}

impl Error {
    /// Wrap an `std::io::Error` with the offending path.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }

    /// Construct a malformed-artifact error.
    pub fn malformed(path: impl Into<PathBuf>, reason: impl Into<String>) -> Self {
        Error::MalformedArtifact { path: path.into(), reason: reason.into() }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
