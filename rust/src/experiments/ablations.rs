//! Ablations on the design choices DESIGN.md §6 calls out:
//!
//! * **pruning** — the paper's §III-D mechanism: accuracy + switching
//!   energy across prune-after-K ∈ {1, 3, 5, ∞}. Quantifies both the power
//!   win and the readout damage of the paper's literal gate-after-first-
//!   fire (the repo's headline negative finding — EXPERIMENTS.md).
//! * **decay** — the 2^-n leak exponent / V_th grid.
//! * **modes** — the RTL refinements: fire-mode (EndOfStep vs Immediate)
//!   and leak scheduling (per-timestep vs per-row).

use crate::config::{FireMode, LeakMode, PruneMode};
use crate::rtl::RtlCore;
use crate::snn::BehavioralNet;

use super::{accuracy, Ctx, Result};

/// One prune setting's measured trade-off point.
#[derive(Debug, Clone, Copy)]
pub struct PrunePoint {
    pub accuracy: f64,
    /// Mean dynamic energy per inference, monolithic weight BRAM (nJ).
    pub dyn_nj: f64,
    /// Mean dynamic energy with a per-neuron *banked* BRAM, where a pruned
    /// neuron's weight column is never fetched: the shared-row fetch
    /// (2.5 pJ) is replaced by one column read (2.5/10 pJ) per actual add.
    /// This is the microarchitecture the paper's power claim implicitly
    /// assumes — see EXPERIMENTS.md ablation A.
    pub dyn_banked_nj: f64,
    pub adds_per_inference: f64,
}

/// Accuracy + mean dynamic energy for one prune setting.
pub fn prune_point(ctx: &Ctx, prune: PruneMode) -> Result<PrunePoint> {
    let imgs = ctx.eval_slice();
    let labels: Vec<u8> = imgs.iter().map(|i| i.label).collect();
    let cfg = ctx.cfg.clone().with_prune(prune);

    // Accuracy over the slice (behavioral).
    let net = BehavioralNet::new(cfg.clone(), ctx.weights.weights.clone())?;
    let preds: Vec<u8> = imgs
        .iter()
        .enumerate()
        .map(|(i, img)| net.classify(img, ctx.eval_seed(i)).class)
        .collect();
    let acc = accuracy(&preds, &labels);

    // Energy + adds on a probe subset (RTL).
    let model = crate::rtl::EnergyModel::default();
    let mut core = RtlCore::new(cfg, ctx.weights.weights.clone())?;
    let probe = imgs.len().min(25).max(1);
    let mut nj = 0.0;
    let mut banked_nj = 0.0;
    let mut adds = 0u64;
    for (i, img) in imgs.iter().take(probe).enumerate() {
        let r = core.run(img, ctx.eval_seed(i))?;
        nj += r.energy.dynamic_nj;
        adds += r.activity.adds;
        // Re-account the BRAM under per-neuron banking: one narrow column
        // read per add instead of one wide row read per input spike.
        let row_pj = r.activity.bram_reads as f64 * model.pj_bram_read;
        let col_pj = r.activity.adds as f64 * model.pj_bram_read
            / ctx.cfg.n_outputs() as f64;
        banked_nj += r.energy.dynamic_nj - row_pj * 1e-3 + col_pj * 1e-3;
    }
    Ok(PrunePoint {
        accuracy: acc,
        dyn_nj: nj / probe as f64,
        dyn_banked_nj: banked_nj / probe as f64,
        adds_per_inference: adds as f64 / probe as f64,
    })
}

pub fn run_ablation_pruning(ctx: &Ctx) -> Result<()> {
    println!("ABLATION — active pruning (accuracy vs switching energy, T={})", ctx.cfg.timesteps);
    println!(
        "{:<18} {:>9} {:>13} {:>16} {:>12}",
        "prune_after", "accuracy", "dyn nJ (mono)", "dyn nJ (banked)", "adds/infer"
    );
    let mut rows = Vec::new();
    let points: Vec<(String, PruneMode)> = vec![
        ("1 (paper §III-D)".into(), PruneMode::AfterFires { after_spikes: 1 }),
        ("3".into(), PruneMode::AfterFires { after_spikes: 3 }),
        ("5".into(), PruneMode::AfterFires { after_spikes: 5 }),
        ("8 (calibrated)".into(), PruneMode::AfterFires { after_spikes: 8 }),
        ("off".into(), PruneMode::Off),
    ];
    for (label, prune) in points {
        let p = prune_point(ctx, prune)?;
        println!(
            "{label:<18} {:>8.2}% {:>13.1} {:>16.1} {:>12.0}",
            p.accuracy * 100.0,
            p.dyn_nj,
            p.dyn_banked_nj,
            p.adds_per_inference
        );
        rows.push(format!(
            "{label},{:.4},{:.2},{:.2},{:.1}",
            p.accuracy, p.dyn_nj, p.dyn_banked_nj, p.adds_per_inference
        ));
    }
    let path = ctx.write_csv(
        "ablation_pruning.csv",
        "prune_after,accuracy,dyn_nj_monolithic,dyn_nj_banked,adds",
        &rows,
    )?;
    println!("-> {}", path.display());
    println!(
        "finding: with a monolithic weight BRAM the row fetch dominates and pruning \
         saves little; the paper's power claim needs per-neuron banking (see the \
         banked column and EXPERIMENTS.md ablation A)"
    );
    Ok(())
}

pub fn run_ablation_decay(ctx: &Ctx) -> Result<()> {
    println!("ABLATION — decay shift × threshold grid (accuracy @T=10)");
    let imgs = ctx.eval_slice();
    let labels: Vec<u8> = imgs.iter().map(|i| i.label).collect();
    let vths = [ctx.cfg.v_th / 2, ctx.cfg.v_th, ctx.cfg.v_th * 2];
    print!("{:<10}", "shift\\vth");
    for v in vths {
        print!(" {v:>9}");
    }
    println!();
    let mut rows = Vec::new();
    for shift in 1..=6u32 {
        print!("{shift:<10}");
        for v in vths {
            let cfg = ctx
                .cfg
                .clone()
                .with_timesteps(10.min(ctx.cfg.timesteps))
                .with_decay_shift(shift)
                .with_v_th(v);
            let net = BehavioralNet::new(cfg, ctx.weights.weights.clone())?;
            let preds: Vec<u8> = imgs
                .iter()
                .enumerate()
                .map(|(i, img)| net.classify(img, ctx.eval_seed(i)).class)
                .collect();
            let acc = accuracy(&preds, &labels);
            print!(" {:>8.2}%", acc * 100.0);
            rows.push(format!("{shift},{v},{acc:.4}"));
        }
        println!();
    }
    let path = ctx.write_csv("ablation_decay.csv", "decay_shift,v_th,accuracy", &rows)?;
    println!("-> {}", path.display());
    Ok(())
}

pub fn run_ablation_modes(ctx: &Ctx) -> Result<()> {
    println!("ABLATION — RTL refinements: fire mode × leak scheduling (T=10, RTL-measured)");
    let imgs = ctx.eval_slice();
    let probe = imgs.len().min(200).max(1);
    let labels: Vec<u8> = imgs.iter().take(probe).map(|i| i.label).collect();
    // Per-row leak applies the shift-decay 28× per timestep: with the
    // paper's β = 2^-3 the membrane retains (7/8)^28 ≈ 2% per step and the
    // array goes silent. The "rescaled" variant compensates with
    // β = 2^-8 ((255/256)^28 ≈ 0.90 ≈ one 2^-3 leak) — the fix the paper
    // would need for its §III-B2 schedule to function.
    let variants: Vec<(&str, FireMode, LeakMode, Option<u32>)> = vec![
        ("endofstep/per-step", FireMode::EndOfStep, LeakMode::PerTimestep, None),
        ("endofstep/per-row", FireMode::EndOfStep, LeakMode::PerRow { row_len: 28 }, None),
        (
            "endofstep/per-row-rescaled",
            FireMode::EndOfStep,
            LeakMode::PerRow { row_len: 28 },
            Some(8),
        ),
        ("immediate/per-step", FireMode::Immediate, LeakMode::PerTimestep, None),
        ("immediate/per-row", FireMode::Immediate, LeakMode::PerRow { row_len: 28 }, None),
    ];
    println!(
        "{:<22} {:>9} {:>12} {:>16}",
        "variant", "accuracy", "cycles/infer", "dyn energy (nJ)"
    );
    let mut rows = Vec::new();
    for (label, fire, leak, decay_override) in variants {
        let cfg = ctx
            .cfg
            .clone()
            .with_timesteps(10.min(ctx.cfg.timesteps))
            .with_fire_mode(fire)
            .with_leak_mode(leak)
            .with_decay_shift(decay_override.unwrap_or(ctx.cfg.decay_shift));
        let mut core = RtlCore::new(cfg, ctx.weights.weights.clone())?;
        let mut preds = Vec::with_capacity(probe);
        let mut cycles = 0u64;
        let mut nj = 0.0;
        for (i, img) in imgs.iter().take(probe).enumerate() {
            let r = core.run(img, ctx.eval_seed(i))?;
            preds.push(r.class);
            cycles += r.cycles;
            nj += r.energy.dynamic_nj;
        }
        let acc = accuracy(&preds, &labels);
        let cyc = cycles / probe as u64;
        let e = nj / probe as f64;
        println!("{label:<22} {:>8.2}% {cyc:>12} {e:>16.1}", acc * 100.0);
        rows.push(format!("{label},{acc:.4},{cyc},{e:.2}"));
    }
    let path = ctx.write_csv("ablation_modes.csv", "variant,accuracy,cycles,dyn_nj", &rows)?;
    println!("-> {}", path.display());
    Ok(())
}

/// Datapath-width sweep: how wide the integration datapath must be for
/// the paper's two (mutually inconsistent) latency claims to hold.
pub fn run_ablation_width(ctx: &Ctx) -> Result<()> {
    println!(
        "ABLATION — datapath width (pixels/cycle) vs inference latency (T=10 @ 40 MHz)"
    );
    println!(
        "{:<14} {:>12} {:>12}   {}",
        "pixels/cycle", "cycles", "latency µs", "note"
    );
    let img = &ctx.test.images[0];
    let mut rows = Vec::new();
    let f_clk = crate::rtl::EnergyModel::default().f_clk_hz;
    for (k, note) in [
        (1usize, "paper Fig. 1 pixel-serial datapath"),
        (2, "matches the paper's §V-C '100 µs' text"),
        (4, ""),
        (8, ""),
        (28, "one image row per clock"),
        (784, "fully parallel; approaches Table II '<1 µs'"),
    ] {
        let cfg = ctx.cfg.clone().with_timesteps(10.min(ctx.cfg.timesteps));
        let mut core = RtlCore::new(cfg, ctx.weights.weights.clone())?
            .with_pixels_per_cycle(k);
        let r = core.run(img, ctx.eval_seed(0))?;
        let us = r.cycles as f64 / f_clk * 1e6;
        println!("{k:<14} {:>12} {us:>12.2}   {note}", r.cycles);
        rows.push(format!("{k},{},{us:.3}", r.cycles));
    }
    let path = ctx.write_csv("ablation_width.csv", "pixels_per_cycle,cycles,latency_us", &rows)?;
    println!("-> {}", path.display());
    println!(
        "reading: the paper's <1 µs (Table II) and 100 µs (§V-C) claims imply datapath \
         widths of ~784 and ~2 lanes respectively — neither is the Fig. 1 design; \
         results are bit-identical at every width (verified by test)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::test_support::synthetic_ctx;

    #[test]
    fn pruning_trades_energy_for_count_resolution() {
        let mut ctx = synthetic_ctx(60);
        ctx.samples = Some(60);
        let k1 = prune_point(&ctx, PruneMode::AfterFires { after_spikes: 1 }).unwrap();
        let off = prune_point(&ctx, PruneMode::Off).unwrap();
        // Pruning must strictly reduce switching.
        assert!(k1.dyn_nj < off.dyn_nj, "energy: {} !< {}", k1.dyn_nj, off.dyn_nj);
        assert!(k1.adds_per_inference < off.adds_per_inference);
        // Banked accounting amplifies the saving (adds scale with pruning).
        let mono_save = 1.0 - k1.dyn_nj / off.dyn_nj;
        let banked_save = 1.0 - k1.dyn_banked_nj / off.dyn_banked_nj;
        assert!(
            banked_save >= mono_save - 1e-9,
            "banked saving {banked_save} should be >= monolithic {mono_save}"
        );
    }
}
