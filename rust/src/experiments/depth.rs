//! Depth ablation: the paper's single 784→10 core against the MLP-shaped
//! 784→hidden→10 spiking pipeline the N-layer refactor unlocks.
//!
//! For each topology the harness measures, end to end through a *pooled
//! coordinator backend* (`RtlBackend` on the fast-path engine — the same
//! object the serving coordinator schedules onto):
//!
//! * accuracy over the eval slice,
//! * cycles per inference (exact: the backend's eviction-hook-harvested
//!   totals divided by the request count),
//! * dynamic energy and wall-clock per inference from an `RtlCore` probe,
//!   with the per-layer split the layered core now accounts.
//!
//! The two-layer weights come from the trained MLP artifact
//! (`ann_weights.bin`, quantized through `Mlp::to_weight_stack`) when it
//! exists; otherwise a deterministic synthetic hidden expansion keeps the
//! harness self-contained (plumbing, cycle and energy numbers stay
//! meaningful; accuracy of the synthetic stack is reported as such).

use crate::ann::Mlp;
use crate::config::SnnConfig;
use crate::coordinator::{Backend, RtlBackend};
use crate::data::Image;
use crate::fixed::{WeightMatrix, WeightStack};
use crate::rtl::RtlCore;
use crate::snn::EarlyExit;

use super::{accuracy, Ctx, Result};

/// One topology's measured point.
#[derive(Debug, Clone)]
pub struct DepthPoint {
    pub topology: Vec<usize>,
    pub accuracy: f64,
    /// Mean clock cycles per inference (exact, via backend totals).
    pub cycles_per_inference: f64,
    /// Mean dynamic energy per inference (nJ), whole core.
    pub dyn_nj: f64,
    /// Dynamic energy split by layer (nJ; excludes the shared encoder
    /// front-end).
    pub dyn_nj_by_layer: Vec<f64>,
    /// Wall-clock per inference at the model's f_clk (µs).
    pub time_us: f64,
}

/// The two-layer stack: trained MLP when built, synthetic otherwise.
/// Returns the stack and whether it came from the trained artifact.
fn two_layer_stack(ctx: &Ctx) -> Result<(WeightStack, bool)> {
    if let Ok(mlp) = Mlp::load(ctx.manifest.path("ann_weights.bin")) {
        if mlp.n_in == ctx.cfg.n_inputs() && mlp.n_out == ctx.cfg.n_outputs() {
            return Ok((mlp.to_weight_stack(ctx.cfg.weight_bits)?, true));
        }
    }
    // Synthetic fallback: block-expand the single-layer weights through a
    // 16-wide hidden layer (hidden h pools pixel block h, outputs re-mix
    // the blocks with the artifact's class structure).
    let hidden = 16usize;
    let n_in = ctx.cfg.n_inputs();
    let n_out = ctx.cfg.n_outputs();
    let block = n_in.div_ceil(hidden);
    let w0: Vec<i32> = (0..n_in * hidden)
        .map(|k| {
            let (i, h) = (k / hidden, k % hidden);
            if i / block == h {
                40
            } else {
                0
            }
        })
        .collect();
    // Hidden h covers pixels [h*block, (h+1)*block); give output j the
    // summed single-layer weight of that block (rescaled into 9 bits).
    let single = &ctx.weights.weights;
    let mut w1 = vec![0i32; hidden * n_out];
    for h in 0..hidden {
        for j in 0..n_out {
            let mut sum = 0i64;
            for i in h * block..((h + 1) * block).min(n_in) {
                sum += i64::from(single.get(i, j));
            }
            let scaled = (sum / block as i64).clamp(
                i64::from(ctx.cfg.weight_min()),
                i64::from(ctx.cfg.weight_max()),
            );
            w1[h * n_out + j] = scaled as i32;
        }
    }
    let stack = WeightStack::from_layers(vec![
        WeightMatrix::from_rows(n_in, hidden, ctx.cfg.weight_bits, w0)?,
        WeightMatrix::from_rows(hidden, n_out, ctx.cfg.weight_bits, w1)?,
    ])?;
    Ok((stack, false))
}

/// Measure one topology through the pooled coordinator backend.
pub fn depth_point(ctx: &Ctx, cfg: &SnnConfig, stack: WeightStack) -> Result<DepthPoint> {
    let imgs = ctx.eval_slice();
    let labels: Vec<u8> = imgs.iter().map(|i| i.label).collect();

    // Accuracy through the pooled backend (the serving object, not a bare
    // engine): one batched call per pool checkout keeps this honest about
    // the production path.
    let backend = RtlBackend::new(cfg.clone(), stack.clone())?;
    let refs: Vec<&Image> = imgs.iter().collect();
    let seeds: Vec<u32> = (0..refs.len()).map(|i| ctx.eval_seed(i)).collect();
    let outs = backend.classify_batch(&refs, &seeds, EarlyExit::Off)?;
    let preds: Vec<u8> = outs.iter().map(|o| o.class).collect();
    let acc = accuracy(&preds, &labels);
    let cycles_per_inference = backend.total_cycles() as f64 / refs.len().max(1) as f64;

    // Energy probe on a direct core (the backend does not expose per-run
    // energy; the fast path is bit-exact with the cycle engine, so the
    // probe numbers are the backend's numbers).
    let probe = imgs.len().min(25).max(1);
    let mut core = RtlCore::new(cfg.clone(), stack)?;
    let mut dyn_nj = 0.0;
    let mut time_us = 0.0;
    let mut dyn_by_layer = vec![0.0; cfg.n_layers()];
    for (i, img) in imgs.iter().take(probe).enumerate() {
        let r = core.run_fast(img, ctx.eval_seed(i))?;
        dyn_nj += r.energy.dynamic_nj;
        time_us += r.energy.time_us;
        for (slot, e) in dyn_by_layer.iter_mut().zip(&r.energy_by_layer) {
            *slot += e.dynamic_nj;
        }
    }
    let n = probe as f64;
    Ok(DepthPoint {
        topology: cfg.topology.clone(),
        accuracy: acc,
        cycles_per_inference,
        dyn_nj: dyn_nj / n,
        dyn_nj_by_layer: dyn_by_layer.into_iter().map(|v| v / n).collect(),
        time_us: time_us / n,
    })
}

pub fn run_ablation_depth(ctx: &Ctx) -> Result<()> {
    let (deep_stack, trained) = two_layer_stack(ctx)?;
    println!(
        "ABLATION — topology depth (T={}, two-layer weights: {})",
        ctx.cfg.timesteps,
        if trained { "trained MLP, quantized" } else { "synthetic block expansion" }
    );
    println!(
        "{:<18} {:>9} {:>13} {:>11} {:>10} {:>20}",
        "topology", "accuracy", "cycles/infer", "dyn nJ", "µs/infer", "dyn nJ by layer"
    );

    let shallow_cfg = ctx.cfg.clone();
    let deep_cfg = SnnConfig {
        topology: deep_stack.topology(),
        ..ctx.cfg.clone()
    }
    .validated()?;

    let mut rows = Vec::new();
    let points = [
        depth_point(ctx, &shallow_cfg, ctx.weights.weights.clone().into())?,
        depth_point(ctx, &deep_cfg, deep_stack)?,
    ];
    for p in &points {
        let label = format!("{:?}", p.topology);
        let per_layer = p
            .dyn_nj_by_layer
            .iter()
            .map(|v| format!("{v:.1}"))
            .collect::<Vec<_>>()
            .join(" + ");
        println!(
            "{label:<18} {:>8.2}% {:>13.0} {:>11.1} {:>10.2} {per_layer:>20}",
            p.accuracy * 100.0,
            p.cycles_per_inference,
            p.dyn_nj,
            p.time_us
        );
        rows.push(format!(
            "\"{label}\",{:.4},{:.0},{:.2},{:.3},\"{per_layer}\"",
            p.accuracy, p.cycles_per_inference, p.dyn_nj, p.time_us
        ));
    }
    let path = ctx.write_csv(
        "ablation_depth.csv",
        "topology,accuracy,cycles_per_inference,dyn_nj,time_us,dyn_nj_by_layer",
        &rows,
    )?;
    println!("-> {}", path.display());
    println!(
        "finding: depth costs one extra walk per timestep ({} extra clocks for the \
         hidden width above) — small next to the 784-pixel input walk — while the \
         hidden layer's adds dominate its energy share; see EXPERIMENTS.md §Depth",
        points[1].cycles_per_inference - points[0].cycles_per_inference
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::test_support;

    #[test]
    fn depth_ablation_runs_on_synthetic_ctx() {
        let ctx = test_support::synthetic_ctx(30);
        run_ablation_depth(&ctx).unwrap();
        let csv = std::fs::read_to_string(ctx.results_dir.join("ablation_depth.csv")).unwrap();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3, "header + two topology rows: {csv}");
        assert!(lines[1].contains("[784, 10]"), "{csv}");
        assert!(lines[2].contains("784"), "{csv}");
    }

    #[test]
    fn deep_point_costs_more_cycles_than_shallow() {
        let ctx = test_support::synthetic_ctx(10);
        let (stack, trained) = two_layer_stack(&ctx).unwrap();
        assert!(!trained, "synthetic ctx has no ann artifact");
        let shallow =
            depth_point(&ctx, &ctx.cfg, ctx.weights.weights.clone().into()).unwrap();
        let deep_cfg = SnnConfig { topology: stack.topology(), ..ctx.cfg.clone() }
            .validated()
            .unwrap();
        let deep = depth_point(&ctx, &deep_cfg, stack).unwrap();
        // Per timestep the deep pipeline adds exactly hidden+2 clocks.
        let t = f64::from(ctx.cfg.timesteps);
        assert_eq!(
            deep.cycles_per_inference - shallow.cycles_per_inference,
            (16.0 + 2.0) * t,
            "layered schedule cost must be hidden_width+2 clocks per step"
        );
        assert_eq!(deep.dyn_nj_by_layer.len(), 2);
        assert!(deep.dyn_nj_by_layer[0] > 0.0);
    }
}
