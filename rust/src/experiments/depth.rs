//! Depth ablation: the paper's single 784→10 core against the MLP-shaped
//! 784→hidden→10 spiking pipeline the N-layer refactor unlocks.
//!
//! For each topology the harness measures, end to end through a *pooled
//! coordinator backend* (`RtlBackend` on the fast-path engine — the same
//! object the serving coordinator schedules onto):
//!
//! * accuracy over the eval slice,
//! * cycles per inference (exact: the backend's eviction-hook-harvested
//!   totals divided by the request count),
//! * dynamic energy and wall-clock per inference from an `RtlCore` probe,
//!   with the per-layer split the layered core now accounts.
//!
//! The two-layer weights come from the trained MLP artifact
//! (`ann_weights.bin`, quantized + threshold-calibrated through
//! `Mlp::calibrated_layer_params` so each layer's `v_th` tracks its own
//! quantization scale) when it exists; otherwise a deterministic
//! synthetic hidden expansion keeps the harness self-contained (plumbing,
//! cycle and energy numbers stay meaningful; accuracy of the synthetic
//! stack is reported as such). The 3-layer calibration rows run the
//! closed-form demo stack either way.

use crate::ann::Mlp;
use crate::config::{LayerParams, PruneMode, SnnConfig};
use crate::coordinator::{Backend, RtlBackend};
use crate::data::{Image, IMG_PIXELS};
use crate::fixed::{WeightMatrix, WeightStack};
use crate::rtl::RtlCore;
use crate::snn::EarlyExit;

use super::{accuracy, Ctx, Result};

/// One topology's measured point.
#[derive(Debug, Clone)]
pub struct DepthPoint {
    pub topology: Vec<usize>,
    pub accuracy: f64,
    /// Mean clock cycles per inference (exact, via backend totals).
    pub cycles_per_inference: f64,
    /// Mean dynamic energy per inference (nJ), whole core.
    pub dyn_nj: f64,
    /// Dynamic energy split by layer (nJ; excludes the shared encoder
    /// front-end).
    pub dyn_nj_by_layer: Vec<f64>,
    /// Wall-clock per inference at the model's f_clk (µs).
    pub time_us: f64,
}

/// The two-layer stack: trained MLP when built, synthetic otherwise.
/// Returns the stack, its per-layer threshold calibration (empty for the
/// synthetic expansion, whose layers share the artifact's scale regime),
/// and whether it came from the trained artifact. The trained path runs
/// `Mlp::calibrated_layer_params`, so each spiking layer's `v_th` comes
/// from its own quantization scale instead of sharing layer 0's integer
/// threshold.
fn two_layer_stack(ctx: &Ctx) -> Result<(WeightStack, Vec<LayerParams>, bool)> {
    if let Ok(mlp) = Mlp::load(ctx.manifest.path("ann_weights.bin")) {
        if mlp.n_in == ctx.cfg.n_inputs() && mlp.n_out == ctx.cfg.n_outputs() {
            let (stack, params) =
                mlp.calibrated_layer_params(ctx.cfg.weight_bits, ctx.cfg.v_th)?;
            return Ok((stack, params, true));
        }
    }
    // Synthetic fallback: block-expand the single-layer weights through a
    // 16-wide hidden layer (hidden h pools pixel block h, outputs re-mix
    // the blocks with the artifact's class structure).
    let hidden = 16usize;
    let n_in = ctx.cfg.n_inputs();
    let n_out = ctx.cfg.n_outputs();
    let block = n_in.div_ceil(hidden);
    let w0: Vec<i32> = (0..n_in * hidden)
        .map(|k| {
            let (i, h) = (k / hidden, k % hidden);
            if i / block == h {
                40
            } else {
                0
            }
        })
        .collect();
    // Hidden h covers pixels [h*block, (h+1)*block); give output j the
    // summed single-layer weight of that block (rescaled into 9 bits).
    let single = &ctx.weights.weights;
    let mut w1 = vec![0i32; hidden * n_out];
    for h in 0..hidden {
        for j in 0..n_out {
            let mut sum = 0i64;
            for i in h * block..((h + 1) * block).min(n_in) {
                sum += i64::from(single.get(i, j));
            }
            let scaled = (sum / block as i64).clamp(
                i64::from(ctx.cfg.weight_min()),
                i64::from(ctx.cfg.weight_max()),
            );
            w1[h * n_out + j] = scaled as i32;
        }
    }
    let stack = WeightStack::from_layers(vec![
        WeightMatrix::from_rows(n_in, hidden, ctx.cfg.weight_bits, w0)?,
        WeightMatrix::from_rows(hidden, n_out, ctx.cfg.weight_bits, w1)?,
    ])?;
    Ok((stack, Vec::new(), false))
}

/// The closed-form per-layer-threshold calibration demo: a 3-weight-layer
/// block classifier `[784, 20, 10, 10]` whose layers deliberately sit at
/// very different weight scales (detector rows at 40, pooling at 200, a
/// 12-weight identity readout) — the regime a quantizing exporter
/// produces, since each layer maps its own max|w| to full range. Under
/// one shared `v_th` the readout's leak plateau (`12 · 2^decay = 96`)
/// never reaches the threshold, so the output layer is silent and every
/// image ties to class 0; the returned per-layer thresholds
/// (`[1500, 300, 20]`) restore firing at every depth. Used by the depth
/// ablation, the bench-report accuracy row and the regression tests.
pub fn calibration_demo_stack() -> (WeightStack, Vec<LayerParams>) {
    let n_in = IMG_PIXELS;
    let mut w0 = vec![0i32; n_in * 20];
    for i in 0..n_in {
        let block = i / 79;
        if block < 10 {
            // Two detectors per class block.
            w0[i * 20 + 2 * block] = 40;
            w0[i * 20 + 2 * block + 1] = 40;
        }
    }
    let mut w1 = vec![0i32; 20 * 10];
    for h in 0..20 {
        w1[h * 10 + h / 2] = 200;
    }
    let mut w2 = vec![0i32; 10 * 10];
    for c in 0..10 {
        w2[c * 10 + c] = 12;
    }
    let stack = WeightStack::from_layers(vec![
        WeightMatrix::from_rows(n_in, 20, 9, w0).expect("closed-form layer 0"),
        WeightMatrix::from_rows(20, 10, 9, w1).expect("closed-form layer 1"),
        WeightMatrix::from_rows(10, 10, 9, w2).expect("closed-form layer 2"),
    ])
    .expect("closed-form chain");
    let params = vec![
        LayerParams::with_v_th(1500),
        LayerParams::with_v_th(300),
        LayerParams::with_v_th(20),
    ];
    (stack, params)
}

/// Per-layer pruning policy for the demo stack: gate the (cheap, chatty)
/// upper layers after two fires, keep the readout intact — the
/// ROADMAP's "prune hidden aggressively, keep the readout intact" row.
pub fn calibration_demo_prune() -> Vec<LayerParams> {
    let (_, thresholds) = calibration_demo_stack();
    thresholds
        .into_iter()
        .enumerate()
        .map(|(l, p)| LayerParams {
            prune: Some(if l < 2 {
                PruneMode::AfterFires { after_spikes: 2 }
            } else {
                PruneMode::Off
            }),
            ..p
        })
        .collect()
}

/// One block image per class: class `c` lights exactly the pixels feeding
/// detector pair `2c, 2c+1` of the demo stack.
pub fn calibration_demo_image(class: usize) -> Image {
    let mut px = vec![0u8; IMG_PIXELS];
    for (i, p) in px.iter_mut().enumerate() {
        if i / 79 == class {
            *p = 250;
        }
    }
    Image { label: class as u8, pixels: px }
}

/// Measure one topology through the pooled coordinator backend over the
/// context's eval slice.
pub fn depth_point(ctx: &Ctx, cfg: &SnnConfig, stack: WeightStack) -> Result<DepthPoint> {
    depth_point_over(ctx, cfg, stack, ctx.eval_slice())
}

/// Measure one topology through the pooled coordinator backend over an
/// explicit image set (the calibration rows use the closed-form block
/// set, where the shared-vs-per-layer outcome is provable).
pub fn depth_point_over(
    ctx: &Ctx,
    cfg: &SnnConfig,
    stack: WeightStack,
    imgs: &[Image],
) -> Result<DepthPoint> {
    let labels: Vec<u8> = imgs.iter().map(|i| i.label).collect();

    // Accuracy through the pooled backend (the serving object, not a bare
    // engine): one batched call per pool checkout keeps this honest about
    // the production path.
    let backend = RtlBackend::new(cfg.clone(), stack.clone())?;
    let refs: Vec<&Image> = imgs.iter().collect();
    let seeds: Vec<u32> = (0..refs.len()).map(|i| ctx.eval_seed(i)).collect();
    let outs = backend.classify_batch(&refs, &seeds, EarlyExit::Off)?;
    let preds: Vec<u8> = outs.iter().map(|o| o.class).collect();
    let acc = accuracy(&preds, &labels);
    let cycles_per_inference = backend.total_cycles() as f64 / refs.len().max(1) as f64;

    // Energy probe on a direct core (the backend does not expose per-run
    // energy; the fast path is bit-exact with the cycle engine, so the
    // probe numbers are the backend's numbers).
    let probe = imgs.len().min(25).max(1);
    let mut core = RtlCore::new(cfg.clone(), stack)?;
    let mut dyn_nj = 0.0;
    let mut time_us = 0.0;
    let mut dyn_by_layer = vec![0.0; cfg.n_layers()];
    for (i, img) in imgs.iter().take(probe).enumerate() {
        let r = core.run_fast(img, ctx.eval_seed(i))?;
        dyn_nj += r.energy.dynamic_nj;
        time_us += r.energy.time_us;
        for (slot, e) in dyn_by_layer.iter_mut().zip(&r.energy_by_layer) {
            *slot += e.dynamic_nj;
        }
    }
    let n = probe as f64;
    Ok(DepthPoint {
        topology: cfg.topology.clone(),
        accuracy: acc,
        cycles_per_inference,
        dyn_nj: dyn_nj / n,
        dyn_nj_by_layer: dyn_by_layer.into_iter().map(|v| v / n).collect(),
        time_us: time_us / n,
    })
}

pub fn run_ablation_depth(ctx: &Ctx) -> Result<()> {
    let (deep_stack, deep_params, trained) = two_layer_stack(ctx)?;
    println!(
        "ABLATION — topology depth (T={}, two-layer weights: {})",
        ctx.cfg.timesteps,
        if trained { "trained MLP, quantized" } else { "synthetic block expansion" }
    );
    println!(
        "{:<18} {:>9} {:>13} {:>11} {:>10} {:>20}",
        "topology", "accuracy", "cycles/infer", "dyn nJ", "µs/infer", "dyn nJ by layer"
    );

    let shallow_cfg = ctx.cfg.clone();
    let deep_cfg = SnnConfig {
        topology: deep_stack.topology(),
        layer_params: deep_params,
        ..ctx.cfg.clone()
    }
    .validated()?;

    // 3-layer calibration rows: the same closed-form stack under one
    // shared v_th, per-layer calibrated v_th, and calibrated v_th with
    // per-layer pruning (upper layers gated after 2 fires, readout
    // intact). Accuracy is measured on the demo's block set, where the
    // outcome is provable (shared threshold silences the readout).
    let (demo_stack, demo_v_th) = calibration_demo_stack();
    let demo_imgs: Vec<Image> = (0..10).map(calibration_demo_image).collect();
    let demo_base = SnnConfig {
        topology: demo_stack.topology(),
        v_th: 128,
        // Pinned: the shared-v_th plateau argument (12 · 2^3 = 96 < 128)
        // must hold whatever decay the artifact calibrated.
        decay_shift: 3,
        prune: PruneMode::Off,
        layer_params: Vec::new(),
        ..ctx.cfg.clone()
    };
    let demo_shared = demo_base.clone().validated()?;
    let demo_cal = demo_base.clone().with_layer_params(demo_v_th).validated()?;
    let demo_cal_prune =
        demo_base.with_layer_params(calibration_demo_prune()).validated()?;

    let mut rows = Vec::new();
    let points = [
        ("shared v_th", depth_point(ctx, &shallow_cfg, ctx.weights.weights.clone().into())?),
        ("shared v_th", depth_point(ctx, &deep_cfg, deep_stack)?),
        (
            "shared v_th (3-layer demo)",
            depth_point_over(ctx, &demo_shared, demo_stack.clone(), &demo_imgs)?,
        ),
        (
            "per-layer v_th",
            depth_point_over(ctx, &demo_cal, demo_stack.clone(), &demo_imgs)?,
        ),
        (
            "per-layer v_th + prune",
            depth_point_over(ctx, &demo_cal_prune, demo_stack, &demo_imgs)?,
        ),
    ];
    for (variant, p) in &points {
        let label = format!("{:?}", p.topology);
        let per_layer = p
            .dyn_nj_by_layer
            .iter()
            .map(|v| format!("{v:.1}"))
            .collect::<Vec<_>>()
            .join(" + ");
        println!(
            "{label:<18} {:>8.2}% {:>13.0} {:>11.1} {:>10.2} {per_layer:>20}  {variant}",
            p.accuracy * 100.0,
            p.cycles_per_inference,
            p.dyn_nj,
            p.time_us
        );
        rows.push(format!(
            "\"{label}\",\"{variant}\",{:.4},{:.0},{:.2},{:.3},\"{per_layer}\"",
            p.accuracy, p.cycles_per_inference, p.dyn_nj, p.time_us
        ));
    }
    let path = ctx.write_csv(
        "ablation_depth.csv",
        "topology,variant,accuracy,cycles_per_inference,dyn_nj,time_us,dyn_nj_by_layer",
        &rows,
    )?;
    println!("-> {}", path.display());
    println!(
        "finding: depth costs one extra walk per timestep ({} extra clocks for the \
         hidden width above) — small next to the 784-pixel input walk — and a shared \
         v_th silences deep readouts whose quantization scale differs from layer 0's \
         ({:.0}% vs {:.0}% on the 3-layer demo); per-layer pruning then trims the \
         upper layers' energy share without touching the recovered accuracy; see \
         EXPERIMENTS.md §Depth",
        points[1].1.cycles_per_inference - points[0].1.cycles_per_inference,
        points[2].1.accuracy * 100.0,
        points[3].1.accuracy * 100.0,
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::test_support;

    #[test]
    fn depth_ablation_runs_on_synthetic_ctx() {
        let ctx = test_support::synthetic_ctx(30);
        run_ablation_depth(&ctx).unwrap();
        let csv = std::fs::read_to_string(ctx.results_dir.join("ablation_depth.csv")).unwrap();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(
            lines.len(),
            6,
            "header + 1/2-layer rows + three 3-layer calibration rows: {csv}"
        );
        assert!(lines[1].contains("[784, 10]"), "{csv}");
        assert!(lines[2].contains("784"), "{csv}");
        assert!(lines[3].contains("shared v_th (3-layer demo)"), "{csv}");
        assert!(lines[4].contains("per-layer v_th"), "{csv}");
        assert!(lines[5].contains("per-layer v_th + prune"), "{csv}");
    }

    #[test]
    fn three_layer_calibration_beats_shared_threshold() {
        // The acceptance row: on the 3-layer demo stack the per-layer
        // calibrated thresholds must beat the shared-v_th baseline, whose
        // readout plateau (12 · 2^3 < 128) provably never fires.
        let ctx = test_support::synthetic_ctx(10);
        let (stack, v_th) = calibration_demo_stack();
        let imgs: Vec<Image> = (0..10).map(calibration_demo_image).collect();
        let base = SnnConfig {
            topology: stack.topology(),
            v_th: 128,
            decay_shift: 3,
            prune: PruneMode::Off,
            layer_params: Vec::new(),
            ..ctx.cfg.clone()
        };
        let shared =
            depth_point_over(&ctx, &base.clone().validated().unwrap(), stack.clone(), &imgs)
                .unwrap();
        let calibrated = depth_point_over(
            &ctx,
            &base.clone().with_layer_params(v_th).validated().unwrap(),
            stack.clone(),
            &imgs,
        )
        .unwrap();
        let pruned = depth_point_over(
            &ctx,
            &base.with_layer_params(calibration_demo_prune()).validated().unwrap(),
            stack,
            &imgs,
        )
        .unwrap();
        assert!(
            (shared.accuracy - 0.1).abs() < 1e-9,
            "shared threshold must silence the readout (ties to class 0): {}",
            shared.accuracy
        );
        assert_eq!(calibrated.accuracy, 1.0, "calibrated thresholds recover every class");
        assert!(calibrated.accuracy > shared.accuracy, "the bench-report acceptance row");
        assert_eq!(
            pruned.accuracy, 1.0,
            "per-layer pruning (readout intact) must not cost accuracy"
        );
        assert!(
            pruned.dyn_nj < calibrated.dyn_nj,
            "gating the upper layers must cut dynamic energy: {} vs {}",
            pruned.dyn_nj,
            calibrated.dyn_nj
        );
        assert_eq!(calibrated.dyn_nj_by_layer.len(), 3);
    }

    #[test]
    fn deep_point_costs_more_cycles_than_shallow() {
        let ctx = test_support::synthetic_ctx(10);
        let (stack, params, trained) = two_layer_stack(&ctx).unwrap();
        assert!(!trained, "synthetic ctx has no ann artifact");
        assert!(params.is_empty(), "synthetic expansion shares the scalar calibration");
        let shallow =
            depth_point(&ctx, &ctx.cfg, ctx.weights.weights.clone().into()).unwrap();
        let deep_cfg = SnnConfig { topology: stack.topology(), ..ctx.cfg.clone() }
            .validated()
            .unwrap();
        let deep = depth_point(&ctx, &deep_cfg, stack).unwrap();
        // Per timestep the deep pipeline adds exactly hidden+2 clocks.
        let t = f64::from(ctx.cfg.timesteps);
        assert_eq!(
            deep.cycles_per_inference - shallow.cycles_per_inference,
            (16.0 + 2.0) * t,
            "layered schedule cost must be hidden_width+2 clocks per step"
        );
        assert_eq!(deep.dyn_nj_by_layer.len(), 2);
        assert!(deep.dyn_nj_by_layer[0] > 0.0);
    }
}
