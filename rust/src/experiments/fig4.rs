//! Fig. 4: membrane potential evolution of a single neuron — integrate,
//! threshold crossing, hard reset — from the cycle-accurate RTL core.

use crate::fixed::WeightMatrix;
use crate::rtl::RtlCore;

use super::{Ctx, Result};

/// The trace behind Fig. 4: per-timestep membrane of one neuron plus its
/// fire flags (pre-reset peak included for plotting the crossing).
#[derive(Debug, Clone)]
pub struct MembraneTrace {
    pub neuron: usize,
    pub v_th: i32,
    /// (timestep, membrane after fire/reset, fired?)
    pub points: Vec<(u32, i32, bool)>,
}

/// Run one image through the RTL core and extract neuron `label`'s trace.
pub fn compute_fig4(ctx: &Ctx, sample_index: usize) -> Result<MembraneTrace> {
    let img = &ctx.test.images[sample_index];
    let neuron = img.label as usize;
    let mut core = RtlCore::new(ctx.cfg.clone(), weights_of(ctx))?;
    let r = core.run(img, ctx.eval_seed(sample_index))?;
    let points = r
        .membrane_by_step
        .iter()
        .zip(&r.spikes_by_step)
        .enumerate()
        .map(|(t, (mem, spikes))| (t as u32, mem[neuron], spikes[neuron]))
        .collect();
    Ok(MembraneTrace { neuron, v_th: ctx.cfg.v_th, points })
}

fn weights_of(ctx: &Ctx) -> WeightMatrix {
    ctx.weights.weights.clone()
}

/// ASCII plot + CSV.
pub fn run_fig4(ctx: &Ctx) -> Result<()> {
    let trace = compute_fig4(ctx, 3)?; // canonical sample: class 3, index 0
    println!(
        "FIG 4 — membrane potential of neuron {} (V_th = {}, hard reset to 0)",
        trace.neuron, trace.v_th
    );
    let max_v = trace.points.iter().map(|&(_, v, _)| v).max().unwrap_or(1).max(trace.v_th);
    let width = 52usize;
    for &(t, v, fired) in &trace.points {
        let bar_len = if v <= 0 { 0 } else { (v as usize * width) / max_v as usize };
        let th_pos = (trace.v_th as usize * width) / max_v as usize;
        let mut line: Vec<char> = vec![' '; width + 1];
        for c in line.iter_mut().take(bar_len) {
            *c = '█';
        }
        if th_pos < line.len() {
            line[th_pos] = '|';
        }
        let marker = if fired { "  << FIRE+reset" } else { "" };
        println!("t={t:>2} {v:>7}  {}{}", line.iter().collect::<String>(), marker);
    }
    let rows: Vec<String> = trace
        .points
        .iter()
        .map(|&(t, v, f)| format!("{t},{v},{}", u8::from(f)))
        .collect();
    let path = ctx.write_csv("fig4.csv", "timestep,membrane,fired", &rows)?;
    println!("-> {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::test_support::synthetic_ctx;

    #[test]
    fn trace_shows_fire_and_reset() {
        let ctx = synthetic_ctx(100);
        let trace = compute_fig4(&ctx, 3).unwrap();
        assert_eq!(trace.points.len(), ctx.cfg.timesteps as usize);
        // Synthetic weights drive the class neuron hard: it must fire.
        assert!(trace.points.iter().any(|&(_, _, f)| f), "neuron never fired");
        // After every fire the stored membrane is the reset value.
        for &(_, v, fired) in &trace.points {
            if fired {
                assert_eq!(v, ctx.cfg.v_rest);
            }
            assert!(v < ctx.cfg.v_th, "post-step membrane at/above threshold");
        }
    }
}
