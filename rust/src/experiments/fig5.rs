//! Fig. 5: classification accuracy vs simulation timesteps (the paper's
//! convergence-by-t≈10 claim).

use crate::snn::BehavioralNet;

use super::{accuracy, Ctx, Result};

/// Accuracy at each window length `1..=t_max`.
///
/// One behavioral run at `t_max` with per-step readout would be faster,
/// but the semantics of pruning differ per window, so each `t` is a
/// genuine fresh inference (matching how the hardware would be configured).
pub fn compute_accuracy_curve(ctx: &Ctx, t_max: u32) -> Result<Vec<(u32, f64)>> {
    let imgs = ctx.eval_slice();
    let labels: Vec<u8> = imgs.iter().map(|i| i.label).collect();
    let mut curve = Vec::with_capacity(t_max as usize);
    for t in 1..=t_max {
        let cfg = ctx.cfg.clone().with_timesteps(t);
        let net = BehavioralNet::new(cfg, ctx.weights.weights.clone())?;
        let preds: Vec<u8> = imgs
            .iter()
            .enumerate()
            .map(|(i, img)| net.classify(img, ctx.eval_seed(i)).class)
            .collect();
        curve.push((t, accuracy(&preds, &labels)));
    }
    Ok(curve)
}

pub fn run_fig5(ctx: &Ctx) -> Result<()> {
    let t_max = ctx.cfg.timesteps;
    let n = ctx.eval_slice().len();
    println!("FIG 5 — accuracy vs simulation timesteps ({n} test samples)");
    let curve = compute_accuracy_curve(ctx, t_max)?;
    let mut rows = Vec::new();
    for &(t, acc) in &curve {
        let bar = "#".repeat((acc * 50.0) as usize);
        println!("t={t:>2}  {:>6.2}%  {bar}", acc * 100.0);
        rows.push(format!("{t},{acc:.4}"));
    }
    let path = ctx.write_csv("fig5.csv", "timesteps,accuracy", &rows)?;
    println!("-> {}", path.display());
    let final_acc = curve.last().map(|&(_, a)| a).unwrap_or(0.0);
    let at10 = curve.iter().find(|&&(t, _)| t == 10).map(|&(_, a)| a).unwrap_or(final_acc);
    println!(
        "accuracy @T=10: {:.2}%  (paper: ~89% on MNIST; see EXPERIMENTS.md for the \
         dataset substitution)",
        at10 * 100.0
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::test_support::synthetic_ctx;

    #[test]
    fn curve_has_window_shape() {
        let mut ctx = synthetic_ctx(50);
        ctx.samples = Some(50);
        let curve = compute_accuracy_curve(&ctx, 4).unwrap();
        assert_eq!(curve.len(), 4);
        assert_eq!(curve[0].0, 1);
        assert_eq!(curve[3].0, 4);
        assert!(curve.iter().all(|&(_, a)| (0.0..=1.0).contains(&a)));
    }

    /// With the real trained weights the curve must rise to the
    /// calibration's ≥95 % plateau (EXPERIMENTS.md; paper: ~89 % on MNIST).
    #[test]
    fn curve_rises_and_converges_on_artifacts() {
        let Some(ctx) = crate::experiments::test_support::artifact_ctx(200) else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let curve = compute_accuracy_curve(&ctx, 10).unwrap();
        let first = curve[0].1;
        let last = curve.last().unwrap().1;
        assert!(last >= first, "accuracy degraded with timesteps: {first} -> {last}");
        assert!(last > 0.9, "trained classifier below plateau at t=10: {last}");
    }
}
