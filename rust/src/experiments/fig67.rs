//! Fig. 6 (accuracy vs wall-clock inference time) and Fig. 7 (the
//! efficiency metric accuracy/time): the Fig. 5 series re-based onto the
//! hardware time axis using *measured* RTL cycle counts at the paper's
//! 40 MHz clock.

use crate::rtl::{EnergyModel, RtlCore};

use super::fig5::compute_accuracy_curve;
use super::{Ctx, Result};

/// Measured cycles for a `t`-timestep window on the RTL core.
pub fn cycles_for_window(ctx: &Ctx, t: u32) -> Result<u64> {
    let cfg = ctx.cfg.clone().with_timesteps(t);
    let mut core = RtlCore::new(cfg, ctx.weights.weights.clone())?;
    let img = &ctx.test.images[0];
    Ok(core.run(img, ctx.eval_seed(0))?.cycles)
}

/// The Fig. 6 series: (timesteps, time_us, accuracy).
pub fn compute_fig6(ctx: &Ctx) -> Result<Vec<(u32, f64, f64)>> {
    let f_clk = EnergyModel::default().f_clk_hz;
    let curve = compute_accuracy_curve(ctx, ctx.cfg.timesteps)?;
    curve
        .into_iter()
        .map(|(t, acc)| {
            let cycles = cycles_for_window(ctx, t)?;
            Ok((t, cycles as f64 / f_clk * 1e6, acc))
        })
        .collect()
}

pub fn run_fig6(ctx: &Ctx) -> Result<()> {
    println!(
        "FIG 6 — accuracy vs inference time (measured RTL cycles @ {} MHz)",
        EnergyModel::default().f_clk_hz / 1e6
    );
    let series = compute_fig6(ctx)?;
    let mut rows = Vec::new();
    for &(t, us, acc) in &series {
        println!("t={t:>2}  {us:>9.1} µs  {:>6.2}%", acc * 100.0);
        rows.push(format!("{t},{us:.2},{acc:.4}"));
    }
    let path = ctx.write_csv("fig6.csv", "timesteps,time_us,accuracy", &rows)?;
    println!("-> {}", path.display());
    Ok(())
}

pub fn run_fig7(ctx: &Ctx) -> Result<()> {
    println!("FIG 7 — efficiency (accuracy% / inference seconds) vs inference time");
    let series = compute_fig6(ctx)?;
    let mut rows = Vec::new();
    let mut peak_t = 0u32;
    let mut peak_eff = 0.0f64;
    for &(t, us, acc) in &series {
        let eff = (acc * 100.0) / (us / 1e6);
        if eff > peak_eff {
            peak_eff = eff;
            peak_t = t;
        }
        println!("t={t:>2}  {us:>9.1} µs  efficiency {eff:>12.0}");
        rows.push(format!("{t},{us:.2},{eff:.1}"));
    }
    let path = ctx.write_csv("fig7.csv", "timesteps,time_us,efficiency", &rows)?;
    println!("-> {}", path.display());
    println!(
        "efficiency peaks at t={peak_t} — earliest usable window, supporting the \
         paper's early-termination argument"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::test_support::synthetic_ctx;

    #[test]
    fn cycles_scale_linearly_with_window() {
        let ctx = synthetic_ctx(10);
        let c1 = cycles_for_window(&ctx, 1).unwrap();
        let c4 = cycles_for_window(&ctx, 4).unwrap();
        assert_eq!(c4, c1 * 4, "per-timestep schedule must be constant");
        assert_eq!(c1, 786, "784 integrate + 1 leak + 1 fire");
    }

    #[test]
    fn fig7_efficiency_decreasing_after_convergence() {
        let mut ctx = synthetic_ctx(50);
        ctx.samples = Some(50);
        ctx.cfg.timesteps = 6;
        let series = compute_fig6(&ctx).unwrap();
        // Once accuracy saturates, efficiency ∝ 1/t must strictly fall.
        let effs: Vec<f64> =
            series.iter().map(|&(_, us, acc)| acc * 100.0 / (us / 1e6)).collect();
        let last = effs.len() - 1;
        assert!(
            effs[last] < effs[last - 1] || series[last].2 > series[last - 1].2,
            "efficiency must decay once accuracy stops improving: {effs:?}"
        );
    }
}
