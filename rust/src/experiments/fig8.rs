//! Fig. 8: robustness under rotation, pixel shift, Gaussian noise and
//! occlusion (the paper's edge-deployment stress test).

use crate::data::perturb::Perturbation;
use crate::snn::BehavioralNet;

use super::{accuracy, Ctx, Result};

/// Accuracy at T = 10 under each perturbation of the paper suite.
pub fn compute_fig8(ctx: &Ctx, perturb_seed: u32) -> Result<Vec<(String, f64)>> {
    let imgs = ctx.eval_slice();
    let labels: Vec<u8> = imgs.iter().map(|i| i.label).collect();
    let t = 10u32.min(ctx.cfg.timesteps);
    let net = BehavioralNet::new(
        ctx.cfg.clone().with_timesteps(t),
        ctx.weights.weights.clone(),
    )?;
    let mut out = Vec::new();
    for p in Perturbation::paper_suite() {
        let preds: Vec<u8> = imgs
            .iter()
            .enumerate()
            .map(|(i, img)| {
                let perturbed = p.apply(img, perturb_seed, i as u32);
                net.classify(&perturbed, ctx.eval_seed(i)).class
            })
            .collect();
        out.push((p.label(), accuracy(&preds, &labels)));
    }
    Ok(out)
}

pub fn run_fig8(ctx: &Ctx) -> Result<()> {
    let n = ctx.eval_slice().len();
    println!("FIG 8 — robustness test ({n} samples, T=10)");
    let results = compute_fig8(ctx, 0xF168)?;
    let mut rows = Vec::new();
    for (label, acc) in &results {
        let bar = "#".repeat((acc * 50.0) as usize);
        println!("{label:<24} {:>6.2}%  {bar}", acc * 100.0);
        rows.push(format!("{label},{acc:.4}"));
    }
    let path = ctx.write_csv("fig8.csv", "perturbation,accuracy", &rows)?;
    println!("-> {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::test_support::synthetic_ctx;

    #[test]
    fn suite_shape() {
        let mut ctx = synthetic_ctx(50);
        ctx.samples = Some(50);
        let results = compute_fig8(&ctx, 7).unwrap();
        assert_eq!(results.len(), 5);
        assert_eq!(results[0].0, "clean");
        assert!(results.iter().all(|(_, a)| (0.0..=1.0).contains(a)));
    }

    /// With the real trained weights, clean accuracy dominates and the
    /// perturbations degrade it (Fig. 8's qualitative claim).
    #[test]
    fn clean_beats_or_matches_perturbed_on_artifacts() {
        let Some(ctx) = crate::experiments::test_support::artifact_ctx(200) else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let results = compute_fig8(&ctx, 7).unwrap();
        let clean = results[0].1;
        assert!(clean > 0.85, "clean accuracy too low: {clean}");
        for (label, acc) in &results[1..] {
            assert!(
                *acc <= clean + 0.02,
                "{label} should not beat clean accuracy: {acc} vs {clean}"
            );
        }
    }
}
