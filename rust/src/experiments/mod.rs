//! Experiment harnesses: one module per table/figure of the paper's
//! evaluation, plus the ablations DESIGN.md §6 calls out.
//!
//! Every experiment loads the canonical artifacts (trained weights, test
//! set, manifest), prints the paper-formatted rows to stdout and writes a
//! CSV under `results/`. Absolute numbers differ from the paper where
//! DESIGN.md §2 documents a substitution (synthetic digits, energy model);
//! the *shape* — who wins, by what factor, where curves bend — is the
//! reproduction target. EXPERIMENTS.md records paper-vs-measured for every
//! row.

mod ablations;
mod depth;
mod fig4;
mod fig5;
mod fig67;
mod fig8;
mod sparsity;
mod table1;
mod table2;

use std::path::{Path, PathBuf};

use crate::data::{codec, Dataset, WeightArtifact};
use crate::error::{Error, Result};
use crate::runtime::Manifest;
use crate::SnnConfig;

pub use ablations::{run_ablation_decay, run_ablation_modes, run_ablation_pruning, run_ablation_width};
pub use depth::{
    calibration_demo_image, calibration_demo_prune, calibration_demo_stack, depth_point,
    depth_point_over, run_ablation_depth, DepthPoint,
};
pub use fig4::run_fig4;
pub use fig5::run_fig5;
pub use fig67::{run_fig6, run_fig7};
pub use fig8::run_fig8;
pub use sparsity::{run_ablation_sparsity, sparsity_point, SparsePoint};
pub use table1::run_table1;
pub use table2::run_table2;

/// Shared context: artifacts + output locations.
pub struct Ctx {
    pub manifest: Manifest,
    pub weights: WeightArtifact,
    pub test: Dataset,
    pub cfg: SnnConfig,
    pub results_dir: PathBuf,
    /// Sample budget for accuracy sweeps (full test set when `None`).
    pub samples: Option<usize>,
}

impl Ctx {
    /// Load from the artifact + results directories.
    pub fn load(artifacts: impl AsRef<Path>, results: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts)?;
        let weights = codec::load_weights(manifest.path("weights.bin"))?;
        let test = codec::load_dataset(manifest.path("digits_test.bin"))?;
        let cfg = manifest.snn_config()?;
        let results_dir = results.as_ref().to_path_buf();
        std::fs::create_dir_all(&results_dir)
            .map_err(|e| Error::io(&results_dir, e))?;
        Ok(Ctx { manifest, weights, test, cfg, results_dir, samples: None })
    }

    /// The shared eval-seed convention (mirrors python aot.py).
    pub fn eval_seed(&self, index: usize) -> u32 {
        self.manifest
            .eval_seed(index as u32)
            .expect("manifest carries eval seed keys")
    }

    /// Evaluation slice: the first `samples` test images (balanced by the
    /// interleaved dataset layout) or the full set.
    pub fn eval_slice(&self) -> &[crate::data::Image] {
        let n = self.samples.unwrap_or(self.test.len()).min(self.test.len());
        &self.test.images[..n]
    }

    /// Write a CSV file into the results directory.
    pub fn write_csv(&self, name: &str, header: &str, rows: &[String]) -> Result<PathBuf> {
        let path = self.results_dir.join(name);
        let mut body = String::from(header);
        body.push('\n');
        for r in rows {
            body.push_str(r);
            body.push('\n');
        }
        std::fs::write(&path, body).map_err(|e| Error::io(&path, e))?;
        Ok(path)
    }
}

/// Run one experiment by id (`all` runs the full paper suite).
pub fn run(id: &str, ctx: &Ctx) -> Result<()> {
    match id {
        "table1" => run_table1(ctx),
        "fig4" => run_fig4(ctx),
        "fig5" => run_fig5(ctx),
        "fig6" => run_fig6(ctx),
        "fig7" => run_fig7(ctx),
        "table2" => run_table2(ctx),
        "fig8" => run_fig8(ctx),
        "ablation-pruning" => run_ablation_pruning(ctx),
        "ablation-decay" => run_ablation_decay(ctx),
        "ablation-modes" => run_ablation_modes(ctx),
        "ablation-width" => run_ablation_width(ctx),
        "ablation-depth" => run_ablation_depth(ctx),
        "ablation-sparsity" => run_ablation_sparsity(ctx),
        "all" => {
            for id in [
                "table1", "fig4", "fig5", "fig6", "fig7", "table2", "fig8",
                "ablation-pruning", "ablation-decay", "ablation-modes", "ablation-width",
                "ablation-depth", "ablation-sparsity",
            ] {
                println!("\n================ {id} ================");
                run(id, ctx)?;
            }
            Ok(())
        }
        other => Err(Error::InvalidConfig(format!(
            "unknown experiment {other:?}; see `snn-rtl experiment --help`"
        ))),
    }
}

/// Accuracy of spike-count argmax predictions.
pub(crate) fn accuracy(preds: &[u8], labels: &[u8]) -> f64 {
    let hits = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
    hits as f64 / preds.len().max(1) as f64
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::data::DigitGen;
    use crate::fixed::WeightMatrix;

    /// Ctx over the real built artifacts (trained weights). `None` when
    /// `make artifacts` has not run — callers skip accuracy assertions
    /// then (the Makefile orders artifacts before tests, so CI always
    /// exercises them).
    pub fn artifact_ctx(samples: usize) -> Option<Ctx> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            return None;
        }
        let results = std::env::temp_dir().join(format!(
            "snn_exp_results_{}_{samples}",
            std::process::id()
        ));
        let mut ctx = Ctx::load(&dir, &results).ok()?;
        ctx.samples = Some(samples);
        Some(ctx)
    }

    /// A self-contained Ctx over synthetic weights (no artifacts needed),
    /// so experiment plumbing is testable in isolation.
    pub fn synthetic_ctx(samples: usize) -> Ctx {
        let dir = std::env::temp_dir().join(format!(
            "snn_exp_ctx_{}_{samples}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "schema=1\nn_inputs=784\nn_outputs=10\nv_th=384\nv_rest=0\n\
             decay_shift=3\nacc_bits=24\nweight_bits=9\ntimesteps=20\n\
             prune_after=5\neval_seed_base=12648430\neval_seed_mult=2654435761\n\
             chunk_steps=5\nforward_batches=1,8,32\nann_batches=1,32\n",
        )
        .unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let cfg = manifest.snn_config().unwrap();
        // Crisp per-class weights so experiments produce meaningful output.
        let mut w = vec![0i32; 784 * 10];
        for i in 0..784 {
            let block = i / 79;
            if block < 10 {
                w[i * 10 + block] = 60;
            }
        }
        let weights = WeightArtifact {
            weights: WeightMatrix::from_rows(784, 10, 9, w).unwrap(),
            v_th: cfg.v_th,
            decay_shift: cfg.decay_shift,
            timesteps: cfg.timesteps,
            prune_after: 5,
        };
        Ctx {
            manifest,
            weights,
            test: DigitGen::new(2).dataset((samples / 10).max(1) as u32),
            cfg,
            results_dir: dir,
            samples: Some(samples),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_math() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 4]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn unknown_experiment_errors() {
        let ctx = test_support::synthetic_ctx(10);
        assert!(run("nope", &ctx).is_err());
    }

    #[test]
    fn ctx_eval_slice_respects_budget() {
        let ctx = test_support::synthetic_ctx(20);
        assert_eq!(ctx.eval_slice().len(), 20);
    }
}
