//! Sparsity ablation: the magnitude-pruning threshold sweep behind the
//! event-driven CSR engine (EXPERIMENTS.md §Sparse).
//!
//! For each keep-threshold t the core runs the *same* artifact weights
//! through `run_fast_sparse` over a CSR built with `|w| >= t`: accuracy
//! measures the pruning damage, density the fraction of synapses left,
//! and adds/inference the event-rate work the sparse sweep actually
//! performs (the dense sweep pays every output column of an active row
//! whether the weight is zero or not). Threshold 0 is the anchor — the
//! CSR keeps every entry and the row must match the dense path exactly.

use crate::rtl::RtlCore;

use super::{accuracy, Ctx, Result};

/// One threshold's measured trade-off point.
#[derive(Debug, Clone, Copy)]
pub struct SparsePoint {
    pub threshold: i32,
    /// Surviving fraction of weight entries under `|w| >= threshold`.
    pub density: f64,
    pub accuracy: f64,
    /// Mean accumulator adds actually performed per inference by the
    /// sparse sweep (probe subset).
    pub adds_per_inference: f64,
}

/// Accuracy + event-rate work of the CSR sweep at one keep-threshold.
pub fn sparsity_point(ctx: &Ctx, threshold: i32) -> Result<SparsePoint> {
    let imgs = ctx.eval_slice();
    let labels: Vec<u8> = imgs.iter().map(|i| i.label).collect();
    let mut core = RtlCore::new(ctx.cfg.clone(), ctx.weights.weights.clone())?;
    core.attach_sparse(threshold);
    let density = core.sparse_density().expect("CSR just attached");
    let probe = imgs.len().min(25).max(1);
    let mut adds = 0u64;
    let mut preds = Vec::with_capacity(imgs.len());
    for (i, img) in imgs.iter().enumerate() {
        let r = core.run_fast_sparse(img, ctx.eval_seed(i))?;
        preds.push(r.class);
        if i < probe {
            adds += r.activity.adds;
        }
    }
    Ok(SparsePoint {
        threshold,
        density,
        accuracy: accuracy(&preds, &labels),
        adds_per_inference: adds as f64 / probe as f64,
    })
}

pub fn run_ablation_sparsity(ctx: &Ctx) -> Result<()> {
    println!(
        "ABLATION — magnitude-pruned CSR sweep (accuracy vs density, T={})",
        ctx.cfg.timesteps
    );
    println!(
        "{:<10} {:>9} {:>9} {:>12}",
        "threshold", "density", "accuracy", "adds/infer"
    );
    let mut rows = Vec::new();
    let mut anchor: Option<SparsePoint> = None;
    for threshold in [0i32, 1, 2, 4, 8, 16, 32] {
        let p = sparsity_point(ctx, threshold)?;
        println!(
            "{threshold:<10} {:>8.1}% {:>8.2}% {:>12.0}",
            p.density * 100.0,
            p.accuracy * 100.0,
            p.adds_per_inference
        );
        rows.push(format!(
            "{threshold},{:.4},{:.4},{:.1}",
            p.density, p.accuracy, p.adds_per_inference
        ));
        if threshold == 0 {
            anchor = Some(p);
        }
    }
    let path = ctx.write_csv(
        "ablation_sparsity.csv",
        "threshold,density,accuracy,adds",
        &rows,
    )?;
    println!("-> {}", path.display());
    if let Some(a) = anchor {
        println!(
            "anchor: threshold 0 keeps density {:.1}% (every entry) at {:.2}% accuracy — \
             the bit-exact dense baseline; the exactness theorem says every other row's \
             accuracy shift is pure pruning damage, never sweep-order noise",
            a.density * 100.0,
            a.accuracy * 100.0
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::test_support::synthetic_ctx;

    #[test]
    fn sparsity_sweep_is_exact_at_threshold_zero_and_sheds_adds() {
        let mut ctx = synthetic_ctx(60);
        ctx.samples = Some(60);
        let t0 = sparsity_point(&ctx, 0).unwrap();
        assert_eq!(t0.density, 1.0);

        // Threshold 0 must agree with the dense fast path image-for-image.
        let imgs = ctx.eval_slice();
        let mut dense = RtlCore::new(ctx.cfg.clone(), ctx.weights.weights.clone()).unwrap();
        let mut sparse = RtlCore::new(ctx.cfg.clone(), ctx.weights.weights.clone()).unwrap();
        sparse.attach_sparse(0);
        for (i, img) in imgs.iter().enumerate() {
            let want = dense.run_fast(img, ctx.eval_seed(i)).unwrap();
            let got = sparse.run_fast_sparse(img, ctx.eval_seed(i)).unwrap();
            assert_eq!(got, want, "image {i}");
        }

        // The synthetic stack is one 60-weight stripe per class on a field
        // of explicit zeros: threshold 1 drops the zeros, keeps the
        // signal, and the event-driven sweep sheds the zero adds without
        // moving accuracy.
        let t1 = sparsity_point(&ctx, 1).unwrap();
        assert!(t1.density < 0.2, "density {}", t1.density);
        assert_eq!(t1.accuracy, t0.accuracy);
        assert!(
            t1.adds_per_inference < t0.adds_per_inference,
            "adds {} !< {}",
            t1.adds_per_inference,
            t0.adds_per_inference
        );
    }
}
