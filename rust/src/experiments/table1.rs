//! Table I: stochastic input current statistics (first timestep, 300
//! samples per digit).
//!
//! For each digit class `d`, over the test samples of that class, we
//! measure the input current `Σ_i W[i][d]·S_i[0]` delivered to the class's
//! own neuron on the very first encoder timestep — the quantity whose
//! avg/min/max the paper tabulates, with an OK/flag status column checking
//! the current is usable (positive mean, below saturation).

use crate::snn::encode_step;

use super::{Ctx, Result};

/// Per-digit first-step current statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct CurrentStats {
    pub digit: u8,
    pub samples: usize,
    pub avg: f64,
    pub min: i64,
    pub max: i64,
    pub ok: bool,
}

/// Compute the Table I statistics over up to `per_class` samples per digit.
pub fn compute_table1(ctx: &Ctx, per_class: usize) -> Result<Vec<CurrentStats>> {
    let w = &ctx.weights.weights;
    let mut out = Vec::with_capacity(10);
    for digit in 0u8..10 {
        let mut sum = 0f64;
        let mut min = i64::MAX;
        let mut max = i64::MIN;
        let mut n = 0usize;
        for (idx, img) in ctx.test.of_class(digit).take(per_class).enumerate() {
            let seed = ctx.eval_seed(idx * 10 + digit as usize);
            let spikes = encode_step(img, seed, 0);
            let mut current = 0i64;
            for (i, &s) in spikes.iter().enumerate() {
                if s {
                    current += i64::from(w.get(i, digit as usize));
                }
            }
            sum += current as f64;
            min = min.min(current);
            max = max.max(current);
            n += 1;
        }
        let avg = if n > 0 { sum / n as f64 } else { 0.0 };
        // Status: the current must drive the neuron (positive mean) and
        // stay far from the accumulator rails.
        let ok = n > 0 && avg > 0.0 && max < i64::from(ctx.cfg.acc_max()) / 4;
        out.push(CurrentStats { digit, samples: n, avg, min, max, ok });
    }
    Ok(out)
}

/// Print the paper-formatted table and write the CSV.
pub fn run_table1(ctx: &Ctx) -> Result<()> {
    let per_class = ctx.samples.map(|s| s / 10).unwrap_or(300).max(1);
    let stats = compute_table1(ctx, per_class)?;
    println!("TABLE I — stochastic input current statistics (first timestep, {per_class} samples)");
    println!("{:<6} {:>12} {:>8} {:>8}   {}", "Digit", "Avg Current", "Min", "Max", "Status");
    let mut rows = Vec::new();
    for s in &stats {
        println!(
            "{:<6} {:>12.1} {:>8} {:>8}   {}",
            s.digit,
            s.avg,
            s.min,
            s.max,
            if s.ok { "OK" } else { "FLAG" }
        );
        rows.push(format!("{},{},{:.2},{},{},{}", s.digit, s.samples, s.avg, s.min, s.max, s.ok));
    }
    let path = ctx.write_csv("table1.csv", "digit,samples,avg,min,max,ok", &rows)?;
    println!("-> {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::test_support::synthetic_ctx;

    #[test]
    fn own_class_current_is_positive_and_ok() {
        let ctx = synthetic_ctx(100);
        let stats = compute_table1(&ctx, 10).unwrap();
        assert_eq!(stats.len(), 10);
        for s in &stats {
            assert_eq!(s.samples, 10);
            assert!(s.avg > 0.0, "digit {} has non-positive mean current", s.digit);
            assert!(s.ok, "digit {} flagged: {s:?}", s.digit);
            assert!(i64::from(s.min as i32) <= s.max);
        }
    }

    #[test]
    fn csv_written() {
        let ctx = synthetic_ctx(50);
        run_table1(&ctx).unwrap();
        let csv = std::fs::read_to_string(ctx.results_dir.join("table1.csv")).unwrap();
        assert_eq!(csv.lines().count(), 11); // header + 10 digits
    }
}
