//! Table II: the TinyML-ANN vs proposed-SNN comparison — arithmetic class,
//! multiplication count, model size, latency and power/energy — built from
//! *measured* quantities on both sides (exact op counts + the documented
//! ESP32 and 45 nm energy models; DESIGN.md §2).

use crate::ann::{AnnOpCounts, Esp32Model, Mlp};
use crate::rtl::{EnergyModel, RtlCore};
use crate::snn::BehavioralNet;

use super::{accuracy, Ctx, Result};

/// Everything Table II reports, measured.
#[derive(Debug, Clone)]
pub struct Table2 {
    pub ann_ops: AnnOpCounts,
    pub ann_latency_soft_us: f64,
    pub ann_latency_dsp_us: f64,
    pub ann_energy_dsp_uj: f64,
    pub ann_accuracy: Option<f64>,
    pub snn_model_bytes: u64,
    pub snn_adds_per_inference: f64,
    pub snn_cycles: u64,
    pub snn_latency_us: f64,
    pub snn_energy_uj: f64,
    pub snn_avg_power_mw: f64,
    pub snn_accuracy: f64,
    /// Model size reduction factor (the paper's 11.3×).
    pub memory_reduction: f64,
}

/// Compute the comparison over the evaluation slice at T = 10 (the paper's
/// convergence window).
pub fn compute_table2(ctx: &Ctx) -> Result<Table2> {
    let imgs = ctx.eval_slice();
    let labels: Vec<u8> = imgs.iter().map(|i| i.label).collect();
    let t = 10u32.min(ctx.cfg.timesteps);
    let cfg = ctx.cfg.clone().with_timesteps(t);

    // --- SNN side: measured on the RTL core -------------------------------
    let mut core = RtlCore::new(cfg.clone(), ctx.weights.weights.clone())?;
    let probe = imgs.len().min(50).max(1);
    let mut adds = 0u64;
    let mut cycles = 0u64;
    let mut energy_nj = 0f64;
    let mut power_mw = 0f64;
    for (i, img) in imgs.iter().take(probe).enumerate() {
        let r = core.run(img, ctx.eval_seed(i))?;
        adds += r.activity.adds;
        cycles += r.cycles;
        energy_nj += r.energy.dynamic_nj + r.energy.static_nj;
        power_mw += r.energy.avg_power_mw;
    }
    let snn_cycles = cycles / probe as u64;
    let f_clk = EnergyModel::default().f_clk_hz;

    // Accuracy over the full slice with the fast behavioral model (bit-
    // equivalent to the RTL by test).
    let net = BehavioralNet::new(cfg, ctx.weights.weights.clone())?;
    let preds: Vec<u8> = imgs
        .iter()
        .enumerate()
        .map(|(i, img)| net.classify(img, ctx.eval_seed(i)).class)
        .collect();
    let snn_accuracy = accuracy(&preds, &labels);

    // --- ANN side ----------------------------------------------------------
    let ann_ops = AnnOpCounts::for_topology(784, 32, 10);
    let esp = Esp32Model::default().evaluate(&ann_ops);
    let ann_accuracy = Mlp::load(ctx.manifest.path("ann_weights.bin"))
        .ok()
        .map(|mlp| {
            let preds: Vec<u8> = imgs.iter().map(|img| mlp.classify(img)).collect();
            accuracy(&preds, &labels)
        });

    let snn_model_bytes = (ctx.cfg.weight_storage_bits() + 7) / 8;
    Ok(Table2 {
        ann_ops,
        ann_latency_soft_us: esp.latency_soft_us,
        ann_latency_dsp_us: esp.latency_dsp_us,
        ann_energy_dsp_uj: esp.energy_dsp_uj,
        ann_accuracy,
        snn_model_bytes,
        snn_adds_per_inference: adds as f64 / probe as f64,
        snn_cycles,
        snn_latency_us: snn_cycles as f64 / f_clk * 1e6,
        snn_energy_uj: energy_nj / probe as f64 / 1e3,
        snn_avg_power_mw: power_mw / probe as f64,
        snn_accuracy,
        memory_reduction: ann_ops.model_bytes as f64 / snn_model_bytes as f64,
    })
}

pub fn run_table2(ctx: &Ctx) -> Result<()> {
    let t2 = compute_table2(ctx)?;
    println!("TABLE II — TinyML ANN (ESP32 cost model) vs proposed SNN (RTL, measured)");
    println!("{:<22} {:>26} {:>26}", "Metric", "Baseline ANN (ESP32)", "Proposed SNN (RTL)");
    println!("{:<22} {:>26} {:>26}", "Arithmetic", "f32 MAC", "fixed-point add/shift");
    println!(
        "{:<22} {:>26} {:>26}",
        "Multiplications",
        format!("{}", t2.ann_ops.multiplications),
        "0"
    );
    println!(
        "{:<22} {:>26} {:>26}",
        "Additions",
        format!("{}", t2.ann_ops.additions),
        format!("{:.0} (event-driven)", t2.snn_adds_per_inference)
    );
    println!(
        "{:<22} {:>26} {:>26}",
        "Model size",
        format!("{:.1} KB", t2.ann_ops.model_bytes as f64 / 1024.0),
        format!("{:.2} KB ({:.1}x smaller)", t2.snn_model_bytes as f64 / 1024.0,
                t2.memory_reduction)
    );
    println!(
        "{:<22} {:>26} {:>26}",
        "Latency",
        format!("{:.2} s / {:.0} µs (DSP)", t2.ann_latency_soft_us / 1e6, t2.ann_latency_dsp_us),
        format!("{:.1} µs ({} cycles)", t2.snn_latency_us, t2.snn_cycles)
    );
    println!(
        "{:<22} {:>26} {:>26}",
        "Energy/inference",
        format!("{:.0} µJ (DSP)", t2.ann_energy_dsp_uj),
        format!("{:.3} µJ", t2.snn_energy_uj)
    );
    println!(
        "{:<22} {:>26} {:>26}",
        "Avg power",
        "continuous active",
        format!("{:.2} mW", t2.snn_avg_power_mw)
    );
    println!(
        "{:<22} {:>26} {:>26}",
        "Accuracy (T=10)",
        t2.ann_accuracy.map_or("n/a".to_string(), |a| format!("{:.2}%", a * 100.0)),
        format!("{:.2}%", t2.snn_accuracy * 100.0)
    );

    let rows = vec![format!(
        "{},{},{},{:.1},{:.1},{:.0},{:.3},{},{:.1},{:.4},{}",
        t2.ann_ops.multiplications,
        t2.ann_ops.additions,
        t2.ann_ops.model_bytes,
        t2.ann_latency_soft_us,
        t2.ann_latency_dsp_us,
        t2.snn_adds_per_inference,
        t2.snn_energy_uj,
        t2.snn_model_bytes,
        t2.snn_latency_us,
        t2.snn_accuracy,
        t2.ann_accuracy.map_or(String::from(""), |a| format!("{a:.4}")),
    )];
    let path = ctx.write_csv(
        "table2.csv",
        "ann_mults,ann_adds,ann_bytes,ann_soft_us,ann_dsp_us,snn_adds,snn_energy_uj,\
         snn_bytes,snn_latency_us,snn_acc,ann_acc",
        &rows,
    )?;
    println!("-> {}", path.display());
    println!(
        "note: paper's Table II latency row (<1 µs) contradicts its own §V-C text \
         (10 steps @ 40 MHz ≈ 100 µs); we report measured cycles — see EXPERIMENTS.md"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::test_support::synthetic_ctx;

    #[test]
    fn headline_ratios_reproduce() {
        let mut ctx = synthetic_ctx(100);
        ctx.samples = Some(100);
        let t2 = compute_table2(&ctx).unwrap();
        // Paper's identity rows. (The exact byte ratio is 101,800 B /
        // 8,820 B = 11.54×; the paper's "11.3×" rounds both sides first.)
        assert_eq!(t2.ann_ops.multiplications, 25_408);
        assert!((t2.memory_reduction - 11.54).abs() < 0.05, "{}", t2.memory_reduction);
        // SNN does fewer adds than the ANN's MAC count (event-driven
        // sparsity) — the paper's §V-A claim.
        assert!(t2.snn_adds_per_inference < t2.ann_ops.additions as f64);
        // Orders of magnitude: SNN latency must sit far below the ESP32
        // soft-float path and below the DSP path too.
        assert!(t2.snn_latency_us * 10.0 < t2.ann_latency_dsp_us);
        // Energy: the event-driven core must be far cheaper per inference.
        assert!(t2.snn_energy_uj * 100.0 < t2.ann_energy_dsp_uj);
    }

    /// With the trained artifacts both classifiers must be accurate and
    /// the SNN side reports a calibrated accuracy near its plateau.
    #[test]
    fn accuracy_rows_on_artifacts() {
        let Some(ctx) = crate::experiments::test_support::artifact_ctx(200) else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let t2 = compute_table2(&ctx).unwrap();
        assert!(t2.snn_accuracy > 0.9, "SNN accuracy {}", t2.snn_accuracy);
        let ann = t2.ann_accuracy.expect("ann artifact present");
        assert!(ann > 0.9, "ANN accuracy {ann}");
    }
}
