//! Fixed-point arithmetic primitives mirroring the paper's datapath.
//!
//! The paper's core avoids floating point entirely: the membrane potential
//! lives in a saturating signed accumulator, the leak `β·V` with `β = 2^-n`
//! is an arithmetic right shift, and weights are 9-bit signed integers.
//! This module provides those primitives plus the pack/unpack codec for the
//! dense 9-bit weight memory (the source of the paper's 8.6 KB figure).

// These kernels *are* the paper's bit-exactness contract, so every new
// arithmetic expression in this file must be consciously annotated with
// the bound that keeps it exact (i64 widening, validated shift ranges).
#![deny(clippy::arithmetic_side_effects)]

// The codec/CSR submodules are outside the deny scope for now: their
// arithmetic is size/offset bookkeeping validated by the golden fixtures,
// not datapath math. Tighten when they are next touched.
#[allow(clippy::arithmetic_side_effects)]
mod sparse;
#[allow(clippy::arithmetic_side_effects)]
mod weights;

pub use sparse::{SparseWeightLayer, SparseWeightStack};
pub use weights::{pack_weights, unpack_weights, WeightMatrix, WeightStack};

/// Saturating add clamped to a symmetric `bits`-wide signed range, i.e.
/// `[-(2^(bits-1)-1), 2^(bits-1)-1]` — the behaviour of an adder with
/// saturation logic on a `bits`-wide register.
// Bounds: operands widen to i64 before the add; `bits` is asserted ≤ 31.
#[allow(clippy::arithmetic_side_effects)]
#[inline(always)]
pub fn sat_add(a: i32, b: i32, bits: u32) -> i32 {
    debug_assert!((2..=31).contains(&bits));
    let max = (1i32 << (bits - 1)) - 1;
    (a as i64 + b as i64).clamp(-(max as i64), max as i64) as i32
}

/// Saturate `v` into the `bits`-wide symmetric signed range.
// Bounds: `bits` is a config-validated register width ≤ 31, so the i64
// shift cannot overflow.
#[allow(clippy::arithmetic_side_effects)]
#[inline(always)]
pub fn sat_clamp(v: i64, bits: u32) -> i32 {
    let max = (1i64 << (bits - 1)) - 1;
    v.clamp(-max, max) as i32
}

/// The paper's leak operation: `v - (v >> n)` with arithmetic shift.
///
/// For `v ≥ 0` this decays toward 0 from above; for `v < 0` the arithmetic
/// shift rounds toward −∞ so the result decays toward 0 from below (and
/// reaches exactly 0 from −1 in one step: `-1 - (-1 >> n) = -1 - (-1) = 0`).
// Bounds: `v - (v >> n)` is a contraction toward 0 for every i32 `v` and
// `n ≥ 1` (asserted), so the subtraction cannot overflow.
#[allow(clippy::arithmetic_side_effects)]
#[inline(always)]
pub fn leak(v: i32, n: u32) -> i32 {
    debug_assert!((1..=30).contains(&n));
    v - (v >> n)
}

/// Quantize an `f32` to a `bits`-wide signed integer with
/// round-half-away-from-zero, saturating at the representable range.
/// Used when importing trained weights.
// Bounds: float math cannot panic; the shift width is ≤ 31 by contract
// and the final value is clamped into i32 range.
#[allow(clippy::arithmetic_side_effects)]
#[inline]
pub fn quantize(v: f32, scale: f32, bits: u32) -> i32 {
    let max = (1i32 << (bits - 1)) - 1;
    let min = -(1i32 << (bits - 1));
    let scaled = v * scale;
    let rounded = if scaled >= 0.0 { (scaled + 0.5).floor() } else { (scaled - 0.5).ceil() };
    (rounded as i64).clamp(min as i64, max as i64) as i32
}

/// True iff `v` fits a `bits`-wide two's-complement signed integer.
// Bounds: `bits` is a validated register width ≤ 31.
#[allow(clippy::arithmetic_side_effects)]
#[inline]
pub fn fits_signed(v: i32, bits: u32) -> bool {
    let max = (1i32 << (bits - 1)) - 1;
    let min = -(1i32 << (bits - 1));
    (min..=max).contains(&v)
}

// Test arithmetic is bounded by the generated case ranges.
#[allow(clippy::arithmetic_side_effects)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::PropRunner;

    #[test]
    fn sat_add_clamps_both_ends() {
        let max24 = (1 << 23) - 1;
        assert_eq!(sat_add(max24, 1, 24), max24);
        assert_eq!(sat_add(max24, max24, 24), max24);
        assert_eq!(sat_add(-max24, -1, 24), -max24);
        assert_eq!(sat_add(-max24, -max24, 24), -max24);
        assert_eq!(sat_add(5, 7, 24), 12);
        assert_eq!(sat_add(-5, 7, 24), 2);
    }

    #[test]
    fn leak_decays_toward_zero() {
        // Positive values strictly decrease (until the shift underflows).
        let mut v = 100_000;
        for _ in 0..200 {
            let next = leak(v, 3);
            assert!(next <= v);
            assert!(next >= 0);
            v = next;
        }
        // Negative values strictly increase toward zero and reach it.
        let mut v = -100_000;
        for _ in 0..200 {
            let next = leak(v, 3);
            assert!(next >= v);
            assert!(next <= 0);
            v = next;
        }
        assert_eq!(v, 0, "negative membrane must fully decay to rest");
    }

    #[test]
    fn leak_fixed_points() {
        // Values in [0, 2^n) are fixed points of v - (v>>n) for v>=0: the
        // shift truncates to zero. This mirrors real LIF hardware, where
        // sub-LSB leak is lost to quantization.
        for v in 0..8 {
            assert_eq!(leak(v, 3), v);
        }
        assert_eq!(leak(8, 3), 7);
        // -1 decays to exactly 0 (arithmetic shift of -1 is -1).
        assert_eq!(leak(-1, 3), 0);
    }

    #[test]
    fn quantize_rounds_half_away() {
        assert_eq!(quantize(0.5, 1.0, 9), 1);
        assert_eq!(quantize(-0.5, 1.0, 9), -1);
        assert_eq!(quantize(0.49, 1.0, 9), 0);
        assert_eq!(quantize(1.0, 100.0, 9), 100);
        // Saturation at the 9-bit range [-256, 255].
        assert_eq!(quantize(10.0, 100.0, 9), 255);
        assert_eq!(quantize(-10.0, 100.0, 9), -256);
    }

    #[test]
    fn prop_sat_add_never_escapes_range() {
        PropRunner::new("sat_add_range", 2000).run(|g| {
            let bits = g.rng.range_i32(2, 31) as u32;
            let a = g.rng.range_i32(i32::MIN / 2, i32::MAX / 2);
            let b = g.rng.range_i32(i32::MIN / 2, i32::MAX / 2);
            let r = sat_add(a, b, bits);
            let max = (1i32 << (bits - 1)) - 1;
            assert!(r >= -max && r <= max, "sat_add({a},{b},{bits}) = {r} escapes ±{max}");
        });
    }

    #[test]
    fn prop_leak_is_contraction() {
        PropRunner::new("leak_contraction", 2000).run(|g| {
            let n = g.rng.range_i32(1, 8) as u32;
            let v = g.rng.range_i32(-(1 << 23), 1 << 23);
            let r = leak(v, n);
            assert!(r.abs() <= v.abs(), "leak({v},{n}) = {r} grew in magnitude");
            assert_eq!(r.signum() * v.signum() >= 0, true, "leak changed sign");
        });
    }

    #[test]
    fn prop_quantize_fits() {
        PropRunner::new("quantize_fits", 2000).run(|g| {
            let bits = g.rng.range_i32(2, 16) as u32;
            let v = (g.rng.next_f64() as f32 - 0.5) * 1000.0;
            let scale = (g.rng.next_f64() as f32) * 100.0;
            let q = quantize(v, scale, bits);
            assert!(
                q >= -(1i32 << (bits - 1)) && q <= (1i32 << (bits - 1)) - 1,
                "quantize produced out-of-range {q} for bits={bits}"
            );
        });
    }
}
