//! CSR sparse weight storage for the event-driven engines.
//!
//! Unstructured magnitude pruning leaves most entries of a trained weight
//! matrix at (or near) zero, yet the dense engines still stream every row
//! word through the adder tree. [`SparseWeightLayer`] stores one
//! connection layer in compressed-sparse-row form — per input row, the
//! column indices and values of the entries that survive a magnitude
//! threshold — so the silence-skipping sweeps
//! ([`crate::rtl::RtlCore::run_fast_sparse`] and the sparse arm of
//! `run_fast_batch`) touch only (active input × retained synapse) pairs.
//!
//! The keep predicate is `|w| >= threshold`. **Threshold 0 keeps every
//! entry — including explicit zeros** — so the CSR walk visits exactly
//! the set of (input, output) pairs the dense row walk visits, in the
//! same ascending-column order as the dense adder-tree fanout
//! (`lane_add_row` iterates enabled outputs ascending). That makes the
//! sparse sweep *bit-exact and activity-exact* with the dense fast path
//! at threshold 0: the dense engine counts an add even for a zero
//! weight, and so does the threshold-0 CSR. At threshold ≥ 1, zeros and
//! sub-threshold magnitudes drop out; the saved adds/BRAM pulses appear
//! as naturally lower [`crate::rtl::ActivityCounters`] — the same
//! crediting mechanism the BRAM-gating ablation uses for pruned neurons.

use crate::error::{Error, Result};

use super::weights::{WeightMatrix, WeightStack};

/// One connection layer in CSR form: `row_ptr[i]..row_ptr[i+1]` indexes
/// the retained entries of input row `i` in `col_idx` / `values`
/// (ascending column order within each row).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SparseWeightLayer {
    n_inputs: usize,
    n_outputs: usize,
    bits: u32,
    /// The magnitude threshold the layer was built with (`|w| >= threshold`
    /// kept).
    threshold: i32,
    row_ptr: Vec<u32>,
    col_idx: Vec<u32>,
    values: Vec<i32>,
}

impl SparseWeightLayer {
    /// Build from a dense matrix, keeping every entry with
    /// `|w| >= threshold`. Threshold 0 keeps everything (exact dense
    /// mirror); threshold 1 drops only explicit zeros.
    pub fn from_dense(m: &WeightMatrix, threshold: i32) -> Self {
        assert!(threshold >= 0, "magnitude threshold must be non-negative");
        let (ni, no) = (m.n_inputs(), m.n_outputs());
        let mut row_ptr = Vec::with_capacity(ni + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for i in 0..ni {
            let row = m.row(i);
            for (j, &w) in row.iter().enumerate() {
                if w.abs() >= threshold {
                    col_idx.push(j as u32);
                    values.push(w);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        SparseWeightLayer { n_inputs: ni, n_outputs: no, bits: m.bits(), threshold, row_ptr, col_idx, values }
    }

    pub fn n_inputs(&self) -> usize {
        self.n_inputs
    }
    pub fn n_outputs(&self) -> usize {
        self.n_outputs
    }
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// The magnitude threshold this layer was pruned at.
    pub fn threshold(&self) -> i32 {
        self.threshold
    }

    /// Retained entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Retained fraction of the dense plane, in `[0, 1]`.
    pub fn density(&self) -> f64 {
        if self.n_inputs * self.n_outputs == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.n_inputs * self.n_outputs) as f64
    }

    /// Input row `i`'s retained entries: `(columns, weights)`, ascending
    /// column order — what the event-driven sweep integrates when input
    /// `i` fires. Empty for a fully pruned row (the sweep then skips the
    /// BRAM pulse entirely).
    #[inline(always)]
    pub fn row(&self, i: usize) -> (&[u32], &[i32]) {
        let (a, b) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
        (&self.col_idx[a..b], &self.values[a..b])
    }

    /// Input row `i`'s retained entries restricted to the output-column
    /// range `[j0, j1)`: `(columns, weights)` sub-slices of [`Self::row`]
    /// (columns stay global). Because columns ascend within a row, the
    /// restriction is two binary searches — this is how the
    /// thread-parallel batched sweep partitions one CSR row across
    /// disjoint neuron-range shards without rebuilding the CSR.
    #[inline]
    pub fn row_span(&self, i: usize, j0: usize, j1: usize) -> (&[u32], &[i32]) {
        let (cols, vals) = self.row(i);
        let lo = cols.partition_point(|&c| (c as usize) < j0);
        let hi = cols.partition_point(|&c| (c as usize) < j1);
        (&cols[lo..hi], &vals[lo..hi])
    }

    /// Reconstruct the dense matrix (pruned entries become 0).
    pub fn to_dense(&self) -> WeightMatrix {
        let mut data = vec![0i32; self.n_inputs * self.n_outputs];
        for i in 0..self.n_inputs {
            let (cols, vals) = self.row(i);
            for (&j, &w) in cols.iter().zip(vals) {
                data[i * self.n_outputs + j as usize] = w;
            }
        }
        WeightMatrix::from_rows(self.n_inputs, self.n_outputs, self.bits, data)
            .expect("CSR entries came from a valid dense matrix")
    }

    /// Storage footprint of the CSR image in bytes: packed values at the
    /// weight width plus one `u32` column index per entry and the row
    /// pointer array — the figure the density-crossover analysis trades
    /// against the dense plane.
    pub fn packed_bytes(&self) -> usize {
        (self.nnz() * self.bits as usize + 7) / 8
            + self.col_idx.len() * 4
            + self.row_ptr.len() * 4
    }
}

/// An N-layer chain of [`SparseWeightLayer`]s — the CSR twin of
/// [`WeightStack`], built via [`WeightStack::to_csr`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SparseWeightStack {
    layers: Vec<SparseWeightLayer>,
}

impl SparseWeightStack {
    pub fn from_layers(layers: Vec<SparseWeightLayer>) -> Result<Self> {
        if layers.is_empty() {
            return Err(Error::InvalidConfig("sparse stack needs at least one layer".into()));
        }
        for (l, pair) in layers.windows(2).enumerate() {
            if pair[0].n_outputs() != pair[1].n_inputs() {
                return Err(Error::ShapeMismatch(format!(
                    "sparse layer {l} outputs {} but layer {} expects {} inputs",
                    pair[0].n_outputs(),
                    l + 1,
                    pair[1].n_inputs()
                )));
            }
        }
        Ok(SparseWeightStack { layers })
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn layer(&self, l: usize) -> &SparseWeightLayer {
        &self.layers[l]
    }

    pub fn layers(&self) -> &[SparseWeightLayer] {
        &self.layers
    }

    /// The dimension chain, comparable with [`crate::SnnConfig::topology`].
    pub fn topology(&self) -> Vec<usize> {
        let mut t = Vec::with_capacity(self.layers.len() + 1);
        t.push(self.layers[0].n_inputs());
        for m in &self.layers {
            t.push(m.n_outputs());
        }
        t
    }

    pub fn check_topology(&self, topology: &[usize]) -> Result<()> {
        let mine = self.topology();
        if mine != topology {
            return Err(Error::ShapeMismatch(format!(
                "sparse stack topology {mine:?} vs config topology {topology:?}"
            )));
        }
        Ok(())
    }

    /// Total retained entries.
    pub fn nnz(&self) -> usize {
        self.layers.iter().map(SparseWeightLayer::nnz).sum()
    }

    /// Retained fraction over the whole chain's dense planes.
    pub fn density(&self) -> f64 {
        let dense: usize =
            self.layers.iter().map(|m| m.n_inputs() * m.n_outputs()).sum();
        if dense == 0 {
            return 0.0;
        }
        self.nnz() as f64 / dense as f64
    }

    /// Total CSR storage footprint in bytes.
    pub fn packed_bytes(&self) -> usize {
        self.layers.iter().map(SparseWeightLayer::packed_bytes).sum()
    }

    /// Reconstruct the dense stack (pruned entries become 0).
    pub fn to_dense(&self) -> WeightStack {
        WeightStack::from_layers(self.layers.iter().map(SparseWeightLayer::to_dense).collect())
            .expect("CSR chain came from a valid dense stack")
    }
}

impl WeightStack {
    /// CSR view of this stack under magnitude threshold `threshold`
    /// (keep iff `|w| >= threshold`; see the module docs for the
    /// threshold-0 exactness contract).
    pub fn to_csr(&self, threshold: i32) -> SparseWeightStack {
        SparseWeightStack {
            layers: self
                .layers()
                .iter()
                .map(|m| SparseWeightLayer::from_dense(m, threshold))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::PropRunner;

    fn random_matrix(g: &mut crate::testutil::Gen, ni: usize, no: usize) -> WeightMatrix {
        let data = g.vec_i32(ni * no, -60, 60);
        WeightMatrix::from_rows(ni, no, 9, data).unwrap()
    }

    #[test]
    fn threshold_zero_is_a_full_mirror() {
        PropRunner::new("csr_threshold0_mirror", 50).run(|g| {
            let ni = g.rng.range_i32(1, 40) as usize;
            let no = g.rng.range_i32(1, 16) as usize;
            let m = random_matrix(g, ni, no);
            let sp = SparseWeightLayer::from_dense(&m, 0);
            assert_eq!(sp.nnz(), ni * no, "threshold 0 must keep every entry");
            assert_eq!(sp.density(), 1.0);
            assert_eq!(sp.to_dense(), m, "threshold-0 roundtrip must be lossless");
            // Ascending-column contract inside every row.
            for i in 0..ni {
                let (cols, vals) = sp.row(i);
                assert!(cols.windows(2).all(|w| w[0] < w[1]), "columns must ascend");
                for (&j, &w) in cols.iter().zip(vals) {
                    assert_eq!(w, m.get(i, j as usize));
                }
            }
        });
    }

    #[test]
    fn threshold_prunes_by_magnitude() {
        PropRunner::new("csr_magnitude_prune", 50).run(|g| {
            let ni = g.rng.range_i32(1, 30) as usize;
            let no = g.rng.range_i32(1, 12) as usize;
            let m = random_matrix(g, ni, no);
            let th = g.rng.range_i32(1, 50);
            let sp = SparseWeightLayer::from_dense(&m, th);
            let want: usize =
                m.as_slice().iter().filter(|&&w| w.abs() >= th).count();
            assert_eq!(sp.nnz(), want, "keep predicate must be |w| >= {th}");
            // The reconstructed dense plane zeroes exactly the dropped set.
            let back = sp.to_dense();
            for i in 0..ni {
                for j in 0..no {
                    let w = m.get(i, j);
                    let expect = if w.abs() >= th { w } else { 0 };
                    assert_eq!(back.get(i, j), expect, "entry ({i},{j})");
                }
            }
        });
    }

    #[test]
    fn row_span_partitions_each_row_exactly() {
        PropRunner::new("csr_row_span", 50).run(|g| {
            let ni = g.rng.range_i32(1, 20) as usize;
            let no = g.rng.range_i32(1, 24) as usize;
            let m = random_matrix(g, ni, no);
            let th = g.rng.range_i32(0, 30);
            let sp = SparseWeightLayer::from_dense(&m, th);
            let cut_a = g.rng.range_i32(0, no as i32) as usize;
            let cut_b = g.rng.range_i32(cut_a as i32, no as i32) as usize;
            for i in 0..ni {
                let (cols, vals) = sp.row(i);
                // Any contiguous tiling's spans concatenate back to the row.
                let spans = [(0, cut_a), (cut_a, cut_b), (cut_b, no)];
                let mut got_cols = Vec::new();
                let mut got_vals = Vec::new();
                for &(j0, j1) in &spans {
                    let (c, v) = sp.row_span(i, j0, j1);
                    assert!(
                        c.iter().all(|&c| (c as usize) >= j0 && (c as usize) < j1),
                        "span [{j0}, {j1}) leaked a foreign column"
                    );
                    got_cols.extend_from_slice(c);
                    got_vals.extend_from_slice(v);
                }
                assert_eq!(got_cols, cols, "spans must tile row {i} losslessly");
                assert_eq!(got_vals, vals);
            }
        });
    }

    #[test]
    fn stack_to_csr_tracks_topology_and_density() {
        let a = WeightMatrix::from_rows(4, 3, 9, vec![0, 5, -5, 0, 0, 0, 1, -1, 2, 0, 9, 0]).unwrap();
        let b = WeightMatrix::from_rows(3, 2, 9, vec![0, 7, 0, 0, -3, 0]).unwrap();
        let stack = WeightStack::from_layers(vec![a, b]).unwrap();
        let sp = stack.to_csr(1);
        assert_eq!(sp.topology(), vec![4, 3, 2]);
        sp.check_topology(&[4, 3, 2]).unwrap();
        assert!(sp.check_topology(&[4, 2]).is_err());
        assert_eq!(sp.layer(0).nnz(), 6);
        assert_eq!(sp.layer(1).nnz(), 2);
        assert_eq!(sp.nnz(), 8);
        let dense_entries = (4 * 3 + 3 * 2) as f64;
        assert!((sp.density() - 8.0 / dense_entries).abs() < 1e-12);
        // A fully pruned row reports itself empty — the silence-skip hook.
        let (cols, vals) = sp.layer(0).row(1);
        assert!(cols.is_empty() && vals.is_empty());
        // Heavier threshold is monotonically sparser.
        assert!(stack.to_csr(8).nnz() < sp.nnz());
        assert_eq!(stack.to_csr(0).density(), 1.0);
    }

    #[test]
    fn rejects_broken_chain() {
        let a = SparseWeightLayer::from_dense(&WeightMatrix::zeros(4, 3, 9), 0);
        let b = SparseWeightLayer::from_dense(&WeightMatrix::zeros(4, 2, 9), 0);
        assert!(SparseWeightStack::from_layers(vec![a, b]).is_err());
        assert!(SparseWeightStack::from_layers(vec![]).is_err());
    }
}
