//! The 9-bit weight memory: matrix type and dense bit-packing codec.
//!
//! The paper stores `784 × 10` weights at 9 bits each (§V-B: "optimized
//! 9-bit fixed-point weights (784 × 10 × 9 bits) ... ~8.6 KB"), i.e. the
//! BRAM image is a dense bitstream with no byte padding. [`pack_weights`] /
//! [`unpack_weights`] implement that layout so the simulator's memory
//! footprint accounting matches the silicon figure exactly.

use crate::error::{Error, Result};

/// A row-major `n_inputs × n_outputs` weight matrix in sign-extended i32.
///
/// Row-major by *input* (`w[input][output]`) matches both the BRAM layout
/// (the controller streams pixels, fetching one row of 10 weights per
/// spike) and the JAX weight array layout `W[784, 10]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WeightMatrix {
    n_inputs: usize,
    n_outputs: usize,
    bits: u32,
    data: Vec<i32>,
}

impl WeightMatrix {
    /// Build from a row-major slice; every value must fit `bits`.
    pub fn from_rows(n_inputs: usize, n_outputs: usize, bits: u32, data: Vec<i32>) -> Result<Self> {
        if data.len() != n_inputs * n_outputs {
            return Err(Error::ShapeMismatch(format!(
                "weight data {} != {}x{}",
                data.len(),
                n_inputs,
                n_outputs
            )));
        }
        let max = (1i32 << (bits - 1)) - 1;
        let min = -(1i32 << (bits - 1));
        if let Some(&bad) = data.iter().find(|&&w| w < min || w > max) {
            return Err(Error::InvalidConfig(format!(
                "weight {bad} does not fit signed {bits}-bit range [{min}, {max}]"
            )));
        }
        Ok(WeightMatrix { n_inputs, n_outputs, bits, data })
    }

    /// All-zero matrix (for tests and initialization).
    pub fn zeros(n_inputs: usize, n_outputs: usize, bits: u32) -> Self {
        WeightMatrix { n_inputs, n_outputs, bits, data: vec![0; n_inputs * n_outputs] }
    }

    pub fn n_inputs(&self) -> usize {
        self.n_inputs
    }
    pub fn n_outputs(&self) -> usize {
        self.n_outputs
    }
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Weight for (input `i`, output `j`).
    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> i32 {
        self.data[i * self.n_outputs + j]
    }

    /// The full row of output weights for input `i` — what the hardware
    /// fetches from BRAM when pixel `i` spikes.
    #[inline(always)]
    pub fn row(&self, i: usize) -> &[i32] {
        &self.data[i * self.n_outputs..(i + 1) * self.n_outputs]
    }

    /// Raw row-major storage.
    pub fn as_slice(&self) -> &[i32] {
        &self.data
    }

    /// Column-major copy (`w[output][input]`), used by backends that
    /// iterate neuron-first.
    pub fn transposed(&self) -> Vec<i32> {
        let mut out = vec![0i32; self.data.len()];
        for i in 0..self.n_inputs {
            for j in 0..self.n_outputs {
                out[j * self.n_inputs + i] = self.get(i, j);
            }
        }
        out
    }

    /// Storage footprint of the dense packed image in bytes (rounded up).
    pub fn packed_bytes(&self) -> usize {
        (self.data.len() * self.bits as usize + 7) / 8
    }
}

/// An N-layer chain of weight matrices: one [`WeightMatrix`] per
/// connection of the topology (`stack.layer(l)` maps `topology[l]` inputs
/// to `topology[l+1]` neurons). The single-layer paper core is the
/// degenerate case `n_layers() == 1`, obtainable via
/// `WeightStack::from(matrix)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WeightStack {
    layers: Vec<WeightMatrix>,
}

impl WeightStack {
    /// Build from an ordered layer chain. Adjacent layers must agree on
    /// their shared dimension and every layer must use the same weight
    /// width (one BRAM word geometry per design).
    pub fn from_layers(layers: Vec<WeightMatrix>) -> Result<Self> {
        if layers.is_empty() {
            return Err(Error::InvalidConfig("weight stack needs at least one layer".into()));
        }
        for (l, pair) in layers.windows(2).enumerate() {
            if pair[0].n_outputs() != pair[1].n_inputs() {
                return Err(Error::ShapeMismatch(format!(
                    "layer {l} outputs {} but layer {} expects {} inputs",
                    pair[0].n_outputs(),
                    l + 1,
                    pair[1].n_inputs()
                )));
            }
            if pair[0].bits() != pair[1].bits() {
                return Err(Error::InvalidConfig(format!(
                    "layer {l} uses {}-bit weights but layer {} uses {}-bit",
                    pair[0].bits(),
                    l + 1,
                    pair[1].bits()
                )));
            }
        }
        Ok(WeightStack { layers })
    }

    /// Number of weight layers (connections).
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Layer `l`'s matrix.
    pub fn layer(&self, l: usize) -> &WeightMatrix {
        &self.layers[l]
    }

    /// All layers in order.
    pub fn layers(&self) -> &[WeightMatrix] {
        &self.layers
    }

    /// Input width of the whole chain.
    pub fn n_inputs(&self) -> usize {
        self.layers[0].n_inputs()
    }

    /// Output width of the whole chain.
    pub fn n_outputs(&self) -> usize {
        self.layers[self.layers.len() - 1].n_outputs()
    }

    /// Shared weight width in bits.
    pub fn bits(&self) -> u32 {
        self.layers[0].bits()
    }

    /// The dimension chain `[n_in_0, n_out_0 (= n_in_1), ..., n_out_last]`
    /// — directly comparable with [`crate::SnnConfig::topology`].
    pub fn topology(&self) -> Vec<usize> {
        let mut t = Vec::with_capacity(self.layers.len() + 1);
        t.push(self.layers[0].n_inputs());
        for m in &self.layers {
            t.push(m.n_outputs());
        }
        t
    }

    /// Total dense-packed storage footprint in bytes (sum over layers).
    pub fn packed_bytes(&self) -> usize {
        self.layers.iter().map(WeightMatrix::packed_bytes).sum()
    }

    /// Check this stack against a config's topology; error text names the
    /// first disagreement.
    pub fn check_topology(&self, topology: &[usize]) -> Result<()> {
        let mine = self.topology();
        if mine != topology {
            return Err(Error::ShapeMismatch(format!(
                "weight stack topology {mine:?} vs config topology {topology:?}"
            )));
        }
        Ok(())
    }
}

impl From<WeightMatrix> for WeightStack {
    fn from(m: WeightMatrix) -> Self {
        WeightStack { layers: vec![m] }
    }
}

/// Pack weights into a dense little-endian bitstream, `bits` per weight,
/// two's complement, no padding between entries — the BRAM image.
pub fn pack_weights(m: &WeightMatrix) -> Vec<u8> {
    let bits = m.bits() as usize;
    let mut out = vec![0u8; m.packed_bytes()];
    let mask = (1u32 << bits) - 1;
    let mut bitpos = 0usize;
    for &w in m.as_slice() {
        let raw = (w as u32) & mask; // two's complement truncation
        // Scatter `bits` bits starting at `bitpos` (LSB-first within bytes).
        let mut remaining = bits;
        let mut val = raw;
        let mut pos = bitpos;
        while remaining > 0 {
            let byte = pos / 8;
            let off = pos % 8;
            let take = (8 - off).min(remaining);
            out[byte] |= ((val & ((1 << take) - 1)) as u8) << off;
            val >>= take;
            pos += take;
            remaining -= take;
        }
        bitpos += bits;
    }
    out
}

/// Inverse of [`pack_weights`].
pub fn unpack_weights(
    bytes: &[u8],
    n_inputs: usize,
    n_outputs: usize,
    bits: u32,
) -> Result<WeightMatrix> {
    let n = n_inputs * n_outputs;
    let need = (n * bits as usize + 7) / 8;
    if bytes.len() < need {
        return Err(Error::ShapeMismatch(format!(
            "packed weights too short: {} bytes, need {need}",
            bytes.len()
        )));
    }
    let mut data = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let mut raw = 0u32;
        let mut got = 0usize;
        let mut pos = bitpos;
        while got < bits as usize {
            let byte = pos / 8;
            let off = pos % 8;
            let take = (8 - off).min(bits as usize - got);
            let chunk = (u32::from(bytes[byte]) >> off) & ((1 << take) - 1);
            raw |= chunk << got;
            got += take;
            pos += take;
        }
        bitpos += bits as usize;
        // Sign-extend from `bits` to 32.
        let shift = 32 - bits;
        data.push(((raw << shift) as i32) >> shift);
    }
    WeightMatrix::from_rows(n_inputs, n_outputs, bits, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::PropRunner;

    #[test]
    fn paper_footprint() {
        let m = WeightMatrix::zeros(784, 10, 9);
        // 784*10*9 bits = 70,560 bits = 8,820 bytes ≈ 8.61 KB — the paper's
        // "~8.6 KB".
        assert_eq!(m.packed_bytes(), 8820);
    }

    #[test]
    fn get_row_transposed_agree() {
        let data: Vec<i32> = (0..12).map(|v| v - 6).collect();
        let m = WeightMatrix::from_rows(4, 3, 9, data).unwrap();
        assert_eq!(m.get(0, 0), -6);
        assert_eq!(m.get(3, 2), 5);
        assert_eq!(m.row(1), &[-3, -2, -1]);
        let t = m.transposed();
        for i in 0..4 {
            for j in 0..3 {
                assert_eq!(t[j * 4 + i], m.get(i, j));
            }
        }
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(WeightMatrix::from_rows(1, 1, 9, vec![256]).is_err());
        assert!(WeightMatrix::from_rows(1, 1, 9, vec![-257]).is_err());
        assert!(WeightMatrix::from_rows(1, 1, 9, vec![255]).is_ok());
        assert!(WeightMatrix::from_rows(1, 1, 9, vec![-256]).is_ok());
        assert!(WeightMatrix::from_rows(2, 2, 9, vec![0; 3]).is_err());
    }

    #[test]
    fn pack_roundtrip_simple() {
        let data = vec![0, 1, -1, 255, -256, 100, -100, 42, 7];
        let m = WeightMatrix::from_rows(3, 3, 9, data).unwrap();
        let packed = pack_weights(&m);
        assert_eq!(packed.len(), (9 * 9 + 7) / 8); // 81 bits -> 11 bytes
        let back = unpack_weights(&packed, 3, 3, 9).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn unpack_rejects_truncated() {
        let m = WeightMatrix::zeros(4, 4, 9);
        let packed = pack_weights(&m);
        assert!(unpack_weights(&packed[..packed.len() - 1], 4, 4, 9).is_err());
    }

    #[test]
    fn stack_validates_chain() {
        let a = WeightMatrix::zeros(4, 3, 9);
        let b = WeightMatrix::zeros(3, 2, 9);
        let s = WeightStack::from_layers(vec![a.clone(), b.clone()]).unwrap();
        assert_eq!(s.n_layers(), 2);
        assert_eq!(s.topology(), vec![4, 3, 2]);
        assert_eq!(s.n_inputs(), 4);
        assert_eq!(s.n_outputs(), 2);
        assert_eq!(s.packed_bytes(), a.packed_bytes() + b.packed_bytes());
        s.check_topology(&[4, 3, 2]).unwrap();
        assert!(s.check_topology(&[4, 2]).is_err());
        // Mismatched chain dimension.
        assert!(WeightStack::from_layers(vec![a.clone(), WeightMatrix::zeros(4, 2, 9)]).is_err());
        // Mismatched bit width.
        assert!(WeightStack::from_layers(vec![a, WeightMatrix::zeros(3, 2, 8)]).is_err());
        // Empty stack.
        assert!(WeightStack::from_layers(vec![]).is_err());
    }

    #[test]
    fn stack_from_single_matrix() {
        let m = WeightMatrix::zeros(784, 10, 9);
        let s: WeightStack = m.clone().into();
        assert_eq!(s.n_layers(), 1);
        assert_eq!(s.layer(0), &m);
        assert_eq!(s.topology(), vec![784, 10]);
    }

    #[test]
    fn prop_pack_roundtrip_random() {
        PropRunner::new("weights_pack_roundtrip", 300).run(|g| {
            let bits = g.rng.range_i32(2, 16) as u32;
            let ni = g.rng.range_i32(1, 40) as usize;
            let no = g.rng.range_i32(1, 12) as usize;
            let max = (1i32 << (bits - 1)) - 1;
            let min = -(1i32 << (bits - 1));
            let data = g.vec_i32(ni * no, min, max);
            let m = WeightMatrix::from_rows(ni, no, bits, data).unwrap();
            let back = unpack_weights(&pack_weights(&m), ni, no, bits).unwrap();
            assert_eq!(back, m, "roundtrip mismatch at bits={bits} {ni}x{no}");
        });
    }
}
