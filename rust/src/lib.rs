//! # snn-rtl — Poisson-encoded spiking neural network accelerator
//!
//! Reproduction of *"Biological Intuition on Digital Hardware: An RTL
//! Implementation of Poisson-Encoded SNNs for Static Image Classification"*
//! (Das, Yogeeth G.K., Gupta — CS.AR 2026) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the serving coordinator (router, dynamic batcher,
//!   worker pool, early-exit scheduler), the cycle-accurate RTL-equivalent
//!   simulator of the paper's SystemVerilog core, the behavioral golden
//!   model, the baseline ANN + ESP32 cost model, and every experiment
//!   harness that regenerates the paper's tables and figures.
//! * **L2 (python/compile/model.py)** — the JAX forward pass (a `lax.scan`
//!   of LIF timesteps) AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the LIF layer
//!   step and the on-chip Poisson encoder, lowered inside the L2 graph.
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` plus trained weights and golden traces, and the
//! Rust binary is self-contained afterwards.
//!
//! ## Architectural contract
//!
//! All layers implement the same timestep-level specification (see
//! `DESIGN.md` §4): per timestep, for each neuron *j*
//!
//! 1. integrate `acc_j += Σ_i W[i][j]·S_i[t]` with Poisson spikes
//!    `S_i[t] = pixel_i > (xorshift32_i(t) & 0xFF)`,
//! 2. leak `acc_j -= acc_j >> n` (arithmetic shift),
//! 3. fire & hard-reset when `acc_j ≥ V_th`,
//! 4. optionally gate the neuron off after it has fired (*active pruning*).
//!
//! The RTL simulator ([`rtl`]) refines this to clock-cycle granularity and
//! is proven equivalent to the behavioral model ([`snn`]) by test; the JAX /
//! Pallas path is proven equivalent through golden traces generated at
//! artifact-build time and through live PJRT execution ([`runtime`]).

// The crate is unsafe-free except for the PJRT backend's documented
// `unsafe impl Send for XlaSnn` (runtime/xla_backend.rs), which only
// compiles under the off-by-default `xla` feature — so the default build
// (CI tier-1, the lint gate, every test) proves the absence of unsafe
// code outright.
#![cfg_attr(not(feature = "xla"), forbid(unsafe_code))]

pub mod ann;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod experiments;
pub mod fixed;
pub mod lint;
pub mod plan;
pub mod prng;
pub mod rtl;
pub mod runtime;
pub mod snn;
pub mod testutil;
pub mod util;

pub use config::SnnConfig;
pub use error::{Error, Result};
