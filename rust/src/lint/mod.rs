//! pallas-lint — the repo-invariant static-analysis pass.
//!
//! A hand-rolled, dependency-free analyzer that walks `rust/src` and
//! `rust/tests` and enforces the concurrency and hot-path invariants the
//! serving tier depends on but the compiler cannot see:
//!
//! * **L1 — poison discipline.** No `.lock().unwrap()` / `.lock().expect(`
//!   anywhere: a panic while holding a guard poisons the mutex, and an
//!   unwrap on the next acquire turns one crashed request into a dead
//!   server. Every acquisition goes through [`crate::util::lock_recover`],
//!   whose `unwrap_or_else(PoisonError::into_inner)` shape is invisible to
//!   this rule on purpose.
//! * **L2 — hot-path allocation discipline.** Inside a
//!   `// pallas-lint: hot` … `// pallas-lint: end-hot` fence, no
//!   allocating construct (`Vec::new(`, `vec![`, `.to_vec()`, `.clone()`,
//!   `.collect()`, `String::from(`, `String::new(`, `Box::new(`,
//!   `.to_string()`, `.to_owned()`, `format!`) may appear, except on lines
//!   (or the statement following a standalone comment) carrying
//!   `// pallas-lint: allow(alloc) reason=…` with a non-empty reason.
//! * **L3 — saturation funnel.** In datapath files (paths containing
//!   `src/rtl/`, `src/snn/`, `src/fixed/`), accumulator-plane arithmetic
//!   must flow through the saturating funnels (`sat_add`, `sat_clamp`,
//!   `write_acc`, `write_acc_at`, `leak`): a statement that touches an
//!   `acc` token with a bare `+`/`+=`, or uses `.saturating_add(` /
//!   `.wrapping_add(` directly, is flagged. Index arithmetic inside
//!   `acc[…]` brackets is masked out first, funnel *bodies* and statements
//!   that *mention* a funnel are exempt, and assertions are exempt
//!   (they compare, they don't write).
//! * **L4 — metrics snapshot coherence.** In the file declaring
//!   `pub struct ServerMetrics`: every atomic load inside `fn snapshot`
//!   must use `Ordering::Acquire` (the snapshot's conservation law reads
//!   sinks first and relies on acquire/release pairing), and every
//!   `pub … : AtomicU64` counter must appear both in `MetricsSnapshot`
//!   and in the `snapshot_conservation_under_load` test body — a counter
//!   missing from either is invisible to the conservation cross-check.
//!   The companion publication rule sweeps the *whole* tree: every
//!   `<counter>.fetch_add(` on an inventoried counter must spell
//!   `Ordering::Release` on the same line — the Acquire snapshot only
//!   orders against Release bumps, and one Relaxed publisher (even a
//!   stronger-but-unconventional `AcqRel`/`SeqCst`) silently breaks the
//!   pairing the conservation law leans on.
//! * **L5 — lock-order acyclicity.** `// pallas-lint: lock(NAME)` /
//!   `// pallas-lint: end-lock(NAME)` annotations declare lexical
//!   lock-acquisition regions (LIFO-matched), and
//!   `// pallas-lint: calls-lock(NAME)` declares a cross-file call-chain
//!   edge from every open region without opening one. The union graph of
//!   declared edges must be acyclic; each edge participating in a cycle
//!   is its own finding.
//!
//! The lexer is a real (if small) state machine: string/raw-string/char
//! literals are blanked before any pattern matching, block comments nest,
//! and line comments are captured separately so the directive parser only
//! ever sees comment text. Directives must *start* the comment text.
//!
//! Known-bad fixtures live in `fixtures/*.fixture` (a non-`.rs` extension
//! so the tree walk never lints them) and carry `EXPECT:Lx` markers on
//! the lines each rule must flag; `rust/tests/lint_self.rs` pins both
//! directions — every fixture fires exactly at its markers, and the real
//! tree is clean.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// Rule identifiers. `Directive` ("D0") covers malformed or unknown
/// `pallas-lint:` annotations themselves, so a typo'd directive can never
/// silently disable a rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Rule {
    L1,
    L2,
    L3,
    L4,
    L5,
    Directive,
}

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::L1 => "L1",
            Rule::L2 => "L2",
            Rule::L3 => "L3",
            Rule::L4 => "L4",
            Rule::L5 => "L5",
            Rule::Directive => "D0",
        }
    }

    pub fn from_id(id: &str) -> Option<Rule> {
        match id {
            "L1" => Some(Rule::L1),
            "L2" => Some(Rule::L2),
            "L3" => Some(Rule::L3),
            "L4" => Some(Rule::L4),
            "L5" => Some(Rule::L5),
            "D0" => Some(Rule::Directive),
            _ => None,
        }
    }
}

/// One machine-readable finding: file, 1-indexed line, rule and a trimmed
/// excerpt of the offending code.
#[derive(Clone, Debug)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
    pub excerpt: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {} — {}",
            self.file,
            self.line,
            self.rule.id(),
            self.message,
            self.excerpt
        )
    }
}

/// A declared lock-order edge: while region `from` is open, lock `to` is
/// (or may be, via `calls-lock`) acquired.
#[derive(Clone, Debug)]
pub struct LockEdge {
    pub from: String,
    pub to: String,
    pub file: String,
    pub line: usize,
}

/// Result of analyzing a set of files.
pub struct Analysis {
    pub findings: Vec<Finding>,
    pub files: usize,
    pub lines: usize,
}

// ---------------------------------------------------------------------------
// Lexer: per-line {code, comment} views with literals blanked.
// ---------------------------------------------------------------------------

struct StrippedLine {
    /// Source code with string/char-literal contents and comments replaced
    /// by spaces (quotes kept), so pattern matching never fires inside a
    /// literal.
    code: String,
    /// Text of the line comment on this line (after `//`, `///` or `//!`),
    /// empty if none. Block-comment text is discarded: directives are
    /// line-comment only.
    comment: String,
}

enum LexState {
    Code,
    Str,
    RawStr(usize),
    Block(usize),
}

fn strip_source(src: &str) -> Vec<StrippedLine> {
    let chars: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = LexState::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            lines.push(StrippedLine {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
            });
            i += 1;
            continue;
        }
        match state {
            LexState::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    // Line comment: capture its text (minus the marker)
                    // up to end of line, then resume at the newline.
                    let mut j = i + 2;
                    if chars.get(j) == Some(&'/') || chars.get(j) == Some(&'!') {
                        j += 1;
                    }
                    while j < chars.len() && chars[j] != '\n' {
                        comment.push(chars[j]);
                        j += 1;
                    }
                    i = j;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = LexState::Block(1);
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    state = LexState::Str;
                    i += 1;
                } else if c == 'r'
                    && !prev_is_ident(&code)
                    && raw_str_hashes(&chars, i + 1).is_some()
                {
                    let n = raw_str_hashes(&chars, i + 1).unwrap();
                    code.push('r');
                    for _ in 0..n {
                        code.push('#');
                    }
                    code.push('"');
                    state = LexState::RawStr(n);
                    i += 2 + n;
                } else if c == '\'' {
                    // Char literal vs lifetime. A char literal is `'x'` or
                    // `'\…'`; anything else (`'a`, `'static`) is a
                    // lifetime and only the quote is consumed.
                    if chars.get(i + 1) == Some(&'\\') {
                        code.push('\'');
                        i += 2; // skip the backslash
                        while i < chars.len() && chars[i] != '\'' {
                            code.push(' ');
                            i += 1;
                        }
                        code.push('\'');
                        i += 1;
                    } else if chars.get(i + 2) == Some(&'\'') {
                        code.push('\'');
                        code.push(' ');
                        code.push('\'');
                        i += 3;
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            LexState::Str => {
                if c == '\\' {
                    code.push(' ');
                    if chars.get(i + 1).is_some_and(|&n| n != '\n') {
                        code.push(' ');
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if c == '"' {
                    code.push('"');
                    state = LexState::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            LexState::RawStr(n) => {
                if c == '"' && (0..n).all(|k| chars.get(i + 1 + k) == Some(&'#')) {
                    code.push('"');
                    for _ in 0..n {
                        code.push('#');
                    }
                    state = LexState::Code;
                    i += 1 + n;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            LexState::Block(d) => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = LexState::Block(d + 1);
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    state = if d == 1 { LexState::Code } else { LexState::Block(d - 1) };
                    i += 2;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        lines.push(StrippedLine { code, comment });
    }
    lines
}

fn prev_is_ident(code: &str) -> bool {
    code.chars().next_back().is_some_and(|c| c.is_alphanumeric() || c == '_')
}

/// If `chars[at..]` starts a raw-string opener tail (`#*"`), the number of
/// hashes; `None` otherwise.
fn raw_str_hashes(chars: &[char], at: usize) -> Option<usize> {
    let mut n = 0;
    while chars.get(at + n) == Some(&'#') {
        n += 1;
    }
    (chars.get(at + n) == Some(&'"')).then_some(n)
}

// ---------------------------------------------------------------------------
// Directives.
// ---------------------------------------------------------------------------

enum Directive {
    Hot,
    EndHot,
    /// `allow(alloc)`; true iff a non-empty `reason=` was given.
    AllowAlloc(bool),
    Lock(String),
    EndLock(String),
    CallsLock(String),
    Malformed(String),
}

const DIRECTIVE_PREFIX: &str = "pallas-lint:";

fn parse_directive(comment: &str) -> Option<Directive> {
    let t = comment.trim();
    let rest = t.strip_prefix(DIRECTIVE_PREFIX)?.trim_start();
    if rest == "hot" || rest.starts_with("hot ") {
        return Some(Directive::Hot);
    }
    if rest == "end-hot" || rest.starts_with("end-hot ") {
        return Some(Directive::EndHot);
    }
    if let Some(tail) = rest.strip_prefix("allow(alloc)") {
        let reason_ok = tail
            .trim_start()
            .strip_prefix("reason=")
            .is_some_and(|r| !r.trim().is_empty());
        return Some(Directive::AllowAlloc(reason_ok));
    }
    for (prefix, make) in [
        ("calls-lock(", Directive::CallsLock as fn(String) -> Directive),
        ("end-lock(", Directive::EndLock as fn(String) -> Directive),
        ("lock(", Directive::Lock as fn(String) -> Directive),
    ] {
        if let Some(tail) = rest.strip_prefix(prefix) {
            return Some(match tail.split_once(')') {
                Some((name, _)) if !name.trim().is_empty() => make(name.trim().to_string()),
                _ => Directive::Malformed(t.to_string()),
            });
        }
    }
    Some(Directive::Malformed(t.to_string()))
}

// ---------------------------------------------------------------------------
// Statement fragments (for L1/L3): code joined across lines, split on
// `;`, `{`, `}`, each fragment remembering its starting line and closing
// delimiter.
// ---------------------------------------------------------------------------

struct Fragment {
    text: String,
    start_line: usize,
    delim: char,
}

fn fragments(lines: &[StrippedLine]) -> Vec<Fragment> {
    let mut out = Vec::new();
    let mut text = String::new();
    let mut start_line = 0usize;
    for (idx, line) in lines.iter().enumerate() {
        for c in line.code.chars() {
            if text.trim().is_empty() && !c.is_whitespace() {
                start_line = idx + 1;
                text.clear();
            }
            if c == ';' || c == '{' || c == '}' {
                out.push(Fragment { text: std::mem::take(&mut text), start_line, delim: c });
            } else {
                text.push(c);
            }
        }
        text.push(' ');
    }
    if !text.trim().is_empty() {
        out.push(Fragment { text, start_line, delim: ' ' });
    }
    out
}

fn excerpt_of(s: &str) -> String {
    let t = s.split_whitespace().collect::<Vec<_>>().join(" ");
    if t.len() <= 80 {
        return t;
    }
    let mut cut = 77;
    while !t.is_char_boundary(cut) {
        cut -= 1;
    }
    format!("{}…", &t[..cut])
}

/// True iff `needle` occurs in `hay` with non-word characters (or the
/// boundary) on both sides.
fn word_present(hay: &str, needle: &str) -> bool {
    let hb = hay.as_bytes();
    let mut from = 0;
    while let Some(pos) = hay[from..].find(needle) {
        let s = from + pos;
        let e = s + needle.len();
        let left_ok = s == 0 || !is_word(hb[s - 1]);
        let right_ok = e >= hb.len() || !is_word(hb[e]);
        if left_ok && right_ok {
            return true;
        }
        from = s + 1;
        while from < hay.len() && !hay.is_char_boundary(from) {
            from += 1;
        }
    }
    false
}

fn is_word(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

// ---------------------------------------------------------------------------
// Rules.
// ---------------------------------------------------------------------------

const L1_PATTERNS: [&str; 2] = [".lock().unwrap()", ".lock().expect("];

const L2_PATTERNS: [&str; 11] = [
    "Vec::new(",
    "vec![",
    ".to_vec()",
    ".clone()",
    ".collect()",
    "String::from(",
    "String::new(",
    "Box::new(",
    ".to_string()",
    ".to_owned()",
    "format!",
];

/// Datapath path markers for L3.
const L3_PATH_MARKERS: [&str; 3] = ["src/rtl/", "src/snn/", "src/fixed/"];

/// Statements mentioning any of these (word-bounded) are sanctioned
/// saturation funnels or funnel call sites.
const L3_FUNNEL_MENTIONS: [&str; 5] =
    ["sat_add", "sat_clamp", "write_acc", "write_acc_at", "leak"];

/// Function bodies exempt from L3 (they *implement* the funnels).
const L3_FUNNEL_FNS: [&str; 5] =
    ["fn sat_add(", "fn sat_clamp(", "fn write_acc(", "fn write_acc_at(", "fn leak("];

/// Blank the interior of every word-bounded `acc[…]` index expression so
/// index arithmetic (`acc[j * lanes + b]`) never reads as accumulator
/// arithmetic.
fn mask_acc_indices(frag: &str) -> String {
    let b: Vec<char> = frag.chars().collect();
    let mut out: Vec<char> = b.clone();
    let mut i = 0;
    while i + 3 < b.len() {
        let bounded = b[i] == 'a'
            && b.get(i + 1) == Some(&'c')
            && b.get(i + 2) == Some(&'c')
            && (i == 0 || !(b[i - 1].is_alphanumeric() || b[i - 1] == '_'))
            && b.get(i + 3) == Some(&'[');
        if bounded {
            let mut depth = 1usize;
            let mut j = i + 4;
            while j < b.len() && depth > 0 {
                match b[j] {
                    '[' => depth += 1,
                    ']' => depth -= 1,
                    _ => {}
                }
                if depth > 0 {
                    out[j] = '#';
                }
                j += 1;
            }
            i = j;
        } else {
            i += 1;
        }
    }
    out.into_iter().collect()
}

// ---------------------------------------------------------------------------
// Per-file analysis.
// ---------------------------------------------------------------------------

/// Analyze one file's source. Pushes findings and declared lock edges;
/// L5 cycle detection runs later over the union of all files' edges
/// (see [`check_lock_graph`]).
pub fn analyze_source(
    path: &str,
    src: &str,
    findings: &mut Vec<Finding>,
    edges: &mut Vec<LockEdge>,
) -> usize {
    let lines = strip_source(src);
    let f = |rule: Rule, line: usize, message: String, excerpt: String| Finding {
        file: path.to_string(),
        line,
        rule,
        message,
        excerpt,
    };

    // --- Pass A: line-oriented (directives, hot fences, L2). -------------
    let mut hot_open: Option<usize> = None;
    let mut pending_allow = false;
    let mut open_locks: Vec<(String, usize)> = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let mut line_allowed = false;
        match parse_directive(&line.comment) {
            Some(Directive::Hot) => {
                if hot_open.is_some() {
                    findings.push(f(
                        Rule::L2,
                        lineno,
                        "nested hot fence".into(),
                        excerpt_of(line.comment.trim()),
                    ));
                }
                hot_open = Some(lineno);
            }
            Some(Directive::EndHot) => {
                if hot_open.is_none() {
                    findings.push(f(
                        Rule::L2,
                        lineno,
                        "end-hot without an open hot fence".into(),
                        excerpt_of(line.comment.trim()),
                    ));
                }
                hot_open = None;
            }
            Some(Directive::AllowAlloc(reason_ok)) => {
                if !reason_ok {
                    findings.push(f(
                        Rule::L2,
                        lineno,
                        "allow(alloc) requires a non-empty reason=".into(),
                        excerpt_of(line.comment.trim()),
                    ));
                } else if line.code.trim().is_empty() {
                    // Standalone: waives the whole following statement.
                    pending_allow = true;
                } else {
                    line_allowed = true;
                }
            }
            Some(Directive::Lock(name)) => {
                for (open, _) in &open_locks {
                    edges.push(LockEdge {
                        from: open.clone(),
                        to: name.clone(),
                        file: path.to_string(),
                        line: lineno,
                    });
                }
                open_locks.push((name, lineno));
            }
            Some(Directive::EndLock(name)) => match open_locks.pop() {
                Some((top, _)) if top == name => {}
                Some((top, opened)) => {
                    findings.push(f(
                        Rule::L5,
                        lineno,
                        format!("end-lock({name}) closes lock({top}) opened at line {opened}"),
                        excerpt_of(line.comment.trim()),
                    ));
                }
                None => {
                    findings.push(f(
                        Rule::L5,
                        lineno,
                        format!("end-lock({name}) without an open lock region"),
                        excerpt_of(line.comment.trim()),
                    ));
                }
            },
            Some(Directive::CallsLock(name)) => {
                for (open, _) in &open_locks {
                    edges.push(LockEdge {
                        from: open.clone(),
                        to: name.clone(),
                        file: path.to_string(),
                        line: lineno,
                    });
                }
            }
            Some(Directive::Malformed(text)) => {
                findings.push(f(
                    Rule::Directive,
                    lineno,
                    "unknown or malformed pallas-lint directive".into(),
                    excerpt_of(&text),
                ));
            }
            None => {}
        }

        let code = line.code.trim();
        if !code.is_empty() {
            if pending_allow {
                line_allowed = true;
                if code.ends_with(';') || code.ends_with('{') || code.ends_with('}') {
                    pending_allow = false;
                }
            }
            if hot_open.is_some() && !line_allowed {
                let hits: Vec<&str> = L2_PATTERNS
                    .iter()
                    .copied()
                    .filter(|p| line.code.contains(*p))
                    .collect();
                if !hits.is_empty() {
                    findings.push(f(
                        Rule::L2,
                        lineno,
                        format!("allocation in hot fence: {}", hits.join(", ")),
                        excerpt_of(code),
                    ));
                }
            }
        }
    }
    if let Some(opened) = hot_open {
        findings.push(f(Rule::L2, opened, "hot fence never closed".into(), String::new()));
    }
    for (name, opened) in open_locks {
        findings.push(f(
            Rule::L5,
            opened,
            format!("lock({name}) region never closed"),
            String::new(),
        ));
    }

    // --- Pass B: statement fragments (L1, L3). ---------------------------
    let datapath = L3_PATH_MARKERS.iter().any(|m| path.contains(m));
    let mut depth = 0usize;
    let mut funnel_body: Option<usize> = None;
    for frag in fragments(&lines) {
        let squashed: String = frag.text.chars().filter(|c| !c.is_whitespace()).collect();
        for p in L1_PATTERNS {
            if squashed.contains(p) {
                findings.push(f(
                    Rule::L1,
                    frag.start_line,
                    format!("direct mutex unwrap ({p}); use util::lock_recover"),
                    excerpt_of(&frag.text),
                ));
            }
        }
        if datapath && funnel_body.is_none() {
            l3_check(path, &frag, &squashed, findings);
        }
        match frag.delim {
            '{' => {
                let funnel_sig = L3_FUNNEL_FNS.iter().any(|s| {
                    let sq: String = s.chars().filter(|c| !c.is_whitespace()).collect();
                    squashed.contains(&sq)
                });
                if funnel_body.is_none() && funnel_sig {
                    funnel_body = Some(depth);
                }
                depth += 1;
            }
            '}' => {
                depth = depth.saturating_sub(1);
                if funnel_body == Some(depth) {
                    funnel_body = None;
                }
            }
            _ => {}
        }
    }

    // --- Pass C: L4, only in the file declaring ServerMetrics. -----------
    if lines.iter().any(|l| l.code.contains("pub struct ServerMetrics")) {
        l4_check(path, &lines, findings);
    }
    lines.len()
}

fn l3_check(path: &str, frag: &Fragment, squashed: &str, findings: &mut Vec<Finding>) {
    let f = |line: usize, message: String, excerpt: String| Finding {
        file: path.to_string(),
        line,
        rule: Rule::L3,
        message,
        excerpt,
    };
    // Assertions compare accumulator state, they don't write it.
    if frag.text.contains("assert") {
        return;
    }
    for p in [".saturating_add(", ".wrapping_add("] {
        if squashed.contains(p) {
            findings.push(f(
                frag.start_line,
                format!("direct {p}…) in datapath; use the sat_add/write_acc funnels"),
                excerpt_of(&frag.text),
            ));
            return;
        }
    }
    if L3_FUNNEL_MENTIONS.iter().any(|m| word_present(&frag.text, m)) {
        return;
    }
    let masked = mask_acc_indices(&frag.text);
    if word_present(&masked, "acc") && masked.contains('+') {
        findings.push(f(
            frag.start_line,
            "bare + on an accumulator outside the saturation funnels".into(),
            excerpt_of(&frag.text),
        ));
    }
}

/// Brace-matched body of the item whose opening `{` is at or after
/// `lines[start]`: returns (first_line_idx, last_line_idx) inclusive, in
/// 0-indexed line indices.
fn body_range(lines: &[StrippedLine], start: usize) -> Option<(usize, usize)> {
    let mut depth = 0usize;
    let mut opened = false;
    for (idx, line) in lines.iter().enumerate().skip(start) {
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    if opened && depth == 0 {
                        return Some((start, idx));
                    }
                }
                _ => {}
            }
        }
    }
    None
}

fn find_line(lines: &[StrippedLine], pat: &str) -> Option<usize> {
    lines.iter().position(|l| l.code.contains(pat))
}

fn body_text(lines: &[StrippedLine], range: (usize, usize)) -> String {
    let mut s = String::new();
    for l in &lines[range.0..=range.1] {
        s.push_str(&l.code);
        s.push('\n');
    }
    s
}

/// Counter inventory of a stripped file: the `pub NAME: AtomicU64` fields
/// of its `pub struct ServerMetrics` body, with declaration lines. Empty
/// when the file declares no `ServerMetrics`.
fn server_metrics_counters(lines: &[StrippedLine]) -> Vec<(String, usize)> {
    let Some(metrics_at) = find_line(lines, "pub struct ServerMetrics") else {
        return Vec::new();
    };
    let mut counters: Vec<(String, usize)> = Vec::new();
    if let Some(range) = body_range(lines, metrics_at) {
        for idx in range.0..=range.1 {
            let code = lines[idx].code.trim();
            if let Some(rest) = code.strip_prefix("pub ") {
                if let Some((name, ty)) = rest.split_once(':') {
                    if ty.contains("AtomicU64") {
                        counters.push((name.trim().to_string(), idx + 1));
                    }
                }
            }
        }
    }
    counters
}

fn l4_check(path: &str, lines: &[StrippedLine], findings: &mut Vec<Finding>) {
    let f = |line: usize, message: String, excerpt: String| Finding {
        file: path.to_string(),
        line,
        rule: Rule::L4,
        message,
        excerpt,
    };

    // Counter inventory from the ServerMetrics body.
    let metrics_at = find_line(lines, "pub struct ServerMetrics").unwrap_or(0);
    let counters = server_metrics_counters(lines);

    // L4a: every atomic load in `fn snapshot` must be Acquire.
    if let Some(snap_at) = find_line(lines, "fn snapshot(") {
        if let Some(range) = body_range(lines, snap_at) {
            for idx in range.0..=range.1 {
                let code = &lines[idx].code;
                if code.contains(".load(") && !code.contains("Acquire") {
                    findings.push(f(
                        idx + 1,
                        "non-Acquire atomic load in snapshot path".into(),
                        excerpt_of(code.trim()),
                    ));
                }
            }
        }
    }

    // L4b: every counter must surface in MetricsSnapshot and be exercised
    // by the conservation test.
    let snap_struct = find_line(lines, "struct MetricsSnapshot")
        .and_then(|at| body_range(lines, at))
        .map(|r| body_text(lines, r));
    let cons_test = find_line(lines, "fn snapshot_conservation_under_load")
        .and_then(|at| body_range(lines, at))
        .map(|r| body_text(lines, r));
    if snap_struct.is_none() {
        findings.push(f(
            metrics_at + 1,
            "ServerMetrics declared but MetricsSnapshot struct not found in this file".into(),
            String::new(),
        ));
    }
    if cons_test.is_none() {
        findings.push(f(
            metrics_at + 1,
            "ServerMetrics declared but snapshot_conservation_under_load test not found".into(),
            String::new(),
        ));
    }
    for (name, lineno) in &counters {
        if let Some(body) = &snap_struct {
            if !word_present(body, name) {
                findings.push(f(
                    *lineno,
                    format!("counter {name} missing from MetricsSnapshot"),
                    String::new(),
                ));
            }
        }
        if let Some(body) = &cons_test {
            if !word_present(body, name) {
                findings.push(f(
                    *lineno,
                    format!("counter {name} not exercised by snapshot_conservation_under_load"),
                    String::new(),
                ));
            }
        }
    }
}

/// L4's publication half, swept over every file (counter bumps live on
/// the request path, not in the metrics module): a line bumping an
/// inventoried counter via `<counter>.fetch_add(` must spell
/// `Ordering::Release` on that line. Line-oriented like the snapshot
/// check — every real site keeps the call on one line. The left word
/// boundary keeps fields that merely *end* with a counter's name (e.g.
/// `resubmitted`) out of scope.
fn l4_release_check(
    path: &str,
    lines: &[StrippedLine],
    counters: &[String],
    findings: &mut Vec<Finding>,
) {
    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        if !code.contains(".fetch_add(") {
            continue;
        }
        for name in counters {
            let pat = format!("{name}.fetch_add(");
            let mut from = 0;
            let mut bounded = false;
            while let Some(pos) = code[from..].find(&pat) {
                let s = from + pos;
                if s == 0 || !is_word(code.as_bytes()[s - 1]) {
                    bounded = true;
                    break;
                }
                from = s + 1;
                while from < code.len() && !code.is_char_boundary(from) {
                    from += 1;
                }
            }
            if bounded && !code.contains("Release") {
                findings.push(Finding {
                    file: path.to_string(),
                    line: idx + 1,
                    rule: Rule::L4,
                    message: format!(
                        "counter {name} published without Ordering::Release; \
                         the Acquire snapshot cannot order against it"
                    ),
                    excerpt: excerpt_of(code.trim()),
                });
                break;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// L5: cycle detection over the union lock graph.
// ---------------------------------------------------------------------------

/// Flag every declared edge that participates in a cycle of the union
/// graph (one finding per edge, pinned at the edge's declaration site).
pub fn check_lock_graph(edges: &[LockEdge], findings: &mut Vec<Finding>) {
    let reaches = |from: &str, to: &str| -> bool {
        let mut stack = vec![from];
        let mut seen: Vec<&str> = Vec::new();
        while let Some(n) = stack.pop() {
            if n == to {
                return true;
            }
            if seen.contains(&n) {
                continue;
            }
            seen.push(n);
            for e in edges {
                if e.from == n {
                    stack.push(&e.to);
                }
            }
        }
        false
    };
    for e in edges {
        if reaches(&e.to, &e.from) {
            findings.push(Finding {
                file: e.file.clone(),
                line: e.line,
                rule: Rule::L5,
                message: format!("lock edge {} -> {} participates in a cycle", e.from, e.to),
                excerpt: String::new(),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Whole-tree entry points.
// ---------------------------------------------------------------------------

/// Analyze an explicit set of `(path_label, source)` pairs, running the
/// cross-file checks (the lock graph and the counter-publication sweep)
/// at the end. This is the pure core used by both the tree walk and the
/// fixture self-tests.
pub fn analyze_files<'a>(files: impl IntoIterator<Item = (&'a str, &'a str)>) -> Analysis {
    let files: Vec<(&str, &str)> = files.into_iter().collect();
    let mut findings = Vec::new();
    let mut edges = Vec::new();
    let mut n_lines = 0usize;
    for (path, src) in &files {
        n_lines += analyze_source(path, src, &mut findings, &mut edges);
    }
    check_lock_graph(&edges, &mut findings);
    // L4 publication sweep: the inventory comes from whichever analyzed
    // file declares `pub struct ServerMetrics`; the bumps live anywhere.
    let stripped: Vec<(&str, Vec<StrippedLine>)> =
        files.iter().map(|(p, s)| (*p, strip_source(s))).collect();
    let mut counters: Vec<String> = Vec::new();
    for (_, lines) in &stripped {
        for (name, _) in server_metrics_counters(lines) {
            if !counters.contains(&name) {
                counters.push(name);
            }
        }
    }
    if !counters.is_empty() {
        for (path, lines) in &stripped {
            l4_release_check(path, lines, &counters, &mut findings);
        }
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Analysis { findings, files: files.len(), lines: n_lines }
}

/// Walk `rust/src` and `rust/tests` under `root` (the repo root) and
/// analyze every `.rs` file. Fixtures use the `.fixture` extension so the
/// walk never sees them; the walk is sorted for deterministic output.
pub fn analyze_tree(root: &Path) -> std::io::Result<Analysis> {
    let mut paths: Vec<PathBuf> = Vec::new();
    for sub in ["rust/src", "rust/tests"] {
        collect_rs(&root.join(sub), &mut paths)?;
    }
    paths.sort();
    let mut sources = Vec::new();
    for p in &paths {
        let label = p.strip_prefix(root).unwrap_or(p).to_string_lossy().replace('\\', "/");
        sources.push((label, fs::read_to_string(p)?));
    }
    Ok(analyze_files(sources.iter().map(|(l, s)| (l.as_str(), s.as_str()))))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Embedded known-bad fixtures.
// ---------------------------------------------------------------------------

/// The known-bad fixtures, as `(virtual_path, source)` pairs. Virtual
/// paths place each fixture in the directory whose rules it exercises
/// (L3 needs a datapath path, L4 a coordinator one). `EXPECT:Lx` markers
/// inside pin the exact line each rule must flag.
pub fn fixtures() -> Vec<(&'static str, &'static str)> {
    vec![
        ("rust/src/coordinator/fixture_l1.rs", include_str!("fixtures/l1_lock_unwrap.fixture")),
        ("rust/src/rtl/fixture_l2.rs", include_str!("fixtures/l2_hot_alloc.fixture")),
        ("rust/src/rtl/fixture_l3.rs", include_str!("fixtures/l3_sat_funnel.fixture")),
        ("rust/src/coordinator/fixture_l4.rs", include_str!("fixtures/l4_metrics.fixture")),
        ("rust/src/coordinator/fixture_l4r.rs", include_str!("fixtures/l4_release.fixture")),
        ("rust/src/coordinator/fixture_l5.rs", include_str!("fixtures/l5_lock_cycle.fixture")),
    ]
}

/// Parse the `EXPECT:Lx` markers of a fixture into the expected
/// `(line, rule)` set.
pub fn expected_findings(src: &str) -> Vec<(usize, Rule)> {
    let mut out = Vec::new();
    for (idx, line) in src.lines().enumerate() {
        let mut rest = line;
        while let Some(pos) = rest.find("EXPECT:") {
            let id = &rest[pos + 7..];
            let id: String = id.chars().take_while(|c| c.is_ascii_alphanumeric()).collect();
            if let Some(rule) = Rule::from_id(&id) {
                out.push((idx + 1, rule));
            }
            rest = &rest[pos + 7..];
        }
    }
    out.sort();
    out.dedup();
    out
}
