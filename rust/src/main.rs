//! `snn-rtl` — leader binary: experiments, classification, serving demo.
//!
//! ```text
//! snn-rtl experiment <id|all> [--artifacts DIR] [--results DIR] [--samples N]
//! snn-rtl classify  [--class C] [--index I] [--seed S] [--backend b]
//! snn-rtl serve     [--requests N] [--workers W] [--batch B] [--backend b]
//!                   [--early-margin M]
//! snn-rtl info      [--artifacts DIR]
//! ```
//!
//! Backends: `behavioral` (pure-Rust golden model), `rtl` (cycle-accurate
//! core), `xla` (AOT JAX/Pallas via PJRT).

use std::sync::Arc;
use std::time::Instant;

use snn_rtl::cli::Args;
use snn_rtl::coordinator::{
    Backend, BatchPolicy, BehavioralBackend, Coordinator, CoordinatorConfig,
    FanoutPolicy, Request, RtlBackend, SupervisionPolicy, XlaBackend,
};
use snn_rtl::data::{codec, DigitGen};
use snn_rtl::experiments::{self, Ctx};
use snn_rtl::runtime::{Manifest, XlaSnn};
use snn_rtl::snn::EarlyExit;

/// Binary-level result: any error bubbles up as a readable message
/// (`anyhow` is not in the offline crate set).
type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let Some(cmd) = args.positional.first().map(String::as_str) else {
        print_usage();
        return Ok(());
    };
    match cmd {
        "experiment" => cmd_experiment(&args),
        "classify" => cmd_classify(&args),
        "serve" => cmd_serve(&args),
        "info" => cmd_info(&args),
        "help" | "--help" => {
            print_usage();
            Ok(())
        }
        other => Err(format!("unknown command {other:?}; run `snn-rtl help`").into()),
    }
}

fn print_usage() {
    println!(
        "snn-rtl — Poisson-encoded SNN accelerator (paper reproduction)\n\n\
         commands:\n  \
         experiment <id|all>   regenerate a paper table/figure \n                        \
         (table1 fig4 fig5 fig6 fig7 table2 fig8\n                        \
         ablation-pruning ablation-decay ablation-modes ablation-depth\n                        \
         ablation-sparsity)\n  \
         classify              classify one synthetic digit\n  \
         serve                 run the serving coordinator demo\n  \
         info                  show artifact calibration\n\n\
         common flags: --artifacts DIR (default artifacts/)\n               \
         --results DIR (default results/)   --samples N"
    );
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args.positional.get(1).cloned().unwrap_or_else(|| "all".to_string());
    let artifacts = args.str_or("artifacts", "artifacts");
    let results = args.str_or("results", "results");
    let samples = args.num_or("samples", 0usize)?;
    args.check_unknown()?;
    let mut ctx = Ctx::load(&artifacts, &results).map_err(|e| {
        format!("loading artifacts from {artifacts}/ (run `make artifacts`): {e}")
    })?;
    if samples > 0 {
        ctx.samples = Some(samples);
    }
    experiments::run(&id, &ctx)?;
    Ok(())
}

fn cmd_classify(args: &Args) -> Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    let class = args.num_or("class", 3u8)?;
    let index = args.num_or("index", 0u32)?;
    let seed = args.num_or("seed", 0xC0FFEEu32)?;
    let backend_name = args.str_or("backend", "behavioral");
    args.check_unknown()?;

    let manifest = Manifest::load(&artifacts)?;
    let img = DigitGen::new(manifest.u32("test_seed").unwrap_or(2)).sample(class, index);
    println!("{}", img.to_ascii());
    let backend = make_backend(&backend_name, &artifacts)?;
    let t0 = Instant::now();
    let out = backend.classify_batch(&[&img], &[seed], EarlyExit::Off)?;
    let dt = t0.elapsed();
    let o = &out[0];
    println!(
        "backend={} predicted={} (true {}) counts={:?} steps={} wall={:?}",
        backend.name(),
        o.class,
        class,
        o.spike_counts,
        o.steps_run,
        dt
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    let requests = args.num_or("requests", 512usize)?;
    let workers = args.num_or("workers", 2usize)?;
    let batch = args.num_or("batch", 8usize)?;
    let backend_name = args.str_or("backend", "behavioral");
    let early_margin = args.num_or("early-margin", 0u32)?;
    args.check_unknown()?;

    let backend = make_backend(&backend_name, &artifacts)?;
    let early = if early_margin > 0 {
        EarlyExit::Margin { margin: early_margin, min_steps: 2 }
    } else {
        EarlyExit::Off
    };
    let coord = Coordinator::start(
        backend,
        CoordinatorConfig {
            workers,
            queue_depth: 1024,
            batch: BatchPolicy { max_batch: batch, ..Default::default() },
            early,
            fanout: FanoutPolicy::default(),
            supervision: SupervisionPolicy::default(),
        },
    );
    let handle = coord.handle();

    println!("serving {requests} requests (backend={backend_name}, workers={workers}, batch={batch}) ...");
    let gen = DigitGen::new(2);
    let t0 = Instant::now();
    let mut receivers = Vec::with_capacity(requests);
    let mut correct_labels = Vec::with_capacity(requests);
    for i in 0..requests {
        let class = (i % 10) as u8;
        let img = gen.sample(class, (i / 10) as u32);
        correct_labels.push(class);
        receivers.push(handle.submit(Request::new(img).with_seed(i as u32 + 1))?);
    }
    let mut hits = 0usize;
    for (rx, label) in receivers.into_iter().zip(correct_labels) {
        let resp = rx.recv().map_err(|_| "worker dropped reply")??;
        if resp.class == label {
            hits += 1;
        }
    }
    let wall = t0.elapsed();
    let snap = coord.metrics().snapshot();
    println!(
        "done in {wall:?}: {:.0} req/s, accuracy {:.2}%",
        requests as f64 / wall.as_secs_f64(),
        hits as f64 / requests as f64 * 100.0
    );
    println!(
        "latency µs: p50 {} p95 {} p99 {} mean {:.0} max {}",
        snap.latency_p50_us,
        snap.latency_p95_us,
        snap.latency_p99_us,
        snap.latency_mean_us,
        snap.latency_max_us
    );
    println!(
        "batches {} (mean size {:.2}), steps executed {} ({:.2}/req)",
        snap.batches,
        snap.mean_batch_size,
        snap.steps_executed,
        snap.steps_executed as f64 / requests as f64
    );
    coord.shutdown();
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    args.check_unknown()?;
    let manifest = Manifest::load(&artifacts)?;
    let cfg = manifest.snn_config()?;
    let w = codec::load_weights(manifest.path("weights.bin"))?;
    println!("artifacts: {}", manifest.dir.display());
    println!("config: {cfg:#?}");
    println!(
        "weights: {}x{} at {} bits = {:.2} KB packed",
        w.weights.n_inputs(),
        w.weights.n_outputs(),
        w.weights.bits(),
        w.weights.packed_bytes() as f64 / 1024.0
    );
    for key in ["snn_test_acc_t10", "ann_test_acc"] {
        if let Ok(v) = manifest.f64(key) {
            println!("{key} = {v:.4}");
        }
    }
    Ok(())
}

fn make_backend(name: &str, artifacts: &str) -> Result<Arc<dyn Backend>> {
    let manifest = Manifest::load(artifacts).map_err(|e| {
        format!("loading {artifacts}/manifest.txt (run `make artifacts`): {e}")
    })?;
    let cfg = manifest.snn_config()?;
    let weights = codec::load_weights(manifest.path("weights.bin"))?;
    Ok(match name {
        "behavioral" => Arc::new(BehavioralBackend::new(cfg, weights.weights)?),
        "rtl" => match manifest.sparse_threshold()? {
            Some(t) => Arc::new(RtlBackend::with_sparse(cfg, weights.weights, t)?),
            None => Arc::new(RtlBackend::new(cfg, weights.weights)?),
        },
        "xla" => Arc::new(XlaBackend::new(XlaSnn::load(artifacts)?)),
        other => return Err(format!("unknown backend {other:?} (behavioral|rtl|xla)").into()),
    })
}
