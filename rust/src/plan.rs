//! Cache-aware lane-chunk planning for the batched engines.
//!
//! The wide-lane sweeps ([`crate::rtl::RtlCore::run_fast_batch`] and the
//! behavioral [`crate::snn::LifBatchStack`]) process a sub-batch in
//! chunks of up to [`MAX_LANES`] images. With neuron-major state planes
//! a chunk's hot working set per layer is `lanes × n_out` accumulator
//! words plus the same-shape spike-count plane, so on wide hidden layers
//! (784→512→10) a fixed 256-lane chunk blows past L2 and the row sweep
//! thrashes. [`ChunkPlan`] picks the lane width per topology the same
//! way `FanoutPolicy::calibrated` picks the fan-out crossover: a pure
//! decision function ([`ChunkPlan::from_budget`]) over a measured
//! constant ([`DEFAULT_L2_BUDGET`]), so the policy is deterministic and
//! unit-testable while the constant stays an explicit calibration knob.
//!
//! This module is also the single source of truth for the lane-width
//! ceiling: `rtl::BATCH_LANES` and `LifBatchStack::MAX_LANES` both
//! re-export [`MAX_LANES`], so the RTL and behavioral batch engines
//! cannot drift apart.

/// Hard ceiling on lanes per chunk — the widest plan any engine runs.
/// Both `rtl::BATCH_LANES` and `snn::LifBatchStack::MAX_LANES` alias
/// this constant.
pub const MAX_LANES: usize = 256;

/// Candidate lane widths, narrowest to widest. All are multiples of the
/// 64-bit mask word (the multi-word machinery handles any of them), and
/// the widest equals [`MAX_LANES`].
pub const LANE_CANDIDATES: [usize; 3] = [64, 128, 256];

/// Measured per-core L2 working-set budget in bytes (512 KiB). Like the
/// fan-out calibration's measured per-image cost, this is the one
/// machine-dependent constant behind the pure decision function: common
/// x86 server parts carry 512 KiB–1.25 MiB of private L2 per core, and
/// 512 KiB is the floor of that range, so a plan that fits it stays
/// L2-resident on every deployment target we bench on.
pub const DEFAULT_L2_BUDGET: usize = 512 * 1024;

/// Bytes of hot plane state per `(neuron, lane)` cell: the i32
/// accumulator plus the u32 spike-count register (the enable bitmask is
/// 1/64th of a plane and is ignored, like the fan-out model ignores
/// sub-percent terms).
pub const BYTES_PER_CELL: usize = 8;

/// A per-topology lane-chunk plan for the batched engines: how many
/// images one chunk serves. Built once per core/stack from the topology
/// ([`ChunkPlan::for_topology`]); the batched entry points split
/// sub-batches into `lanes()`-wide chunks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkPlan {
    lanes: usize,
}

impl ChunkPlan {
    /// A fixed-width plan (benchmark overrides and tests). Clamped to
    /// `1..=MAX_LANES`.
    pub fn fixed(lanes: usize) -> Self {
        ChunkPlan { lanes: lanes.clamp(1, MAX_LANES) }
    }

    /// The pure decision function: the widest [`LANE_CANDIDATES`] entry
    /// whose widest-layer plane working set — `lanes × max_width ×`
    /// [`BYTES_PER_CELL`] — fits `budget_bytes`, falling back to the
    /// narrowest candidate when none fits (one mask word per plan is the
    /// floor; correctness never depends on the width). Deterministic:
    /// same inputs, same plan, no measurement in the loop.
    pub fn from_budget(max_width: usize, budget_bytes: usize) -> Self {
        let mut lanes = LANE_CANDIDATES[0];
        for &cand in &LANE_CANDIDATES {
            let working_set = cand
                .saturating_mul(max_width.max(1))
                .saturating_mul(BYTES_PER_CELL);
            if working_set <= budget_bytes {
                lanes = cand.max(lanes);
            }
        }
        ChunkPlan { lanes }
    }

    /// The calibrated plan for a topology (`[n_in, hidden…, n_out]`):
    /// [`ChunkPlan::from_budget`] over the widest *output* layer (the
    /// planes are sized to layer outputs; the input layer holds no
    /// plane) under the measured [`DEFAULT_L2_BUDGET`].
    pub fn for_topology(topology: &[usize]) -> Self {
        let max_width = topology.iter().skip(1).copied().max().unwrap_or(1);
        Self::from_budget(max_width, DEFAULT_L2_BUDGET)
    }

    /// Images per chunk under this plan.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Number of chunks an `n`-image sub-batch splits into.
    pub fn chunks(&self, n: usize) -> usize {
        n.div_ceil(self.lanes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_are_sane() {
        assert_eq!(*LANE_CANDIDATES.last().unwrap(), MAX_LANES);
        for w in LANE_CANDIDATES {
            assert_eq!(w % 64, 0, "lane widths must be whole mask words");
        }
    }

    #[test]
    fn from_budget_picks_the_knee() {
        // Paper output layer (10 wide): everything fits, take the ceiling.
        assert_eq!(ChunkPlan::from_budget(10, DEFAULT_L2_BUDGET).lanes(), 256);
        // MLP hidden layer (128 wide): 256×128×8 = 256 KiB fits 512 KiB.
        assert_eq!(ChunkPlan::from_budget(128, DEFAULT_L2_BUDGET).lanes(), 256);
        // Wide hidden layer (512): 256 lanes need 1 MiB — step down to
        // 128 lanes (exactly 512 KiB).
        assert_eq!(ChunkPlan::from_budget(512, DEFAULT_L2_BUDGET).lanes(), 128);
        // 1024-wide: 128 lanes need 1 MiB too — step down to 64.
        assert_eq!(ChunkPlan::from_budget(1024, DEFAULT_L2_BUDGET).lanes(), 64);
        // Nothing fits: the narrowest candidate is the floor, never 0.
        assert_eq!(ChunkPlan::from_budget(1 << 20, DEFAULT_L2_BUDGET).lanes(), 64);
    }

    #[test]
    fn for_topology_uses_widest_plane_layer() {
        // The 784 input column holds no plane and must not count.
        assert_eq!(ChunkPlan::for_topology(&[784, 10]).lanes(), 256);
        assert_eq!(ChunkPlan::for_topology(&[784, 128, 10]).lanes(), 256);
        assert_eq!(ChunkPlan::for_topology(&[784, 512, 10]).lanes(), 128);
        assert_eq!(ChunkPlan::for_topology(&[784, 17, 12, 10]).lanes(), 256);
    }

    #[test]
    fn width_shrinks_monotonically_with_budget() {
        let mut last = usize::MAX;
        for budget in [4 << 20, 1 << 20, 512 * 1024, 128 * 1024, 0] {
            let lanes = ChunkPlan::from_budget(512, budget).lanes();
            assert!(lanes <= last, "narrower budget must never widen the plan");
            last = lanes;
        }
    }

    #[test]
    fn chunk_arithmetic() {
        let plan = ChunkPlan::fixed(128);
        assert_eq!(plan.chunks(0), 0);
        assert_eq!(plan.chunks(128), 1);
        assert_eq!(plan.chunks(129), 2);
        assert_eq!(ChunkPlan::fixed(0).lanes(), 1, "fixed clamps to ≥1");
        assert_eq!(ChunkPlan::fixed(1 << 20).lanes(), MAX_LANES);
    }
}
