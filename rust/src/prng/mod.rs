//! Pseudo-random number generation matching the paper's on-chip hardware.
//!
//! The paper's Poisson encoder uses a **32-bit XOR-shift PRNG** (Marsaglia
//! xorshift32, the canonical `13/17/5` variant — the standard choice for a
//! 32-bit LFSR-free hardware RNG and the one used in the authors' released
//! RTL). Seeding uses a splitmix32 finalizer so that per-pixel streams are
//! decorrelated while remaining trivially reproducible.
//!
//! **This module is the cross-layer contract**: `python/compile/kernels/
//! encoder.py` (Pallas), `python/compile/kernels/ref.py` (jnp oracle) and
//! [`crate::rtl::encoder`] implement bit-identical state updates, verified
//! by golden vectors generated at artifact-build time and by the embedded
//! golden tests below.

mod xorshift;

pub use xorshift::{splitmix32, xorshift32_step, Xorshift32};

/// Multiplicative constant used to decorrelate per-pixel seeds
/// (2^32 / golden ratio, the Weyl increment of splitmix).
pub const GOLDEN_GAMMA: u32 = 0x9E37_79B9;

/// Fallback state used when seeding would produce the xorshift fixed point
/// zero. Any nonzero constant works; this one is shared with the Python
/// implementations.
pub const ZERO_STATE_FALLBACK: u32 = 0xDEAD_BEEF;

/// Derive the initial xorshift32 state for pixel `index` of an image
/// encoded with `seed`.
///
/// Contract (identical in `dataset.py` / `encoder.py` / the RTL encoder):
///
/// ```text
/// s = splitmix32(seed XOR (index * GOLDEN_GAMMA))
/// state0 = s == 0 ? ZERO_STATE_FALLBACK : s
/// ```
#[inline]
pub fn pixel_seed(seed: u32, index: u32) -> u32 {
    let s = splitmix32(seed ^ index.wrapping_mul(GOLDEN_GAMMA));
    if s == 0 {
        ZERO_STATE_FALLBACK
    } else {
        s
    }
}

/// Derive an independent xorshift32 stream from a base seed plus two
/// domain-separation indices (e.g. `(class, sample)` for the dataset
/// generator, `(perturbation kind, sample)` for the robustness harness).
///
/// Contract (identical in `python/compile/dataset.py`):
///
/// ```text
/// s = splitmix32(splitmix32(seed XOR a·0x85EBCA6B) XOR b·GOLDEN_GAMMA)
/// state0 = s == 0 ? ZERO_STATE_FALLBACK : s
/// ```
pub fn derive_stream(seed: u32, a: u32, b: u32) -> Xorshift32 {
    let s = splitmix32(splitmix32(seed ^ a.wrapping_mul(0x85EB_CA6B)) ^ b.wrapping_mul(GOLDEN_GAMMA));
    Xorshift32::from_raw_state(if s == 0 { ZERO_STATE_FALLBACK } else { s })
}

/// A bank of independent xorshift32 streams, one per pixel, as instantiated
/// by the hardware Poisson encoder (one PRNG register per input channel).
#[derive(Debug, Clone)]
pub struct StreamBank {
    states: Vec<u32>,
}

impl StreamBank {
    /// Create `n` streams for image seed `seed` following the
    /// [`pixel_seed`] contract.
    pub fn new(seed: u32, n: usize) -> Self {
        let states = (0..n as u32).map(|i| pixel_seed(seed, i)).collect();
        StreamBank { states }
    }

    /// Number of streams in the bank.
    pub fn len(&self) -> usize {
        self.states.len()
    }

    /// True if the bank has no streams.
    pub fn is_empty(&self) -> bool {
        self.states.is_empty()
    }

    /// Advance every stream one step and return a view of the new states.
    ///
    /// Walks four interleaved lanes per iteration: the streams are
    /// independent, so the XOR/shift stages vectorize across lanes (the
    /// behavioral encoder's hottest loop). Bit-identical per lane to the
    /// scalar walk — pinned by `rust/tests/encoder_stats.rs`.
    pub fn step(&mut self) -> &[u32] {
        let mut chunks = self.states.chunks_exact_mut(4);
        for c in &mut chunks {
            c[0] = xorshift::xorshift32_step(c[0]);
            c[1] = xorshift::xorshift32_step(c[1]);
            c[2] = xorshift::xorshift32_step(c[2]);
            c[3] = xorshift::xorshift32_step(c[3]);
        }
        for s in chunks.into_remainder() {
            *s = xorshift::xorshift32_step(*s);
        }
        &self.states
    }

    /// Current (already-stepped) states.
    pub fn states(&self) -> &[u32] {
        &self.states
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden vectors for xorshift32 (13/17/5). These exact values are also
    /// asserted in `python/tests/test_prng.py`; together they pin the
    /// cross-language contract.
    #[test]
    fn xorshift32_golden() {
        let mut r = Xorshift32::from_raw_state(1);
        let got: Vec<u32> = (0..6).map(|_| r.next_u32()).collect();
        assert_eq!(got, vec![270369, 67634689, 2647435461, 307599695, 2398689233, 745495504]);
    }

    #[test]
    fn xorshift32_golden_large_seed() {
        let mut r = Xorshift32::from_raw_state(0xDEAD_BEEF);
        let got: Vec<u32> = (0..4).map(|_| r.next_u32()).collect();
        assert_eq!(got, vec![1199382711, 2384302402, 3129746520, 4276113467]);
    }

    /// splitmix32 golden values, mirrored in the Python tests.
    #[test]
    fn splitmix32_golden() {
        assert_eq!(splitmix32(0), 2462723854);
        assert_eq!(splitmix32(1), 2527132011);
        assert_eq!(splitmix32(0xDEAD_BEEF), 3553530007);
        assert_eq!(splitmix32(u32::MAX), 920564995);
    }

    #[test]
    fn pixel_seed_never_zero() {
        // Exhaustively check a large swath of (seed, index) pairs; the
        // fallback guarantees nonzero states so xorshift never sticks.
        for seed in [0u32, 1, 42, 0xFFFF_FFFF, 0x1234_5678] {
            for index in 0..4096u32 {
                assert_ne!(pixel_seed(seed, index), 0);
            }
        }
    }

    #[test]
    fn pixel_seed_decorrelates_neighbours() {
        // Neighbouring pixels must get very different streams: check the
        // hamming distance of the first output across adjacent indices.
        let mut total = 0u32;
        let n = 1024u32;
        for i in 0..n {
            let a = Xorshift32::from_raw_state(pixel_seed(7, i)).next_u32_once();
            let b = Xorshift32::from_raw_state(pixel_seed(7, i + 1)).next_u32_once();
            total += (a ^ b).count_ones();
        }
        let mean = f64::from(total) / f64::from(n);
        assert!((mean - 16.0).abs() < 1.5, "mean hamming distance {mean} too far from 16");
    }

    #[test]
    fn stream_bank_matches_manual_streams() {
        let mut bank = StreamBank::new(99, 8);
        let mut manual: Vec<Xorshift32> =
            (0..8).map(|i| Xorshift32::from_raw_state(pixel_seed(99, i))).collect();
        for _ in 0..32 {
            let bank_states = bank.step().to_vec();
            let manual_states: Vec<u32> = manual.iter_mut().map(|r| r.next_u32()).collect();
            assert_eq!(bank_states, manual_states);
        }
    }

    #[test]
    fn uniformity_of_low_byte() {
        // The encoder compares pixel intensity against the low byte; check
        // the low byte is close to uniform over a long run.
        let mut counts = [0u32; 256];
        let mut r = Xorshift32::new(2024);
        let n = 1 << 18;
        for _ in 0..n {
            counts[(r.next_u32() & 0xFF) as usize] += 1;
        }
        let expect = n as f64 / 256.0;
        let chi2: f64 = counts
            .iter()
            .map(|&c| {
                let d = f64::from(c) - expect;
                d * d / expect
            })
            .sum();
        // 255 dof: mean 255, sd ~22.6; allow a generous band.
        assert!(chi2 < 400.0, "low byte chi2 {chi2} too high");
    }
}
