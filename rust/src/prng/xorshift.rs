//! Marsaglia xorshift32 (shifts 13/17/5) and the splitmix32 finalizer.
//!
//! These are the exact functions the paper's hardware implements: a 32-bit
//! register plus three XOR/shift stages — no multipliers, one state update
//! per clock.

/// One xorshift32 state transition (`x ^= x<<13; x ^= x>>17; x ^= x<<5`).
///
/// `state` must be nonzero (zero is the fixed point of the map); callers
/// seed through [`super::pixel_seed`] which guarantees this.
#[inline(always)]
pub fn xorshift32_step(state: u32) -> u32 {
    let mut x = state;
    x ^= x << 13;
    x ^= x >> 17;
    x ^= x << 5;
    x
}

/// splitmix32: a full-avalanche finalizer used for seeding.
///
/// This is the 32-bit analogue of splitmix64 (murmur3-style finalizer with
/// the Weyl increment applied first), shared bit-for-bit with
/// `python/compile/dataset.py`.
#[inline(always)]
pub fn splitmix32(x: u32) -> u32 {
    let mut z = x.wrapping_add(0x9E37_79B9);
    z = (z ^ (z >> 16)).wrapping_mul(0x85EB_CA6B);
    z = (z ^ (z >> 13)).wrapping_mul(0xC2B2_AE35);
    z ^ (z >> 16)
}

/// A stateful xorshift32 generator.
///
/// The default constructor passes the seed through [`splitmix32`] so that
/// small consecutive seeds (0, 1, 2, ...) still produce unrelated streams —
/// the same convention as the Python dataset generator. Use
/// [`Xorshift32::from_raw_state`] when the exact hardware register value is
/// required (the RTL encoder does).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Xorshift32 {
    state: u32,
}

impl Xorshift32 {
    /// Seed through splitmix32 (never yields the zero state).
    pub fn new(seed: u32) -> Self {
        let s = splitmix32(seed);
        Xorshift32 { state: if s == 0 { super::ZERO_STATE_FALLBACK } else { s } }
    }

    /// Use `state` directly as the register value. `state` must be nonzero.
    pub fn from_raw_state(state: u32) -> Self {
        debug_assert_ne!(state, 0, "xorshift32 cannot leave the zero state");
        Xorshift32 { state }
    }

    /// Current register value.
    pub fn state(&self) -> u32 {
        self.state
    }

    /// Advance and return the new state (hardware semantics: the register
    /// value *is* the output).
    #[inline(always)]
    pub fn next_u32(&mut self) -> u32 {
        self.state = xorshift32_step(self.state);
        self.state
    }

    /// Advance once and return, consuming the generator (test helper).
    pub fn next_u32_once(mut self) -> u32 {
        self.next_u32()
    }

    /// Uniform value in `[0, bound)` by rejection-free multiply-shift.
    ///
    /// Slightly biased for bounds that do not divide 2^32; fine for test
    /// case generation and workload synthesis (never used in the hardware
    /// model, which only takes the low byte).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        ((u64::from(self.next_u32()) * u64::from(bound)) >> 32) as u32
    }

    /// Uniform value in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(lo <= hi);
        let span = (i64::from(hi) - i64::from(lo) + 1) as u32;
        lo.wrapping_add(self.below(span) as i32)
    }

    /// Bernoulli draw with probability `num / 256`.
    #[inline]
    pub fn chance_u8(&mut self, num: u8) -> bool {
        (self.next_u32() & 0xFF) < u32::from(num)
    }

    /// An `f64` in `[0, 1)` (metrics / workload generation only).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        f64::from(self.next_u32()) / 4294967296.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn period_does_not_collapse() {
        // xorshift32 has period 2^32-1; sanity-check no short cycle over a
        // modest window.
        let mut r = Xorshift32::from_raw_state(1);
        let first = r.next_u32();
        for _ in 0..100_000 {
            assert_ne!(r.next_u32(), 0, "entered zero fixed point");
        }
        // Coming back to the first value this early would mean a tiny cycle.
        let mut r2 = Xorshift32::from_raw_state(first);
        for _ in 0..10_000 {
            assert_ne!(r2.next_u32(), first);
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Xorshift32::new(3);
        for bound in [1u32, 2, 3, 10, 255, 256, 1000] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Xorshift32::new(4);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..10_000 {
            let v = r.range_i32(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi, "range endpoints never drawn");
    }

    #[test]
    fn chance_u8_rate() {
        let mut r = Xorshift32::new(5);
        let n = 100_000;
        let hits = (0..n).filter(|_| r.chance_u8(64)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.01, "rate {rate} too far from 0.25");
    }
}
