//! Layer controller (paper Fig. 3): the global FSM that sequences
//! integration, leak and fire phases, owns the spike register and drives
//! the per-neuron enable lines (`en_0 .. en_9`) implementing active
//! pruning.

use crate::config::{LeakMode, PruneMode, SnnConfig};

/// FSM states. One clock per state transition; `Integrate` self-loops over
/// the pixel counter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CtrlState {
    /// Waiting for an image load.
    Idle,
    /// Walking pixels; the payload is the pixel counter value.
    Integrate { pixel: usize },
    /// Applying the shift-subtract decay (one clock, all neurons parallel).
    /// `resume_pixel` is where integration continues in `PerRow` mode
    /// (`None` = the end-of-timestep leak).
    Leak { resume_pixel: Option<usize> },
    /// Evaluating threshold comparators, latching the spike register,
    /// updating the pruning mask.
    Fire,
    /// Window complete; outputs valid.
    Done,
}

/// The controller's architectural registers.
#[derive(Debug, Clone)]
pub struct LayerController {
    state: CtrlState,
    /// Timestep counter register.
    timestep: u32,
    /// Spike register: the fire pattern latched on the last `Fire` clock.
    spike_reg: Vec<bool>,
    /// Enable lines (true = enabled); pruning clears bits.
    enables: Vec<bool>,
    /// Count of set enable lines — the O(1) "any neuron still enabled"
    /// signal the core's integrate path gates BRAM reads on (hoisted out
    /// of the per-cycle loop; previously recomputed by scanning `enables`
    /// every clock).
    enabled_count: usize,
    /// Datapath width: pixels served per `Integrate` clock. 1 = the
    /// paper's Fig. 1 pixel-serial datapath; wider values model a
    /// multi-lane encoder + adder tree (the only way the paper's §V-C
    /// 100 µs / Table II <1 µs latency claims can hold — see
    /// `experiments::ablations::run_ablation_width`).
    pixels_per_cycle: usize,
    cfg: SnnConfig,
}

impl LayerController {
    pub fn new(cfg: &SnnConfig) -> Self {
        LayerController {
            state: CtrlState::Idle,
            timestep: 0,
            spike_reg: vec![false; cfg.n_outputs],
            enables: vec![true; cfg.n_outputs],
            enabled_count: cfg.n_outputs,
            pixels_per_cycle: 1,
            cfg: cfg.clone(),
        }
    }

    /// Set the datapath width (≥1). `PerRow` leak scheduling requires the
    /// width to divide the row length so leak clocks stay row-aligned.
    pub fn set_pixels_per_cycle(&mut self, k: usize) {
        assert!(k >= 1, "datapath width must be >= 1");
        if let crate::config::LeakMode::PerRow { row_len } = self.cfg.leak_mode {
            assert!(
                row_len % k == 0,
                "pixels_per_cycle {k} must divide row_len {row_len} in PerRow mode"
            );
        }
        self.pixels_per_cycle = k;
    }

    /// Configured datapath width.
    pub fn pixels_per_cycle(&self) -> usize {
        self.pixels_per_cycle
    }

    pub fn state(&self) -> CtrlState {
        self.state
    }

    /// Current timestep counter value.
    pub fn timestep(&self) -> u32 {
        self.timestep
    }

    /// Spike register contents (`spike_reg[j]`).
    pub fn spike_reg(&self) -> &[bool] {
        &self.spike_reg
    }

    /// Enable line for neuron `j` (`en_j` in Fig. 3).
    pub fn enable(&self, j: usize) -> bool {
        self.enables[j]
    }

    /// All enable lines.
    pub fn enables(&self) -> &[bool] {
        &self.enables
    }

    /// O(1): is any neuron still enabled? (OR-reduction of the enable
    /// lines; gates the weight BRAM once pruning has shut the array off.)
    pub fn any_enabled(&self) -> bool {
        self.enabled_count > 0
    }

    /// `start` pulse: begin a new inference window.
    pub fn start(&mut self) {
        self.state = CtrlState::Integrate { pixel: 0 };
        self.timestep = 0;
        self.spike_reg.fill(false);
        self.enables.fill(true);
        self.enabled_count = self.enables.len();
    }

    /// Jump straight to `Done` (used by the fast path, which executes the
    /// window without walking the FSM cycle by cycle).
    pub fn finish(&mut self) {
        self.state = CtrlState::Done;
        self.timestep = self.cfg.timesteps;
    }

    /// Latch the fire pattern (driven by the `Fire`-state clock) and apply
    /// the pruning mask update. `spike_counts[j]` must already include this
    /// cycle's spikes.
    pub fn latch_fire(&mut self, fired: &[bool], spike_counts: &[u32]) {
        debug_assert_eq!(fired.len(), self.spike_reg.len());
        self.spike_reg.copy_from_slice(fired);
        if let PruneMode::AfterFires { after_spikes } = self.cfg.prune {
            for (j, &count) in spike_counts.iter().enumerate() {
                if count >= after_spikes && self.enables[j] {
                    self.enables[j] = false;
                    self.enabled_count -= 1;
                }
            }
        }
    }

    /// Advance the FSM one clock from the current state. The core calls
    /// this *after* performing the state's datapath work for this cycle.
    pub fn advance(&mut self) {
        self.state = match self.state {
            CtrlState::Idle => CtrlState::Idle,
            CtrlState::Integrate { pixel } => {
                let next_pixel = (pixel + self.pixels_per_cycle).min(self.cfg.n_inputs);
                let row_boundary = match self.cfg.leak_mode {
                    LeakMode::PerRow { row_len } => next_pixel % row_len == 0,
                    LeakMode::PerTimestep => false,
                };
                if next_pixel == self.cfg.n_inputs {
                    // End of the integration window: the end-of-step leak.
                    // (In PerRow mode the final row's leak is this same
                    // clock — `resume_pixel: None` routes to Fire.)
                    CtrlState::Leak { resume_pixel: None }
                } else if row_boundary {
                    CtrlState::Leak { resume_pixel: Some(next_pixel) }
                } else {
                    CtrlState::Integrate { pixel: next_pixel }
                }
            }
            CtrlState::Leak { resume_pixel: Some(p) } => CtrlState::Integrate { pixel: p },
            CtrlState::Leak { resume_pixel: None } => CtrlState::Fire,
            CtrlState::Fire => {
                self.timestep += 1;
                if self.timestep >= self.cfg.timesteps {
                    CtrlState::Done
                } else {
                    CtrlState::Integrate { pixel: 0 }
                }
            }
            CtrlState::Done => CtrlState::Done,
        };
    }

    /// Priority-encoder readout: lowest class index among the max spike
    /// counts (hardware argmax over the count registers). Thin wrapper over
    /// the one shared [`crate::util::priority_argmax`] implementation.
    pub fn decide(spike_counts: &[u32]) -> u8 {
        crate::util::priority_argmax(spike_counts) as u8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LeakMode, SnnConfig};

    fn tiny() -> SnnConfig {
        SnnConfig { n_inputs: 4, n_outputs: 2, timesteps: 2, ..SnnConfig::paper() }
    }

    /// Walk the FSM and collect the state sequence for one window.
    fn trace_states(cfg: &SnnConfig, max: usize) -> Vec<CtrlState> {
        let mut c = LayerController::new(cfg);
        c.start();
        let mut states = vec![c.state()];
        for _ in 0..max {
            if c.state() == CtrlState::Done {
                break;
            }
            c.advance();
            states.push(c.state());
        }
        states
    }

    #[test]
    fn per_timestep_schedule() {
        // 4 pixels: I0 I1 I2 I3 L F | I0 I1 I2 I3 L F | Done
        let states = trace_states(&tiny(), 32);
        use CtrlState::*;
        assert_eq!(
            states,
            vec![
                Integrate { pixel: 0 },
                Integrate { pixel: 1 },
                Integrate { pixel: 2 },
                Integrate { pixel: 3 },
                Leak { resume_pixel: None },
                Fire,
                Integrate { pixel: 0 },
                Integrate { pixel: 1 },
                Integrate { pixel: 2 },
                Integrate { pixel: 3 },
                Leak { resume_pixel: None },
                Fire,
                Done,
            ]
        );
    }

    #[test]
    fn per_row_schedule_inserts_leaks() {
        let cfg = SnnConfig {
            leak_mode: LeakMode::PerRow { row_len: 2 },
            timesteps: 1,
            ..tiny()
        };
        let states = trace_states(&cfg, 32);
        use CtrlState::*;
        assert_eq!(
            states,
            vec![
                Integrate { pixel: 0 },
                Integrate { pixel: 1 },
                Leak { resume_pixel: Some(2) },
                Integrate { pixel: 2 },
                Integrate { pixel: 3 },
                Leak { resume_pixel: None },
                Fire,
                Done,
            ]
        );
    }

    #[test]
    fn cycles_per_timestep_paper_config() {
        // 784 integrate + 1 leak + 1 fire = 786 cycles per timestep.
        let cfg = SnnConfig { timesteps: 1, ..SnnConfig::paper() };
        let states = trace_states(&cfg, 2000);
        assert_eq!(states.len(), 784 + 1 + 1 + 1); // + Done observation
    }

    #[test]
    fn pruning_mask_clears_enables() {
        let mut c = LayerController::new(&tiny());
        c.start();
        assert!(c.enable(0) && c.enable(1));
        c.latch_fire(&[true, false], &[1, 0]);
        assert!(!c.enable(0), "fired neuron must be pruned");
        assert!(c.enable(1));
        assert_eq!(c.spike_reg(), &[true, false]);
        // start() restores enables.
        c.start();
        assert!(c.enable(0));
    }

    #[test]
    fn any_enabled_tracks_pruning() {
        let mut c = LayerController::new(&tiny());
        c.start();
        assert!(c.any_enabled());
        c.latch_fire(&[true, false], &[1, 0]);
        assert!(c.any_enabled(), "one neuron still live");
        // Re-latching the same counts must not double-decrement.
        c.latch_fire(&[false, false], &[1, 0]);
        assert!(c.any_enabled());
        c.latch_fire(&[false, true], &[1, 1]);
        assert!(!c.any_enabled(), "all pruned");
        c.start();
        assert!(c.any_enabled(), "start() restores the array");
    }

    #[test]
    fn finish_jumps_to_done() {
        let mut c = LayerController::new(&tiny());
        c.start();
        c.finish();
        assert_eq!(c.state(), CtrlState::Done);
        assert_eq!(c.timestep(), tiny().timesteps);
    }

    #[test]
    fn prune_off_keeps_enables() {
        let cfg = SnnConfig { prune: crate::config::PruneMode::Off, ..tiny() };
        let mut c = LayerController::new(&cfg);
        c.start();
        c.latch_fire(&[true, true], &[5, 5]);
        assert!(c.enable(0) && c.enable(1));
    }

    #[test]
    fn decide_is_priority_encoder() {
        assert_eq!(LayerController::decide(&[0, 0, 0]), 0);
        assert_eq!(LayerController::decide(&[1, 3, 3]), 1);
        assert_eq!(LayerController::decide(&[0, 2, 5, 5]), 2);
    }
}
