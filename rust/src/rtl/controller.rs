//! Layer controller (paper Fig. 3): the global FSM that sequences
//! integration, leak and fire phases, owns the per-layer spike registers
//! and drives each layer's enable lines (`en_0 .. en_9`) implementing
//! active pruning.
//!
//! Since the N-layer refactor the FSM time-multiplexes the layer chain
//! inside one timestep: layer 0 integrates the encoder's pixel walk, then
//! each deeper layer integrates the previous layer's latched spike
//! register, each walk followed by its own Leak and Fire clocks. The
//! timestep counter advances on the *final* layer's Fire clock. A
//! single-layer topology reproduces the original schedule clock for clock.

use crate::config::{LeakMode, PruneMode, SnnConfig};

/// FSM states. One clock per state transition; `Integrate` self-loops over
/// the pixel counter within one layer's walk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CtrlState {
    /// Waiting for an image load.
    Idle,
    /// Walking layer `layer`'s inputs; `pixel` is the input counter value
    /// (a pixel index for layer 0, a spike-register index above).
    Integrate { layer: usize, pixel: usize },
    /// Applying the shift-subtract decay to layer `layer` (one clock, all
    /// neurons parallel). `resume_pixel` is where integration continues in
    /// `PerRow` mode (`None` = the end-of-walk leak).
    Leak { layer: usize, resume_pixel: Option<usize> },
    /// Evaluating layer `layer`'s threshold comparators, latching its
    /// spike register, updating its pruning mask.
    Fire { layer: usize },
    /// Window complete; outputs valid.
    Done,
}

impl CtrlState {
    /// The layer whose datapath is active this clock (`None` for
    /// `Idle`/`Done`). Drives per-layer cycle attribution.
    pub fn layer(&self) -> Option<usize> {
        match *self {
            CtrlState::Integrate { layer, .. }
            | CtrlState::Leak { layer, .. }
            | CtrlState::Fire { layer } => Some(layer),
            CtrlState::Idle | CtrlState::Done => None,
        }
    }
}

/// The controller's architectural registers.
#[derive(Debug, Clone)]
pub struct LayerController {
    state: CtrlState,
    /// Timestep counter register.
    timestep: u32,
    /// Per-layer spike registers: the fire pattern latched on each layer's
    /// last `Fire` (or mid-walk Immediate) clock.
    spike_reg: Vec<Vec<bool>>,
    /// Per-layer OR-accumulated fire pattern of the *current timestep* —
    /// the inter-layer hand-off register. Unlike `spike_reg` (overwritten
    /// by every latch, cleared at the Fire clock under Immediate firing)
    /// this keeps every spike a layer emitted this step, so the next
    /// layer's walk sees the full pattern. Cleared when the final layer's
    /// Fire clock ends the timestep.
    step_fired: Vec<Vec<bool>>,
    /// Per-layer enable lines (true = enabled); pruning clears bits.
    enables: Vec<Vec<bool>>,
    /// Per-layer count of set enable lines — the O(1) "any neuron still
    /// enabled" signal the core's integrate path gates BRAM reads on.
    enabled_count: Vec<usize>,
    /// Datapath width: inputs served per `Integrate` clock. 1 = the
    /// paper's Fig. 1 pixel-serial datapath; wider values model a
    /// multi-lane encoder + adder tree (the only way the paper's §V-C
    /// 100 µs / Table II <1 µs latency claims can hold — see
    /// `experiments::ablations::run_ablation_width`).
    pixels_per_cycle: usize,
    /// Per-layer resolved pruning policy (the controller's mask update is
    /// the one place pruning acts, so this is the one place the per-layer
    /// prune axis lands in the RTL model).
    prune: Vec<PruneMode>,
    cfg: SnnConfig,
}

impl LayerController {
    pub fn new(cfg: &SnnConfig) -> Self {
        let widths: Vec<usize> = (0..cfg.n_layers()).map(|l| cfg.layer_output(l)).collect();
        LayerController {
            state: CtrlState::Idle,
            timestep: 0,
            spike_reg: widths.iter().map(|&n| vec![false; n]).collect(),
            step_fired: widths.iter().map(|&n| vec![false; n]).collect(),
            enables: widths.iter().map(|&n| vec![true; n]).collect(),
            enabled_count: widths,
            pixels_per_cycle: 1,
            prune: (0..cfg.n_layers()).map(|l| cfg.layer_prune(l)).collect(),
            cfg: cfg.clone(),
        }
    }

    /// Number of weight layers sequenced per timestep.
    pub fn n_layers(&self) -> usize {
        self.spike_reg.len()
    }

    /// Set the datapath width (≥1). `PerRow` leak scheduling requires the
    /// width to divide the row length so leak clocks stay row-aligned.
    pub fn set_pixels_per_cycle(&mut self, k: usize) {
        assert!(k >= 1, "datapath width must be >= 1");
        if let crate::config::LeakMode::PerRow { row_len } = self.cfg.leak_mode {
            assert!(
                row_len % k == 0,
                "pixels_per_cycle {k} must divide row_len {row_len} in PerRow mode"
            );
        }
        self.pixels_per_cycle = k;
    }

    /// Configured datapath width.
    pub fn pixels_per_cycle(&self) -> usize {
        self.pixels_per_cycle
    }

    pub fn state(&self) -> CtrlState {
        self.state
    }

    /// Current timestep counter value.
    pub fn timestep(&self) -> u32 {
        self.timestep
    }

    /// Layer `l`'s spike register contents (`spike_reg[j]`).
    pub fn spike_reg(&self, l: usize) -> &[bool] {
        &self.spike_reg[l]
    }

    /// Layer `l`'s OR-accumulated fire pattern for the current timestep
    /// (what layer `l+1`'s integrate walk reads).
    pub fn step_fired(&self, l: usize) -> &[bool] {
        &self.step_fired[l]
    }

    /// Enable line for neuron `j` of layer `l` (`en_j` in Fig. 3).
    pub fn enable(&self, l: usize, j: usize) -> bool {
        self.enables[l][j]
    }

    /// All enable lines of layer `l`.
    pub fn enables(&self, l: usize) -> &[bool] {
        &self.enables[l]
    }

    /// O(1): is any neuron of layer `l` still enabled? (OR-reduction of
    /// the enable lines; gates the layer's weight BRAM once pruning has
    /// shut the array off.)
    pub fn any_enabled(&self, l: usize) -> bool {
        self.enabled_count[l] > 0
    }

    /// `start` pulse: begin a new inference window.
    pub fn start(&mut self) {
        self.state = CtrlState::Integrate { layer: 0, pixel: 0 };
        self.timestep = 0;
        for reg in &mut self.spike_reg {
            reg.fill(false);
        }
        for f in &mut self.step_fired {
            f.fill(false);
        }
        for (en, count) in self.enables.iter_mut().zip(&mut self.enabled_count) {
            en.fill(true);
            *count = en.len();
        }
    }

    /// Jump straight to `Done` (used by the fast path, which executes the
    /// window without walking the FSM cycle by cycle).
    pub fn finish(&mut self) {
        self.state = CtrlState::Done;
        self.timestep = self.cfg.timesteps;
    }

    /// Latch layer `l`'s fire pattern (driven by its `Fire`-state clock or
    /// a mid-walk Immediate event), fold it into the timestep accumulator
    /// and apply the pruning mask update. `spike_counts[j]` must already
    /// include this cycle's spikes.
    pub fn latch_fire(&mut self, l: usize, fired: &[bool], spike_counts: &[u32]) {
        debug_assert_eq!(fired.len(), self.spike_reg[l].len());
        self.spike_reg[l].copy_from_slice(fired);
        for (acc, &f) in self.step_fired[l].iter_mut().zip(fired) {
            *acc |= f;
        }
        if let PruneMode::AfterFires { after_spikes } = self.prune[l] {
            for (j, &count) in spike_counts.iter().enumerate() {
                if count >= after_spikes && self.enables[l][j] {
                    self.enables[l][j] = false;
                    self.enabled_count[l] -= 1;
                }
            }
        }
    }

    /// Clear the per-timestep fire accumulators (the end-of-timestep edge;
    /// `advance` does this on the final layer's Fire clock, the fast path
    /// calls it directly between timesteps).
    pub fn end_timestep(&mut self) {
        for f in &mut self.step_fired {
            f.fill(false);
        }
    }

    /// Advance the FSM one clock from the current state. The core calls
    /// this *after* performing the state's datapath work for this cycle.
    pub fn advance(&mut self) {
        self.state = match self.state {
            CtrlState::Idle => CtrlState::Idle,
            CtrlState::Integrate { layer, pixel } => {
                let n_in = self.cfg.layer_input(layer);
                let next_pixel = (pixel + self.pixels_per_cycle).min(n_in);
                // Row boundaries are image geometry: only the input
                // layer's pixel walk observes PerRow scheduling.
                let row_boundary = layer == 0
                    && match self.cfg.leak_mode {
                        LeakMode::PerRow { row_len } => next_pixel % row_len == 0,
                        LeakMode::PerTimestep => false,
                    };
                if next_pixel == n_in {
                    // End of the walk: the end-of-walk leak. (In PerRow
                    // mode the final row's leak is this same clock —
                    // `resume_pixel: None` routes to Fire.)
                    CtrlState::Leak { layer, resume_pixel: None }
                } else if row_boundary {
                    CtrlState::Leak { layer, resume_pixel: Some(next_pixel) }
                } else {
                    CtrlState::Integrate { layer, pixel: next_pixel }
                }
            }
            CtrlState::Leak { layer, resume_pixel: Some(p) } => {
                CtrlState::Integrate { layer, pixel: p }
            }
            CtrlState::Leak { layer, resume_pixel: None } => CtrlState::Fire { layer },
            CtrlState::Fire { layer } => {
                if layer + 1 < self.n_layers() {
                    CtrlState::Integrate { layer: layer + 1, pixel: 0 }
                } else {
                    self.timestep += 1;
                    self.end_timestep();
                    if self.timestep >= self.cfg.timesteps {
                        CtrlState::Done
                    } else {
                        CtrlState::Integrate { layer: 0, pixel: 0 }
                    }
                }
            }
            CtrlState::Done => CtrlState::Done,
        };
    }

    /// Priority-encoder readout: lowest class index among the max spike
    /// counts (hardware argmax over the count registers). Thin wrapper over
    /// the one shared [`crate::util::priority_argmax`] implementation.
    pub fn decide(spike_counts: &[u32]) -> u8 {
        crate::util::priority_argmax(spike_counts) as u8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LeakMode, SnnConfig};

    fn tiny() -> SnnConfig {
        SnnConfig { topology: vec![4, 2], timesteps: 2, ..SnnConfig::paper() }
    }

    /// Walk the FSM and collect the state sequence for one window.
    fn trace_states(cfg: &SnnConfig, max: usize) -> Vec<CtrlState> {
        let mut c = LayerController::new(cfg);
        c.start();
        let mut states = vec![c.state()];
        for _ in 0..max {
            if c.state() == CtrlState::Done {
                break;
            }
            c.advance();
            states.push(c.state());
        }
        states
    }

    #[test]
    fn per_timestep_schedule() {
        // 4 pixels: I0 I1 I2 I3 L F | I0 I1 I2 I3 L F | Done
        let states = trace_states(&tiny(), 32);
        use CtrlState::*;
        assert_eq!(
            states,
            vec![
                Integrate { layer: 0, pixel: 0 },
                Integrate { layer: 0, pixel: 1 },
                Integrate { layer: 0, pixel: 2 },
                Integrate { layer: 0, pixel: 3 },
                Leak { layer: 0, resume_pixel: None },
                Fire { layer: 0 },
                Integrate { layer: 0, pixel: 0 },
                Integrate { layer: 0, pixel: 1 },
                Integrate { layer: 0, pixel: 2 },
                Integrate { layer: 0, pixel: 3 },
                Leak { layer: 0, resume_pixel: None },
                Fire { layer: 0 },
                Done,
            ]
        );
    }

    #[test]
    fn layered_schedule_multiplexes_within_timestep() {
        // [3, 2, 2], T=1: the hidden walk (3 inputs) then the output walk
        // (2 spike-register reads), each with leak + fire, in one step.
        let cfg = SnnConfig { topology: vec![3, 2, 2], timesteps: 1, ..SnnConfig::paper() };
        let states = trace_states(&cfg, 32);
        use CtrlState::*;
        assert_eq!(
            states,
            vec![
                Integrate { layer: 0, pixel: 0 },
                Integrate { layer: 0, pixel: 1 },
                Integrate { layer: 0, pixel: 2 },
                Leak { layer: 0, resume_pixel: None },
                Fire { layer: 0 },
                Integrate { layer: 1, pixel: 0 },
                Integrate { layer: 1, pixel: 1 },
                Leak { layer: 1, resume_pixel: None },
                Fire { layer: 1 },
                Done,
            ]
        );
    }

    #[test]
    fn per_row_schedule_inserts_leaks() {
        let cfg = SnnConfig {
            leak_mode: LeakMode::PerRow { row_len: 2 },
            timesteps: 1,
            ..tiny()
        };
        let states = trace_states(&cfg, 32);
        use CtrlState::*;
        assert_eq!(
            states,
            vec![
                Integrate { layer: 0, pixel: 0 },
                Integrate { layer: 0, pixel: 1 },
                Leak { layer: 0, resume_pixel: Some(2) },
                Integrate { layer: 0, pixel: 2 },
                Integrate { layer: 0, pixel: 3 },
                Leak { layer: 0, resume_pixel: None },
                Fire { layer: 0 },
                Done,
            ]
        );
    }

    #[test]
    fn per_row_leak_stays_on_input_layer() {
        // A deep topology under PerRow: the hidden walk gets row-aligned
        // leaks, the output walk (spike-register inputs, no rows) gets
        // exactly one end-of-walk leak.
        let cfg = SnnConfig {
            topology: vec![4, 3, 2],
            leak_mode: LeakMode::PerRow { row_len: 2 },
            timesteps: 1,
            ..SnnConfig::paper()
        };
        let states = trace_states(&cfg, 48);
        let layer1_leaks = states
            .iter()
            .filter(|s| matches!(s, CtrlState::Leak { layer: 1, .. }))
            .count();
        assert_eq!(layer1_leaks, 1, "deep layers leak once per walk: {states:?}");
        let layer0_leaks = states
            .iter()
            .filter(|s| matches!(s, CtrlState::Leak { layer: 0, .. }))
            .count();
        assert_eq!(layer0_leaks, 2, "4-pixel walk with row_len 2 leaks twice");
    }

    #[test]
    fn cycles_per_timestep_paper_config() {
        // 784 integrate + 1 leak + 1 fire = 786 cycles per timestep.
        let cfg = SnnConfig { timesteps: 1, ..SnnConfig::paper() };
        let states = trace_states(&cfg, 2000);
        assert_eq!(states.len(), 784 + 1 + 1 + 1); // + Done observation
    }

    #[test]
    fn pruning_mask_clears_enables() {
        let mut c = LayerController::new(&tiny());
        c.start();
        assert!(c.enable(0, 0) && c.enable(0, 1));
        c.latch_fire(0, &[true, false], &[1, 0]);
        assert!(!c.enable(0, 0), "fired neuron must be pruned");
        assert!(c.enable(0, 1));
        assert_eq!(c.spike_reg(0), &[true, false]);
        // start() restores enables.
        c.start();
        assert!(c.enable(0, 0));
    }

    #[test]
    fn step_fired_accumulates_until_end_of_timestep() {
        let mut c = LayerController::new(&tiny());
        c.start();
        // Two latches in one timestep (the Immediate-mode pattern): the
        // spike register shows the last, the accumulator the union.
        c.latch_fire(0, &[true, false], &[0, 0]);
        c.latch_fire(0, &[false, true], &[0, 0]);
        assert_eq!(c.spike_reg(0), &[false, true]);
        assert_eq!(c.step_fired(0), &[true, true], "accumulator keeps the union");
        c.end_timestep();
        assert_eq!(c.step_fired(0), &[false, false]);
        assert_eq!(c.spike_reg(0), &[false, true], "spike register survives the clear");
    }

    #[test]
    fn any_enabled_tracks_pruning() {
        let mut c = LayerController::new(&tiny());
        c.start();
        assert!(c.any_enabled(0));
        c.latch_fire(0, &[true, false], &[1, 0]);
        assert!(c.any_enabled(0), "one neuron still live");
        // Re-latching the same counts must not double-decrement.
        c.latch_fire(0, &[false, false], &[1, 0]);
        assert!(c.any_enabled(0));
        c.latch_fire(0, &[false, true], &[1, 1]);
        assert!(!c.any_enabled(0), "all pruned");
        c.start();
        assert!(c.any_enabled(0), "start() restores the array");
    }

    #[test]
    fn per_layer_enables_are_independent() {
        let cfg = SnnConfig { topology: vec![4, 2, 3], ..SnnConfig::paper() };
        let mut c = LayerController::new(&cfg);
        c.start();
        c.latch_fire(0, &[true, true], &[1, 1]);
        assert!(!c.any_enabled(0), "hidden layer fully pruned");
        assert!(c.any_enabled(1), "output layer untouched");
        assert_eq!(c.enables(1), &[true, true, true]);
    }

    #[test]
    fn per_layer_prune_policies_act_independently() {
        // Hidden layer prunes after 1 fire, readout never: the same latch
        // sequence must gate layer 0 and leave layer 1 untouched.
        use crate::config::{LayerParams, PruneMode};
        let cfg = SnnConfig {
            topology: vec![4, 2, 2],
            layer_params: vec![
                LayerParams {
                    prune: Some(PruneMode::AfterFires { after_spikes: 1 }),
                    ..Default::default()
                },
                LayerParams { prune: Some(PruneMode::Off), ..Default::default() },
            ],
            ..SnnConfig::paper()
        };
        let mut c = LayerController::new(&cfg);
        c.start();
        c.latch_fire(0, &[true, true], &[1, 1]);
        c.latch_fire(1, &[true, true], &[5, 5]);
        assert!(!c.any_enabled(0), "hidden layer must be fully pruned");
        assert_eq!(c.enables(1), &[true, true], "unpruned readout keeps its enables");
    }

    #[test]
    fn finish_jumps_to_done() {
        let mut c = LayerController::new(&tiny());
        c.start();
        c.finish();
        assert_eq!(c.state(), CtrlState::Done);
        assert_eq!(c.timestep(), tiny().timesteps);
    }

    #[test]
    fn prune_off_keeps_enables() {
        let cfg = SnnConfig { prune: crate::config::PruneMode::Off, ..tiny() };
        let mut c = LayerController::new(&cfg);
        c.start();
        c.latch_fire(0, &[true, true], &[5, 5]);
        assert!(c.enable(0, 0) && c.enable(0, 1));
    }

    #[test]
    fn decide_is_priority_encoder() {
        assert_eq!(LayerController::decide(&[0, 0, 0]), 0);
        assert_eq!(LayerController::decide(&[1, 3, 3]), 1);
        assert_eq!(LayerController::decide(&[0, 2, 5, 5]), 2);
    }
}
