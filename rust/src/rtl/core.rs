//! Top-level SNN core: controller + encoder + per-layer neuron arrays +
//! per-layer weight BRAMs.
//!
//! Since the N-layer refactor the core instantiates one [`LifNeuronArray`]
//! and one weight BRAM per connection of `SnnConfig::topology`, and the
//! controller time-multiplexes the layer chain inside each timestep: the
//! hidden layer integrates encoder spikes over the pixel walk, then every
//! deeper layer integrates the previous layer's latched spike register —
//! so one spike propagates through the whole depth within a single
//! architectural step. The single-layer paper core is the degenerate case
//! and reproduces the original schedule clock for clock.
//!
//! Two execution engines share the same architectural state:
//!
//! * the **cycle path** ([`RtlCore::tick_cycle`] / [`RtlCore::run`]) —
//!   advances one clock per call through the controller FSM; required for
//!   waveform capture and cycle-by-cycle observability;
//! * the **fast path** ([`RtlCore::run_fast`] /
//!   [`RtlCore::run_fast_early`]) — executes a whole timestep per loop
//!   iteration: the Poisson comparator draws for a pixel range are
//!   bulk-generated into an active-pixel index list, only spiking rows are
//!   integrated, and the cycle count is computed arithmetically from the
//!   FSM schedule instead of being walked. It is **bit-exact and
//!   activity-exact** with the cycle path across every
//!   `FireMode`/`LeakMode`/`PruneMode`/datapath-width/topology combination
//!   (property-tested by `fast_path_equals_cycle_path`; equivalence
//!   argument in EXPERIMENTS.md §Perf). `run_fast_early` additionally
//!   applies the serving-level [`EarlyExit`] margin policy between
//!   timesteps — the fast path makes the per-timestep check effectively
//!   free.

use crate::config::{FireMode, LeakMode, PruneMode, SnnConfig};
use crate::data::Image;
use crate::error::{Error, Result};
use crate::fixed::{SparseWeightLayer, SparseWeightStack, WeightStack};
use crate::snn::EarlyExit;
use crate::util::margin_reached;

use super::controller::{CtrlState, LayerController};
use crate::plan::ChunkPlan;

use super::encoder::RtlPoissonEncoder;
use super::lif_neuron::{LifBatchArray, LifNeuronArray};
use super::power::{ActivityCounters, EnergyModel, EnergyReport};
use super::vcd::VcdWriter;

/// Ceiling lane-chunk width for [`RtlCore::run_fast_batch`] — an alias
/// of [`crate::plan::MAX_LANES`], the single source of truth shared with
/// the behavioral `LifBatchStack`. The transposed active/step-fired
/// masks are **multi-word** bitsets (`lanes.div_ceil(64)` words per
/// input/neuron), so any width up to this works; the width a core
/// actually runs is picked per topology by its [`ChunkPlan`] so the
/// neuron-major accumulator planes stay L2-resident on wide hidden
/// layers (override via [`RtlCore::with_chunk_plan`]).
pub const BATCH_LANES: usize = crate::plan::MAX_LANES;

/// Number of ceiling-width chunks an `n`-image sub-batch splits into
/// (observability for sizing tests and the bench harness; a core's own
/// chunking follows its [`ChunkPlan`], which never exceeds this width —
/// see [`ChunkPlan::chunks`] for the plan-aware count).
pub fn batch_chunks(n: usize) -> usize {
    n.div_ceil(BATCH_LANES)
}

/// Result of one inference window on the RTL core.
#[derive(Debug, Clone, PartialEq)]
pub struct RtlResult {
    /// Priority-encoded argmax of the final layer's spike-count registers.
    pub class: u8,
    /// Spike counts per output neuron (final layer).
    pub spike_counts: Vec<u32>,
    /// Clock cycles consumed by the window (excludes load).
    pub cycles: u64,
    /// Switching-activity totals for the window (all layers + encoder).
    pub activity: ActivityCounters,
    /// Energy estimate under the core's [`EnergyModel`].
    pub energy: EnergyReport,
    /// Membrane potential of every neuron after each timestep's Fire
    /// clocks, layers concatenated in topology order (for the single-layer
    /// paper core: exactly the output layer). Each layer's snapshot is
    /// taken at its own Fire clock.
    pub membrane_by_step: Vec<Vec<i32>>,
    /// Fire-clock spike patterns after each timestep, layers concatenated
    /// in the same order as `membrane_by_step`.
    pub spikes_by_step: Vec<Vec<bool>>,
    /// Spike counts of every layer (the last entry equals `spike_counts`).
    pub spike_counts_by_layer: Vec<Vec<u32>>,
    /// Per-layer window activity: each layer's datapath events (adds,
    /// BRAM reads, comparator checks, toggles) plus the clocks attributed
    /// to its walk. The encoder front-end's events are shared, not
    /// per-layer, so these sum to slightly less than `activity`.
    pub activity_by_layer: Vec<ActivityCounters>,
    /// Per-layer energy under the core's model (same caveat as
    /// `activity_by_layer`).
    pub energy_by_layer: Vec<EnergyReport>,
}

/// The synthesizable top (paper Fig. 3) as a cycle-stepped simulator with a
/// batched-timestep fast path.
pub struct RtlCore {
    cfg: SnnConfig,
    weights: WeightStack,
    controller: LayerController,
    encoder: RtlPoissonEncoder,
    /// One neuron array per weight layer.
    neurons: Vec<LifNeuronArray>,
    /// Encoder front-end activity (PRNG steps, comparators, load toggles).
    /// Cycles are *not* counted here — every clock belongs to a layer.
    enc_act: ActivityCounters,
    /// Per-layer cumulative activity: each layer's datapath events plus
    /// the clocks attributed to its phases. Global totals are the sum of
    /// these with `enc_act` ([`RtlCore::total_activity`]).
    layer_act: Vec<ActivityCounters>,
    /// Clock mirror for VCD timestamps (equals the summed layer cycles).
    cycle_no: u64,
    energy_model: EnergyModel,
    /// Membrane snapshot log (per timestep, layers concatenated).
    membrane_log: Vec<Vec<i32>>,
    spike_log: Vec<Vec<bool>>,
    /// Current timestep's concatenated snapshots under construction.
    step_membranes: Vec<i32>,
    step_spikes: Vec<bool>,
    /// Reusable per-layer fire-pattern buffers.
    fired_scratch: Vec<Vec<bool>>,
    /// Reusable active-input index list for the fast path.
    active_scratch: Vec<u32>,
    /// CSR twin of `weights` for the event-driven sparse sweeps
    /// ([`RtlCore::attach_sparse`]). `None` until attached.
    sparse: Option<SparseWeightStack>,
    /// Pooled batched-sweep scratch (masks, planes, gates, encoders) —
    /// reused across chunks and across `run_fast_batch` calls.
    batch_scratch: BatchScratch,
    /// Cache-aware lane-chunk plan for the batched sweeps (defaults to
    /// the topology-calibrated [`ChunkPlan::for_topology`]).
    plan: ChunkPlan,
    /// Worker threads for the per-chunk neuron-range-sharded sweep
    /// (1 = the serial sweep; see [`RtlCore::with_batch_threads`]).
    batch_threads: usize,
    /// Optional waveform sink.
    vcd: Option<VcdWriter>,
}

impl RtlCore {
    /// Build a core from a config and any weight source convertible to a
    /// [`WeightStack`] (a bare [`crate::fixed::WeightMatrix`] becomes the
    /// single-layer chain).
    pub fn new(cfg: SnnConfig, weights: impl Into<WeightStack>) -> Result<Self> {
        let cfg = cfg.validated()?;
        let weights: WeightStack = weights.into();
        weights.check_topology(&cfg.topology)?;
        let n_layers = cfg.n_layers();
        let neurons: Vec<LifNeuronArray> =
            (0..n_layers).map(|l| LifNeuronArray::new(&cfg.layer_config(l))).collect();
        Ok(RtlCore {
            controller: LayerController::new(&cfg),
            encoder: RtlPoissonEncoder::new(cfg.n_inputs()),
            fired_scratch: (0..n_layers).map(|l| vec![false; cfg.layer_output(l)]).collect(),
            neurons,
            enc_act: ActivityCounters::default(),
            layer_act: vec![ActivityCounters::default(); n_layers],
            cycle_no: 0,
            energy_model: EnergyModel::default(),
            membrane_log: Vec::new(),
            spike_log: Vec::new(),
            step_membranes: Vec::new(),
            step_spikes: Vec::new(),
            active_scratch: Vec::with_capacity(cfg.n_inputs()),
            sparse: None,
            batch_scratch: BatchScratch {
                encoders: Vec::new(),
                arrays: (0..n_layers)
                    .map(|l| LifBatchArray::new(&cfg.layer_config(l), 0))
                    .collect(),
                layer_act: vec![Vec::new(); n_layers],
                step_fired: vec![Vec::new(); n_layers],
                masks: Vec::new(),
                gate: Vec::new(),
                apply: Vec::new(),
                idx: Vec::new(),
                fired: Vec::new(),
                active: Vec::new(),
                counts: Vec::new(),
                prune: (0..n_layers).map(|l| cfg.layer_prune(l)).collect(),
                active_mask: Vec::new(),
                ranges: Vec::new(),
                range_act: Vec::new(),
                worker_apply: Vec::new(),
            },
            plan: ChunkPlan::for_topology(&cfg.topology),
            batch_threads: 1,
            weights,
            cfg,
            vcd: None,
        })
    }

    /// Override the lane-chunk plan (bench comparisons against the
    /// calibrated default, width-sensitivity tests).
    pub fn with_chunk_plan(mut self, plan: ChunkPlan) -> Self {
        self.plan = plan;
        self
    }

    /// The lane-chunk plan the batched sweeps run under.
    pub fn chunk_plan(&self) -> ChunkPlan {
        self.plan
    }

    /// Run each batch chunk's per-layer sweep across `n` worker threads
    /// (neuron-range sharding). Results are **bit-identical at any
    /// thread count** — each layer's output neurons are partitioned into
    /// disjoint contiguous ranges, and the neuron-major planes make each
    /// range a private slice, so sharding only re-orders work across
    /// lanes/neurons whose per-cell event sequences are unchanged
    /// (pinned by `thread_count_invariance_*`). `n` ≤ 1 keeps the serial
    /// sweep; `FireMode::Immediate` configs always run serial (mid-walk
    /// fires re-gate the whole layer per integrate group, which is
    /// inherently sequential across neurons).
    pub fn with_batch_threads(mut self, n: usize) -> Self {
        self.batch_threads = n.max(1);
        self
    }

    /// Worker threads the sharded batch sweep uses.
    pub fn batch_threads(&self) -> usize {
        self.batch_threads
    }

    /// Override the energy model (ablations).
    pub fn with_energy_model(mut self, m: EnergyModel) -> Self {
        self.energy_model = m;
        self
    }

    /// Set the datapath width (pixels integrated per clock); see
    /// [`LayerController::set_pixels_per_cycle`]. Results are identical
    /// for any width (same architectural work per timestep — verified by
    /// test); only the cycle count changes.
    pub fn with_pixels_per_cycle(mut self, k: usize) -> Self {
        self.controller.set_pixels_per_cycle(k);
        self
    }

    /// Attach a VCD waveform writer; final-layer signals are dumped every
    /// cycle.
    pub fn attach_vcd(&mut self, vcd: VcdWriter) {
        self.vcd = Some(vcd);
    }

    /// Take back the VCD writer (to finish/flush it).
    pub fn detach_vcd(&mut self) -> Option<VcdWriter> {
        self.vcd.take()
    }

    pub fn config(&self) -> &SnnConfig {
        &self.cfg
    }

    /// Controller FSM state (observability).
    pub fn state(&self) -> CtrlState {
        self.controller.state()
    }

    /// Current membrane potentials of the final (output) layer.
    pub fn membranes(&self) -> Vec<i32> {
        self.neurons[self.neurons.len() - 1].membranes()
    }

    /// `load` pulse: latch an image + seed, reset all neuron state, leave
    /// the FSM in `Integrate{0,0}`.
    pub fn load_image(&mut self, img: &Image, seed: u32) -> Result<()> {
        if img.pixels.len() != self.cfg.n_inputs() {
            return Err(Error::ShapeMismatch(format!(
                "image {} pixels vs core {}",
                img.pixels.len(),
                self.cfg.n_inputs()
            )));
        }
        self.encoder.load(&img.pixels, seed, &mut self.enc_act);
        for (l, arr) in self.neurons.iter_mut().enumerate() {
            arr.reset(&mut self.layer_act[l]);
        }
        self.controller.start();
        self.membrane_log.clear();
        self.spike_log.clear();
        self.step_membranes.clear();
        self.step_spikes.clear();
        Ok(())
    }

    /// Advance exactly one clock. Returns `true` while the window is still
    /// running (`false` once `Done`).
    pub fn tick_cycle(&mut self) -> bool {
        let state = self.controller.state();
        match state {
            CtrlState::Idle | CtrlState::Done => return false,
            CtrlState::Integrate { layer, pixel } => {
                // One clock serves `pixels_per_cycle` lanes (1 = the
                // paper's Fig. 1 pixel-serial datapath). On layer 0 each
                // lane has its own encoder comparator; deeper layers read
                // the previous layer's spike accumulator instead. Spiking
                // lanes fetch their weight row and pulse the adder tree.
                // BRAM fetches happen only on a spike AND only while at
                // least one neuron of the layer is still enabled — once
                // pruning has gated the whole array, the weight memory
                // goes idle too. (Measured consequence: without that
                // gate, BRAM reads dominate dynamic energy and pruning
                // saves almost nothing — EXPERIMENTS.md ablation A.)
                let end =
                    (pixel + self.controller.pixels_per_cycle()).min(self.cfg.layer_input(layer));
                let any_enabled = self.controller.any_enabled(layer);
                for lane in pixel..end {
                    let spike = if layer == 0 {
                        self.encoder.tick_pixel(lane, &mut self.enc_act)
                    } else {
                        self.controller.step_fired(layer - 1)[lane]
                    };
                    if spike && any_enabled {
                        self.layer_act[layer].bram_reads += 1;
                        self.neurons[layer]
                            .add_row(self.weights.layer(layer).row(lane), &mut self.layer_act[layer]);
                    }
                }
                // Immediate fire mode: comparator is combinational on the
                // accumulator; fire mid-integration.
                if self.cfg.fire_mode == FireMode::Immediate {
                    self.fired_scratch[layer].fill(false);
                    let any = self.neurons[layer]
                        .immediate_fire(&mut self.fired_scratch[layer], &mut self.layer_act[layer]);
                    if any {
                        self.controller.latch_fire(
                            layer,
                            &self.fired_scratch[layer],
                            self.neurons[layer].spike_counts(),
                        );
                        self.apply_prune_mask(layer);
                    }
                }
            }
            CtrlState::Leak { layer, .. } => {
                self.neurons[layer].leak_enabled(&mut self.layer_act[layer]);
            }
            CtrlState::Fire { layer } => {
                self.fired_scratch[layer].fill(false);
                if self.cfg.fire_mode == FireMode::EndOfStep {
                    self.neurons[layer]
                        .fire_check(&mut self.fired_scratch[layer], &mut self.layer_act[layer]);
                }
                self.controller.latch_fire(
                    layer,
                    &self.fired_scratch[layer],
                    self.neurons[layer].spike_counts(),
                );
                self.apply_prune_mask(layer);
                self.step_membranes.extend_from_slice(self.neurons[layer].accs());
                self.step_spikes.extend_from_slice(&self.fired_scratch[layer]);
                if layer + 1 == self.neurons.len() {
                    self.membrane_log.push(std::mem::take(&mut self.step_membranes));
                    self.spike_log.push(std::mem::take(&mut self.step_spikes));
                }
            }
        }
        let layer = state.layer().expect("working states carry a layer");
        self.layer_act[layer].cycles += 1;
        self.cycle_no += 1;
        if let Some(v) = self.vcd.as_mut() {
            let last = self.neurons.len() - 1;
            let membranes = self.neurons[last].membranes();
            v.sample(
                self.cycle_no,
                &state,
                &membranes,
                self.controller.spike_reg(last),
                self.controller.enables(last),
            );
        }
        self.controller.advance();
        self.controller.state() != CtrlState::Done
    }

    /// Drive layer `l`'s enable latches from the controller's pruning mask.
    fn apply_prune_mask(&mut self, l: usize) {
        self.neurons[l].set_enables(self.controller.enables(l));
    }

    /// Run one full inference window through the cycle-stepped FSM.
    pub fn run(&mut self, img: &Image, seed: u32) -> Result<RtlResult> {
        self.load_image(img, seed)?;
        let start = self.total_activity();
        let start_layers = self.layer_act.clone();
        while self.tick_cycle() {}
        Ok(self.collect_result(&start, &start_layers))
    }

    /// Run one full inference window on the batched-timestep fast path
    /// (full window; see [`RtlCore::run_fast_early`] for the margin-exit
    /// variant).
    pub fn run_fast(&mut self, img: &Image, seed: u32) -> Result<RtlResult> {
        self.run_fast_early(img, seed, EarlyExit::Off)
    }

    /// Run one inference window on the fast path, optionally stopping
    /// early once the final layer's leading spike count beats the
    /// runner-up by the [`EarlyExit::Margin`] policy (checked between
    /// timesteps, the same schedule point as the behavioral model's
    /// check — `steps_run` parity is pinned by test).
    ///
    /// Produces an [`RtlResult`] byte-identical to [`RtlCore::run`] over
    /// the executed window (including [`ActivityCounters`] and the
    /// per-step logs) without walking the FSM clock by clock: per
    /// timestep and per layer the active inputs are bulk-gathered (layer
    /// 0 from the encoder comparators, deeper layers from the previous
    /// layer's spike accumulator), only spiking rows reach the adder
    /// tree, and cycle counts come from the closed-form schedule
    /// (`⌈n_in/k⌉` integrate + leak + fire clocks per layer). Falls back
    /// to the cycle path when a VCD sink is attached, which needs every
    /// clock (the fallback runs the full window — early exit is a hint).
    pub fn run_fast_early(
        &mut self,
        img: &Image,
        seed: u32,
        early: EarlyExit,
    ) -> Result<RtlResult> {
        if self.vcd.is_some() {
            return self.run(img, seed);
        }
        // Same clamp, same entry point as the behavioral model: margins
        // the output layer's prune cap makes unreachable are brought down
        // instead of silently running the full window.
        let early = early.clamped_for(&self.cfg);
        self.load_image(img, seed)?;
        let start = self.total_activity();
        let start_layers = self.layer_act.clone();

        let k = self.controller.pixels_per_cycle();
        let row_len = match self.cfg.leak_mode {
            LeakMode::PerRow { row_len } => Some(row_len),
            LeakMode::PerTimestep => None,
        };
        let n_layers = self.neurons.len();

        'window: for t in 0..self.cfg.timesteps {
            for l in 0..n_layers {
                match self.cfg.fire_mode {
                    FireMode::EndOfStep => {
                        self.fast_integrate_end_of_step(l, row_len);
                        // Closed-form clock counts for this layer's walk
                        // (EndOfStep only; the Immediate path counts
                        // incrementally because enables — and with them
                        // the schedule-relevant datapath state — can
                        // change per integrate clock).
                        let n_in = self.cfg.layer_input(l);
                        let integrate_clocks = n_in.div_ceil(k) as u64;
                        let leak_clocks = match (l, row_len) {
                            (0, Some(r)) => ((n_in - 1) / r + 1) as u64,
                            _ => 1,
                        };
                        self.layer_act[l].cycles += integrate_clocks + leak_clocks;
                        self.cycle_no += integrate_clocks + leak_clocks;
                    }
                    FireMode::Immediate => self.fast_integrate_immediate(l, k, row_len),
                }
                // The layer's Fire clock.
                self.fired_scratch[l].fill(false);
                if self.cfg.fire_mode == FireMode::EndOfStep {
                    self.neurons[l]
                        .fire_check(&mut self.fired_scratch[l], &mut self.layer_act[l]);
                }
                self.controller.latch_fire(
                    l,
                    &self.fired_scratch[l],
                    self.neurons[l].spike_counts(),
                );
                self.apply_prune_mask(l);
                self.step_membranes.extend_from_slice(self.neurons[l].accs());
                self.step_spikes.extend_from_slice(&self.fired_scratch[l]);
                self.layer_act[l].cycles += 1;
                self.cycle_no += 1;
            }
            self.controller.end_timestep();
            self.membrane_log.push(std::mem::take(&mut self.step_membranes));
            self.spike_log.push(std::mem::take(&mut self.step_spikes));

            if let EarlyExit::Margin { margin, min_steps } = early {
                // Same predicate (`util::margin_reached`), same schedule
                // point as the behavioral model's check in
                // `snn::network::run_inference` — and allocation-free,
                // where this loop used to clone + sort the whole count
                // vector every timestep.
                if t + 1 >= min_steps
                    && margin_reached(self.neurons[n_layers - 1].spike_counts(), margin)
                {
                    break 'window;
                }
            }
        }
        self.controller.finish();
        Ok(self.collect_result(&start, &start_layers))
    }

    /// Build (or rebuild) the CSR twin of the core's weight stack under
    /// magnitude threshold `threshold` (keep iff `|w| >= threshold`) and
    /// attach it for the event-driven sweeps. Threshold 0 keeps every
    /// entry, making [`RtlCore::run_fast_sparse`] bit-exact with the
    /// dense fast path; threshold ≥ 1 drops zeros and sub-threshold
    /// magnitudes, and the saved rows/synapses show up as lower
    /// [`ActivityCounters`].
    pub fn attach_sparse(&mut self, threshold: i32) {
        self.sparse = Some(self.weights.to_csr(threshold));
    }

    /// Attach a prebuilt CSR stack (must match the core's topology).
    pub fn attach_sparse_stack(&mut self, sparse: SparseWeightStack) -> Result<()> {
        sparse.check_topology(&self.cfg.topology)?;
        self.sparse = Some(sparse);
        Ok(())
    }

    /// Density of the attached CSR stack, if any.
    pub fn sparse_density(&self) -> Option<f64> {
        self.sparse.as_ref().map(SparseWeightStack::density)
    }

    /// Run one full inference window on the **event-driven sparse sweep**
    /// (requires [`RtlCore::attach_sparse`]); see
    /// [`RtlCore::run_fast_sparse_early`].
    pub fn run_fast_sparse(&mut self, img: &Image, seed: u32) -> Result<RtlResult> {
        self.run_fast_sparse_early(img, seed, EarlyExit::Off)
    }

    /// The sparse twin of [`RtlCore::run_fast_early`]: the same
    /// timestep/layer schedule, closed-form cycle counts, fire/leak/prune
    /// clocking and early-exit policy, but integration iterates only
    /// (active input × retained synapse) CSR entries instead of dense
    /// rows — a fully pruned row skips its BRAM pulse entirely, and each
    /// retained entry runs the identical per-add saturation and
    /// Hamming-toggle arithmetic as the dense adder tree
    /// (`lane_add_sparse`). At magnitude threshold 0 the CSR holds every
    /// entry, so the result — including every [`ActivityCounters`] field
    /// and per-step log — is bit-identical to the dense fast path
    /// (property-tested and pinned by all golden fixtures). At threshold
    /// ≥ 1 the schedule (cycles) is unchanged while adds/BRAM
    /// reads/toggles drop with density.
    pub fn run_fast_sparse_early(
        &mut self,
        img: &Image,
        seed: u32,
        early: EarlyExit,
    ) -> Result<RtlResult> {
        let sparse = self.sparse.take().ok_or_else(|| {
            Error::InvalidConfig("no sparse weights attached (call attach_sparse first)".into())
        })?;
        let out = self.run_sparse_window(&sparse, img, seed, early);
        self.sparse = Some(sparse);
        out
    }

    /// The sparse window body (split out so the CSR stack can be taken
    /// out of `self` for the duration — the integrate helpers need it
    /// alongside mutable neuron state).
    fn run_sparse_window(
        &mut self,
        sparse: &SparseWeightStack,
        img: &Image,
        seed: u32,
        early: EarlyExit,
    ) -> Result<RtlResult> {
        let early = early.clamped_for(&self.cfg);
        self.load_image(img, seed)?;
        let start = self.total_activity();
        let start_layers = self.layer_act.clone();

        let k = self.controller.pixels_per_cycle();
        let row_len = match self.cfg.leak_mode {
            LeakMode::PerRow { row_len } => Some(row_len),
            LeakMode::PerTimestep => None,
        };
        let n_layers = self.neurons.len();

        'window: for t in 0..self.cfg.timesteps {
            for l in 0..n_layers {
                match self.cfg.fire_mode {
                    FireMode::EndOfStep => {
                        self.sparse_integrate_end_of_step(sparse.layer(l), l, row_len);
                        // Closed-form clock counts: the FSM schedule walks
                        // every input lane regardless of weight contents,
                        // so sparsity changes datapath events, never
                        // clocks — identical to the dense fast path.
                        let n_in = self.cfg.layer_input(l);
                        let integrate_clocks = n_in.div_ceil(k) as u64;
                        let leak_clocks = match (l, row_len) {
                            (0, Some(r)) => ((n_in - 1) / r + 1) as u64,
                            _ => 1,
                        };
                        self.layer_act[l].cycles += integrate_clocks + leak_clocks;
                        self.cycle_no += integrate_clocks + leak_clocks;
                    }
                    FireMode::Immediate => {
                        self.sparse_integrate_immediate(sparse.layer(l), l, k, row_len)
                    }
                }
                // The layer's Fire clock — identical to the dense path.
                self.fired_scratch[l].fill(false);
                if self.cfg.fire_mode == FireMode::EndOfStep {
                    self.neurons[l]
                        .fire_check(&mut self.fired_scratch[l], &mut self.layer_act[l]);
                }
                self.controller.latch_fire(
                    l,
                    &self.fired_scratch[l],
                    self.neurons[l].spike_counts(),
                );
                self.apply_prune_mask(l);
                self.step_membranes.extend_from_slice(self.neurons[l].accs());
                self.step_spikes.extend_from_slice(&self.fired_scratch[l]);
                self.layer_act[l].cycles += 1;
                self.cycle_no += 1;
            }
            self.controller.end_timestep();
            self.membrane_log.push(std::mem::take(&mut self.step_membranes));
            self.spike_log.push(std::mem::take(&mut self.step_spikes));

            if let EarlyExit::Margin { margin, min_steps } = early {
                if t + 1 >= min_steps
                    && margin_reached(self.neurons[n_layers - 1].spike_counts(), margin)
                {
                    break 'window;
                }
            }
        }
        self.controller.finish();
        Ok(self.collect_result(&start, &start_layers))
    }

    /// Sparse twin of [`RtlCore::fast_integrate_end_of_step`]: same
    /// segment/leak structure, but each active input applies only its
    /// retained CSR entries, and a fully pruned row skips its BRAM pulse.
    fn sparse_integrate_end_of_step(
        &mut self,
        layer: &SparseWeightLayer,
        l: usize,
        row_len: Option<usize>,
    ) {
        let n_in = self.cfg.layer_input(l);
        let seg = if l == 0 { row_len.unwrap_or(n_in) } else { n_in };
        let any_enabled = self.controller.any_enabled(l);
        let mut start = 0usize;
        while start < n_in {
            let end = (start + seg).min(n_in);
            self.active_scratch.clear();
            if l == 0 {
                self.encoder.tick_range_into(start, end, &mut self.active_scratch, &mut self.enc_act);
            } else {
                let prev = self.controller.step_fired(l - 1);
                for p in start..end {
                    if prev[p] {
                        self.active_scratch.push(p as u32);
                    }
                }
            }
            if any_enabled {
                for &p in &self.active_scratch {
                    let (cols, vals) = layer.row(p as usize);
                    if cols.is_empty() {
                        // Silence skip: the whole row was pruned away, so
                        // the weight memory is never pulsed for it.
                        continue;
                    }
                    self.layer_act[l].bram_reads += 1;
                    self.neurons[l].add_row_sparse(cols, vals, &mut self.layer_act[l]);
                }
            }
            self.neurons[l].leak_enabled(&mut self.layer_act[l]);
            start = end;
        }
    }

    /// Sparse twin of [`RtlCore::fast_integrate_immediate`]: same k-wide
    /// group walk, mid-phase fire and leak clocking, CSR row application.
    fn sparse_integrate_immediate(
        &mut self,
        layer: &SparseWeightLayer,
        l: usize,
        k: usize,
        row_len: Option<usize>,
    ) {
        let n_in = self.cfg.layer_input(l);
        let mut pixel = 0usize;
        while pixel < n_in {
            let end = (pixel + k).min(n_in);
            let any_enabled = self.controller.any_enabled(l);
            self.active_scratch.clear();
            if l == 0 {
                self.encoder.tick_range_into(pixel, end, &mut self.active_scratch, &mut self.enc_act);
            } else {
                let prev = self.controller.step_fired(l - 1);
                for p in pixel..end {
                    if prev[p] {
                        self.active_scratch.push(p as u32);
                    }
                }
            }
            if any_enabled {
                for &p in &self.active_scratch {
                    let (cols, vals) = layer.row(p as usize);
                    if cols.is_empty() {
                        continue;
                    }
                    self.layer_act[l].bram_reads += 1;
                    self.neurons[l].add_row_sparse(cols, vals, &mut self.layer_act[l]);
                }
            }
            self.layer_act[l].cycles += 1; // the Integrate clock
            self.cycle_no += 1;
            self.fired_scratch[l].fill(false);
            let any = self.neurons[l]
                .immediate_fire(&mut self.fired_scratch[l], &mut self.layer_act[l]);
            if any {
                self.controller.latch_fire(
                    l,
                    &self.fired_scratch[l],
                    self.neurons[l].spike_counts(),
                );
                self.apply_prune_mask(l);
            }
            pixel = end;
            let row_boundary = l == 0 && row_len.is_some_and(|r| pixel % r == 0);
            if pixel == n_in || row_boundary {
                self.neurons[l].leak_enabled(&mut self.layer_act[l]);
                self.layer_act[l].cycles += 1; // the Leak clock
                self.cycle_no += 1;
            }
        }
    }

    /// Run a whole sub-batch of images through **one timestep sweep**:
    /// per timestep, each image's independent Poisson lanes are drawn,
    /// then every weight row is walked **once** and applied to every
    /// batch image whose input fired (bitset-transposed active masks —
    /// `mask[p]` bit `b` = image `b`'s input `p` spiked), so the row
    /// fetch that dominates the per-image fast path is amortized over the
    /// batch. Per-image early exit retires images from the sweep via
    /// batch compaction (the active-lane list shrinks; retired lanes stop
    /// drawing PRNG lanes and stop accruing cycles, exactly where the
    /// sequential engine would have stopped).
    ///
    /// **Bit-exact with the sequential path**: because the PRNG streams
    /// are per-`(image, seed)` and every lane's neuron state, activity
    /// counters and schedule are private, batching only reorders work
    /// *across* images — each image's own operations retain the exact
    /// sequential order. `run_fast_batch(images, seeds, early)[i]` equals
    /// `run_fast_early(images[i], seeds[i], early)` field for field,
    /// including [`ActivityCounters`] and the per-step logs (pinned by
    /// `batched_fast_path_equals_sequential` and the golden fixtures).
    /// Every lane's window activity folds into the core's cumulative
    /// totals, so cycle counts — and every window-attributed event —
    /// in [`RtlCore::total_activity`] stay exact under batching. The
    /// *load-pulse* toggle events (encoder re-seed / accumulator reset
    /// Hamming distances, which are excluded from every window) are
    /// those of the pooled per-lane encoder state, so they can differ
    /// from a reused sequential core's — they depend on engine reuse
    /// history, which already varies with pool assignment.
    ///
    /// Falls back to per-image [`RtlCore::run_fast_early`] when a VCD
    /// sink is attached (waveforms need every clock of one engine).
    /// Sub-batches larger than the core's [`ChunkPlan`] width are
    /// processed in plan-width chunks (≤ [`BATCH_LANES`]); with
    /// [`RtlCore::with_batch_threads`] each chunk's layer sweeps are
    /// additionally sharded across worker threads by neuron range —
    /// both knobs change throughput only, never results.
    pub fn run_fast_batch(
        &mut self,
        images: &[&Image],
        seeds: &[u32],
        early: EarlyExit,
    ) -> Result<Vec<RtlResult>> {
        if images.len() != seeds.len() {
            return Err(Error::ShapeMismatch(format!(
                "batch of {} images vs {} seeds",
                images.len(),
                seeds.len()
            )));
        }
        if self.vcd.is_some() {
            return images
                .iter()
                .zip(seeds)
                .map(|(img, &seed)| self.run_fast_early(img, seed, early))
                .collect();
        }
        let mut out = Vec::with_capacity(images.len());
        let lanes = self.plan.lanes();
        for (imgs, sds) in images.chunks(lanes).zip(seeds.chunks(lanes)) {
            self.run_batch_chunk(imgs, sds, early, None, &mut out)?;
        }
        Ok(out)
    }

    /// The sparse arm of [`RtlCore::run_fast_batch`] (requires
    /// [`RtlCore::attach_sparse`]): the same one-timestep-sweep batching —
    /// each retained weight row fetched once per timestep and applied to
    /// every lane whose input fired — but row application iterates only
    /// CSR entries, and fully pruned rows skip their fetch for the whole
    /// batch. Bit-exact lane-for-lane with
    /// [`RtlCore::run_fast_sparse_early`] (and, at threshold 0, with the
    /// dense engines). Does not sample VCD (waveform capture stays on the
    /// dense cycle path).
    pub fn run_fast_batch_sparse(
        &mut self,
        images: &[&Image],
        seeds: &[u32],
        early: EarlyExit,
    ) -> Result<Vec<RtlResult>> {
        if images.len() != seeds.len() {
            return Err(Error::ShapeMismatch(format!(
                "batch of {} images vs {} seeds",
                images.len(),
                seeds.len()
            )));
        }
        let sparse = self.sparse.take().ok_or_else(|| {
            Error::InvalidConfig("no sparse weights attached (call attach_sparse first)".into())
        })?;
        let mut out = Vec::with_capacity(images.len());
        let mut result = Ok(());
        let lanes = self.plan.lanes();
        for (imgs, sds) in images.chunks(lanes).zip(seeds.chunks(lanes)) {
            result = self.run_batch_chunk(imgs, sds, early, Some(&sparse), &mut out);
            if result.is_err() {
                break;
            }
        }
        self.sparse = Some(sparse);
        result.map(|()| out)
    }

    // pallas-lint: hot
    /// One ≤[`BATCH_LANES`]-image chunk of [`RtlCore::run_fast_batch`]
    /// (dense when `sparse` is `None`, CSR row application otherwise).
    fn run_batch_chunk(
        &mut self,
        images: &[&Image],
        seeds: &[u32],
        early: EarlyExit,
        sparse: Option<&SparseWeightStack>,
        out: &mut Vec<RtlResult>,
    ) -> Result<()> {
        let n_inputs = self.cfg.n_inputs();
        for img in images {
            if img.pixels.len() != n_inputs {
                // pallas-lint: allow(alloc) reason=cold shape-validation error path
                return Err(Error::ShapeMismatch(format!(
                    "image {} pixels vs core {}",
                    img.pixels.len(),
                    n_inputs
                )));
            }
        }
        let early = early.clamped_for(&self.cfg);
        let n_layers = self.cfg.n_layers();
        let b_n = images.len();
        // Lane-mask words for this chunk: sized to the chunk actually
        // running, so small batches keep single-word masks.
        let lw = b_n.div_ceil(64).max(1);
        let row_len = match self.cfg.leak_mode {
            LeakMode::PerRow { row_len } => Some(row_len),
            LeakMode::PerTimestep => None,
        };
        let max_width =
            (0..n_layers).map(|l| self.cfg.layer_output(l)).max().expect("≥1 layer");

        // Re-arm the pooled scratch arena for this chunk. Everything here
        // reuses the buffers of previous chunks/calls (allocation-free in
        // steady state — pinned by `batch_scratch_is_reused…`); only the
        // per-lane result logs below are fresh, because they are moved
        // into each lane's `RtlResult`.
        let s = &mut self.batch_scratch;
        while s.encoders.len() < b_n {
            s.encoders.push(RtlPoissonEncoder::new(n_inputs));
        }
        for arr in &mut s.arrays {
            arr.reset(b_n);
        }
        for acts in &mut s.layer_act {
            acts.clear();
            acts.resize(b_n, ActivityCounters::default());
        }
        for (l, f) in s.step_fired.iter_mut().enumerate() {
            f.clear();
            f.resize(self.cfg.layer_output(l) * lw, 0);
        }
        s.masks.clear();
        s.masks.resize(n_inputs * lw, 0);
        s.gate.clear();
        s.gate.resize(lw, 0);
        s.apply.clear();
        s.apply.resize(lw, 0);
        s.fired.clear();
        s.fired.resize(max_width, false);
        s.active.clear();
        s.active.extend(0..b_n);

        // Per-lane state: pooled encoder (re-seeded by the load pulse,
        // exactly like the sequential core's) + per-image logs. The load
        // pulse is recorded separately — the sequential engines snapshot
        // their window *after* `load_image`, so seeding-network events
        // belong to the cumulative totals, not the per-image window.
        // pallas-lint: allow(alloc) reason=per-lane result logs are moved into each RtlResult
        let mut lanes: Vec<BatchLane> = (0..b_n).map(|_| BatchLane::default()).collect();
        for (b, (img, &seed)) in images.iter().zip(seeds).enumerate() {
            s.encoders[b].load(&img.pixels, seed, &mut lanes[b].load_act);
        }

        let mut run = BatchRun {
            cfg: &self.cfg,
            weights: &self.weights,
            sparse,
            k: self.controller.pixels_per_cycle(),
            row_len,
            lw,
            lanes,
            s,
        };

        // Thread-parallel sharding applies to `EndOfStep` sweeps only:
        // an `Immediate` walk's mid-group fires re-gate the whole layer
        // per integrate clock, which is inherently sequential across the
        // walk, so it keeps the serial sweep at any thread setting.
        let threads = self.batch_threads;
        for t in 0..self.cfg.timesteps {
            for l in 0..n_layers {
                match self.cfg.fire_mode {
                    FireMode::EndOfStep => {
                        if threads > 1 {
                            run.sweep_end_of_step_sharded(l, threads);
                        } else {
                            run.integrate_end_of_step(l);
                        }
                        // Closed-form clock counts, as on the sequential
                        // fast path — identical for every active lane
                        // (the schedule depends only on the config).
                        let n_in = self.cfg.layer_input(l);
                        let integrate_clocks = n_in.div_ceil(run.k) as u64;
                        let leak_clocks = match (l, row_len) {
                            (0, Some(r)) => ((n_in - 1) / r + 1) as u64,
                            _ => 1,
                        };
                        for &b in &run.s.active {
                            run.s.layer_act[l][b].cycles += integrate_clocks + leak_clocks;
                        }
                        if threads > 1 {
                            // The sharded sweep already committed the
                            // fire checks and prune latches in-range;
                            // only the per-lane snapshots and the Fire
                            // clock remain.
                            run.fire_gather(l);
                        } else {
                            run.fire_clock(l);
                        }
                    }
                    FireMode::Immediate => {
                        run.integrate_immediate(l);
                        run.fire_clock(l);
                    }
                }
            }
            run.close_timestep();
            if let EarlyExit::Margin { margin, min_steps } = early {
                // Same predicate, same schedule point as the sequential
                // engines; confident lanes retire from the sweep.
                if t + 1 >= min_steps {
                    run.retire_confident(margin);
                }
            }
            if run.s.active.is_empty() {
                break;
            }
        }

        let BatchRun { lanes, s, .. } = run;
        for (b, lane) in lanes.into_iter().enumerate() {
            let mut window = lane.enc_act;
            // pallas-lint: allow(alloc) reason=owned by the returned RtlResult
            let activity_by_layer: Vec<ActivityCounters> =
                (0..n_layers).map(|l| s.layer_act[l][b]).collect();
            for la in &activity_by_layer {
                window.add(la);
            }
            // Fold the lane into the core's cumulative totals so backend
            // cycle accounting (and every window-attributed event) stays
            // exact under batching; see the method docs for the
            // load-pulse toggle caveat.
            self.enc_act.add(&lane.load_act);
            self.enc_act.add(&lane.enc_act);
            for (l, la) in activity_by_layer.iter().enumerate() {
                self.layer_act[l].add(la);
            }
            self.cycle_no += window.cycles;

            let energy = self.energy_model.evaluate(&window);
            let energy_by_layer = self.energy_model.evaluate_layers(&activity_by_layer);
            // pallas-lint: allow(alloc) reason=owned by the returned RtlResult
            let spike_counts_by_layer: Vec<Vec<u32>> =
                s.arrays.iter().map(|a| a.spike_counts(b)).collect();
            let spike_counts =
                spike_counts_by_layer.last().cloned().expect("core has at least one layer");
            out.push(RtlResult {
                class: LayerController::decide(&spike_counts),
                spike_counts,
                cycles: window.cycles,
                activity: window,
                energy,
                membrane_by_step: lane.membrane_log,
                spikes_by_step: lane.spike_log,
                spike_counts_by_layer,
                activity_by_layer,
                energy_by_layer,
            });
        }
        Ok(())
    }
    // pallas-lint: end-hot

    /// One layer's integrate + leak phases, `FireMode::EndOfStep`.
    ///
    /// Enables cannot change mid-walk in this mode (pruning only acts on
    /// Fire clocks), so the BRAM gate is hoisted out of the input loop and
    /// the whole leak segment structure reduces to: one segment per image
    /// row on layer 0 in `PerRow` mode, or one segment for the full walk,
    /// each followed by its Leak clock — the last segment's leak being the
    /// end-of-walk leak, exactly as the FSM schedules it.
    fn fast_integrate_end_of_step(&mut self, l: usize, row_len: Option<usize>) {
        let n_in = self.cfg.layer_input(l);
        let seg = if l == 0 { row_len.unwrap_or(n_in) } else { n_in };
        let any_enabled = self.controller.any_enabled(l);
        let mut start = 0usize;
        while start < n_in {
            let end = (start + seg).min(n_in);
            self.active_scratch.clear();
            if l == 0 {
                self.encoder.tick_range_into(start, end, &mut self.active_scratch, &mut self.enc_act);
            } else {
                let prev = self.controller.step_fired(l - 1);
                for p in start..end {
                    if prev[p] {
                        self.active_scratch.push(p as u32);
                    }
                }
            }
            if any_enabled {
                for &p in &self.active_scratch {
                    self.layer_act[l].bram_reads += 1;
                    self.neurons[l]
                        .add_row(self.weights.layer(l).row(p as usize), &mut self.layer_act[l]);
                }
            }
            self.neurons[l].leak_enabled(&mut self.layer_act[l]);
            start = end;
        }
    }

    /// One layer's integrate + leak phases, `FireMode::Immediate`.
    ///
    /// Replays the FSM's exact grouping: each integrate clock serves `k`
    /// input lanes, then the combinational threshold check fires (and
    /// possibly prunes) mid-phase; leak clocks land on row boundaries
    /// (layer 0 only) and at the end of the walk. Cycle counting is
    /// incremental because the schedule is walked group by group.
    fn fast_integrate_immediate(&mut self, l: usize, k: usize, row_len: Option<usize>) {
        let n_in = self.cfg.layer_input(l);
        let mut pixel = 0usize;
        while pixel < n_in {
            let end = (pixel + k).min(n_in);
            let any_enabled = self.controller.any_enabled(l);
            self.active_scratch.clear();
            if l == 0 {
                self.encoder.tick_range_into(pixel, end, &mut self.active_scratch, &mut self.enc_act);
            } else {
                let prev = self.controller.step_fired(l - 1);
                for p in pixel..end {
                    if prev[p] {
                        self.active_scratch.push(p as u32);
                    }
                }
            }
            if any_enabled {
                for &p in &self.active_scratch {
                    self.layer_act[l].bram_reads += 1;
                    self.neurons[l]
                        .add_row(self.weights.layer(l).row(p as usize), &mut self.layer_act[l]);
                }
            }
            self.layer_act[l].cycles += 1; // the Integrate clock
            self.cycle_no += 1;
            self.fired_scratch[l].fill(false);
            let any = self.neurons[l]
                .immediate_fire(&mut self.fired_scratch[l], &mut self.layer_act[l]);
            if any {
                self.controller.latch_fire(
                    l,
                    &self.fired_scratch[l],
                    self.neurons[l].spike_counts(),
                );
                self.apply_prune_mask(l);
            }
            pixel = end;
            let row_boundary = l == 0 && row_len.is_some_and(|r| pixel % r == 0);
            if pixel == n_in || row_boundary {
                self.neurons[l].leak_enabled(&mut self.layer_act[l]);
                self.layer_act[l].cycles += 1; // the Leak clock
                self.cycle_no += 1;
            }
        }
    }

    /// Package the window's outputs + activity deltas into an
    /// [`RtlResult`].
    fn collect_result(
        &mut self,
        start: &ActivityCounters,
        start_layers: &[ActivityCounters],
    ) -> RtlResult {
        let window = self.total_activity().since(start);
        let activity_by_layer: Vec<ActivityCounters> = self
            .layer_act
            .iter()
            .zip(start_layers)
            .map(|(a, s)| a.since(s))
            .collect();
        let energy = self.energy_model.evaluate(&window);
        let energy_by_layer = self.energy_model.evaluate_layers(&activity_by_layer);
        let spike_counts_by_layer: Vec<Vec<u32>> =
            self.neurons.iter().map(|n| n.spike_counts().to_vec()).collect();
        let spike_counts =
            spike_counts_by_layer.last().cloned().expect("core has at least one layer");
        RtlResult {
            class: LayerController::decide(&spike_counts),
            spike_counts,
            cycles: window.cycles,
            activity: window,
            energy,
            membrane_by_step: std::mem::take(&mut self.membrane_log),
            spikes_by_step: std::mem::take(&mut self.spike_log),
            spike_counts_by_layer,
            activity_by_layer,
            energy_by_layer,
        }
    }

    /// Cumulative activity across all windows run so far: encoder
    /// front-end events plus every layer's datapath events and clocks.
    pub fn total_activity(&self) -> ActivityCounters {
        let mut total = self.enc_act;
        for la in &self.layer_act {
            total.add(la);
        }
        total
    }

    /// Cumulative per-layer activity across all windows run so far.
    pub fn layer_activity(&self) -> &[ActivityCounters] {
        &self.layer_act
    }

    /// Test-only fingerprint of the batched-sweep scratch arena: the
    /// `(pointer, capacity)` pair of every pooled buffer. Two equal
    /// fingerprints across `run_fast_batch` calls prove the hot loop
    /// re-used its scratch in place instead of re-allocating (the alloc-
    /// free pin mirroring the PR 4 `top2` fix).
    #[cfg(test)]
    pub(crate) fn batch_scratch_fingerprint(&self) -> Vec<(usize, usize)> {
        fn fp<T>(v: &Vec<T>) -> (usize, usize) {
            (v.as_ptr() as usize, v.capacity())
        }
        let s = &self.batch_scratch;
        let mut out = vec![
            fp(&s.encoders),
            fp(&s.masks),
            fp(&s.gate),
            fp(&s.apply),
            fp(&s.idx),
            fp(&s.fired),
            fp(&s.active),
            fp(&s.counts),
        ];
        out.extend(s.step_fired.iter().map(fp));
        out.extend(s.layer_act.iter().map(fp));
        out.extend(s.arrays.iter().flat_map(|a| a.plane_fingerprint()));
        out.push(fp(&s.active_mask));
        out.push(fp(&s.ranges));
        out.extend(s.range_act.iter().map(fp));
        out.extend(s.worker_apply.iter().map(fp));
        out
    }
}

/// Per-image state of one batched sweep lane: its activity buckets and
/// per-step logs. The lane's encoder lives in the pooled
/// [`BatchScratch`]; the logs stay here because they are moved into the
/// lane's [`RtlResult`].
#[derive(Default)]
struct BatchLane {
    /// Load-pulse events (seeding network): folded into the core's
    /// cumulative totals, excluded from the per-image window — the
    /// sequential engines snapshot their window *after* `load_image`.
    load_act: ActivityCounters,
    enc_act: ActivityCounters,
    membrane_log: Vec<Vec<i32>>,
    spike_log: Vec<Vec<bool>>,
    step_membranes: Vec<i32>,
    step_spikes: Vec<bool>,
}

/// Reusable batched-sweep scratch, hoisted onto the pooled core so mask
/// words, accumulator planes, counter planes and encoders are armed in
/// place across chunks *and* across `run_fast_batch` calls instead of
/// reallocated per chunk (the PR 4 `top2` fix, applied to the whole
/// batch engine). Per-lane result logs are the one exception — they are
/// moved into each `RtlResult`, so `BatchLane` keeps them.
///
/// Every lane mask in here is multi-word: `lw = lanes.div_ceil(64)`
/// words per neuron/pixel, lane `b` at word `b / 64`, bit `b % 64` —
/// the same word-walk idiom as `LifBatchArray`'s per-neuron enable mask.
struct BatchScratch {
    /// Pooled per-lane encoders, grown on demand and fully re-seeded by
    /// each chunk's load pulse (only the load-pulse *toggle counts*
    /// depend on prior contents; those are excluded from result windows).
    encoders: Vec<RtlPoissonEncoder>,
    /// Per-layer neuron-major accumulator/spike planes, re-armed via
    /// `reset(lanes)`.
    arrays: Vec<LifBatchArray>,
    /// Per-layer, per-lane activity buckets: `layer_act[l][b]`. Lives
    /// here (not in `BatchLane`) so a wide sweep can borrow one layer's
    /// whole counter plane alongside the lane masks.
    layer_act: Vec<Vec<ActivityCounters>>,
    /// Per-layer transposed fire masks for the current timestep:
    /// `step_fired[l][j * lw + b / 64]` bit `b % 64` = lane `b`'s neuron
    /// `j` fired this step — the inter-layer hand-off register,
    /// batch-wide. Cleared at the end of each timestep like the
    /// controller's accumulator.
    step_fired: Vec<Vec<u64>>,
    /// Layer-0 transposed input masks, `masks[p * lw + wb]` (rebuilt per
    /// segment/group from the per-lane encoder draws).
    masks: Vec<u64>,
    /// BRAM gate over lanes (`lw` words), hoisted per walk/group.
    gate: Vec<u64>,
    /// Per-row apply mask (`lw` words): `src & gate`.
    apply: Vec<u64>,
    /// Per-lane encoder spike-index scratch.
    idx: Vec<u32>,
    /// Per-lane fire-pattern scratch (sized to the widest layer).
    fired: Vec<bool>,
    /// Lanes still running, in submission order. Early exit compacts this
    /// list; retired lanes drop out of every subsequent sweep.
    active: Vec<usize>,
    /// Final-layer spike-count gather scratch for the retire predicate.
    counts: Vec<u32>,
    /// Per-layer resolved pruning policy (mirrors the controller's).
    prune: Vec<PruneMode>,
    /// Active-lane bitmask (`lw` words) for the sharded sweep's
    /// leak/fire gating — the mask twin of the `active` list.
    active_mask: Vec<u64>,
    /// Neuron-range partition of the current layer for the sharded
    /// sweep: `[j0, j1)` per worker, re-tiled per layer.
    ranges: Vec<(usize, usize)>,
    /// Per-worker, per-lane activity buckets for the sharded sweep
    /// (`range_act[w][b]`): workers tally privately with zero sharing,
    /// then the serial merge sums them into `layer_act` — u64 sums, so
    /// the merge is reorder-invariant. Grown on demand, re-armed per
    /// layer sweep.
    range_act: Vec<Vec<ActivityCounters>>,
    /// Per-worker apply-mask words (`lw` each): every worker computes
    /// the same `src & gate` row mask, but into its own words so the
    /// sweep shares nothing mutable.
    worker_apply: Vec<Vec<u64>>,
}

/// One in-flight batched sweep: the transposed-mask schedule walker
/// behind [`RtlCore::run_fast_batch`]. Field-disjoint from the core's
/// single-image state — a batch run never disturbs `RtlCore::neurons` or
/// the controller registers. All planes/masks live in the borrowed
/// [`BatchScratch`] arena.
struct BatchRun<'a> {
    cfg: &'a SnnConfig,
    weights: &'a WeightStack,
    /// When set, `apply_rows` integrates CSR entries instead of dense
    /// rows (the sparse arm of the batched sweep).
    sparse: Option<&'a SparseWeightStack>,
    k: usize,
    row_len: Option<usize>,
    /// Lane-mask words for this chunk: `chunk_lanes.div_ceil(64)`.
    lw: usize,
    lanes: Vec<BatchLane>,
    s: &'a mut BatchScratch,
}

impl BatchRun<'_> {
    // pallas-lint: hot
    /// Per-lane BRAM gate as a multi-word bitmask over lanes, written
    /// into the scratch `gate` words. Under `EndOfStep` firing enables
    /// cannot change mid-walk, so the caller hoists this out of the walk
    /// exactly like the sequential engine; `Immediate` recomputes it per
    /// integrate group.
    fn bram_gate(&mut self, l: usize) {
        self.s.gate.fill(0);
        for i in 0..self.s.active.len() {
            let b = self.s.active[i];
            if self.s.arrays[l].any_enabled(b) {
                self.s.gate[b / 64] |= 1 << (b % 64);
            }
        }
    }

    /// Draw every active lane's Poisson comparators for input range
    /// `start..end` into the transposed masks. Each lane's PRNG stream
    /// advances exactly as its sequential window would — retired lanes
    /// draw nothing.
    fn draw_layer0(&mut self, start: usize, end: usize) {
        let lw = self.lw;
        self.s.masks[start * lw..end * lw].fill(0);
        for i in 0..self.s.active.len() {
            let b = self.s.active[i];
            let lane = &mut self.lanes[b];
            self.s.idx.clear();
            self.s.encoders[b].tick_range_into(start, end, &mut self.s.idx, &mut lane.enc_act);
            for &p in &self.s.idx {
                self.s.masks[p as usize * lw + b / 64] |= 1 << (b % 64);
            }
        }
    }

    /// The row-reuse inner loop: for each input of `start..end`, fetch
    /// its weight row **once** and integrate it into every gated lane
    /// whose input fired via one neuron-major wide sweep
    /// (`add_row_lanes` / `add_sparse_lanes`). Ascending `p` preserves
    /// each lane's sequential row order; per-lane BRAM reads and adder
    /// activity land in that lane's own counters.
    fn apply_rows(&mut self, l: usize, start: usize, end: usize) {
        let lw = self.lw;
        for p in start..end {
            let src = if l == 0 {
                &self.s.masks[p * lw..(p + 1) * lw]
            } else {
                &self.s.step_fired[l - 1][p * lw..(p + 1) * lw]
            };
            let mut any = 0u64;
            for wb in 0..lw {
                let m = src[wb] & self.s.gate[wb];
                self.s.apply[wb] = m;
                any |= m;
            }
            if any == 0 {
                continue;
            }
            if let Some(sp) = self.sparse {
                // CSR arm: a fully pruned row skips its fetch for the
                // whole batch; retained entries run the same per-add
                // arithmetic across all applied lanes.
                let (cols, vals) = sp.layer(l).row(p);
                if cols.is_empty() {
                    continue;
                }
                for wb in 0..lw {
                    let mut m = self.s.apply[wb];
                    while m != 0 {
                        let b = wb * 64 + m.trailing_zeros() as usize;
                        m &= m - 1;
                        self.s.layer_act[l][b].bram_reads += 1;
                    }
                }
                let acts = &mut self.s.layer_act[l];
                self.s.arrays[l].add_sparse_lanes(&self.s.apply, cols, vals, acts);
            } else {
                let row = self.weights.layer(l).row(p);
                for wb in 0..lw {
                    let mut m = self.s.apply[wb];
                    while m != 0 {
                        let b = wb * 64 + m.trailing_zeros() as usize;
                        m &= m - 1;
                        self.s.layer_act[l][b].bram_reads += 1;
                    }
                }
                self.s.arrays[l].add_row_lanes(&self.s.apply, row, &mut self.s.layer_act[l]);
            }
        }
    }

    /// One layer's integrate + leak phases, `FireMode::EndOfStep` —
    /// the batched mirror of `fast_integrate_end_of_step`: one segment
    /// per image row on layer 0 in `PerRow` mode (or one for the full
    /// walk), each followed by its Leak clock on every active lane.
    fn integrate_end_of_step(&mut self, l: usize) {
        let n_in = self.cfg.layer_input(l);
        let seg = if l == 0 { self.row_len.unwrap_or(n_in) } else { n_in };
        self.bram_gate(l);
        let mut start = 0usize;
        while start < n_in {
            let end = (start + seg).min(n_in);
            if l == 0 {
                self.draw_layer0(start, end);
            }
            self.apply_rows(l, start, end);
            for i in 0..self.s.active.len() {
                let b = self.s.active[i];
                let (arrays, acts) = (&mut self.s.arrays, &mut self.s.layer_act);
                arrays[l].leak_enabled(b, &mut acts[l][b]);
            }
            start = end;
        }
    }

    /// One layer's integrate + leak + fire phases under
    /// `FireMode::EndOfStep`, sharded across `threads` worker threads by
    /// neuron range — the thread-parallel twin of
    /// [`BatchRun::integrate_end_of_step`] plus the in-range half of
    /// [`BatchRun::fire_clock`] (the serial remainder is
    /// [`BatchRun::fire_gather`]).
    ///
    /// Soundness of the zero-barrier split: under `EndOfStep` the BRAM
    /// gate and enable masks are fixed for the whole walk (pruning only
    /// latches at the fire clock), layer 0's Poisson comparators are
    /// per-pixel independent PRNG streams (so the whole walk's draws are
    /// hoisted ahead of the scope — same masks and per-lane tallies as
    /// the per-segment draws), and every mutable word — plane cells,
    /// step-fired words, activity buckets, apply masks — is owned by
    /// exactly one worker: neuron-major planes make a contiguous neuron
    /// range a contiguous plane slice, carved with `split_at_mut`, so
    /// the borrow checker proves disjointness with no locks and no
    /// `unsafe`. Each (neuron, lane) cell therefore commits exactly the
    /// sequential sweep's event sequence — rows ascending, leak per
    /// segment, fire, prune — and per-lane counters are order-invariant
    /// u64 sums, so results are bit-identical at any thread count
    /// (pinned by `thread_count_invariance_*` and the sharded fixture
    /// replay). The one whole-row tally, the per-lane BRAM read, is
    /// owned by rank 0 alone. The scope's implicit join is the
    /// per-layer barrier: `step_fired[l]` is complete before the serial
    /// gather and the next layer's walk read it.
    fn sweep_end_of_step_sharded(&mut self, l: usize, threads: usize) {
        let n_in = self.cfg.layer_input(l);
        let n_out = self.s.arrays[l].width();
        let b_n = self.lanes.len();
        let lw = self.lw;
        let seg = if l == 0 { self.row_len.unwrap_or(n_in) } else { n_in };
        let t_eff = threads.min(n_out).max(1);
        self.bram_gate(l);
        if l == 0 {
            self.draw_layer0(0, n_in);
        }
        let layer = self.weights.layer(l);
        let sparse_layer = self.sparse.map(|sp| sp.layer(l));
        let s = &mut *self.s;

        // Arm the pooled per-worker scratch — grow-on-demand once, then
        // re-armed in place like the rest of the arena.
        s.active_mask.clear();
        s.active_mask.resize(lw, 0);
        for i in 0..s.active.len() {
            let b = s.active[i];
            s.active_mask[b / 64] |= 1 << (b % 64);
        }
        s.ranges.clear();
        let (base, rem) = (n_out / t_eff, n_out % t_eff);
        let mut next_j = 0usize;
        for w in 0..t_eff {
            let j1 = next_j + base + usize::from(w < rem);
            s.ranges.push((next_j, j1));
            next_j = j1;
        }
        while s.range_act.len() < t_eff {
            // pallas-lint: allow(alloc) reason=grow-on-demand pooled per-worker tallies
            s.range_act.push(Vec::new());
        }
        while s.worker_apply.len() < t_eff {
            // pallas-lint: allow(alloc) reason=grow-on-demand pooled per-worker masks
            s.worker_apply.push(Vec::new());
        }
        for ra in s.range_act.iter_mut().take(t_eff) {
            ra.clear();
            ra.resize(b_n, ActivityCounters::default());
        }
        for ap in s.worker_apply.iter_mut().take(t_eff) {
            ap.clear();
            ap.resize(lw, 0);
        }

        let BatchScratch {
            arrays,
            step_fired,
            masks,
            gate,
            active_mask,
            ranges,
            range_act,
            worker_apply,
            layer_act,
            prune,
            ..
        } = s;
        let prune_mode = prune[l];
        let (prev_layers, cur) = step_fired.split_at_mut(l);
        let src_plane: &[u64] = if l == 0 { masks } else { &prev_layers[l - 1] };
        let mut cur: &mut [u64] = &mut cur[0][..];
        let (gate, active_mask) = (&gate[..], &active_mask[..]);
        // pallas-lint: allow(alloc) reason=per-sweep shard list, bounded by the thread count
        let shards = arrays[l].shards(&ranges[..]);
        std::thread::scope(|scope| {
            let mut acts = range_act.iter_mut();
            let mut applies = worker_apply.iter_mut();
            for (w, mut shard) in shards.into_iter().enumerate() {
                let (sf_part, rest) =
                    std::mem::take(&mut cur).split_at_mut(shard.width() * lw);
                cur = rest;
                let ra = &mut acts.next().expect("armed above")[..];
                let ap = &mut applies.next().expect("armed above")[..];
                scope.spawn(move || {
                    let (j0, j1) = (shard.start(), shard.start() + shard.width());
                    let mut start = 0usize;
                    while start < n_in {
                        let end = (start + seg).min(n_in);
                        for p in start..end {
                            let src = &src_plane[p * lw..(p + 1) * lw];
                            let mut any = 0u64;
                            for wb in 0..lw {
                                let m = src[wb] & gate[wb];
                                ap[wb] = m;
                                any |= m;
                            }
                            if any == 0 {
                                continue;
                            }
                            if let Some(sp) = sparse_layer {
                                let (all_cols, _) = sp.row(p);
                                if all_cols.is_empty() {
                                    continue;
                                }
                                if w == 0 {
                                    // One BRAM read per fetched row per
                                    // applied lane — a whole-row event,
                                    // so rank 0 alone owns the tally.
                                    for wb in 0..lw {
                                        let mut m = ap[wb];
                                        while m != 0 {
                                            let b = wb * 64 + m.trailing_zeros() as usize;
                                            m &= m - 1;
                                            ra[b].bram_reads += 1;
                                        }
                                    }
                                }
                                let (cols, vals) = sp.row_span(p, j0, j1);
                                shard.add_sparse_lanes(ap, cols, vals, ra);
                            } else {
                                if w == 0 {
                                    for wb in 0..lw {
                                        let mut m = ap[wb];
                                        while m != 0 {
                                            let b = wb * 64 + m.trailing_zeros() as usize;
                                            m &= m - 1;
                                            ra[b].bram_reads += 1;
                                        }
                                    }
                                }
                                let row = layer.row(p);
                                shard.add_row_lanes(ap, &row[j0..j1], ra);
                            }
                        }
                        shard.leak_lanes(active_mask, ra);
                        start = end;
                    }
                    shard.fire_check_lanes(active_mask, sf_part, ra);
                    shard.latch_prune_lanes(active_mask, prune_mode);
                });
            }
        });
        // Serial merge of the per-worker tallies into the per-lane layer
        // buckets — u64 sums commute, so worker order cannot affect the
        // totals. Merged buckets are cleared so a later sweep with fewer
        // workers can never double-count a stale bucket.
        for ra in range_act.iter_mut().take(t_eff) {
            for (dst, src) in layer_act[l].iter_mut().zip(ra.iter()) {
                dst.add(src);
            }
            ra.clear();
        }
    }

    /// One layer's integrate + leak phases, `FireMode::Immediate` — the
    /// batched mirror of `fast_integrate_immediate`: each integrate clock
    /// serves `k` input lanes, the combinational threshold check fires
    /// (and possibly prunes) mid-phase per lane, and leak clocks land on
    /// row boundaries (layer 0) and at the end of the walk.
    fn integrate_immediate(&mut self, l: usize) {
        let n_in = self.cfg.layer_input(l);
        let width = self.s.arrays[l].width();
        let lw = self.lw;
        let mut pixel = 0usize;
        while pixel < n_in {
            let end = (pixel + self.k).min(n_in);
            self.bram_gate(l);
            if l == 0 {
                self.draw_layer0(pixel, end);
            }
            self.apply_rows(l, pixel, end);
            for i in 0..self.s.active.len() {
                let b = self.s.active[i];
                self.s.layer_act[l][b].cycles += 1; // the Integrate clock
                let fired = &mut self.s.fired[..width];
                fired.fill(false);
                let any = self.s.arrays[l].immediate_fire(b, fired, &mut self.s.layer_act[l][b]);
                if any {
                    for (j, &f) in fired.iter().enumerate() {
                        if f {
                            self.s.step_fired[l][j * lw + b / 64] |= 1 << (b % 64);
                        }
                    }
                    self.s.arrays[l].latch_prune(b, self.s.prune[l]);
                }
            }
            pixel = end;
            let row_boundary = l == 0 && self.row_len.is_some_and(|r| pixel % r == 0);
            if pixel == n_in || row_boundary {
                for i in 0..self.s.active.len() {
                    let b = self.s.active[i];
                    let (arrays, acts) = (&mut self.s.arrays, &mut self.s.layer_act);
                    let act = &mut acts[l][b];
                    arrays[l].leak_enabled(b, act);
                    act.cycles += 1; // the Leak clock
                }
            }
        }
    }

    /// The layer's Fire clock on every active lane: threshold check
    /// (`EndOfStep` only), fire-mask latch into the inter-layer hand-off,
    /// pruning-mask update, per-step snapshots and the clock itself.
    fn fire_clock(&mut self, l: usize) {
        let width = self.s.arrays[l].width();
        let lw = self.lw;
        let end_of_step = self.cfg.fire_mode == FireMode::EndOfStep;
        for i in 0..self.s.active.len() {
            let b = self.s.active[i];
            let fired = &mut self.s.fired[..width];
            fired.fill(false);
            if end_of_step {
                self.s.arrays[l].fire_check(b, fired, &mut self.s.layer_act[l][b]);
            }
            for (j, &f) in fired.iter().enumerate() {
                if f {
                    self.s.step_fired[l][j * lw + b / 64] |= 1 << (b % 64);
                }
            }
            self.s.arrays[l].latch_prune(b, self.s.prune[l]);
            let lane = &mut self.lanes[b];
            self.s.arrays[l].extend_accs(b, &mut lane.step_membranes);
            lane.step_spikes.extend_from_slice(&self.s.fired[..width]);
            self.s.layer_act[l][b].cycles += 1;
        }
    }

    /// The sharded sweep's serial fire epilogue (its [`BatchRun::fire_clock`]
    /// twin): the threshold checks and prune latches already committed
    /// inside each worker's range, so what remains is per-lane
    /// bookkeeping — the membrane snapshot, the fire-pattern snapshot
    /// reconstructed from the step-fired words (under `EndOfStep` each
    /// bit is written at most once per step and cleared at the timestep
    /// edge, so the words are a lossless record of this step's fires),
    /// and the Fire clock itself.
    fn fire_gather(&mut self, l: usize) {
        let width = self.s.arrays[l].width();
        let lw = self.lw;
        for i in 0..self.s.active.len() {
            let b = self.s.active[i];
            let lane = &mut self.lanes[b];
            self.s.arrays[l].extend_accs(b, &mut lane.step_membranes);
            let (wb, bit) = (b / 64, b % 64);
            for j in 0..width {
                lane.step_spikes.push((self.s.step_fired[l][j * lw + wb] >> bit) & 1 == 1);
            }
            self.s.layer_act[l][b].cycles += 1;
        }
    }

    /// End-of-timestep edge: push every active lane's per-step snapshot
    /// and clear the batch-wide fire accumulators.
    fn close_timestep(&mut self) {
        for &b in &self.s.active {
            let lane = &mut self.lanes[b];
            lane.membrane_log.push(std::mem::take(&mut lane.step_membranes));
            lane.spike_log.push(std::mem::take(&mut lane.step_spikes));
        }
        for f in &mut self.s.step_fired {
            f.fill(0);
        }
    }

    /// Batch compaction: retire every lane whose final-layer margin is
    /// reached from the active list (submission order preserved for the
    /// survivors; the spike counts are gathered from the strided plane
    /// into the `counts` scratch).
    fn retire_confident(&mut self, margin: u32) {
        let last = self.s.arrays.len() - 1;
        let mut kept = 0usize;
        for i in 0..self.s.active.len() {
            let b = self.s.active[i];
            self.s.counts.clear();
            self.s.arrays[last].extend_spike_counts(b, &mut self.s.counts);
            if !margin_reached(&self.s.counts, margin) {
                self.s.active[kept] = b;
                kept += 1;
            }
        }
        self.s.active.truncate(kept);
    }
    // pallas-lint: end-hot
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DecisionPolicy, FireMode, LeakMode, PruneMode};
    use crate::data::DigitGen;
    use crate::fixed::WeightMatrix;
    use crate::snn::BehavioralNet;
    use crate::testutil::PropRunner;

    fn test_weights(seed: u32) -> WeightMatrix {
        let mut rng = crate::prng::Xorshift32::new(seed);
        let data: Vec<i32> = (0..7840).map(|_| rng.range_i32(-30, 60)).collect();
        WeightMatrix::from_rows(784, 10, 9, data).unwrap()
    }

    /// A random weight stack matching `topology` (9-bit, mild magnitudes
    /// so the 24-bit accumulator never saturates).
    fn test_stack(topology: &[usize], seed: u32) -> WeightStack {
        let mut rng = crate::prng::Xorshift32::new(seed);
        let layers = topology
            .windows(2)
            .map(|d| {
                let data: Vec<i32> = (0..d[0] * d[1]).map(|_| rng.range_i32(-30, 60)).collect();
                WeightMatrix::from_rows(d[0], d[1], 9, data).unwrap()
            })
            .collect();
        WeightStack::from_layers(layers).unwrap()
    }

    #[test]
    fn cycle_count_matches_schedule() {
        let cfg = SnnConfig::paper().with_timesteps(3);
        let mut core = RtlCore::new(cfg, test_weights(1)).unwrap();
        let img = DigitGen::new(1).sample(0, 0);
        let r = core.run(&img, 42).unwrap();
        // (784 integrate + 1 leak + 1 fire) × 3 timesteps.
        assert_eq!(r.cycles, 786 * 3);
        assert_eq!(r.membrane_by_step.len(), 3);
        assert_eq!(r.spikes_by_step.len(), 3);
    }

    #[test]
    fn layered_cycle_count_matches_schedule() {
        // [784, 16, 10], T=2: per timestep the hidden walk costs 784+1+1
        // and the output walk 16+1+1 clocks.
        let cfg = SnnConfig::paper().with_topology(vec![784, 16, 10]).with_timesteps(2);
        let mut core = RtlCore::new(cfg, test_stack(&[784, 16, 10], 5)).unwrap();
        let img = DigitGen::new(1).sample(2, 0);
        let r = core.run(&img, 7).unwrap();
        assert_eq!(r.cycles, (786 + 18) * 2);
        // Per-layer attribution decomposes the total exactly.
        assert_eq!(r.activity_by_layer[0].cycles, 786 * 2);
        assert_eq!(r.activity_by_layer[1].cycles, 18 * 2);
        // Concatenated logs carry 16 hidden + 10 output entries per step.
        assert_eq!(r.membrane_by_step.len(), 2);
        assert_eq!(r.membrane_by_step[0].len(), 26);
        assert_eq!(r.spikes_by_step[0].len(), 26);
        assert_eq!(r.spike_counts_by_layer.len(), 2);
        assert_eq!(r.spike_counts_by_layer[1], r.spike_counts);
    }

    #[test]
    fn per_row_leak_adds_cycles() {
        let cfg = SnnConfig::paper()
            .with_timesteps(1)
            .with_leak_mode(LeakMode::PerRow { row_len: 28 });
        let mut core = RtlCore::new(cfg, test_weights(1)).unwrap();
        let img = DigitGen::new(1).sample(0, 0);
        let r = core.run(&img, 42).unwrap();
        // 784 integrate + 28 leaks (27 mid-row + 1 final) + 1 fire.
        assert_eq!(r.cycles, 784 + 28 + 1);
    }

    /// The core equivalence theorem: RTL (EndOfStep, PerTimestep) ==
    /// behavioral model, step by step, over random weights/images/seeds.
    #[test]
    fn rtl_equals_behavioral_model() {
        PropRunner::new("rtl_equiv", 12).run(|g| {
            let cfg = SnnConfig::paper()
                .with_timesteps(g.rng.range_i32(2, 8) as u32)
                .with_v_th(g.rng.range_i32(60, 400))
                .with_decay_shift(g.rng.range_i32(1, 5) as u32);
            let w = test_weights(g.rng.next_u32());
            let img = DigitGen::new(g.rng.next_u32()).sample(g.rng.below(10) as u8, g.rng.below(20));
            let seed = g.rng.next_u32();

            let mut core = RtlCore::new(cfg.clone(), w.clone()).unwrap();
            let rtl = core.run(&img, seed).unwrap();
            assert_eq!(rtl.activity.saturations, 0, "saturation voids equivalence");

            let net = BehavioralNet::new(cfg.clone(), w).unwrap();
            let (beh, traces) = net.classify_traced(&img, seed, cfg.timesteps);

            assert_eq!(rtl.spike_counts, beh.spike_counts, "spike counts diverge");
            assert_eq!(rtl.class, beh.class, "decision diverges");
            for (t, (rtl_mem, trace)) in
                rtl.membrane_by_step.iter().zip(traces.iter()).enumerate()
            {
                assert_eq!(rtl_mem, &trace.membrane, "membrane diverges at step {t}");
                assert_eq!(
                    &rtl.spikes_by_step[t], &trace.fired,
                    "fire pattern diverges at step {t}"
                );
            }
        });
    }

    /// A random per-layer override list for `n_layers` layers: each field
    /// of each entry is independently an override or a scalar fallback,
    /// so the sweep covers partial, full and empty heterogeneity.
    fn random_layer_params(
        g: &mut crate::testutil::Gen,
        n_layers: usize,
    ) -> Vec<crate::config::LayerParams> {
        (0..n_layers)
            .map(|_| crate::config::LayerParams {
                v_th: if g.rng.below(2) == 0 {
                    Some(g.rng.range_i32(60, 300))
                } else {
                    None
                },
                decay_shift: if g.rng.below(2) == 0 {
                    Some(g.rng.range_i32(1, 5) as u32)
                } else {
                    None
                },
                prune: if g.rng.below(2) == 0 {
                    Some(*g.choice(&[
                        PruneMode::Off,
                        PruneMode::AfterFires { after_spikes: 1 },
                        PruneMode::AfterFires { after_spikes: 3 },
                    ]))
                } else {
                    None
                },
            })
            .collect()
    }

    /// The layered equivalence theorem: a deep RTL core (EndOfStep,
    /// PerTimestep) matches the chained behavioral stack — final-layer
    /// decision, spike counts and the output-layer slice of every
    /// per-step log — over random stacks/images/seeds, including
    /// heterogeneous per-layer threshold/decay/prune overrides.
    #[test]
    fn deep_rtl_equals_behavioral_model() {
        PropRunner::new("deep_rtl_equiv", 8).run(|g| {
            let hidden = g.rng.range_i32(8, 40) as usize;
            let topology = vec![784, hidden, 10];
            let layer_params =
                if g.rng.below(2) == 0 { random_layer_params(g, 2) } else { Vec::new() };
            let cfg = SnnConfig::paper()
                .with_topology(topology.clone())
                .with_timesteps(g.rng.range_i32(2, 6) as u32)
                .with_v_th(g.rng.range_i32(60, 300))
                .with_decay_shift(g.rng.range_i32(1, 5) as u32)
                .with_layer_params(layer_params);
            let stack = test_stack(&topology, g.rng.next_u32());
            let img = DigitGen::new(g.rng.next_u32()).sample(g.rng.below(10) as u8, g.rng.below(20));
            let seed = g.rng.next_u32();

            let mut core = RtlCore::new(cfg.clone(), stack.clone()).unwrap();
            let rtl = core.run(&img, seed).unwrap();
            assert_eq!(rtl.activity.saturations, 0, "saturation voids equivalence");

            let net = BehavioralNet::new(cfg.clone(), stack).unwrap();
            let (beh, traces) = net.classify_traced(&img, seed, cfg.timesteps);

            assert_eq!(rtl.spike_counts, beh.spike_counts, "spike counts diverge");
            assert_eq!(rtl.class, beh.class, "decision diverges");
            for (t, trace) in traces.iter().enumerate() {
                // The RTL log concatenates [hidden | output]; the
                // behavioral trace carries the output layer.
                assert_eq!(
                    &rtl.membrane_by_step[t][hidden..],
                    &trace.membrane[..],
                    "output membrane diverges at step {t}"
                );
                assert_eq!(
                    &rtl.spikes_by_step[t][hidden..],
                    &trace.fired[..],
                    "output fire pattern diverges at step {t}"
                );
            }
        });
    }

    /// The fast-path theorem: `run_fast` produces a bit-identical
    /// `RtlResult` — spike counts, decision, cycle count, per-step
    /// membrane/fire logs AND every activity counter (global and
    /// per-layer) — across the full fire/leak/prune mode cross-product,
    /// datapath widths, topology depths, and weights hot enough to
    /// exercise per-add saturation.
    #[test]
    fn fast_path_equals_cycle_path() {
        PropRunner::new("fast_path_equiv", 40).run(|g| {
            let fire = *g.choice(&[FireMode::EndOfStep, FireMode::Immediate]);
            let leak = *g.choice(&[
                LeakMode::PerTimestep,
                LeakMode::PerRow { row_len: 28 },
                LeakMode::PerRow { row_len: 112 },
            ]);
            let prune = *g.choice(&[
                PruneMode::Off,
                PruneMode::AfterFires { after_spikes: 1 },
                PruneMode::AfterFires { after_spikes: 3 },
            ]);
            // Widths that divide 28 keep PerRow's alignment contract.
            let k = *g.choice(&[1usize, 2, 4, 7, 14, 28]);
            // Sample the layered schedule too: the hidden widths are
            // deliberately *not* multiples of k so the walk's final
            // partial group is exercised.
            let topology = g
                .choice(&[vec![784usize, 10], vec![784, 24, 10], vec![784, 17, 12, 10]])
                .clone();
            // Occasionally squeeze the accumulator so the saturating adder
            // actually clamps — the fast path must count those events and
            // clamp per-add exactly like the cycle path.
            let squeeze = g.rng.below(3) == 0;
            let cfg = SnnConfig::paper()
                .with_topology(if squeeze { vec![784, 10] } else { topology.clone() })
                .with_timesteps(g.rng.range_i32(1, 6) as u32)
                .with_fire_mode(fire)
                .with_leak_mode(leak)
                .with_prune(prune)
                .with_v_th(if squeeze { 120 } else { g.rng.range_i32(80, 300) })
                .with_decay_shift(g.rng.range_i32(1, 5) as u32);
            let cfg = if squeeze { SnnConfig { acc_bits: 9, ..cfg } } else { cfg };
            // Half the non-squeeze cases attach heterogeneous per-layer
            // threshold/decay/prune overrides, so the fast path is proven
            // bit-exact on the per-layer axis at depths 1-3 too.
            let cfg = if !squeeze && g.rng.below(2) == 0 {
                cfg.with_layer_params(random_layer_params(g, topology.len() - 1))
            } else {
                cfg
            };
            let w = if squeeze {
                // Hot uniform drive against a 9-bit accumulator saturates.
                WeightStack::from(
                    WeightMatrix::from_rows(784, 10, 9, vec![120; 7840]).unwrap(),
                )
            } else {
                test_stack(&topology, g.rng.next_u32())
            };
            let img = DigitGen::new(g.rng.next_u32()).sample(g.rng.below(10) as u8, g.rng.below(20));
            let seed = g.rng.next_u32();

            let slow = RtlCore::new(cfg.clone(), w.clone())
                .unwrap()
                .with_pixels_per_cycle(k)
                .run(&img, seed)
                .unwrap();
            let fast = RtlCore::new(cfg.clone(), w)
                .unwrap()
                .with_pixels_per_cycle(k)
                .run_fast(&img, seed)
                .unwrap();
            // With EndOfStep firing the hot drive provably saturates the
            // 9-bit accumulator during the first step; under Immediate the
            // mid-phase resets can keep it below the rail, so only the
            // equality check applies there.
            if squeeze && fire == FireMode::EndOfStep {
                assert!(
                    fast.activity.saturations > 0,
                    "squeeze case must exercise the saturating adder"
                );
            }
            assert_eq!(
                slow, fast,
                "fast path diverges (fire={fire:?} leak={leak:?} prune={prune:?} k={k} \
                 topology={topology:?} layer_params={:?})",
                cfg.layer_params
            );
        });
    }

    /// The batch equivalence theorem: `run_fast_batch` equals
    /// `run_fast_early` image for image — full `RtlResult` equality
    /// including every activity counter and per-step log — swept across
    /// batch sizes 1–9 × depths 1–3 × heterogeneous `layer_params` ×
    /// early-exit on/off, with both fire modes and `PerRow` leak folded
    /// into the sweep. Deterministic nested loops (not sampled), so the
    /// full cross-product is exercised on every run.
    #[test]
    fn batched_fast_path_equals_sequential() {
        use crate::config::LayerParams;
        let mut rng = crate::prng::Xorshift32::new(0xBA7C_4E11);
        let topologies: [Vec<usize>; 3] =
            [vec![784, 10], vec![784, 17, 10], vec![784, 14, 12, 10]];
        for topology in &topologies {
            let stack = test_stack(topology, rng.next_u32());
            let n_layers = topology.len() - 1;
            for batch in 1usize..=9 {
                for early_on in [false, true] {
                    let early = if early_on {
                        EarlyExit::Margin { margin: 2, min_steps: 1 }
                    } else {
                        EarlyExit::Off
                    };
                    let fire = if batch % 2 == 0 {
                        FireMode::Immediate
                    } else {
                        FireMode::EndOfStep
                    };
                    let leak = if batch % 3 == 0 {
                        LeakMode::PerRow { row_len: 28 }
                    } else {
                        LeakMode::PerTimestep
                    };
                    // Half the cases carry heterogeneous per-layer
                    // threshold/decay/prune overrides.
                    let layer_params: Vec<LayerParams> = if rng.below(2) == 0 {
                        (0..n_layers)
                            .map(|_| LayerParams {
                                v_th: Some(60 + rng.below(200) as i32),
                                decay_shift: Some(1 + rng.below(4)),
                                prune: Some(if rng.below(2) == 0 {
                                    PruneMode::Off
                                } else {
                                    PruneMode::AfterFires { after_spikes: 1 + rng.below(3) }
                                }),
                            })
                            .collect()
                    } else {
                        Vec::new()
                    };
                    let cfg = SnnConfig::paper()
                        .with_topology(topology.clone())
                        .with_timesteps(4)
                        .with_v_th(90 + rng.below(120) as i32)
                        .with_fire_mode(fire)
                        .with_leak_mode(leak)
                        .with_prune(PruneMode::Off)
                        .with_layer_params(layer_params);
                    let gen = DigitGen::new(rng.next_u32());
                    let images: Vec<crate::data::Image> =
                        (0..batch).map(|i| gen.sample(rng.below(10) as u8, i)).collect();
                    let refs: Vec<&crate::data::Image> = images.iter().collect();
                    let seeds: Vec<u32> = (0..batch).map(|_| rng.next_u32()).collect();

                    let mut batch_core = RtlCore::new(cfg.clone(), stack.clone()).unwrap();
                    let got = batch_core.run_fast_batch(&refs, &seeds, early).unwrap();
                    assert_eq!(got.len(), batch);
                    let mut seq_core = RtlCore::new(cfg.clone(), stack.clone()).unwrap();
                    for (i, (img, &seed)) in images.iter().zip(&seeds).enumerate() {
                        let want = seq_core.run_fast_early(img, seed, early).unwrap();
                        assert_eq!(
                            got[i], want,
                            "lane {i} diverges (batch={batch} topology={topology:?} \
                             fire={fire:?} leak={leak:?} early={early:?})"
                        );
                    }
                    // Cumulative cycle accounting stays exact under
                    // batching (the backend's total_cycles contract).
                    assert_eq!(
                        batch_core.total_activity().cycles,
                        seq_core.total_activity().cycles,
                        "cumulative cycles diverge"
                    );
                }
            }
        }
    }

    /// The sparse lockdown theorem: at magnitude threshold 0 the
    /// event-driven sweep (`run_fast_sparse` / `run_fast_batch_sparse`)
    /// produces the full-`RtlResult`-equality of the dense engines —
    /// every activity counter, per-step log and cycle — and above
    /// threshold 0 the schedule (cycles) stays identical while
    /// adds/BRAM reads only ever shrink and the winner stays a valid
    /// class. Deterministic nested loops over thresholds
    /// (0 / light / heavy) × depths 1–3 × batch 1–9, with fire modes,
    /// `PerRow` leak, hetero `layer_params` and early exit folded in.
    #[test]
    fn sparse_sweep_equals_dense_at_threshold_zero() {
        use crate::config::LayerParams;
        let mut rng = crate::prng::Xorshift32::new(0x5AB5_E001);
        let topologies: [Vec<usize>; 3] =
            [vec![784, 10], vec![784, 17, 10], vec![784, 14, 12, 10]];
        for topology in &topologies {
            let stack = test_stack(topology, rng.next_u32());
            let n_layers = topology.len() - 1;
            for &threshold in &[0i32, 15, 40] {
                // Dense reference plane for this threshold: the CSR's
                // dropped entries zeroed. Zero-weight adds change no
                // state, so the sparse sweep must match a dense run of
                // this plane bit for bit in everything except the adds
                // and BRAM pulses it skips.
                let pruned_stack = stack.to_csr(threshold).to_dense();
                for batch in 1usize..=9 {
                    let early = if batch % 2 == 1 {
                        EarlyExit::Margin { margin: 2, min_steps: 1 }
                    } else {
                        EarlyExit::Off
                    };
                    let fire = if batch % 3 == 0 {
                        FireMode::Immediate
                    } else {
                        FireMode::EndOfStep
                    };
                    let leak = if batch % 4 == 0 {
                        LeakMode::PerRow { row_len: 28 }
                    } else {
                        LeakMode::PerTimestep
                    };
                    let layer_params: Vec<LayerParams> = if rng.below(2) == 0 {
                        (0..n_layers)
                            .map(|_| LayerParams {
                                v_th: Some(60 + rng.below(200) as i32),
                                decay_shift: Some(1 + rng.below(4)),
                                prune: Some(if rng.below(2) == 0 {
                                    PruneMode::Off
                                } else {
                                    PruneMode::AfterFires { after_spikes: 1 + rng.below(3) }
                                }),
                            })
                            .collect()
                    } else {
                        Vec::new()
                    };
                    let cfg = SnnConfig::paper()
                        .with_topology(topology.clone())
                        .with_timesteps(4)
                        .with_v_th(90 + rng.below(120) as i32)
                        .with_fire_mode(fire)
                        .with_leak_mode(leak)
                        .with_prune(PruneMode::Off)
                        .with_layer_params(layer_params);
                    let gen = DigitGen::new(rng.next_u32());
                    let images: Vec<crate::data::Image> =
                        (0..batch).map(|i| gen.sample(rng.below(10) as u8, i)).collect();
                    let refs: Vec<&crate::data::Image> = images.iter().collect();
                    let seeds: Vec<u32> = (0..batch).map(|_| rng.next_u32()).collect();

                    let mut sparse_core = RtlCore::new(cfg.clone(), stack.clone()).unwrap();
                    sparse_core.attach_sparse(threshold);
                    let sparse_batch =
                        sparse_core.run_fast_batch_sparse(&refs, &seeds, early).unwrap();
                    assert_eq!(sparse_batch.len(), batch);

                    let mut seq_sparse = RtlCore::new(cfg.clone(), stack.clone()).unwrap();
                    seq_sparse.attach_sparse(threshold);
                    let mut seq_pruned =
                        RtlCore::new(cfg.clone(), pruned_stack.clone()).unwrap();
                    for (i, (img, &seed)) in images.iter().zip(&seeds).enumerate() {
                        let want_sparse =
                            seq_sparse.run_fast_sparse_early(img, seed, early).unwrap();
                        // Batched sparse ≡ sequential sparse, always.
                        assert_eq!(
                            sparse_batch[i], want_sparse,
                            "sparse batch lane {i} diverges (threshold={threshold} \
                             batch={batch} topology={topology:?} fire={fire:?})"
                        );
                        let dense = seq_pruned.run_fast_early(img, seed, early).unwrap();
                        if threshold == 0 {
                            // Full RtlResult equality: the threshold-0 CSR
                            // is the dense engine, event for event.
                            assert_eq!(
                                want_sparse, dense,
                                "threshold-0 sparse diverges from dense (lane {i} \
                                 batch={batch} topology={topology:?} fire={fire:?})"
                            );
                        } else {
                            // Zero-weight adds change no membrane state,
                            // so against the pruned dense plane the
                            // sparse sweep is bit-exact in results,
                            // schedule and logs — only the adds and BRAM
                            // pulses it skipped are (weakly) lower.
                            assert_eq!(want_sparse.class, dense.class, "winner diverges");
                            assert_eq!(want_sparse.spike_counts, dense.spike_counts);
                            assert_eq!(
                                want_sparse.spike_counts_by_layer,
                                dense.spike_counts_by_layer
                            );
                            assert_eq!(want_sparse.cycles, dense.cycles, "schedule diverges");
                            assert_eq!(want_sparse.membrane_by_step, dense.membrane_by_step);
                            assert_eq!(want_sparse.spikes_by_step, dense.spikes_by_step);
                            assert_eq!(
                                want_sparse.activity.saturations,
                                dense.activity.saturations
                            );
                            assert_eq!(want_sparse.activity.compares, dense.activity.compares);
                            assert_eq!(
                                want_sparse.activity.prng_steps,
                                dense.activity.prng_steps
                            );
                            assert!(
                                want_sparse.activity.adds <= dense.activity.adds,
                                "skipped synapses must only lower adds: {} > {}",
                                want_sparse.activity.adds,
                                dense.activity.adds
                            );
                            assert!(
                                want_sparse.activity.bram_reads <= dense.activity.bram_reads,
                                "skipped rows must only lower BRAM reads"
                            );
                        }
                    }
                }
            }
        }
    }

    /// Guard rails: the sparse entry points demand an attached CSR stack,
    /// and a topology-mismatched prebuilt stack is rejected.
    #[test]
    fn sparse_entry_points_require_attached_stack() {
        let cfg = SnnConfig::paper().with_timesteps(1);
        let img = DigitGen::new(1).sample(0, 0);
        let mut core = RtlCore::new(cfg.clone(), test_weights(1)).unwrap();
        assert!(core.run_fast_sparse(&img, 1).is_err());
        assert!(core.run_fast_batch_sparse(&[&img], &[1], EarlyExit::Off).is_err());
        assert!(core.sparse_density().is_none());
        let wrong = test_stack(&[784, 12, 10], 2).to_csr(0);
        assert!(core.attach_sparse_stack(wrong).is_err());
        core.attach_sparse(0);
        assert_eq!(core.sparse_density(), Some(1.0));
        core.run_fast_sparse(&img, 1).unwrap();
    }
    /// image B (black — never fires, never confident) runs the full
    /// window. A's retirement must not perturb B's counts/cycles/logs,
    /// and per-image `steps_run` must match the behavioral model exactly.
    #[test]
    fn batched_early_exit_compaction_is_isolated() {
        let cfg = SnnConfig::paper().with_timesteps(12).with_prune(PruneMode::Off);
        let mut w = vec![0i32; 7840];
        for i in 0..784 {
            if i / 79 == 4 {
                w[i * 10 + 4] = 40;
            }
        }
        let w = WeightMatrix::from_rows(784, 10, 9, w).unwrap();
        let mut px = vec![0u8; 784];
        for (i, p) in px.iter_mut().enumerate() {
            if i / 79 == 4 {
                *p = 250;
            }
        }
        let img_a = crate::data::Image { label: 4, pixels: px };
        let img_b = crate::data::Image { label: 0, pixels: vec![0; 784] };
        let early = EarlyExit::Margin { margin: 2, min_steps: 2 };

        let mut core = RtlCore::new(cfg.clone(), w.clone()).unwrap();
        let batch = core.run_fast_batch(&[&img_a, &img_b], &[7, 9], early).unwrap();
        let steps_a = batch[0].membrane_by_step.len();
        assert!(steps_a >= 2 && steps_a < 12, "A must exit early, ran {steps_a}");
        assert_eq!(batch[1].membrane_by_step.len(), 12, "B must run the full window");
        assert_eq!(batch[0].cycles, 786 * steps_a as u64);
        assert_eq!(batch[1].cycles, 786 * 12);

        // Both lanes bit-exact vs solo runs: the retirement is invisible.
        let solo_a = RtlCore::new(cfg.clone(), w.clone())
            .unwrap()
            .run_fast_early(&img_a, 7, early)
            .unwrap();
        let solo_b = RtlCore::new(cfg.clone(), w.clone())
            .unwrap()
            .run_fast_early(&img_b, 9, early)
            .unwrap();
        assert_eq!(batch[0], solo_a, "A diverges from its solo window");
        assert_eq!(batch[1], solo_b, "B perturbed by A's retirement");

        // steps_run parity with the behavioral model, per image.
        let net = BehavioralNet::new(cfg, w).unwrap();
        let beh_a = net.classify_opts(&img_a, 7, 12, early);
        let beh_b = net.classify_opts(&img_b, 9, 12, early);
        assert_eq!(beh_a.steps_run as usize, steps_a, "A steps_run diverges");
        assert_eq!(beh_b.steps_run, 12, "B steps_run diverges");
        assert_eq!(batch[0].spike_counts, beh_a.spike_counts);
        assert_eq!(batch[1].spike_counts, beh_b.spike_counts);
    }

    #[test]
    fn batch_chunks_past_64_lanes_and_rejects_seed_mismatch() {
        // 70 lanes crossed the old single-word 64-lane ceiling; at the
        // widened default it must run as ONE multi-word chunk, not two.
        assert_eq!(BATCH_LANES, 256);
        assert_eq!(batch_chunks(0), 0);
        assert_eq!(batch_chunks(70), 1);
        assert_eq!(batch_chunks(256), 1);
        assert_eq!(batch_chunks(257), 2);
        let cfg = SnnConfig::paper().with_timesteps(1);
        let w = test_weights(3);
        let gen = DigitGen::new(5);
        let images: Vec<crate::data::Image> =
            (0..70).map(|i| gen.sample((i % 10) as u8, i)).collect();
        let refs: Vec<&crate::data::Image> = images.iter().collect();
        let seeds: Vec<u32> = (0..70).map(|i| 40 + i as u32).collect();
        let mut core = RtlCore::new(cfg.clone(), w.clone()).unwrap();
        assert!(core.run_fast_batch(&refs[..2], &seeds[..1], EarlyExit::Off).is_err());
        let got = core.run_fast_batch(&refs, &seeds, EarlyExit::Off).unwrap();
        assert_eq!(got.len(), 70);
        let mut seq = RtlCore::new(cfg, w).unwrap();
        for (i, r) in got.iter().enumerate() {
            assert_eq!(r, &seq.run_fast(&images[i], seeds[i]).unwrap(), "lane {i}");
        }
        assert_eq!(core.run_fast_batch(&[], &[], EarlyExit::Off).unwrap().len(), 0);
    }

    /// Single-chunk widths 65/128/256 — one word past the boundary, two
    /// full words, and the full default — bit-exact lane-for-lane with
    /// the sequential engine across depths 1–3, both fire modes and
    /// early exit (multi-word step-fired hand-off + lane compaction),
    /// dense and CSR sweeps.
    #[test]
    fn wide_chunk_widths_match_sequential() {
        let topologies: [&[usize]; 3] = [&[784, 10], &[784, 17, 10], &[784, 14, 12, 10]];
        for (wi, &width) in [65usize, 128, 256].iter().enumerate() {
            assert_eq!(batch_chunks(width), 1, "width {width} must be one chunk");
            let topology = topologies[wi];
            let mut cfg = SnnConfig::paper()
                .with_topology(topology.to_vec())
                .with_timesteps(2)
                .with_v_th(120);
            if wi == 1 {
                cfg = cfg.with_fire_mode(FireMode::Immediate);
            }
            let early = if wi == 2 {
                EarlyExit::Margin { margin: 2, min_steps: 1 }
            } else {
                EarlyExit::Off
            };
            let w = test_stack(topology, 11 + wi as u32);
            let gen = DigitGen::new(6 + wi as u64);
            let images: Vec<crate::data::Image> =
                (0..width).map(|i| gen.sample((i % 10) as u8, i as u64)).collect();
            let refs: Vec<&crate::data::Image> = images.iter().collect();
            let seeds: Vec<u32> = (0..width).map(|i| 100 + i as u32).collect();

            let mut core = RtlCore::new(cfg.clone(), w.clone()).unwrap();
            let got = core.run_fast_batch(&refs, &seeds, early).unwrap();
            assert_eq!(got.len(), width);
            let mut seq = RtlCore::new(cfg.clone(), w.clone()).unwrap();
            for (i, r) in got.iter().enumerate() {
                let want = seq.run_fast_early(&images[i], seeds[i], early).unwrap();
                assert_eq!(r, &want, "width {width} lane {i} diverges");
            }
            assert_eq!(
                core.total_activity().cycles,
                seq.total_activity().cycles,
                "width {width}: cumulative cycles diverge"
            );

            if wi <= 1 {
                // The CSR sweep through the same wide chunk.
                let mut sc = RtlCore::new(cfg.clone(), w.clone()).unwrap();
                sc.attach_sparse(15);
                let sparse = sc.run_fast_batch_sparse(&refs, &seeds, early).unwrap();
                let mut ss = RtlCore::new(cfg, w).unwrap();
                ss.attach_sparse(15);
                for (i, r) in sparse.iter().enumerate() {
                    let want = ss.run_fast_sparse_early(&images[i], seeds[i], early).unwrap();
                    assert_eq!(r, &want, "width {width} sparse lane {i} diverges");
                }
            }
        }
    }

    /// Early-exit compaction when the retiring lanes straddle a mask-word
    /// boundary (lanes 63, 64, 65 of a 67-lane chunk): the confident
    /// lanes must retire without perturbing any word-neighbour.
    #[test]
    fn early_exit_compaction_across_lane_word_boundary() {
        let cfg = SnnConfig::paper().with_timesteps(12).with_prune(PruneMode::Off);
        let mut w = vec![0i32; 7840];
        for i in 0..784 {
            if i / 79 == 4 {
                w[i * 10 + 4] = 40;
            }
        }
        let w = WeightMatrix::from_rows(784, 10, 9, w).unwrap();
        let mut px = vec![0u8; 784];
        for (i, p) in px.iter_mut().enumerate() {
            if i / 79 == 4 {
                *p = 250;
            }
        }
        let img_a = crate::data::Image { label: 4, pixels: px };
        let img_b = crate::data::Image { label: 0, pixels: vec![0; 784] };
        let early = EarlyExit::Margin { margin: 2, min_steps: 2 };

        // 67 lanes: the hot image (early-confident) sits exactly on the
        // word boundary — last bit of word 0, first two bits of word 1.
        let lanes = 67usize;
        let hot = [63usize, 64, 65];
        let images: Vec<&crate::data::Image> =
            (0..lanes).map(|b| if hot.contains(&b) { &img_a } else { &img_b }).collect();
        let seeds: Vec<u32> = (0..lanes).map(|b| 7 + b as u32).collect();

        let mut core = RtlCore::new(cfg.clone(), w.clone()).unwrap();
        let batch = core.run_fast_batch(&images, &seeds, early).unwrap();
        for &b in &hot {
            let steps = batch[b].membrane_by_step.len();
            assert!((2..12).contains(&steps), "hot lane {b} must exit early, ran {steps}");
        }
        assert_eq!(batch[62].membrane_by_step.len(), 12, "lane 62 must run the full window");
        assert_eq!(batch[66].membrane_by_step.len(), 12, "lane 66 must run the full window");
        let mut seq = RtlCore::new(cfg, w).unwrap();
        for b in 0..lanes {
            let want = seq.run_fast_early(images[b], seeds[b], early).unwrap();
            assert_eq!(batch[b], want, "lane {b} perturbed by boundary retirement");
        }
    }

    /// The thread-count-invariance theorem: the neuron-range-sharded
    /// sweep is bit-identical to the serial sweep at any thread count —
    /// full `RtlResult` equality (logs, counters, cycles) and exact
    /// cumulative cycle accounting — across depths 1–3, heterogeneous
    /// per-layer params, `PerRow` leak, Margin early exit, and the CSR
    /// arm. Deterministic loops; threads 2/4/7 all reduce to the
    /// threads=1 reference, and `Immediate` configs ignore the thread
    /// knob entirely.
    #[test]
    fn thread_count_invariance_dense_and_sparse() {
        use crate::config::LayerParams;
        let mut rng = crate::prng::Xorshift32::new(0x7EAD_C0DE);
        let topologies: [&[usize]; 3] = [&[784, 10], &[784, 17, 10], &[784, 14, 12, 10]];
        for (ti, topology) in topologies.iter().enumerate() {
            let stack = test_stack(topology, rng.next_u32());
            let n_layers = topology.len() - 1;
            let early = if ti % 2 == 0 {
                EarlyExit::Margin { margin: 2, min_steps: 1 }
            } else {
                EarlyExit::Off
            };
            let leak = if ti == 1 {
                LeakMode::PerRow { row_len: 28 }
            } else {
                LeakMode::PerTimestep
            };
            let layer_params: Vec<LayerParams> = if ti == 2 {
                (0..n_layers)
                    .map(|l| LayerParams {
                        v_th: Some(90 + 40 * l as i32),
                        decay_shift: Some(1 + (l as u32 % 3)),
                        prune: Some(PruneMode::AfterFires { after_spikes: 2 }),
                    })
                    .collect()
            } else {
                Vec::new()
            };
            let cfg = SnnConfig::paper()
                .with_topology(topology.to_vec())
                .with_timesteps(3)
                .with_v_th(110)
                .with_leak_mode(leak)
                .with_prune(PruneMode::Off)
                .with_layer_params(layer_params);
            let gen = DigitGen::new(rng.next_u32());
            let batch = 6usize;
            let images: Vec<crate::data::Image> =
                (0..batch).map(|i| gen.sample((i % 10) as u8, i as u32)).collect();
            let refs: Vec<&crate::data::Image> = images.iter().collect();
            let seeds: Vec<u32> = (0..batch).map(|_| rng.next_u32()).collect();

            let mut reference = RtlCore::new(cfg.clone(), stack.clone()).unwrap();
            let want = reference.run_fast_batch(&refs, &seeds, early).unwrap();
            let mut ref_sparse = RtlCore::new(cfg.clone(), stack.clone()).unwrap();
            ref_sparse.attach_sparse(15);
            let want_sparse = ref_sparse.run_fast_batch_sparse(&refs, &seeds, early).unwrap();

            for threads in [2usize, 4, 7] {
                let mut core = RtlCore::new(cfg.clone(), stack.clone())
                    .unwrap()
                    .with_batch_threads(threads);
                let got = core.run_fast_batch(&refs, &seeds, early).unwrap();
                assert_eq!(got, want, "threads={threads} topology={topology:?} diverges");
                assert_eq!(
                    core.total_activity().cycles,
                    reference.total_activity().cycles,
                    "threads={threads}: cumulative cycles diverge"
                );

                let mut sc = RtlCore::new(cfg.clone(), stack.clone())
                    .unwrap()
                    .with_batch_threads(threads);
                sc.attach_sparse(15);
                let got_sparse = sc.run_fast_batch_sparse(&refs, &seeds, early).unwrap();
                assert_eq!(
                    got_sparse, want_sparse,
                    "threads={threads} topology={topology:?} sparse arm diverges"
                );
            }
        }

        // `Immediate` mode keeps the serial sweep at any thread setting
        // (mid-walk fires are inherently sequential) — pinned here.
        let topology = [784usize, 12, 10];
        let stack = test_stack(&topology, rng.next_u32());
        let cfg = SnnConfig::paper()
            .with_topology(topology.to_vec())
            .with_timesteps(2)
            .with_fire_mode(FireMode::Immediate);
        let gen = DigitGen::new(3);
        let images: Vec<crate::data::Image> =
            (0..4u32).map(|i| gen.sample(i as u8, i)).collect();
        let refs: Vec<&crate::data::Image> = images.iter().collect();
        let seeds = [5u32, 6, 7, 8];
        let want = RtlCore::new(cfg.clone(), stack.clone())
            .unwrap()
            .run_fast_batch(&refs, &seeds, EarlyExit::Off)
            .unwrap();
        let got = RtlCore::new(cfg, stack)
            .unwrap()
            .with_batch_threads(4)
            .run_fast_batch(&refs, &seeds, EarlyExit::Off)
            .unwrap();
        assert_eq!(got, want, "Immediate mode must be thread-setting-invariant");
    }

    /// Odd neuron-range boundaries: layer widths 10/17/512 split across
    /// 3 workers leave uneven ranges (4+3+3, 6+6+5, 171+171+170); each
    /// split must reproduce the serial sweep bit for bit — including at
    /// 512, the width where the calibrated plan also narrows the chunk.
    #[test]
    fn odd_neuron_range_boundaries_across_three_threads() {
        for &hidden in &[10usize, 17, 512] {
            let topology = [784, hidden, 10];
            let stack = test_stack(&topology, 0xB0 + hidden as u32);
            let cfg = SnnConfig::paper()
                .with_topology(topology.to_vec())
                .with_timesteps(2)
                .with_v_th(130);
            let gen = DigitGen::new(hidden as u32);
            let images: Vec<crate::data::Image> =
                (0..3u32).map(|i| gen.sample((i % 10) as u8, i)).collect();
            let refs: Vec<&crate::data::Image> = images.iter().collect();
            let seeds: Vec<u32> = (0..3u32).map(|i| 11 + i).collect();
            let want = RtlCore::new(cfg.clone(), stack.clone())
                .unwrap()
                .run_fast_batch(&refs, &seeds, EarlyExit::Off)
                .unwrap();
            let got = RtlCore::new(cfg, stack)
                .unwrap()
                .with_batch_threads(3)
                .run_fast_batch(&refs, &seeds, EarlyExit::Off)
                .unwrap();
            assert_eq!(got, want, "hidden={hidden} sharded across 3 threads diverges");
        }
    }

    /// Early-exit lane compaction under the parallel sweep: the 67-lane
    /// word-boundary retirement scenario run with 3 workers. Retirement
    /// happens in the serial portion between timesteps; the workers only
    /// ever see the rebuilt active mask, so compaction must stay
    /// invisible lane-for-lane.
    #[test]
    fn early_exit_compaction_under_parallel_sweep() {
        let cfg = SnnConfig::paper().with_timesteps(12).with_prune(PruneMode::Off);
        let mut w = vec![0i32; 7840];
        for i in 0..784 {
            if i / 79 == 4 {
                w[i * 10 + 4] = 40;
            }
        }
        let w = WeightMatrix::from_rows(784, 10, 9, w).unwrap();
        let mut px = vec![0u8; 784];
        for (i, p) in px.iter_mut().enumerate() {
            if i / 79 == 4 {
                *p = 250;
            }
        }
        let img_a = crate::data::Image { label: 4, pixels: px };
        let img_b = crate::data::Image { label: 0, pixels: vec![0; 784] };
        let early = EarlyExit::Margin { margin: 2, min_steps: 2 };
        let lanes = 67usize;
        let hot = [63usize, 64, 65];
        let images: Vec<&crate::data::Image> =
            (0..lanes).map(|b| if hot.contains(&b) { &img_a } else { &img_b }).collect();
        let seeds: Vec<u32> = (0..lanes).map(|b| 7 + b as u32).collect();

        let mut core = RtlCore::new(cfg.clone(), w.clone()).unwrap().with_batch_threads(3);
        let got = core.run_fast_batch(&images, &seeds, early).unwrap();
        for &b in &hot {
            let steps = got[b].membrane_by_step.len();
            assert!((2..12).contains(&steps), "hot lane {b} must exit early, ran {steps}");
        }
        let mut serial = RtlCore::new(cfg, w).unwrap();
        let want = serial.run_fast_batch(&images, &seeds, early).unwrap();
        assert_eq!(got, want, "parallel compaction diverges from the serial sweep");
    }

    /// The calibrated chunk plan: wide hidden layers narrow the lane
    /// width so the planes stay L2-resident, narrow topologies keep the
    /// ceiling, and any plan width produces identical results — the
    /// chunk width is a throughput knob only.
    #[test]
    fn chunk_plan_narrows_on_wide_layers_and_preserves_results() {
        use crate::plan::ChunkPlan;
        let core = RtlCore::new(
            SnnConfig::paper().with_topology(vec![784, 512, 10]),
            test_stack(&[784, 512, 10], 21),
        )
        .unwrap();
        assert_eq!(core.chunk_plan().lanes(), 128, "512-wide hidden must narrow to 128");
        drop(core);

        let cfg = SnnConfig::paper().with_topology(vec![784, 17, 10]).with_timesteps(2);
        let stack = test_stack(&[784, 17, 10], 22);
        assert_eq!(
            RtlCore::new(cfg.clone(), stack.clone()).unwrap().chunk_plan().lanes(),
            256,
            "narrow topologies keep the ceiling width"
        );
        let gen = DigitGen::new(31);
        let n = 70usize;
        let images: Vec<crate::data::Image> =
            (0..n).map(|i| gen.sample((i % 10) as u8, i as u32)).collect();
        let refs: Vec<&crate::data::Image> = images.iter().collect();
        let seeds: Vec<u32> = (0..n as u32).map(|i| 900 + i).collect();
        let mut reference = RtlCore::new(cfg.clone(), stack.clone()).unwrap();
        let want = reference.run_fast_batch(&refs, &seeds, EarlyExit::Off).unwrap();
        for lanes in [64usize, 128] {
            // 70 images at width 64 cross a chunk boundary (64 + 6).
            let mut core = RtlCore::new(cfg.clone(), stack.clone())
                .unwrap()
                .with_chunk_plan(ChunkPlan::fixed(lanes));
            assert_eq!(core.chunk_plan().chunks(n), n.div_ceil(lanes));
            let got = core.run_fast_batch(&refs, &seeds, EarlyExit::Off).unwrap();
            assert_eq!(got, want, "plan width {lanes} changes results");
        }
    }

    /// The batched sweep's scratch arena (masks, gates, counter planes,
    /// state planes, encoders) must be re-used in place across chunks and
    /// across calls — the alloc-free hot-loop pin mirroring the PR 4
    /// `top2` fix.
    #[test]
    fn batch_scratch_is_reused_across_chunks_and_calls() {
        let cfg = SnnConfig::paper().with_timesteps(2);
        let w = test_weights(5);
        let gen = DigitGen::new(9);
        let images: Vec<crate::data::Image> =
            (0..20).map(|i| gen.sample((i % 10) as u8, i)).collect();
        let refs: Vec<&crate::data::Image> = images.iter().collect();
        let seeds: Vec<u32> = (0..20).map(|i| 60 + i as u32).collect();
        let early = EarlyExit::Margin { margin: 30, min_steps: 1 };

        let mut core = RtlCore::new(cfg, w).unwrap();
        // Warm-up arms every pooled buffer (including the early-exit
        // gather scratch); after it the arena must be pointer-stable.
        let first = core.run_fast_batch(&refs, &seeds, early).unwrap();
        let fp = core.batch_scratch_fingerprint();
        let second = core.run_fast_batch(&refs, &seeds, early).unwrap();
        assert_eq!(fp, core.batch_scratch_fingerprint(), "scratch re-allocated on 2nd call");
        let third = core.run_fast_batch(&refs, &seeds, early).unwrap();
        assert_eq!(fp, core.batch_scratch_fingerprint(), "scratch re-allocated on 3rd call");
        assert_eq!(first, second, "pooled scratch leaked state across calls");
        assert_eq!(first, third);
    }

    #[test]
    fn fast_path_leaves_core_reusable_and_done() {
        // Back-to-back windows on one core must be independent on both
        // paths, and the fast path must leave the FSM observable as Done.
        let cfg = SnnConfig::paper().with_timesteps(3);
        let img = DigitGen::new(1).sample(5, 1);
        let mut core = RtlCore::new(cfg.clone(), test_weights(3)).unwrap();
        let a = core.run_fast(&img, 7).unwrap();
        assert_eq!(core.state(), CtrlState::Done);
        let b = core.run_fast(&img, 7).unwrap();
        assert_eq!(a, b, "fast path must be stateless across windows");
        let c = core.run(&img, 7).unwrap();
        assert_eq!(a, c, "interleaved cycle path must agree");
        assert_eq!(core.total_activity().cycles, 3 * 786 * 3);
    }

    #[test]
    fn early_exit_stops_at_margin_and_preserves_prefix() {
        // Without pruning the margin is reachable; the early window's
        // per-step logs must be a prefix of the full window's.
        let cfg = SnnConfig::paper().with_timesteps(20).with_prune(PruneMode::Off);
        // Crisp block weights: one class accumulates a margin quickly.
        let mut w = vec![0i32; 7840];
        for i in 0..784 {
            if i / 79 == 4 {
                w[i * 10 + 4] = 40;
            }
        }
        let w = WeightMatrix::from_rows(784, 10, 9, w).unwrap();
        let mut px = vec![0u8; 784];
        for (i, p) in px.iter_mut().enumerate() {
            if i / 79 == 4 {
                *p = 250;
            }
        }
        let img = crate::data::Image { label: 4, pixels: px };

        let mut core = RtlCore::new(cfg.clone(), w.clone()).unwrap();
        let full = core.run_fast(&img, 9).unwrap();
        let mut core = RtlCore::new(cfg, w).unwrap();
        let early = core
            .run_fast_early(&img, 9, EarlyExit::Margin { margin: 3, min_steps: 2 })
            .unwrap();
        assert_eq!(early.class, full.class);
        let steps = early.membrane_by_step.len();
        assert!(steps >= 2 && steps < 20, "margin never triggered: {steps} steps");
        assert_eq!(early.cycles, 786 * steps as u64);
        assert_eq!(
            &early.membrane_by_step[..],
            &full.membrane_by_step[..steps],
            "early window must be a bit-exact prefix"
        );
    }

    #[test]
    fn unreachable_margin_clamps_on_fast_path() {
        // Bugfix regression, RTL side: prune-after-1 caps every count at
        // 1, so margin 4 used to silently never trigger and the fast path
        // ran the full 20-step window. The clamp must make it behave
        // exactly like margin 1 — same early stop, same prefix.
        let cfg = SnnConfig::paper()
            .with_timesteps(20)
            .with_prune(PruneMode::AfterFires { after_spikes: 1 });
        let mut w = vec![0i32; 7840];
        for i in 0..784 {
            if i / 79 == 4 {
                w[i * 10 + 4] = 40;
            }
        }
        let w = WeightMatrix::from_rows(784, 10, 9, w).unwrap();
        let mut px = vec![0u8; 784];
        for (i, p) in px.iter_mut().enumerate() {
            if i / 79 == 4 {
                *p = 250;
            }
        }
        let img = crate::data::Image { label: 4, pixels: px };

        let unreachable = RtlCore::new(cfg.clone(), w.clone())
            .unwrap()
            .run_fast_early(&img, 9, EarlyExit::Margin { margin: 4, min_steps: 2 })
            .unwrap();
        let capped = RtlCore::new(cfg, w)
            .unwrap()
            .run_fast_early(&img, 9, EarlyExit::Margin { margin: 1, min_steps: 2 })
            .unwrap();
        assert_eq!(unreachable, capped, "clamped margin must match the reachable one");
        assert!(
            (unreachable.membrane_by_step.len() as u32) < 20,
            "clamped margin must still exit early"
        );
    }

    #[test]
    fn per_layer_prune_policies_act_independently_in_rtl() {
        // Unpruned hidden layer + prune-after-1 readout: the hidden layer
        // keeps firing every step while the output layer gates off after
        // its first spike — the PruneMode-per-layer ROADMAP item, proven
        // identical on both engines. (A shared policy caps *both* layers,
        // so the hidden counts below discriminate the per-layer path.)
        use crate::config::LayerParams;
        let cfg = SnnConfig::paper()
            .with_topology(vec![784, 12, 10])
            .with_timesteps(6)
            .with_v_th(100)
            .with_layer_params(vec![
                LayerParams { prune: Some(PruneMode::Off), ..Default::default() },
                LayerParams {
                    prune: Some(PruneMode::AfterFires { after_spikes: 1 }),
                    ..Default::default()
                },
            ]);
        let l0 = WeightMatrix::from_rows(784, 12, 9, vec![20; 784 * 12]).unwrap();
        let l1 = WeightMatrix::from_rows(12, 10, 9, vec![60; 120]).unwrap();
        let stack = WeightStack::from_layers(vec![l0, l1]).unwrap();
        let img = crate::data::Image { label: 0, pixels: vec![255; 784] };
        let mut core = RtlCore::new(cfg.clone(), stack.clone()).unwrap();
        let fast = core.run_fast(&img, 11).unwrap();
        let mut core = RtlCore::new(cfg, stack).unwrap();
        let slow = core.run(&img, 11).unwrap();
        assert_eq!(fast, slow, "per-layer prune diverges between engines");
        assert!(
            fast.spike_counts_by_layer[0].iter().all(|&c| c == 6),
            "unpruned hidden layer must fire every step: {:?}",
            fast.spike_counts_by_layer[0]
        );
        assert!(
            fast.spike_counts.iter().all(|&c| c == 1),
            "pruned readout must cap at 1: {:?}",
            fast.spike_counts
        );
    }

    #[test]
    fn fast_path_falls_back_under_vcd() {
        let cfg = SnnConfig::paper().with_timesteps(2);
        let img = DigitGen::new(1).sample(4, 0);
        let mut plain = RtlCore::new(cfg.clone(), test_weights(5)).unwrap();
        let want = plain.run_fast(&img, 9).unwrap();
        let mut core = RtlCore::new(cfg, test_weights(5)).unwrap();
        core.attach_vcd(VcdWriter::new(10, 25));
        let got = core.run_fast(&img, 9).unwrap();
        assert_eq!(want, got);
        let vcd = core.detach_vcd().unwrap().finish();
        assert!(vcd.matches('#').count() > 10, "VCD must still capture every cycle");
    }

    #[test]
    fn pruning_reduces_activity() {
        let img = DigitGen::new(1).sample(3, 0);
        let w = test_weights(7);
        let on = SnnConfig::paper()
            .with_timesteps(20)
            .with_prune(PruneMode::AfterFires { after_spikes: 1 });
        let off = on.clone().with_prune(PruneMode::Off);
        let r_on = RtlCore::new(on, w.clone()).unwrap().run(&img, 9).unwrap();
        let r_off = RtlCore::new(off, w).unwrap().run(&img, 9).unwrap();
        // Same cycle count (the schedule is fixed) but strictly less
        // switching activity when neurons get gated off.
        assert_eq!(r_on.cycles, r_off.cycles);
        assert!(
            r_on.spike_counts.iter().sum::<u32>() > 0,
            "test needs at least one spike to exercise pruning"
        );
        assert!(
            r_on.activity.adds < r_off.activity.adds,
            "pruning must cut integrate adds: {} vs {}",
            r_on.activity.adds,
            r_off.activity.adds
        );
        assert!(r_on.energy.dynamic_nj < r_off.energy.dynamic_nj);
    }

    #[test]
    fn immediate_mode_fires_mid_step() {
        // With a huge drive, Immediate mode fires during integration and
        // (with pruning) freezes counts at 1 per neuron.
        let cfg = SnnConfig::paper()
            .with_timesteps(2)
            .with_v_th(64)
            .with_fire_mode(FireMode::Immediate)
            .with_decision(DecisionPolicy::SpikeCount);
        let w = WeightMatrix::from_rows(784, 10, 9, vec![100; 7840]).unwrap();
        let img = crate::data::Image { label: 0, pixels: vec![255; 784] };
        let mut core = RtlCore::new(cfg, w).unwrap();
        let r = core.run(&img, 3).unwrap();
        assert!(r.spike_counts.iter().all(|&c| c == 1), "{:?}", r.spike_counts);
    }

    #[test]
    fn deep_core_propagates_spikes_through_hidden_layer() {
        // Uniform positive drive: the hidden layer fires, which must give
        // the output layer nonzero input current and spikes of its own.
        let cfg = SnnConfig::paper()
            .with_topology(vec![784, 12, 10])
            .with_timesteps(4)
            .with_v_th(100)
            .with_prune(PruneMode::Off);
        let l0 = WeightMatrix::from_rows(784, 12, 9, vec![20; 784 * 12]).unwrap();
        let l1 = WeightMatrix::from_rows(12, 10, 9, vec![60; 120]).unwrap();
        let stack = WeightStack::from_layers(vec![l0, l1]).unwrap();
        let img = crate::data::Image { label: 0, pixels: vec![255; 784] };
        let mut core = RtlCore::new(cfg, stack).unwrap();
        let r = core.run_fast(&img, 11).unwrap();
        assert!(
            r.spike_counts_by_layer[0].iter().sum::<u32>() > 0,
            "hidden layer never fired"
        );
        assert!(
            r.spike_counts.iter().sum::<u32>() > 0,
            "output layer never fired: hidden spikes did not propagate"
        );
        assert!(
            r.activity_by_layer[1].bram_reads > 0,
            "output layer BRAM idle despite hidden spikes"
        );
    }

    #[test]
    fn event_driven_gating_zero_input() {
        // A black image produces no spikes: no adds, no BRAM reads.
        let cfg = SnnConfig::paper().with_timesteps(5);
        let img = crate::data::Image { label: 0, pixels: vec![0; 784] };
        let mut core = RtlCore::new(cfg, test_weights(3)).unwrap();
        let r = core.run(&img, 11).unwrap();
        assert_eq!(r.activity.bram_reads, 0);
        // Only leak-cycle adds (the subtract half of shift-subtract).
        assert_eq!(r.activity.adds, 5 * 10); // 5 steps × 10 neurons × 1 leak
    }

    #[test]
    fn datapath_width_changes_cycles_not_results() {
        let img = DigitGen::new(1).sample(6, 2);
        let w = test_weights(11);
        let cfg = SnnConfig::paper().with_timesteps(4);
        let mut reference = None;
        for k in [1usize, 2, 4, 7, 784] {
            let mut core =
                RtlCore::new(cfg.clone(), w.clone()).unwrap().with_pixels_per_cycle(k);
            let r = core.run(&img, 99).unwrap();
            // Cycle count: ceil(784/k) integrate clocks + leak + fire.
            let integrate = 784usize.div_ceil(k);
            assert_eq!(r.cycles, (integrate as u64 + 2) * 4, "width {k}");
            match &reference {
                None => reference = Some(r),
                Some(base) => {
                    assert_eq!(r.spike_counts, base.spike_counts, "width {k}");
                    assert_eq!(r.membrane_by_step, base.membrane_by_step, "width {k}");
                    // Same architectural work regardless of width.
                    assert_eq!(r.activity.adds, base.activity.adds, "width {k}");
                    assert_eq!(r.activity.prng_steps, base.activity.prng_steps);
                }
            }
        }
    }

    #[test]
    fn per_row_width_alignment_enforced() {
        let cfg = SnnConfig::paper().with_leak_mode(LeakMode::PerRow { row_len: 28 });
        let core = RtlCore::new(cfg, test_weights(1)).unwrap();
        // 28 % 4 == 0: fine; 28 % 3 != 0: must panic.
        let _ok = core.with_pixels_per_cycle(4);
        let cfg = SnnConfig::paper().with_leak_mode(LeakMode::PerRow { row_len: 28 });
        let core = RtlCore::new(cfg, test_weights(1)).unwrap();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            core.with_pixels_per_cycle(3)
        }));
        assert!(res.is_err(), "misaligned width must be rejected");
    }

    #[test]
    fn bram_goes_idle_once_all_neurons_pruned() {
        // Huge uniform drive + prune-after-1: all ten neurons fire on the
        // first step; from step 2 on the weight BRAM must not be read.
        let cfg = SnnConfig::paper()
            .with_timesteps(5)
            .with_v_th(64)
            .with_prune(PruneMode::AfterFires { after_spikes: 1 });
        let w = WeightMatrix::from_rows(784, 10, 9, vec![100; 7840]).unwrap();
        let img = crate::data::Image { label: 0, pixels: vec![255; 784] };
        let mut core = RtlCore::new(cfg, w).unwrap();
        let r = core.run(&img, 3).unwrap();
        assert!(r.spike_counts.iter().all(|&c| c == 1));
        // Roughly one timestep's worth of spikes (~99% rate), not five.
        assert!(
            r.activity.bram_reads < 790,
            "BRAM still active after full pruning: {} reads",
            r.activity.bram_reads
        );
    }

    #[test]
    fn rejects_geometry_mismatch() {
        let cfg = SnnConfig::paper();
        let w = WeightMatrix::zeros(100, 10, 9);
        assert!(RtlCore::new(cfg, w).is_err());
        // A stack whose depth disagrees with the config is rejected too.
        let cfg = SnnConfig::paper();
        let stack = WeightStack::from_layers(vec![
            WeightMatrix::zeros(784, 16, 9),
            WeightMatrix::zeros(16, 10, 9),
        ])
        .unwrap();
        assert!(RtlCore::new(cfg, stack).is_err());
        let cfg = SnnConfig::paper();
        let w = WeightMatrix::zeros(784, 10, 9);
        let mut core = RtlCore::new(cfg, w).unwrap();
        let bad = crate::data::Image { label: 0, pixels: vec![0; 10] };
        assert!(core.load_image(&bad, 1).is_err());
    }
}
