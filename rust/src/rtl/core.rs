//! Top-level SNN core: controller + encoder + neuron array + weight BRAM.
//!
//! Two execution engines share the same architectural state:
//!
//! * the **cycle path** ([`RtlCore::tick_cycle`] / [`RtlCore::run`]) —
//!   advances one clock per call through the controller FSM; required for
//!   waveform capture and cycle-by-cycle observability;
//! * the **fast path** ([`RtlCore::run_fast`]) — executes a whole timestep
//!   per loop iteration: the Poisson comparator draws for a pixel range are
//!   bulk-generated into an active-pixel index list, only spiking rows are
//!   integrated, and the cycle count is computed arithmetically from the
//!   FSM schedule instead of being walked. It is **bit-exact and
//!   activity-exact** with the cycle path across every
//!   `FireMode`/`LeakMode`/`PruneMode`/datapath-width combination
//!   (property-tested by `fast_path_equals_cycle_path`; equivalence
//!   argument in EXPERIMENTS.md §Perf).

use crate::config::{FireMode, LeakMode, SnnConfig};
use crate::data::Image;
use crate::error::{Error, Result};
use crate::fixed::WeightMatrix;

use super::controller::{CtrlState, LayerController};
use super::encoder::RtlPoissonEncoder;
use super::lif_neuron::LifNeuronArray;
use super::power::{ActivityCounters, EnergyModel, EnergyReport};
use super::vcd::VcdWriter;

/// Result of one inference window on the RTL core.
#[derive(Debug, Clone, PartialEq)]
pub struct RtlResult {
    /// Priority-encoded argmax of the spike-count registers.
    pub class: u8,
    /// Spike counts per output neuron.
    pub spike_counts: Vec<u32>,
    /// Clock cycles consumed by the window (excludes load).
    pub cycles: u64,
    /// Switching-activity totals for the window.
    pub activity: ActivityCounters,
    /// Energy estimate under the core's [`EnergyModel`].
    pub energy: EnergyReport,
    /// Membrane potential of every neuron after each timestep's Fire clock
    /// (pre-reset value NOT included; equivalence tests use this).
    pub membrane_by_step: Vec<Vec<i32>>,
    /// Spike register pattern after each timestep.
    pub spikes_by_step: Vec<Vec<bool>>,
}

/// The synthesizable top (paper Fig. 3) as a cycle-stepped simulator with a
/// batched-timestep fast path.
pub struct RtlCore {
    cfg: SnnConfig,
    weights: WeightMatrix,
    controller: LayerController,
    encoder: RtlPoissonEncoder,
    neurons: LifNeuronArray,
    act: ActivityCounters,
    energy_model: EnergyModel,
    /// Membrane snapshot log (per timestep) while running.
    membrane_log: Vec<Vec<i32>>,
    spike_log: Vec<Vec<bool>>,
    /// Reusable fire-pattern buffer (hoisted out of the per-cycle loop).
    fired_scratch: Vec<bool>,
    /// Reusable active-pixel index list for the fast path.
    active_scratch: Vec<u32>,
    /// Optional waveform sink.
    vcd: Option<VcdWriter>,
}

impl RtlCore {
    pub fn new(cfg: SnnConfig, weights: WeightMatrix) -> Result<Self> {
        let cfg = cfg.validated()?;
        if weights.n_inputs() != cfg.n_inputs || weights.n_outputs() != cfg.n_outputs {
            return Err(Error::ShapeMismatch(format!(
                "weights {}x{} vs config {}x{}",
                weights.n_inputs(),
                weights.n_outputs(),
                cfg.n_inputs,
                cfg.n_outputs
            )));
        }
        if cfg.n_outputs > 64 {
            return Err(Error::InvalidConfig(format!(
                "RtlCore models at most 64 output neurons (u64 enable mask), got {}",
                cfg.n_outputs
            )));
        }
        Ok(RtlCore {
            controller: LayerController::new(&cfg),
            encoder: RtlPoissonEncoder::new(cfg.n_inputs),
            neurons: LifNeuronArray::new(&cfg),
            act: ActivityCounters::default(),
            energy_model: EnergyModel::default(),
            membrane_log: Vec::new(),
            spike_log: Vec::new(),
            fired_scratch: vec![false; cfg.n_outputs],
            active_scratch: Vec::with_capacity(cfg.n_inputs),
            weights,
            cfg,
            vcd: None,
        })
    }

    /// Override the energy model (ablations).
    pub fn with_energy_model(mut self, m: EnergyModel) -> Self {
        self.energy_model = m;
        self
    }

    /// Set the datapath width (pixels integrated per clock); see
    /// [`LayerController::set_pixels_per_cycle`]. Results are identical
    /// for any width (same architectural work per timestep — verified by
    /// test); only the cycle count changes.
    pub fn with_pixels_per_cycle(mut self, k: usize) -> Self {
        self.controller.set_pixels_per_cycle(k);
        self
    }

    /// Attach a VCD waveform writer; signals are dumped every cycle.
    pub fn attach_vcd(&mut self, vcd: VcdWriter) {
        self.vcd = Some(vcd);
    }

    /// Take back the VCD writer (to finish/flush it).
    pub fn detach_vcd(&mut self) -> Option<VcdWriter> {
        self.vcd.take()
    }

    pub fn config(&self) -> &SnnConfig {
        &self.cfg
    }

    /// Controller FSM state (observability).
    pub fn state(&self) -> CtrlState {
        self.controller.state()
    }

    /// Current membrane potentials.
    pub fn membranes(&self) -> Vec<i32> {
        self.neurons.membranes()
    }

    /// `load` pulse: latch an image + seed, reset all neuron state, leave
    /// the FSM in `Integrate{0}`.
    pub fn load_image(&mut self, img: &Image, seed: u32) -> Result<()> {
        if img.pixels.len() != self.cfg.n_inputs {
            return Err(Error::ShapeMismatch(format!(
                "image {} pixels vs core {}",
                img.pixels.len(),
                self.cfg.n_inputs
            )));
        }
        self.encoder.load(&img.pixels, seed, &mut self.act);
        self.neurons.reset(&mut self.act);
        self.controller.start();
        self.membrane_log.clear();
        self.spike_log.clear();
        Ok(())
    }

    /// Advance exactly one clock. Returns `true` while the window is still
    /// running (`false` once `Done`).
    pub fn tick_cycle(&mut self) -> bool {
        let state = self.controller.state();
        match state {
            CtrlState::Idle | CtrlState::Done => return false,
            CtrlState::Integrate { pixel } => {
                // One clock serves `pixels_per_cycle` lanes (1 = the
                // paper's Fig. 1 pixel-serial datapath). Each lane has its
                // own encoder comparator; spiking lanes fetch their weight
                // row and pulse the adder tree. BRAM fetches happen only
                // on a spike AND only while at least one neuron is still
                // enabled — once pruning has gated the whole array, the
                // weight memory goes idle too. (Measured consequence:
                // without that gate, BRAM reads dominate dynamic energy
                // and pruning saves almost nothing — EXPERIMENTS.md
                // ablation A.)
                let end = (pixel + self.controller.pixels_per_cycle()).min(self.cfg.n_inputs);
                let any_enabled = self.controller.any_enabled();
                for lane_pixel in pixel..end {
                    let spike = self.encoder.tick_pixel(lane_pixel, &mut self.act);
                    if spike && any_enabled {
                        self.act.bram_reads += 1;
                        self.neurons.add_row(self.weights.row(lane_pixel), &mut self.act);
                    }
                }
                // Immediate fire mode: comparator is combinational on the
                // accumulator; fire mid-integration.
                if self.cfg.fire_mode == FireMode::Immediate {
                    self.fired_scratch.fill(false);
                    let any =
                        self.neurons.immediate_fire(&mut self.fired_scratch, &mut self.act);
                    if any {
                        self.controller
                            .latch_fire(&self.fired_scratch, self.neurons.spike_counts());
                        self.apply_prune_mask();
                    }
                }
            }
            CtrlState::Leak { .. } => {
                self.neurons.leak_enabled(&mut self.act);
            }
            CtrlState::Fire => {
                self.fired_scratch.fill(false);
                if self.cfg.fire_mode == FireMode::EndOfStep {
                    self.neurons.fire_check(&mut self.fired_scratch, &mut self.act);
                }
                self.controller.latch_fire(&self.fired_scratch, self.neurons.spike_counts());
                self.apply_prune_mask();
                self.membrane_log.push(self.neurons.membranes());
                self.spike_log.push(self.fired_scratch.clone());
            }
        }
        self.act.cycles += 1;
        if let Some(v) = self.vcd.as_mut() {
            let membranes = self.neurons.membranes();
            v.sample(
                self.act.cycles,
                &state,
                &membranes,
                self.controller.spike_reg(),
                self.controller.enables(),
            );
        }
        self.controller.advance();
        self.controller.state() != CtrlState::Done
    }

    /// Drive the enable latches from the controller's pruning mask.
    fn apply_prune_mask(&mut self) {
        self.neurons.set_enables(self.controller.enables());
    }

    /// Run one full inference window through the cycle-stepped FSM.
    pub fn run(&mut self, img: &Image, seed: u32) -> Result<RtlResult> {
        self.load_image(img, seed)?;
        let start_cycles = self.act.cycles;
        let start_act = self.act;
        while self.tick_cycle() {}
        Ok(self.collect_result(start_cycles, &start_act))
    }

    /// Run one full inference window on the batched-timestep fast path.
    ///
    /// Produces an [`RtlResult`] byte-identical to [`RtlCore::run`]
    /// (including [`ActivityCounters`] and the per-step logs) without
    /// walking the FSM clock by clock: per timestep the encoder bulk-draws
    /// its comparators into an active-pixel list, only spiking rows reach
    /// the adder tree, and cycle counts come from the closed-form schedule
    /// (`⌈n_inputs/k⌉` integrate + leak + fire clocks). Falls back to the
    /// cycle path when a VCD sink is attached, which needs every clock.
    pub fn run_fast(&mut self, img: &Image, seed: u32) -> Result<RtlResult> {
        if self.vcd.is_some() {
            return self.run(img, seed);
        }
        self.load_image(img, seed)?;
        let start_cycles = self.act.cycles;
        let start_act = self.act;

        let n_in = self.cfg.n_inputs;
        let k = self.controller.pixels_per_cycle();
        let row_len = match self.cfg.leak_mode {
            LeakMode::PerRow { row_len } => Some(row_len),
            LeakMode::PerTimestep => None,
        };
        // Closed-form clock counts per timestep (EndOfStep only; the
        // Immediate path counts incrementally because enables — and with
        // them the schedule-relevant datapath state — can change per
        // integrate clock).
        let integrate_clocks = n_in.div_ceil(k) as u64;
        let leak_clocks = match row_len {
            Some(r) => ((n_in - 1) / r + 1) as u64,
            None => 1,
        };

        for _ in 0..self.cfg.timesteps {
            match self.cfg.fire_mode {
                FireMode::EndOfStep => {
                    self.fast_integrate_end_of_step(row_len);
                    self.act.cycles += integrate_clocks + leak_clocks;
                }
                FireMode::Immediate => self.fast_integrate_immediate(k, row_len),
            }
            // The Fire clock.
            self.fired_scratch.fill(false);
            if self.cfg.fire_mode == FireMode::EndOfStep {
                self.neurons.fire_check(&mut self.fired_scratch, &mut self.act);
            }
            self.controller.latch_fire(&self.fired_scratch, self.neurons.spike_counts());
            self.apply_prune_mask();
            self.membrane_log.push(self.neurons.membranes());
            self.spike_log.push(self.fired_scratch.clone());
            self.act.cycles += 1;
        }
        self.controller.finish();
        Ok(self.collect_result(start_cycles, &start_act))
    }

    /// One timestep's integrate + leak phases, `FireMode::EndOfStep`.
    ///
    /// Enables cannot change mid-timestep in this mode (pruning only acts
    /// on the Fire clock), so the BRAM gate is hoisted out of the pixel
    /// loop and the whole leak segment structure reduces to: one segment
    /// per row (`PerRow`) or one segment for the full frame, each followed
    /// by its Leak clock — the last segment's leak being the end-of-step
    /// leak, exactly as the FSM schedules it.
    fn fast_integrate_end_of_step(&mut self, row_len: Option<usize>) {
        let n_in = self.cfg.n_inputs;
        let seg = row_len.unwrap_or(n_in);
        let any_enabled = self.controller.any_enabled();
        let mut start = 0usize;
        while start < n_in {
            let end = (start + seg).min(n_in);
            self.active_scratch.clear();
            self.encoder.tick_range_into(start, end, &mut self.active_scratch, &mut self.act);
            if any_enabled {
                for &p in &self.active_scratch {
                    self.act.bram_reads += 1;
                    self.neurons.add_row(self.weights.row(p as usize), &mut self.act);
                }
            }
            self.neurons.leak_enabled(&mut self.act);
            start = end;
        }
    }

    /// One timestep's integrate + leak phases, `FireMode::Immediate`.
    ///
    /// Replays the FSM's exact grouping: each integrate clock serves `k`
    /// encoder lanes, then the combinational threshold check fires (and
    /// possibly prunes) mid-phase; leak clocks land on row boundaries and
    /// at the end of the frame. Cycle counting is incremental because the
    /// schedule is walked group by group.
    fn fast_integrate_immediate(&mut self, k: usize, row_len: Option<usize>) {
        let n_in = self.cfg.n_inputs;
        let mut pixel = 0usize;
        while pixel < n_in {
            let end = (pixel + k).min(n_in);
            let any_enabled = self.controller.any_enabled();
            self.active_scratch.clear();
            self.encoder.tick_range_into(pixel, end, &mut self.active_scratch, &mut self.act);
            if any_enabled {
                for &p in &self.active_scratch {
                    self.act.bram_reads += 1;
                    self.neurons.add_row(self.weights.row(p as usize), &mut self.act);
                }
            }
            self.act.cycles += 1; // the Integrate clock
            self.fired_scratch.fill(false);
            let any = self.neurons.immediate_fire(&mut self.fired_scratch, &mut self.act);
            if any {
                self.controller.latch_fire(&self.fired_scratch, self.neurons.spike_counts());
                self.apply_prune_mask();
            }
            pixel = end;
            if pixel == n_in || row_len.is_some_and(|r| pixel % r == 0) {
                self.neurons.leak_enabled(&mut self.act);
                self.act.cycles += 1; // the Leak clock
            }
        }
    }

    /// Package the window's outputs + activity delta into an [`RtlResult`].
    fn collect_result(&mut self, start_cycles: u64, start_act: &ActivityCounters) -> RtlResult {
        let spike_counts = self.neurons.spike_counts().to_vec();
        let window_act = self.act.since(start_act);
        let energy = self.energy_model.evaluate(&window_act);
        RtlResult {
            class: LayerController::decide(&spike_counts),
            spike_counts,
            cycles: self.act.cycles - start_cycles,
            activity: window_act,
            energy,
            membrane_by_step: std::mem::take(&mut self.membrane_log),
            spikes_by_step: std::mem::take(&mut self.spike_log),
        }
    }

    /// Cumulative activity across all windows run so far.
    pub fn total_activity(&self) -> ActivityCounters {
        self.act
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DecisionPolicy, FireMode, LeakMode, PruneMode};
    use crate::data::DigitGen;
    use crate::snn::BehavioralNet;
    use crate::testutil::PropRunner;

    fn test_weights(seed: u32) -> WeightMatrix {
        let mut rng = crate::prng::Xorshift32::new(seed);
        let data: Vec<i32> = (0..7840).map(|_| rng.range_i32(-30, 60)).collect();
        WeightMatrix::from_rows(784, 10, 9, data).unwrap()
    }

    #[test]
    fn cycle_count_matches_schedule() {
        let cfg = SnnConfig::paper().with_timesteps(3);
        let mut core = RtlCore::new(cfg, test_weights(1)).unwrap();
        let img = DigitGen::new(1).sample(0, 0);
        let r = core.run(&img, 42).unwrap();
        // (784 integrate + 1 leak + 1 fire) × 3 timesteps.
        assert_eq!(r.cycles, 786 * 3);
        assert_eq!(r.membrane_by_step.len(), 3);
        assert_eq!(r.spikes_by_step.len(), 3);
    }

    #[test]
    fn per_row_leak_adds_cycles() {
        let cfg = SnnConfig::paper()
            .with_timesteps(1)
            .with_leak_mode(LeakMode::PerRow { row_len: 28 });
        let mut core = RtlCore::new(cfg, test_weights(1)).unwrap();
        let img = DigitGen::new(1).sample(0, 0);
        let r = core.run(&img, 42).unwrap();
        // 784 integrate + 28 leaks (27 mid-row + 1 final) + 1 fire.
        assert_eq!(r.cycles, 784 + 28 + 1);
    }

    /// The core equivalence theorem: RTL (EndOfStep, PerTimestep) ==
    /// behavioral model, step by step, over random weights/images/seeds.
    #[test]
    fn rtl_equals_behavioral_model() {
        PropRunner::new("rtl_equiv", 12).run(|g| {
            let cfg = SnnConfig::paper()
                .with_timesteps(g.rng.range_i32(2, 8) as u32)
                .with_v_th(g.rng.range_i32(60, 400))
                .with_decay_shift(g.rng.range_i32(1, 5) as u32);
            let w = test_weights(g.rng.next_u32());
            let img = DigitGen::new(g.rng.next_u32()).sample(g.rng.below(10) as u8, g.rng.below(20));
            let seed = g.rng.next_u32();

            let mut core = RtlCore::new(cfg.clone(), w.clone()).unwrap();
            let rtl = core.run(&img, seed).unwrap();
            assert_eq!(rtl.activity.saturations, 0, "saturation voids equivalence");

            let net = BehavioralNet::new(cfg.clone(), w).unwrap();
            let (beh, traces) = net.classify_traced(&img, seed, cfg.timesteps);

            assert_eq!(rtl.spike_counts, beh.spike_counts, "spike counts diverge");
            assert_eq!(rtl.class, beh.class, "decision diverges");
            for (t, (rtl_mem, trace)) in
                rtl.membrane_by_step.iter().zip(traces.iter()).enumerate()
            {
                assert_eq!(rtl_mem, &trace.membrane, "membrane diverges at step {t}");
                assert_eq!(
                    &rtl.spikes_by_step[t], &trace.fired,
                    "fire pattern diverges at step {t}"
                );
            }
        });
    }

    /// The fast-path theorem: `run_fast` produces a bit-identical
    /// `RtlResult` — spike counts, decision, cycle count, per-step
    /// membrane/fire logs AND every activity counter — across the full
    /// fire/leak/prune mode cross-product, datapath widths, and weights
    /// hot enough to exercise per-add saturation.
    #[test]
    fn fast_path_equals_cycle_path() {
        PropRunner::new("fast_path_equiv", 40).run(|g| {
            let fire = *g.choice(&[FireMode::EndOfStep, FireMode::Immediate]);
            let leak = *g.choice(&[
                LeakMode::PerTimestep,
                LeakMode::PerRow { row_len: 28 },
                LeakMode::PerRow { row_len: 112 },
            ]);
            let prune = *g.choice(&[
                PruneMode::Off,
                PruneMode::AfterFires { after_spikes: 1 },
                PruneMode::AfterFires { after_spikes: 3 },
            ]);
            // Widths that divide 28 keep PerRow's alignment contract.
            let k = *g.choice(&[1usize, 2, 4, 7, 14, 28]);
            // Occasionally squeeze the accumulator so the saturating adder
            // actually clamps — the fast path must count those events and
            // clamp per-add exactly like the cycle path.
            let squeeze = g.rng.below(3) == 0;
            let cfg = SnnConfig::paper()
                .with_timesteps(g.rng.range_i32(1, 6) as u32)
                .with_fire_mode(fire)
                .with_leak_mode(leak)
                .with_prune(prune)
                .with_v_th(if squeeze { 120 } else { g.rng.range_i32(80, 300) })
                .with_decay_shift(g.rng.range_i32(1, 5) as u32);
            let cfg = if squeeze { SnnConfig { acc_bits: 9, ..cfg } } else { cfg };
            let w = if squeeze {
                // Hot uniform drive against a 9-bit accumulator saturates.
                WeightMatrix::from_rows(784, 10, 9, vec![120; 7840]).unwrap()
            } else {
                test_weights(g.rng.next_u32())
            };
            let img = DigitGen::new(g.rng.next_u32()).sample(g.rng.below(10) as u8, g.rng.below(20));
            let seed = g.rng.next_u32();

            let slow = RtlCore::new(cfg.clone(), w.clone())
                .unwrap()
                .with_pixels_per_cycle(k)
                .run(&img, seed)
                .unwrap();
            let fast = RtlCore::new(cfg.clone(), w)
                .unwrap()
                .with_pixels_per_cycle(k)
                .run_fast(&img, seed)
                .unwrap();
            // With EndOfStep firing the hot drive provably saturates the
            // 9-bit accumulator during the first step; under Immediate the
            // mid-phase resets can keep it below the rail, so only the
            // equality check applies there.
            if squeeze && fire == FireMode::EndOfStep {
                assert!(
                    fast.activity.saturations > 0,
                    "squeeze case must exercise the saturating adder"
                );
            }
            assert_eq!(
                slow, fast,
                "fast path diverges (fire={fire:?} leak={leak:?} prune={prune:?} k={k})"
            );
        });
    }

    #[test]
    fn fast_path_leaves_core_reusable_and_done() {
        // Back-to-back windows on one core must be independent on both
        // paths, and the fast path must leave the FSM observable as Done.
        let cfg = SnnConfig::paper().with_timesteps(3);
        let img = DigitGen::new(1).sample(5, 1);
        let mut core = RtlCore::new(cfg.clone(), test_weights(3)).unwrap();
        let a = core.run_fast(&img, 7).unwrap();
        assert_eq!(core.state(), CtrlState::Done);
        let b = core.run_fast(&img, 7).unwrap();
        assert_eq!(a, b, "fast path must be stateless across windows");
        let c = core.run(&img, 7).unwrap();
        assert_eq!(a, c, "interleaved cycle path must agree");
        assert_eq!(core.total_activity().cycles, 3 * 786 * 3);
    }

    #[test]
    fn fast_path_falls_back_under_vcd() {
        let cfg = SnnConfig::paper().with_timesteps(2);
        let img = DigitGen::new(1).sample(4, 0);
        let mut plain = RtlCore::new(cfg.clone(), test_weights(5)).unwrap();
        let want = plain.run_fast(&img, 9).unwrap();
        let mut core = RtlCore::new(cfg, test_weights(5)).unwrap();
        core.attach_vcd(VcdWriter::new(10, 25));
        let got = core.run_fast(&img, 9).unwrap();
        assert_eq!(want, got);
        let vcd = core.detach_vcd().unwrap().finish();
        assert!(vcd.matches('#').count() > 10, "VCD must still capture every cycle");
    }

    #[test]
    fn pruning_reduces_activity() {
        let img = DigitGen::new(1).sample(3, 0);
        let w = test_weights(7);
        let on = SnnConfig::paper()
            .with_timesteps(20)
            .with_prune(PruneMode::AfterFires { after_spikes: 1 });
        let off = on.clone().with_prune(PruneMode::Off);
        let r_on = RtlCore::new(on, w.clone()).unwrap().run(&img, 9).unwrap();
        let r_off = RtlCore::new(off, w).unwrap().run(&img, 9).unwrap();
        // Same cycle count (the schedule is fixed) but strictly less
        // switching activity when neurons get gated off.
        assert_eq!(r_on.cycles, r_off.cycles);
        assert!(
            r_on.spike_counts.iter().sum::<u32>() > 0,
            "test needs at least one spike to exercise pruning"
        );
        assert!(
            r_on.activity.adds < r_off.activity.adds,
            "pruning must cut integrate adds: {} vs {}",
            r_on.activity.adds,
            r_off.activity.adds
        );
        assert!(r_on.energy.dynamic_nj < r_off.energy.dynamic_nj);
    }

    #[test]
    fn immediate_mode_fires_mid_step() {
        // With a huge drive, Immediate mode fires during integration and
        // (with pruning) freezes counts at 1 per neuron.
        let cfg = SnnConfig::paper()
            .with_timesteps(2)
            .with_v_th(64)
            .with_fire_mode(FireMode::Immediate)
            .with_decision(DecisionPolicy::SpikeCount);
        let w = WeightMatrix::from_rows(784, 10, 9, vec![100; 7840]).unwrap();
        let img = crate::data::Image { label: 0, pixels: vec![255; 784] };
        let mut core = RtlCore::new(cfg, w).unwrap();
        let r = core.run(&img, 3).unwrap();
        assert!(r.spike_counts.iter().all(|&c| c == 1), "{:?}", r.spike_counts);
    }

    #[test]
    fn event_driven_gating_zero_input() {
        // A black image produces no spikes: no adds, no BRAM reads.
        let cfg = SnnConfig::paper().with_timesteps(5);
        let img = crate::data::Image { label: 0, pixels: vec![0; 784] };
        let mut core = RtlCore::new(cfg, test_weights(3)).unwrap();
        let r = core.run(&img, 11).unwrap();
        assert_eq!(r.activity.bram_reads, 0);
        // Only leak-cycle adds (the subtract half of shift-subtract).
        assert_eq!(r.activity.adds, 5 * 10); // 5 steps × 10 neurons × 1 leak
    }

    #[test]
    fn datapath_width_changes_cycles_not_results() {
        let img = DigitGen::new(1).sample(6, 2);
        let w = test_weights(11);
        let cfg = SnnConfig::paper().with_timesteps(4);
        let mut reference = None;
        for k in [1usize, 2, 4, 7, 784] {
            let mut core =
                RtlCore::new(cfg.clone(), w.clone()).unwrap().with_pixels_per_cycle(k);
            let r = core.run(&img, 99).unwrap();
            // Cycle count: ceil(784/k) integrate clocks + leak + fire.
            let integrate = 784usize.div_ceil(k);
            assert_eq!(r.cycles, (integrate as u64 + 2) * 4, "width {k}");
            match &reference {
                None => reference = Some(r),
                Some(base) => {
                    assert_eq!(r.spike_counts, base.spike_counts, "width {k}");
                    assert_eq!(r.membrane_by_step, base.membrane_by_step, "width {k}");
                    // Same architectural work regardless of width.
                    assert_eq!(r.activity.adds, base.activity.adds, "width {k}");
                    assert_eq!(r.activity.prng_steps, base.activity.prng_steps);
                }
            }
        }
    }

    #[test]
    fn per_row_width_alignment_enforced() {
        let cfg = SnnConfig::paper().with_leak_mode(LeakMode::PerRow { row_len: 28 });
        let core = RtlCore::new(cfg, test_weights(1)).unwrap();
        // 28 % 4 == 0: fine; 28 % 3 != 0: must panic.
        let _ok = core.with_pixels_per_cycle(4);
        let cfg = SnnConfig::paper().with_leak_mode(LeakMode::PerRow { row_len: 28 });
        let core = RtlCore::new(cfg, test_weights(1)).unwrap();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            core.with_pixels_per_cycle(3)
        }));
        assert!(res.is_err(), "misaligned width must be rejected");
    }

    #[test]
    fn bram_goes_idle_once_all_neurons_pruned() {
        // Huge uniform drive + prune-after-1: all ten neurons fire on the
        // first step; from step 2 on the weight BRAM must not be read.
        let cfg = SnnConfig::paper()
            .with_timesteps(5)
            .with_v_th(64)
            .with_prune(PruneMode::AfterFires { after_spikes: 1 });
        let w = WeightMatrix::from_rows(784, 10, 9, vec![100; 7840]).unwrap();
        let img = crate::data::Image { label: 0, pixels: vec![255; 784] };
        let mut core = RtlCore::new(cfg, w).unwrap();
        let r = core.run(&img, 3).unwrap();
        assert!(r.spike_counts.iter().all(|&c| c == 1));
        // Roughly one timestep's worth of spikes (~99% rate), not five.
        assert!(
            r.activity.bram_reads < 790,
            "BRAM still active after full pruning: {} reads",
            r.activity.bram_reads
        );
    }

    #[test]
    fn rejects_geometry_mismatch() {
        let cfg = SnnConfig::paper();
        let w = WeightMatrix::zeros(100, 10, 9);
        assert!(RtlCore::new(cfg, w).is_err());
        let cfg = SnnConfig::paper();
        let w = WeightMatrix::zeros(784, 10, 9);
        let mut core = RtlCore::new(cfg, w).unwrap();
        let bad = crate::data::Image { label: 0, pixels: vec![0; 10] };
        assert!(core.load_image(&bad, 1).is_err());
    }
}
