//! RTL Poisson encoder (paper Fig. 2): a file of per-pixel xorshift32
//! state registers plus an 8-bit magnitude comparator.
//!
//! One pixel is served per `Integrate` clock: its register advances through
//! the three XOR/shift stages and the comparator asserts `spike` when the
//! stored intensity exceeds the low byte of the new state. Over a full
//! timestep (784 cycles) this produces exactly the same spike vector as the
//! behavioral [`crate::snn::PoissonEncoder`], which advances all streams
//! "at once" — the per-pixel streams are independent, so serialization
//! order cannot change the values. That equality is pinned by tests here.

use crate::prng::{pixel_seed, xorshift32_step};

use super::power::ActivityCounters;

/// The encoder's architectural state: one 32-bit PRNG register per pixel
/// plus the latched input intensities.
#[derive(Debug, Clone)]
pub struct RtlPoissonEncoder {
    states: Vec<u32>,
    intensities: Vec<u8>,
}

impl RtlPoissonEncoder {
    /// Instantiate for `n_pixels` channels (registers undefined until
    /// [`RtlPoissonEncoder::load`], as in hardware after power-up).
    pub fn new(n_pixels: usize) -> Self {
        RtlPoissonEncoder { states: vec![1; n_pixels], intensities: vec![0; n_pixels] }
    }

    /// `load` pulse: latch the image and re-seed every PRNG register
    /// (the seed bus carries the per-image seed; the seeding network is
    /// the [`pixel_seed`] contract).
    pub fn load(&mut self, intensities: &[u8], seed: u32, act: &mut ActivityCounters) {
        assert_eq!(intensities.len(), self.states.len(), "encoder width");
        self.intensities.copy_from_slice(intensities);
        for (i, s) in self.states.iter_mut().enumerate() {
            let next = pixel_seed(seed, i as u32);
            act.reg_toggles += u64::from((*s ^ next).count_ones());
            *s = next;
        }
        act.prng_steps += self.states.len() as u64; // seeding network pass
    }

    /// One `Integrate` clock serving pixel `p`: advance its PRNG register
    /// and return the comparator output.
    #[inline]
    pub fn tick_pixel(&mut self, p: usize, act: &mut ActivityCounters) -> bool {
        let prev = self.states[p];
        let next = xorshift32_step(prev);
        act.reg_toggles += u64::from((prev ^ next).count_ones());
        act.prng_steps += 1;
        act.compares += 1; // the 8-bit magnitude comparator
        self.states[p] = next;
        u32::from(self.intensities[p]) > (next & 0xFF)
    }

    /// Bulk variant of [`RtlPoissonEncoder::tick_pixel`] for the fast path:
    /// advance every PRNG register in `start..end` and append the indices
    /// of spiking pixels to `active` (not cleared). Records exactly the
    /// same [`ActivityCounters`] events as `end - start` `tick_pixel` calls
    /// (the counter sums are order-independent), but keeps the running
    /// toggle total in a register instead of read-modify-writing the
    /// counter struct per pixel.
    ///
    /// The body runs four interleaved xorshift32 lanes per iteration: the
    /// per-pixel streams are independent, so the three XOR/shift stages
    /// and the popcount vectorize across lanes (this is the fast path's
    /// hottest loop — one draw per pixel per timestep). Spike indices are
    /// emitted lane-by-lane in ascending order, so the active list is
    /// byte-identical to the scalar walk; the pinned lane draws and
    /// chi-squared law in `rust/tests/encoder_stats.rs` plus the golden
    /// `run_fast` fixtures fail loudly on any bit drift.
    // pallas-lint: hot
    pub fn tick_range_into(
        &mut self,
        start: usize,
        end: usize,
        active: &mut Vec<u32>,
        act: &mut ActivityCounters,
    ) {
        debug_assert!(start <= end && end <= self.states.len());
        let mut toggles = 0u64;
        let mut p = start;
        while p + 4 <= end {
            let s0 = self.states[p];
            let s1 = self.states[p + 1];
            let s2 = self.states[p + 2];
            let s3 = self.states[p + 3];
            let n0 = xorshift32_step(s0);
            let n1 = xorshift32_step(s1);
            let n2 = xorshift32_step(s2);
            let n3 = xorshift32_step(s3);
            toggles += u64::from((s0 ^ n0).count_ones())
                + u64::from((s1 ^ n1).count_ones())
                + u64::from((s2 ^ n2).count_ones())
                + u64::from((s3 ^ n3).count_ones());
            self.states[p] = n0;
            self.states[p + 1] = n1;
            self.states[p + 2] = n2;
            self.states[p + 3] = n3;
            if u32::from(self.intensities[p]) > (n0 & 0xFF) {
                active.push(p as u32);
            }
            if u32::from(self.intensities[p + 1]) > (n1 & 0xFF) {
                active.push(p as u32 + 1);
            }
            if u32::from(self.intensities[p + 2]) > (n2 & 0xFF) {
                active.push(p as u32 + 2);
            }
            if u32::from(self.intensities[p + 3]) > (n3 & 0xFF) {
                active.push(p as u32 + 3);
            }
            p += 4;
        }
        while p < end {
            let prev = self.states[p];
            let next = xorshift32_step(prev);
            toggles += u64::from((prev ^ next).count_ones());
            self.states[p] = next;
            if u32::from(self.intensities[p]) > (next & 0xFF) {
                active.push(p as u32);
            }
            p += 1;
        }
        act.reg_toggles += toggles;
        act.prng_steps += (end - start) as u64;
        act.compares += (end - start) as u64;
    }
    // pallas-lint: end-hot

    /// Current PRNG register values (observability for tests/waveforms).
    pub fn states(&self) -> &[u32] {
        &self.states
    }

    /// Latched intensity for pixel `p`.
    pub fn intensity(&self, p: usize) -> u8 {
        self.intensities[p]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DigitGen, Image, IMG_PIXELS};
    use crate::snn::encode_image;

    #[test]
    fn matches_behavioral_encoder_exactly() {
        let img = DigitGen::new(1).sample(7, 3);
        let seed = 0xABCD_1234;
        let timesteps = 12u32;
        let golden = encode_image(&img, seed, timesteps);

        let mut act = ActivityCounters::default();
        let mut enc = RtlPoissonEncoder::new(IMG_PIXELS);
        enc.load(&img.pixels, seed, &mut act);
        for t in 0..timesteps as usize {
            for p in 0..IMG_PIXELS {
                let spike = enc.tick_pixel(p, &mut act);
                assert_eq!(
                    spike, golden[t][p],
                    "RTL/behavioral encoder divergence at t={t} pixel={p}"
                );
            }
        }
    }

    #[test]
    fn reload_restarts_stream() {
        let img = Image { label: 0, pixels: vec![200; IMG_PIXELS] }; // bright
        let mut act = ActivityCounters::default();
        let mut enc = RtlPoissonEncoder::new(IMG_PIXELS);
        enc.load(&img.pixels, 5, &mut act);
        let first: Vec<bool> = (0..IMG_PIXELS).map(|p| enc.tick_pixel(p, &mut act)).collect();
        // Re-load with the same seed: identical spikes again.
        enc.load(&img.pixels, 5, &mut act);
        let second: Vec<bool> = (0..IMG_PIXELS).map(|p| enc.tick_pixel(p, &mut act)).collect();
        assert_eq!(first, second);
    }

    #[test]
    fn tick_range_matches_tick_pixel() {
        let img = DigitGen::new(3).sample(4, 1);
        let mut a = RtlPoissonEncoder::new(IMG_PIXELS);
        let mut b = RtlPoissonEncoder::new(IMG_PIXELS);
        let mut act_a = ActivityCounters::default();
        let mut act_b = ActivityCounters::default();
        a.load(&img.pixels, 77, &mut act_a);
        b.load(&img.pixels, 77, &mut act_b);
        let mut active = Vec::new();
        for t in 0..8 {
            // Uneven splits exercise the range boundaries, including
            // non-multiple-of-4 lengths that take the scalar tail of the
            // 4-lane bulk walk.
            active.clear();
            b.tick_range_into(0, 157, &mut active, &mut act_b);
            b.tick_range_into(157, 301, &mut active, &mut act_b);
            b.tick_range_into(301, IMG_PIXELS, &mut active, &mut act_b);
            let mut expect = Vec::new();
            for p in 0..IMG_PIXELS {
                if a.tick_pixel(p, &mut act_a) {
                    expect.push(p as u32);
                }
            }
            assert_eq!(active, expect, "active set diverges at step {t}");
            assert_eq!(act_a, act_b, "activity diverges at step {t}");
            assert_eq!(a.states(), b.states(), "PRNG state diverges at step {t}");
        }
    }

    #[test]
    fn counts_activity() {
        let img = Image { label: 0, pixels: vec![128; IMG_PIXELS] };
        let mut act = ActivityCounters::default();
        let mut enc = RtlPoissonEncoder::new(IMG_PIXELS);
        enc.load(&img.pixels, 5, &mut act);
        let after_load = act.prng_steps;
        assert_eq!(after_load, IMG_PIXELS as u64);
        for p in 0..IMG_PIXELS {
            enc.tick_pixel(p, &mut act);
        }
        assert_eq!(act.prng_steps, after_load + IMG_PIXELS as u64);
        assert_eq!(act.compares, IMG_PIXELS as u64);
        assert!(act.reg_toggles > 0);
    }
}
