//! One LIF neuron core (paper Fig. 1): accumulator register, saturating
//! adder, shift-based decay unit, threshold comparator, spike-count
//! register and enable gating.
//!
//! The core is modelled two-phase: the controller presents a [`NeuronCtrl`]
//! command word (the decoded control signals for this clock) and `tick`
//! commits the posedge. All datapath activity is recorded into
//! [`ActivityCounters`].
//!
//! Three representations share the same semantics:
//!
//! * [`LifNeuronCore`] — one neuron as an object; the readable reference
//!   model, kept for unit tests and documentation.
//! * [`LifNeuronArray`] — one whole layer as a structure-of-arrays (flat
//!   `acc` / `spike_count` buffers plus a multi-word enable bitmask, so
//!   hidden layers wider than 64 neurons fit). This is what
//!   [`crate::rtl::RtlCore`] actually runs on the single-image paths —
//!   one array per layer of the topology: the per-cycle inner loops walk
//!   contiguous memory and skip disabled neurons by bit iteration instead
//!   of dispatching through an object array.
//! * [`LifBatchArray`] — one layer × a whole sub-batch: per-image
//!   accumulator/spike-count planes plus one enable bitmask per batch
//!   lane, addressed `plane[b * width + j]`. This is the state behind
//!   [`crate::rtl::RtlCore::run_fast_batch`], where one weight-row fetch
//!   is applied to every batch image whose input fired.
//!
//! The single-image array and the batch array run the *same* lane-level
//! datapath primitives (`lane_add_row` / `lane_leak` / `lane_fire_check` /
//! `lane_immediate_fire` below) — the wrappers differ only in plane
//! addressing, so the arithmetic (per-add saturation, Hamming-distance
//! toggle accounting, enable gating) cannot drift between the sequential
//! and the batched engines. All three representations are proven state-
//! and activity-equivalent by the property tests below.

use crate::config::{PruneMode, SnnConfig};
use crate::fixed::leak;

use super::power::ActivityCounters;

/// Decoded per-clock control signals driven by the layer controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NeuronCtrl {
    /// Hold: no enable asserted this clock.
    Idle,
    /// `add_en`: integrate `weight` into the accumulator.
    Add { weight: i32 },
    /// `leak_en`: apply the shift-subtract decay.
    Leak,
    /// `fire_en`: evaluate the threshold comparator; fire & hard-reset when
    /// `acc ≥ V_th`.
    FireCheck,
    /// Synchronous reset (new inference window).
    Reset,
}

/// Architectural state of a single neuron core.
#[derive(Debug, Clone)]
pub struct LifNeuronCore {
    /// Membrane accumulator register (sign-extended to i32; physically
    /// `acc_bits` wide).
    acc: i32,
    /// Output spike count register (used by readout and pruning).
    spike_count: u32,
    /// Enable latch: cleared by the controller's pruning mask.
    enabled: bool,
    /// Fired-this-cycle flag (the `Fire` output wire).
    fired: bool,
    cfg_acc_bits: u32,
    cfg_decay_shift: u32,
    cfg_v_th: i32,
    cfg_v_rest: i32,
}

impl LifNeuronCore {
    pub fn new(cfg: &SnnConfig) -> Self {
        LifNeuronCore {
            acc: cfg.v_rest,
            spike_count: 0,
            enabled: true,
            fired: false,
            cfg_acc_bits: cfg.acc_bits,
            cfg_decay_shift: cfg.decay_shift,
            cfg_v_th: cfg.v_th,
            cfg_v_rest: cfg.v_rest,
        }
    }

    /// Membrane potential (the accumulator register).
    pub fn acc(&self) -> i32 {
        self.acc
    }

    /// Spike-count register.
    pub fn spike_count(&self) -> u32 {
        self.spike_count
    }

    /// Enable latch value.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The `Fire` wire: did the neuron fire on the last `tick`?
    pub fn fired(&self) -> bool {
        self.fired
    }

    /// Controller drives the enable latch (pruning mask).
    pub fn set_enabled(&mut self, en: bool) {
        self.enabled = en;
    }

    /// Commit one clock edge under `ctrl`. Returns the `Fire` wire value.
    pub fn tick(&mut self, ctrl: NeuronCtrl, act: &mut ActivityCounters) -> bool {
        self.fired = false;
        if !self.enabled && !matches!(ctrl, NeuronCtrl::Reset) {
            // Gated clock: a disabled neuron burns no dynamic power.
            return false;
        }
        match ctrl {
            NeuronCtrl::Idle => {}
            NeuronCtrl::Add { weight } => {
                let max = (1i32 << (self.cfg_acc_bits - 1)) - 1;
                let sum = i64::from(self.acc) + i64::from(weight);
                let clamped = sum.clamp(-(max as i64), max as i64) as i32;
                if clamped as i64 != sum {
                    act.saturations += 1;
                }
                act.adds += 1;
                self.write_acc(clamped, act);
            }
            NeuronCtrl::Leak => {
                let next = leak(self.acc, self.cfg_decay_shift);
                act.shifts += 1;
                act.adds += 1; // the subtract half of shift-subtract
                self.write_acc(next, act);
            }
            NeuronCtrl::FireCheck => {
                act.compares += 1;
                if self.acc >= self.cfg_v_th {
                    self.fired = true;
                    self.spike_count += 1;
                    act.reg_toggles += 1; // spike-count increment (approx.)
                    self.write_acc(self.cfg_v_rest, act);
                }
            }
            NeuronCtrl::Reset => {
                self.write_acc(self.cfg_v_rest, act);
                self.spike_count = 0;
                self.enabled = true;
                self.fired = false;
            }
        }
        self.fired
    }

    /// Combinational threshold check used in `FireMode::Immediate` during
    /// integration (comparator output without a clock commit).
    pub fn above_threshold(&self) -> bool {
        self.acc >= self.cfg_v_th
    }

    #[inline]
    fn write_acc(&mut self, next: i32, act: &mut ActivityCounters) {
        act.reg_toggles += u64::from(((self.acc as u32) ^ (next as u32)).count_ones());
        self.acc = next;
    }
}

// ---------------------------------------------------------------------------

/// The calibration registers one neuron lane runs under (resolved per
/// layer; shared by every lane of a batch — a batch multiplexes images
/// over one physical layer, so the calibration is common by construction).
#[derive(Debug, Clone, Copy)]
struct LaneParams {
    acc_max: i32,
    decay_shift: u32,
    v_th: i32,
    v_rest: i32,
}

impl LaneParams {
    fn from_cfg(cfg: &SnnConfig) -> Self {
        LaneParams {
            acc_max: cfg.acc_max(),
            decay_shift: cfg.decay_shift,
            v_th: cfg.v_th,
            v_rest: cfg.v_rest,
        }
    }
}

/// Register write with Hamming-distance toggle accounting — the one
/// `write_acc` every lane-level primitive goes through.
#[inline(always)]
fn write_acc_at(acc: &mut [i32], j: usize, next: i32, act: &mut ActivityCounters) {
    act.reg_toggles += u64::from(((acc[j] as u32) ^ (next as u32)).count_ones());
    acc[j] = next;
}

/// One BRAM row pulse over one lane: integrate `row[j]` into every
/// *enabled* neuron with per-add saturation (ascending `j`, like the
/// adder-tree fanout).
#[inline]
fn lane_add_row(
    acc: &mut [i32],
    enabled: &[u64],
    row: &[i32],
    p: &LaneParams,
    act: &mut ActivityCounters,
) {
    debug_assert_eq!(row.len(), acc.len());
    for wi in 0..enabled.len() {
        let mut m = enabled[wi];
        while m != 0 {
            let j = wi * 64 + m.trailing_zeros() as usize;
            m &= m - 1;
            let sum = i64::from(acc[j]) + i64::from(row[j]);
            let clamped = sum.clamp(-i64::from(p.acc_max), i64::from(p.acc_max)) as i32;
            if i64::from(clamped) != sum {
                act.saturations += 1;
            }
            act.adds += 1;
            write_acc_at(acc, j, clamped, act);
        }
    }
}

/// One CSR row pulse over one lane: integrate the row's retained
/// `(column, weight)` entries into every *enabled* neuron, per-add
/// saturation, ascending column order — the event-driven twin of
/// [`lane_add_row`]. Skipped synapses (pruned entries, disabled
/// neurons) record nothing, which is exactly how the BRAM-gating
/// ablation credits pruned neurons: the counters are simply lower. At
/// magnitude threshold 0 the CSR holds every entry, so the visited set,
/// order and arithmetic are identical to the dense walk — bit- and
/// activity-exact.
#[inline]
fn lane_add_sparse(
    acc: &mut [i32],
    enabled: &[u64],
    cols: &[u32],
    vals: &[i32],
    p: &LaneParams,
    act: &mut ActivityCounters,
) {
    debug_assert_eq!(cols.len(), vals.len());
    for (&j, &w) in cols.iter().zip(vals) {
        let j = j as usize;
        if (enabled[j / 64] >> (j % 64)) & 1 == 0 {
            continue;
        }
        let sum = i64::from(acc[j]) + i64::from(w);
        let clamped = sum.clamp(-i64::from(p.acc_max), i64::from(p.acc_max)) as i32;
        if i64::from(clamped) != sum {
            act.saturations += 1;
        }
        act.adds += 1;
        write_acc_at(acc, j, clamped, act);
    }
}

/// One `Leak` clock over one lane: shift-subtract decay on every enabled
/// neuron.
#[inline]
fn lane_leak(acc: &mut [i32], enabled: &[u64], p: &LaneParams, act: &mut ActivityCounters) {
    for wi in 0..enabled.len() {
        let mut m = enabled[wi];
        while m != 0 {
            let j = wi * 64 + m.trailing_zeros() as usize;
            m &= m - 1;
            let next = leak(acc[j], p.decay_shift);
            act.shifts += 1;
            act.adds += 1; // the subtract half of shift-subtract
            write_acc_at(acc, j, next, act);
        }
    }
}

/// One `Fire` clock over one lane (`FireMode::EndOfStep`): evaluate the
/// threshold comparator of every enabled neuron, setting `fired[j]` and
/// hard-resetting on a crossing. `fired` must be pre-cleared.
fn lane_fire_check(
    acc: &mut [i32],
    spike_count: &mut [u32],
    enabled: &[u64],
    fired: &mut [bool],
    p: &LaneParams,
    act: &mut ActivityCounters,
) {
    debug_assert_eq!(fired.len(), acc.len());
    for wi in 0..enabled.len() {
        let mut m = enabled[wi];
        while m != 0 {
            let j = wi * 64 + m.trailing_zeros() as usize;
            m &= m - 1;
            act.compares += 1;
            if acc[j] >= p.v_th {
                fired[j] = true;
                spike_count[j] += 1;
                act.reg_toggles += 1; // spike-count increment (approx.)
                write_acc_at(acc, j, p.v_rest, act);
            }
        }
    }
}

/// Mid-integration combinational fire over one lane
/// (`FireMode::Immediate`): only neurons whose accumulator is at/above
/// threshold commit a `FireCheck` (and its comparator activity), exactly
/// like the cycle path's `above_threshold()` pre-gate. Returns true when
/// any neuron fired. `fired` must be pre-cleared.
fn lane_immediate_fire(
    acc: &mut [i32],
    spike_count: &mut [u32],
    enabled: &[u64],
    fired: &mut [bool],
    p: &LaneParams,
    act: &mut ActivityCounters,
) -> bool {
    debug_assert_eq!(fired.len(), acc.len());
    let mut any = false;
    for wi in 0..enabled.len() {
        let mut m = enabled[wi];
        while m != 0 {
            let j = wi * 64 + m.trailing_zeros() as usize;
            m &= m - 1;
            if acc[j] >= p.v_th {
                act.compares += 1;
                fired[j] = true;
                any = true;
                spike_count[j] += 1;
                act.reg_toggles += 1;
                write_acc_at(acc, j, p.v_rest, act);
            }
        }
    }
    any
}

/// Full enable mask for `n` neurons over `words` mask words.
fn full_mask_words(n: usize) -> Vec<u64> {
    let words = n.div_ceil(64).max(1);
    let mut mask = vec![u64::MAX; words];
    let rem = n % 64;
    if rem != 0 {
        mask[words - 1] = (1u64 << rem) - 1;
    }
    if n == 0 {
        mask[0] = 0;
    }
    mask
}

// ---------------------------------------------------------------------------

/// One whole layer as a structure-of-arrays.
///
/// State layout: flat `acc` / `spike_count` vectors plus a multi-word
/// enable bitmask (bit `j % 64` of word `j / 64` = neuron `j` enabled), so
/// any layer width works — the paper's output layer has 10 neurons, the
/// MLP-shaped hidden layer 128.
///
/// Every mutator records exactly the [`ActivityCounters`] events the
/// per-neuron [`LifNeuronCore::tick`] would: adds, per-add saturations,
/// shift-subtract leaks, comparator evaluations and the Hamming distance of
/// every register write. Bit-exactness against a `Vec<LifNeuronCore>` is
/// pinned by `array_matches_core_reference` below.
#[derive(Debug, Clone)]
pub struct LifNeuronArray {
    acc: Vec<i32>,
    spike_count: Vec<u32>,
    /// Enable latch words; cleared by the pruning mask.
    enabled: Vec<u64>,
    params: LaneParams,
}

impl LifNeuronArray {
    /// Build an array sized to the config's *output* width — callers
    /// construct one per layer via [`crate::SnnConfig::layer_config`].
    pub fn new(cfg: &SnnConfig) -> Self {
        let n = cfg.n_outputs();
        LifNeuronArray {
            acc: vec![cfg.v_rest; n],
            spike_count: vec![0; n],
            enabled: full_mask_words(n),
            params: LaneParams::from_cfg(cfg),
        }
    }

    /// Number of neurons.
    pub fn len(&self) -> usize {
        self.acc.len()
    }

    /// True when the layer has no neurons (never for valid configs).
    pub fn is_empty(&self) -> bool {
        self.acc.is_empty()
    }

    /// Membrane potential of neuron `j`.
    pub fn acc(&self, j: usize) -> i32 {
        self.acc[j]
    }

    /// All membrane potentials (borrowed; no allocation).
    pub fn accs(&self) -> &[i32] {
        &self.acc
    }

    /// All membrane potentials (owned copy).
    pub fn membranes(&self) -> Vec<i32> {
        self.acc.clone()
    }

    /// All spike-count registers.
    pub fn spike_counts(&self) -> &[u32] {
        &self.spike_count
    }

    /// Enable latch of neuron `j`.
    pub fn enabled(&self, j: usize) -> bool {
        (self.enabled[j / 64] >> (j % 64)) & 1 == 1
    }

    /// True while at least one neuron is still enabled.
    pub fn any_enabled(&self) -> bool {
        self.enabled.iter().any(|&w| w != 0)
    }

    /// Drive the enable latches from the controller's pruning mask.
    pub fn set_enables(&mut self, enables: &[bool]) {
        debug_assert_eq!(enables.len(), self.acc.len());
        self.enabled.iter_mut().for_each(|w| *w = 0);
        for (j, &e) in enables.iter().enumerate() {
            self.enabled[j / 64] |= u64::from(e) << (j % 64);
        }
    }

    /// Synchronous reset of every neuron (new inference window); re-enables
    /// the whole array, like `NeuronCtrl::Reset` on each core.
    pub fn reset(&mut self, act: &mut ActivityCounters) {
        for j in 0..self.acc.len() {
            write_acc_at(&mut self.acc, j, self.params.v_rest, act);
        }
        self.spike_count.fill(0);
        self.enabled = full_mask_words(self.acc.len());
    }

    /// One BRAM row pulse: integrate `row[j]` into every *enabled* neuron
    /// with per-add saturation (ascending `j`, like the adder-tree fanout).
    #[inline]
    pub fn add_row(&mut self, row: &[i32], act: &mut ActivityCounters) {
        lane_add_row(&mut self.acc, &self.enabled, row, &self.params, act);
    }

    /// One CSR row pulse: integrate the retained `(column, weight)`
    /// entries into every *enabled* neuron (per-add saturation, ascending
    /// column) — see [`lane_add_sparse`] for the dense-equivalence
    /// contract.
    #[inline]
    pub fn add_row_sparse(&mut self, cols: &[u32], vals: &[i32], act: &mut ActivityCounters) {
        lane_add_sparse(&mut self.acc, &self.enabled, cols, vals, &self.params, act);
    }

    /// One `Leak` clock: shift-subtract decay on every enabled neuron.
    #[inline]
    pub fn leak_enabled(&mut self, act: &mut ActivityCounters) {
        lane_leak(&mut self.acc, &self.enabled, &self.params, act);
    }

    /// One `Fire` clock (`FireMode::EndOfStep`): evaluate the threshold
    /// comparator of every enabled neuron, setting `fired[j]` and
    /// hard-resetting on a crossing. `fired` must be pre-cleared.
    pub fn fire_check(&mut self, fired: &mut [bool], act: &mut ActivityCounters) {
        lane_fire_check(
            &mut self.acc,
            &mut self.spike_count,
            &self.enabled,
            fired,
            &self.params,
            act,
        );
    }

    /// Mid-integration combinational fire (`FireMode::Immediate`): only
    /// neurons whose accumulator is at/above threshold commit a `FireCheck`
    /// (and its comparator activity), exactly like the cycle path's
    /// `above_threshold()` pre-gate. Returns true when any neuron fired.
    /// `fired` must be pre-cleared.
    pub fn immediate_fire(&mut self, fired: &mut [bool], act: &mut ActivityCounters) -> bool {
        lane_immediate_fire(
            &mut self.acc,
            &mut self.spike_count,
            &self.enabled,
            fired,
            &self.params,
            act,
        )
    }
}

// ---------------------------------------------------------------------------

/// One layer × a whole sub-batch: per-image accumulator, spike-count and
/// enable planes over one shared calibration, addressed
/// `plane[b * width + j]` (lane-major, so each image's neuron state stays
/// contiguous for the row-apply inner loop).
///
/// This is the state behind [`crate::rtl::RtlCore::run_fast_batch`]: the
/// batched engine walks each weight row **once** per timestep and calls
/// [`LifBatchArray::add_row`] for every lane whose input fired, so the
/// row fetch is amortized over the batch while each lane's arithmetic —
/// the shared lane primitives above — stays bit-identical to a private
/// [`LifNeuronArray`] (pinned by `batch_array_matches_single_arrays`).
///
/// Pruning lives here too ([`LifBatchArray::latch_prune`]): a lane's
/// enable plane is driven from its own spike counts exactly like the
/// controller's mask update, so per-image gating never couples lanes.
#[derive(Debug, Clone)]
pub struct LifBatchArray {
    /// Neurons per lane (the layer width).
    n: usize,
    /// Enable mask words per lane.
    words: usize,
    lanes: usize,
    acc: Vec<i32>,
    spike_count: Vec<u32>,
    enabled: Vec<u64>,
    params: LaneParams,
}

impl LifBatchArray {
    /// Build `lanes` fresh lanes sized to the config's *output* width
    /// (callers construct one per layer via
    /// [`crate::SnnConfig::layer_config`]). Every lane starts reset:
    /// `v_rest` accumulators, zero counts, fully enabled.
    pub fn new(cfg: &SnnConfig, lanes: usize) -> Self {
        let n = cfg.n_outputs();
        let words = n.div_ceil(64).max(1);
        let lane_mask = full_mask_words(n);
        let mut enabled = Vec::with_capacity(words * lanes);
        for _ in 0..lanes {
            enabled.extend_from_slice(&lane_mask);
        }
        LifBatchArray {
            n,
            words,
            lanes,
            acc: vec![cfg.v_rest; n * lanes],
            spike_count: vec![0; n * lanes],
            enabled,
            params: LaneParams::from_cfg(cfg),
        }
    }

    /// Batch lanes held.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Neurons per lane.
    pub fn width(&self) -> usize {
        self.n
    }

    /// Lane `b`'s membrane potentials.
    pub fn accs(&self, b: usize) -> &[i32] {
        &self.acc[b * self.n..(b + 1) * self.n]
    }

    /// Lane `b`'s spike-count registers.
    pub fn spike_counts(&self, b: usize) -> &[u32] {
        &self.spike_count[b * self.n..(b + 1) * self.n]
    }

    /// True while at least one neuron of lane `b` is still enabled — the
    /// per-image BRAM gate.
    pub fn any_enabled(&self, b: usize) -> bool {
        self.enabled[b * self.words..(b + 1) * self.words].iter().any(|&w| w != 0)
    }

    /// One BRAM row pulse into lane `b` (per-add saturation, ascending `j`).
    #[inline]
    pub fn add_row(&mut self, b: usize, row: &[i32], act: &mut ActivityCounters) {
        lane_add_row(
            &mut self.acc[b * self.n..(b + 1) * self.n],
            &self.enabled[b * self.words..(b + 1) * self.words],
            row,
            &self.params,
            act,
        );
    }

    /// One CSR row pulse into lane `b` (per-add saturation, ascending
    /// column; see [`lane_add_sparse`]).
    #[inline]
    pub fn add_row_sparse(
        &mut self,
        b: usize,
        cols: &[u32],
        vals: &[i32],
        act: &mut ActivityCounters,
    ) {
        lane_add_sparse(
            &mut self.acc[b * self.n..(b + 1) * self.n],
            &self.enabled[b * self.words..(b + 1) * self.words],
            cols,
            vals,
            &self.params,
            act,
        );
    }

    /// One `Leak` clock on lane `b`.
    #[inline]
    pub fn leak_enabled(&mut self, b: usize, act: &mut ActivityCounters) {
        lane_leak(
            &mut self.acc[b * self.n..(b + 1) * self.n],
            &self.enabled[b * self.words..(b + 1) * self.words],
            &self.params,
            act,
        );
    }

    /// One `Fire` clock on lane `b` (`FireMode::EndOfStep`); `fired` must
    /// be pre-cleared and `width()` long.
    pub fn fire_check(&mut self, b: usize, fired: &mut [bool], act: &mut ActivityCounters) {
        lane_fire_check(
            &mut self.acc[b * self.n..(b + 1) * self.n],
            &mut self.spike_count[b * self.n..(b + 1) * self.n],
            &self.enabled[b * self.words..(b + 1) * self.words],
            fired,
            &self.params,
            act,
        );
    }

    /// Mid-integration combinational fire on lane `b`
    /// (`FireMode::Immediate`); `fired` must be pre-cleared.
    pub fn immediate_fire(
        &mut self,
        b: usize,
        fired: &mut [bool],
        act: &mut ActivityCounters,
    ) -> bool {
        lane_immediate_fire(
            &mut self.acc[b * self.n..(b + 1) * self.n],
            &mut self.spike_count[b * self.n..(b + 1) * self.n],
            &self.enabled[b * self.words..(b + 1) * self.words],
            fired,
            &self.params,
            act,
        )
    }

    /// Drive lane `b`'s enable plane from its own spike counts — the
    /// controller's pruning-mask update, applied at the same latch points
    /// the sequential engine applies it (fire clocks, and mid-walk
    /// Immediate fires). Clearing is idempotent, exactly like the
    /// controller's `enabled_count` guard.
    pub fn latch_prune(&mut self, b: usize, mode: PruneMode) {
        let PruneMode::AfterFires { after_spikes } = mode else { return };
        let counts = &self.spike_count[b * self.n..(b + 1) * self.n];
        let mask = &mut self.enabled[b * self.words..(b + 1) * self.words];
        for (j, &count) in counts.iter().enumerate() {
            if count >= after_spikes {
                mask[j / 64] &= !(1u64 << (j % 64));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SnnConfig {
        SnnConfig { v_th: 10, decay_shift: 1, acc_bits: 16, ..SnnConfig::paper() }
    }

    #[test]
    fn add_leak_fire_sequence() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: 7 }, &mut act);
        assert_eq!(n.acc(), 7);
        n.tick(NeuronCtrl::Leak, &mut act);
        assert_eq!(n.acc(), 4); // 7 - (7>>1)=3
        n.tick(NeuronCtrl::Add { weight: 7 }, &mut act);
        assert_eq!(n.acc(), 11);
        let fired = n.tick(NeuronCtrl::FireCheck, &mut act);
        assert!(fired);
        assert_eq!(n.acc(), 0);
        assert_eq!(n.spike_count(), 1);
    }

    #[test]
    fn disabled_neuron_is_inert_and_free() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.set_enabled(false);
        let before = act;
        n.tick(NeuronCtrl::Add { weight: 100 }, &mut act);
        n.tick(NeuronCtrl::Leak, &mut act);
        n.tick(NeuronCtrl::FireCheck, &mut act);
        assert_eq!(n.acc(), 0);
        assert_eq!(n.spike_count(), 0);
        assert_eq!(act, before, "disabled neuron must record zero activity");
    }

    #[test]
    fn reset_reenables() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: 25 }, &mut act);
        n.tick(NeuronCtrl::FireCheck, &mut act);
        n.set_enabled(false);
        n.tick(NeuronCtrl::Reset, &mut act);
        assert!(n.enabled());
        assert_eq!(n.acc(), 0);
        assert_eq!(n.spike_count(), 0);
    }

    #[test]
    fn saturation_is_counted() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&SnnConfig { acc_bits: 8, v_th: 100, ..cfg() });
        for _ in 0..3 {
            n.tick(NeuronCtrl::Add { weight: 120 }, &mut act);
        }
        // 120, then 240 -> clamp 127, then 127+120 -> clamp.
        assert_eq!(n.acc(), 127);
        assert_eq!(act.saturations, 2);
    }

    #[test]
    fn negative_membrane_decays_up() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: -9 }, &mut act);
        assert_eq!(n.acc(), -9);
        n.tick(NeuronCtrl::Leak, &mut act);
        // -9 - (-9>>1) = -9 - (-5) = -4
        assert_eq!(n.acc(), -4);
    }

    #[test]
    fn toggle_counting_tracks_hamming_distance() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: 0b1111 }, &mut act);
        assert_eq!(act.reg_toggles, 4); // 0 -> 0b1111 toggles 4 bits
    }

    /// The SoA array and a `Vec<LifNeuronCore>` must stay state- and
    /// activity-identical under random command streams — the foundation of
    /// the RTL core's fast path.
    #[test]
    fn array_matches_core_reference() {
        use crate::testutil::PropRunner;

        PropRunner::new("lif_array_equiv", 60).run(|g| {
            // Mostly narrow arrays, sometimes wider than one mask word so
            // the multi-word enable iteration is exercised too.
            let n = if g.rng.below(4) == 0 {
                g.rng.range_i32(65, 140) as usize
            } else {
                g.rng.range_i32(1, 12) as usize
            };
            let cfg = SnnConfig {
                topology: vec![784, n],
                v_th: g.rng.range_i32(5, 60),
                decay_shift: g.rng.range_i32(1, 4) as u32,
                // Narrow accumulator so per-add saturation gets exercised.
                acc_bits: g.rng.range_i32(8, 16) as u32,
                ..SnnConfig::paper()
            };
            let mut array = LifNeuronArray::new(&cfg);
            let mut cores: Vec<LifNeuronCore> =
                (0..n).map(|_| LifNeuronCore::new(&cfg)).collect();
            let mut act_a = ActivityCounters::default();
            let mut act_c = ActivityCounters::default();
            let mut fired_a = vec![false; n];

            for _ in 0..120 {
                match g.rng.below(6) {
                    0 => {
                        let row = g.vec_i32(n, -120, 120);
                        array.add_row(&row, &mut act_a);
                        for (j, c) in cores.iter_mut().enumerate() {
                            c.tick(NeuronCtrl::Add { weight: row[j] }, &mut act_c);
                        }
                    }
                    1 => {
                        array.leak_enabled(&mut act_a);
                        for c in cores.iter_mut() {
                            c.tick(NeuronCtrl::Leak, &mut act_c);
                        }
                    }
                    2 => {
                        fired_a.fill(false);
                        array.fire_check(&mut fired_a, &mut act_a);
                        for (j, c) in cores.iter_mut().enumerate() {
                            let f = c.tick(NeuronCtrl::FireCheck, &mut act_c);
                            assert_eq!(fired_a[j], f, "fire wire diverges at {j}");
                        }
                    }
                    3 => {
                        fired_a.fill(false);
                        array.immediate_fire(&mut fired_a, &mut act_a);
                        for (j, c) in cores.iter_mut().enumerate() {
                            let mut f = false;
                            if c.enabled() && c.above_threshold() {
                                f = c.tick(NeuronCtrl::FireCheck, &mut act_c);
                            }
                            assert_eq!(fired_a[j], f, "immediate fire diverges at {j}");
                        }
                    }
                    4 => {
                        let enables: Vec<bool> =
                            (0..n).map(|_| g.rng.next_u32() & 1 == 1).collect();
                        array.set_enables(&enables);
                        for (c, &e) in cores.iter_mut().zip(&enables) {
                            c.set_enabled(e);
                        }
                    }
                    _ => {
                        array.reset(&mut act_a);
                        for c in cores.iter_mut() {
                            c.tick(NeuronCtrl::Reset, &mut act_c);
                        }
                    }
                }
                for (j, c) in cores.iter().enumerate() {
                    assert_eq!(array.acc(j), c.acc(), "membrane diverges at {j}");
                    assert_eq!(array.spike_counts()[j], c.spike_count(), "count at {j}");
                    assert_eq!(array.enabled(j), c.enabled(), "enable at {j}");
                }
                assert_eq!(act_a, act_c, "activity counters diverge");
            }
        });
    }

    /// The CSR row pulse at threshold 0 must be state- and
    /// activity-identical to the dense row pulse — the per-entry
    /// foundation of the sparse sweep's bit-exactness — and above
    /// threshold 0 it must apply exactly the surviving subset.
    #[test]
    fn sparse_add_matches_dense_at_threshold_zero() {
        use crate::fixed::{SparseWeightLayer, WeightMatrix};
        use crate::testutil::PropRunner;

        PropRunner::new("lane_sparse_equiv", 60).run(|g| {
            let n = if g.rng.below(4) == 0 {
                g.rng.range_i32(65, 120) as usize
            } else {
                g.rng.range_i32(1, 14) as usize
            };
            let cfg = SnnConfig {
                topology: vec![784, n],
                v_th: g.rng.range_i32(5, 60),
                decay_shift: g.rng.range_i32(1, 4) as u32,
                acc_bits: g.rng.range_i32(8, 16) as u32,
                ..SnnConfig::paper()
            };
            let rows = 6usize;
            let m = WeightMatrix::from_rows(rows, n, 9, g.vec_i32(rows * n, -120, 120)).unwrap();
            let csr0 = SparseWeightLayer::from_dense(&m, 0);

            let mut dense = LifNeuronArray::new(&cfg);
            let mut sparse = LifNeuronArray::new(&cfg);
            let mut act_d = ActivityCounters::default();
            let mut act_s = ActivityCounters::default();
            let mut fired = vec![false; n];
            for round in 0..40 {
                let i = g.rng.below(rows as u32) as usize;
                let (cols, vals) = csr0.row(i);
                dense.add_row(m.row(i), &mut act_d);
                sparse.add_row_sparse(cols, vals, &mut act_s);
                if round % 7 == 3 {
                    // Random pruning mask: the enabled-gating must agree.
                    let enables: Vec<bool> =
                        (0..n).map(|_| g.rng.next_u32() & 1 == 1).collect();
                    dense.set_enables(&enables);
                    sparse.set_enables(&enables);
                }
                if round % 5 == 2 {
                    dense.leak_enabled(&mut act_d);
                    sparse.leak_enabled(&mut act_s);
                    fired.fill(false);
                    dense.fire_check(&mut fired, &mut act_d);
                    fired.fill(false);
                    sparse.fire_check(&mut fired, &mut act_s);
                }
                assert_eq!(dense.accs(), sparse.accs(), "membranes diverge");
                assert_eq!(act_d, act_s, "activity diverges at threshold 0");
            }

            // Above threshold 0 the sparse pulse applies exactly the
            // surviving entries: fewer (or equal) adds, and the membrane
            // equals a dense pulse of the pruned plane.
            let th = g.rng.range_i32(1, 100);
            let csr = SparseWeightLayer::from_dense(&m, th);
            let pruned = csr.to_dense();
            let mut via_sparse = LifNeuronArray::new(&cfg);
            let mut via_pruned_dense = LifNeuronArray::new(&cfg);
            let mut a_s = ActivityCounters::default();
            let mut a_d = ActivityCounters::default();
            for i in 0..rows {
                let (cols, vals) = csr.row(i);
                via_sparse.add_row_sparse(cols, vals, &mut a_s);
                via_pruned_dense.add_row(pruned.row(i), &mut a_d);
            }
            assert_eq!(via_sparse.accs(), via_pruned_dense.accs());
            assert!(
                a_s.adds <= a_d.adds,
                "sparse must never add more than the pruned dense plane"
            );
            assert_eq!(a_s.adds as usize, csr.nnz(), "one add per retained synapse");
        });
    }

    /// Every lane of a [`LifBatchArray`] must stay state- and
    /// activity-identical to a private [`LifNeuronArray`] driven with the
    /// same command stream — lanes are independent by construction, and a
    /// random interleaving of per-lane commands must never couple them.
    /// This is the foundation of `RtlCore::run_fast_batch`'s bit-exactness.
    #[test]
    fn batch_array_matches_single_arrays() {
        use crate::testutil::PropRunner;

        PropRunner::new("lif_batch_equiv", 40).run(|g| {
            let lanes = g.rng.range_i32(1, 7) as usize;
            // Mostly narrow layers, sometimes wider than one mask word.
            let n = if g.rng.below(4) == 0 {
                g.rng.range_i32(65, 100) as usize
            } else {
                g.rng.range_i32(1, 14) as usize
            };
            let cfg = SnnConfig {
                topology: vec![784, n],
                v_th: g.rng.range_i32(5, 60),
                decay_shift: g.rng.range_i32(1, 4) as u32,
                acc_bits: g.rng.range_i32(8, 16) as u32,
                ..SnnConfig::paper()
            };
            let prune = *g.choice(&[
                PruneMode::Off,
                PruneMode::AfterFires { after_spikes: 1 },
                PruneMode::AfterFires { after_spikes: 2 },
            ]);
            let mut batch = LifBatchArray::new(&cfg, lanes);
            let mut singles: Vec<LifNeuronArray> =
                (0..lanes).map(|_| LifNeuronArray::new(&cfg)).collect();
            let mut act_b: Vec<ActivityCounters> =
                vec![ActivityCounters::default(); lanes];
            let mut act_s: Vec<ActivityCounters> =
                vec![ActivityCounters::default(); lanes];
            let mut fired_b = vec![false; n];
            let mut fired_s = vec![false; n];

            for _ in 0..100 {
                // One random command on one random lane per round: the
                // interleaving across lanes is itself randomized.
                let b = g.rng.below(lanes as u32) as usize;
                match g.rng.below(5) {
                    0 => {
                        let row = g.vec_i32(n, -120, 120);
                        batch.add_row(b, &row, &mut act_b[b]);
                        singles[b].add_row(&row, &mut act_s[b]);
                    }
                    1 => {
                        batch.leak_enabled(b, &mut act_b[b]);
                        singles[b].leak_enabled(&mut act_s[b]);
                    }
                    2 => {
                        fired_b.fill(false);
                        fired_s.fill(false);
                        batch.fire_check(b, &mut fired_b, &mut act_b[b]);
                        singles[b].fire_check(&mut fired_s, &mut act_s[b]);
                        assert_eq!(fired_b, fired_s, "fire pattern diverges on lane {b}");
                    }
                    3 => {
                        fired_b.fill(false);
                        fired_s.fill(false);
                        let any_b = batch.immediate_fire(b, &mut fired_b, &mut act_b[b]);
                        let any_s = singles[b].immediate_fire(&mut fired_s, &mut act_s[b]);
                        assert_eq!(any_b, any_s, "immediate any-fire diverges on {b}");
                        assert_eq!(fired_b, fired_s, "immediate pattern diverges on {b}");
                    }
                    _ => {
                        // Prune latch: the single array mirrors the
                        // controller's mask update from its own counts.
                        batch.latch_prune(b, prune);
                        if let PruneMode::AfterFires { after_spikes } = prune {
                            let enables: Vec<bool> = (0..n)
                                .map(|j| {
                                    singles[b].enabled(j)
                                        && singles[b].spike_counts()[j] < after_spikes
                                })
                                .collect();
                            singles[b].set_enables(&enables);
                        }
                    }
                }
                for (lane, single) in singles.iter().enumerate() {
                    assert_eq!(batch.accs(lane), single.accs(), "membranes, lane {lane}");
                    assert_eq!(
                        batch.spike_counts(lane),
                        single.spike_counts(),
                        "counts, lane {lane}"
                    );
                    for j in 0..n {
                        let bit = batch.enabled[lane * batch.words + j / 64] >> (j % 64) & 1;
                        assert_eq!(bit == 1, single.enabled(j), "enable {j}, lane {lane}");
                    }
                    assert_eq!(batch.any_enabled(lane), single.any_enabled());
                    assert_eq!(act_b[lane], act_s[lane], "activity, lane {lane}");
                }
            }
        });
    }
}
