//! One LIF neuron core (paper Fig. 1): accumulator register, saturating
//! adder, shift-based decay unit, threshold comparator, spike-count
//! register and enable gating.
//!
//! The core is modelled two-phase: the controller presents a [`NeuronCtrl`]
//! command word (the decoded control signals for this clock) and `tick`
//! commits the posedge. All datapath activity is recorded into
//! [`ActivityCounters`].

use crate::config::SnnConfig;
use crate::fixed::leak;

use super::power::ActivityCounters;

/// Decoded per-clock control signals driven by the layer controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NeuronCtrl {
    /// Hold: no enable asserted this clock.
    Idle,
    /// `add_en`: integrate `weight` into the accumulator.
    Add { weight: i32 },
    /// `leak_en`: apply the shift-subtract decay.
    Leak,
    /// `fire_en`: evaluate the threshold comparator; fire & hard-reset when
    /// `acc ≥ V_th`.
    FireCheck,
    /// Synchronous reset (new inference window).
    Reset,
}

/// Architectural state of a single neuron core.
#[derive(Debug, Clone)]
pub struct LifNeuronCore {
    /// Membrane accumulator register (sign-extended to i32; physically
    /// `acc_bits` wide).
    acc: i32,
    /// Output spike count register (used by readout and pruning).
    spike_count: u32,
    /// Enable latch: cleared by the controller's pruning mask.
    enabled: bool,
    /// Fired-this-cycle flag (the `Fire` output wire).
    fired: bool,
    cfg_acc_bits: u32,
    cfg_decay_shift: u32,
    cfg_v_th: i32,
    cfg_v_rest: i32,
}

impl LifNeuronCore {
    pub fn new(cfg: &SnnConfig) -> Self {
        LifNeuronCore {
            acc: cfg.v_rest,
            spike_count: 0,
            enabled: true,
            fired: false,
            cfg_acc_bits: cfg.acc_bits,
            cfg_decay_shift: cfg.decay_shift,
            cfg_v_th: cfg.v_th,
            cfg_v_rest: cfg.v_rest,
        }
    }

    /// Membrane potential (the accumulator register).
    pub fn acc(&self) -> i32 {
        self.acc
    }

    /// Spike-count register.
    pub fn spike_count(&self) -> u32 {
        self.spike_count
    }

    /// Enable latch value.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The `Fire` wire: did the neuron fire on the last `tick`?
    pub fn fired(&self) -> bool {
        self.fired
    }

    /// Controller drives the enable latch (pruning mask).
    pub fn set_enabled(&mut self, en: bool) {
        self.enabled = en;
    }

    /// Commit one clock edge under `ctrl`. Returns the `Fire` wire value.
    pub fn tick(&mut self, ctrl: NeuronCtrl, act: &mut ActivityCounters) -> bool {
        self.fired = false;
        if !self.enabled && !matches!(ctrl, NeuronCtrl::Reset) {
            // Gated clock: a disabled neuron burns no dynamic power.
            return false;
        }
        match ctrl {
            NeuronCtrl::Idle => {}
            NeuronCtrl::Add { weight } => {
                let max = (1i32 << (self.cfg_acc_bits - 1)) - 1;
                let sum = i64::from(self.acc) + i64::from(weight);
                let clamped = sum.clamp(-(max as i64), max as i64) as i32;
                if clamped as i64 != sum {
                    act.saturations += 1;
                }
                act.adds += 1;
                self.write_acc(clamped, act);
            }
            NeuronCtrl::Leak => {
                let next = leak(self.acc, self.cfg_decay_shift);
                act.shifts += 1;
                act.adds += 1; // the subtract half of shift-subtract
                self.write_acc(next, act);
            }
            NeuronCtrl::FireCheck => {
                act.compares += 1;
                if self.acc >= self.cfg_v_th {
                    self.fired = true;
                    self.spike_count += 1;
                    act.reg_toggles += 1; // spike-count increment (approx.)
                    self.write_acc(self.cfg_v_rest, act);
                }
            }
            NeuronCtrl::Reset => {
                self.write_acc(self.cfg_v_rest, act);
                self.spike_count = 0;
                self.enabled = true;
                self.fired = false;
            }
        }
        self.fired
    }

    /// Combinational threshold check used in `FireMode::Immediate` during
    /// integration (comparator output without a clock commit).
    pub fn above_threshold(&self) -> bool {
        self.acc >= self.cfg_v_th
    }

    #[inline]
    fn write_acc(&mut self, next: i32, act: &mut ActivityCounters) {
        act.reg_toggles += u64::from(((self.acc as u32) ^ (next as u32)).count_ones());
        self.acc = next;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SnnConfig {
        SnnConfig { v_th: 10, decay_shift: 1, acc_bits: 16, ..SnnConfig::paper() }
    }

    #[test]
    fn add_leak_fire_sequence() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: 7 }, &mut act);
        assert_eq!(n.acc(), 7);
        n.tick(NeuronCtrl::Leak, &mut act);
        assert_eq!(n.acc(), 4); // 7 - (7>>1)=3
        n.tick(NeuronCtrl::Add { weight: 7 }, &mut act);
        assert_eq!(n.acc(), 11);
        let fired = n.tick(NeuronCtrl::FireCheck, &mut act);
        assert!(fired);
        assert_eq!(n.acc(), 0);
        assert_eq!(n.spike_count(), 1);
    }

    #[test]
    fn disabled_neuron_is_inert_and_free() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.set_enabled(false);
        let before = act;
        n.tick(NeuronCtrl::Add { weight: 100 }, &mut act);
        n.tick(NeuronCtrl::Leak, &mut act);
        n.tick(NeuronCtrl::FireCheck, &mut act);
        assert_eq!(n.acc(), 0);
        assert_eq!(n.spike_count(), 0);
        assert_eq!(act, before, "disabled neuron must record zero activity");
    }

    #[test]
    fn reset_reenables() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: 25 }, &mut act);
        n.tick(NeuronCtrl::FireCheck, &mut act);
        n.set_enabled(false);
        n.tick(NeuronCtrl::Reset, &mut act);
        assert!(n.enabled());
        assert_eq!(n.acc(), 0);
        assert_eq!(n.spike_count(), 0);
    }

    #[test]
    fn saturation_is_counted() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&SnnConfig { acc_bits: 8, v_th: 100, ..cfg() });
        for _ in 0..3 {
            n.tick(NeuronCtrl::Add { weight: 120 }, &mut act);
        }
        // 120, then 240 -> clamp 127, then 127+120 -> clamp.
        assert_eq!(n.acc(), 127);
        assert_eq!(act.saturations, 2);
    }

    #[test]
    fn negative_membrane_decays_up() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: -9 }, &mut act);
        assert_eq!(n.acc(), -9);
        n.tick(NeuronCtrl::Leak, &mut act);
        // -9 - (-9>>1) = -9 - (-5) = -4
        assert_eq!(n.acc(), -4);
    }

    #[test]
    fn toggle_counting_tracks_hamming_distance() {
        let mut act = ActivityCounters::default();
        let mut n = LifNeuronCore::new(&cfg());
        n.tick(NeuronCtrl::Add { weight: 0b1111 }, &mut act);
        assert_eq!(act.reg_toggles, 4); // 0 -> 0b1111 toggles 4 bits
    }
}
